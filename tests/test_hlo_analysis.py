"""Loop-aware HLO analyzer: exactness on closed-form programs.

The §Roofline numbers stand on this tool, so its trip-count recovery and
dot-FLOP attribution are pinned against analytically-known programs.
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 4, timeout: int = 420) -> str:
    code = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={devices}"\n'
        + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_scan_flops_exact():
    """flops(scan of L matmuls, sharded 2x2) == 2·M·N·K·L / shards exactly,
    while XLA's builtin counts the loop body once."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
        M, N, K, L = 256, 512, 384, 10

        def f(x, ws):
            def body(c, w):
                return jnp.einsum("mk,kn->mn",
                                  c @ jnp.ones((N, K), c.dtype), w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y

        x = jax.ShapeDtypeStruct((M, N), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, K, N), jnp.float32)
        with mesh:
            c = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P("data", "model")),
                NamedSharding(mesh, P(None, None, "model")))
            ).lower(x, ws).compile()
        cost = analyze_hlo(c.as_text())
        expect = (2 * M * N * K + 2 * M * K * N) * L / 4
        assert abs(cost.flops - expect) / expect < 1e-6, (cost.flops, expect)
        ca = c.cost_analysis()          # dict, or [dict] on older jax
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        builtin = float(ca.get("flops", 0))
        assert builtin < cost.flops / 5      # builtin counts body once
        assert 10 in cost.while_trip_counts.values()
        print("HLO_FLOPS_OK", cost.flops, expect)
    """)
    assert "HLO_FLOPS_OK" in out


def test_nested_scan_multipliers():
    """Nested scans multiply: outer 3 × inner 5 matmuls."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.hlo_analysis import analyze_hlo
        D, OUT, IN = 64, 3, 5

        def f(x, ws):
            def outer(c, _):
                def inner(cc, w):
                    return cc @ w, None
                c2, _ = jax.lax.scan(inner, c, ws)
                return c2, None
            y, _ = jax.lax.scan(outer, x, None, length=OUT)
            return y

        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((IN, D, D), jnp.float32)
        c = jax.jit(f).lower(x, ws).compile()
        cost = analyze_hlo(c.as_text())
        expect = 2 * D * D * D * OUT * IN
        assert abs(cost.flops - expect) / expect < 1e-6, (cost.flops, expect)
        print("NESTED_OK")
    """, devices=1)
    assert "NESTED_OK" in out


def test_collective_bytes_by_kind():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:4]).reshape(4,), ("data",))

        def f(x):
            # contraction over the sharded dim -> all-reduce of [D,D] f32
            return x.T @ x

        D = 128
        x = jax.ShapeDtypeStruct((512, D), jnp.float32)
        with mesh:
            c = jax.jit(f, in_shardings=NamedSharding(mesh, P("data", None)),
                        out_shardings=NamedSharding(mesh, P())
                        ).lower(x).compile()
        cost = analyze_hlo(c.as_text())
        ar = cost.collective_bytes.get("all-reduce", 0)
        assert ar == D * D * 4, cost.collective_bytes
        print("COLL_OK", cost.collective_bytes)
    """)
    assert "COLL_OK" in out


def test_model_flops_sanity():
    """Analytic MODEL_FLOPS ≈ 6·N·D for a dense train cell."""
    from repro.configs import get_config
    from repro.launch.roofline import model_flops
    from repro.models.config import SHAPES
    cfg = get_config("command_r_plus_104b")
    mf = model_flops(cfg, SHAPES["train_4k"])
    n, d = cfg.n_params(), 256 * 4096
    assert 0.9 * 6 * n * d < mf < 1.6 * 6 * n * d, (mf, 6 * n * d)
