"""Tenant-churn correctness: manager, scenarios, and the serving path.

The budget/floor property of ``test_baselines_budget`` extended to churn
sequences (tenants joining and retiring mid-run, fixed-Δt and
event-driven), plus the churn guarantees the scenario suite relies on:

  * ``sum(sizes) <= capacity`` with per-tenant ``c_min`` floors honored
    on every analyzed window of any join/retire schedule;
  * a retired tenant's quota is actually redistributed (survivors' total
    grows under capacity pressure) and its partitions drop to zero;
  * surviving tenants' SHARDS-sampled monitor curves are bit-identical
    to a run where the retired neighbor never existed (retirement must
    not perturb anyone else's salts or estimates);
  * the tiered serving path (``TieredKVCache``) carries joins through
    ``add_tenant`` → ``"join"`` reconfiguration events → quotas for the
    newcomer, with every per-tenant structure extended;
  * event-driven telemetry respects ``history_limit`` (the ``events``
    deque is bounded; ``reconfig_events`` keeps the true total).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from oracle import examples

from repro.cache import BlockPool, TieredKVCache
from repro.core import ECICacheManager
from repro.data.scenarios import SCENARIOS, churn, replay_scenario
from repro.data.traces import msr_trace

NAMES = ["wdev_0", "hm_1", "prn_1", "web_0", "prxy_0", "ts_0"]
SIM = dict(t_fast=1.0, t_slow=20.0, flush_cost=10.0)


def _assert_budget(mgr):
    d = mgr.history[-1]
    act = [i for i, t in enumerate(mgr.tenants) if t.active]
    assert int(d.sizes.sum()) <= mgr.capacity
    assert int(mgr.allocated_sizes().sum()) <= mgr.capacity
    # retired tenants hold nothing
    for i, t in enumerate(mgr.tenants):
        if not t.active:
            assert t.cache.capacity == 0
            assert d.sizes[i] == 0
    # c_min floors, capped by each tenant's useful mass and a fair share
    if act:
        floors = np.minimum(mgr.c_min,
                            [mgr.tenants[i].urd_size for i in act])
        floors = np.minimum(floors, mgr.capacity // len(act))
        assert np.all(d.sizes[act] >= floors), (d.sizes[act], floors)


# ops per window: 0 = steady, 1 = join a tenant, 2 = retire one
@settings(max_examples=examples(15), deadline=None)
@given(st.lists(st.integers(0, 2), min_size=2, max_size=5),
       st.booleans(), st.integers(0, 1000))
def test_budget_and_floors_under_churn(ops, event_driven, seed):
    rng = np.random.default_rng(seed)
    capacity, c_min = 300, 15
    mgr = ECICacheManager(capacity, NAMES[:3], c_min=c_min,
                          initial_blocks=20,
                          phase_detect=event_driven, reconfig_interval=1,
                          **SIM)
    alive = [0, 1, 2]
    for w, op in enumerate(ops):
        if op == 1 and len(mgr.tenants) < len(NAMES):
            i = mgr.add_tenant(NAMES[len(mgr.tenants)])
            alive.append(i)
        retiring = None
        if op == 2 and len(alive) > 1:
            retiring = alive.pop(int(rng.integers(0, len(alive))))
        traces = [None] * len(mgr.tenants)
        for i in alive:
            traces[i] = msr_trace(NAMES[i], 150, seed=31 * w + i)
        mgr.run_window(traces)
        assert mgr.history, "interval=1 must analyze every window"
        _assert_budget(mgr)
        if retiring is not None:
            assert not mgr.tenants[retiring].active
    # churn telemetry: every join/retire left an event (event-driven
    # managers also log their interval/phase triggers)
    n_churn = sum(1 for e in mgr.events if e.reason in ("join", "retire"))
    joined = len(mgr.tenants) - 3
    retired = sum(1 for t in mgr.tenants if not t.active)
    assert n_churn == joined + retired


def test_retired_quota_redistributed():
    """Under pressure (sum of demands > capacity), a retirement frees
    real blocks for the survivors."""
    capacity = 150
    mgr = ECICacheManager(capacity, NAMES[:3], c_min=10, initial_blocks=20,
                          **SIM)
    for w in range(2):
        mgr.run_window([msr_trace(nm, 600, seed=10 * w + i)
                        for i, nm in enumerate(NAMES[:3])])
    before = mgr.history[-1].sizes.copy()
    assert not mgr.history[-1].feasible       # genuinely constrained
    mgr.run_window([msr_trace(NAMES[0], 600, seed=100),
                    msr_trace(NAMES[1], 600, seed=101), None])
    after = mgr.history[-1].sizes
    assert after[2] == 0
    assert int(after[:2].sum()) > int(before[:2].sum())
    assert [e.reason for e in mgr.events].count("retire") == 1


@pytest.mark.parametrize("engine", ["batch", "lru"])
def test_survivor_curves_unchanged_by_neighbor_retirement(engine):
    """SHARDS-sampled monitor output for the survivors is bit-identical
    whether a third tenant retires mid-run or never existed at all."""
    kw = dict(c_min=10, initial_blocks=20, sample_rate=0.5, engine=engine,
              **SIM)
    m_churn = ECICacheManager(400, NAMES[:3], **kw)
    m_clean = ECICacheManager(400, NAMES[:2], **kw)

    def windows(w):
        return [msr_trace(nm, 400, seed=50 * w + i)
                for i, nm in enumerate(NAMES[:3])]

    for w in range(3):
        tr = windows(w)
        # the neighbor retires after window 0
        m_churn.run_window(tr if w == 0 else tr[:2] + [None])
        m_clean.run_window(tr[:2])
        assert m_churn.windows_analyzed == m_clean.windows_analyzed
        for i in range(2):
            a, b = m_churn.tenants[i], m_clean.tenants[i]
            assert a.urd_size == b.urd_size
            assert a.policy == b.policy
            grid = [1, 5, 20, 80, 200]
            assert [a.h_fn(c) for c in grid] == [b.h_fn(c) for c in grid]


def test_scenario_churn_replay_budget_every_window():
    """The churn scenario through ``replay_scenario``: budget + floors
    hold on every analyzed window, joins/retires land as events."""
    run = churn(seed=0)
    capacity = 2000

    def factory(names):
        return ECICacheManager(capacity, names, c_min=50, initial_blocks=50,
                               phase_detect=True, reconfig_interval=1,
                               **SIM)
    mgr, imap = replay_scenario(run, factory)
    assert mgr.windows_run == run.n_windows
    _assert_budget(mgr)
    reasons = [e.reason for e in mgr.events]
    assert reasons.count("join") == int(np.sum(run.join_windows > 0))
    assert reasons.count("retire") == int(
        np.sum(run.retire_windows < run.n_windows))
    # every scenario tenant was replayed under its own manager slot
    assert sorted(imap) == list(range(run.n_tenants))


def test_scenario_generator_labels_are_consistent():
    """Generator invariants the detection tests lean on: labels cover
    active cells, changes only at labeled phase starts, address spaces
    of different (tenant, phase) slots never collide."""
    for name, build in SCENARIOS.items():
        run = build(seed=1)
        for w in range(run.n_windows):
            for t in range(run.n_tenants):
                tr = run.traces[w][t]
                assert (tr is None) == (run.labels[w, t] < 0)
                if tr is not None:
                    lab = run.access_labels(w, t)
                    assert lab.shape == (len(tr),)
                    assert np.all(lab == run.labels[w, t])
        # a change window implies the label actually changed
        for (w, t) in run.true_changes():
            assert w > 0 and run.labels[w, t] != run.labels[w - 1, t]
        # per-tenant address spaces are disjoint across tenants
        for t in range(run.n_tenants):
            mine = np.concatenate(
                [run.traces[w][t].addrs for w in range(run.n_windows)
                 if run.traces[w][t] is not None])
            others = [np.concatenate(
                [run.traces[w][u].addrs for w in range(run.n_windows)
                 if run.traces[w][u] is not None])
                for u in range(run.n_tenants) if u != t]
            if others:
                assert not np.intersect1d(mine,
                                          np.concatenate(others)).size


def test_tiered_serving_churn():
    """Join on the serving path: every per-tenant structure extends, the
    next rebalance records the join and sizes the newcomer."""
    pool = BlockPool(64, 8, 2, 2, 16, allocate_device=False)
    mgr = ECICacheManager(48, ["a", "b"], c_min=4, initial_blocks=8,
                          **SIM)
    tiered = TieredKVCache(pool, mgr, window_events=10 ** 9)
    rng = np.random.default_rng(0)
    for r in range(30):
        for t in (0, 1):
            tiered.access_page(t, ("t", t, int(rng.integers(0, 12))),
                               fresh=(r == 0))
    i = tiered.add_tenant("late")
    assert i == 2
    assert len(tiered.stats) == 3 and i in tiered.quotas \
        and i in tiered.host_lru and i in tiered.host_quotas
    for r in range(30):
        for t in (0, 1, 2):
            tiered.access_page(t, ("t", t, int(rng.integers(0, 12))),
                               fresh=(t == 2 and r == 0))
    tiered.rebalance()
    assert [e.reason for e in mgr.events].count("join") == 1
    assert mgr.history[-1].trigger  # the join rode on the decision
    assert tiered.quotas[2] is not None and tiered.quotas[2] >= 0
    assert sum(q for q in tiered.quotas.values() if q) <= mgr.capacity
    # retirement through the serving path still redistributes
    tiered.finish_tenant(0)
    for r in range(10):
        for t in (1, 2):
            tiered.access_page(t, ("t", t, int(rng.integers(0, 12))))
    tiered.rebalance()
    assert tiered.quotas[0] == 0
    assert not mgr.tenants[0].active


def test_events_respect_history_limit():
    """The events deque is bounded by history_limit while the summary
    counter keeps the cumulative total."""
    mgr = ECICacheManager(300, NAMES[:2], c_min=10, initial_blocks=20,
                          phase_detect=True, reconfig_interval=1,
                          history_limit=3, **SIM)
    for w in range(8):
        mgr.run_window([msr_trace(nm, 120, seed=9 * w + i)
                        for i, nm in enumerate(NAMES[:2])])
    assert len(mgr.events) <= 3
    assert len(mgr.history) <= 3
    s = mgr.summary()
    assert s["reconfig_events"] >= 8          # one interval tick per window
    assert s["windows_run"] == 8
    assert s["windows_analyzed"] == 8
