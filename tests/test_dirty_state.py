"""Dirty-flag bookkeeping on a persistent cache across policy switches.

Regression tests for the ``c_dirty`` leaks: evictions from WT-path inserts
and RO invalidations previously never popped their shadow entries, so stale
dirty flags survived across long traces and later windows were overcharged
``flush_cost``.  The shadow map must mirror residency exactly, under every
policy-switch sequence the manager can produce.
"""
import numpy as np
import pytest

from repro.core import Trace, WritePolicy, simulate, simulate_batch
from repro.core.simulator import LRUCache


def _tr(pairs):
    addrs = np.array([a for a, _ in pairs], dtype=np.int64)
    reads = np.array([r for _, r in pairs], dtype=bool)
    return Trace(addrs, reads)


def _dirty_state(cache):
    return dict(cache._od)


def test_wt_write_leaves_block_clean():
    """Write-through propagates synchronously: the cached copy is clean,
    so a later eviction of that block must NOT charge a flush."""
    c = LRUCache(1)
    t = _tr([(1, False), (2, True)])   # WT write installs 1; read 2 evicts it
    r = simulate(t, 1, WritePolicy.WT, flush_cost=100.0, cache=c)
    assert r.total_latency == pytest.approx(1.2 + 20.0)   # no flush charged
    assert _dirty_state(c) == {2: False}


def test_wt_write_hit_cleans_previously_dirty_block():
    """WB dirties a block; a WT write to it (policy switched between
    windows) re-propagates it -> clean; evicting it later is flush-free."""
    c = LRUCache(1)
    simulate(_tr([(1, False)]), 1, WritePolicy.WB, flush_cost=100.0, cache=c)
    assert _dirty_state(c) == {1: True}
    simulate(_tr([(1, False)]), 1, WritePolicy.WT, flush_cost=100.0, cache=c)
    assert _dirty_state(c) == {1: False}
    r = simulate(_tr([(2, True)]), 1, WritePolicy.WT, flush_cost=100.0,
                 cache=c)
    assert r.total_latency == pytest.approx(20.0)         # eviction, no flush


def test_wt_insert_eviction_pops_and_charges_dirty_block():
    """A dirty block (from a WB window) evicted by a WT write-miss insert
    must charge its flush once and drop the shadow entry — not leak it."""
    c = LRUCache(1)
    simulate(_tr([(1, False)]), 1, WritePolicy.WB, flush_cost=100.0, cache=c)
    r = simulate(_tr([(2, False)]), 1, WritePolicy.WT, flush_cost=100.0,
                 cache=c)
    assert r.total_latency == pytest.approx(1.2 + 100.0)  # flush exactly once
    assert _dirty_state(c) == {2: False}
    # the evicted block's stale flag must not resurface: re-reading 1
    # (clean install, evicts clean 2) and then 3 (evicts clean 1) charges
    # two misses and zero flushes
    r2 = simulate(_tr([(1, True), (3, True)]), 1, WritePolicy.WB,
                  flush_cost=100.0, cache=c)
    assert r2.total_latency == pytest.approx(40.0)
    assert _dirty_state(c) == {3: False}


def test_ro_invalidation_pops_dirty_flag():
    """RO write invalidates a dirty cached copy; when the block is later
    re-installed clean and evicted, no stale flush may be charged."""
    c = LRUCache(1)
    simulate(_tr([(1, False)]), 1, WritePolicy.WB, flush_cost=100.0, cache=c)
    assert _dirty_state(c) == {1: True}
    r = simulate(_tr([(1, False)]), 1, WritePolicy.RO, flush_cost=100.0,
                 cache=c)
    assert r.write_hits == 1 and len(c) == 0
    # re-install 1 via read miss, then evict via another read miss
    r2 = simulate(_tr([(1, True), (2, True)]), 1, WritePolicy.RO,
                  flush_cost=100.0, cache=c)
    assert r2.total_latency == pytest.approx(40.0)        # no stale flush
    assert _dirty_state(c) == {2: False}


def test_long_trace_policy_switches_no_leak():
    """Randomized policy switches on one persistent cache: the shadow map
    (rebuilt each call from the LRU) must match what the batch engine
    reconstructs — any stale leak would diverge flush accounting."""
    rng = np.random.default_rng(7)
    c1, c2 = LRUCache(6), LRUCache(6)
    for w in range(12):
        n = int(rng.integers(1, 40))
        t = Trace(rng.integers(0, 10, n).astype(np.int64),
                  rng.random(n) < 0.5)
        pol = [WritePolicy.WB, WritePolicy.WT, WritePolicy.RO][w % 3]
        r1 = simulate(t, 6, pol, flush_cost=10.0, cache=c1)
        r2 = simulate_batch(t, 6, pol, flush_cost=10.0, cache=c2)
        assert r1.total_latency == pytest.approx(r2.total_latency), w
        assert r1.cache_writes == r2.cache_writes, w
        assert list(c1._od.items()) == list(c2._od.items()), w
