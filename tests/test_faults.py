"""Fault-injection harness + graceful-degradation ladder tests.

Three layers of coverage:

  * unit — the decision guard's invariant checks, the ingest validator's
    ``TraceError`` coordinates, and each degrade counter incrementing
    exactly once per injected event;
  * differential — a manager with a *disabled* fault plan is bit-identical
    to one with no plan at all (the default-off contract), and a faulted
    run reconverges to the no-fault decisions within the documented K
    windows after the last fault clears;
  * chaos (hypothesis) — random seeded ``FaultPlan.chaos`` schedules: a
    tolerant manager never raises, never actuates a guard-violating
    decision, and always reconverges.  The nightly job deepens the sweep
    via ``HYP_EXAMPLES_SCALE``.

Plus the serving-tier half: an HBM-pool crash drops residents (dirty loss
accounted), re-routes traffic to the host tier, demotes WB tenants, and
the engine aborts/requeues in-flight requests under admission control.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from oracle import examples
from repro.cache import BlockPool, TieredKVCache
from repro.core import (ECICacheManager, FaultPlan, FaultSpec, GuardReport,
                        InjectedFault, Trace, TraceError, WritePolicy,
                        validate_decision, validate_trace_arrays)
from repro.core.manager import AnalyzerDecision, DegradeEvent
from repro.core.partitioner import PartitionResult

SIM = dict(t_fast=1.0, t_slow=20.0)


def mk_trace(seed: int, n: int = 400, spread: int = 120) -> Trace:
    rng = np.random.default_rng(seed)
    return Trace(rng.integers(0, spread, n), rng.random(n) < 0.6, f"t{seed}")


def mk_manager(faults=None, capacity=6000, names=("a", "b", "c"), **kw):
    kw.setdefault("c_min", 500)
    return ECICacheManager(capacity, list(names), faults=faults,
                           **SIM, **kw)


def run_windows(mgr, n_windows, n_tenants=3, base=100):
    for w in range(n_windows):
        mgr.run_window([mk_trace(base + 10 * w + i) for i in range(n_tenants)])
    return mgr


def degrade_events(mgr, reason=None):
    evs = [e for e in mgr.events if isinstance(e, DegradeEvent)]
    return evs if reason is None else [e for e in evs if e.reason == reason]


# =========================================================== guard (unit)
def _decision(sizes, policies=None, latency=0.0, hit_ratios=None,
              sizes2=None, policies2=None):
    sizes = np.asarray(sizes)
    if policies is None:
        policies = [WritePolicy.WB] * len(sizes)
    hr = np.zeros(len(sizes)) if hit_ratios is None else np.asarray(
        hit_ratios, dtype=np.float64)
    part = PartitionResult(sizes, True, latency, hr)
    return AnalyzerDecision(sizes, policies, True, part, sizes2=sizes2,
                            policies2=policies2)


def test_guard_passes_clean_decision():
    rep = validate_decision(_decision([10, 20, 30]), capacity=100)
    assert rep.ok and rep.violations == ()


@pytest.mark.parametrize("sizes,msg", [
    ([60, 60], "exceed capacity"),
    ([-5, 10], "negative L1"),
    ([np.nan, 10], "non-finite L1"),
    ([np.inf, 10], "non-finite L1"),
])
def test_guard_flags_bad_sizes(sizes, msg):
    rep = validate_decision(_decision(sizes), capacity=100)
    assert not rep.ok and any(msg in v for v in rep.violations)


def test_guard_flags_l2_overflow_only_when_l2_exists():
    d = _decision([10], sizes2=np.array([999]), policies2=[WritePolicy.WB])
    assert validate_decision(d, capacity=100, capacity2=0).ok
    rep = validate_decision(d, capacity=100, capacity2=50)
    assert any("L2 sizes exceed" in v for v in rep.violations)


def test_guard_flags_non_finite_objective_and_hit_ratios():
    rep = validate_decision(_decision([10], latency=np.nan), capacity=100)
    assert any("latency" in v for v in rep.violations)
    rep = validate_decision(_decision([10], hit_ratios=[np.inf]),
                            capacity=100)
    assert any("hit ratios" in v for v in rep.violations)
    rep = validate_decision(_decision([10], hit_ratios=[1.5]), capacity=100)
    assert any("outside [0, 1]" in v for v in rep.violations)


def test_guard_flags_invalid_policy():
    rep = validate_decision(_decision([10], policies=["wb"]), capacity=100)
    assert any("invalid L1 policy" in v for v in rep.violations)


def test_guard_floor_checks():
    d = _decision([5, 50])
    # floor violated for tenant 0
    rep = validate_decision(d, capacity=100, floors=np.array([20, 20]))
    assert any("floor violated for tenants [0]" in v for v in rep.violations)
    # floors that do not fit the budget are definitionally unsatisfiable
    assert validate_decision(d, capacity=100, floors=np.array([20, 20]),
                             floor_budget=30).ok
    # a negative floor means the monitor reported a corrupt URD
    rep = validate_decision(d, capacity=100, floors=np.array([-7, 0]))
    assert any("corrupt URD" in v for v in rep.violations)


def test_guard_report_default_ok():
    assert GuardReport().ok


# ================================================== ingest TraceError(s)
def test_trace_error_carries_coordinates():
    with pytest.raises(TraceError) as ei:
        validate_trace_arrays(np.array([1, -4]), np.array([True, False]),
                              tenant=7, window=13)
    assert ei.value.tenant == 7 and ei.value.window == 13
    assert "(tenant=7, window=13)" in str(ei.value)


@pytest.mark.parametrize("addrs,reads,msg", [
    (np.array([[1]]), np.array([[True]]), "1-D"),
    (np.array([1, 2]), np.array([True]), "length"),
    (np.array([1.5]), np.array([True]), "non-integer"),
    (np.array([-3]), np.array([True]), "negative block address"),
    (np.array([1]), np.array([1.0]), "op codes must be bool"),
    (np.array([1, 2]), np.array([1, 2], np.int8), "unknown op code 2"),
])
def test_ingest_validator_catches_each_corruption(addrs, reads, msg):
    with pytest.raises(TraceError, match=msg):
        validate_trace_arrays(addrs, reads)


def test_ingest_validator_accepts_valid_and_empty():
    validate_trace_arrays(np.array([], np.int64), np.array([], bool))
    validate_trace_arrays(np.array([3, 1]), np.array([0, 1], np.int64))


def test_manager_record_raises_with_coordinates():
    mgr = run_windows(mk_manager(), 2)
    with pytest.raises(TraceError) as ei:
        mgr.record(1, np.array([-1]), np.array([True]))
    assert ei.value.tenant == 1 and ei.value.window == 2


# ===================================== default-off bit-identity contract
def test_disabled_plan_is_bit_identical():
    base = run_windows(mk_manager(), 8)
    off = run_windows(mk_manager(faults=FaultPlan((), seed=3)), 8)
    sb, so = base.summary(), off.summary()
    assert set(sb) == set(so)
    for k in sb:
        assert np.array_equal(sb[k], so[k]), k
    for tb, to in zip(base.tenants, off.tenants):
        assert tb.cache.capacity == to.cache.capacity
        assert tb.policy is to.policy
    for db, do in zip(base.history, off.history):
        assert np.array_equal(db.sizes, do.sizes)
        assert db.policies == do.policies
    assert off.summary()["degrade_events"] == 0


# ============================== counters increment exactly once per event
def test_poison_counts_once_and_quarantines_tenant_window():
    plan = FaultPlan((FaultSpec("poison", window=2, tenant=1),), seed=1)
    mgr = run_windows(mk_manager(faults=plan), 5)
    s = mgr.summary()
    assert s["poisoned_windows"] == 1 and s["degrade_events"] == 1
    (ev,) = degrade_events(mgr, "poisoned")
    assert ev.window == 2 and ev.tenant == 1
    assert s["guard_violations_actuated"] == 0


def test_straggler_counts_per_window_and_defers():
    plan = FaultPlan((FaultSpec("straggler", window=1, tenant=0,
                                duration=2),), seed=1)
    mgr = run_windows(mk_manager(faults=plan), 5)
    s = mgr.summary()
    assert s["straggler_windows"] == 2
    assert [e.window for e in degrade_events(mgr, "straggler")] == [1, 2]
    # while held, the tenant keeps its last-known-good size
    held_dec = mgr.history[1]
    assert held_dec.held == (0,) and 0 in held_dec.deferred


def test_tier_loss_counts_once_with_dirty_blocks_and_recovery():
    plan = FaultPlan((FaultSpec("tier_loss", window=3, level=1,
                                duration=2),), seed=1)
    mgr = run_windows(mk_manager(faults=plan), 9)
    s = mgr.summary()
    assert s["tier_failures"] == 1
    (loss,) = degrade_events(mgr, "tier_loss")
    (rec,) = degrade_events(mgr, "tier_recover")
    assert loss.window == 3 and loss.level == 1
    assert rec.window == 5                    # duration 2: down for 3, 4
    assert s["dirty_loss"] == loss.blocks > 0
    # while down the L1 partition is empty; it refills after recovery
    assert all(sz == 0 for sz in mgr.history[3].sizes)
    assert any(sz > 0 for sz in mgr.history[8].sizes)


def test_tier_loss_demotes_wb_for_cooldown_then_restores():
    plan = FaultPlan((FaultSpec("tier_loss", window=3, level=1),), seed=1)
    mgr = run_windows(mk_manager(faults=plan, demote_cooldown=2), 9)
    pol = [d.policies[0] for d in mgr.history]
    assert pol[2] is WritePolicy.WB           # before the crash
    # crash window + cooldown analyzes after recovery stay demoted
    assert pol[3] is WritePolicy.WT
    assert pol[4] is WritePolicy.WT and pol[5] is WritePolicy.WT
    assert pol[6] is WritePolicy.WB           # cooldown expired


def test_pipeline_retry_succeeds_in_rung_without_stepdown():
    plan = FaultPlan((FaultSpec("pipeline", window=2, rung="host",
                                count=1),), seed=1)
    mgr = run_windows(mk_manager(faults=plan, retry_limit=2), 5)
    s = mgr.summary()
    assert s["host_stepdowns"] == 0 and s["lkg_decisions"] == 0
    assert s["degrade_events"] == 0


def test_pipeline_exhaustion_steps_down_to_per_tenant_rung():
    plan = FaultPlan((FaultSpec("pipeline", window=2, rung="host",
                                count=99),), seed=1)
    mgr = run_windows(mk_manager(faults=plan, retry_limit=1), 5)
    s = mgr.summary()
    assert s["host_stepdowns"] == 1
    (ev,) = degrade_events(mgr, "stepdown")
    assert ev.window == 2 and ev.rung == "host"
    # the per-tenant rung still produced a full decision
    assert not mgr.history[2].quarantined
    assert s["guard_violations_actuated"] == 0


def test_device_rung_failure_steps_down_to_host():
    plan = FaultPlan((FaultSpec("pipeline", window=2, rung="device",
                                count=99),), seed=1)
    mgr = run_windows(mk_manager(faults=plan, retry_limit=1,
                                 pipeline="device"), 4)
    s = mgr.summary()
    assert s["device_stepdowns"] == 1
    assert s["host_stepdowns"] == 0
    (ev,) = degrade_events(mgr, "stepdown")
    assert ev.window == 2 and ev.rung == "device"
    # the fused host rung still produced a full decision
    assert not mgr.history[2].quarantined
    assert s["guard_violations_actuated"] == 0


def test_sharded_rung_failure_steps_down_to_device():
    """A per-shard launch failure inside the mesh program kills the whole
    sharded rung for the window: exactly one sharded→device step-down,
    the single-device rung still delivers a full decision."""
    plan = FaultPlan((FaultSpec("pipeline", window=2, rung="sharded",
                                count=99),), seed=1)
    mgr = run_windows(mk_manager(faults=plan, retry_limit=1,
                                 pipeline="sharded"), 4)
    s = mgr.summary()
    assert s["sharded_stepdowns"] == 1
    assert s["device_stepdowns"] == 0
    assert s["host_stepdowns"] == 0
    (ev,) = degrade_events(mgr, "stepdown")
    assert ev.window == 2 and ev.rung == "sharded"
    assert not mgr.history[2].quarantined
    assert s["guard_violations_actuated"] == 0


def test_all_rungs_dead_falls_back_to_last_known_good():
    plan = FaultPlan((FaultSpec("pipeline", window=2, count=99),), seed=1)
    mgr = run_windows(mk_manager(faults=plan, retry_limit=0), 5)
    s = mgr.summary()
    assert s["host_stepdowns"] == 1
    assert s["tenant_quarantines"] == 3       # every solo analyze died too
    assert s["lkg_decisions"] == 1
    dec = mgr.history[2]
    assert dec.quarantined and dec.degraded == "monitor_outage"
    # LKG reissues the sizes that were current going into the window
    assert np.array_equal(dec.sizes, mgr.history[1].sizes)


def test_curve_corruption_quarantined_by_guard():
    for mode in (0, 1, 2):                    # NaN / inf heights, bad URD
        plan = FaultPlan((FaultSpec("curve_nan", window=3, tenant=1,
                                    param=mode),), seed=1)
        mgr = run_windows(mk_manager(faults=plan), 6)
        s = mgr.summary()
        assert s["guard_quarantines"] == 1, mode
        assert s["guard_violations_observed"] >= 1
        assert s["guard_violations_actuated"] == 0
        assert s["lkg_decisions"] == 1
        dec = mgr.history[3]
        assert dec.quarantined and dec.degraded == "guard_quarantine"
        assert len(dec.guard) >= 1
        # the corrupted pass's Alg.-3 policy flips must not leak
        assert dec.policies[1] is mgr.history[2].policies[1]


def test_intolerant_manager_counts_actuated_violations():
    plan = FaultPlan((FaultSpec("curve_nan", window=3, tenant=1),), seed=1)
    mgr = run_windows(mk_manager(faults=plan, fault_tolerant=False), 6)
    s = mgr.summary()
    assert s["guard_violations_actuated"] == 1
    assert s["guard_quarantines"] == 0
    assert len(mgr.history[3].guard) >= 1     # violation shipped, flagged


def test_sampled_violation_retries_exact_before_quarantine():
    plan = FaultPlan((FaultSpec("curve_nan", window=3, tenant=0),), seed=1)
    mgr = mk_manager(faults=plan, sample_rate=0.3)
    run_windows(mgr, 6)
    s = mgr.summary()
    assert s["sampled_exact_retries"] == 1    # corruption survives the
    assert s["guard_quarantines"] == 1        # exact retry -> quarantine
    assert s["guard_violations_actuated"] == 0


def test_injected_fault_is_runtime_error():
    assert issubclass(InjectedFault, RuntimeError)
    with pytest.raises(ValueError):
        FaultSpec("bogus", window=0)
    with pytest.raises(ValueError):
        FaultSpec("poison", window=0, duration=0)


# ======================================================== reconvergence
def _final_state(mgr):
    return ([t.cache.capacity for t in mgr.tenants],
            [t.policy for t in mgr.tenants])


def test_standard_plan_reconverges_within_k():
    plan = FaultPlan.standard(3, 8, seed=1)
    k = plan.reconverge_bound(demote_cooldown=2)
    n = plan.last_fault_window() + k + 1
    base = run_windows(mk_manager(), n)
    faulted = run_windows(mk_manager(faults=plan), n)
    assert _final_state(base) == _final_state(faulted)
    assert faulted.summary()["guard_violations_actuated"] == 0


@settings(max_examples=examples(10), deadline=None)
@given(st.integers(0, 10**6))
def test_chaos_never_raises_never_actuates_garbage(seed):
    """Random fault schedules: the tolerant manager survives anything the
    plan throws at it and what it actuates always passes the guard."""
    plan = FaultPlan.chaos(3, 12, seed=seed, max_faults=4)
    n = plan.last_fault_window() + plan.reconverge_bound(2) + 1
    base = run_windows(mk_manager(), n, base=seed % 1000)
    faulted = run_windows(mk_manager(faults=plan), n, base=seed % 1000)
    s = faulted.summary()
    assert s["guard_violations_actuated"] == 0
    assert _final_state(base) == _final_state(faulted)
    # every non-quarantined decision in the run satisfies the invariants
    for d in faulted.history:
        if not d.quarantined:
            assert validate_decision(d, faulted.capacity,
                                     faulted.capacity2).ok


@settings(max_examples=examples(3), deadline=None)
@given(st.integers(0, 10**6))
def test_chaos_sharded_pipeline_reconverges(seed):
    """Chaos schedules against the sharded top rung (``FaultPlan.chaos``
    now draws ``rung="sharded"`` pipeline faults): the tolerant
    sharded-pipeline manager steps down the full ladder as needed and
    reconverges to the no-fault sharded run within the documented K."""
    plan = FaultPlan.chaos(3, 10, seed=seed, max_faults=3)
    n = plan.last_fault_window() + plan.reconverge_bound(2) + 1
    base = run_windows(mk_manager(pipeline="sharded"), n,
                       base=seed % 1000)
    faulted = run_windows(mk_manager(faults=plan, pipeline="sharded"), n,
                          base=seed % 1000)
    s = faulted.summary()
    assert s["guard_violations_actuated"] == 0
    assert _final_state(base) == _final_state(faulted)


@pytest.mark.slow
@settings(max_examples=examples(40), deadline=None)
@given(st.integers(0, 10**9), st.integers(2, 5))
def test_chaos_deep_sweep(seed, n_tenants):
    """Nightly: wider tenant counts and denser fault schedules."""
    plan = FaultPlan.chaos(n_tenants, 14, seed=seed, max_faults=6)
    n = plan.last_fault_window() + plan.reconverge_bound(2) + 1
    names = [f"t{i}" for i in range(n_tenants)]
    base = run_windows(mk_manager(names=names), n, n_tenants=n_tenants,
                       base=seed % 1000)
    faulted = run_windows(mk_manager(faults=plan, names=names), n,
                          n_tenants=n_tenants, base=seed % 1000)
    assert faulted.summary()["guard_violations_actuated"] == 0
    assert _final_state(base) == _final_state(faulted)


# ============================================== serving tiers + engine
def _tiered(capacity=64, capacity2=128, n_pages=64, **kw):
    mgr = ECICacheManager(capacity, ["a", "b"], c_min=4,
                          capacity2=capacity2, fault_tolerant=True,
                          demote_cooldown=1, **SIM, **kw)
    pool = BlockPool(n_pages, 16, 1, 1, 8, allocate_device=False)
    return TieredKVCache(pool, mgr, window_events=10**9), pool, mgr


def test_pool_crash_drops_dirty_and_reroutes():
    tk, pool, mgr = _tiered()
    for k in range(20):
        tk.access_page(0, ("a", k), fresh=True)
        tk.access_page(1, ("b", k), fresh=True)
    out = tk.fail_tier(1)
    assert out == {"dropped": 40, "dirty": 40}
    assert tk.tier_down(1) and not pool.meta and not pool.by_key
    assert len(pool.free) == pool.n_pages
    # WB tenants demoted at the tiered layer too
    assert all(p is WritePolicy.WT for p in tk.policies.values())
    assert mgr.summary()["dirty_loss"] == 40
    # traffic re-routes: no HBM allocation while down
    assert tk.access_page(0, ("a", 100), fresh=True) == "host"
    assert tk.access_page(0, ("a", 100), fresh=False) == "host"
    assert not pool.meta
    # recovery restores routing; a second fail_tier while down is a no-op
    assert tk.fail_tier(1) == {"dropped": 0, "dirty": 0}
    tk.recover_tier(1)
    assert not tk.tier_down(1)
    s = tk.summary()
    assert s["tier_failures"] == 1 and s["dropped_pages"] == 40
    assert s["dirty_loss"] == 40


def test_host_tier_crash_requires_managed_and_drops_pages():
    tk, pool, mgr = _tiered()
    for k in range(10):                       # RO-style host residency
        tk._host_insert(0, ("a", k))
    out = tk.fail_tier(2)
    assert out["dropped"] == 10 and out["dirty"] == 0
    # while down, host lookups miss and inserts drop
    assert not tk._host_materialized(0, ("a", 1))
    tk._host_insert(0, ("a", 99))
    assert sum(len(q) for q in tk.host_lru.values()) == 0
    tk.recover_tier(2)

    tk2, _, _ = _tiered(capacity2=0)
    with pytest.raises(ValueError, match="managed host"):
        tk2.fail_tier(2)


def test_engine_aborts_and_requeues_over_pool_crash():
    pytest.importorskip("jax")
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.models.attention import build_heads
    from repro.serve.engine import MultiTenantEngine, Request

    cfg = get_smoke_config("qwen3_0_6b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), tp=1)
    _, hkv = build_heads(cfg, 1)
    mgr = ECICacheManager(128, ["t0"], c_min=8, initial_blocks=32,
                          fault_tolerant=True, **SIM)
    pool = BlockPool(256, 8, cfg.n_layers, hkv, cfg.head_dim,
                     dtype=jnp.float32)
    tiered = TieredKVCache(pool, mgr, window_events=10**9)
    eng = MultiTenantEngine(cfg, params, tiered, page_size=8,
                            max_pages_per_seq=16)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    eng.submit(Request(tenant=0, prompt=prompt, max_new_tokens=6))
    eng.step()                                # prefill + first decode
    assert eng.active and not eng.completed

    tiered.fail_tier(1)
    eng.step()                                # admission control kicks in
    assert eng.aborted_restarts == 1
    assert not eng.active and len(eng.waiting) == 1
    eng.step()                                # still down: nothing admitted
    assert not eng.active and not eng.completed

    tiered.recover_tier(1)
    eng.run(32)
    assert len(eng.completed) == 1
    done = eng.completed[0]
    assert len(done.generated) == 6 and done.done
