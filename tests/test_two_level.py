"""Two-level (ETICA) hierarchy: batch engine ≡ two-level interpreter.

Property tests assert exact agreement of per-level hits, write hits, cache
writes, latency and the final per-level LRU states over random traces ×
(C1, C2) capacities × per-level policies, cold and across warm multi-window
chains; plus the degenerate ``C2 == 0`` identity with the single-level
scheme, the device port of the RO eviction-token loop, the kernel's
both-level residency masks, the two-stage Eq.-2 solver, and the manager's
end-to-end engine equivalence.  Engine comparisons run through the shared
differential oracle harness (``tests/oracle.py``).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from oracle import (EngineDiff, RESULT_FIELDS, assert_results_equal,
                    assert_states_equal, examples, mk_trace, trace_strategy)
from repro.core import (ECICacheManager, Trace, WritePolicy,
                        assign_write_policy_levels, build_hit_ratio_function,
                        greedy_allocate, make_manager, reuse_distances,
                        ro_token_replay_device, simulate, simulate_batch,
                        two_level_solve)
from repro.core.batch_sim import _ro_token_replay
from repro.core.simulator import LRUCache, rebalance_levels
from repro.data.traces import msr_trace

POLICIES = [WritePolicy.WB, WritePolicy.WT, WritePolicy.RO]


def two_level_strategy(max_n=50, max_addr=8):
    return trace_strategy(max_n=max_n, max_addr=max_addr)


# ------------------------------------------------ engine ≡ oracle (cold)
@settings(max_examples=examples(200), deadline=None)
@given(two_level_strategy(), st.integers(0, 5), st.integers(0, 5),
       st.sampled_from(POLICIES), st.sampled_from(POLICIES),
       st.sampled_from([0.0, 10.0]))
def test_two_level_batch_equals_simulate_cold(trace_list, c1, c2, p1, p2,
                                              flush):
    EngineDiff([c1], [p1], [c2], [p2],
               flush=flush).run_window([mk_trace(trace_list)])


@settings(max_examples=examples(50), deadline=None)
@given(st.lists(st.tuples(two_level_strategy(max_n=30), st.integers(0, 5),
                          st.integers(0, 5), st.sampled_from(POLICIES),
                          st.sampled_from(POLICIES)),
                min_size=1, max_size=3),
       st.sampled_from([0.0, 10.0]))
def test_two_level_warm_multi_window_chain(windows_spec, flush):
    """Warm cross-window per-level state must stay byte-identical (content,
    order, dirty flags) between the interpreter and the batch engine."""
    diff = EngineDiff([c1 for _, c1, _, _, _ in windows_spec],
                      [p for _, _, _, p, _ in windows_spec],
                      [c2 for _, _, c2, _, _ in windows_spec],
                      [p for _, _, _, _, p in windows_spec], flush=flush)
    diff.run_windows([[mk_trace(tl) for tl, _, _, _, _ in windows_spec]
                      for _ in range(3)])


@settings(max_examples=examples(100), deadline=None)
@given(two_level_strategy(max_n=60, max_addr=5), st.integers(1, 3),
       st.integers(1, 3))
def test_two_level_ro_under_pressure(trace_list, c1, c2):
    """Small caps + few addresses force the two-level RO pressure path."""
    EngineDiff([c1], [WritePolicy.RO], [c2], [WritePolicy.WB],
               flush=10.0).run_window([mk_trace(trace_list)])


@settings(max_examples=examples(100), deadline=None)
@given(two_level_strategy(max_n=40), st.integers(0, 6),
       st.sampled_from(POLICIES), st.sampled_from([0.0, 10.0]))
def test_capacity2_zero_is_single_level(trace_list, cap, policy, flush):
    """C2 == 0 must reproduce each single-level engine bit-identically
    (old single-level API vs the same engine with the two-level knobs)."""
    t = mk_trace(trace_list)
    for eng in (simulate, simulate_batch):
        ca, cb = LRUCache(cap), LRUCache(cap)
        r_old = eng(t, cap, policy, flush_cost=flush, cache=ca)
        r_new = eng(t, cap, policy, flush_cost=flush, cache=cb,
                    capacity2=0, policy2=WritePolicy.RO)
        for f in RESULT_FIELDS:
            assert getattr(r_old, f) == getattr(r_new, f), f
        assert r_new.read_hits_l2 == 0 and r_new.cache_writes_l2 == 0
        assert r_old.total_latency == r_new.total_latency  # bit-identical
        assert_states_equal(ca, cb)


def test_rebalance_levels_invariant():
    """Growing L1 refills it from L2's MRU; union order is preserved."""
    c1, c2 = LRUCache(4), LRUCache(4)
    c1.set_state_arrays(np.array([7, 8], np.int64), np.array([True, False]))
    c2.set_state_arrays(np.array([1, 2, 3], np.int64),
                        np.array([False, True, False]))
    rebalance_levels(c1, c2)
    assert list(c1._od.items()) == [(2, True), (3, False), (7, True),
                                    (8, False)]
    assert list(c2._od.items()) == [(1, False)]


def test_promotion_and_demotion_counting(engine_diff):
    """r(a) r(b) r(a) at C1=1, C2=1: second r(a) is an L2 hit (a was
    demoted by r(b)); the promotion writes L1 and demotes b to L2."""
    t = Trace(np.array([0, 1, 0], np.int64), np.ones(3, bool))
    r = engine_diff([1], [WritePolicy.WB], [1], [WritePolicy.WB],
                    t_fast2=4.0).run_window([t])[0]
    assert (r.read_hits, r.read_hits_l2) == (0, 1)
    assert r.cache_writes == 3          # 2 installs + 1 promotion
    assert r.cache_writes_l2 == 2       # a demoted, then b demoted
    assert r.total_latency == pytest.approx(2 * 20.0 + 4.0)


def test_clean_l2_flushes_at_demotion(engine_diff):
    """policy2 != WB: the dirty victim flushes when demoted, not at union
    eviction; L2 content stays clean."""
    t = Trace(np.array([0, 1], np.int64), np.array([False, True]))
    diff = engine_diff([1], [WritePolicy.WB], [1], [WritePolicy.RO],
                       flush=5.0)
    r = diff.run_window([t])[0]
    # w(0) installs dirty; r(1) demotes 0 -> flush charged at demote
    assert r.total_latency == pytest.approx(1.0 + 20.0 + 5.0)
    assert list(diff.got2[0]._od.items()) == [(0, False)]


# ------------------------------------------------ RO token loop, on device
@settings(max_examples=examples(60), deadline=None)
@given(two_level_strategy(max_n=80, max_addr=5), st.integers(1, 4))
def test_ro_token_replay_device_matches_host(trace_list, cap):
    t = mk_trace(trace_list)
    if len(t) == 0:
        return
    from repro.core.trace import prev_next_occurrence
    prev, nxt = prev_next_occurrence(t.addrs)
    nxt = np.minimum(nxt, len(t))
    force = np.zeros(len(t), bool)
    force[::3] = True
    d1, y1, f1 = _ro_token_replay(t.is_read, prev, nxt, force, cap)
    d2, y2, f2 = ro_token_replay_device(t.is_read, prev, nxt, force, cap)
    assert np.array_equal(d1, d2)
    assert np.array_equal(y1, y2)
    assert f1 == f2


# ------------------------------------------------ kernel both-level masks
def test_residency_levels_ops_ref_match_host():
    from repro.core.batch_sim import _stack_distances_host
    from repro.core.trace import prev_next_occurrence
    from repro.kernels.cache_sim.ops import residency_levels_accel
    rng = np.random.default_rng(11)
    addrs = rng.integers(0, 40, 600).astype(np.int64)
    prev, nxt = prev_next_occurrence(addrs)
    cap1 = rng.integers(0, 6, 600)
    captot = cap1 + rng.integers(0, 6, 600)
    sd = _stack_distances_host(prev, nxt)
    hot = prev >= 0
    want1 = hot & (sd >= 0) & (sd < cap1)
    wantu = hot & (sd >= 0) & (sd < captot)
    l1, un = residency_levels_accel(prev, nxt, cap1, captot,
                                    use_kernel=False)
    assert np.array_equal(l1, want1)
    assert np.array_equal(un, wantu)


@pytest.mark.slow
def test_residency_levels_kernel_interpret():
    from repro.core.batch_sim import _stack_distances_host
    from repro.core.trace import prev_next_occurrence
    from repro.kernels.cache_sim.ops import residency_levels_accel
    rng = np.random.default_rng(12)
    addrs = rng.integers(0, 30, 400).astype(np.int64)
    prev, nxt = prev_next_occurrence(addrs)
    cap1 = rng.integers(0, 5, 400)
    captot = cap1 + rng.integers(0, 5, 400)
    sd = _stack_distances_host(prev, nxt)
    hot = prev >= 0
    l1, un = residency_levels_accel(prev, nxt, cap1, captot, use_kernel=True)
    assert np.array_equal(l1, hot & (sd >= 0) & (sd < cap1))
    assert np.array_equal(un, hot & (sd >= 0) & (sd < captot))


# ------------------------------------------------ two-stage Eq.-2 solver
def test_shifted_hit_ratio_curve():
    t = msr_trace("prn_1", 1200, seed=4)
    h = build_hit_ratio_function(reuse_distances(t, "urd"))
    for base in (0, 5, h.max_useful_size // 2, h.max_useful_size + 10):
        sh = h.shifted(base)
        assert sh.edges[0] == 0
        assert np.all(np.diff(sh.edges) > 0)
        assert np.all(np.diff(sh.heights) >= 0) and sh.heights[0] == 0.0
        for c in (1, 3, 17, 1000):
            assert sh(c) == pytest.approx(h(base + c) - h(base))
    assert h.shifted(0).max_useful_size == h.max_useful_size
    sat = h.shifted(h.max_useful_size + 10)
    assert sat.max_useful_size == 0 and sat.max_hit_ratio == 0.0


def test_two_level_solve_budgets_and_degenerate():
    traces = [msr_trace(n, 1500, seed=i)
              for i, n in enumerate(["wdev_0", "prn_1", "prxy_0", "web_0"])]
    hs = [build_hit_ratio_function(reuse_distances(t, "urd"))
          for t in traces]
    p1, p2 = two_level_solve(hs, 60, 150, 1.0, 4.0, 20.0, c_min=5,
                             partition_fn=greedy_allocate)
    assert int(p1.sizes.sum()) <= 60
    assert int(p2.sizes.sum()) <= 150
    # level-2 grants never exceed the residual useful mass
    for h, s1, s2 in zip(hs, p1.sizes, p2.sizes):
        assert int(s1) + int(s2) <= h.max_useful_size
    # degenerate: no L2 budget reproduces the single-level call exactly
    p1b, p2b = two_level_solve(hs, 60, 0, 1.0, 4.0, 20.0, c_min=5,
                               partition_fn=greedy_allocate)
    assert p2b is None
    assert np.array_equal(p1.sizes, p1b.sizes)


def test_assign_write_policy_levels():
    wr_heavy = Trace(np.array([1, 1, 1, 1], np.int64),
                     np.array([False, False, False, False]))
    assert assign_write_policy_levels(wr_heavy) == (WritePolicy.RO,
                                                    WritePolicy.RO)
    mixed = Trace(np.array([1, 1, 2, 2, 3, 3, 4, 4, 5, 5], np.int64),
                  np.array([False, False, True, True, True, True, True,
                            True, True, True]))
    # writeRatio = 0.1: below both thresholds -> WB everywhere
    assert assign_write_policy_levels(mixed) == (WritePolicy.WB,
                                                 WritePolicy.WB)
    # writeRatio in [w_threshold2, w_threshold): clean L2, buffering L1
    waw = Trace(np.array([1, 1, 1, 2, 2, 3, 3, 4, 4, 5], np.int64),
                np.array([False, False, False, True, True, True, True,
                          True, True, True]))
    p1, p2 = assign_write_policy_levels(waw, 0.5, 0.2)
    assert (p1, p2) == (WritePolicy.WB, WritePolicy.RO)


# --------------------------------------------------- manager end-to-end
def test_manager_two_level_batch_equals_lru():
    names = ["wdev_0", "hm_1", "prn_1", "web_0"]
    mgrs = {}
    for engine in ("batch", "lru"):
        mgr = make_manager("etica", 150, names, capacity2=400, c_min=10,
                           initial_blocks=30, t_fast=1.0, t_fast2=4.0,
                           t_slow=20.0, flush_cost=10.0, engine=engine)
        for w in range(3):
            traces = [msr_trace(nm, 600, seed=97 * w + i)
                      for i, nm in enumerate(names)]
            mgr.run_window(traces)
        mgrs[engine] = mgr
    mb, ml = mgrs["batch"], mgrs["lru"]
    for tb, tl in zip(mb.tenants, ml.tenants):
        assert_results_equal(tl.result, tb.result)
        assert tb.policy is tl.policy and tb.policy2 is tl.policy2
        assert tb.cache.capacity == tl.cache.capacity
        assert tb.cache2.capacity == tl.cache2.capacity
        assert_states_equal(tb.cache, tl.cache)
        assert_states_equal(tb.cache2, tl.cache2)
    for db, dl in zip(mb.history, ml.history):
        assert np.array_equal(db.sizes, dl.sizes)
        assert np.array_equal(db.sizes2, dl.sizes2)
        assert db.policies2 == dl.policies2
    d = mb.history[-1]
    assert int(d.sizes.sum()) <= 150
    assert int(d.sizes2.sum()) <= 400
    assert int(d.sizes2.sum()) > 0      # pressure regime: L2 gets used


def test_manager_two_level_dominates_single_tier():
    """ETICA headline at equal L1 budget: latency strictly improves while
    L1 cache writes do not increase (promotions replace miss installs)."""
    names = ["wdev_0", "hm_1", "prn_1", "web_0", "prxy_0"]
    kw = dict(c_min=10, initial_blocks=30, t_fast=1.0, t_slow=20.0,
              flush_cost=10.0)
    one = make_manager("eci", 150, names, **kw)
    two = make_manager("etica", 150, names, capacity2=400, t_fast2=4.0, **kw)
    for w in range(3):
        traces = [msr_trace(nm, 700, seed=97 * w + i)
                  for i, nm in enumerate(names)]
        one.run_window(list(traces))
        two.run_window(list(traces))
    s1, s2 = one.summary(), two.summary()
    assert s2["mean_latency"] < s1["mean_latency"]
    assert s2["cache_writes"] <= s1["cache_writes"]
    assert s2["read_hit_ratio_l2"] > 0


def test_history_limit_bounds_memory():
    mgr = ECICacheManager(500, ["a", "b"], c_min=8, initial_blocks=16,
                          history_limit=5)
    tr = msr_trace("wdev_0", 120, seed=0)
    for w in range(12):
        mgr.run_window([tr, tr])
    assert len(mgr.history) == 5
    # default is bounded too; None means unbounded
    assert ECICacheManager(10, ["a"]).history.maxlen == 256
    assert ECICacheManager(10, ["a"], history_limit=None).history.maxlen \
        is None
