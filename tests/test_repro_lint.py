"""Fixture-driven tests for the repro-lint AST pass (tools/repro_lint).

Per rule: a true positive (the violation is found), a true negative (the
compliant idiom is NOT flagged — precision is what makes the pass
adoptable), and suppression handling.  Plus the meta-tests the satellite
demands: registry / README catalog / --list-rules stay in sync, the real
tree lints clean, and a seeded ``.item()`` violation in a copy of
``device_pipeline.py`` is caught (the CI-failure path).
"""
import json
import pathlib
import re
import subprocess
import sys
import textwrap

import pytest

from tools.repro_lint import REGISTRY, lint_paths
from tools.repro_lint.cli import list_rules

REPO = pathlib.Path(__file__).resolve().parents[1]


def run_lint(tmp_path, files, select=None):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return lint_paths([tmp_path], root=tmp_path, select=select)


def rule_hits(res, rule):
    return [f for f in res.findings if f.rule == rule]


# ================================================================= RL001
JITTED_SYNC = """
    import jax
    import jax.numpy as jnp

    def stage(d):
        s = jnp.sum(d["x"])
        return s.item()

    prog = jax.jit(stage)
"""


def test_rl001_item_in_jitted_function(tmp_path):
    res = run_lint(tmp_path, {"core/device_pipeline.py": JITTED_SYNC},
                   select=["RL001"])
    (f,) = rule_hits(res, "RL001")
    assert ".item()" in f.message and f.path == "core/device_pipeline.py"


def test_rl001_float_and_numpy_on_traced(tmp_path):
    src = """
        import jax
        import numpy as np

        def stage(d):
            a = float(d["x"])
            b = np.asarray(d["y"])
            return a, b

        prog = jax.jit(stage)
    """
    res = run_lint(tmp_path, {"core/device_pipeline.py": src},
                   select=["RL001"])
    msgs = " | ".join(f.message for f in rule_hits(res, "RL001"))
    assert "float()" in msgs and "np.asarray" in msgs


def test_rl001_negative_static_and_shape_sanitized(tmp_path):
    src = """
        import functools
        import jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("width",))
        def op(x, width):
            t = int(width)              # static: a trace-time python int
            n = int(x.shape[0])         # sanitized through .shape
            return x * t + n

        def host_wrapper(arr):
            import numpy as np
            return np.asarray(arr)      # not reachable from any jit
    """
    res = run_lint(tmp_path, {"kernels/foo/ops.py": src}, select=["RL001"])
    assert rule_hits(res, "RL001") == []


def test_rl001_propagates_through_called_helper(tmp_path):
    src = """
        import jax
        import jax.numpy as jnp

        def helper(v):
            return v.item()

        def stage(d):
            return helper(jnp.sum(d["x"]))

        prog = jax.jit(stage)
    """
    res = run_lint(tmp_path, {"core/device_pipeline.py": src},
                   select=["RL001"])
    (f,) = rule_hits(res, "RL001")
    assert ".item()" in f.message


def test_rl001_suppression_honored(tmp_path):
    src = JITTED_SYNC.replace(
        "return s.item()",
        "return s.item()  # repro-lint: disable=RL001")
    res = run_lint(tmp_path, {"core/device_pipeline.py": src},
                   select=["RL001"])
    assert res.ok and len(res.suppressed) == 1


def test_rl001_suppression_on_preceding_comment_line(tmp_path):
    src = JITTED_SYNC.replace(
        "return s.item()",
        "# repro-lint: disable=RL001\n        return s.item()")
    res = run_lint(tmp_path, {"core/device_pipeline.py": src},
                   select=["RL001"])
    assert res.ok and len(res.suppressed) == 1


def test_rl001_sees_through_shard_map_bodies(tmp_path):
    """A host sync inside a ``shard_map`` body is traced code exactly like
    a jitted function (the rule gap the shard pipeline exposed): flagged,
    while a sync-free body stays clean."""
    src = """
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def body(tape):
            s = jnp.sum(tape["x"])
            return s.item()

        prog = shard_map(body, mesh=None, in_specs=(P(),), out_specs=P())
    """
    res = run_lint(tmp_path, {"core/shard_pipeline.py": src},
                   select=["RL001"])
    (f,) = rule_hits(res, "RL001")
    assert ".item()" in f.message and f.path == "core/shard_pipeline.py"
    clean = src.replace("return s.item()", "return s")
    res = run_lint(tmp_path, {"core/shard_pipeline.py": clean},
                   select=["RL001"])
    assert rule_hits(res, "RL001") == []


# ================================================================= RL002
def test_rl002_missing_oracle(tmp_path):
    res = run_lint(tmp_path, {
        "kernels/foo/kernel.py": """
            __all__ = ["foo_scan"]
            def foo_scan(x):
                return x
        """,
        "kernels/foo/ref.py": """
            __all__ = ["unrelated_ref"]
            def unrelated_ref(x):
                return x
        """,
    }, select=["RL002"])
    (f,) = rule_hits(res, "RL002")
    assert "no matching oracle" in f.message


def test_rl002_missing_differential_test(tmp_path):
    res = run_lint(tmp_path, {
        "kernels/foo/kernel.py": """
            __all__ = ["foo_scan"]
            def foo_scan(x):
                return x
        """,
        "kernels/foo/ref.py": """
            __all__ = ["foo_ref"]
            def foo_ref(x):
                return x
        """,
        "tests/test_other.py": "def test_nothing():\n    pass\n",
    }, select=["RL002"])
    (f,) = rule_hits(res, "RL002")
    assert "differential coverage" in f.message


def test_rl002_triad_complete(tmp_path):
    res = run_lint(tmp_path, {
        "kernels/foo/kernel.py": """
            __all__ = ["foo_scan"]
            def foo_scan(x):
                return x
        """,
        "kernels/foo/ref.py": """
            __all__ = ["foo_ref"]
            def foo_ref(x):
                return x
        """,
        "tests/test_foo.py": """
            from kernels.foo.kernel import foo_scan
            from kernels.foo.ref import foo_ref
            def test_match():
                assert foo_scan(1) == foo_ref(1)
        """,
    }, select=["RL002"])
    assert res.ok


# ================================================================= RL003
def test_rl003_default_on_and_wrong_enum(tmp_path):
    res = run_lint(tmp_path, {
        "core/monitor.py": """
            def analyze_windows(traces, kind="urd", shiny=True,
                                pipeline="device"):
                return None
        """,
        "tests/test_m.py": "def test_x():\n    pass\n",
    }, select=["RL003"])
    msgs = " | ".join(f.message for f in rule_hits(res, "RL003"))
    assert "must default to False" in msgs
    assert "must default to 'host'" in msgs
    assert "not named in any test" in msgs


def test_rl003_compliant_flags(tmp_path):
    res = run_lint(tmp_path, {
        "core/monitor.py": """
            def analyze_windows(traces, kind="urd", shiny=False,
                                pipeline="host"):
                return None
        """,
        "tests/test_m.py": """
            def test_bit_identity():
                shiny = False
                pipeline = "host"
        """,
    }, select=["RL003"])
    assert res.ok


def test_rl003_suppression(tmp_path):
    res = run_lint(tmp_path, {
        "core/monitor.py": """
            def analyze_windows(
                    traces,
                    shiny=True):  # repro-lint: disable=RL003
                return None
        """,
        "tests/test_m.py": "def test_x():\n    shiny = True\n",
    }, select=["RL003"])
    assert res.ok and len(res.suppressed) == 1


# ================================================================= RL004
COUNTER_CLASS = """
    class Mgr:
        def __init__(self):
            self.foo_events = 0
            self.bar_windows = 0
            self._hidden_windows = 0

        def work(self):
            self.foo_events += 1
            self.bar_windows += 1
            self._hidden_windows += 1

        def summary(self):
            return {"bar_windows": self.bar_windows}
"""


def test_rl004_unregistered_and_untested_counter(tmp_path):
    res = run_lint(tmp_path, {
        "core/m.py": COUNTER_CLASS,
        "tests/test_m.py": """
            def test_counts():
                assert mgr.summary()["bar_windows"] == 1
        """,
    }, select=["RL004"])
    hits = rule_hits(res, "RL004")
    msgs = " | ".join(f.message for f in hits)
    assert "missing from Mgr.summary()" in msgs
    assert "no test assertion" in msgs
    # private attrs and registered+tested counters are not flagged
    assert all("foo_events" in f.message for f in hits)


def test_rl004_clean_when_registered_and_tested(tmp_path):
    res = run_lint(tmp_path, {
        "core/m.py": COUNTER_CLASS.replace(
            '{"bar_windows": self.bar_windows}',
            '{"bar_windows": self.bar_windows, '
            '"foo_events": self.foo_events}'),
        "tests/test_m.py": """
            def test_counts():
                assert mgr.summary()["bar_windows"] == 1
                assert mgr.summary()["foo_events"] == 1
        """,
    }, select=["RL004"])
    assert res.ok


# ================================================================= RL005
def test_rl005_global_config_mutation(tmp_path):
    res = run_lint(tmp_path, {"core/x.py": """
        import jax
        jax.config.update("jax_enable_x64", True)
    """}, select=["RL005"])
    (f,) = rule_hits(res, "RL005")
    assert "global" in f.message


def test_rl005_unscoped_call_and_attr_assign(tmp_path):
    res = run_lint(tmp_path, {"core/x.py": """
        import jax
        from jax.experimental import enable_x64

        def f():
            enable_x64()          # called for effect: leaks

        jax.config.jax_enable_x64 = True
    """}, select=["RL005"])
    assert len(rule_hits(res, "RL005")) == 2


def test_rl005_scoped_uses_allowed(tmp_path):
    res = run_lint(tmp_path, {"core/x.py": """
        import contextlib
        from jax.experimental import enable_x64

        def _x64(f64):
            if f64:
                return enable_x64()
            return contextlib.nullcontext()

        def work():
            with enable_x64():
                return 1
    """}, select=["RL005"])
    assert res.ok


# ================================================================= RL006
def test_rl006_closure_mutation_in_scan_body(tmp_path):
    res = run_lint(tmp_path, {"core/x.py": """
        from jax import lax

        def outer(xs):
            acc = []

            def body(c, x):
                acc.append(x)
                return c, x

            return lax.scan(body, 0, xs)
    """}, select=["RL006"])
    (f,) = rule_hits(res, "RL006")
    assert "acc" in f.message and "scan" in f.message


def test_rl006_nonlocal_and_subscript_write(tmp_path):
    res = run_lint(tmp_path, {"core/x.py": """
        from jax import lax

        def outer(n, table):
            total = 0

            def body(i, c):
                nonlocal total
                table[i] = c
                return c + 1

            return lax.fori_loop(0, n, body, 0)
    """}, select=["RL006"])
    msgs = " | ".join(f.message for f in rule_hits(res, "RL006"))
    assert "nonlocal" in msgs and "table" in msgs


def test_rl006_pure_bodies_clean(tmp_path):
    res = run_lint(tmp_path, {"core/x.py": """
        import jax.numpy as jnp
        from jax import lax

        def outer(n, xs, hist):
            def body(i, carry):
                acc, h = carry
                local = {}
                local["k"] = i                   # local container: fine
                h = h.at[i].add(1)               # functional update: fine
                return acc + xs[i], h

            return lax.fori_loop(0, n, body, (jnp.float32(0), hist))
    """}, select=["RL006"])
    assert res.ok


# ============================================================== meta-tests
ALL_RULES = ("RL001", "RL002", "RL003", "RL004", "RL005", "RL006")


def test_registry_has_all_rules():
    assert tuple(sorted(REGISTRY)) == ALL_RULES
    for rid, rule in REGISTRY.items():
        assert rule.id == rid and rule.name and rule.summary


def test_list_rules_matches_registry():
    out = list_rules()
    for rid, rule in REGISTRY.items():
        assert re.search(rf"^{rid} {re.escape(rule.name)}:", out,
                         re.MULTILINE), rid


def test_readme_catalog_matches_registry():
    readme = (REPO / "tools" / "repro_lint" / "README.md").read_text()
    table_ids = set(re.findall(r"^\|\s*(RL\d{3})\s*\|", readme,
                               re.MULTILINE))
    assert table_ids == set(REGISTRY)
    for rule in REGISTRY.values():
        assert rule.name in readme, rule.id


def test_real_tree_is_clean():
    """The standing quality bar: src + tests + benchmarks lint clean."""
    res = lint_paths([REPO / "src", REPO / "tests", REPO / "benchmarks"],
                     root=REPO)
    assert res.ok, "\n".join(f.render() for f in res.findings)


def test_seeded_violation_fails_the_run(tmp_path):
    """CI failure path: an .item() seeded into the real device pipeline
    module (inside the jitted count stage) must be caught."""
    real = (REPO / "src" / "repro" / "core" /
            "device_pipeline.py").read_text()
    anchor = '        hot = d["gprev"] >= 0'
    assert anchor in real
    seeded = real.replace(anchor, "        counts.item()\n" + anchor, 1)
    out = tmp_path / "core" / "device_pipeline.py"
    out.parent.mkdir(parents=True)
    out.write_text(seeded)
    res = lint_paths([out], root=tmp_path, select=["RL001"])
    assert not res.ok
    (f,) = rule_hits(res, "RL001")
    assert ".item()" in f.message


def test_cli_exit_codes_and_json(tmp_path):
    bad = tmp_path / "core" / "device_pipeline.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(textwrap.dedent(JITTED_SYNC))
    proc = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", str(bad),
         "--root", str(tmp_path), "--json"],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert payload["findings"][0]["rule"] == "RL001"
    good = tmp_path / "ok.py"
    good.write_text("x = 1\n")
    proc = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", str(good),
         "--root", str(tmp_path)],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0
