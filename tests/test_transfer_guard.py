"""Transfer-guard sanitizer: the zero-hidden-sync window contract, enforced.

The ``StageProfile`` counter *counts* host syncs after the fact; the
``transfer_sanitizer`` guard *forbids* them as they happen — any implicit
device->host escape (``.item()``, ``float()``, numpy coercion) inside a
guarded window raises ``XlaRuntimeError``.  The one permitted sync per
window is the decision fetch, which crosses via explicit
``jax.device_get`` and therefore stays legal under the guard.  These
tests pin: the guard has teeth, the sanitized path is bit-identical to
the default (off) path, and a guarded streaming run still pays at most
one sync per window by the profile counter.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DeviceWindowPipeline, StageProfile, Trace,
                        monitor_window_device, transfer_sanitizer)


def _traces(seed, n_tenants=3, n=300, spread=80):
    rng = np.random.default_rng(seed)
    return [Trace(rng.integers(0, spread, n).astype(np.int64),
                  rng.random(n) < 0.6, f"t{i}")
            for i in range(n_tenants)]


# ------------------------------------------------------------- guard teeth
def test_guard_catches_hidden_sync():
    """An implicit device->host escape raises; the explicit fetch stays
    legal — exactly the asymmetry the window contract needs."""
    x = jnp.arange(3.0)
    with pytest.raises(Exception, match="[Dd]isallow"):
        with transfer_sanitizer():
            float(x[0])
    with pytest.raises(Exception, match="[Dd]isallow"):
        with transfer_sanitizer():
            x[0].item()
    with transfer_sanitizer():
        out = jax.device_get(x)          # the permitted explicit sync
    assert np.array_equal(out, np.arange(3.0))


def test_guard_disabled_is_noop():
    x = jnp.arange(3.0)
    with transfer_sanitizer(False):
        assert float(x[0]) == 0.0


# ------------------------------------------------- sanitized == default-off
def test_pipeline_sanitized_bit_identical():
    traces = _traces(0)
    a = DeviceWindowPipeline(5000, c_min=100).run(traces)
    b = DeviceWindowPipeline(5000, c_min=100,
                             transfer_sanitize=True).run(traces)
    assert np.array_equal(a.sizes, b.sizes)
    assert np.array_equal(a.urd_sizes, b.urd_sizes)
    assert np.array_equal(a.write_ratios, b.write_ratios)
    assert np.array_equal(a.hit_ratios, b.hit_ratios)
    assert a.latency == b.latency and a.feasible == b.feasible


def test_monitor_window_device_sanitized_bit_identical():
    traces = _traces(2)
    lens = np.array([len(t) for t in traces], np.int64)
    bounds = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    addrs = np.concatenate([t.addrs for t in traces])
    is_read = np.concatenate([t.is_read for t in traces])
    a = monitor_window_device(addrs, is_read, bounds, lens)
    b = monitor_window_device(addrs, is_read, bounds, lens,
                              transfer_sanitize=True)
    assert np.array_equal(a[1], b[1])        # urd sizes
    assert np.array_equal(a[2], b[2])        # write ratios
    assert np.array_equal(a[3], b[3])        # cold counts
    for k in range(len(traces)):
        assert np.array_equal(a[0][k].edges, b[0][k].edges)
        assert np.array_equal(a[0][k].heights, b[0][k].heights)


# ------------------------------------------- guarded stream: <= 1 sync/window
def test_run_stream_sanitized_one_sync_per_window():
    """The guard forbids hidden syncs *while* the profile counts the one
    permitted fetch — together: exactly <= 1 sync per window, enforced
    dynamically, with decisions bit-identical to the unguarded stream."""
    windows = [_traces(s) for s in range(4)]
    prof = StageProfile()
    res = DeviceWindowPipeline(5000, c_min=100,
                               transfer_sanitize=True
                               ).run_stream(windows, prof)
    assert len(res) == 4 and prof.windows == 4
    assert prof.syncs_per_window <= 1.0
    base = DeviceWindowPipeline(5000, c_min=100).run_stream(windows)
    for a, b in zip(base, res):
        assert np.array_equal(a.sizes, b.sizes)
        assert a.latency == b.latency


def test_run_sanitized_profile_counts_single_fetch():
    prof = StageProfile()
    DeviceWindowPipeline(5000, c_min=100,
                         transfer_sanitize=True).run(_traces(7), prof)
    assert prof.windows == 1 and prof.syncs == 1
