"""Characterization feature pass + phase detector, property-tested.

Three contracts from ``repro.core.characterize``:

  * the batched ``characterize_windows`` is **bit-identical** to the
    naive per-tenant set-loop reference ``characterize_trace`` — cold and
    warm (previous-window sets threaded), exact and SHARDS-sampled, and
    on the replay engine's precomputed window-distance path;
  * the SHARDS-sampled working-set estimate lands within its stated
    Horvitz–Thompson error bars of the exact count;
  * the hysteresis ``PhaseDetector`` hits precision/recall >= 0.9 with
    detection latency <= 2 windows on the labeled scenario suite, and an
    event-driven manager at ``reconfig_interval=1`` makes decisions
    bit-identical to the fixed-Δt manager (the detector only *adds*
    analyze triggers; with the clock at every window nothing changes).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from oracle import examples, mk_trace, trace_strategy

from repro.core import WritePolicy, simulate_many
from repro.core.characterize import (PhaseDetector, characterize_salt,
                                     characterize_trace,
                                     characterize_windows)
from repro.core.manager import ECICacheManager
from repro.core.simulator import LRUCache
from repro.data.scenarios import SCENARIOS, replay_scenario
from repro.data.traces import msr_trace

FEATURE_FIELDS = ("stride_hist", "seq_fraction", "read_fraction",
                  "write_ratio", "working_set", "jaccard_drift",
                  "reuse_intensity", "sample_rates")


def assert_features_equal(got, k: int, ref) -> None:
    """Row k of a batched WindowFeatures == the single-tenant reference."""
    for f in FEATURE_FIELDS:
        g = np.asarray(getattr(got, f))[k]
        w = np.asarray(getattr(ref, f))[0]
        assert np.array_equal(g, w), (f, g, w)
    assert np.array_equal(got.address_sets[k], ref.address_sets[0])


@settings(max_examples=examples(40), deadline=None)
@given(st.lists(trace_strategy(max_n=50, max_addr=12), min_size=1,
                max_size=4),
       st.lists(trace_strategy(max_n=50, max_addr=12), min_size=1,
                max_size=4))
def test_fused_matches_naive_cold_and_warm(win0, win1):
    """Exact path: batched == naive, first window and with drift."""
    n = min(len(win0), len(win1))
    t0 = [mk_trace(w) for w in win0[:n]]
    t1 = [mk_trace(w) for w in win1[:n]]
    cold = characterize_windows(t0)
    refs0 = [characterize_trace(tr) for tr in t0]
    for k in range(n):
        assert_features_equal(cold, k, refs0[k])
    warm = characterize_windows(t1, prev_sets=list(cold.address_sets))
    for k in range(n):
        ref = characterize_trace(t1[k], prev_set=cold.address_sets[k])
        assert_features_equal(warm, k, ref)


@settings(max_examples=examples(30), deadline=None)
@given(st.lists(trace_strategy(max_n=60, max_addr=40), min_size=1,
                max_size=4),
       st.sampled_from([0.3, 0.5, 0.8]),
       st.integers(0, 50))
def test_fused_matches_naive_sampled(wins, rate, id0):
    """SHARDS path: batched == naive on the identically-filtered
    sub-trace, with explicit tenant ids salting the filters."""
    traces = [mk_trace(w) for w in wins]
    ids = list(range(id0, id0 + len(traces)))
    got = characterize_windows(traces, sample_rate=rate, tenant_ids=ids)
    for k, tr in enumerate(traces):
        ref = characterize_trace(tr, rate=rate,
                                 salt=characterize_salt(ids[k]))
        assert_features_equal(got, k, ref)


def test_fused_matches_naive_on_msr_mixes():
    """Deterministic multi-window check on realistic mixes, including the
    precomputed-distance path from the batch replay engine."""
    names = ["wdev_0", "hm_1", "prn_1", "rsrch_2"]
    prev = [None] * len(names)
    caches = [LRUCache(64) for _ in names]
    for w in range(3):
        traces = [msr_trace(nm, 500, seed=10 * w + i)
                  for i, nm in enumerate(names)]
        _, rds = simulate_many(
            traces, policies=[WritePolicy.WB] * len(names),
            t_fast=1.0, t_slow=20.0, caches=caches, return_window_rd=True)
        plain = characterize_windows(traces, prev_sets=prev)
        fused = characterize_windows(traces, prev_sets=prev, dists=list(rds))
        for k, tr in enumerate(traces):
            ref = characterize_trace(tr, prev_set=prev[k])
            assert_features_equal(plain, k, ref)
            assert_features_equal(fused, k, ref)
        prev = list(plain.address_sets)


@pytest.mark.parametrize("rate", [0.2, 0.5])
def test_sampled_working_set_within_error_bars(rate):
    """HT working-set estimate within ~4/sqrt(kept) relative error."""
    for i, nm in enumerate(["prn_1", "usr_0", "stg_1"]):
        tr = msr_trace(nm, 6000, seed=i)
        exact = characterize_trace(tr)
        smp = characterize_trace(tr, rate=rate, salt=characterize_salt(i))
        ws_true = float(exact.working_set[0])
        ws_est = float(smp.working_set[0])
        kept_distinct = smp.address_sets[0].size
        rel_err = abs(ws_est - ws_true) / ws_true
        assert rel_err <= 4.0 / np.sqrt(max(kept_distinct, 1)), \
            (nm, rate, ws_true, ws_est, kept_distinct)


# ------------------------------------------------------- phase detection
def _match(run, detected, bound=2):
    truth = run.true_changes()
    matched: dict[tuple, int] = {}
    used = set()
    for (w, t) in sorted(set(detected)):
        for (tw, tt) in truth:
            if tt == t and (tw, tt) not in matched and 0 <= w - tw <= bound:
                matched[(tw, tt)] = w - tw
                used.add((w, t))
                break
    fp = [e for e in sorted(set(detected)) if e not in used]
    return matched, fp, len(truth)


@pytest.mark.parametrize("seed", [0, 1])
def test_detector_quality_on_labeled_scenarios(seed):
    """Precision/recall >= 0.9, detection latency <= 2 windows, across
    the whole labeled scenario suite (detector driven standalone — no
    manager, no replay — so this isolates the characterize+detect path)."""
    tp = fp_n = truth_n = 0
    max_lat = 0
    for name, build in SCENARIOS.items():
        run = build(seed=seed)
        det = PhaseDetector(w_threshold=0.5)
        prev: dict[int, np.ndarray] = {}
        detected = []
        for w in range(run.n_windows):
            idx = [t for t in range(run.n_tenants)
                   if run.traces[w][t] is not None]
            if not idx:
                continue
            for t in range(run.n_tenants):
                if run.retire_windows[t] == w:
                    det.forget(t)
                    prev.pop(t, None)
            feats = characterize_windows(
                [run.traces[w][t] for t in idx],
                prev_sets=[prev.get(t) for t in idx], tenant_ids=idx)
            for k, t in enumerate(idx):
                prev[t] = feats.address_sets[k]
            detected += [(e.window, e.tenant)
                         for e in det.update(feats, w, idx)]
        matched, false_pos, n_truth = _match(run, detected)
        tp += len(matched)
        fp_n += len(false_pos)
        truth_n += n_truth
        if matched:
            max_lat = max(max_lat, max(matched.values()))
    precision = tp / max(tp + fp_n, 1)
    recall = tp / max(truth_n, 1)
    assert precision >= 0.9, (precision, tp, fp_n)
    assert recall >= 0.9, (recall, tp, truth_n)
    assert max_lat <= 2, max_lat


def test_detector_single_event_per_change():
    """A step change in a stationary stream yields exactly one event
    (hysteresis + post-trigger cold restart), and the detector re-arms
    for a later change."""
    det = PhaseDetector()
    rng = np.random.default_rng(0)

    def feats(read_frac, base):
        tr = mk_trace([(int(a) + base, bool(r < read_frac))
                       for a, r in zip(rng.integers(0, 40, 300),
                                       rng.random(300))])
        return characterize_windows([tr])

    events = []
    for w in range(14):
        if w < 5:
            f = feats(0.9, 0)
        elif w < 10:
            f = feats(0.1, 10_000)     # phase change at w=5
        else:
            f = feats(0.9, 20_000)     # and back at w=10
        events += det.update(f, w, [0])
    assert [e.window for e in events] == [5, 10], events


def test_event_driven_interval1_matches_fixed_dt():
    """phase_detect=True + reconfig_interval=1 analyzes every window,
    so decisions (sizes + policies) are bit-identical to detector-off."""
    names = ["wdev_0", "hm_1", "prn_1", "web_0"]
    kw = dict(c_min=20, initial_blocks=30, t_fast=1.0, t_slow=20.0,
              flush_cost=10.0)
    m_fix = ECICacheManager(600, names, **kw)
    m_evt = ECICacheManager(600, names, phase_detect=True,
                            reconfig_interval=1, **kw)
    for w in range(4):
        traces = [msr_trace(nm, 400, seed=100 * w + i)
                  for i, nm in enumerate(names)]
        m_fix.run_window(traces)
        m_evt.run_window(traces)
        d_fix, d_evt = m_fix.history[-1], m_evt.history[-1]
        assert np.array_equal(d_fix.sizes, d_evt.sizes)
        assert d_fix.policies == d_evt.policies
        assert np.array_equal(m_fix.allocated_sizes(),
                              m_evt.allocated_sizes())
    assert m_fix.windows_analyzed == m_evt.windows_analyzed == 4
    s_fix, s_evt = m_fix.summary(), m_evt.summary()
    for k in ("accesses", "mean_latency", "cache_writes",
              "read_hit_ratio"):
        assert s_fix[k] == s_evt[k], k
    # telemetry: the fixed manager records no events, the event-driven
    # one at least its interval ticks
    assert s_fix["reconfig_events"] == 0
    assert s_evt["reconfig_events"] >= 4


def test_event_driven_accumulates_windows_between_analyzes():
    """With the clock at N windows and a stationary workload, analyzes
    happen ~1/N as often, and each analyze sees the accumulated span
    (windows clear only on actuate)."""
    names = ["hm_1", "prn_1"]
    mgr = ECICacheManager(400, names, c_min=20, initial_blocks=30,
                          phase_detect=True, reconfig_interval=3)
    for w in range(6):
        mgr.run_window([msr_trace(nm, 300, seed=50 * w + i)
                        for i, nm in enumerate(names)])
    assert mgr.windows_run == 6
    assert mgr.windows_analyzed == 2          # windows 2 and 5 (clock)
    reasons = [e.reason for e in mgr.events]
    assert reasons.count("interval") == 2
    # every analyze was triggered, and the trigger is on the decision
    assert all(d.trigger for d in mgr.history)
