"""Pallas kernel sweeps (interpret mode) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cache_sim.kernel import (cache_sim_levels_scan,
                                            cache_sim_scan,
                                            cache_sim_segments_scan,
                                            live_count_scan)
from repro.kernels.cache_sim.ref import (cache_sim_levels_ref,
                                         cache_sim_ref,
                                         cache_sim_segments_ref,
                                         live_counts_ref)
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2_ssd.kernel import mamba2_ssd
from repro.kernels.mamba2_ssd.ref import mamba2_ssd_ref, seg_from_dA
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.urd_scan.kernel import urd_scan
from repro.kernels.urd_scan.ref import urd_scan_ref

# interpret-mode Pallas sweeps are minutes-scale on CPU: tier-1 deselects
# them (`pytest -m slow` opts in; the jnp oracles are covered by the fast
# suite through batch_sim/urd property tests).  The cheap ops.py dispatch
# test below stays un-marked so tier-1 keeps covering the jit wrappers.
slow_sweep = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Sq,Skv,D,causal,window", [
    (1, 2, 128, 128, 64, True, 0),
    (2, 2, 96, 160, 64, True, 0),        # ragged / pad paths
    (1, 1, 256, 256, 128, False, 0),
    (1, 2, 256, 256, 64, True, 96),      # sliding window
    (2, 4, 64, 64, 32, True, 0),         # small head dim
])
@slow_sweep
def test_flash_attention_sweep(B, H, Sq, Skv, D, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, H, Skv, D), dtype)
    v = jax.random.normal(ks[2], (B, H, Skv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_kv=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,D,page,nps,npool", [
    (2, 4, 2, 64, 16, 4, 32),
    (3, 8, 8, 128, 32, 3, 16),
    (1, 8, 2, 64, 8, 6, 64),
])
@slow_sweep
def test_paged_attention_sweep(B, Hq, Hkv, D, page, nps, npool, dtype):
    ks = jax.random.split(KEY, 3)
    rng = np.random.default_rng(0)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kp = jax.random.normal(ks[1], (npool, page, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (npool, page, Hkv, D), dtype)
    tables = jnp.asarray(
        rng.integers(0, npool, size=(B, nps)).astype(np.int32))
    lens = jnp.asarray(rng.integers(1, nps * page, size=(B,)
                                    ).astype(np.int32))
    out = paged_attention(q, kp, vp, tables, lens, interpret=True)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("BH,S,P,N,chunk", [
    (2, 128, 32, 16, 32),
    (4, 256, 64, 128, 64),
    (1, 64, 16, 8, 16),
])
@slow_sweep
def test_mamba2_ssd_sweep(BH, S, P, N, chunk):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (BH, S, P), jnp.float32) * 0.5
    B = jax.random.normal(ks[1], (BH, S, N), jnp.float32) * 0.5
    C = jax.random.normal(ks[2], (BH, S, N), jnp.float32) * 0.5
    dA = -jax.random.uniform(ks[3], (BH, S), jnp.float32) * 0.5
    seg = seg_from_dA(dA, chunk)
    out = mamba2_ssd(x, B, C, seg, chunk=chunk, interpret=True)
    ref = mamba2_ssd_ref(x, B, C, dA)
    scale = float(jnp.max(jnp.abs(ref)))
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(ref) / scale, atol=3e-6)


@pytest.mark.parametrize("n,tile", [(64, 16), (100, 32), (512, 128),
                                    (997, 256)])
@slow_sweep
def test_urd_scan_sweep(n, tile):
    rng = np.random.default_rng(n)
    addrs = rng.integers(0, max(4, n // 8), size=n).astype(np.int64)
    from repro.core.trace import Trace, prev_next_occurrence
    prev, nxt = prev_next_occurrence(addrs)
    out = urd_scan(jnp.asarray(prev, jnp.int32), jnp.asarray(nxt, jnp.int32),
                   tile=tile, interpret=True)
    ref = urd_scan_ref(jnp.asarray(prev, jnp.int32),
                       jnp.asarray(nxt, jnp.int32))
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("n,tile", [(64, 16), (100, 32), (257, 64)])
@pytest.mark.parametrize("occ_mode", ["all", "reads"])
@slow_sweep
def test_cache_sim_scan_sweep(n, tile, occ_mode):
    """Occupancy-masked stack-distance kernel vs jnp oracle (interpret)."""
    rng = np.random.default_rng(n)
    addrs = rng.integers(0, max(4, n // 6), size=n).astype(np.int64)
    from repro.core.trace import prev_next_occurrence
    prev, nxt = prev_next_occurrence(addrs)
    occ = (np.ones(n, np.int32) if occ_mode == "all"
           else (rng.random(n) < 0.6).astype(np.int32))
    out = cache_sim_scan(jnp.asarray(prev, jnp.int32),
                         jnp.asarray(nxt, jnp.int32),
                         jnp.asarray(occ), tile=tile, interpret=True)
    ref = cache_sim_ref(jnp.asarray(prev, jnp.int32),
                        jnp.asarray(nxt, jnp.int32), jnp.asarray(occ))
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("w,tile", [(32, 16), (64, 32)])
@slow_sweep
def test_cache_sim_segments_scan_sweep(w, tile):
    """Segment-restricted kernel vs the dense segments oracle (interpret).

    The tape is built the way ``padded_segment_layout`` guarantees it:
    one independent segment per ``w``-aligned block, links severed at the
    boundaries (each block's prev/nxt computed in isolation)."""
    rng = np.random.default_rng(w)
    from repro.core.trace import prev_next_occurrence
    prevs, nxts, occs = [], [], []
    for b in range(4):
        addrs = rng.integers(0, max(4, w // 4), size=w).astype(np.int64)
        p, x = prev_next_occurrence(addrs)
        prevs.append(np.where(p >= 0, p + b * w, -1))
        nxts.append(np.minimum(x, w) + b * w)
        occs.append((rng.random(w) < 0.7).astype(np.int32))
    prev, nxt = np.concatenate(prevs), np.concatenate(nxts)
    occ = np.concatenate(occs)
    out = cache_sim_segments_scan(jnp.asarray(prev, jnp.int32),
                                  jnp.asarray(nxt, jnp.int32),
                                  jnp.asarray(occ), seg_width=w,
                                  tile=tile, interpret=True)
    ref = cache_sim_segments_ref(jnp.asarray(prev, jnp.int32),
                                 jnp.asarray(nxt, jnp.int32),
                                 jnp.asarray(occ), w)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("n,tile", [(64, 16), (200, 64)])
@slow_sweep
def test_live_count_scan_sweep(n, tile):
    """RO live-count kernel vs the dense (i, j)-plane oracle (interpret)."""
    rng = np.random.default_rng(n)
    addrs = rng.integers(0, 30, n).astype(np.int64)
    from repro.core.trace import prev_next_occurrence
    _, nxt = prev_next_occurrence(addrs)
    nxt = np.minimum(nxt, n)
    occ = (rng.random(n) < 0.6).astype(np.int32)
    out = live_count_scan(jnp.asarray(nxt, jnp.int32),
                          jnp.asarray(occ), tile=tile, interpret=True)
    ref = live_counts_ref(jnp.asarray(nxt, jnp.int32), jnp.asarray(occ))
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("n,tile", [(64, 16), (257, 64)])
@slow_sweep
def test_cache_sim_levels_scan_sweep(n, tile):
    """Two-level residency-mask kernel vs the jnp oracle (interpret)."""
    rng = np.random.default_rng(n)
    addrs = rng.integers(0, max(4, n // 6), size=n).astype(np.int64)
    from repro.core.trace import prev_next_occurrence
    prev, nxt = prev_next_occurrence(addrs)
    occ = (rng.random(n) < 0.7).astype(np.int32)
    cap1 = rng.integers(0, 6, n).astype(np.int32)
    captot = cap1 + rng.integers(0, 6, n).astype(np.int32)
    l1, un = cache_sim_levels_scan(jnp.asarray(prev, jnp.int32),
                                   jnp.asarray(nxt, jnp.int32),
                                   jnp.asarray(occ), jnp.asarray(cap1),
                                   jnp.asarray(captot), tile=tile,
                                   interpret=True)
    r1, ru = cache_sim_levels_ref(jnp.asarray(prev, jnp.int32),
                                  jnp.asarray(nxt, jnp.int32),
                                  jnp.asarray(occ), jnp.asarray(cap1),
                                  jnp.asarray(captot))
    assert jnp.array_equal(l1, r1) and jnp.array_equal(un, ru)


def test_ops_wrappers_dispatch_cpu():
    """ops.py jit wrappers run (reference path) on CPU."""
    from repro.kernels.flash_attention.ops import flash_attention_op
    from repro.kernels.paged_attention.ops import paged_attention_op
    from repro.kernels.mamba2_ssd.ops import mamba2_ssd_op
    from repro.kernels.urd_scan.ops import urd_scan_op
    q = jax.random.normal(KEY, (1, 2, 32, 16))
    o = flash_attention_op(q, q, q)
    assert o.shape == (1, 2, 32, 16)
    q2 = jax.random.normal(KEY, (2, 4, 16))
    kp = jax.random.normal(KEY, (8, 4, 2, 16))
    tb = jnp.zeros((2, 2), jnp.int32)
    ln = jnp.array([3, 5], jnp.int32)
    o2 = paged_attention_op(q2, kp, kp, tb, ln)
    assert o2.shape == (2, 4, 16)
    x = jax.random.normal(KEY, (2, 32, 8))
    Bm = jax.random.normal(KEY, (2, 32, 4))
    dA = -jnp.ones((2, 32)) * 0.1
    o3 = mamba2_ssd_op(x, Bm, Bm, dA, chunk=16)
    assert o3.shape == x.shape
    prev = jnp.array([-1, -1, 0, 1], jnp.int32)
    nxt = jnp.array([2, 3, 4, 4], jnp.int32)
    assert urd_scan_op(prev, nxt).shape == (4,)
