"""MRC construction + Eq.-2 partitioners: exactness and invariants."""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (HitRatioFunction, Trace, WritePolicy,
                        aggregate_latency, build_hit_ratio_function,
                        greedy_allocate, pgd_solve, reuse_distances,
                        simulate)


def _trace(addrs, reads=None):
    a = np.asarray(addrs, np.int64)
    r = np.ones(len(a), bool) if reads is None else np.asarray(reads, bool)
    return Trace(a, r)


def test_mattson_inclusion_exactness():
    """For a read-only trace, H(c) must equal the LRU simulator's measured
    hit ratio at every capacity (stack-distance ⇔ LRU inclusion)."""
    rng = np.random.default_rng(0)
    addrs = rng.zipf(1.5, 800) % 50
    t = _trace(addrs)
    h = build_hit_ratio_function(reuse_distances(t, "trd"))
    for c in [1, 2, 3, 5, 8, 13, 21, 34, 50, 64]:
        sim = simulate(t, c, WritePolicy.WB)
        assert sim.hit_ratio == pytest.approx(h(c), abs=1e-12), c


def test_hit_ratio_monotone_and_saturating():
    rng = np.random.default_rng(1)
    t = _trace(rng.integers(0, 40, 500))
    h = build_hit_ratio_function(reuse_distances(t, "trd"))
    vals = h(np.arange(0, 60))
    assert np.all(np.diff(vals) >= -1e-15)
    assert h(h.max_useful_size) == pytest.approx(h.max_hit_ratio)
    assert h(10**9) == pytest.approx(h.max_hit_ratio)


def _mk_h(edges, heights, n=1000):
    return HitRatioFunction(np.asarray(edges, np.int64),
                            np.asarray(heights, float), n)


def _brute_force_best(hs, capacity, t_fast, t_slow):
    """Exhaustive search over breakpoint combinations (tiny instances)."""
    options = []
    for h in hs:
        opts = [int(e) for e in h.edges]
        options.append(opts)
    best, best_alloc = float("inf"), None
    for combo in itertools.product(*options):
        if sum(combo) <= capacity:
            lat = aggregate_latency(hs, np.array(combo), t_fast, t_slow)
            if lat < best - 1e-12:
                best, best_alloc = lat, combo
    return best, best_alloc


@settings(max_examples=60, deadline=None)
@given(st.lists(
    st.lists(st.tuples(st.integers(1, 20), st.floats(0.01, 0.2)),
             min_size=1, max_size=4),
    min_size=2, max_size=4),
    st.integers(5, 60))
def test_greedy_feasibility_and_bounds(steps_per_tenant, capacity):
    hs = []
    for steps in steps_per_tenant:
        sizes = np.cumsum([s for s, _ in steps])
        heights = np.minimum(np.cumsum([h for _, h in steps]), 1.0)
        hs.append(_mk_h(np.concatenate([[0], sizes]),
                        np.concatenate([[0.0], heights])))
    res = greedy_allocate(hs, capacity, 1.0, 20.0, c_min=0)
    assert int(res.sizes.sum()) <= max(capacity,
                                       sum(h.max_useful_size for h in hs))
    if not res.feasible:
        assert int(res.sizes.sum()) <= capacity
    for h, s in zip(hs, res.sizes):
        assert 0 <= s <= h.max_useful_size


def test_greedy_near_optimal_vs_brute_force():
    """Breakpoint greedy: exact on the hull, <= one-breakpoint knapsack gap
    at tight capacities (cap=4 exhibits the documented gap)."""
    hs = [
        _mk_h([0, 2, 5, 9], [0.0, 0.4, 0.6, 0.7]),
        _mk_h([0, 3, 7], [0.0, 0.5, 0.65]),
        _mk_h([0, 1, 4, 10], [0.0, 0.3, 0.5, 0.6]),
    ]
    for cap in (4, 8, 12, 16, 26):
        res = greedy_allocate(hs, cap, 1.0, 20.0, c_min=0)
        best, _ = _brute_force_best(hs, cap, 1.0, 20.0)
        assert res.latency <= best * 1.06 + 1e-9, (cap, res.latency, best)
    # ample capacity: exact
    res = greedy_allocate(hs, 26, 1.0, 20.0, c_min=0)
    best, _ = _brute_force_best(hs, 26, 1.0, 20.0)
    assert res.latency == pytest.approx(best, rel=1e-9)


def test_feasible_case_allocates_urd_sizes():
    hs = [_mk_h([0, 5], [0.0, 0.5]), _mk_h([0, 7], [0.0, 0.4])]
    res = greedy_allocate(hs, 100, 1.0, 20.0, c_min=1)
    assert res.feasible
    assert list(res.sizes) == [5, 7]
    res2 = pgd_solve(hs, 100, 1.0, 20.0, c_min=1)
    assert res2.feasible and list(res2.sizes) == [5, 7]


def test_pgd_respects_constraints_and_is_competitive():
    rng = np.random.default_rng(3)
    hs = []
    for _ in range(6):
        k = rng.integers(2, 6)
        sizes = np.sort(rng.choice(np.arange(1, 200), size=k, replace=False))
        heights = np.sort(rng.random(k)) * 0.8
        hs.append(_mk_h(np.concatenate([[0], sizes]),
                        np.concatenate([[0.0], heights])))
    cap = int(sum(h.max_useful_size for h in hs) * 0.5)
    res = pgd_solve(hs, cap, 1.0, 20.0, c_min=0)
    assert int(res.sizes.sum()) <= cap
    for h, s in zip(hs, res.sizes):
        assert 0 <= s <= h.max_useful_size
    greedy = greedy_allocate(hs, cap, 1.0, 20.0, c_min=0)
    # fmincon-analog is a local method: allow 25% optimality gap vs exact
    assert res.latency <= greedy.latency * 1.25 + 1e-9


def test_appendix_d_convexity_of_relaxation():
    """App. D: the relaxed objective is convex along any segment when the
    interpolated h is concave (checked numerically)."""
    h = _mk_h([0, 4, 10, 20], [0.0, 0.5, 0.8, 0.9])
    c = np.linspace(0, 20, 41)
    lat = (h.interp(c) * 1.0 + (1 - h.interp(c)) * 20.0)
    d2 = np.diff(lat, 2)
    assert np.all(d2 >= -1e-9)   # convex (non-increasing marginal gain)
