"""Two-level RO (write-around) under eviction pressure: token replay ≡
interpreter oracle.

The differential suite for the per-level eviction-token formulation
(``batch_sim._ro_token_replay_levels`` and its ``lax.fori_loop`` device
port): small capacities + few addresses force invalidation pressure, so
every window here used to take the per-access interpreter fallback and now
must replay vectorized — bit-identical per-level hits, write hits, cache
writes (endurance), demotions, flush charges, latency and final per-level
LRU states, cold and across warm multi-window chains, with clean and
dirty-accepting L2 policies.  ``SimResult.fallback`` must stay 0
everywhere except genuinely degenerate windows.  Engine comparisons run
through the shared differential oracle harness (``tests/oracle.py``).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from oracle import (EngineDiff, assert_results_equal, examples, mk_trace,
                    trace_strategy)
from repro.core import (Trace, WritePolicy, make_manager,
                        ro_token_replay_levels_device, simulate_batch)
from repro.core.batch_sim import _ro_token_replay_levels
from repro.core.simulator import LRUCache
from repro.core.trace import prev_next_occurrence


def ro_strategy(max_n=60, max_addr=5):
    return trace_strategy(max_n=max_n, max_addr=max_addr)


# --------------------------------------------- cold, both L2 dirty policies
@settings(max_examples=examples(200), deadline=None)
@given(ro_strategy(), st.integers(1, 3), st.integers(1, 3),
       st.sampled_from([WritePolicy.WB, WritePolicy.RO]),
       st.sampled_from([0.0, 10.0]))
def test_ro_pressure_cold_matches_interpreter(trace_list, c1, c2, p2, flush):
    t = mk_trace(trace_list)
    r = EngineDiff([c1], [WritePolicy.RO], [c2], [p2],
                   flush=flush).run_window([t])[0]
    # pressure stays on the token path; only the degenerate empty
    # two-level window takes the interpreter
    assert r.fallback == (1 if len(t) == 0 else 0)


# ------------------------------------ warm chains under sustained pressure
@settings(max_examples=examples(60), deadline=None)
@given(st.lists(ro_strategy(max_n=50, max_addr=5), min_size=2, max_size=4),
       st.integers(1, 3), st.integers(1, 3),
       st.sampled_from([WritePolicy.WB, WritePolicy.RO]))
def test_ro_pressure_warm_chain_matches_interpreter(windows, c1, c2, p2):
    """Warm per-level state (content, order, dirty flags) must survive the
    token replay byte-identically across windows; the first window runs WB
    to seed dirty blocks into the hierarchy before RO takes over."""
    diff = EngineDiff([c1], [WritePolicy.RO], [c2], [p2], flush=10.0)
    for w, tl in enumerate(windows):
        pol = WritePolicy.WB if w == 0 else WritePolicy.RO
        diff.run_window([mk_trace(tl)], policies=[pol])


# --------------------------------------------- device port ≡ host oracle
@settings(max_examples=examples(60), deadline=None)
@given(ro_strategy(max_n=80, max_addr=5), st.integers(0, 3),
       st.integers(0, 3), st.integers(1, 4), st.integers(1, 4),
       st.booleans())
def test_ro_levels_device_matches_host(trace_list, n_l2, n_l1, c1, c2,
                                       clean2):
    """The fori_loop port must reproduce death/dirty/level/flush/demotion
    outputs exactly, including warm-L2 and warm-L1 pseudo-read prefixes."""
    t = mk_trace(trace_list)
    n_l2, n_l1 = min(n_l2, c2), min(n_l1, c1)
    warm = np.arange(100, 100 + n_l2 + n_l1, dtype=np.int64)
    addrs = np.concatenate([warm, t.addrs])
    rd = np.concatenate([np.ones(warm.size, bool), t.is_read])
    n = addrs.size
    if n == 0:
        return
    prev, nxt = prev_next_occurrence(addrs)
    nxt = np.minimum(nxt, n)
    force = np.zeros(n, bool)
    force[:warm.size] = (np.arange(warm.size) % 2) == 0
    args = (rd, prev, nxt, force, c1, c2, n_l2, clean2)
    dh, yh, lh, fh, mh = _ro_token_replay_levels(*args)
    dd, yd, ld, fd, md = ro_token_replay_levels_device(*args)
    assert np.array_equal(dh, dd)
    assert np.array_equal(yh, yd)
    assert np.array_equal(lh, ld)
    assert (fh, mh) == (fd, md)


# ------------------------------------------- per-level flush accounting
def test_clean_l2_flushes_at_demotion_under_pressure(engine_diff):
    """A dirty warm-L1 block demoted under RO pressure must flush at the
    demotion boundary (clean L2) or at its final L2 eviction (WB L2) —
    one flush either way, charged on the vectorized path."""
    # warm L1 = {9 (dirty)}; reads to 0,1,2 demote 9, then push it out of
    # the 1-block L2 entirely
    t = Trace(np.array([0, 1, 2], np.int64), np.ones(3, bool))
    for p2 in (WritePolicy.RO, WritePolicy.WB):
        diff = engine_diff([1], [WritePolicy.RO], [1], [p2], flush=5.0)
        for caches in (diff.ref1, diff.got1):
            caches[0].set_state_arrays(np.array([9], np.int64),
                                       np.array([True]))
        r = diff.run_window([t])[0]
        # clean2 (p2=RO): flush when 9 demotes; WB L2: flush when 9 is
        # finally evicted from L2 — one 5.0 charge either way
        assert r.total_latency == pytest.approx(3 * 20.0 + 5.0), p2
        assert r.cache_writes_l2 == 3       # 9, 0, 1 each demoted
        assert r.fallback == 0
        assert list(diff.got1[0]._od) == [2], p2
        assert list(diff.got2[0]._od) == [1], p2


def test_ro_pressure_endurance_counters(engine_diff):
    """cache_writes = installs + promotions; cache_writes_l2 = demotions —
    checked against the interpreter on a promotion-heavy pressure mix."""
    rng = np.random.default_rng(3)
    t = Trace(rng.integers(0, 5, 300).astype(np.int64),
              rng.random(300) < 0.7)
    r_b = engine_diff([2], [WritePolicy.RO], [2],
                      [WritePolicy.WB]).run_window([t])[0]
    assert r_b.fallback == 0
    assert r_b.cache_writes == (r_b.reads - r_b.read_hits
                                - r_b.read_hits_l2) + r_b.read_hits_l2
    assert r_b.cache_writes_l2 > 0              # pressure ⇒ demotions


# ----------------------------------------------------- fallback telemetry
def test_manager_pressure_mix_no_fallback():
    """A pressure-heavy all-RO two-level deployment must keep every window
    on the vectorized path (ro_fallback_windows == 0) and still agree with
    the interpreter engine exactly."""
    names = ["wdev_0", "hm_1", "prn_1"]
    from repro.data.traces import msr_trace
    mgrs = {}
    for engine in ("batch", "lru"):
        mgr = make_manager("etica", 30, names, capacity2=60, c_min=5,
                           initial_blocks=10, w_threshold=0.0,
                           flush_cost=10.0, engine=engine)
        for w in range(3):
            traces = [msr_trace(nm, 400, seed=31 * w + i)
                      for i, nm in enumerate(names)]
            mgr.run_window(traces)
        mgrs[engine] = mgr
    mb, ml = mgrs["batch"], mgrs["lru"]
    assert all(t.policy is WritePolicy.RO for t in mb.tenants)
    assert mb.summary()["ro_fallback_windows"] == 0
    for tb, tl in zip(mb.tenants, ml.tenants):
        assert_results_equal(tl.result, tb.result)
        assert list(tb.cache._od.items()) == list(tl.cache._od.items())
        assert list(tb.cache2._od.items()) == list(tl.cache2._od.items())


def test_degenerate_windows_still_count_as_fallback():
    """Empty two-level windows and warm L2 behind a dead C2 <= 0 level are
    the only remaining interpreter replays — flagged in telemetry."""
    empty = Trace(np.zeros(0, np.int64), np.zeros(0, bool))
    r = simulate_batch(empty, 2, WritePolicy.RO, capacity2=2)
    assert r.fallback == 1
    # warm L2 content behind a dead level
    c1, c2 = LRUCache(2), LRUCache(0)
    c2.set_state_arrays(np.array([7], np.int64), np.array([False]))
    t = Trace(np.array([7, 8], np.int64), np.ones(2, bool))
    r = simulate_batch(t, 2, WritePolicy.RO, cache=c1, cache2=c2)
    assert r.fallback == 1
    # while an ordinary pressure window is not a fallback
    t = Trace(np.arange(6, dtype=np.int64), np.ones(6, bool))
    assert simulate_batch(t, 1, WritePolicy.RO, capacity2=1).fallback == 0


# ------------------------------------------------- kernel live-count scan
def test_ro_live_counts_ref_matches_numpy():
    import jax.numpy as jnp

    from repro.kernels.cache_sim.ops import ro_live_counts_accel
    from repro.kernels.cache_sim.ref import live_counts_ref
    rng = np.random.default_rng(5)
    addrs = rng.integers(0, 30, 500).astype(np.int64)
    occ = rng.random(500) < 0.6
    _, nxt = prev_next_occurrence(addrs)
    nxt = np.minimum(nxt, 500)
    d = (np.bincount(np.flatnonzero(occ), minlength=501)
         - np.bincount(nxt[occ], minlength=501))
    want = np.cumsum(d[:500])
    got = ro_live_counts_accel(nxt, occ)        # O(n) delta-cumsum path
    assert np.array_equal(got, want)
    dense = np.asarray(live_counts_ref(jnp.asarray(nxt, jnp.int32),
                                       jnp.asarray(occ, jnp.int32)))
    assert np.array_equal(dense, want)          # dense (i, j)-plane oracle


@pytest.mark.slow
def test_ro_live_counts_kernel_interpret():
    from repro.kernels.cache_sim.ops import ro_live_counts_accel
    rng = np.random.default_rng(6)
    addrs = rng.integers(0, 25, 400).astype(np.int64)
    occ = rng.random(400) < 0.5
    _, nxt = prev_next_occurrence(addrs)
    nxt = np.minimum(nxt, 400)
    lin = ro_live_counts_accel(nxt, occ)
    ker = ro_live_counts_accel(nxt, occ, use_kernel=True)
    assert np.array_equal(lin, ker)
