"""GPipe pipeline over the pod axis: exactness vs sequential execution."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 4, timeout: int = 420) -> str:
    code = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={devices}"\n'
        + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_pipeline_matches_sequential():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed.pipeline import pipeline_forward, stack_stages

        n_stages, L, B, D = 2, 8, 8, 16
        mesh = Mesh(np.array(jax.devices()[:2]).reshape(2,), ("pod",))
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (L, D, D)) * 0.1
        x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

        def layer(w, h):
            return jnp.tanh(h @ w)

        def seq_forward(ws, x):
            def body(h, w):
                return layer(w, h), None
            h, _ = jax.lax.scan(body, x, ws)
            return h

        def stage_fn(ws_stage, h):
            def body(hh, w):
                return layer(w, hh), None
            h, _ = jax.lax.scan(body, h, ws_stage)
            return h

        expect = seq_forward(ws, x)
        staged = stack_stages(ws, n_stages)
        with mesh:
            got = jax.jit(lambda p, xx: pipeline_forward(
                stage_fn, p, xx, mesh=mesh, n_microbatches=4))(staged, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_pipeline_grads_flow():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed.pipeline import pipeline_forward, stack_stages

        mesh = Mesh(np.array(jax.devices()[:2]).reshape(2,), ("pod",))
        L, B, D = 4, 4, 8
        ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.1
        x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

        def stage_fn(ws_stage, h):
            def body(hh, w):
                return jnp.tanh(hh @ w), None
            h, _ = jax.lax.scan(body, h, ws_stage)
            return h

        def loss_pipe(p):
            with mesh:
                y = pipeline_forward(stage_fn, p, x, mesh=mesh,
                                     n_microbatches=2)
            return jnp.sum(y ** 2)

        def loss_seq(ws):
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, ws)
            return jnp.sum(h ** 2)

        g_pipe = jax.grad(loss_pipe)(stack_stages(ws, 2))
        g_seq = jax.grad(loss_seq)(ws)
        np.testing.assert_allclose(
            np.asarray(g_pipe).reshape(L, D, D), np.asarray(g_seq),
            rtol=1e-4, atol=1e-5)
        print("PIPE_GRADS_OK")
    """)
    assert "PIPE_GRADS_OK" in out
