"""Partition budget properties for every comparison scheme.

Regression tests for two baseline-partitioner bugs: ``_static_partition``
silently dropped the ``capacity % n`` remainder blocks, and
``_reuse_intensity_partition`` applied the ``c_min`` clamp *after* the
proportional floor without re-normalizing, so intensity-skewed mixes could
allocate more than the budget.  Both must now allocate exactly the budget
(deterministically), and every scheme in ``SCHEMES`` must respect
``sum(sizes) <= capacity`` with the per-tenant minimum honored.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (GlobalLRUManager, Trace, build_hit_ratio_function,
                        make_manager, reuse_distances)
from repro.core.baselines import (SCHEMES, _reuse_intensity_partition,
                                  _static_partition)
from repro.data.traces import msr_trace


def _curves(rng, n):
    hs = []
    for i in range(n):
        ln = int(rng.integers(1, 80))
        t = Trace(rng.integers(0, max(int(rng.integers(1, 12)), 1),
                               ln).astype(np.int64),
                  rng.random(ln) < 0.7)
        hs.append(build_hit_ratio_function(reuse_distances(t, "urd")))
    return hs


@settings(max_examples=80, deadline=None)
@given(st.integers(1, 8), st.integers(0, 200), st.integers(0, 60),
       st.integers(0, 10_000))
def test_reuse_intensity_partition_respects_budget(n, capacity, c_min, seed):
    hs = _curves(np.random.default_rng(seed), n)
    part = _reuse_intensity_partition(hs, capacity, 1.0, 20.0, c_min=c_min)
    assert part.sizes.shape == (n,)
    assert int(part.sizes.sum()) == capacity      # exact, never over
    assert np.all(part.sizes >= min(c_min, capacity // n))
    # deterministic (largest-remainder ties broken by index)
    again = _reuse_intensity_partition(hs, capacity, 1.0, 20.0, c_min=c_min)
    assert np.array_equal(part.sizes, again.sizes)


def test_reuse_intensity_partition_skew_regression():
    """The documented overshoot case: two tenants, capacity 10, c_min 5,
    intensities ~99:1 used to allocate 14 blocks."""
    rng = np.random.default_rng(0)
    heavy = Trace(rng.integers(0, 4, 400).astype(np.int64),
                  np.ones(400, bool))
    light = Trace(np.array([0, 1, 0], np.int64), np.ones(3, bool))
    hs = [build_hit_ratio_function(reuse_distances(t, "urd"))
          for t in (heavy, light)]
    part = _reuse_intensity_partition(hs, 10, 1.0, 20.0, c_min=5)
    assert int(part.sizes.sum()) == 10
    assert np.all(part.sizes >= 5)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 9), st.integers(0, 100), st.integers(0, 10_000))
def test_static_partition_distributes_remainder(n, capacity, seed):
    hs = _curves(np.random.default_rng(seed), n)
    part = _static_partition(hs, capacity, 1.0, 20.0)
    assert int(part.sizes.sum()) == capacity      # remainder not dropped
    assert int(part.sizes.max() - part.sizes.min()) <= (1 if n > 1 else 0)
    assert np.all(np.diff(part.sizes) <= 0)       # deterministic: first get +1


@pytest.mark.parametrize("scheme", SCHEMES)
def test_every_scheme_respects_budget_and_c_min(scheme):
    names = ["wdev_0", "hm_1", "prn_1", "web_0"]
    capacity, c_min = 210, 20
    traces = [msr_trace(nm, 500, seed=i) for i, nm in enumerate(names)]
    if scheme == "global":
        mgr = GlobalLRUManager(capacity, names)
        mgr.run_window(traces)
        assert mgr.summary()["allocated_blocks"] == capacity
        return
    kw = dict(capacity2=400) if scheme == "etica" else {}
    mgr = make_manager(scheme, capacity, names, c_min=c_min,
                       initial_blocks=30, **kw)
    for w in range(2):
        mgr.run_window([msr_trace(nm, 500, seed=7 * w + i)
                        for i, nm in enumerate(names)])
    d = mgr.history[-1]
    assert int(d.sizes.sum()) <= capacity
    # c_min honored up to each tenant's useful mass (a tenant whose whole
    # reuse fits in fewer blocks is never force-fed)
    floors = np.minimum(c_min, [t.urd_size for t in mgr.tenants])
    floors = np.minimum(floors, capacity // len(names))
    assert np.all(d.sizes >= floors), (d.sizes, floors)
    if scheme == "etica":
        assert int(d.sizes2.sum()) <= 400
