"""Shared test setup.

Installs the pure-python ``hypothesis`` fallback (tests/_hypothesis_fallback)
when the real library is not importable, so the property-test modules can be
collected and run in hermetic environments.  With ``pip install -e .[test]``
the genuine hypothesis package takes precedence.
"""
import importlib.util
import pathlib
import sys


def _install_hypothesis_fallback() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass
    path = pathlib.Path(__file__).with_name("_hypothesis_fallback.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies


_install_hypothesis_fallback()
