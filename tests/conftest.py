"""Shared test setup.

Installs the pure-python ``hypothesis`` fallback (tests/_hypothesis_fallback)
when the real library is not importable, so the property-test modules can be
collected and run in hermetic environments.  With ``pip install -e .[test]``
the genuine hypothesis package takes precedence.

Also exposes the differential oracle harness (``tests/oracle.py``) as
fixtures, so non-hypothesis tests can consume the shared engine-equality
core without imports.  The nightly CI job scales every suite's example
count through ``HYP_EXAMPLES_SCALE`` (see ``oracle.examples``).
"""
import importlib.util
import pathlib
import sys

import pytest


def _install_hypothesis_fallback() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass
    path = pathlib.Path(__file__).with_name("_hypothesis_fallback.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies


_install_hypothesis_fallback()


@pytest.fixture
def engine_diff():
    """Factory for the differential oracle harness (tests/oracle.py)."""
    from oracle import EngineDiff
    return EngineDiff


@pytest.fixture
def oracle_mod():
    """The oracle module itself (strategies, comparators, helpers)."""
    import oracle
    return oracle
