"""Shared test setup.

Installs the pure-python ``hypothesis`` fallback (tests/_hypothesis_fallback)
when the real library is not importable, so the property-test modules can be
collected and run in hermetic environments.  With ``pip install -e .[test]``
the genuine hypothesis package takes precedence.

Also exposes the differential oracle harness (``tests/oracle.py``) as
fixtures, so non-hypothesis tests can consume the shared engine-equality
core without imports.  The nightly CI job scales every suite's example
count through ``HYP_EXAMPLES_SCALE`` (see ``oracle.examples``).

Forces 8 host platform devices (before any jax import) so the sharded
control plane (``core.shard_pipeline``) runs against a real multi-device
mesh on CPU hosts; subprocess-based tests overwrite ``XLA_FLAGS`` in the
child themselves, so the parent-level flag never leaks a wrong count.
"""
import importlib.util
import os
import pathlib
import sys

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import pytest


def _install_hypothesis_fallback() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass
    path = pathlib.Path(__file__).with_name("_hypothesis_fallback.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies


_install_hypothesis_fallback()


@pytest.fixture
def engine_diff():
    """Factory for the differential oracle harness (tests/oracle.py)."""
    from oracle import EngineDiff
    return EngineDiff


@pytest.fixture
def oracle_mod():
    """The oracle module itself (strategies, comparators, helpers)."""
    import oracle
    return oracle


@pytest.fixture(scope="session")
def shard_mesh():
    """Full-width ``("shards",)`` control-plane mesh (8 forced host
    devices on CPU, the real device set on accelerator hosts)."""
    from repro.distributed.sharding import control_plane_mesh
    return control_plane_mesh()
