"""Segment-aligned padding is exact: padded fused monitoring ≡ per-tenant.

The fused monitor's counting pass lays all tenants' windows out on a
power-of-two padded, self-aligned tape and stops the merge recursion at
each segment's padded width (``batch_sim.padded_segment_layout`` /
``count_prev_ge_padded``).  These property tests pin the cancellation
proof to adversarial shapes: empty tenant windows, single-access segments,
all-write traces, window lengths and tenant counts straddling power-of-two
boundaries, and the SHARDS-sampled sub-trace path — plus the width-bounded
counting primitives against their unpadded oracles and the ``cache_sim``
segments ops/kernel entry against the host pass.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from oracle import examples
from repro.core import (Trace, analyze_windows, build_hit_ratio_function,
                        reuse_distances_fast, sampled_reuse_distances,
                        shards_salt, urd_cache_blocks)
from repro.core.batch_sim import (_PAD_MIN, _stack_distances_host,
                                  count_prev_ge, count_prev_ge_padded,
                                  padded_segment_layout)
from repro.core.monitor import _segment_links
from repro.core.write_policy import write_ratio

# window shapes that straddle power-of-two boundaries, plus degenerates
ADVERSARIAL_LENS = [0, 1, 2, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 129]


def windows_strategy(max_tenants=9, max_n=120, max_addr=12):
    """Random multi-tenant windows: (addr, is_read) lists, empties common."""
    return st.lists(
        st.lists(st.tuples(st.integers(0, max_addr), st.booleans()),
                 min_size=0, max_size=max_n),
        min_size=1, max_size=max_tenants)


def mk_traces(windows):
    out = []
    for i, w in enumerate(windows):
        addrs = np.array([a for a, _ in w], dtype=np.int64)
        reads = np.array([r for _, r in w], dtype=bool)
        out.append(Trace(addrs, reads, f"t{i}"))
    return out


def links_for(traces):
    lens = np.array([len(t) for t in traces], dtype=np.int64)
    bounds = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    addrs = (np.concatenate([t.addrs for t in traces]) if int(lens.sum())
             else np.zeros(0, np.int64))
    tid = np.repeat(np.arange(len(traces), dtype=np.int64), lens)
    prev, nxt = _segment_links(addrs, tid, bounds)
    return prev, nxt, bounds


def assert_monitor_matches_per_tenant(traces, kind="urd"):
    mon = analyze_windows(traces, kind)
    for k, tr in enumerate(traces):
        rd = reuse_distances_fast(tr, kind)
        h = build_hit_ratio_function(rd)
        assert np.array_equal(h.edges, mon.curves[k].edges), k
        assert np.array_equal(h.heights, mon.curves[k].heights), k
        assert h.n_accesses == mon.curves[k].n_accesses, k
        assert urd_cache_blocks(rd) == mon.urd_sizes[k], k
        assert write_ratio(tr) == mon.write_ratios[k], k


# --------------------------------------------------- fused == per-tenant
@settings(max_examples=examples(40), deadline=None)
@given(windows_strategy(), st.sampled_from(["urd", "trd"]))
def test_padded_fused_monitor_bit_identical(windows, kind):
    assert_monitor_matches_per_tenant(mk_traces(windows), kind)


@pytest.mark.parametrize("n_tenants", [1, 2, 3, 15, 16, 17, 31, 33])
def test_tenant_counts_straddling_pow2(n_tenants):
    """Tenant counts around power-of-two boundaries, window lengths from
    the adversarial list (empty, single-access, straddling widths)."""
    rng = np.random.default_rng(n_tenants)
    traces = []
    for i in range(n_tenants):
        n = ADVERSARIAL_LENS[i % len(ADVERSARIAL_LENS)]
        traces.append(Trace(rng.integers(0, 7, n).astype(np.int64),
                            rng.random(n) < 0.6, f"t{i}"))
    assert_monitor_matches_per_tenant(traces)


def test_adversarial_degenerates():
    """Empty windows, single accesses, all-writes, and one long tenant
    behind many empties — the padding layout's worst shapes."""
    rng = np.random.default_rng(0)
    traces = [
        Trace(np.zeros(0, np.int64), np.zeros(0, bool), "empty"),
        Trace(np.array([5], np.int64), np.array([True]), "single-read"),
        Trace(np.array([5], np.int64), np.array([False]), "single-write"),
        Trace(np.arange(40, dtype=np.int64) % 4, np.zeros(40, bool),
              "all-writes"),
        Trace(np.zeros(0, np.int64), np.zeros(0, bool), "empty2"),
        Trace(rng.integers(0, 50, 513).astype(np.int64),
              rng.random(513) < 0.5, "long"),
    ]
    for kind in ("urd", "trd"):
        assert_monitor_matches_per_tenant(traces, kind)


# ------------------------------------------------- SHARDS sub-trace path
@settings(max_examples=examples(25), deadline=None)
@given(windows_strategy(max_tenants=5, max_n=200, max_addr=60),
       st.sampled_from([0.3, 0.6]), st.integers(0, 7))
def test_padded_fused_monitor_sampled_path(windows, rate, seed):
    """The sampled path pads the *kept sub-tape*: still bit-identical to
    the per-tenant sampled engine, including zero-kept tenants."""
    traces = mk_traces(windows)
    mon = analyze_windows(traces, "urd", sample_rate=rate, window_seed=seed)
    for k, tr in enumerate(traces):
        rd = sampled_reuse_distances(tr, "urd", rate=rate,
                                     salt=shards_salt(seed, k))
        h = build_hit_ratio_function(rd)
        assert np.array_equal(h.edges, mon.curves[k].edges), k
        assert np.array_equal(h.heights, mon.curves[k].heights), k
        assert mon.urd_sizes[k] == urd_cache_blocks(rd), k


# ------------------------------------------- width-bounded primitives
@settings(max_examples=examples(60), deadline=None)
@given(st.lists(st.integers(0, 150), min_size=1, max_size=8),
       st.integers(0, 9))
def test_padded_counting_pass_matches_per_segment(lens, seed):
    """The padded tape's SD pass ≡ each segment counted alone."""
    rng = np.random.default_rng(seed)
    bounds = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    traces = [Trace(rng.integers(0, 9, n).astype(np.int64),
                    np.ones(n, bool)) for n in lens]
    prev, nxt, bounds = links_for(traces)
    got = _stack_distances_host(prev, nxt, bounds=bounds)
    for k, (s, e) in enumerate(zip(bounds[:-1], bounds[1:])):
        s, e = int(s), int(e)
        if e <= s:
            continue
        alone = reuse_distances_fast(traces[k], "trd").distances
        assert np.array_equal(got[s:e], alone), k


@settings(max_examples=examples(60), deadline=None)
@given(st.lists(st.lists(st.integers(0, 40), min_size=0, max_size=90),
                min_size=1, max_size=6))
def test_count_prev_ge_padded_matches_unpadded(segments):
    """Width-bounded merge counts ≡ count_prev_ge per segment (pads 0)."""
    lens = np.array([len(s) for s in segments], dtype=np.int64)
    bounds = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    src, tpos, base_src, base_pad, widths, total, starts = \
        padded_segment_layout(bounds)
    if tpos.size == 0:
        return
    vals = np.concatenate([np.asarray(s, np.int64) for s in segments]) + 1
    gy = np.zeros(total, dtype=np.int64)
    gy[tpos] = vals if src is None else vals[src]
    cnt = count_prev_ge_padded(gy, widths)
    # compare per segment against the unpadded primitive
    w_off = 0
    order = np.argsort(-np.maximum(
        1 << np.ceil(np.log2(np.maximum(lens[lens > 0], 1))).astype(int),
        _PAD_MIN), kind="stable")
    seg_ids = np.flatnonzero(lens > 0)[order]
    for row, k in enumerate(seg_ids):
        w = int(widths[row])
        seg = np.asarray(segments[k], np.int64) + 1
        want = count_prev_ge(seg)
        assert np.array_equal(cnt[w_off:w_off + seg.size], want), k
        w_off += w


def test_layout_alignment_invariants():
    """Every padded segment starts at a multiple of its own width, widths
    descend, and the real entries land inside their own row."""
    rng = np.random.default_rng(1)
    for _ in range(50):
        lens = rng.integers(0, 300, rng.integers(1, 10))
        bounds = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
        src, tpos, base_src, base_pad, widths, total, starts = \
            padded_segment_layout(bounds)
        if tpos.size == 0:
            continue
        assert np.all(widths[:-1] >= widths[1:])        # descending
        assert np.all((1 << np.round(np.log2(widths)).astype(int))
                      == widths)                        # powers of two
        row_base = np.concatenate([[0], np.cumsum(widths)[:-1]])
        assert np.all(row_base % widths == 0)           # self-aligned
        assert int(widths.sum()) == total
        # every real entry sits inside its own padded row
        assert np.all(tpos.astype(np.int64) - base_pad < np.repeat(
            widths, np.diff(np.flatnonzero(np.concatenate(
                [[True], base_pad[1:] != base_pad[:-1], [True]])))))


# --------------------------------------------- cache_sim segments entry
@settings(max_examples=examples(20), deadline=None)
@given(st.lists(st.integers(0, 120), min_size=1, max_size=6),
       st.integers(0, 5))
def test_segments_accel_ref_matches_host(lens, seed):
    from repro.kernels.cache_sim.ops import stack_distances_segments_accel
    rng = np.random.default_rng(seed)
    traces = [Trace(rng.integers(0, 11, n).astype(np.int64),
                    np.ones(n, bool)) for n in lens]
    prev, nxt, bounds = links_for(traces)
    host = _stack_distances_host(prev, nxt, bounds=bounds)
    acc = stack_distances_segments_accel(prev, nxt, bounds=bounds,
                                         use_kernel=False)
    assert np.array_equal(host, acc)


def test_segments_dense_ref_masks_cross_block():
    """The dense segments oracle counts nothing across aligned blocks even
    when fed unsevered links (the mask, not the links, is load-bearing)."""
    import jax.numpy as jnp
    from repro.kernels.cache_sim.ref import (cache_sim_ref,
                                             cache_sim_segments_ref)
    rng = np.random.default_rng(4)
    n, w = 128, 32
    prev = rng.integers(-1, n, n)
    nxt = rng.integers(0, n + 1, n)
    occ = np.ones(n, np.int32)
    seg = np.asarray(cache_sim_segments_ref(
        jnp.asarray(prev, jnp.int32), jnp.asarray(nxt, jnp.int32),
        jnp.asarray(occ), w))
    # reference: dense count with j restricted to i's block by hand
    blk = np.arange(n) // w
    for i in range(n):
        js = np.flatnonzero((np.arange(n) > prev[i]) & (np.arange(n) < i)
                            & (nxt >= i) & (blk == blk[i]))
        assert seg[i] == js.size, i
    # and the unrestricted oracle differs whenever a window spans blocks
    full = np.asarray(cache_sim_ref(jnp.asarray(prev, jnp.int32),
                                    jnp.asarray(nxt, jnp.int32),
                                    jnp.asarray(occ)))
    assert np.any(full != seg)


@pytest.mark.slow
def test_segments_kernel_interpret_matches_ref():
    from repro.kernels.cache_sim.ops import stack_distances_segments_accel
    rng = np.random.default_rng(8)
    lens = [300, 70, 64, 5, 0, 129]
    traces = [Trace(rng.integers(0, 17, n).astype(np.int64),
                    np.ones(n, bool)) for n in lens]
    prev, nxt, bounds = links_for(traces)
    host = _stack_distances_host(prev, nxt, bounds=bounds)
    acc = stack_distances_segments_accel(prev, nxt, bounds=bounds,
                                         use_kernel=True)
    assert np.array_equal(host, acc)
