"""Golden regression tests: small-seed fig10/12/14/16 outputs, frozen.

The full benchmark suite verifies the paper's figures bit-identically, but
only when someone runs it.  This suite freezes *small-seed* versions of
the four figure pipelines (allocation feasibility, request mixes + policy
assignment, performance(-per-cost), endurance) as a checked-in golden file
so any change to the decision loop — monitor, curves, partitioner, policy
assignment, replay engines — that shifts a single byte of figure output
fails ``pytest -x -q``, not just the nightly/full benchmark run.

Everything here is integer counts, policy strings, or float64 sums of
small products — deterministic on a fixed platform, and JSON round-trips
float64 exactly — so the comparison is strict equality.

Regenerate (after an *intentional* change) with:

    PYTHONPATH=src python tests/test_goldens.py --regen
"""
import json
import pathlib

import numpy as np

from repro.core import make_manager, request_type_mix, write_ratio
from repro.core.write_policy import assign_write_policy
from repro.data.scenarios import (churn, per_tenant_latency,
                                  replay_scenario, scan_flood)
from repro.data.traces import msr_trace

GOLDEN_PATH = pathlib.Path(__file__).parent / "goldens" / "figs_small.json"
NAMES = ["wdev_0", "hm_1", "prn_1", "web_0", "prxy_0", "ts_0"]
SIM = dict(t_fast=1.0, t_slow=20.0, flush_cost=10.0)


def _run_scheme(scheme, capacity, windows=2, n=400, **kw):
    mgr = make_manager(scheme, capacity, NAMES, c_min=10,
                       initial_blocks=20, engine="batch", **SIM, **kw)
    for w in range(windows):
        mgr.run_window([msr_trace(nm, n, seed=1000 * w + i)
                        for i, nm in enumerate(NAMES)])
    return mgr


def _fig10():
    """Allocation under limited capacity: totals + infeasibility."""
    out = {}
    for scheme in ("eci", "centaur"):
        mgr = _run_scheme(scheme, 900)
        out[scheme] = {
            "infeasible_windows": sum(not d.feasible for d in mgr.history),
            "allocs": [int(d.sizes.sum()) for d in mgr.history],
            "final_sizes": [int(s) for s in mgr.history[-1].sizes],
        }
    return out


def _fig12():
    """Request-type mixes, per-window policies, wThreshold sweep."""
    mixes, policies = {}, {}
    for nm in NAMES:
        t = msr_trace(nm, 600, seed=12)
        mixes[nm] = {k: float(v) for k, v in request_type_mix(t).items()}
        policies[nm] = [
            assign_write_policy(msr_trace(nm, 300, seed=100 + w), 0.5).value
            for w in range(3)]
    sweep = {str(thr): sum(assign_write_policy(
        msr_trace(nm, 300, seed=7), thr).value == "ro" for nm in NAMES)
        for thr in (0.2, 0.5, 0.8)}
    wr = {nm: float(write_ratio(msr_trace(nm, 600, seed=12)))
          for nm in NAMES}
    return {"mixes": mixes, "policies": policies, "sweep": sweep,
            "write_ratios": wr}


def _fig14():
    """Performance / perf-per-cost, ECI vs Centaur, limited capacity."""
    out = {}
    for scheme in ("eci", "centaur"):
        mgr = _run_scheme(scheme, 800)
        s = mgr.summary()
        out[scheme] = {
            "performance": float(s["performance"]),
            "perf_per_cost": float(s["perf_per_cost"]),
            "mean_latency": float(s["mean_latency"]),
            "tenant_latencies": [float(t.result.total_latency)
                                 for t in mgr.tenants],
        }
    return out


def _fig16():
    """Endurance: cache writes per tenant and totals."""
    out = {}
    for scheme in ("eci", "centaur"):
        mgr = _run_scheme(scheme, 900)
        out[scheme] = {
            "cache_writes": [int(t.result.cache_writes)
                             for t in mgr.tenants],
            "total": int(mgr.summary()["cache_writes"]),
            "policies": [t.policy.value for t in mgr.tenants],
        }
    return out


def _scenarios():
    """Scenario suite: per-scheme isolation metric on a small scan flood
    + the event-driven reconfiguration log on the churn scenario."""
    flood = scan_flood(n_victims=2, n_windows=6, flood_at=2, n_victim=800,
                       n_benign=400, cycle_base=400, cycle_step=100,
                       seed=0)
    isolation = {}
    for scheme in ("eci", "static"):
        def factory(names, _s=scheme):
            return make_manager(_s, 1024, names, c_min=32,
                                initial_blocks=32, engine="batch", **SIM)
        m_full, im_full = replay_scenario(flood, factory)
        m_solo, im_solo = replay_scenario(flood, factory,
                                          exclude={flood.aggressor})
        lat_full = per_tenant_latency(m_full, im_full)
        lat_solo = per_tenant_latency(m_solo, im_solo)
        degr = {str(v): float((lat_full[v] - lat_solo[v])
                              / max(lat_solo[v], 1e-12))
                for v in sorted(lat_solo) if v != flood.aggressor}
        isolation[scheme] = {
            "per_victim_degradation": degr,
            "max_degradation": max(degr.values()),
        }

    run = churn(seed=0)
    mgr, _ = replay_scenario(
        run, lambda names: make_manager(
            "eci", 2000, names, c_min=50, initial_blocks=50,
            engine="batch", phase_detect=True, reconfig_interval=4, **SIM))
    return {
        "isolation": isolation,
        "churn_events": [[e.window, e.tenant, e.reason]
                         for e in mgr.events],
        "churn_windows_analyzed": int(mgr.windows_analyzed),
        "churn_windows_run": int(mgr.windows_run),
    }


def compute_goldens():
    return {"fig10": _fig10(), "fig12": _fig12(), "fig14": _fig14(),
            "fig16": _fig16(), "scenarios": _scenarios()}


def _diff(path, want, got, out):
    if isinstance(want, dict) and isinstance(got, dict):
        for k in set(want) | set(got):
            _diff(f"{path}.{k}", want.get(k), got.get(k), out)
    elif isinstance(want, list) and isinstance(got, list):
        if len(want) != len(got):
            out.append(f"{path}: length {len(want)} != {len(got)}")
        else:
            for i, (a, b) in enumerate(zip(want, got)):
                _diff(f"{path}[{i}]", a, b, out)
    elif want != got:
        out.append(f"{path}: golden {want!r} != current {got!r}")


def test_fig_outputs_match_goldens():
    assert GOLDEN_PATH.exists(), \
        "golden file missing — run: python tests/test_goldens.py --regen"
    want = json.loads(GOLDEN_PATH.read_text())
    got = json.loads(json.dumps(compute_goldens()))  # normalize types
    mismatches: list[str] = []
    _diff("goldens", want, got, mismatches)
    assert not mismatches, "\n".join(
        ["figure outputs drifted from goldens (bit-identity broken);",
         "if intentional: PYTHONPATH=src python tests/test_goldens.py "
         "--regen"] + mismatches[:30])


def test_goldens_sanity():
    """The frozen numbers still tell the paper's story at small seed:
    ECI is feasible at least as often, and commits fewer cache writes."""
    g = json.loads(GOLDEN_PATH.read_text())
    assert g["fig10"]["eci"]["infeasible_windows"] <= \
        g["fig10"]["centaur"]["infeasible_windows"]
    assert g["fig16"]["eci"]["total"] < g["fig16"]["centaur"]["total"]
    assert np.isfinite(g["fig14"]["eci"]["performance"])
    iso = g["scenarios"]["isolation"]
    assert iso["eci"]["max_degradation"] <= \
        0.5 * iso["static"]["max_degradation"]
    reasons = {e[2] for e in g["scenarios"]["churn_events"]}
    assert {"join", "retire"} <= reasons


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="rewrite the golden file from the current code")
    if ap.parse_args().regen:
        GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN_PATH.write_text(json.dumps(compute_goldens(), indent=1,
                                          sort_keys=True) + "\n")
        print(f"wrote {GOLDEN_PATH}")
