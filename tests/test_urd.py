"""Reuse-distance engines: paper examples, cross-engine equality, properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AccessClass, Trace, classify_accesses, max_rd,
                        request_type_mix, reuse_distances,
                        reuse_distances_vectorized, sampled_reuse_distances,
                        total_cache_writes_wb, urd_cache_blocks, write_ratio)
from repro.core.write_policy import WritePolicy, assign_write_policy


def brute_force_rd(addrs, is_read, kind):
    """O(n²) straight-from-definition oracle."""
    out = np.full(len(addrs), -1, dtype=np.int64)
    for i in range(len(addrs)):
        prev = -1
        for j in range(i - 1, -1, -1):
            if addrs[j] == addrs[i]:
                prev = j
                break
        if prev < 0:
            continue
        if kind == "urd" and not is_read[i]:
            continue
        out[i] = len(set(addrs[prev + 1:i]))
    return out


def trace_strategy(max_n=60, max_addr=12):
    return st.lists(
        st.tuples(st.integers(0, max_addr), st.booleans()),
        min_size=0, max_size=max_n)


def _mk(trace_list):
    addrs = np.array([a for a, _ in trace_list], dtype=np.int64)
    reads = np.array([r for _, r in trace_list], dtype=bool)
    return Trace(addrs, reads)


class TestPaperFig5:
    """The worked example of §4: TRD=4 (5 blocks), URD=1 (2 blocks)."""

    def setup_method(self):
        addrs = np.array([1, 2, 1, 3, 4, 5, 2], dtype=np.int64)
        reads = np.array([False, True, True, True, True, True, False])
        self.trace = Trace(addrs, reads, "fig5")

    def test_trd(self):
        assert max_rd(reuse_distances(self.trace, "trd")) == 4
        assert urd_cache_blocks(reuse_distances(self.trace, "trd")) == 5

    def test_urd(self):
        assert max_rd(reuse_distances(self.trace, "urd")) == 1
        assert urd_cache_blocks(reuse_distances(self.trace, "urd")) == 2

    def test_classification(self):
        codes = classify_accesses(self.trace)
        # Req1 CW, Req2 CR, Req3 RAW, Req4-6 CR, Req7 WAR
        assert codes[0] == AccessClass.CW
        assert codes[2] == AccessClass.RAW
        assert codes[6] == AccessClass.WAR


@settings(max_examples=200, deadline=None)
@given(trace_strategy())
def test_engines_agree_with_brute_force(trace_list):
    from repro.core import reuse_distances_fast
    t = _mk(trace_list)
    for kind in ("trd", "urd"):
        bf = brute_force_rd(t.addrs, t.is_read, kind)
        fen = reuse_distances(t, kind).distances
        vec = reuse_distances_vectorized(t, kind, tile=16).distances
        fast = reuse_distances_fast(t, kind).distances
        assert np.array_equal(bf, fen), kind
        assert np.array_equal(bf, vec), kind
        assert np.array_equal(bf, fast), kind


@settings(max_examples=200, deadline=None)
@given(trace_strategy())
def test_urd_subset_of_trd(trace_list):
    """Paper Eq. 1: URD samples ⊆ TRD samples -> max/percentiles ordered."""
    t = _mk(trace_list)
    trd = reuse_distances(t, "trd")
    urd = reuse_distances(t, "urd")
    mask = urd.distances >= 0
    assert np.all(trd.distances[mask] == urd.distances[mask])
    assert max_rd(urd) <= max_rd(trd)
    assert urd_cache_blocks(urd) <= urd_cache_blocks(trd)


@settings(max_examples=100, deadline=None)
@given(trace_strategy())
def test_classification_partition(trace_list):
    """Every access has exactly one class; cold counts = distinct addrs."""
    t = _mk(trace_list)
    codes = classify_accesses(t)
    cold = np.sum((codes == AccessClass.CR) | (codes == AccessClass.CW))
    assert cold == t.n_unique
    mix = request_type_mix(t)
    assert abs(sum(mix.values()) - (1.0 if len(t) else 0.0)) < 1e-9


@settings(max_examples=100, deadline=None)
@given(trace_strategy())
def test_eq3_write_accounting(trace_list):
    """Eq. 3: WB cache writes = CR + CW + WAR + WAW."""
    t = _mk(trace_list)
    codes = classify_accesses(t)
    expected = int(np.sum(np.isin(codes, [AccessClass.CR, AccessClass.CW,
                                          AccessClass.WAR,
                                          AccessClass.WAW])))
    assert total_cache_writes_wb(t) == expected


@settings(max_examples=100, deadline=None)
@given(trace_strategy(), st.floats(0.1, 0.9))
def test_write_policy_threshold(trace_list, thr):
    t = _mk(trace_list)
    wr = write_ratio(t)
    pol = assign_write_policy(t, thr)
    assert pol is (WritePolicy.RO if wr >= thr else WritePolicy.WB)


def test_shards_sampling_unbiased_scale():
    rng = np.random.default_rng(0)
    addrs = rng.integers(0, 500, size=5000).astype(np.int64)
    t = Trace(addrs, np.ones(5000, bool))
    exact = reuse_distances(t, "trd")
    samp = sampled_reuse_distances(t, "trd", rate=0.3, seed=1)
    # scaled sample mean within 35% of exact mean (statistical)
    assert samp.samples.size > 100
    ratio = samp.samples.mean() / exact.samples.mean()
    assert 0.65 < ratio < 1.35, ratio


def test_shards_empty_subtrace_well_formed():
    """A fixed low rate on a tiny window can keep zero accesses: the result
    must still be a well-formed RDResult (no samples, saturated error bar,
    ``urd_cache_blocks`` -> 0), for both engines and both kinds."""
    from repro.core.reuse_distance import shards_keep_mask
    t = Trace(np.array([5, 6, 5, 7], np.int64),
              np.array([True, True, False, True]))
    # find a salt whose hash filter drops every address at this rate
    salt = next(s for s in range(1, 10_000)
                if not np.any(shards_keep_mask(t.addrs, 0.001, s)))
    for kind in ("trd", "urd"):
        for engine in ("fast", "fenwick"):
            r = sampled_reuse_distances(t, kind, rate=0.001, salt=salt,
                                        engine=engine)
            assert r.distances.shape == (4,)
            assert np.all(r.distances == -1)
            assert r.samples.size == 0
            assert r.rate == 0.001
            assert r.expected_error == 1.0
            assert max_rd(r) == -1
            assert urd_cache_blocks(r) == 0
            assert r.histogram().tolist() == [0]
    # an empty input trace is exact by definition (no sampling noise)
    empty = Trace(np.zeros(0, np.int64), np.zeros(0, bool))
    r = sampled_reuse_distances(empty, "urd", rate=0.001, salt=1)
    assert r.distances.size == 0 and r.expected_error == 0.0
    assert urd_cache_blocks(r) == 0


def test_accel_matches_exact():
    from repro.kernels.urd_scan.ops import reuse_distances_accel
    rng = np.random.default_rng(2)
    addrs = rng.integers(0, 100, size=700).astype(np.int64)
    reads = rng.random(700) < 0.6
    t = Trace(addrs, reads)
    for kind in ("trd", "urd"):
        a = reuse_distances_accel(t, kind, use_kernel=True)
        e = reuse_distances(t, kind)
        assert np.array_equal(a.distances, e.distances)
