"""The differential oracle harness — one engine-equality test core.

Every engine-equality suite used to carry its own copy of the same
scaffolding: a trace strategy, a list→Trace builder, a result-field
comparator and byte-level LRU-state assertions.  This module is the single
shared implementation; ``test_batch_sim.py``, ``test_two_level.py`` and
``test_ro_levels.py`` (and the conftest ``engine_diff`` fixture) all
consume it.

The core object is :class:`EngineDiff`: it owns one interpreter-side and
one batch-side cache pair per tenant, replays every window through
``simulator.simulate`` (the per-access oracle) *and* ``simulate_many``
(the vectorized engine), and asserts after each window that

  * every counted field agrees exactly (reads/hits/writes/cache writes —
    i.e. endurance — and flush charges, per level),
  * total latency agrees to float tolerance,
  * the final LRU states are byte-identical per level (content, order,
    dirty flags).

``examples(n)`` scales hypothesis ``max_examples`` by the
``HYP_EXAMPLES_SCALE`` env var so the nightly CI job can run the same
suites at 10x depth without touching the tests (tier-1 keeps the fast
profile).
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.core import Trace, WritePolicy, simulate, simulate_many
from repro.core.simulator import LRUCache

try:  # real hypothesis or the conftest fallback shim — either works
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover
    st = None

__all__ = [
    "RESULT_FIELDS",
    "EngineDiff",
    "assert_monitor_equal",
    "assert_results_equal",
    "assert_states_equal",
    "examples",
    "mk_trace",
    "trace_strategy",
]

# every counted SimResult field, both levels: hits, writes, endurance
RESULT_FIELDS = ("reads", "read_hits", "read_hits_l2", "writes",
                 "write_hits", "write_hits_l2", "cache_writes",
                 "cache_writes_l2")

POLICIES = (WritePolicy.WB, WritePolicy.WT, WritePolicy.RO)


def examples(n: int) -> int:
    """Scale a suite's ``max_examples`` by ``HYP_EXAMPLES_SCALE`` (the
    nightly CI profile sets it to 10; tier-1 leaves it unset)."""
    return max(1, int(n * float(os.environ.get("HYP_EXAMPLES_SCALE", "1"))))


def trace_strategy(max_n: int = 60, max_addr: int = 10):
    """The shared randomized-trace strategy: a list of (addr, is_read)."""
    return st.lists(st.tuples(st.integers(0, max_addr), st.booleans()),
                    min_size=0, max_size=max_n)


def mk_trace(trace_list) -> Trace:
    addrs = np.array([a for a, _ in trace_list], dtype=np.int64)
    reads = np.array([r for _, r in trace_list], dtype=bool)
    return Trace(addrs, reads)


def assert_results_equal(r_ref, r_got, fields=RESULT_FIELDS) -> None:
    """Exact equality on every counted field; latency to float tolerance."""
    for f in fields:
        assert getattr(r_ref, f) == getattr(r_got, f), \
            (f, getattr(r_ref, f), getattr(r_got, f))
    assert r_got.total_latency == pytest.approx(r_ref.total_latency,
                                                rel=1e-9, abs=1e-9)


def assert_monitor_equal(ref, got, exact_floats: bool = True) -> None:
    """Bit-equality of two ``MonitorResult``s (host vs device pipeline).

    The device window program's f64 mode reproduces the host monitor
    bit-for-bit — curve stores included; ``exact_floats=False`` (the TPU
    f32 tolerance documented in ``core.device_pipeline``) relaxes heights
    and write ratios to a float tolerance while keeping the integer
    outputs (edges, offsets, URD sizes) exact.
    """
    assert np.array_equal(ref.curves.edges, got.curves.edges)
    assert np.array_equal(ref.curves.offsets, got.curves.offsets)
    assert np.array_equal(ref.curves.n_accesses, got.curves.n_accesses)
    assert np.array_equal(ref.urd_sizes, got.urd_sizes)
    assert np.array_equal(ref.sample_rates, got.sample_rates)
    if exact_floats:
        assert np.array_equal(ref.curves.heights, got.curves.heights)
        assert np.array_equal(ref.write_ratios, got.write_ratios)
        assert np.array_equal(ref.expected_errors, got.expected_errors)
    else:
        np.testing.assert_allclose(ref.curves.heights, got.curves.heights,
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ref.write_ratios, got.write_ratios,
                                   rtol=1e-5, atol=1e-6)


def assert_states_equal(c_ref, c_got) -> None:
    """Byte-identical LRU state: content, order and dirty flags."""
    if c_ref is None and c_got is None:
        return
    assert list(c_ref._od.items()) == list(c_got._od.items())


class EngineDiff:
    """Replays windows through interpreter and batch engine, asserting
    equality of results and cache states after every window.

    caps1/policies (and optionally caps2/policies2) are per-tenant; pass
    ``caps2=None`` for a single-level hierarchy.  ``run_window`` accepts a
    per-window ``policies`` override (e.g. a WB warm-up window before RO
    pressure) and returns the batch-engine results so tests can assert
    extras (fallback flags, exact counter values, ...).
    """

    def __init__(self, caps1, policies, caps2=None, policies2=None, *,
                 flush: float = 0.0, t_fast: float = 1.0,
                 t_slow: float = 20.0, t_fast2: float | None = None):
        self.n = len(caps1)
        self.policies = list(policies)
        self.two_level = caps2 is not None
        self.policies2 = list(policies2 if policies2 is not None
                              else [WritePolicy.WB] * self.n)
        self.flush = flush
        self.t_fast, self.t_slow, self.t_fast2 = t_fast, t_slow, t_fast2
        self.ref1 = [LRUCache(int(c)) for c in caps1]
        self.got1 = [LRUCache(int(c)) for c in caps1]
        if self.two_level:
            self.ref2 = [LRUCache(int(c)) for c in caps2]
            self.got2 = [LRUCache(int(c)) for c in caps2]
        else:
            self.ref2 = self.got2 = None
        self.windows = 0

    def run_window(self, traces, policies=None):
        pols = list(policies) if policies is not None else self.policies
        kw2 = {}
        if self.t_fast2 is not None:
            kw2["t_fast2"] = self.t_fast2
        r_ref = [
            simulate(traces[k], self.ref1[k].capacity, pols[k],
                     self.t_fast, self.t_slow, flush_cost=self.flush,
                     cache=self.ref1[k],
                     capacity2=(self.ref2[k].capacity if self.two_level
                                else 0),
                     policy2=self.policies2[k],
                     cache2=(self.ref2[k] if self.two_level else None),
                     **kw2)
            for k in range(self.n)]
        r_got = simulate_many(
            traces, policies=pols, t_fast=self.t_fast, t_slow=self.t_slow,
            flush_cost=self.flush, caches=self.got1,
            policies2=self.policies2 if self.two_level else None,
            caches2=self.got2, **kw2)
        self.windows += 1
        for k in range(self.n):
            assert_results_equal(r_ref[k], r_got[k])
            assert_states_equal(self.ref1[k], self.got1[k])
            if self.two_level:
                assert_states_equal(self.ref2[k], self.got2[k])
        return r_got

    def run_windows(self, all_windows, policies=None):
        """Replay a warm multi-window chain; returns the last results."""
        out = None
        for traces in all_windows:
            out = self.run_window(traces, policies=policies)
        return out
