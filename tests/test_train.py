"""Trainer, optimizer, checkpointing, data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint)
from repro.configs import get_smoke_config
from repro.data.lm import PrefetchIterator, SyntheticLM
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.train.steps import init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


def _setup(microbatches=1):
    cfg = get_smoke_config("qwen3_0_6b")
    params = M.init_params(cfg, KEY)
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=5, total_steps=50)
    step = jax.jit(make_train_step(cfg, opt_cfg, microbatches=microbatches))
    return cfg, init_train_state(params), step


def test_loss_decreases():
    cfg, state, step = _setup()
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    losses = []
    for i in range(15):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_microbatch_equivalence():
    """Grad accumulation over 4 microbatches == single full batch."""
    cfg, state, step1 = _setup(microbatches=1)
    _, state4, step4 = _setup(microbatches=4)
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    b = data.batch_at(0)
    s1, m1 = step1(state, b)
    s4, m4 = step4(state4, b)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)
    p1 = jax.tree.leaves(s1["params"])
    p4 = jax.tree.leaves(s4["params"])
    for a, b_ in zip(p1, p4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=5e-2, atol=5e-3)


@pytest.mark.slow
def test_adamw_dtype_stability():
    cfg, state, step = _setup()
    data = SyntheticLM(cfg.vocab_size, 16, 4, seed=1)
    dtypes0 = jax.tree.map(lambda x: x.dtype, state["params"])
    state, _ = step(state, data.batch_at(0))
    dtypes1 = jax.tree.map(lambda x: x.dtype, state["params"])
    assert jax.tree.all(jax.tree.map(lambda a, b: a == b, dtypes0, dtypes1))


def test_gradient_clipping():
    p = {"w": jnp.ones((4, 4))}
    opt = init_opt_state(p)
    g = {"w": jnp.full((4, 4), 1e6)}
    cfg = AdamWConfig(clip_norm=1.0)
    _, opt2, gnorm = adamw_update(g, opt, cfg, params=p)
    assert float(gnorm) > 1e6 - 1
    assert float(jnp.abs(opt2["mu"]["w"]).max()) < 1.0  # clipped


@pytest.mark.slow
def test_checkpoint_roundtrip_and_restart_determinism():
    cfg, state, step = _setup()
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=2)
    with tempfile.TemporaryDirectory() as d:
        for i in range(5):
            state, _ = step(state, data.batch_at(i))
        save_checkpoint(d, state, 5)
        ref_state = state
        for i in range(5, 8):
            ref_state, ref_m = step(ref_state, data.batch_at(i))
        # restart from the checkpoint: identical continuation
        restored, s = restore_checkpoint(d, state)
        assert s == 5
        for i in range(5, 8):
            restored, m = step(restored, data.batch_at(i))
        assert float(m["loss"]) == pytest.approx(float(ref_m["loss"]),
                                                 rel=1e-5)


@pytest.mark.slow
def test_trainer_failure_injection_and_recovery():
    cfg, state, step = _setup()
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=3)
    fails = {"n": 0}

    def hook(step_i):
        if step_i == 7 and fails["n"] == 0:
            fails["n"] += 1
            raise RuntimeError("injected")

    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(total_steps=12, ckpt_every=3, ckpt_dir=d)
        tr = Trainer(step, state, data, tc, failure_hook=hook)
        out = tr.run()
    assert out["restarts"] == 1
    assert out["final_step"] == 12


@pytest.mark.slow
def test_trainer_straggler_detection():
    import time
    cfg, state, step = _setup()
    data = SyntheticLM(cfg.vocab_size, 16, 4, seed=4)
    slow = {"hits": 0}

    def slow_hook(step_i):
        if step_i == 9:
            time.sleep(4.0)         # >> straggler_factor × median step time

    def mitigation(step_i, factor):
        slow["hits"] += 1

    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(total_steps=11, ckpt_every=100, ckpt_dir=d,
                           straggler_factor=2.0)
        tr = Trainer(step, state, data, tc, failure_hook=slow_hook,
                     straggler_hook=mitigation)
        tr.run()
    assert slow["hits"] >= 1


def test_data_pipeline_deterministic_and_prefetch():
    src = SyntheticLM(1000, 16, 4, seed=5)
    a, b = src.batch_at(3), src.batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    it = PrefetchIterator(src, start_step=0, prefetch=2)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], src.batch_at(0)["tokens"])
    it.close()


def test_compression_error_feedback():
    from repro.optim.compression import (dequantize_int8, init_error_state,
                                         quantize_int8)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    q, s = quantize_int8(g)
    deq = dequantize_int8(q, s)
    rel = float(jnp.abs(deq - g).max() / jnp.abs(g).max())
    assert rel < 0.02                           # <= one int8 step
    # error feedback: accumulated residual corrects the quantization bias
    err = jnp.zeros_like(g)
    total_true, total_sent = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(50):
        g32 = g + err
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        err = g32 - deq
        total_true += g
        total_sent += deq
    drift = float(jnp.abs(total_sent - total_true).max())
    assert drift < 0.05                          # residual stays bounded
