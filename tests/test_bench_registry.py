"""Audit the benchmark registry against the CI wiring.

Three contracts, checked statically so the audit costs milliseconds:

1. every ``benchmarks/bench_*.py`` module is registered in ``run.py``'s
   ``BENCHES`` table (and nothing registered is missing on disk) — a
   bench that isn't registered never runs under ``python -m
   benchmarks.run`` and its reproduction checks silently vanish;
2. every module that emits a ``BENCH_*.json`` artifact has a ``--smoke``
   invocation in ``.github/workflows/ci.yml``, so its gates run on every
   push, not just nightly;
3. every gate is *enforced*, not just reported: each emitter's
   ``__main__`` block raises ``SystemExit`` when any value in
   ``result["checks"]`` is falsy, and ``run.py`` aggregates the same
   ``checks`` dicts into its PASS/FAIL summary.
"""
import ast
import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parents[1]
BENCH_DIR = REPO / "benchmarks"
CI = (REPO / ".github" / "workflows" / "ci.yml").read_text()


def bench_modules():
    return sorted(p.stem for p in BENCH_DIR.glob("bench_*.py"))


def registered_benches():
    """Parse run.py's BENCHES literal: [(name, module), ...]."""
    tree = ast.parse((BENCH_DIR / "run.py").read_text())
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign)
                and any(getattr(t, "id", None) == "BENCHES"
                        for t in node.targets)):
            return sorted(
                (elt.elts[0].value, elt.elts[1].id)
                for elt in node.value.elts)
    raise AssertionError("BENCHES table not found in benchmarks/run.py")


def json_emitters():
    """Modules that write a BENCH_*.json artifact."""
    out = {}
    for p in sorted(BENCH_DIR.glob("bench_*.py")):
        m = re.search(r"open\(\"(BENCH_\w+\.json)\", \"w\"\)",
                      p.read_text())
        if m:
            out[p.stem] = m.group(1)
    return out


def test_every_bench_module_is_registered():
    regs = registered_benches()
    assert sorted(mod for _, mod in regs) == bench_modules()
    # display name matches the module name minus the bench_ prefix
    for name, mod in regs:
        assert mod == f"bench_{name}"


def test_json_emitters_cover_the_gated_suites():
    # the four artifact-emitting suites; growing this set is fine,
    # shrinking it means a gate was dropped
    assert set(json_emitters()) >= {
        "bench_etica_two_level", "bench_faults",
        "bench_monitor_scale", "bench_scenarios"}


def test_every_emitter_has_a_ci_smoke_invocation():
    for mod in json_emitters():
        pat = rf"python -m benchmarks\.{mod} --smoke"
        assert re.search(pat, CI), f"{mod}: no --smoke step in ci.yml"


def test_every_emitter_enforces_its_checks():
    """Gates fail the process, they don't just print: the __main__ block
    must raise SystemExit when any check value is falsy."""
    for mod in json_emitters():
        src = (BENCH_DIR / f"{mod}.py").read_text()
        assert re.search(
            r"if not all\(result\[\"checks\"\]\.values\(\)\):\s*\n"
            r"\s*raise SystemExit", src), mod


def test_run_py_aggregates_checks_into_summary():
    src = (BENCH_DIR / "run.py").read_text()
    assert '.get("checks", {})' in src
    assert "reproduction checks:" in src


def test_ci_runs_the_linter_and_the_tests():
    assert "python -m tools.repro_lint src tests benchmarks" in CI
    assert "python -m pytest -x -q" in CI
