"""Paper Appendix C worst-case traces: ECI-Cache's documented failure modes.

These tests PIN the documented behaviour (under/over-estimation in adverse
interval patterns) rather than asserting the scheme wins — the appendix's
point is that Centaur degenerates identically (case 1/2) and that interval
length is the mitigation (case 3)."""
import numpy as np

from repro.core import (ECICacheManager, Trace, reuse_distances,
                        urd_cache_blocks)
from repro.data.traces import (random_then_sequential, semi_sequential,
                               sequential_then_random)


def test_case1_sequential_then_random_underestimates_first_window():
    t = sequential_then_random(200, 200, seed=0)
    first = t.slice(0, 200)
    # pure streaming window: URD finds no reuse -> no cache requested
    assert urd_cache_blocks(reuse_distances(first, "urd")) == 0
    # second window discovers the reuse
    second = t.slice(0, 400)
    assert urd_cache_blocks(reuse_distances(second, "urd")) > 0


def test_case1_centaur_behaves_identically():
    t = sequential_then_random(200, 200, seed=0).slice(0, 200)
    assert urd_cache_blocks(reuse_distances(t, "trd")) == 0


def test_case2_random_then_sequential_overestimates():
    t = random_then_sequential(100, 300, ws=16, seed=1)
    mid = t.slice(0, 400)   # random interval + sequential writes
    urd_mid = urd_cache_blocks(reuse_distances(mid, "urd"))
    # the random prefix still dominates the estimate even though the
    # sequential writes will use up the cache
    assert urd_mid >= 1
    # sequential writes produce no URD samples themselves
    seq_only = t.slice(100, 400)
    assert urd_cache_blocks(reuse_distances(seq_only, "urd")) == 0


def test_case3_semi_sequential_large_urd_no_locality():
    t = semi_sequential(stride=64, repeats=3, seed=2)
    rd = reuse_distances(t, "urd")
    # repeats create distance == stride-1 reuses: large URD, poor locality
    assert urd_cache_blocks(rd) == 64
    # shrinking the analysis interval below the stride hides the repeats —
    # the paper's mitigation ("changing the length of the intervals")
    short = t.slice(0, 48)
    assert urd_cache_blocks(reuse_distances(short, "urd")) == 0


def test_manager_survives_corner_traces():
    mgr = ECICacheManager(500, ["a", "b", "c"], c_min=4, initial_blocks=8)
    mgr.run_window([
        sequential_then_random(100, 100),
        random_then_sequential(50, 150),
        semi_sequential(32, 4),
    ])
    d = mgr.history[-1]
    assert int(d.sizes.sum()) <= 500
    assert (d.sizes >= 0).all()
