"""Serving engine + tiered cache + block pool behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import BlockPool, TieredKVCache
from repro.configs import get_smoke_config
from repro.core import ECICacheManager, WritePolicy
from repro.models import model as M
from repro.models.attention import build_heads
from repro.serve.engine import MultiTenantEngine, Request

KEY = jax.random.PRNGKey(0)


def _engine(n_pages=256, window_events=10**9, capacity=128, page=8,
            tenants=("t0", "t1")):
    cfg = get_smoke_config("qwen3_0_6b")
    params = M.init_params(cfg, KEY, tp=1)
    hq, hkv = build_heads(cfg, 1)
    pool = BlockPool(n_pages, page, cfg.n_layers, hkv, cfg.head_dim,
                     dtype=jnp.float32)
    mgr = ECICacheManager(capacity, list(tenants), c_min=8,
                          initial_blocks=32)
    tiered = TieredKVCache(pool, mgr, window_events=window_events)
    return MultiTenantEngine(cfg, params, tiered, page_size=page,
                             max_pages_per_seq=16), pool, tiered, cfg, params


def test_prefix_reuse_across_requests():
    eng, pool, tiered, cfg, _ = _engine()
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    for _ in range(3):
        eng.submit(Request(tenant=0, prompt=prefix.copy(), max_new_tokens=2))
    eng.run(16)
    assert pool.stats["reused"] >= 4            # 2 shared pages × 2 reuses
    assert tiered.stats[0].hbm_hits >= 4


def test_paged_decode_matches_dense_decode():
    eng, pool, tiered, cfg, params = _engine()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    eng.submit(Request(tenant=0, prompt=prompt, max_new_tokens=4))
    eng.run(16)
    paged_tokens = eng.completed[0].generated

    cache = M.init_decode_cache(cfg, 1, 64)
    out = None
    for t in range(len(prompt)):
        out, cache = M.decode_step(params, cfg,
                                   jnp.asarray(prompt[t:t + 1]), cache)
    dense_tokens = [int(jnp.argmax(out[0, :cfg.vocab_size]))]
    for _ in range(3):
        out, cache = M.decode_step(
            params, cfg, jnp.asarray([dense_tokens[-1]], jnp.int32), cache)
        dense_tokens.append(int(jnp.argmax(out[0, :cfg.vocab_size])))
    assert paged_tokens == dense_tokens


def test_ro_policy_bypasses_admissions():
    eng, pool, tiered, cfg, _ = _engine()
    tiered.policies[1] = WritePolicy.RO
    rng = np.random.default_rng(2)
    eng.submit(Request(tenant=1,
                       prompt=rng.integers(0, cfg.vocab_size, 24
                                           ).astype(np.int32),
                       max_new_tokens=2))
    eng.run(8)
    assert tiered.stats[1].bypassed_writes > 0
    assert tiered.stats[1].hbm_writes == 0      # nothing admitted on write


def test_quota_enforcement_and_pinning():
    pool = BlockPool(64, 8, 2, 2, 16, allocate_device=False)
    for i in range(10):
        pid, _ = pool.allocate(0, key=("t0", i), quota=None)
        assert pid is not None
    pool.pin(next(iter(pool.lru[0])))           # pin the LRU page
    evicted = pool.enforce_quota(0, 4)
    assert pool.resident(0) == 4                # quota met
    assert len(evicted) == 6
    # the pinned page survived even though it was LRU-first
    assert any(pool.meta[p].pinned for p in pool.lru[0])


def test_pool_eviction_frees_keys():
    pool = BlockPool(4, 8, 1, 2, 16, allocate_device=False)
    pids = [pool.allocate(0, key=("k", i))[0] for i in range(4)]
    assert pool.lookup(("k", 0)) == pids[0]
    pool.allocate(0, key=("k", 9))              # full → evicts LRU ("k",1?)
    assert len(pool.free) == 0
    assert pool.stats["evicted"] == 1


def test_release_tenant():
    pool = BlockPool(16, 8, 1, 2, 16, allocate_device=False)
    for i in range(5):
        pool.allocate(3, key=("x", i))
    assert pool.resident(3) == 5
    n = pool.release_tenant(3)
    assert n == 5 and pool.resident(3) == 0
    assert len(pool.free) == 16


def test_rebalance_applies_quotas():
    eng, pool, tiered, cfg, _ = _engine(window_events=4, capacity=16)
    rng = np.random.default_rng(3)
    for t in range(2):
        eng.submit(Request(tenant=t,
                           prompt=rng.integers(0, cfg.vocab_size, 32
                                               ).astype(np.int32),
                           max_new_tokens=3))
    eng.run(16)
    s = tiered.summary()
    for t, q in s["quotas"].items():
        if q is not None:
            assert pool.resident(t) <= max(q, pool.resident(t))  # no crash
    assert len(tiered.manager.history) >= 1     # analyzer ran
