"""Serving engine + tiered cache + block pool behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import BlockPool, TieredKVCache
from repro.configs import get_smoke_config
from repro.core import ECICacheManager, WritePolicy
from repro.models import model as M
from repro.models.attention import build_heads
from repro.serve.engine import MultiTenantEngine, Request

KEY = jax.random.PRNGKey(0)


def _engine(n_pages=256, window_events=10**9, capacity=128, page=8,
            tenants=("t0", "t1")):
    cfg = get_smoke_config("qwen3_0_6b")
    params = M.init_params(cfg, KEY, tp=1)
    hq, hkv = build_heads(cfg, 1)
    pool = BlockPool(n_pages, page, cfg.n_layers, hkv, cfg.head_dim,
                     dtype=jnp.float32)
    mgr = ECICacheManager(capacity, list(tenants), c_min=8,
                          initial_blocks=32)
    tiered = TieredKVCache(pool, mgr, window_events=window_events)
    return MultiTenantEngine(cfg, params, tiered, page_size=page,
                             max_pages_per_seq=16), pool, tiered, cfg, params


def test_prefix_reuse_across_requests():
    eng, pool, tiered, cfg, _ = _engine()
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    for _ in range(3):
        eng.submit(Request(tenant=0, prompt=prefix.copy(), max_new_tokens=2))
    eng.run(16)
    assert pool.stats["reused"] >= 4            # 2 shared pages × 2 reuses
    assert tiered.stats[0].hbm_hits >= 4


def test_paged_decode_matches_dense_decode():
    eng, pool, tiered, cfg, params = _engine()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    eng.submit(Request(tenant=0, prompt=prompt, max_new_tokens=4))
    eng.run(16)
    paged_tokens = eng.completed[0].generated

    cache = M.init_decode_cache(cfg, 1, 64)
    out = None
    for t in range(len(prompt)):
        out, cache = M.decode_step(params, cfg,
                                   jnp.asarray(prompt[t:t + 1]), cache)
    dense_tokens = [int(jnp.argmax(out[0, :cfg.vocab_size]))]
    for _ in range(3):
        out, cache = M.decode_step(
            params, cfg, jnp.asarray([dense_tokens[-1]], jnp.int32), cache)
        dense_tokens.append(int(jnp.argmax(out[0, :cfg.vocab_size])))
    assert paged_tokens == dense_tokens


def test_ro_policy_bypasses_admissions():
    eng, pool, tiered, cfg, _ = _engine()
    tiered.policies[1] = WritePolicy.RO
    rng = np.random.default_rng(2)
    eng.submit(Request(tenant=1,
                       prompt=rng.integers(0, cfg.vocab_size, 24
                                           ).astype(np.int32),
                       max_new_tokens=2))
    eng.run(8)
    assert tiered.stats[1].bypassed_writes > 0
    assert tiered.stats[1].hbm_writes == 0      # nothing admitted on write


def test_quota_enforcement_and_pinning():
    pool = BlockPool(64, 8, 2, 2, 16, allocate_device=False)
    for i in range(10):
        pid, _ = pool.allocate(0, key=("t0", i), quota=None)
        assert pid is not None
    pool.pin(next(iter(pool.lru[0])))           # pin the LRU page
    evicted = pool.enforce_quota(0, 4)
    assert pool.resident(0) == 4                # quota met
    assert len(evicted) == 6
    # the pinned page survived even though it was LRU-first
    assert any(pool.meta[p].pinned for p in pool.lru[0])


def test_pool_eviction_frees_keys():
    pool = BlockPool(4, 8, 1, 2, 16, allocate_device=False)
    pids = [pool.allocate(0, key=("k", i))[0] for i in range(4)]
    assert pool.lookup(("k", 0)) == pids[0]
    pool.allocate(0, key=("k", 9))              # full → evicts LRU ("k",1?)
    assert len(pool.free) == 0
    assert pool.stats["evicted"] == 1


def test_release_tenant():
    pool = BlockPool(16, 8, 1, 2, 16, allocate_device=False)
    for i in range(5):
        pool.allocate(3, key=("x", i))
    assert pool.resident(3) == 5
    n = pool.release_tenant(3)
    assert n == 5 and pool.resident(3) == 0
    assert len(pool.free) == 16


def _tiered(n_pages=32, capacity=16, capacity2=0, window_events=10**9,
            tenants=("t0", "t1")):
    pool = BlockPool(n_pages, 8, 2, 2, 16, allocate_device=False)
    mgr = ECICacheManager(capacity, list(tenants), c_min=2,
                          initial_blocks=8, capacity2=capacity2)
    return pool, mgr, TieredKVCache(pool, mgr, window_events=window_events)


def test_managed_host_demote_then_promote():
    """HBM victims land in the managed host tier; a later read is a host
    hit that promotes the page back into the pool."""
    pool, mgr, tiered = _tiered(n_pages=4, capacity2=64)
    for i in range(4):
        assert tiered.access_page(0, ("k", i), fresh=True) == "hbm"
    # pool full: admitting one more evicts the LRU page ("k", 0) -> demote
    tiered.access_page(0, ("k", 4), fresh=True)
    assert tiered.stats[0].demotions == 1
    assert ("k", 0) in tiered.host_lru[0]
    served = tiered.access_page(0, ("k", 0), fresh=False)
    assert served == "host"
    assert tiered.stats[0].host_hits == 1
    assert tiered.stats[0].promotions == 1
    assert ("k", 0) not in tiered.host_lru[0]       # exclusive levels
    assert pool.lookup(("k", 0)) is not None
    # unmanaged mode keeps the legacy "host retains everything" behaviour
    pool2, _, t2 = _tiered(n_pages=4, capacity2=0)
    for i in range(5):
        t2.access_page(0, ("k", i), fresh=True)
    assert t2.stats[0].demotions == 0
    assert t2.access_page(0, ("k", 0), fresh=False) == "host"


def test_managed_host_eviction_is_a_real_miss():
    """Pages falling off the managed host tier must be recomputed."""
    pool, mgr, tiered = _tiered(n_pages=4, capacity2=8)
    tiered.host_quotas[0] = 2
    for i in range(4):
        tiered.access_page(0, ("k", i), fresh=True)
    for i in range(4, 8):                 # 4 more admissions -> 4 demotions
        tiered.access_page(0, ("k", i), fresh=True)
    assert tiered.stats[0].demotions == 4
    assert tiered.stats[0].host_evictions == 2      # quota 2: oldest fell off
    assert len(tiered.host_lru[0]) == 2
    assert tiered.access_page(0, ("k", 0), fresh=False) == "miss"
    assert tiered.stats[0].misses == 1


def test_finish_tenant_redistributes_quota():
    """Retired tenants are excluded from partitioning and their freed
    space is redistributed at the next rebalance()."""
    pool, mgr, tiered = _tiered(n_pages=64, capacity=20, capacity2=30)
    # both tenants demand more than half the pool: infeasible regime
    for t in range(2):
        for i in range(40):
            tiered.access_page(t, (t, i), fresh=True)
        for i in range(40):
            tiered.access_page(t, (t, i), fresh=False)
    tiered.rebalance()
    before = dict(tiered.quotas)
    assert sum(v for v in before.values() if v) <= 20
    share_before = before[1]

    demo_before = tiered.stats[0].demotions
    hev_before = tiered.stats[0].host_evictions
    tiered.finish_tenant(0)
    assert pool.resident(0) == 0
    assert not mgr.tenants[0].active
    assert len(tiered.host_lru[0]) == 0
    # retiring pages are releases, not demotions: stats stay clean
    assert tiered.stats[0].demotions == demo_before
    assert tiered.stats[0].host_evictions == hev_before
    for i in range(40):
        tiered.access_page(1, (1, i), fresh=False)
    tiered.rebalance()
    d = mgr.history[-1]
    assert d.sizes[0] == 0                          # excluded from Alg. 1
    assert d.sizes2 is None or d.sizes2[0] == 0
    assert tiered.quotas[1] >= share_before         # freed space flows over
    assert tiered.quotas[0] == 0
    # retired tenant stays excluded and untouched on further rebalances
    for i in range(10):
        tiered.access_page(1, (1, 100 + i), fresh=True)
    tiered.rebalance()
    assert mgr.tenants[0].cache.capacity == 0
    assert mgr.tenants[0].cache2.capacity == 0


def test_monitor_batching_grows_and_flushes():
    pool, mgr, tiered = _tiered(window_events=10**9)
    # shrink the preallocated buffers so one doubling is exercised cheaply
    tiered._ev_tenant = np.empty(16, np.int32)
    tiered._ev_addr = np.empty(16, np.int64)
    tiered._ev_read = np.empty(16, bool)
    for i in range(20):
        tiered.access_page(i % 2, ("g", i), fresh=True)
    assert tiered._ev_addr.size == 32               # doubled once
    assert tiered._n_ev == 20
    tiered.rebalance()
    assert tiered._n_ev == 0
    assert tiered.rebalance_seconds > 0.0
    assert len(mgr.history) == 1                    # analyzer consumed them


def test_rebalance_applies_quotas():
    eng, pool, tiered, cfg, _ = _engine(window_events=4, capacity=16)
    rng = np.random.default_rng(3)
    for t in range(2):
        eng.submit(Request(tenant=t,
                           prompt=rng.integers(0, cfg.vocab_size, 32
                                               ).astype(np.int32),
                           max_new_tokens=3))
    eng.run(16)
    s = tiered.summary()
    for t, q in s["quotas"].items():
        if q is not None:
            assert pool.resident(t) <= max(q, pool.resident(t))  # no crash
    assert len(tiered.manager.history) >= 1     # analyzer ran
