"""Per-arch smoke tests (reduced configs) + cross-path consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, skip_shapes
from repro.models import model as M
from repro.models.config import Family, SHAPES

# per-arch smoke forwards/train/decode are minutes-scale on CPU: tier-1
# deselects them (`pytest -m slow` opts in)
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def _batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == Family.ENCDEC:
        batch["enc_embeds"] = jax.random.normal(
            KEY, (B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_loss_finite(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, KEY, tp=1)
    loss = M.loss_fn(params, cfg, _batch(cfg))
    assert jnp.isfinite(loss), arch
    assert 0.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_no_nans(arch):
    from repro.optim.adamw import AdamWConfig
    from repro.train.steps import init_train_state, make_train_step
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, KEY, tp=1)
    state = init_train_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr_peak=1e-3)))
    state, metrics = step(state, _batch(cfg))
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    leaves = jax.tree.leaves(jax.tree.map(
        lambda x: jnp.all(jnp.isfinite(x.astype(jnp.float32))),
        state["params"]))
    assert all(map(bool, leaves)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_steps(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, KEY, tp=1)
    enc_len = S if cfg.family == Family.ENCDEC else 0
    cache = M.init_decode_cache(cfg, B, 32, enc_len=enc_len)
    if cfg.family == Family.ENCDEC:
        from repro.models.attention import cross_attention_kv
        enc = jax.random.normal(KEY, (B, S, cfg.d_model),
                                jnp.dtype(cfg.dtype))
        ck, cv = jax.vmap(
            lambda p: cross_attention_kv(p["cross"], enc, cfg, 1)
        )(params["layers"])
        cache["cross_k"], cache["cross_v"] = ck, cv
    tok = jnp.zeros((B,), jnp.int32)
    for _ in range(4):
        logits, cache = M.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert jnp.all(jnp.isfinite(logits[:, :cfg.vocab_size]))
    assert int(cache["len"][0]) == 4


def test_prefill_decode_consistency_dense():
    """Greedy decode over a prefix must reproduce teacher-forced logits."""
    cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"),
                              dtype="float32")
    params = M.init_params(cfg, KEY, tp=1)
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size)
    logits_pre = M.prefill(params, cfg, toks)
    cache = M.init_decode_cache(cfg, 1, 16)
    out = None
    for t in range(12):
        out, cache = M.decode_step(params, cfg, toks[:, t], cache)
    np.testing.assert_allclose(np.asarray(out), np.asarray(logits_pre),
                               rtol=2e-4, atol=2e-4)


def test_mamba2_chunked_matches_sequential():
    from repro.models.mamba2 import (init_mamba2_layer, init_ssm_state,
                                     mamba2_decode_step, mamba2_forward)
    cfg = get_smoke_config("mamba2_780m")
    p = init_mamba2_layer(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model),
                          jnp.float32) * 0.1
    y_chunk = mamba2_forward(p, x, cfg)
    st = init_ssm_state(cfg, 2)
    ys = []
    for t in range(64):
        y_t, st = mamba2_decode_step(p, x[:, t:t + 1], st, cfg)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=2e-4)


def test_swa_masks_long_range():
    """h2o-danube SWA: token attends only inside its window."""
    cfg = dataclasses.replace(get_smoke_config("h2o_danube_3_4b"),
                              dtype="float32", window=8, n_layers=1)
    params = M.init_params(cfg, KEY, tp=1)
    toks = jax.random.randint(KEY, (1, 40), 0, cfg.vocab_size)
    h1 = M.forward_hidden(params, cfg, toks)
    # perturbing a token far outside the window must not change position -1
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    h2 = M.forward_hidden(params, cfg, toks2)
    np.testing.assert_allclose(np.asarray(h1[0, -1]), np.asarray(h2[0, -1]),
                               atol=1e-5)
    # ... but a token inside the window does
    toks3 = toks.at[0, 38].set((toks[0, 38] + 1) % cfg.vocab_size)
    h3 = M.forward_hidden(params, cfg, toks3)
    assert not np.allclose(np.asarray(h1[0, -1]), np.asarray(h3[0, -1]),
                           atol=1e-5)


def test_causality_dense():
    cfg = dataclasses.replace(get_smoke_config("chameleon_34b"),
                              dtype="float32", n_layers=1)
    params = M.init_params(cfg, KEY, tp=1)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    h1 = M.forward_hidden(params, cfg, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab_size)
    h2 = M.forward_hidden(params, cfg, toks2)
    # past positions unchanged when a future token changes
    np.testing.assert_allclose(np.asarray(h1[0, :-1]),
                               np.asarray(h2[0, :-1]), atol=1e-5)


def test_moe_router_masks_padded_experts():
    from repro.models.layers import moe_ffn
    cfg = get_smoke_config("qwen2_moe_a2_7b")
    params = M.init_params(cfg, KEY, tp=1)
    # pad experts to 16 (ep=16): router must never route beyond n_experts
    cfg16 = cfg
    p0 = jax.tree.map(lambda x: x[0], params["layers"])["mlp"]
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.dtype(cfg.dtype))
    y = moe_ffn(x, p0, cfg16, ep=1)
    assert jnp.all(jnp.isfinite(y.astype(jnp.float32)))


def test_full_configs_param_counts():
    """Full configs' analytic param counts are in the advertised ballpark."""
    expect = {
        "qwen2_moe_a2_7b": (10e9, 20e9),      # 14.3B total (A2.7B active)
        "deepseek_moe_16b": (14e9, 20e9),
        "chameleon_34b": (30e9, 38e9),
        "command_r_plus_104b": (95e9, 115e9),
        "minicpm3_4b": (3e9, 5.5e9),
        "qwen3_0_6b": (0.4e9, 0.9e9),
        "h2o_danube_3_4b": (3e9, 5e9),
        "mamba2_780m": (0.6e9, 1.0e9),
        "zamba2_7b": (6e9, 9e9),
        "seamless_m4t_large_v2": (1.5e9, 3.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo < n < hi, (arch, n)


def test_shape_skips_documented():
    full_attn = {"qwen2_moe_a2_7b", "deepseek_moe_16b", "chameleon_34b",
                 "command_r_plus_104b", "minicpm3_4b", "qwen3_0_6b",
                 "seamless_m4t_large_v2"}
    for arch in ARCH_IDS:
        skips = skip_shapes(arch)
        if arch in full_attn:
            assert "long_500k" in skips, arch
        else:
            assert "long_500k" not in skips, arch
    # 40 cells minus 7 documented long-context skips
    from repro.configs import all_cells
    assert len(all_cells()) == 40 - len(full_attn)


def test_mamba2_kernel_path_matches_inline():
    """The Pallas mamba2_ssd production path == the inline jnp scan."""
    from repro.models.mamba2 import init_mamba2_layer, mamba2_forward
    cfg = get_smoke_config("mamba2_780m")
    p = init_mamba2_layer(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model),
                          jnp.float32) * 0.1
    y_jnp = mamba2_forward(p, x, cfg, use_kernel=False)
    y_ker = mamba2_forward(p, x, cfg, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_ker),
                               atol=2e-4)
