"""Fused monitor + vectorized partitioner: exactness, SHARDS accuracy,
telemetry.

The thousand-tenant control plane must be a pure optimization: on the
exact path every curve / URD size / write ratio / allocation is
bit-identical to the per-tenant seed code (still in-tree as the oracles:
``reuse_distances_fast`` + ``build_hit_ratio_function`` + ``write_ratio``
per tenant, and ``greedy_allocate(method="heap")``).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from oracle import assert_monitor_equal, examples

from repro.core import (DeviceWindowPipeline, ECICacheManager,
                        HitRatioFunction, StageProfile, Trace, WritePolicy,
                        aggregate_latency, analyze_windows,
                        build_hit_ratio_function, greedy_allocate,
                        reuse_distances, reuse_distances_fast,
                        sampled_reuse_distances, shards_salt, simulate_many,
                        two_level_solve, urd_cache_blocks)
from repro.core.device_pipeline import monitor_window_device
from repro.core.mrc import BatchedHitRatioFunctions
from repro.core.reuse_distance import auto_sample_rate, shards_keep_mask
from repro.core.simulator import LRUCache
from repro.core.write_policy import write_ratio
from repro.kernels.cache_sim.ops import _on_tpu


def _rand_traces(seed, n_tenants=6, max_n=300, max_addr=40):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_tenants):
        n = int(rng.integers(0, max_n))
        a = rng.integers(0, max_addr, n).astype(np.int64)
        r = rng.random(n) < rng.uniform(0.1, 0.9)
        out.append(Trace(a, r, f"t{i}"))
    # degenerate shapes the fused reductions must survive
    out.append(Trace(np.zeros(0, np.int64), np.zeros(0, bool), "empty"))
    out.append(Trace(np.arange(40, dtype=np.int64) % 4,
                     np.zeros(40, bool), "all-writes"))
    out.append(Trace(np.arange(30, dtype=np.int64), np.ones(30, bool),
                     "streaming"))
    return out


# ---------------------------------------------------------- fused == seed
@pytest.mark.parametrize("kind", ["urd", "trd"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fused_monitor_matches_per_tenant(kind, seed):
    traces = _rand_traces(seed)
    mon = analyze_windows(traces, kind)
    for k, tr in enumerate(traces):
        rd = reuse_distances_fast(tr, kind)
        h = build_hit_ratio_function(rd)
        assert np.array_equal(h.edges, mon.curves[k].edges)
        assert np.array_equal(h.heights, mon.curves[k].heights)
        assert h.n_accesses == mon.curves[k].n_accesses
        assert urd_cache_blocks(rd) == mon.urd_sizes[k]
        assert write_ratio(tr) == mon.write_ratios[k]
        assert mon.sample_rates[k] == 1.0
        assert mon.expected_errors[k] == 0.0


def test_fused_monitor_precomputed_raw_path():
    """Raw TRD arrays from the batch engine short-circuit the counting
    pass without changing any output (mixed present/missing entries)."""
    traces = _rand_traces(5)
    pre = [reuse_distances(t, "trd").distances if (i % 2 == 0 and len(t))
           else None for i, t in enumerate(traces)]
    a = analyze_windows(traces, "urd")
    b = analyze_windows(traces, "urd", precomputed_trd=pre)
    for k in range(len(traces)):
        assert np.array_equal(a.curves[k].edges, b.curves[k].edges)
        assert np.array_equal(a.curves[k].heights, b.curves[k].heights)
    assert np.array_equal(a.urd_sizes, b.urd_sizes)
    assert np.array_equal(a.write_ratios, b.write_ratios)


def test_fused_monitor_short_precomputed_list():
    """A precomputed_trd list shorter than traces must not silently zero
    out the uncovered tenants — missing entries are counted."""
    traces = _rand_traces(13)
    pre = [reuse_distances(traces[0], "trd").distances
           if len(traces[0]) else None]
    a = analyze_windows(traces, "urd")
    b = analyze_windows(traces, "urd", precomputed_trd=pre)
    assert np.array_equal(a.urd_sizes, b.urd_sizes)
    for k in range(len(traces)):
        assert np.array_equal(a.curves[k].heights, b.curves[k].heights)


def test_validate_flag_off_by_default_and_bit_identical():
    """``validate=True`` only adds the ingest pre-check: every Monitor
    output is bit-identical to the default (off) path."""
    traces = _rand_traces(3)
    a = analyze_windows(traces, "urd")
    b = analyze_windows(traces, "urd", validate=True)
    assert np.array_equal(a.urd_sizes, b.urd_sizes)
    assert np.array_equal(a.write_ratios, b.write_ratios)
    for k in range(len(traces)):
        assert np.array_equal(a.curves[k].edges, b.curves[k].edges)
        assert np.array_equal(a.curves[k].heights, b.curves[k].heights)


def test_shards_keep_mask_rate_near_one():
    """rate within 2**-32 of 1.0 must keep everything, not overflow."""
    a = np.arange(500, dtype=np.int64)
    assert shards_keep_mask(a, 1.0 - 1e-13, 7).all()
    s = sampled_reuse_distances(Trace(a % 9, np.ones(500, bool)),
                                "trd", rate=1.0 - 1e-13)
    e = reuse_distances_fast(Trace(a % 9, np.ones(500, bool)), "trd")
    assert np.array_equal(s.distances, e.distances)


def test_fused_monitor_percentile():
    traces = _rand_traces(9)
    mon = analyze_windows(traces, "urd", percentile=90.0)
    for k, tr in enumerate(traces):
        rd = reuse_distances_fast(tr, "urd")
        assert urd_cache_blocks(rd, 90.0) == mon.urd_sizes[k]


# ------------------------------------------------------- batched curves
def test_batched_curves_evaluate_and_shift():
    rng = np.random.default_rng(3)
    hs = []
    for _ in range(8):
        k = int(rng.integers(1, 7))
        sizes = np.cumsum(rng.integers(1, 30, k))
        heights = np.minimum(np.cumsum(rng.random(k) * 0.3), 1.0)
        hs.append(HitRatioFunction(
            np.concatenate([[0], sizes]).astype(np.int64),
            np.concatenate([[0.0], heights]), 500))
    b = BatchedHitRatioFunctions.from_curves(hs)
    queries = rng.integers(-2, 80, len(hs))
    ev = b.evaluate(queries)
    for i, h in enumerate(hs):
        assert ev[i] == h(int(queries[i]))
    bases = rng.integers(0, 60, len(hs))
    sh = b.shifted(bases)
    for i, h in enumerate(hs):
        ref = h.shifted(int(bases[i]))
        assert np.array_equal(ref.edges, sh[i].edges)
        assert np.array_equal(ref.heights, sh[i].heights)
    # sequence protocol keeps legacy partition_fns working
    assert len(list(b)) == len(hs)
    assert aggregate_latency(b, queries, 1.0, 20.0) == pytest.approx(
        aggregate_latency(hs, queries, 1.0, 20.0))


# ------------------------------------------- vectorized greedy == heap
def _curve_strategy():
    return st.lists(
        st.lists(st.tuples(st.integers(1, 20), st.floats(0.01, 0.3)),
                 min_size=1, max_size=5),
        min_size=1, max_size=6)


@settings(max_examples=150, deadline=None)
@given(_curve_strategy(), st.integers(0, 120), st.integers(0, 12),
       st.booleans())
def test_greedy_fast_bit_identical_to_heap(steps_per_tenant, capacity,
                                           c_min, weighted):
    hs = []
    for steps in steps_per_tenant:
        sizes = np.cumsum([s for s, _ in steps])
        heights = np.minimum(np.cumsum([h for _, h in steps]), 1.0)
        hs.append(HitRatioFunction(
            np.concatenate([[0], sizes]).astype(np.int64),
            np.concatenate([[0.0], heights]), 1000))
    w = (np.linspace(0.5, 2.0, len(hs)) if weighted else None)
    heap = greedy_allocate(hs, capacity, 1.0, 20.0, c_min=c_min,
                           weights=w, method="heap")
    fast = greedy_allocate(hs, capacity, 1.0, 20.0, c_min=c_min,
                           weights=w, method="fast")
    assert np.array_equal(heap.sizes, fast.sizes)
    assert heap.feasible == fast.feasible
    assert np.array_equal(heap.hit_ratios, fast.hit_ratios)


def test_two_level_solve_batched_matches_list():
    traces = _rand_traces(11)
    mon = analyze_windows(traces, "urd")
    hs_list = list(mon.curves)
    cap = max(1, int(mon.curves.max_useful_sizes.sum()) // 3)
    for fn_kw in ({"partition_fn": greedy_allocate},):
        p1a, p2a = two_level_solve(mon.curves, cap, cap // 2, 1.0, 3.0,
                                   20.0, c_min=2, **fn_kw)
        p1b, p2b = two_level_solve(hs_list, cap, cap // 2, 1.0, 3.0,
                                   20.0, c_min=2, **fn_kw)
        assert np.array_equal(p1a.sizes, p1b.sizes)
        assert np.array_equal(p2a.sizes, p2b.sizes)


# ------------------------------------------------------- SHARDS sampling
def test_sampled_reuse_distances_fast_equals_fenwick():
    """Satellite fix: the sampled monitor must route the filtered
    sub-trace through the vectorized engine with unchanged output."""
    rng = np.random.default_rng(0)
    a = rng.integers(0, 200, 3000).astype(np.int64)
    r = rng.random(3000) < 0.6
    t = Trace(a, r)
    for kind in ("trd", "urd"):
        fast = sampled_reuse_distances(t, kind, rate=0.4, seed=9)
        slow = sampled_reuse_distances(t, kind, rate=0.4, seed=9,
                                       engine="fenwick")
        assert np.array_equal(fast.distances, slow.distances)
        assert fast.rate == 0.4 and fast.expected_error > 0.0


def test_sampled_rate_one_is_exact():
    t = Trace(np.arange(100, dtype=np.int64) % 9, np.ones(100, bool))
    s = sampled_reuse_distances(t, "trd", rate=1.0)
    e = reuse_distances_fast(t, "trd")
    assert np.array_equal(s.distances, e.distances)
    assert s.rate == 1.0 and s.expected_error == 0.0


def test_salt_stable_per_tenant_window():
    assert shards_salt(3, 7) == shards_salt(3, 7)
    assert shards_salt(3, 7) != shards_salt(3, 8)
    assert shards_salt(3, 7) != shards_salt(4, 7)
    # fused monitor uses the same (window_seed, tenant) salts as the
    # standalone function, so per-tenant results line up exactly
    rng = np.random.default_rng(1)
    traces = [Trace(rng.integers(0, 150, 1500).astype(np.int64),
                    rng.random(1500) < 0.7, f"t{i}") for i in range(3)]
    mon = analyze_windows(traces, "urd", sample_rate=0.5, window_seed=42)
    for i, tr in enumerate(traces):
        rd = sampled_reuse_distances(tr, "urd", rate=0.5,
                                     salt=shards_salt(42, i))
        h = build_hit_ratio_function(rd)
        assert np.array_equal(h.edges, mon.curves[i].edges)
        assert np.array_equal(h.heights, mon.curves[i].heights)
        assert mon.urd_sizes[i] == urd_cache_blocks(rd)


def test_auto_sample_rate_tuner():
    assert auto_sample_rate(0) == 1.0
    assert auto_sample_rate(100, target=4096) == 1.0      # tiny: exact
    assert auto_sample_rate(8192, target=4096) == 0.5
    assert auto_sample_rate(10**6, target=4096) == pytest.approx(4096 / 10**6)
    # floor guards curves built from too few samples
    assert auto_sample_rate(1000, target=100, floor=500) == 0.5
    mask = shards_keep_mask(np.arange(1000, dtype=np.int64), 1.0, 123)
    assert mask.all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 63), st.sampled_from([0.3, 0.5]))
def test_sampled_curve_error_bound(seed, rate):
    """SHARDS accuracy: on randomized zipf-ish traces the sampled curve
    tracks the exact one within a few expected-error bars."""
    rng = np.random.default_rng(seed)
    ws = int(rng.integers(50, 400))
    u = rng.random(4000)
    a = np.minimum((u ** 2.0) * ws, ws - 1).astype(np.int64)
    t = Trace(a, np.ones(4000, bool))
    exact = build_hit_ratio_function(reuse_distances_fast(t, "trd"))
    rd = sampled_reuse_distances(t, "trd", rate=rate, seed=seed)
    samp = build_hit_ratio_function(rd)
    grid = np.arange(0, max(exact.max_useful_size, 2), 2)
    err = np.abs(samp(grid) - exact(grid))
    # generous statistical bound: 4 expected-error bars, floor 0.1
    assert float(err.max()) <= max(4.0 * rd.expected_error, 0.1), \
        (seed, rate, float(err.max()), rd.expected_error)


def test_monitor_sampled_write_ratio_unbiased_direction():
    rng = np.random.default_rng(4)
    a = rng.integers(0, 100, 4000).astype(np.int64)
    r = rng.random(4000) < 0.5
    t = Trace(a, r)
    mon = analyze_windows([t], "urd", sample_rate=0.5, window_seed=0)
    assert abs(float(mon.write_ratios[0]) - write_ratio(t)) < 0.1
    assert 0.0 < mon.sample_rates[0] < 1.0
    assert mon.expected_errors[0] > 0.0


# ------------------------------------------------------ manager wiring
def test_manager_auto_sampling_threshold():
    names = [f"t{i}" for i in range(8)]
    exact = ECICacheManager(5000, names, c_min=5, auto_sample_tenants=256)
    assert exact.effective_sample_rate() is None
    auto = ECICacheManager(5000, names, c_min=5, auto_sample_tenants=8)
    assert auto.effective_sample_rate() == "auto"
    rng = np.random.default_rng(0)
    traces = [Trace(rng.integers(0, 60, 400).astype(np.int64),
                    rng.random(400) < 0.6, nm) for nm in names]
    auto.run_window(traces)
    assert auto.history[-1].sizes.sum() > 0
    assert auto.windows_analyzed == 1


def test_manager_sampled_windows_progress_salts():
    """Each Δt window gets fresh per-tenant salts (windows_analyzed)."""
    names = ["a", "b"]
    mgr = ECICacheManager(10**5, names, c_min=5, sample_rate=0.5)
    rng = np.random.default_rng(0)
    for w in range(3):
        traces = [Trace(rng.integers(0, 900, 1200).astype(np.int64),
                        rng.random(1200) < 0.7, nm) for nm in names]
        mgr.run_window(traces)
    assert mgr.windows_analyzed == 3
    assert len(mgr.history) == 3


# --------------------------------------- device pipeline == host pipeline
def _device_traces(seed):
    """Adversarial window shapes for the fused device program: empty
    windows, single-access segments, and pow2-straddling lengths (63/64/65
    — the padded widths the shape-bucket key must separate)."""
    rng = np.random.default_rng(seed)
    out = _rand_traces(seed)
    out.append(Trace(np.array([7], np.int64), np.array([True]), "one"))
    out.append(Trace(np.array([7], np.int64), np.array([False]), "one-w"))
    for ln in (63, 64, 65):
        a = rng.integers(0, 12, ln).astype(np.int64)
        out.append(Trace(a, rng.random(ln) < 0.5, f"pow2-{ln}"))
    return out


@pytest.mark.parametrize("kind", ["urd", "trd"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_device_pipeline_bit_identical(kind, seed):
    """The fused device program reproduces the host monitor bit-for-bit
    (f64 mode off-TPU), paying exactly one host sync per window."""
    traces = _device_traces(seed)
    ref = analyze_windows(traces, kind)
    prof = StageProfile()
    got = analyze_windows(traces, kind, pipeline="device", profile=prof)
    assert_monitor_equal(ref, got, exact_floats=not _on_tpu())
    assert prof.windows == 1 and prof.syncs_per_window <= 1.0


def test_device_pipeline_sampled_bit_identical():
    traces = _device_traces(7)
    for rate in (0.5, "auto"):
        ref = analyze_windows(traces, "urd", sample_rate=rate,
                              window_seed=11)
        got = analyze_windows(traces, "urd", sample_rate=rate,
                              window_seed=11, pipeline="device")
        assert_monitor_equal(ref, got, exact_floats=not _on_tpu())


def test_device_pipeline_all_empty_window():
    traces = [Trace(np.zeros(0, np.int64), np.zeros(0, bool), f"e{i}")
              for i in range(3)]
    ref = analyze_windows(traces, "urd")
    prof = StageProfile()
    got = analyze_windows(traces, "urd", pipeline="device", profile=prof)
    assert_monitor_equal(ref, got)
    assert prof.syncs == 0               # trivial window: no device work


def test_device_pipeline_kernel_route():
    """The Pallas-kernel counting route of the device program (interpret
    mode off-TPU) agrees with the host monitor on a small tape."""
    traces = _device_traces(3)[:4] + [
        Trace(np.zeros(0, np.int64), np.zeros(0, bool), "empty")]
    lens = np.array([len(t) for t in traces], np.int64)
    bounds = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    addrs = np.concatenate([t.addrs for t in traces])
    is_read = np.concatenate([t.is_read for t in traces])
    ref = analyze_windows(traces, "urd")
    curves, urd, wr, _ = monitor_window_device(
        addrs, is_read, bounds, lens, kind="urd", use_kernel=True)
    assert np.array_equal(ref.curves.edges, curves.edges)
    assert np.array_equal(ref.curves.offsets, curves.offsets)
    assert np.array_equal(ref.urd_sizes, urd)
    if not _on_tpu():
        assert np.array_equal(ref.curves.heights, curves.heights)
        assert np.array_equal(ref.write_ratios, wr)


def test_device_pipeline_rejects_percentile():
    with pytest.raises(ValueError, match="percentile"):
        analyze_windows(_rand_traces(0), "urd", percentile=90.0,
                        pipeline="device")


@settings(max_examples=40, deadline=None)
@given(_curve_strategy(), st.integers(0, 120), st.integers(0, 12),
       st.booleans())
def test_greedy_device_bit_identical_to_heap(steps_per_tenant, capacity,
                                             c_min, weighted):
    """The jitted lax walk replays the heap's grant order exactly."""
    hs = []
    for steps in steps_per_tenant:
        sizes = np.cumsum([s for s, _ in steps])
        heights = np.minimum(np.cumsum([h for _, h in steps]), 1.0)
        hs.append(HitRatioFunction(
            np.concatenate([[0], sizes]).astype(np.int64),
            np.concatenate([[0.0], heights]), 1000))
    w = (np.linspace(0.5, 2.0, len(hs)) if weighted else None)
    heap = greedy_allocate(hs, capacity, 1.0, 20.0, c_min=c_min,
                           weights=w, method="heap")
    dev = greedy_allocate(hs, capacity, 1.0, 20.0, c_min=c_min,
                          weights=w, method="device")
    if _on_tpu():                        # f32 ties: compare by objective
        assert dev.latency == pytest.approx(heap.latency, rel=1e-5)
    else:
        assert np.array_equal(heap.sizes, dev.sizes)
        assert np.array_equal(heap.hit_ratios, dev.hit_ratios)
    assert heap.feasible == dev.feasible


@pytest.mark.parametrize("frac", [0.1, 0.6, 2.0])
def test_device_decision_pipeline_matches_host(frac):
    """End-to-end fused decision (count→curve→wr→partition in one jit)
    equals the host monitor + fast walk, including the feasible and
    scale-down branches."""
    traces = _device_traces(5)
    mon = analyze_windows(traces, "urd")
    cap = max(int(mon.urd_sizes.sum() * frac), 1)
    pipe = DeviceWindowPipeline(capacity=cap, c_min=4)
    prof = StageProfile()
    dec = pipe.run(traces, profile=prof)
    part = greedy_allocate(mon.curves, cap, 1.0, 20.0, c_min=4,
                           method="fast")
    assert dec.feasible == part.feasible
    assert np.array_equal(dec.urd_sizes, mon.urd_sizes)
    assert prof.syncs_per_window <= 1.0
    if _on_tpu():
        assert dec.latency == pytest.approx(part.latency, rel=1e-3)
    else:
        assert np.array_equal(dec.sizes, part.sizes)
        assert np.array_equal(dec.hit_ratios, part.hit_ratios)
        assert np.array_equal(dec.write_ratios, mon.write_ratios)
        assert dec.latency == pytest.approx(part.latency, rel=1e-12)


def test_device_run_stream_double_buffered():
    """The double-buffered stream returns the same per-window decisions
    as window-at-a-time runs (empty windows interleaved)."""
    empty = [Trace(np.zeros(0, np.int64), np.zeros(0, bool))] * 3
    wins = [_device_traces(s) for s in (0, 1)] + [empty] + \
           [_device_traces(2)]
    pipe = DeviceWindowPipeline(capacity=300, c_min=3)
    prof = StageProfile()
    stream = pipe.run_stream(wins, profile=prof)
    solo = [pipe.run(w) for w in wins]
    assert len(stream) == len(wins)
    for a, b in zip(stream, solo):
        assert np.array_equal(a.sizes, b.sizes)
        assert np.array_equal(a.urd_sizes, b.urd_sizes)
        assert a.feasible == b.feasible
    assert prof.syncs_per_window <= 1.0


def test_manager_device_pipeline_matches_host():
    """ECICacheManager(pipeline="device") reproduces the host manager's
    decisions window for window (batch engine + precomputed TRD on the
    host side vs device recount)."""
    def drive(pipeline):
        mgr = ECICacheManager(600, [f"t{i}" for i in range(5)], c_min=8,
                              pipeline=pipeline)
        rng = np.random.default_rng(17)
        for _ in range(3):
            traces = []
            for i in range(5):
                n = int(rng.integers(20, 250))
                traces.append(Trace(rng.integers(0, 50, n).astype(np.int64),
                                    rng.random(n) < 0.6, f"t{i}"))
            mgr.run_window(traces)
        return mgr
    mh, md = drive("host"), drive("device")
    for a, b in zip(mh.history, md.history):
        assert a.policies == b.policies
        if _on_tpu():
            assert a.partition.latency == pytest.approx(
                b.partition.latency, rel=1e-3)
        else:
            assert np.array_equal(a.sizes, b.sizes)


@pytest.mark.slow
@settings(max_examples=examples(10), deadline=None)
@given(st.integers(0, 10**6), st.sampled_from([None, 0.4]),
       st.sampled_from(["urd", "trd"]))
def test_device_pipeline_differential_deep(seed, rate, kind):
    """Nightly depth: randomized window shapes through both pipelines."""
    rng = np.random.default_rng(seed)
    traces = []
    for i in range(int(rng.integers(1, 10))):
        n = int(rng.integers(0, 200))
        traces.append(Trace(rng.integers(0, 30, n).astype(np.int64),
                            rng.random(n) < rng.uniform(0, 1), f"t{i}"))
    ref = analyze_windows(traces, kind, sample_rate=rate, window_seed=seed)
    got = analyze_windows(traces, kind, sample_rate=rate, window_seed=seed,
                          pipeline="device")
    assert_monitor_equal(ref, got, exact_floats=not _on_tpu())


# --------------------------------------------------- fallback telemetry
def _pressure_trace():
    # 3 live read addresses cycle twice: live count 3 > C1=1 forces the
    # two-level RO guard to fail
    a = np.array([0, 1, 2, 0, 1, 2], dtype=np.int64)
    return Trace(a, np.ones(6, bool), "pressure")


def test_simulate_many_two_level_ro_pressure_stays_vectorized():
    # two-level RO pressure replays through the per-level token loop now —
    # no interpreter fallback (the flag only marks degenerate windows)
    res = simulate_many([_pressure_trace()], capacities=[1],
                        policies=[WritePolicy.RO], capacities2=[1],
                        policies2=[WritePolicy.RO])
    assert res[0].fallback == 0
    assert res[0].cache_writes_l2 > 0    # demotions prove the token path
    # single-level RO pressure stays on the vectorized token path
    res1 = simulate_many([_pressure_trace()], capacities=[1],
                         policies=[WritePolicy.RO])
    assert res1[0].fallback == 0
    # WB never falls back
    res2 = simulate_many([_pressure_trace()], capacities=[1],
                         policies=[WritePolicy.WB], capacities2=[1])
    assert res2[0].fallback == 0
    # degenerate: warm L2 content behind a dead C2 <= 0 level
    c2 = LRUCache(0)
    c2.set_state_arrays(np.array([9], np.int64), np.array([False]))
    res3 = simulate_many([_pressure_trace()], capacities=[1],
                         policies=[WritePolicy.RO], caches2=[c2])
    assert res3[0].fallback == 1


def test_manager_counts_ro_fallback_windows():
    mgr = ECICacheManager(100, ["p"], c_min=1, initial_blocks=1,
                          capacity2=4, adaptive_policy=False)
    t = mgr.tenants[0]
    t.policy = WritePolicy.RO
    t.cache2 = LRUCache(1)
    mgr.run_window([_pressure_trace()])
    # pressure windows replay vectorized: the counter stays 0, the
    # denominator still counts the replayed tenant-window
    assert mgr.ro_fallback_windows == 0
    assert mgr.tenant_windows == 1
    assert mgr.summary()["ro_fallback_windows"] == 0
    assert t.result.fallback == 0
    # an empty two-level window is the remaining (degenerate) fallback
    t.cache2 = LRUCache(1)               # keep the second level alive
    empty = Trace(np.zeros(0, np.int64), np.zeros(0, bool), "empty")
    mgr.run_window([empty])
    assert mgr.ro_fallback_windows == 1
    assert mgr.summary()["ro_fallback_windows"] == 1
