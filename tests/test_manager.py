"""ECICacheManager (Monitor/Analyzer/Actuator) + baselines end-to-end."""
import numpy as np
import pytest

from repro.core import (ECICacheManager, GlobalLRUManager, Trace,
                        WritePolicy, make_manager)
from repro.data.traces import MSR_PROFILES, msr_trace


NAMES = ["wdev_0", "hm_1", "prn_1", "web_0"]


def _run(scheme, capacity, windows=3, n=1500, **kw):
    mgr = make_manager(scheme, capacity, NAMES, c_min=20,
                       initial_blocks=50, **kw)
    for w in range(windows):
        traces = [msr_trace(nm, n, seed=97 * w + i)
                  for i, nm in enumerate(NAMES)]
        mgr.run_window(traces)
    return mgr


def test_feasible_allocates_urd_sizes():
    mgr = _run("eci", capacity=10**6)
    d = mgr.history[-1]
    assert d.feasible
    for t, s in zip(mgr.tenants, d.sizes):
        assert s == t.h_fn.max_useful_size


def test_infeasible_respects_capacity():
    mgr = _run("eci", capacity=300)
    for d in mgr.history:
        if not d.feasible:
            assert int(d.sizes.sum()) <= 300


def test_policy_assignment_matches_alg3():
    mgr = _run("eci", capacity=10**5)
    for t in mgr.tenants:
        # wdev-like WAW-heavy tenants end RO; hm_1 (pure reads) stays WB
        if t.name == "hm_1":
            assert t.policy is WritePolicy.WB
        if t.name == "wdev_0":
            assert t.policy is WritePolicy.RO


def test_centaur_never_adapts_policy():
    mgr = _run("centaur", capacity=10**5)
    assert all(t.policy is WritePolicy.WB for t in mgr.tenants)


def test_eci_writes_fewer_blocks_than_centaur():
    """Headline endurance direction (paper: -65%)."""
    eci = _run("eci", capacity=2000)
    cen = _run("centaur", capacity=2000)
    assert eci.summary()["cache_writes"] < cen.summary()["cache_writes"]


def test_eci_allocates_no_more_than_centaur_feasible():
    """Feasible state (App. A): URD sizes <= TRD sizes."""
    eci = _run("eci", capacity=10**6)
    cen = _run("centaur", capacity=10**6)
    assert (eci.summary()["allocated_blocks"]
            <= cen.summary()["allocated_blocks"])


def test_retire_tenant_releases_space():
    mgr = make_manager("eci", 1000, NAMES, c_min=10, initial_blocks=50)
    traces = [msr_trace(nm, 500, seed=i) for i, nm in enumerate(NAMES)]
    mgr.run_window(traces)
    mgr.run_window([traces[0], None, traces[2], traces[3]])
    assert mgr.tenants[1].cache.capacity == 0
    assert not mgr.tenants[1].active
    assert mgr.allocated_sizes()[1] == 0


def test_global_lru_baseline_runs():
    g = GlobalLRUManager(500, NAMES)
    traces = [msr_trace(nm, 500, seed=i) for i, nm in enumerate(NAMES)]
    g.run_window(traces)
    s = g.summary()
    assert s["accesses"] == 2000
    assert 0 <= s["read_hit_ratio"] <= 1


def test_static_and_reuse_intensity_schemes():
    for scheme in ("static", "reuse_intensity"):
        mgr = _run(scheme, capacity=800)
        s = mgr.summary()
        assert s["accesses"] > 0
        assert s["allocated_blocks"] <= 800 + len(NAMES)  # rounding slack


def test_sampled_monitor_mode():
    mgr = make_manager("eci", 5000, NAMES, c_min=10, initial_blocks=50,
                       sample_rate=0.5)
    traces = [msr_trace(nm, 800, seed=i) for i, nm in enumerate(NAMES)]
    mgr.run_window(traces)
    assert mgr.history[-1].sizes.sum() > 0
