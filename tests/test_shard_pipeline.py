"""Sharded control plane == fused device program == host monitor.

The shard pipeline (``core.shard_pipeline``) partitions the padded
window tape across a 1-D ``("shards",)`` mesh by whole tenant-segments
and must be a pure optimization: every curve / URD size / write ratio /
allocation is bit-identical to the host monitor (f64 mode) at *any*
shard count — 1, 2 and 8 are the matrix here (conftest forces 8 host
devices).  The suite also pins the placement invariants (true
partition, per-shard self-alignment, 2x-of-optimal balance) and the
<= 1 host sync per window per mesh transfer contract.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from oracle import assert_monitor_equal, examples

from repro.core import (DeviceWindowPipeline, ECICacheManager, StageProfile,
                        Trace, analyze_windows)
from repro.core.shard_pipeline import (monitor_window_sharded,
                                       shard_assignment,
                                       uniform_shard_layout)
from repro.distributed.sharding import control_plane_mesh
from repro.kernels.cache_sim.ops import _on_tpu

SHARD_COUNTS = (1, 2, 8)


def _rand_traces(seed, n_tenants=6, max_n=300, max_addr=40):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_tenants):
        n = int(rng.integers(0, max_n))
        a = rng.integers(0, max_addr, n).astype(np.int64)
        r = rng.random(n) < rng.uniform(0.1, 0.9)
        out.append(Trace(a, r, f"t{i}"))
    return out


def _shard_traces(seed):
    """Adversarial shapes for the sharded program: empty windows,
    single-access segments and pow2-straddling lengths (63/64/65 land in
    different padded-width blocks, so they exercise cross-shard width
    groups and the uniform layout's per-width row capacities)."""
    rng = np.random.default_rng(seed)
    out = _rand_traces(seed)
    out.append(Trace(np.zeros(0, np.int64), np.zeros(0, bool), "empty"))
    out.append(Trace(np.array([7], np.int64), np.array([True]), "one"))
    out.append(Trace(np.array([7], np.int64), np.array([False]), "one-w"))
    for ln in (63, 64, 65):
        a = rng.integers(0, 12, ln).astype(np.int64)
        out.append(Trace(a, rng.random(ln) < 0.5, f"pow2-{ln}"))
    return out


def _window_arrays(traces):
    lens = np.array([len(t) for t in traces], np.int64)
    bounds = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    addrs = (np.concatenate([t.addrs for t in traces]) if lens.sum()
             else np.zeros(0, np.int64))
    reads = (np.concatenate([t.is_read for t in traces]) if lens.sum()
             else np.zeros(0, bool))
    return addrs, reads, bounds, lens


# ------------------------------------------------- sharded == host monitor
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("kind", ["urd", "trd"])
def test_sharded_monitor_bit_identical(kind, n_shards):
    """Exact path at every mesh width, adversarial window shapes."""
    traces = _shard_traces(0)
    ref = analyze_windows(traces, kind)
    addrs, reads, bounds, lens = _window_arrays(traces)
    prof = StageProfile()
    curves, urd, wr, _ = monitor_window_sharded(
        addrs, reads, bounds, lens, mesh=control_plane_mesh(n_shards),
        kind=kind, profile=prof, transfer_sanitize=True)
    assert np.array_equal(ref.curves.edges, curves.edges)
    assert np.array_equal(ref.curves.offsets, curves.offsets)
    assert np.array_equal(ref.urd_sizes, urd)
    if not _on_tpu():
        assert np.array_equal(ref.curves.heights, curves.heights)
        assert np.array_equal(ref.write_ratios, wr)
    # the transfer contract: one host sync per window per mesh (asserted
    # under the transfer guard — any hidden device_get would have raised)
    assert prof.windows == 1 and prof.syncs_per_window <= 1.0


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("kind", ["urd", "trd"])
def test_analyze_windows_sharded_default_mesh(kind, seed):
    """``analyze_windows(pipeline="sharded")`` (default full-width mesh)
    reproduces the host monitor bit-for-bit, one sync per window."""
    traces = _shard_traces(seed)
    ref = analyze_windows(traces, kind)
    prof = StageProfile()
    got = analyze_windows(traces, kind, pipeline="sharded", profile=prof)
    assert_monitor_equal(ref, got, exact_floats=not _on_tpu())
    assert prof.windows == 1 and prof.syncs_per_window <= 1.0


def test_sharded_more_shards_than_tenants():
    """8-shard mesh, 2 tenants: most shards carry only padding rows and
    must contribute exact zeros to every psum."""
    traces = _rand_traces(3, n_tenants=2, max_n=120)
    ref = analyze_windows(traces, "urd")
    addrs, reads, bounds, lens = _window_arrays(traces)
    curves, urd, wr, _ = monitor_window_sharded(
        addrs, reads, bounds, lens, mesh=control_plane_mesh(8))
    assert np.array_equal(ref.curves.edges, curves.edges)
    assert np.array_equal(ref.urd_sizes, urd)
    if not _on_tpu():
        assert np.array_equal(ref.curves.heights, curves.heights)
        assert np.array_equal(ref.write_ratios, wr)


def test_sharded_single_tenant_per_shard():
    """8 equal-width tenants over 8 shards: LPT gives every shard exactly
    one segment (the fully-distributed corner)."""
    rng = np.random.default_rng(11)
    traces = [Trace(rng.integers(0, 30, 100).astype(np.int64),
                    rng.random(100) < 0.6, f"t{i}") for i in range(8)]
    ref = analyze_windows(traces, "urd")
    got = analyze_windows(traces, "urd", pipeline="sharded")
    assert_monitor_equal(ref, got, exact_floats=not _on_tpu())
    widths = np.full(8, 128, np.int64)           # 100 pads to 128
    assert len(set(shard_assignment(widths, 8).tolist())) == 8


def test_sharded_all_empty_window():
    """All-empty windows take the trivial path: parity, zero syncs."""
    traces = [Trace(np.zeros(0, np.int64), np.zeros(0, bool), f"e{i}")
              for i in range(3)]
    ref = analyze_windows(traces, "urd")
    prof = StageProfile()
    got = analyze_windows(traces, "urd", pipeline="sharded", profile=prof)
    assert_monitor_equal(ref, got)
    assert prof.syncs == 0


@pytest.mark.parametrize("rate", [0.5, "auto"])
def test_sharded_sampled_bit_identical(rate):
    """SHARDS-filtered sub-tape through the mesh: same salts, same
    filtered segments, bit-identical sampled curves."""
    traces = _shard_traces(7)
    ref = analyze_windows(traces, "urd", sample_rate=rate, window_seed=11)
    got = analyze_windows(traces, "urd", sample_rate=rate, window_seed=11,
                          pipeline="sharded")
    assert_monitor_equal(ref, got, exact_floats=not _on_tpu())


def test_sharded_rejects_percentile():
    with pytest.raises(ValueError, match="percentile"):
        analyze_windows(_rand_traces(0), "urd", percentile=90.0,
                        pipeline="sharded")


# --------------------------------------------- decision pipeline + stream
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_sharded_decision_pipeline_matches_device(n_shards):
    """``DeviceWindowPipeline(mesh=...)`` returns the same allocation as
    the single-device pipeline (the budget cut is replicated, so sizes /
    policies / curves agree bit-for-bit in f64 mode)."""
    traces = _shard_traces(5)
    solo = DeviceWindowPipeline(capacity=300, c_min=4)
    shrd = DeviceWindowPipeline(capacity=300, c_min=4,
                                mesh=control_plane_mesh(n_shards),
                                transfer_sanitize=True)
    prof = StageProfile()
    a, b = solo.run(traces), shrd.run(traces, profile=prof)
    assert a.feasible == b.feasible
    assert np.array_equal(a.urd_sizes, b.urd_sizes)
    assert prof.syncs_per_window <= 1.0
    if _on_tpu():
        assert b.latency == pytest.approx(a.latency, rel=1e-3)
    else:
        assert np.array_equal(a.sizes, b.sizes)
        assert np.array_equal(a.hit_ratios, b.hit_ratios)
        assert np.array_equal(a.write_ratios, b.write_ratios)


def test_sharded_run_stream_double_buffered(shard_mesh):
    """The double-buffered stream over the mesh (per-shard async ingest
    of window k+1 behind window k's program) equals window-at-a-time
    runs, empty windows interleaved."""
    empty = [Trace(np.zeros(0, np.int64), np.zeros(0, bool))] * 3
    wins = [_shard_traces(s) for s in (0, 1)] + [empty] + \
           [_shard_traces(2)]
    pipe = DeviceWindowPipeline(capacity=300, c_min=3, mesh=shard_mesh,
                                transfer_sanitize=True)
    prof = StageProfile()
    stream = pipe.run_stream(wins, profile=prof)
    solo = [pipe.run(w) for w in wins]
    assert len(stream) == len(wins)
    for a, b in zip(stream, solo):
        assert np.array_equal(a.sizes, b.sizes)
        assert np.array_equal(a.urd_sizes, b.urd_sizes)
        assert a.feasible == b.feasible
    assert prof.syncs_per_window <= 1.0


def test_manager_sharded_pipeline_matches_host():
    """``ECICacheManager(pipeline="sharded")`` reproduces the host
    manager's decisions window for window."""
    def drive(pipeline):
        mgr = ECICacheManager(600, [f"t{i}" for i in range(5)], c_min=8,
                              pipeline=pipeline)
        rng = np.random.default_rng(17)
        for _ in range(3):
            traces = []
            for i in range(5):
                n = int(rng.integers(20, 250))
                traces.append(Trace(rng.integers(0, 50, n).astype(np.int64),
                                    rng.random(n) < 0.6, f"t{i}"))
            mgr.run_window(traces)
        return mgr
    mh, ms = drive("host"), drive("sharded")
    for a, b in zip(mh.history, ms.history):
        assert a.policies == b.policies
        if _on_tpu():
            assert a.partition.latency == pytest.approx(
                b.partition.latency, rel=1e-3)
        else:
            assert np.array_equal(a.sizes, b.sizes)


# --------------------------------------------------- placement invariants
def _widths_strategy():
    return st.lists(st.integers(0, 10), min_size=1, max_size=40)


@settings(max_examples=examples(60), deadline=None)
@given(_widths_strategy(), st.sampled_from([1, 2, 3, 8]))
def test_shard_assignment_invariants(exps, n_shards):
    """True partition, per-shard descending self-aligned layout, and
    max-shard width within 2x of optimal."""
    widths = np.sort(2 ** np.array(exps, np.int64))[::-1]
    assign = shard_assignment(widths, n_shards)
    # every row lands on exactly one valid shard (a true partition)
    assert assign.shape == widths.shape
    assert ((assign >= 0) & (assign < n_shards)).all()
    lay = uniform_shard_layout(widths, assign, n_shards)
    # self-alignment: each row's local entry offset is a multiple of its
    # own pow2 width, so row-internal indices keep the device program's
    # alignment guarantees on every shard
    assert (lay.entry_base % widths == 0).all()
    assert (lay.entry_base >= 0).all()
    assert (lay.entry_base + widths <= lay.size).all()
    for s in range(n_shards):
        rows = np.flatnonzero(assign == s)       # global descending order
        w_s = widths[rows]
        assert (np.diff(w_s) <= 0).all()         # stays width-sorted
        # local entry ranges are disjoint (no two rows share tape slots)
        order = np.argsort(lay.entry_base[rows], kind="stable")
        lo = lay.entry_base[rows][order]
        assert (lo[:-1] + w_s[order][:-1] <= lo[1:]).all()
    # LPT balance: max_load <= mean + w_max <= 2 * max(opt_lb, w_max)
    loads = np.bincount(assign, weights=widths, minlength=n_shards)
    opt_lb = max(int(np.ceil(widths.sum() / n_shards)), int(widths.max()))
    assert int(loads.max()) <= 2 * opt_lb


@pytest.mark.slow
@settings(max_examples=examples(10), deadline=None)
@given(st.integers(0, 10**6), st.sampled_from([None, 0.4]),
       st.sampled_from(["urd", "trd"]), st.sampled_from([2, 8]))
def test_sharded_differential_deep(seed, rate, kind, n_shards):
    """Nightly depth: randomized window shapes, host vs sharded mesh."""
    rng = np.random.default_rng(seed)
    traces = []
    for i in range(int(rng.integers(1, 10))):
        n = int(rng.integers(0, 200))
        traces.append(Trace(rng.integers(0, 30, n).astype(np.int64),
                            rng.random(n) < rng.uniform(0, 1), f"t{i}"))
    ref = analyze_windows(traces, kind, sample_rate=rate, window_seed=seed)
    addrs, reads, bounds, lens = _window_arrays(traces)
    if rate is None:
        curves, urd, wr, _ = monitor_window_sharded(
            addrs, reads, bounds, lens, mesh=control_plane_mesh(n_shards),
            kind=kind)
        assert np.array_equal(ref.curves.edges, curves.edges)
        assert np.array_equal(ref.urd_sizes, urd)
        if not _on_tpu():
            assert np.array_equal(ref.curves.heights, curves.heights)
            assert np.array_equal(ref.write_ratios, wr)
    else:
        got = analyze_windows(traces, kind, sample_rate=rate,
                              window_seed=seed, pipeline="sharded")
        assert_monitor_equal(ref, got, exact_floats=not _on_tpu())
