"""Distributed behaviour on multi-host-device CPU meshes.

Each test runs in a subprocess that overwrites ``XLA_FLAGS`` with its own
``xla_force_host_platform_device_count`` before importing jax, so the
device count each script sees is exactly what it asked for — independent
of the 8-device flag conftest now sets for the in-process shard suite
(``tests/test_shard_pipeline.py``).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 8, timeout: int = 420) -> str:
    code = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={devices}"\n'
        + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_pjit_train_step_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models import model as M
        from repro.optim.adamw import AdamWConfig
        from repro.train.steps import make_train_step, init_train_state
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.sharding import state_specs, batch_specs
        from repro.distributed.ctx import activation_rules, default_train_rules
        from repro.data.lm import SyntheticLM

        cfg = get_smoke_config("qwen3_0_6b")
        import dataclasses
        cfg = dataclasses.replace(cfg, dtype="float32")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = AdamWConfig(lr_peak=1e-3)
        step = make_train_step(cfg, opt)
        state = init_train_state(params)
        data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
        b = data.batch_at(0)

        # single device
        s1, m1 = jax.jit(step)(state, b)

        # 2x2 mesh
        mesh = make_test_mesh(2, 2)
        sspec = state_specs(state, mesh)
        bspec = batch_specs(b, mesh)
        with mesh:
            with activation_rules(default_train_rules(mesh)):
                f = jax.jit(step, in_shardings=(sspec, bspec),
                            out_shardings=(sspec, NamedSharding(mesh, P())))
                s2, m2 = f(jax.device_put(state, sspec),
                           jax.device_put(b, bspec))
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert abs(l1 - l2) / abs(l1) < 2e-4, (l1, l2)
        # params agree after one step
        for a, c in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(s2["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=2e-3, atol=2e-4)
        print("PJIT_MATCH_OK", l1, l2)
    """)
    assert "PJIT_MATCH_OK" in out


def test_multipod_mesh_and_dp_axes():
    out = run_py("""
        from repro.launch.mesh import make_test_mesh, dp_axes
        m = make_test_mesh(2, 2, pod=2)
        assert m.axis_names == ("pod", "data", "model")
        assert dp_axes(m) == ("pod", "data")
        print("MESH_OK")
    """)
    assert "MESH_OK" in out


def test_sharding_specs_divisibility_all_archs():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import ARCH_IDS, get_smoke_config
        from repro.models import model as M
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.sharding import param_specs
        mesh = make_test_mesh(2, 2)
        for arch in ARCH_IDS:
            cfg = get_smoke_config(arch)
            sds = jax.eval_shape(
                lambda k: M.init_params(cfg, k),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            specs = param_specs(sds, mesh)
            # every spec must evenly divide its leaf (or be replicated)
            def check(path, leaf, spec):
                for dim, entry in zip(leaf.shape, spec.spec):
                    if entry is None: continue
                    axes = entry if isinstance(entry, tuple) else (entry,)
                    tot = 1
                    for a in axes:
                        tot *= dict(zip(mesh.axis_names,
                                        mesh.devices.shape))[a]
                    assert dim % tot == 0, (arch, path, leaf.shape, spec)
            jax.tree_util.tree_map_with_path(
                lambda p, l, s: check(p, l, s), sds, specs)
        print("SPECS_OK")
    """)
    assert "SPECS_OK" in out


def test_elastic_restore_across_meshes():
    out = run_py("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models import model as M
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.sharding import param_specs
        from repro.checkpoint.checkpoint import (save_checkpoint,
                                                 restore_checkpoint)
        cfg = get_smoke_config("qwen3_0_6b")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        mesh4 = make_test_mesh(2, 2)
        p4 = jax.device_put(params, param_specs(params, mesh4))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, p4, 1)
            mesh2 = make_test_mesh(2, 1)       # "shrunk cluster"
            restored, s = restore_checkpoint(
                d, params, shardings=param_specs(params, mesh2))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=1e-6)
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_compressed_psum_shard_map():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_test_mesh
        from repro.optim.compression import compressed_psum, init_error_state
        mesh = make_test_mesh(4, 1)
        rows = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 7.3
        g = {"w": rows}
        err = init_error_state(g)

        def body(g, e):
            return compressed_psum(g, e, "data")

        f = shard_map(body, mesh=mesh,
                      in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data")))
        red, new_err = f(g, err)
        # DP mean across shards, each with its own int8 scale
        expect = jnp.broadcast_to(rows.mean(axis=0, keepdims=True), rows.shape)
        np.testing.assert_allclose(np.asarray(red["w"]),
                                   np.asarray(expect),
                                   rtol=2e-2, atol=2e-2)
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_decode_cache_specs_multipod():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import model as M
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.sharding import cache_specs
        mesh = make_test_mesh(2, 2, pod=2)
        cfg = get_smoke_config("command_r_plus_104b")
        cache = jax.eval_shape(lambda: M.init_decode_cache(cfg, 8, 64))
        specs = cache_specs(cache, mesh)
        kspec = specs["kv"]["k"].spec
        assert kspec[1] is not None       # batch sharded over DP
        assert kspec[2] == "model"        # sequence split-KV
        print("CACHE_SPEC_OK", kspec)
    """, devices=8)
    assert "CACHE_SPEC_OK" in out
