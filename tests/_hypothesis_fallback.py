"""Minimal stand-in for ``hypothesis`` when the real package is unavailable.

The test environment for this repo cannot always install third-party
packages, but the property tests are written against the (tiny) subset of
the hypothesis API below.  ``tests/conftest.py`` installs this module into
``sys.modules`` as ``hypothesis`` *only* when the real library is missing —
with ``pip install -e .[test]`` (see pyproject.toml) the genuine article is
used and this file is inert.

Semantics: ``@given`` draws ``max_examples`` pseudo-random examples from the
strategies with a seed derived from the test name (deterministic across
runs).  There is no shrinking; the failing example is attached to the
exception instead.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies", "example_seed"]

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    """A strategy is just a callable drawing one example from an rng."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)))


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (``st``)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(
            lambda rng: float(min_value
                              + (max_value - min_value) * rng.random()))

    @staticmethod
    def sampled_from(options) -> _Strategy:
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(len(options)))])

    @staticmethod
    def just(value) -> _Strategy:
        return _Strategy(lambda rng: value)

    @staticmethod
    def tuples(*elems: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            # Bias toward short lists (like hypothesis) but cover the range.
            size = int(rng.integers(min_size, max_size + 1))
            return [elem.example(rng) for _ in range(size)]
        return _Strategy(draw)


def example_seed(name: str) -> int:
    return zlib.crc32(name.encode())


def given(*gargs: _Strategy, **gkwargs: _Strategy):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(example_seed(fn.__qualname__))
            for k in range(n):
                ex_args = tuple(s.example(rng) for s in gargs)
                ex_kwargs = {key: s.example(rng)
                             for key, s in gkwargs.items()}
                try:
                    fn(*args, *ex_args, **ex_kwargs, **kwargs)
                except Exception as e:  # noqa: BLE001 - annotate and re-raise
                    raise AssertionError(
                        f"falsifying example #{k} for {fn.__name__}: "
                        f"args={ex_args!r} kwargs={ex_kwargs!r}") from e

        # hide the example parameters from pytest's fixture resolution
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def decorate(fn):
        fn._max_examples = int(max_examples)
        return fn
    return decorate
