"""End-to-end behaviour: the paper's headline claims, directionally pinned.

The quantitative reproduction (17% perf / 30% perf-per-cost / 65% writes)
lives in ``benchmarks/``; these tests assert the *directions* hold on small
instances so regressions are caught in seconds.
"""
import numpy as np
import pytest

from repro.core import make_manager
from repro.data.traces import (FILEBENCH_PROFILES, MSR_PROFILES,
                               filebench_trace, generate_trace, msr_trace)
from repro.core.trace import request_type_mix

NAMES = list(MSR_PROFILES)


def _run(scheme, capacity, windows=3, n=2000, seed=0, **kw):
    mgr = make_manager(scheme, capacity, NAMES, c_min=50, initial_blocks=100,
                       t_fast=1.0, t_slow=20.0, flush_cost=10.0, **kw)
    for w in range(windows):
        traces = [msr_trace(nm, n, seed=seed + 1000 * w + i)
                  for i, nm in enumerate(NAMES)]
        mgr.run_window(traces)
    return mgr


@pytest.fixture(scope="module")
def pair():
    eci = _run("eci", capacity=4000)
    cen = _run("centaur", capacity=4000)
    return eci.summary(), cen.summary()


def test_eci_reduces_cache_writes_substantially(pair):
    es, cs = pair
    saved = 1 - es["cache_writes"] / cs["cache_writes"]
    assert saved > 0.35, f"writes saved only {saved:.1%}"


def test_eci_improves_perf_per_cost(pair):
    es, cs = pair
    assert es["perf_per_cost"] > cs["perf_per_cost"]


def test_eci_not_slower_than_centaur_under_pressure(pair):
    es, cs = pair
    assert es["mean_latency"] <= cs["mean_latency"] * 1.10


def test_feasible_state_smaller_allocation_same_schemes():
    """App. A: with unlimited capacity ECI allocates much less."""
    eci = _run("eci", capacity=10**7, windows=2)
    cen = _run("centaur", capacity=10**7, windows=2)
    ratio = (eci.summary()["allocated_blocks"]
             / cen.summary()["allocated_blocks"])
    assert ratio < 0.75, ratio


def test_generator_matches_requested_mix():
    for name in ("wdev_0", "hm_1", "prn_1"):
        prof = MSR_PROFILES[name].normalized()
        t = msr_trace(name, 6000, seed=9)
        mix = request_type_mix(t)
        # cold classes migrate into re-touch classes when pools are warm;
        # check the read/write split instead (tight) + WAW ballpark
        want_reads = prof.cold_read + prof.rar + prof.raw
        got_reads = mix["CR"] + mix["RAR"] + mix["RAW"]
        assert abs(got_reads - want_reads) < 0.08, name
        assert abs(mix["WAW"] - prof.waw) < 0.12, name


def test_filebench_profiles_cover_fig4_workloads():
    for name in ("fileserver", "webserver", "copyfiles",
                 "singlestreamread"):
        t = filebench_trace(name, 1000)
        assert len(t) == 1000


def test_sixteen_tenants_capacity_invariant():
    mgr = _run("eci", capacity=3000, windows=2)
    for d in mgr.history:
        assert int(d.sizes.sum()) <= max(
            3000, sum(t.urd_size for t in mgr.tenants))
        if not d.feasible:
            assert int(d.sizes.sum()) <= 3000
