"""Vectorized batch simulation engine ≡ the per-access interpreter.

Property tests (hypothesis) assert exact agreement of hits, write_hits,
cache_writes, latency and final LRU state over random traces × capacities ×
all three WritePolicy values, cold and across warm multi-window chains —
plus the paper invariants (URD ⊆ TRD; Fig. 5 sizing) on the fast
reuse-distance engine that rides on the same counting pass.  All engine
comparisons run through the shared differential oracle harness
(``tests/oracle.py``).
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from oracle import (EngineDiff, assert_results_equal, examples, mk_trace,
                    trace_strategy)
from repro.core import (Trace, WritePolicy, make_manager, reuse_distances,
                        reuse_distances_fast, simulate_batch, simulate_many,
                        stack_distances, urd_cache_blocks)
from repro.core.batch_sim import count_prev_ge
from repro.core.reuse_distance import max_rd, reuse_distances_vectorized
from repro.core.simulator import LRUCache

POLICIES = [WritePolicy.WB, WritePolicy.WT, WritePolicy.RO]


# ------------------------------------------------------------- primitives
@settings(max_examples=examples(100), deadline=None)
@given(trace_strategy(max_n=120, max_addr=25))
def test_count_prev_ge_matches_brute_force(trace_list):
    y = np.array([a for a, _ in trace_list], dtype=np.int64)
    brute = np.array([np.sum(y[:q] >= y[q]) for q in range(len(y))],
                     dtype=np.int64)
    assert np.array_equal(count_prev_ge(y), brute)


@settings(max_examples=examples(100), deadline=None)
@given(trace_strategy())
def test_stack_distances_match_brute_force(trace_list):
    t = mk_trace(trace_list)
    sd = stack_distances(t, backend="host")
    addrs = t.addrs
    for i in range(len(t)):
        window = [j for j in range(i) if addrs[j] == addrs[i]]
        if not window:
            assert sd[i] == -1
        else:
            p = window[-1]
            assert sd[i] == len(set(addrs[p + 1:i].tolist())), i


# ------------------------------------------------ engine ≡ oracle (cold)
@settings(max_examples=examples(150), deadline=None)
@given(trace_strategy(), st.integers(0, 8), st.sampled_from(POLICIES),
       st.sampled_from([0.0, 10.0]))
def test_batch_equals_simulate_cold(trace_list, cap, policy, flush):
    EngineDiff([cap], [policy], flush=flush).run_window([mk_trace(trace_list)])


@settings(max_examples=examples(60), deadline=None)
@given(st.lists(st.tuples(trace_strategy(max_n=40), st.integers(0, 7),
                          st.sampled_from(POLICIES)),
                min_size=1, max_size=4),
       st.sampled_from([0.0, 10.0]))
def test_batch_warm_multi_window_chain(windows_spec, flush):
    """Warm cross-window state: caches seeded by earlier windows, replayed
    by both engines, must stay byte-identical (content, order, dirty)."""
    diff = EngineDiff([cap for _, cap, _ in windows_spec],
                      [p for _, _, p in windows_spec], flush=flush)
    diff.run_windows([[mk_trace(tl) for tl, _, _ in windows_spec]
                      for _ in range(3)])


def test_ro_stack_property_counterexample():
    """LRU-with-invalidation loses the Mattson stack property once an
    eviction occurs (why batch RO needs the token loop, not a distance
    oracle): after r(a) r(b) r(c) w(b) w(c), block a was evicted at r(c),
    so r(a) must miss even though zero live blocks separate it from its
    reuse."""
    addrs = np.array([0, 1, 2, 1, 2, 0], dtype=np.int64)
    reads = np.array([True, True, True, False, False, True])
    t = Trace(addrs, reads)
    rs = EngineDiff([2], [WritePolicy.RO]).run_window([t])
    assert rs[0].read_hits == 0


@settings(max_examples=examples(80), deadline=None)
@given(trace_strategy(max_n=80, max_addr=6), st.integers(1, 4))
def test_ro_token_replay_under_pressure(trace_list, cap):
    """Small capacity + few addresses forces the eviction-token path."""
    EngineDiff([cap], [WritePolicy.RO],
               flush=10.0).run_window([mk_trace(trace_list)])


def test_edge_cases_empty_and_zero_capacity(engine_diff):
    empty = Trace(np.zeros(0, np.int64), np.zeros(0, bool))
    for pol in POLICIES:
        r = simulate_batch(empty, 4, pol)
        assert r.n == 0 and r.capacity == 4
        t = Trace(np.array([1, 2, 1], np.int64),
                  np.array([True, False, True]))
        engine_diff([0], [pol]).run_window([t])


# ---------------------------------------------- fast RD engine invariants
@settings(max_examples=examples(100), deadline=None)
@given(trace_strategy())
def test_fast_rd_matches_fenwick_and_vectorized(trace_list):
    t = mk_trace(trace_list)
    for kind in ("trd", "urd"):
        fen = reuse_distances(t, kind).distances
        fast = reuse_distances_fast(t, kind).distances
        vec = reuse_distances_vectorized(t, kind, tile=16).distances
        assert np.array_equal(fen, fast), kind
        assert np.array_equal(fen, vec), kind


@settings(max_examples=examples(100), deadline=None)
@given(trace_strategy())
def test_fast_rd_paper_invariants(trace_list):
    """Paper Eq. 1 (URD samples ⊆ TRD samples) and Fig. 5 sizing
    (urd_cache_blocks == max URD + 1) on the fast engine."""
    t = mk_trace(trace_list)
    trd = reuse_distances_fast(t, "trd")
    urd = reuse_distances_fast(t, "urd")
    mask = urd.distances >= 0
    assert np.all(trd.distances[mask] == urd.distances[mask])
    assert max_rd(urd) <= max_rd(trd)
    m = max_rd(urd)
    assert urd_cache_blocks(urd) == (m + 1 if m >= 0 else 0)


def test_accel_ref_matches_host_engine():
    from repro.kernels.cache_sim.ops import stack_distances_accel
    from repro.core.trace import prev_next_occurrence
    rng = np.random.default_rng(3)
    addrs = rng.integers(0, 60, 500).astype(np.int64)
    t = Trace(addrs, rng.random(500) < 0.6)
    prev, nxt = prev_next_occurrence(addrs)
    host = stack_distances(t, backend="host")
    accel = stack_distances_accel(prev, nxt, use_kernel=False)
    assert np.array_equal(host, accel)


# --------------------------------------------------- manager end-to-end
def test_manager_batch_equals_lru_engine():
    """Whole Monitor→Analyzer→Actuator runs must be identical under both
    engines: per-tenant stats, latencies, decisions, policies and the
    exact final LRU states."""
    from repro.data.traces import msr_trace
    names = ["wdev_0", "hm_1", "prn_1", "web_0", "prxy_0"]
    for scheme in ("eci", "centaur"):
        mgrs = {}
        for engine in ("batch", "lru"):
            mgr = make_manager(scheme, 900, names, c_min=20,
                               initial_blocks=50, t_fast=1.0, t_slow=20.0,
                               flush_cost=10.0, engine=engine)
            for w in range(3):
                traces = [msr_trace(nm, 700, seed=97 * w + i)
                          for i, nm in enumerate(names)]
                mgr.run_window(traces)
            mgrs[engine] = mgr
        mb, ml = mgrs["batch"], mgrs["lru"]
        for tb, tl in zip(mb.tenants, ml.tenants):
            assert_results_equal(tl.result, tb.result)
            assert tb.policy is tl.policy
            assert tb.cache.capacity == tl.cache.capacity
            assert list(tb.cache._od.items()) == list(tl.cache._od.items())
        for db, dl in zip(mb.history, ml.history):
            assert np.array_equal(db.sizes, dl.sizes)
            assert db.policies == dl.policies


def test_manager_batch_handles_retired_tenants():
    from repro.data.traces import msr_trace
    mgr = make_manager("eci", 500, ["a", "b"], c_min=8, initial_blocks=16,
                       engine="batch")
    tr = msr_trace("wdev_0", 300, seed=0)
    mgr.run_window([tr, tr])
    mgr.run_window([tr, None])
    assert not mgr.tenants[1].active
    assert mgr.tenants[1].cache.capacity == 0
    mgr.run_window([tr, None])
    assert mgr.tenants[0].result.n == 900


def test_simulate_many_matches_simulate_batch():
    """simulate_batch is the 1-tenant view of simulate_many (same path)."""
    rng = np.random.default_rng(9)
    t = Trace(rng.integers(0, 12, 200).astype(np.int64),
              rng.random(200) < 0.5)
    for pol in POLICIES:
        r1 = simulate_batch(t, 5, pol, flush_cost=10.0)
        r2 = simulate_many([t], [5], [pol], flush_cost=10.0)[0]
        assert_results_equal(r1, r2)
