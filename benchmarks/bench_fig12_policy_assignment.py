"""Fig. 12/13 + §6.4 — request-type mixes and per-window policy assignment.

Emits each workload's CR/CW/RAR/RAW/WAR/WAW mix (Fig. 12), the policy
ECI-Cache assigns per window at wThreshold=0.5 (Fig. 13), and the
wThreshold sweep 0.2–0.9 the paper describes.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import request_type_mix, write_ratio
from repro.core.write_policy import assign_write_policy
from repro.data.traces import msr_trace

from benchmarks.common import MSR_NAMES, emit


def main() -> dict:
    mixes, policies = {}, {}
    t0 = time.perf_counter()
    for name in MSR_NAMES:
        t = msr_trace(name, 4000, seed=12)
        mix = request_type_mix(t)
        mixes[name] = mix
        emit(f"fig12_{name}", 0.0,
             "|".join(f"{k}:{v:.2f}" for k, v in mix.items()))
        per_window = []
        for w in range(4):
            tw = msr_trace(name, 2000, seed=100 + w)
            per_window.append(assign_write_policy(tw, 0.5).value)
        policies[name] = per_window
        emit(f"fig13_{name}", 0.0, "|".join(per_window))
    dt = (time.perf_counter() - t0) / (len(MSR_NAMES) * 12000) * 1e6
    emit("fig12_per_access_us", dt, "classification+URD-mix")

    # wThreshold sweep: count of RO tenants per threshold
    sweep = {}
    for thr in (0.2, 0.35, 0.5, 0.65, 0.8, 0.9):
        ro = sum(assign_write_policy(msr_trace(n, 2000, seed=7), thr)
                 .value == "ro" for n in MSR_NAMES)
        sweep[thr] = ro
        emit(f"fig13_sweep_thr{thr}", 0.0, f"ro_tenants={ro}/16")
    # monotone: higher threshold -> fewer RO tenants
    vals = list(sweep.values())
    ok = all(a >= b for a, b in zip(vals, vals[1:]))
    emit("fig13_check_threshold_monotone", 0.0, ok)

    # paper's specific observations
    checks = {
        "hm_1_stays_wb": policies["hm_1"][-1] == "wb",
        "wdev_0_goes_ro": policies["wdev_0"][-1] == "ro",
        "prxy_0_goes_ro": policies["prxy_0"][-1] == "ro",
        "hm_1_rar_dominant": mixes["hm_1"]["RAR"] > 0.8,
        "wdev_0_waw_dominant": mixes["wdev_0"]["WAW"] > 0.5,
    }
    emit("fig13_checks", 0.0, ";".join(f"{k}={v}" for k, v in checks.items()))
    return {"mixes": mixes, "policies": policies, "sweep": sweep,
            "checks": checks}


if __name__ == "__main__":
    main()
