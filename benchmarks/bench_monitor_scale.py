"""Thousand-tenant control plane — analyze+partition wall time at scale.

Measures one Δt decision (Monitor reuse distances → hit-ratio curves →
Alg.-3 write ratios → Eq.-2 partition) for tenant counts {16, 128, 1024}
on synthetic mixes, four ways:

  * ``seed``    — the pre-fusion control plane: a Python loop per tenant
    (``reuse_distances_fast`` + ``build_hit_ratio_function`` +
    ``write_ratio``) and the heap breakpoint walk (``method="heap"``) —
    exactly what ``ECICacheManager.analyze`` did per window when no batch
    replay supplied precomputed distances (the serving-style deployment).
  * ``fused``   — ``analyze_windows`` exact (one counting pass, batched
    curves/ratios) + the vectorized ``greedy_allocate`` fast walk.
    Allocations must be **bit-identical** to seed.
  * ``device``  — ``DeviceWindowPipeline``: the whole decision as one
    jitted device program (``core.device_pipeline``), one host sync per
    window.  Timed after a warm-up decision so jit compilation stays out
    of the row; the profiled warm-up asserts the ≤1-sync property under
    ``transfer_sanitize=True`` (jax.transfer_guard — a hidden sync
    raises; the one permitted sync is the explicit decision fetch), and
    ``--profile`` reports the per-stage breakdown (count/curve/
    write_ratio/partition, via staged fenced launches) next to the host
    pipeline's stage times.
  * ``sharded`` — ``DeviceWindowPipeline(mesh=...)``: the same window
    program partitioned over the full local ``("shards",)`` mesh
    (``core.shard_pipeline``) — the warm-up runs under the transfer
    guard and asserts the ≤1 host sync per window *per mesh* contract.
  * ``sampled`` — ``analyze_windows`` with SHARDS ``sample_rate="auto"``
    + the fast walk: the thousand-tenant default.

Full mode adds the ≥65k-tenant frontier row: sampled-only (the
per-tenant seed loop would dominate the nightly budget at that scale),
SHARDS-tuned down to ~64 samples per tenant, host-fused vs sharded-mesh
decisions — the scale target of the ROADMAP sharding item.

Checks: fused ≡ seed allocations at every scale; device ≡ fused and
sharded ≡ fused allocations (bit-identical off TPU; aggregate-latency
tolerance on TPU f32); ``device_syncs_le_1`` plus
``device_guard_enforced`` (the same property under the transfer guard)
and ``sharded_syncs_le_mesh`` (≤1 sync per window per mesh); sampled
allocations within 5% aggregate latency of exact both on the synthetic
mixes and on the Table-3 workloads (prxy_0/prn_1/hm_1/web_1, default
auto-tuner); ≥50× seed→sampled speedup at 1024 tenants (full mode
only); the segment-aligned-padding gate — the **exact fused path must
beat the per-tenant loop outright**: ``speedup_fused >= 2.0`` at the
largest tenant count of the run; and, on accelerator hosts, the
device-pipeline gate ``speedup_device >= 1.5`` over the fused host path
and the mesh gate ``speedup_sharded >= 1.2`` over the single-device
program (both vacuous on CPU, where every pipeline shares the same
cores).  All engine timings are best-of-reps (single-shot timings
flaked the 2.0 fused gate on noisy boxes).  Results are written to
``BENCH_monitor_scale.json``.

``--smoke`` (the CI configuration) runs the 16-tenant point only with a
short window — fast, and still fails on any control-plane hot-path
regression, *including* the fused-speedup, device and sharded gates.
"""
from __future__ import annotations

import argparse
import json
import os
import time

# the sharded rows need a real multi-device mesh on CPU hosts; must be
# set before jax initializes (harmless on accelerator hosts, where the
# flag only affects the unused host platform)
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import numpy as np

from repro.core import (DeviceWindowPipeline, StageProfile, Trace,
                        aggregate_latency, analyze_windows,
                        build_hit_ratio_function, greedy_allocate,
                        reuse_distances_fast, urd_cache_blocks)
from repro.core.batch_sim import _accel_default
from repro.core.write_policy import write_ratio
from repro.data.traces import msr_trace

from benchmarks.common import emit

TABLE3_NAMES = ("prxy_0", "prn_1", "hm_1", "web_1")
SIM = dict(t_fast=1.0, t_slow=20.0)


def synthetic_mix(n_tenants: int, n: int, seed: int = 0) -> list[Trace]:
    """Fast vectorized zipf-ish mixes (trace realism is irrelevant to the
    control-plane cost; the Table-3 check below uses the MSR profiles)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_tenants):
        ws = int(rng.integers(300, 3000))
        u = rng.random(n)
        addrs = np.minimum((u ** 2.2) * ws, ws - 1).astype(np.int64)
        is_read = rng.random(n) < float(rng.uniform(0.4, 0.9))
        out.append(Trace(addrs, is_read, f"mix{i}"))
    return out


def seed_path(traces, capacity, c_min):
    """The pre-fusion per-tenant Analyzer loop + heap partitioner."""
    hs = []
    for tr in traces:
        rd = reuse_distances_fast(tr, "urd")
        hs.append(build_hit_ratio_function(rd))
        urd_cache_blocks(rd)
        write_ratio(tr)
    part = greedy_allocate(hs, capacity, SIM["t_fast"], SIM["t_slow"],
                           c_min=c_min, method="heap")
    return part, hs


def fused_path(traces, capacity, c_min, sample_rate=None, target=256,
               floor=64):
    mon = analyze_windows(traces, "urd", sample_rate=sample_rate,
                          sample_target=target, sample_floor=floor)
    part = greedy_allocate(mon.curves, capacity, SIM["t_fast"],
                           SIM["t_slow"], c_min=c_min, method="fast")
    return part, mon


def device_path(traces, capacity, c_min, profile=None,
                transfer_sanitize=False):
    pipe = DeviceWindowPipeline(capacity=capacity, c_min=c_min,
                                t_fast=SIM["t_fast"], t_slow=SIM["t_slow"],
                                transfer_sanitize=transfer_sanitize)
    return pipe.run(traces, profile=profile)


def sharded_path(traces, capacity, c_min, mesh, profile=None,
                 transfer_sanitize=False):
    pipe = DeviceWindowPipeline(capacity=capacity, c_min=c_min,
                                t_fast=SIM["t_fast"], t_slow=SIM["t_slow"],
                                transfer_sanitize=transfer_sanitize,
                                mesh=mesh)
    return pipe.run(traces, profile=profile)


def run_scale(n_tenants: int, n: int, c_min: int = 50,
              reps: int = 3, engine_reps: int = 2,
              profile: bool = False) -> dict:
    traces = synthetic_mix(n_tenants, n, seed=7)
    # capacity between Σc_min and ΣURD so the partitioner actually walks
    urd_total = sum(h.max_useful_size
                    for h in analyze_windows(traces, "urd").curves)
    capacity = max(n_tenants * c_min + 1, int(0.35 * urd_total))

    # every engine timing is best-of-reps: single-shot full-mode runs
    # flaked the 2.0 fused-speedup gate on noisy boxes (a one-off 1.62x
    # reading at 1024 tenants), and the smoke configuration's
    # millisecond-scale runs need it even more
    seed_s = fused_s = float("inf")
    for _ in range(engine_reps):
        t0 = time.perf_counter()
        p_seed, hs_exact = seed_path(traces, capacity, c_min)
        seed_s = min(seed_s, time.perf_counter() - t0)

    for _ in range(engine_reps):
        t0 = time.perf_counter()
        p_fused, _ = fused_path(traces, capacity, c_min)
        fused_s = min(fused_s, time.perf_counter() - t0)

    # device pipeline: one warm-up decision compiles the window program
    # and proves the <=1-sync property two ways at once — the profiled
    # counter reports the sync count, and transfer_sanitize=True runs the
    # window under jax.transfer_guard("disallow") so any hidden sync
    # beyond the explicit decision fetch would raise here, not just
    # inflate the counter.  Timed runs below use the default (off) path.
    sprof = StageProfile()
    dec = device_path(traces, capacity, c_min, profile=sprof,
                      transfer_sanitize=True)
    device_syncs = sprof.syncs_per_window
    device_s = float("inf")
    for _ in range(max(engine_reps, 2)):
        t0 = time.perf_counter()
        dec = device_path(traces, capacity, c_min)
        device_s = min(device_s, time.perf_counter() - t0)
    lat_fused = aggregate_latency(hs_exact, p_fused.sizes, **SIM)
    lat_dev = aggregate_latency(hs_exact, dec.sizes, **SIM)
    device_identical = bool(np.array_equal(dec.sizes, p_fused.sizes))
    # documented TPU f32 tolerance: tie-flips only, compare by objective
    device_ok = (device_identical if not _accel_default()
                 else lat_dev <= lat_fused * 1.001)

    # sharded pipeline: the same decision under shard_map over the full
    # local mesh.  Warm-up under the transfer guard proves the <=1 host
    # sync per window per mesh contract (any hidden broadcast or fetch
    # beyond the explicit decision pull raises); timed runs use the
    # default path
    from repro.distributed.sharding import control_plane_mesh
    mesh = control_plane_mesh()
    shprof = StageProfile()
    sdec = sharded_path(traces, capacity, c_min, mesh, profile=shprof,
                        transfer_sanitize=True)
    sharded_syncs = shprof.syncs_per_window
    sharded_s = float("inf")
    for _ in range(max(engine_reps, 2)):
        t0 = time.perf_counter()
        sdec = sharded_path(traces, capacity, c_min, mesh)
        sharded_s = min(sharded_s, time.perf_counter() - t0)
    sharded_identical = bool(np.array_equal(sdec.sizes, p_fused.sizes))
    lat_sh = aggregate_latency(hs_exact, sdec.sizes, **SIM)
    sharded_ok = (sharded_identical if not _accel_default()
                  else lat_sh <= lat_fused * 1.001)

    # the sampled decision runs in milliseconds: always take best-of-reps
    sampled_s = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        p_smp, mon_smp = fused_path(traces, capacity, c_min,
                                    sample_rate="auto")
        sampled_s = min(sampled_s, time.perf_counter() - t0)

    lat_exact = aggregate_latency(hs_exact, p_seed.sizes, **SIM)
    lat_smp = aggregate_latency(hs_exact, p_smp.sizes, **SIM)
    row = {
        "tenants": n_tenants, "n_per_window": n, "capacity": capacity,
        "seed_s": seed_s, "fused_s": fused_s, "device_s": device_s,
        "sharded_s": sharded_s, "sampled_s": sampled_s,
        "speedup_fused": seed_s / max(fused_s, 1e-12),
        "speedup_device": fused_s / max(device_s, 1e-12),
        "speedup_sharded": device_s / max(sharded_s, 1e-12),
        "speedup_sampled": seed_s / max(sampled_s, 1e-12),
        "fused_bit_identical": bool(np.array_equal(p_seed.sizes,
                                                   p_fused.sizes)),
        "device_bit_identical": device_identical,
        "device_decision_ok": device_ok,
        "device_syncs_per_window": device_syncs,
        # the profiled warm-up above completed under the transfer guard:
        # zero hidden syncs, one explicit fetch — enforced, not counted
        "device_guard_enforced": True,
        "sharded_bit_identical": sharded_identical,
        "sharded_decision_ok": sharded_ok,
        "sharded_syncs_per_window": sharded_syncs,
        "n_shards": int(np.asarray(mesh.devices).size),
        "sampled_latency_ratio": lat_smp / max(lat_exact, 1e-12),
        "mean_expected_error": float(mon_smp.expected_errors.mean()),
    }
    if profile:
        # per-stage wall time: host pipeline stages (links/count/curve,
        # plus the accel route's per-width sync count) next to the device
        # program's fenced staged breakdown
        hprof = StageProfile()
        fused_path_mon = analyze_windows(traces, "urd", profile=hprof)
        greedy_allocate(fused_path_mon.curves, capacity, SIM["t_fast"],
                        SIM["t_slow"], c_min=c_min, method="fast")
        device_path(traces, capacity, c_min,
                    profile=StageProfile(staged=True))  # compile staged jits
        dprof = StageProfile(staged=True)
        # staged fences are block_until_ready calls, not transfers: the
        # guard holds through the per-stage breakdown too
        device_path(traces, capacity, c_min, profile=dprof,
                    transfer_sanitize=True)
        row["profile"] = {"host": hprof.report(),
                          "device_staged": dprof.report()}
        for side in ("host", "device_staged"):
            for st_name, st_s in row["profile"][side]["times_s"].items():
                emit(f"monitor_scale_T{n_tenants}_{side}_{st_name}",
                     st_s * 1e6, f"{st_s * 1e3:.1f}ms")
    emit(f"monitor_scale_T{n_tenants}_seed", seed_s * 1e6, f"{seed_s:.3f}s")
    emit(f"monitor_scale_T{n_tenants}_fused", fused_s * 1e6,
         f"speedup={row['speedup_fused']:.1f}x_identical="
         f"{row['fused_bit_identical']}")
    emit(f"monitor_scale_T{n_tenants}_device", device_s * 1e6,
         f"speedup_vs_fused={row['speedup_device']:.2f}x_identical="
         f"{device_identical}_syncs={device_syncs:.0f}")
    emit(f"monitor_scale_T{n_tenants}_sharded", sharded_s * 1e6,
         f"speedup_vs_device={row['speedup_sharded']:.2f}x_identical="
         f"{sharded_identical}_shards={row['n_shards']}"
         f"_syncs={sharded_syncs:.0f}")
    emit(f"monitor_scale_T{n_tenants}_sampled", sampled_s * 1e6,
         f"speedup={row['speedup_sampled']:.1f}x_lat_ratio="
         f"{row['sampled_latency_ratio']:.4f}")
    return row


def frontier_row(n_tenants: int = 65536, n: int = 400, c_min: int = 2,
                 reps: int = 2) -> dict:
    """The ≥65k-tenant frontier: sampled-only (the per-tenant seed loop
    would dominate the nightly budget at this scale), SHARDS auto-tuned
    down to ~64 samples per tenant.  Times the host-fused sampled
    decision and the sharded-mesh sampled monitor at the same salts, and
    checks the mesh reproduces the host's integer outputs exactly (URD
    sizes — exact at any float width) plus the full allocation off TPU.
    """
    traces = synthetic_mix(n_tenants, n, seed=9)
    capacity = n_tenants * (c_min + 20)
    sampled_s = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        p_smp, mon = fused_path(traces, capacity, c_min,
                                sample_rate="auto", target=64, floor=16)
        sampled_s = min(sampled_s, time.perf_counter() - t0)
    sharded_s = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        mon_sh = analyze_windows(traces, "urd", sample_rate="auto",
                                 sample_target=64, sample_floor=16,
                                 pipeline="sharded")
        sharded_s = min(sharded_s, time.perf_counter() - t0)
    p_sh = greedy_allocate(mon_sh.curves, capacity, SIM["t_fast"],
                           SIM["t_slow"], c_min=c_min, method="fast")
    identical = bool(np.array_equal(mon_sh.urd_sizes, mon.urd_sizes))
    if not _accel_default():
        identical = identical and bool(np.array_equal(p_sh.sizes,
                                                      p_smp.sizes))
    row = {
        "tenants": n_tenants, "n_per_window": n, "capacity": capacity,
        "sampled_only": True, "sampled_s": sampled_s,
        "sharded_monitor_s": sharded_s,
        "sharded_bit_identical": identical,
        "mean_sample_rate": float(mon.sample_rates.mean()),
        "mean_expected_error": float(mon.expected_errors.mean()),
    }
    emit(f"monitor_scale_T{n_tenants}_sampled_frontier", sampled_s * 1e6,
         f"rate={row['mean_sample_rate']:.3f}_sharded_identical="
         f"{identical}")
    return row


def table3_decision_check(n: int = 8000, target: int = 4096) -> dict:
    """Sampled vs exact *decisions* on the Table-3 workloads: the sampled
    allocation must cost within 5% aggregate latency of the exact one
    (evaluated on the exact curves).  ``target`` must keep the auto-tuner
    rate below 1 for the window length, or the check is vacuous."""
    traces = [msr_trace(nm, n, seed=3) for nm in TABLE3_NAMES]
    mon = analyze_windows(traces, "urd")
    urd_total = int(mon.curves.max_useful_sizes.sum())
    capacity = max(1, urd_total // 2)
    p_exact = greedy_allocate(mon.curves, capacity, SIM["t_fast"],
                              SIM["t_slow"], c_min=50)
    mon_s = analyze_windows(traces, "urd", sample_rate="auto",
                            sample_target=target, sample_floor=64)
    p_smp = greedy_allocate(mon_s.curves, capacity, SIM["t_fast"],
                            SIM["t_slow"], c_min=50)
    lat_exact = aggregate_latency(mon.curves, p_exact.sizes, **SIM)
    lat_smp = aggregate_latency(mon.curves, p_smp.sizes, **SIM)
    ratio = lat_smp / max(lat_exact, 1e-12)
    emit("monitor_scale_table3_sampled_vs_exact", 0.0,
         f"lat_ratio={ratio:.4f}_rates="
         + "|".join(f"{r:.2f}" for r in mon_s.sample_rates))
    return {"latency_ratio": ratio, "within_5pct": bool(ratio <= 1.05)}


def main(tenant_counts=(16, 128, 1024), n_per_window: int = 8000,
         smoke: bool = False, profile: bool = False) -> dict:
    _accel_default()          # warm the jax backend probe outside timings
    engine_reps = 2
    if smoke:
        tenant_counts, n_per_window, engine_reps = (16,), 2000, 3
    rows = [run_scale(t, n_per_window, engine_reps=engine_reps,
                      profile=profile)
            for t in tenant_counts]
    # full mode appends the >=65k-tenant sampled frontier row (skipped in
    # smoke: the CI tier-1 budget is seconds, the frontier is minutes)
    if not smoke:
        rows.append(frontier_row())
    full = [r for r in rows if not r.get("sampled_only")]
    # smoke shrinks the tuner target so the sampled path is actually
    # exercised (rate < 1) on the short CI windows
    t3 = (table3_decision_check(2000, target=512) if smoke
          else table3_decision_check(8000))
    # the padding gate: the exact fused pass must beat the per-tenant
    # loop outright at the largest scale of the run (2x, not just parity)
    big = max(full, key=lambda r: r["tenants"])
    checks = {
        "fused_bit_identical_all": all(r["fused_bit_identical"]
                                       for r in full),
        "device_bit_identical_all": all(r["device_decision_ok"]
                                        for r in full),
        "device_syncs_le_1": all(r["device_syncs_per_window"] <= 1.0
                                 for r in full),
        "device_guard_enforced": all(r["device_guard_enforced"]
                                     for r in full),
        # the mesh is a pure optimization at every scale (the frontier
        # row's sampled sharded monitor included) ...
        "sharded_bit_identical": all(r["sharded_decision_ok"]
                                     if not r.get("sampled_only")
                                     else r["sharded_bit_identical"]
                                     for r in rows),
        # ... and pays at most one host sync per window per mesh
        "sharded_syncs_le_mesh": all(r["sharded_syncs_per_window"] <= 1.0
                                     for r in full),
        "sampled_within_5pct_mix": all(r["sampled_latency_ratio"] <= 1.05
                                       for r in full),
        "table3_sampled_within_5pct": t3["within_5pct"],
        "fused_speedup_ge": big["speedup_fused"] >= 2.0,
        # the device program's win over the fused host path — and the
        # mesh's win over the single-device program — are accelerator
        # properties (off TPU every pipeline shares the same CPU cores);
        # the gates arm only on accelerator hosts, the rows are always
        # reported
        "speedup_device_ge": (big["speedup_device"] >= 1.5
                              if _accel_default() else True),
        "speedup_sharded_ge": (big["speedup_sharded"] >= 1.2
                               if _accel_default() else True),
    }
    if 1024 in tenant_counts:
        big = next(r for r in full if r["tenants"] == 1024)
        checks["speedup_1024_ge_50x"] = big["speedup_sampled"] >= 50.0
    if not smoke:
        checks["sampled_65k_row"] = any(r.get("sampled_only")
                                        and r["tenants"] >= 65536
                                        for r in rows)
    out = {"rows": rows, "table3": t3,
           "checks": checks, "fused_speedup_gate": 2.0,
           "device_speedup_gate": 1.5, "sharded_speedup_gate": 1.2}
    with open("BENCH_monitor_scale.json", "w") as f:
        json.dump(out, f, indent=2)
    for k, v in checks.items():
        emit(f"monitor_scale_check_{k}", 0.0, v)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI configuration: 16 tenants, short windows; "
                         "equality/latency checks plus the fused-speedup "
                         "and device gates (best-of-reps wall clock)")
    ap.add_argument("--profile", action="store_true",
                    help="attach per-stage wall times (host pipeline "
                         "stages and the device program's fenced staged "
                         "breakdown) to every row")
    ap.add_argument("--tenants", type=str, default=None,
                    help="comma-separated tenant counts (default 16,128,1024)")
    args = ap.parse_args()
    counts = (tuple(int(x) for x in args.tenants.split(","))
              if args.tenants else (16, 128, 1024))
    result = main(counts, smoke=args.smoke, profile=args.profile)
    if not all(result["checks"].values()):
        raise SystemExit(f"CHECK FAILED: {result['checks']}")
