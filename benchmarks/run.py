"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and a final PASS/FAIL summary
of the per-benchmark reproduction checks.  See EXPERIMENTS.md for the
interpretation against the paper's published numbers.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import common
from benchmarks import (bench_appendixA_feasible, bench_etica_two_level,
                        bench_faults, bench_fig04_write_policy,
                        bench_fig10_allocation,
                        bench_fig12_policy_assignment,
                        bench_fig14_perf_per_cost, bench_fig16_endurance,
                        bench_monitor_scale, bench_scenarios,
                        bench_serving_cache, bench_table3_urd_overhead)

BENCHES = [
    ("fig04_write_policy", bench_fig04_write_policy),
    ("fig10_allocation", bench_fig10_allocation),
    ("fig12_policy_assignment", bench_fig12_policy_assignment),
    ("fig14_perf_per_cost", bench_fig14_perf_per_cost),
    ("fig16_endurance", bench_fig16_endurance),
    ("table3_urd_overhead", bench_table3_urd_overhead),
    ("appendixA_feasible", bench_appendixA_feasible),
    ("etica_two_level", bench_etica_two_level),
    ("serving_cache", bench_serving_cache),
    ("monitor_scale", bench_monitor_scale),
    ("scenarios", bench_scenarios),
    ("faults", bench_faults),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", choices=("batch", "lru"), default="batch",
                    help="window-replay engine for the trace-driven "
                         "benchmarks (batch = vectorized, lru = per-access "
                         "interpreter; results are identical)")
    args = ap.parse_args()
    common.DEFAULT_ENGINE = args.engine
    print("name,us_per_call,derived")
    failures = []
    all_checks: dict[str, bool] = {}
    for name, mod in BENCHES:
        t0 = time.time()
        try:
            out = mod.main()
            checks = (out or {}).get("checks", {})
            for k, v in checks.items():
                all_checks[f"{name}:{k}"] = bool(v)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
        print(f"bench_{name}_wall,{(time.time() - t0) * 1e6:.0f},done")
    print()
    n_pass = sum(all_checks.values())
    for k, v in sorted(all_checks.items()):
        if not v:
            print(f"CHECK-FAIL {k}")
    print(f"reproduction checks: {n_pass}/{len(all_checks)} passed; "
          f"{len(failures)} benchmark errors {failures or ''}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
