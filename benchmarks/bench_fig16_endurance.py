"""Fig. 16 + §6.6 — endurance: writes into the cache, ECI vs Centaur.

Paper: ECI-Cache reduces SSD-committed writes by 65% on average (RO on
unreferenced-write-heavy tenants + smaller URD partitions); per-workload
reductions range 0% (hm_1, pure reads) to ~90% (ts_0/prxy_0).
"""
from __future__ import annotations

from benchmarks.common import MSR_NAMES, emit, run_scheme


def main() -> dict:
    cap = 7000
    eci, secs = run_scheme("eci", cap, windows=5)
    cen, _ = run_scheme("centaur", cap, windows=5)

    per_tenant = {}
    for t_e, t_c in zip(eci.tenants, cen.tenants):
        we, wc = t_e.result.cache_writes, t_c.result.cache_writes
        red = 1 - we / wc if wc else 0.0
        per_tenant[t_e.name] = red
        emit(f"fig16_{t_e.name}", 0.0,
             f"writes_{we}v{wc}_saved={red:+.1%}_policy={t_e.policy.value}")

    tot_e = eci.summary()["cache_writes"]
    tot_c = cen.summary()["cache_writes"]
    total_red = 1 - tot_e / tot_c
    emit("fig16_total_write_reduction", secs / 5 * 1e6, f"{total_red:.1%}")

    checks = {
        "total_reduction_over_40pct": total_red > 0.40,
        "hm_1_unaffected": abs(per_tenant["hm_1"]) < 0.15,
        "write_heavy_tenants_big_savings":
            per_tenant["prxy_0"] > 0.5 and per_tenant["wdev_0"] > 0.5,
    }
    emit("fig16_checks", 0.0, ";".join(f"{k}={v}" for k, v in checks.items()))
    return {"total_reduction": total_red, "per_tenant": per_tenant,
            "checks": checks}


if __name__ == "__main__":
    main()
