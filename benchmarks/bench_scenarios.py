"""Adversarial scenario suite — detection quality + gated isolation.

Two halves, both over the labeled generator in ``repro.data.scenarios``:

  * **Detection** — every scenario is replayed through an event-driven
    ECI manager (``phase_detect=True`` with the interval clock pushed out
    of the run, so *only* detector/churn events cause analyzes).  Detected
    ``"phase"``/``"write_ratio"`` events are matched against the
    scenario's ground-truth ``changes`` matrix: an event counts as a true
    positive when the same tenant has an unmatched labeled change at most
    ``LATENCY_BOUND`` windows earlier.  Micro-averaged precision, recall
    and the worst detection latency are gated (``>= 0.9``, ``>= 0.9``,
    ``<= 2``), along with the point of the exercise: the event-driven
    manager must run *fewer* analyzes than windows.

  * **Isolation** — the ``scan_flood`` scenario is replayed twice per
    scheme: once complete, once with the aggressor excluded
    (*differential replay*: every victim sees bit-identical traces either
    way, so any latency delta is attributable to the aggressor).  The
    isolation metric is the worst per-victim mean-latency degradation

        max_v (lat_with(v) - lat_without(v)) / lat_without(v).

    Static partitioning degrades mechanically — victims hold
    ``capacity/n`` instead of ``capacity/(n-1)`` — while ECI's URD sizing
    prices the scan flood at its (tiny) marginal-gain density and keeps
    the victims near their aggressor-free allocations.  The gate:
    ECI's degradation must be at most ``ISOLATION_GATE`` (0.5) of
    static's.

``--smoke`` (the CI step) runs one seed per scenario; the full run
averages ``N_SEEDS``.  Results land in ``BENCH_scenarios.json`` with the
standard ``checks`` dict.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import make_manager
from repro.data.scenarios import (SCENARIOS, per_tenant_latency,
                                  replay_scenario, scan_flood)

from benchmarks.common import DEFAULT_SIM, emit

LATENCY_BOUND = 2          # windows: worst tolerated detection delay
ISOLATION_GATE = 0.5       # ECI degradation <= 0.5 x static degradation
N_SEEDS = 5
CAPACITY = 8192
C_MIN = 256


def _manager_factory(scheme: str, **kw):
    def factory(names):
        return make_manager(scheme, CAPACITY, names, c_min=C_MIN,
                            initial_blocks=C_MIN, **DEFAULT_SIM, **kw)
    return factory


# ------------------------------------------------------------- detection
def match_events(run, detected, bound: int = LATENCY_BOUND):
    """Greedily match detected (window, tenant) events to labeled changes.

    Returns (true_positives, false_positives, latencies) where a true
    positive pairs an event with an unmatched labeled change of the same
    tenant at most ``bound`` windows earlier.
    """
    truth = run.true_changes()
    matched: dict[tuple[int, int], int] = {}
    used = set()
    for (w, t) in sorted(set(detected)):
        for (tw, tt) in truth:
            if tt == t and (tw, tt) not in matched and 0 <= w - tw <= bound:
                matched[(tw, tt)] = w - tw
                used.add((w, t))
                break
    fp = [e for e in sorted(set(detected)) if e not in used]
    return matched, fp, list(matched.values())


def run_detection(seeds) -> dict:
    """Replay every scenario event-driven; score against the labels."""
    tp = fp = truth_n = 0
    latencies: list[int] = []
    analyzed = windows = 0
    per_scenario = {}
    for name, build in SCENARIOS.items():
        s_tp = s_fp = s_truth = 0
        for seed in seeds:
            run = build(seed=seed)
            mgr, imap = replay_scenario(
                run, _manager_factory("eci", phase_detect=True,
                                      reconfig_interval=10 ** 9))
            inv = {v: k for k, v in imap.items()}
            detected = [(e.window, inv[e.tenant]) for e in mgr.events
                        if e.reason in ("phase", "write_ratio")
                        and e.tenant in inv]
            matched, false_pos, lats = match_events(run, detected)
            s_tp += len(matched)
            s_fp += len(false_pos)
            s_truth += len(run.true_changes())
            latencies.extend(lats)
            analyzed += mgr.windows_analyzed
            windows += mgr.windows_run
        tp += s_tp; fp += s_fp; truth_n += s_truth
        per_scenario[name] = {
            "true_positives": s_tp, "false_positives": s_fp,
            "labeled_changes": s_truth,
        }
        emit(f"scenarios_detect_{name}", 0.0,
             f"tp={s_tp}_fp={s_fp}_truth={s_truth}")
    precision = tp / max(tp + fp, 1)
    recall = tp / max(truth_n, 1)
    max_lat = max(latencies) if latencies else 0
    out = {
        "precision": precision, "recall": recall,
        "max_detection_latency": max_lat,
        "windows_analyzed": analyzed, "windows_run": windows,
        "analyze_fraction": analyzed / max(windows, 1),
        "per_scenario": per_scenario,
    }
    emit("scenarios_detection", 0.0,
         f"precision={precision:.3f}_recall={recall:.3f}_maxlat={max_lat}"
         f"_analyzes={analyzed}/{windows}")
    return out


# ------------------------------------------------------------- isolation
def isolation_degradation(scheme: str, seed: int) -> dict:
    """Worst victim latency degradation attributable to the aggressor."""
    run = scan_flood(seed=seed)
    assert run.aggressor is not None
    mgr_full, imap_full = replay_scenario(run, _manager_factory(scheme))
    mgr_solo, imap_solo = replay_scenario(run, _manager_factory(scheme),
                                          exclude={run.aggressor})
    with_lat = per_tenant_latency(mgr_full, imap_full)
    solo_lat = per_tenant_latency(mgr_solo, imap_solo)
    victims = [t for t in range(run.n_tenants) if t != run.aggressor]
    degr = {t: (with_lat[t] - solo_lat[t]) / max(solo_lat[t], 1e-12)
            for t in victims}
    worst = max(degr.values())
    return {"scheme": scheme, "seed": seed, "degradation": worst,
            "per_victim": {str(t): degr[t] for t in victims}}


def run_isolation(seeds) -> dict:
    rows = []
    for scheme in ("eci", "static"):
        for seed in seeds:
            rows.append(isolation_degradation(scheme, seed))
    mean = {s: float(np.mean([r["degradation"] for r in rows
                              if r["scheme"] == s]))
            for s in ("eci", "static")}
    ratio = mean["eci"] / max(mean["static"], 1e-12)
    for s in ("eci", "static"):
        emit(f"scenarios_isolation_{s}", 0.0, f"degradation={mean[s]:.4f}")
    emit("scenarios_isolation_ratio", 0.0, f"{ratio:.3f}")
    return {"rows": rows, "mean_degradation": mean, "ratio": ratio}


def main(smoke: bool = False) -> dict:
    seeds = (0,) if smoke else tuple(range(N_SEEDS))
    det = run_detection(seeds)
    iso = run_isolation(seeds)
    checks = {
        "detection_precision_ge_090": det["precision"] >= 0.9,
        "detection_recall_ge_090": det["recall"] >= 0.9,
        "detection_latency_le_2": det["max_detection_latency"]
        <= LATENCY_BOUND,
        "event_driven_fewer_analyzes": det["windows_analyzed"]
        < det["windows_run"],
        "isolation_eci_le_half_static": iso["ratio"] <= ISOLATION_GATE,
    }
    out = {"detection": det, "isolation": iso, "checks": checks,
           "latency_bound": LATENCY_BOUND, "isolation_gate": ISOLATION_GATE,
           "seeds": list(seeds)}
    with open("BENCH_scenarios.json", "w") as f:
        json.dump(out, f, indent=2)
    for k, v in checks.items():
        emit(f"scenarios_check_{k}", 0.0, v)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI configuration: one seed per scenario")
    args = ap.parse_args()
    result = main(smoke=args.smoke)
    if not all(result["checks"].values()):
        raise SystemExit(f"CHECK FAILED: {result['checks']}")
