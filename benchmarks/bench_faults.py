"""Chaos benchmark — the degradation ladder under scheduled faults.

Three gated experiments over the fault-injection harness
(``repro.core.faults``), each replayed on a deterministic workload so the
run is bit-reproducible:

  * **Fault scenarios** — every labeled ``FAULT_SCENARIOS`` case
    (tier loss mid-phase, straggler burst during a correlated reconfig,
    a poisoned tenant joining) replays through a fault-tolerant ECI
    manager.  Gates: zero guard-violating decisions actuated anywhere,
    and each scenario leaves its expected fingerprint (dirty loss /
    straggler holds / poisoned-window quarantines).

  * **Reconvergence** — ``FaultPlan.standard`` (one of everything: trace
    poison, launch retries, a forced rung step-down, an L1 loss, a NaN
    curve, a truncated tape) against the identical no-fault run.  The
    faulted manager must issue decisions identical to the fault-free one
    within ``K = reconverge_bound(demote_cooldown) = demote_cooldown + 2``
    windows of the last fault clearing, and dirty loss must be positive
    (the crash really hit WB state) yet bounded by the L1 capacity.

  * **Default-off identity** — a manager carrying a *disabled* plan is
    bit-identical (summary, sizes, policies, per-window decisions) to one
    with no plan: the harness costs nothing when off.

``--smoke`` (the CI step) runs one seed; the full run sweeps ``N_SEEDS``.
The nightly job re-runs the hypothesis chaos suite at 10x depth via
``HYP_EXAMPLES_SCALE=10`` (see ``tests/test_faults.py``); this benchmark
gates the deterministic half.  Results land in ``BENCH_faults.json``.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import ECICacheManager, FaultPlan, Trace
from repro.data.scenarios import FAULT_SCENARIOS, replay_scenario

from benchmarks.common import DEFAULT_SIM, emit

CAPACITY = 8192
C_MIN = 256
DEMOTE_COOLDOWN = 2
N_SEEDS = 3
N_TENANTS = 4          # reconvergence experiment fleet
N_WINDOWS_MIN = 8      # FaultPlan.standard needs >= 8


def _mgr(names, faults=None, **kw):
    return ECICacheManager(CAPACITY, list(names), c_min=C_MIN,
                           initial_blocks=C_MIN, faults=faults,
                           demote_cooldown=DEMOTE_COOLDOWN,
                           **DEFAULT_SIM, **kw)


def _trace(seed: int, window: int, tenant: int, n: int = 2500) -> Trace:
    rng = np.random.default_rng(
        (seed * 1_000_003 + window * 8_191 + tenant * 131) & 0x7FFFFFFF)
    return Trace(rng.integers(0, 2048, n), rng.random(n) < 0.55,
                 f"t{tenant}")


def _decisions_equal(da, db) -> bool:
    return (np.array_equal(da.sizes, db.sizes) and da.policies == db.policies
            and np.array_equal(da.sizes2, db.sizes2))


# -------------------------------------------------------- fault scenarios
EXPECTED_FINGERPRINT = {
    # scenario -> summary counter that must be > 0 after the replay
    "faulted_tier_loss": "dirty_loss",
    "faulted_straggler_burst": "straggler_windows",
    "faulted_poisoned_join": "poisoned_windows",
}


def run_fault_scenarios(seeds) -> dict:
    rows = []
    for name, build in FAULT_SCENARIOS.items():
        for seed in seeds:
            fs = build(seed=seed)

            def factory(names, plan=fs.plan):
                return _mgr(names, faults=plan)

            mgr, _ = replay_scenario(fs.run, factory)
            s = mgr.summary()
            rows.append({
                "scenario": name, "seed": seed,
                "guard_violations_actuated": s["guard_violations_actuated"],
                "degrade_events": s["degrade_events"],
                "fingerprint": EXPECTED_FINGERPRINT[name],
                "fingerprint_value": s[EXPECTED_FINGERPRINT[name]],
                "dirty_loss": s["dirty_loss"],
                "lkg_decisions": s["lkg_decisions"],
            })
        vals = [r for r in rows if r["scenario"] == name]
        emit(f"faults_scenario_{name}", 0.0,
             f"actuated={sum(r['guard_violations_actuated'] for r in vals)}"
             f"_events={sum(r['degrade_events'] for r in vals)}")
    return {
        "rows": rows,
        "actuated_total": sum(r["guard_violations_actuated"] for r in rows),
        "fingerprints_present": all(r["fingerprint_value"] > 0
                                    for r in rows),
    }


# --------------------------------------------------------- reconvergence
def reconvergence_case(seed: int) -> dict:
    plan = FaultPlan.standard(N_TENANTS, N_WINDOWS_MIN, seed=seed)
    k = plan.reconverge_bound(DEMOTE_COOLDOWN)
    last = plan.last_fault_window()
    n_windows = last + k + 2                  # room to observe convergence
    names = [f"t{i}" for i in range(N_TENANTS)]
    base = _mgr(names)
    faulted = _mgr(names, faults=plan)
    for mgr in (base, faulted):
        for w in range(n_windows):
            mgr.run_window([_trace(seed, w, t) for t in range(N_TENANTS)])
    # recovery = first window from which every later decision matches
    recovered_at = n_windows
    for w in range(n_windows - 1, -1, -1):
        if not _decisions_equal(base.history[w], faulted.history[w]):
            break
        recovered_at = w
    s = faulted.summary()
    return {
        "seed": seed, "last_fault_window": last, "k": k,
        "recovered_at": recovered_at,
        "recovery_windows": max(recovered_at - last, 0),
        "dirty_loss": s["dirty_loss"],
        "guard_violations_actuated": s["guard_violations_actuated"],
        "degrade_events": s["degrade_events"],
    }


def run_reconvergence(seeds) -> dict:
    rows = [reconvergence_case(seed) for seed in seeds]
    worst = max(r["recovery_windows"] for r in rows)
    emit("faults_reconvergence", 0.0,
         f"worst_recovery={worst}_k={rows[0]['k']}")
    return {
        "rows": rows,
        "worst_recovery_windows": worst,
        "k": rows[0]["k"],
        "dirty_loss_min": min(r["dirty_loss"] for r in rows),
        "dirty_loss_max": max(r["dirty_loss"] for r in rows),
        "actuated_total": sum(r["guard_violations_actuated"] for r in rows),
    }


# ---------------------------------------------------- default-off identity
def run_disabled_identity(seed: int) -> dict:
    names = [f"t{i}" for i in range(N_TENANTS)]
    plain = _mgr(names)
    disabled = _mgr(names, faults=FaultPlan((), seed=seed))
    for mgr in (plain, disabled):
        for w in range(N_WINDOWS_MIN):
            mgr.run_window([_trace(seed, w, t) for t in range(N_TENANTS)])
    sa, sb = plain.summary(), disabled.summary()
    identical = (set(sa) == set(sb)
                 and all(np.array_equal(sa[k], sb[k]) for k in sa)
                 and all(_decisions_equal(da, db) for da, db
                         in zip(plain.history, disabled.history)))
    emit("faults_disabled_identity", 0.0, identical)
    return {"identical": identical, "seed": seed}


def main(smoke: bool = False) -> dict:
    seeds = (0,) if smoke else tuple(range(N_SEEDS))
    scen = run_fault_scenarios(seeds)
    recon = run_reconvergence(seeds)
    ident = run_disabled_identity(seeds[0])
    checks = {
        "no_guard_violations_actuated":
            scen["actuated_total"] == 0 and recon["actuated_total"] == 0,
        "scenario_fingerprints_present": scen["fingerprints_present"],
        "dirty_loss_positive_and_bounded":
            0 < recon["dirty_loss_min"]
            and recon["dirty_loss_max"] <= CAPACITY,
        "recovery_within_k":
            recon["worst_recovery_windows"] <= recon["k"],
        "disabled_plan_bit_identical": ident["identical"],
    }
    out = {"scenarios": scen, "reconvergence": recon, "identity": ident,
           "checks": checks, "seeds": list(seeds),
           "demote_cooldown": DEMOTE_COOLDOWN}
    with open("BENCH_faults.json", "w") as f:
        json.dump(out, f, indent=2)
    for k, v in checks.items():
        emit(f"faults_check_{k}", 0.0, v)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI configuration: one seed")
    args = ap.parse_args()
    result = main(smoke=args.smoke)
    if not all(result["checks"].values()):
        raise SystemExit(f"CHECK FAILED: {result['checks']}")
