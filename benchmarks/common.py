"""Shared benchmark harness: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (one per measured
configuration) so ``benchmarks.run`` aggregates a single CSV, and returns a
dict of headline metrics validated against the paper's claims in
EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

import numpy as np

from repro.core import make_manager
from repro.data.traces import MSR_PROFILES, msr_trace

__all__ = ["emit", "timed", "run_scheme", "MSR_NAMES", "DEFAULT_SIM",
           "DEFAULT_ENGINE"]

MSR_NAMES = list(MSR_PROFILES)

# latency model shared by every trace-driven benchmark (DESIGN.md §2):
# t_fast = HBM page hit, t_slow = host-tier fetch, flush = dirty writeback
# contention (the Fig. 3 effect), bypassed writes absorbed by the slow
# tier's write buffer.
DEFAULT_SIM = dict(t_fast=1.0, t_slow=20.0, flush_cost=10.0)

# window-replay engine for every trace-driven benchmark: "batch" (the
# vectorized multi-tenant engine) or "lru" (the per-access interpreter).
# Overridable via `python -m benchmarks.run --engine lru`.
DEFAULT_ENGINE = "batch"


def emit(name: str, us_per_call: float, derived: str | float) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


@contextmanager
def timed(holder: dict, key: str = "s"):
    t0 = time.perf_counter()
    yield
    holder[key] = time.perf_counter() - t0


def run_scheme(scheme: str, capacity: int, *, windows: int = 5,
               n_per_window: int = 4000, seed: int = 0, names=None,
               c_min: int = 50, initial_blocks: int = 100,
               engine: str | None = None, **kw):
    """Standard 16-tenant experiment; returns (manager, wall_seconds).

    Traces are generated *outside* the timed region: the reported wall
    time measures the scheme under test (window replay + Analyzer +
    Actuator), not the synthetic workload generator.
    """
    names = names or MSR_NAMES
    sim = dict(DEFAULT_SIM)
    sim.update(kw)
    mgr = make_manager(scheme, capacity, names, c_min=c_min,
                       initial_blocks=initial_blocks,
                       engine=engine or DEFAULT_ENGINE, **sim)
    all_windows = [
        [msr_trace(nm, n_per_window, seed=seed + 1000 * w + i)
         for i, nm in enumerate(names)]
        for w in range(windows)]
    t0 = time.perf_counter()
    for traces in all_windows:
        mgr.run_window(traces)
    return mgr, time.perf_counter() - t0
