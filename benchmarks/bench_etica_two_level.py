"""ETICA-style single-tier vs two-level comparison (ETICA Fig. 9/10 axes,
on the Fig.-14 workload mix), plus the two-level RO pressure path.

At an *equal L1 (HBM) budget* in the paper's limited-capacity regime, the
two-level hierarchy adds a managed host-DRAM level (``capacity2``, per-VM
sizes from the residual Eq.-2 pass, per-level write policies).  Because
promotions replace miss installs one-for-one, L1 cache writes (the
endurance metric) must not increase, while every L2 hit converts a
``t_slow`` miss into a ``t_fast2`` hierarchy hit — so mean latency must
strictly improve.  Both claims are checked on **both** replay engines
(``batch`` and ``lru``), plus cross-engine agreement.

The *pressure* section drives an endurance-critical mix — every tenant on
write-around (``w_threshold=0``) at an L1 budget far below the working
sets, i.e. exactly the windows that used to fall back to the per-access
interpreter — and asserts ``ro_fallback_windows == 0``: two-level RO under
eviction pressure now replays through the per-level eviction-token loop on
the vectorized path.  The measured batch-vs-interpreter speedup on that
mix is recorded in ``BENCH_etica_two_level.json``.

``--smoke`` (the CI configuration) shrinks windows/trace length and skips
the wall-time claims; the exactness and zero-fallback checks still run.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import emit, run_scheme

CAP1 = 2000           # L1-infeasible regime for the mix (URD sum ~6k)
CAP2 = 8000            # host-DRAM blocks (cheap, bigger than HBM)
T_FAST2 = 4.0          # host-tier page fetch vs 1.0 HBM / 20.0 recompute
WINDOWS = 4

# pressure mix: every tenant forced to write-around, L1 far below the
# working sets -> sustained invalidation + eviction pressure on both levels
PRESSURE_CAP1 = 400
PRESSURE_CAP2 = 1200


def _pair(engine: str, windows: int, n: int):
    one, secs1 = run_scheme("eci", CAP1, windows=windows, n_per_window=n,
                            engine=engine)
    two, secs2 = run_scheme("etica", CAP1, windows=windows, n_per_window=n,
                            engine=engine, capacity2=CAP2, t_fast2=T_FAST2)
    return one, two, secs1, secs2


def _pressure(engine: str, windows: int, n: int):
    mgr, secs = run_scheme("etica", PRESSURE_CAP1, windows=windows,
                           n_per_window=n, engine=engine,
                           capacity2=PRESSURE_CAP2, t_fast2=T_FAST2,
                           w_threshold=0.0)     # Alg. 3 -> RO everywhere
    return mgr, secs


def main(smoke: bool = False) -> dict:
    windows, n = (2, 1500) if smoke else (WINDOWS, 4000)
    for engine in ("batch", "lru"):        # warm jits/allocators
        run_scheme("etica", CAP1, windows=1, n_per_window=n, engine=engine,
                   capacity2=CAP2, t_fast2=T_FAST2)
    checks: dict[str, bool] = {}
    summaries = {}
    for engine in ("batch", "lru"):
        one, two, secs1, secs2 = _pair(engine, windows, n)
        s1, s2 = one.summary(), two.summary()
        summaries[engine] = (s1, s2)
        lat_gain = 1.0 - s2["mean_latency"] / s1["mean_latency"]
        emit(f"etica_single_tier_{engine}", secs1 / windows * 1e6,
             f"lat={s1['mean_latency']:.4f}_hr={s1['read_hit_ratio']:.3f}"
             f"_l1w={s1['cache_writes']}")
        emit(f"etica_two_level_{engine}", secs2 / windows * 1e6,
             f"lat={s2['mean_latency']:.4f}_hr={s2['read_hit_ratio']:.3f}"
             f"+{s2['read_hit_ratio_l2']:.3f}_l1w={s2['cache_writes']}"
             f"_l2w={s2['cache_writes_l2']}")
        emit(f"etica_latency_gain_{engine}", 0.0, f"{lat_gain:+.1%}")
        checks[f"latency_improves_{engine}"] = \
            s2["mean_latency"] < s1["mean_latency"]
        checks[f"l1_writes_not_increased_{engine}"] = \
            s2["cache_writes"] <= s1["cache_writes"]
        checks[f"l2_hits_present_{engine}"] = s2["read_hit_ratio_l2"] > 0.0

    sb, sl = summaries["batch"][1], summaries["lru"][1]
    checks["engines_agree"] = (
        sb["cache_writes"] == sl["cache_writes"]
        and sb["cache_writes_l2"] == sl["cache_writes_l2"]
        and abs(sb["mean_latency"] - sl["mean_latency"])
        <= 1e-9 * max(sb["mean_latency"], 1.0))

    # ---------------------------------------- two-level RO under pressure
    pb, pb_secs = _pressure("batch", windows, n)
    pl, pl_secs = _pressure("lru", windows, n)
    ps_b, ps_l = pb.summary(), pl.summary()
    speedup = pl_secs / max(pb_secs, 1e-12)
    emit("etica_ro_pressure_batch", pb_secs / windows * 1e6,
         f"fallbacks={ps_b['ro_fallback_windows']}"
         f"/{ps_b['tenant_windows']}_l2w={ps_b['cache_writes_l2']}")
    emit("etica_ro_pressure_speedup_vs_interp", 0.0, f"{speedup:.1f}x")
    checks["ro_pressure_no_fallback"] = ps_b["ro_fallback_windows"] == 0
    # demotions only happen under pressure, so a nonzero L2 write count
    # proves the token path (not the no-eviction guard) carried the mix
    checks["ro_pressure_exercises_tokens"] = ps_b["cache_writes_l2"] > 0
    checks["ro_pressure_engines_agree"] = (
        ps_b["cache_writes"] == ps_l["cache_writes"]
        and ps_b["cache_writes_l2"] == ps_l["cache_writes_l2"]
        and abs(ps_b["mean_latency"] - ps_l["mean_latency"])
        <= 1e-9 * max(ps_b["mean_latency"], 1.0))
    if not smoke:
        checks["ro_pressure_batch_faster"] = speedup > 1.0

    out = {
        "batch": summaries["batch"][1], "single": summaries["batch"][0],
        "pressure": {
            "batch": ps_b, "lru": ps_l,
            "batch_s": pb_secs, "lru_s": pl_secs,
            "speedup_vs_interpreter": speedup,
            "cap1": PRESSURE_CAP1, "cap2": PRESSURE_CAP2,
            "windows": windows, "n_per_window": n,
        },
        "checks": checks,
    }
    with open("BENCH_etica_two_level.json", "w") as f:
        json.dump(out, f, indent=2)
    emit("etica_checks", 0.0, ";".join(f"{k}={v}" for k, v in checks.items()))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI configuration: short windows, exactness + "
                         "zero-fallback checks only (no wall-time claims)")
    args = ap.parse_args()
    result = main(smoke=args.smoke)
    if not all(result["checks"].values()):
        raise SystemExit(f"CHECK FAILED: {result['checks']}")
