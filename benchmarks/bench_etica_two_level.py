"""ETICA-style single-tier vs two-level comparison (ETICA Fig. 9/10 axes,
on the Fig.-14 workload mix).

At an *equal L1 (HBM) budget* in the paper's limited-capacity regime, the
two-level hierarchy adds a managed host-DRAM level (``capacity2``, per-VM
sizes from the residual Eq.-2 pass, per-level write policies).  Because
promotions replace miss installs one-for-one, L1 cache writes (the
endurance metric) must not increase, while every L2 hit converts a
``t_slow`` miss into a ``t_fast2`` hierarchy hit — so mean latency must
strictly improve.  Both claims are checked on **both** replay engines
(``batch`` and ``lru``), plus cross-engine agreement.
"""
from __future__ import annotations

from benchmarks.common import emit, run_scheme

CAP1 = 2000           # L1-infeasible regime for the mix (URD sum ~6k)
CAP2 = 8000            # host-DRAM blocks (cheap, bigger than HBM)
T_FAST2 = 4.0          # host-tier page fetch vs 1.0 HBM / 20.0 recompute
WINDOWS = 4


def _pair(engine: str):
    one, secs1 = run_scheme("eci", CAP1, windows=WINDOWS, engine=engine)
    two, secs2 = run_scheme("etica", CAP1, windows=WINDOWS, engine=engine,
                            capacity2=CAP2, t_fast2=T_FAST2)
    return one, two, secs1, secs2


def main() -> dict:
    for engine in ("batch", "lru"):        # warm jits/allocators
        run_scheme("etica", CAP1, windows=1, engine=engine,
                   capacity2=CAP2, t_fast2=T_FAST2)
    checks: dict[str, bool] = {}
    summaries = {}
    for engine in ("batch", "lru"):
        one, two, secs1, secs2 = _pair(engine)
        s1, s2 = one.summary(), two.summary()
        summaries[engine] = (s1, s2)
        lat_gain = 1.0 - s2["mean_latency"] / s1["mean_latency"]
        emit(f"etica_single_tier_{engine}", secs1 / WINDOWS * 1e6,
             f"lat={s1['mean_latency']:.4f}_hr={s1['read_hit_ratio']:.3f}"
             f"_l1w={s1['cache_writes']}")
        emit(f"etica_two_level_{engine}", secs2 / WINDOWS * 1e6,
             f"lat={s2['mean_latency']:.4f}_hr={s2['read_hit_ratio']:.3f}"
             f"+{s2['read_hit_ratio_l2']:.3f}_l1w={s2['cache_writes']}"
             f"_l2w={s2['cache_writes_l2']}")
        emit(f"etica_latency_gain_{engine}", 0.0, f"{lat_gain:+.1%}")
        checks[f"latency_improves_{engine}"] = \
            s2["mean_latency"] < s1["mean_latency"]
        checks[f"l1_writes_not_increased_{engine}"] = \
            s2["cache_writes"] <= s1["cache_writes"]
        checks[f"l2_hits_present_{engine}"] = s2["read_hit_ratio_l2"] > 0.0

    sb, sl = summaries["batch"][1], summaries["lru"][1]
    checks["engines_agree"] = (
        sb["cache_writes"] == sl["cache_writes"]
        and sb["cache_writes_l2"] == sl["cache_writes_l2"]
        and abs(sb["mean_latency"] - sl["mean_latency"])
        <= 1e-9 * max(sb["mean_latency"], 1.0))
    emit("etica_checks", 0.0, ";".join(f"{k}={v}" for k, v in checks.items()))
    return {"batch": summaries["batch"][1], "single": summaries["batch"][0],
            "checks": checks}


if __name__ == "__main__":
    main()
