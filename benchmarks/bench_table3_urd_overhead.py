"""Table 3 / Appendix B — URD calculation overhead per analysis window.

The paper reports 0.4–22.7 s/window with modified PARDA on the host CPU and
sizes Δt so the overhead stays <5%.  We measure our four engines — exact
Fenwick, vectorized-counting (jnp oracle of the Pallas kernel), the
SHARDS-sampled monitor, and the kernel-backed accelerated path — on the
same windows, reporting per-window seconds and the implied Δt for a 5%
budget.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (reuse_distances, reuse_distances_vectorized,
                        sampled_reuse_distances)
from repro.data.traces import msr_trace
from repro.kernels.urd_scan.ops import reuse_distances_accel

from benchmarks.common import emit


def main() -> dict:
    n = 8000
    rows = {}
    for name in ("prxy_0", "prn_1", "hm_1", "web_1"):
        t = msr_trace(name, n, seed=3)
        timings = {}
        t0 = time.perf_counter()
        exact = reuse_distances(t, "urd")
        timings["fenwick_exact"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        vec = reuse_distances_vectorized(t, "urd", tile=1024)
        timings["vectorized_oracle"] = time.perf_counter() - t0
        assert np.array_equal(exact.distances, vec.distances)

        t0 = time.perf_counter()
        sampled_reuse_distances(t, "urd", rate=0.1)
        timings["shards_r0.1"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        acc = reuse_distances_accel(t, "urd", use_kernel=False)
        timings["accel_jnp"] = time.perf_counter() - t0
        assert np.array_equal(exact.distances, acc.distances)

        rows[name] = timings
        for k, v in timings.items():
            emit(f"table3_{name}_{k}", v / n * 1e6,
                 f"window_s={v:.3f}_dt_for_5pct={v / 0.05:.1f}s")
    # paper check: overhead scales ~linearly in window length for sampled
    t_small = msr_trace("prxy_0", 2000, seed=3)
    t0 = time.perf_counter()
    sampled_reuse_distances(t_small, "urd", rate=0.1)
    small = time.perf_counter() - t0
    emit("table3_scaling_2k_vs_8k", 0.0,
         f"{small:.3f}s_vs_{rows['prxy_0']['shards_r0.1']:.3f}s")
    return rows


if __name__ == "__main__":
    main()
