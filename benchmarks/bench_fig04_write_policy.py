"""Fig. 4 — impact of write policy on workload performance.

Runs the eight Filebench personalities against {no-cache, WB, RO} at a
fixed cache size and reports the performance (1/mean-latency, the paper's
IOPS proxy) normalized to no-cache.  Validates the paper's five
observations (§3): WB >> NC for fileserver/randomrw/varmail, WB ≈ RO for
webserver/webproxy, RO best for singlestreamread, caching unhelpful for
copyfiles/mongo.
"""
from __future__ import annotations

import time

from repro.core import WritePolicy, simulate
from repro.data.traces import FILEBENCH_PROFILES, filebench_trace

from benchmarks.common import DEFAULT_SIM, emit


def main() -> dict:
    n, cap = 8000, 1200
    results = {}
    for name in FILEBENCH_PROFILES:
        t = filebench_trace(name, n, seed=4)
        perfs = {}
        t0 = time.perf_counter()
        nc = simulate(t, 0, WritePolicy.RO, **DEFAULT_SIM)
        for pol in (WritePolicy.WB, WritePolicy.RO):
            r = simulate(t, cap, pol, **DEFAULT_SIM)
            perfs[pol.value] = r.perf / nc.perf
        dt = (time.perf_counter() - t0) / (3 * n) * 1e6
        results[name] = perfs
        emit(f"fig04_{name}", dt,
             f"WBx{perfs['wb']:.2f}_ROx{perfs['ro']:.2f}")

    checks = {
        "wb_wins_fileserver": results["fileserver"]["wb"]
        > max(results["fileserver"]["ro"], 1.05),
        "wb_wins_randomrw": results["randomrw"]["wb"]
        > max(results["randomrw"]["ro"], 1.0),
        "webserver_parity": abs(results["webserver"]["wb"]
                                - results["webserver"]["ro"])
        / max(results["webserver"]["wb"], 1e-9) < 0.30,
        "ro_good_singlestream": results["singlestreamread"]["ro"] > 1.0,
        "copyfiles_no_benefit": results["copyfiles"]["wb"] < 1.25,
    }
    emit("fig04_checks", 0.0,
         ";".join(f"{k}={v}" for k, v in checks.items()))
    return {"results": results, "checks": checks}


if __name__ == "__main__":
    main()
