"""Appendix A — feasible state (unlimited capacity): ECI allocates much
smaller partitions at equal performance.

Paper: 29.45% smaller on average, with extremes (stg_1 ~1000×,
rsrch_2 ~50000× in the paper's traces; our synthetic ratios are milder but
the ordering and the equal-performance conclusion reproduce).
"""
from __future__ import annotations

from benchmarks.common import MSR_NAMES, emit, run_scheme


def main() -> dict:
    cap = 10**7           # effectively unlimited
    eci, secs = run_scheme("eci", cap, windows=4)
    cen, _ = run_scheme("centaur", cap, windows=4)
    es, cs = eci.summary(), cen.summary()

    alloc_ratio = es["allocated_blocks"] / cs["allocated_blocks"]
    perf_ratio = es["performance"] / cs["performance"]
    emit("appA_alloc_ratio", secs / 4 * 1e6,
         f"eci/centaur={alloc_ratio:.2f}_(smaller_is_better)")
    emit("appA_perf_ratio", 0.0, f"{perf_ratio:.3f}")

    extremes = {}
    for t_e, t_c in zip(eci.tenants, cen.tenants):
        r = (t_c.cache.capacity / max(t_e.cache.capacity, 1))
        extremes[t_e.name] = r
        emit(f"appA_{t_e.name}", 0.0,
             f"centaur/eci_size={r:.1f}x")
    checks = {
        "allocates_less": alloc_ratio < 0.75,
        "performance_parity": perf_ratio > 0.85,
        "stg_1_extreme": extremes["stg_1"] > 2.0,
        "every_feasible_window": all(d.feasible for d in eci.history),
    }
    emit("appA_checks", 0.0, ";".join(f"{k}={v}" for k, v in checks.items()))
    return {"alloc_ratio": alloc_ratio, "perf_ratio": perf_ratio,
            "checks": checks}


if __name__ == "__main__":
    main()
