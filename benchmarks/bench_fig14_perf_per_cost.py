"""Fig. 14/15 + §6.5 — performance and performance-per-cost, ECI vs Centaur.

The paper's headline numbers (infeasible/limited-capacity regime):
performance +17%, performance-per-cost +30%.  Reports both headline ratios
plus the per-tenant breakdown and cumulative-latency curve (Fig. 15).
"""
from __future__ import annotations

from benchmarks.common import MSR_NAMES, emit, run_scheme


def main() -> dict:
    cap = 6000            # the paper's regime: ECI feasible, Centaur not
    for scheme in ("eci", "centaur"):         # steady-state: warm jits and
        run_scheme(scheme, cap, windows=1)    # allocators at full size
    eci, secs_e = run_scheme("eci", cap, windows=6)
    cen, secs_c = run_scheme("centaur", cap, windows=6)
    es, cs = eci.summary(), cen.summary()

    perf_gain = es["performance"] / cs["performance"] - 1.0
    ppc_gain = es["perf_per_cost"] / cs["perf_per_cost"] - 1.0
    emit("fig14_performance_gain", secs_e / 6 * 1e6, f"{perf_gain:+.1%}")
    emit("fig14_perf_per_cost_gain", secs_c / 6 * 1e6, f"{ppc_gain:+.1%}")

    for t_e, t_c in zip(eci.tenants, cen.tenants):
        pe = t_e.result.perf
        pc = t_c.result.perf
        emit(f"fig14_{t_e.name}", 0.0,
             f"perf_ratio={pe / pc if pc else float('nan'):.2f}"
             f"_alloc={t_e.cache.capacity}v{t_c.cache.capacity}")

    # Fig. 15: cumulative latency over windows
    cum_e = cum_c = 0.0
    curve = []
    for w, (de, dc) in enumerate(zip(eci.history, cen.history)):
        cum_e = sum(t.result.total_latency for t in eci.tenants)
        cum_c = sum(t.result.total_latency for t in cen.tenants)
        curve.append((w, cum_e, cum_c))
    emit("fig15_final_cumulative_latency", 0.0,
         f"eci={cum_e:.0f}_centaur={cum_c:.0f}_"
         f"reduction={1 - cum_e / cum_c:+.1%}")
    checks = {
        "perf_improves": perf_gain > 0.0,
        "ppc_improves": ppc_gain > 0.10,
        "latency_reduced": cum_e < cum_c,
    }
    emit("fig14_checks", 0.0, ";".join(f"{k}={v}" for k, v in checks.items()))
    return {"perf_gain": perf_gain, "ppc_gain": ppc_gain, "checks": checks}


if __name__ == "__main__":
    main()
