"""Beyond-paper: the live serving integration — ECI-managed HBM page pool
under a multi-tenant request stream (smoke-scale model, real paged decode).

Measures HBM page hit ratio, pool admission writes and bypassed writes for
ECI vs an always-WB (Centaur-policy) pool on the same request schedule:
the serving-level translation of Fig. 16.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import BlockPool, TieredKVCache
from repro.configs import get_smoke_config
from repro.core import ECICacheManager, WritePolicy
from repro.models import model as M
from repro.models.attention import build_heads
from repro.serve.engine import MultiTenantEngine, Request

from benchmarks.common import emit


def _run(adaptive: bool, seed: int = 0):
    cfg = get_smoke_config("qwen3_0_6b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    hq, hkv = build_heads(cfg, 1)
    pool = BlockPool(512, 8, cfg.n_layers, hkv, cfg.head_dim,
                     dtype=jnp.float32)
    mgr = ECICacheManager(192, ["chat", "batchjob"], c_min=8,
                          initial_blocks=64, adaptive_policy=adaptive)
    tiered = TieredKVCache(pool, mgr, window_events=48)
    eng = MultiTenantEngine(cfg, params, tiered, page_size=8,
                            max_pages_per_seq=16)
    rng = np.random.default_rng(seed)
    # tenant 0 "chat": heavy shared system prompt -> RAR-style reuse
    sys_prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    # tenant 1 "batchjob": unique prompts, never re-read -> WAW-style churn
    for i in range(10):
        if i % 2 == 0:
            p = np.concatenate([sys_prompt,
                                rng.integers(0, cfg.vocab_size, 8
                                             ).astype(np.int32)])
            eng.submit(Request(tenant=0, prompt=p, max_new_tokens=4))
        else:
            p = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
            eng.submit(Request(tenant=1, prompt=p, max_new_tokens=4))
    t0 = time.perf_counter()
    eng.run(64)
    return eng, time.perf_counter() - t0


def main() -> dict:
    eci_eng, secs = _run(adaptive=True)
    wb_eng, _ = _run(adaptive=False)
    es, ws = eci_eng.tiered.summary(), wb_eng.tiered.summary()
    emit("serving_eci", secs * 1e6 / 64,
         f"hit={es['hbm_hit_ratio']:.2f}_writes={es['hbm_writes']}"
         f"_bypassed={es['bypassed_writes']}")
    emit("serving_wb_always", 0.0,
         f"hit={ws['hbm_hit_ratio']:.2f}_writes={ws['hbm_writes']}")
    # Actuator-path cost: wall time of the batched Monitor flush +
    # Analyzer + quota enforcement, per rebalance window
    n_windows = max(len(eci_eng.tiered.manager.history), 1)
    emit("serving_rebalance_path", es["rebalance_seconds"] / n_windows * 1e6,
         f"total_s={es['rebalance_seconds']:.4f}_windows={n_windows}")
    saved = 1 - es["hbm_writes"] / max(ws["hbm_writes"], 1)
    emit("serving_write_savings", 0.0, f"{saved:+.1%}")
    checks = {
        "completed_all": len(eci_eng.completed) == 10,
        "prefix_reuse_happened": es["hbm_hit_ratio"] > 0.2,
        "eci_fewer_pool_writes": es["hbm_writes"] <= ws["hbm_writes"],
    }
    emit("serving_checks", 0.0,
         ";".join(f"{k}={v}" for k, v in checks.items()))
    return {"eci": es, "wb": ws, "checks": checks}


if __name__ == "__main__":
    main()
