"""Fig. 10/11 — per-window cache allocation under limited capacity.

16 tenants, capacity between sum(URD) and sum(TRD): Centaur goes infeasible
(squeezes every VM) while ECI-Cache stays feasible — the paper's §6.3
observation.  Emits per-window total allocations + infeasibility counts.
"""
from __future__ import annotations

from benchmarks.common import MSR_NAMES, emit, run_scheme


def main() -> dict:
    cap = 7000            # between Σ URD (~5.6k) and Σ TRD (~12.5k)
    out = {}
    for scheme in ("eci", "centaur"):
        mgr, secs = run_scheme(scheme, cap, windows=5)
        infeasible = sum(not d.feasible for d in mgr.history)
        allocs = [int(d.sizes.sum()) for d in mgr.history]
        out[scheme] = {"infeasible_windows": infeasible, "allocs": allocs}
        emit(f"fig10_{scheme}", secs / 5 * 1e6,
             f"infeasible={infeasible}/5_allocs={'|'.join(map(str, allocs))}")
    # per-tenant detail (Fig. 11): final window
    for scheme in ("eci", "centaur"):
        mgr, _ = run_scheme(scheme, cap, windows=3)
        sizes = mgr.history[-1].sizes
        emit(f"fig11_{scheme}_final_sizes", 0.0,
             "|".join(f"{n}:{int(s)}" for n, s in zip(MSR_NAMES, sizes)))
    ok = (out["eci"]["infeasible_windows"]
          <= out["centaur"]["infeasible_windows"])
    emit("fig10_check_eci_feasible_more_often", 0.0, ok)
    out["check"] = ok
    return out


if __name__ == "__main__":
    main()
