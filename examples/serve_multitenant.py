"""End-to-end driver: serve a small model with batched multi-tenant
requests over the ECI-managed paged HBM pool.

Two tenants share the engine: "chat" re-uses a system prompt (prefix-cache
RAR pattern, rewarded with WB admissions) and "batch" streams unique
prompts (WAW-ish churn ECI demotes to write-around).  The engine runs real
paged decode (the Pallas paged_attention path on TPU, its oracle here).

    PYTHONPATH=src python examples/serve_multitenant.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import BlockPool, TieredKVCache
from repro.configs import get_smoke_config
from repro.core import ECICacheManager
from repro.models import model as M
from repro.models.attention import build_heads
from repro.serve.engine import MultiTenantEngine, Request


def main() -> None:
    cfg = get_smoke_config("qwen3_0_6b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    hq, hkv = build_heads(cfg, 1)
    pool = BlockPool(n_pages=512, page_size=8, n_layers=cfg.n_layers,
                     kv_heads=hkv, head_dim=cfg.head_dim,
                     dtype=jnp.float32)
    manager = ECICacheManager(capacity=96, tenant_names=["chat", "batch"],
                              c_min=8, initial_blocks=48)
    tiered = TieredKVCache(pool, manager, window_events=48)
    engine = MultiTenantEngine(cfg, params, tiered, page_size=8,
                               max_pages_per_seq=16)

    rng = np.random.default_rng(0)
    system_prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    batch_jobs: list = []
    print("submitting 12 requests (6 chat w/ shared prefix, 6 batch)...")
    for i in range(12):
        if i % 2 == 0:
            prompt = np.concatenate(
                [system_prompt,
                 rng.integers(0, cfg.vocab_size, 8).astype(np.int32)])
            engine.submit(Request(tenant=0, prompt=prompt, max_new_tokens=6))
        else:
            # cycling batch jobs: same prompts re-run, pages rewritten after
            # eviction (the WAW pattern ECI demotes to write-around)
            if len(batch_jobs) < 3:
                job = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
                batch_jobs.append(job)
            else:
                job = batch_jobs[(i // 2) % 3]
            engine.submit(Request(tenant=1, prompt=job, max_new_tokens=6))
    engine.run(max_steps=64)

    print(f"completed {len(engine.completed)}/12 requests")
    for r in engine.completed[:4]:
        print(f"  tenant={r.tenant} generated={r.generated}")
    s = tiered.summary()
    print("\nECI-managed pool state:")
    print(f"  HBM page hit ratio : {s['hbm_hit_ratio']:.2f}")
    print(f"  pool admissions    : {s['hbm_writes']}")
    print(f"  bypassed (RO)      : {s['bypassed_writes']}")
    print(f"  quotas             : {s['quotas']}")
    print(f"  policies           : {s['policies']}")
    print(f"  pool stats         : {pool.stats}")


if __name__ == "__main__":
    main()
