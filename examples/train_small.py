"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on synthetic data, with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_config
from repro.models import model as M
from repro.data.lm import SyntheticLM
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: qwen3 geometry scaled (12L, d512, vocab 32k)
    cfg = dataclasses.replace(
        get_config("qwen3_0_6b"), n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=32000,
        tie_embeddings=False)
    print(f"model: {cfg.n_params() / 1e6:.0f}M params")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr_peak=3e-4, warmup_steps=30,
                          total_steps=args.steps)
    step = jax.jit(make_train_step(cfg, opt_cfg),
                   donate_argnums=(0,))
    state = init_train_state(params)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=50,
                             ckpt_dir=ckpt_dir)
        trainer = Trainer(step, state, data, tcfg)
        out = trainer.run()
    log = trainer.metrics_log
    print(f"steps={out['final_step']} restarts={out['restarts']} "
          f"stragglers={out['straggler_steps']}")
    print(f"loss: {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f} "
          f"({sum(m['time_s'] for m in log):.1f}s total, "
          f"{1e3 * sum(m['time_s'] for m in log) / len(log):.0f} ms/step)")


if __name__ == "__main__":
    main()
