"""Quickstart: ECI-Cache on synthetic multi-tenant block traces.

Runs the paper's core loop (Monitor → Analyzer → Actuator) on four tenants,
comparing ECI-Cache against Centaur, and prints the three headline metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (make_manager, max_rd, request_type_mix,
                        reuse_distances, urd_cache_blocks)
from repro.data.traces import msr_trace

NAMES = ["wdev_0", "hm_1", "prn_1", "prxy_0"]


def main() -> None:
    print("=== per-workload URD vs TRD (paper §4) ===")
    for name in NAMES:
        t = msr_trace(name, 4000, seed=0)
        trd = reuse_distances(t, "trd")
        urd = reuse_distances(t, "urd")
        mix = request_type_mix(t)
        print(f"{name:8s} maxTRD={max_rd(trd):5d} maxURD={max_rd(urd):5d} "
              f"-> cache {urd_cache_blocks(trd):5d} vs "
              f"{urd_cache_blocks(urd):5d} blocks | "
              f"WAW={mix['WAW']:.0%} RAR={mix['RAR']:.0%}")

    print("\n=== ECI-Cache vs Centaur (5 windows, capacity 1500) ===")
    results = {}
    for scheme in ("eci", "centaur"):
        mgr = make_manager(scheme, 1500, NAMES, c_min=20, initial_blocks=50,
                           t_fast=1.0, t_slow=20.0, flush_cost=10.0)
        for w in range(5):
            traces = [msr_trace(n, 2000, seed=1000 * w + i)
                      for i, n in enumerate(NAMES)]
            mgr.run_window(traces)
        results[scheme] = mgr.summary()
        s = results[scheme]
        print(f"{scheme:8s} latency={s['mean_latency']:.2f} "
              f"writes={s['cache_writes']:6d} "
              f"alloc={s['allocated_blocks']:5d} "
              f"perf/cost={s['perf_per_cost']:.2e}")
        for t in mgr.tenants:
            print(f"   {t.name:8s} policy={t.policy.value} "
                  f"alloc={t.cache.capacity}")

    e, c = results["eci"], results["centaur"]
    print(f"\nECI vs Centaur: performance "
          f"{e['performance'] / c['performance'] - 1:+.1%}, "
          f"perf-per-cost {e['perf_per_cost'] / c['perf_per_cost'] - 1:+.1%}, "
          f"cache writes {1 - e['cache_writes'] / c['cache_writes']:+.1%} saved")


if __name__ == "__main__":
    main()
