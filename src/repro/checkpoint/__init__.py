"""Sharding-aware checkpointing with elastic (cross-mesh) restore."""
