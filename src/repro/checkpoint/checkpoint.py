"""Sharding-aware checkpointing with async save and elastic restore.

Format: one ``.npz`` of flattened tree leaves + a JSON manifest (tree paths,
shapes, dtypes, step).  Leaves are pulled to host as full (logical) arrays —
with jax.Array + NamedSharding this is a device-to-host gather; restore
``device_put``s each leaf with the *target* mesh's sharding, so a checkpoint
written on one mesh restores onto any other (elastic scaling), including
meshes with different axis sizes — the manifest stores logical shapes only.

Fault-tolerance contract (used by ``repro.train.trainer``): saves are
atomic (tmp + rename), the latest complete step wins, an async writer thread
overlaps serialization with the next training step.
"""
from __future__ import annotations

import json
import os
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":   # ml_dtypes (bf16 …): store f32
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(path: str, tree, step: int) -> str:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    tmp = os.path.join(path, f".tmp_step_{step}.npz")
    final = os.path.join(path, f"step_{step}.npz")
    np.savez(tmp, **flat)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
    }
    with open(os.path.join(path, f".tmp_step_{step}.json"), "w") as f:
        json.dump(manifest, f)
    os.rename(tmp, final)
    os.rename(os.path.join(path, f".tmp_step_{step}.json"),
              os.path.join(path, f"step_{step}.json"))
    return final


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(f[5:-5]) for f in os.listdir(path)
             if f.startswith("step_") and f.endswith(".json")]
    return max(steps) if steps else None


def restore_checkpoint(path: str, like_tree, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching tree of NamedShardings for the target
    mesh (elastic restore re-shards here).
    """
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    data = np.load(os.path.join(path, f"step_{step}.npz"))
    flat_like = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else None)
    for i, (pth, leaf) in enumerate(flat_like[0]):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in pth)
        arr = data[key]
        if shard_leaves is not None:
            leaves.append(jax.device_put(
                arr.astype(leaf.dtype) if arr.dtype != leaf.dtype else arr,
                shard_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves), step


class AsyncCheckpointer:
    """Background writer: overlap checkpoint serialization with training."""

    def __init__(self, path: str):
        self.path = path
        self._thread: threading.Thread | None = None

    def save(self, tree, step: int) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # sync pull to host
        self._thread = threading.Thread(
            target=save_checkpoint, args=(self.path, host_tree, step),
            daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
