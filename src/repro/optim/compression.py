"""Gradient compression for cross-pod data parallelism.

Int8 quantized all-reduce with error feedback (1-bit-Adam-family technique,
Seide et al. / Tang et al.): before the DP all-reduce each shard quantizes
its gradient block to int8 with a per-tensor scale, accumulates the
quantization residual locally, and adds it back next step.  Over the slow
cross-pod (DCN) axis this cuts gradient bytes 4× (bf16→int8) [or 2× fp32
master-grad] at no asymptotic convergence cost.

``compressed_psum`` is written for ``shard_map`` bodies; under plain pjit
the same function applies quantize→psum→dequantize semantics (the wire
format is the int8 tensor — XLA transfers the quantized representation
when the all-reduce operand is the int-cast tensor).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum",
           "init_error_state"]

_F32 = jnp.float32


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(_F32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(_F32) * scale


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, _F32), grads)


def compressed_psum(grads, err_state, axis_name: str):
    """Error-feedback int8 all-reduce over ``axis_name``.

    Returns (reduced_grads_f32, new_err_state).  Call inside shard_map with
    the DP ('pod') axis unreduced.
    """
    def one(g, e):
        g32 = g.astype(_F32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        new_e = g32 - deq
        # wire transfer: int8 payload + per-shard fp32 scale.  Each shard's
        # contribution must carry ITS OWN scale, so the reduce sums the
        # dequantized values (on real hardware: scale exchange + int8
        # payload; bytes modeled as int8 in the roofline).
        red = jax.lax.psum(deq, axis_name)
        n = jax.lax.psum(jnp.ones((), _F32), axis_name)
        return red / n, new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree.unflatten(tree, [o[0] for o in out])
    new_err = jax.tree.unflatten(tree, [o[1] for o in out])
    return red, new_err
