"""AdamW with fp32 master weights + schedules (self-contained, no optax).

Params train in bf16 (MXU-native); the optimizer keeps fp32 master copies
and moments.  Update math follows Loshchilov & Hutter (decoupled weight
decay) with global-norm clipping.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update",
           "cosine_schedule", "linear_schedule"]

_F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"      # cosine | linear | constant


def cosine_schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr_peak * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def linear_schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr_peak * warm * (1.0 - prog)


def _lr(step, cfg: AdamWConfig):
    if cfg.schedule == "cosine":
        return cosine_schedule(step, cfg)
    if cfg.schedule == "linear":
        return linear_schedule(step, cfg)
    return jnp.asarray(cfg.lr_peak)


def init_opt_state(params) -> dict:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(_F32), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, _F32), t)
    return {"master": f32(params), "mu": zeros(params), "nu": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, opt_state, cfg: AdamWConfig, params=None):
    """Returns (new_params, new_opt_state, grad_norm).

    ``params`` (old tree) supplies per-leaf dtypes so low-precision leaves
    stay low-precision and fp32 leaves (norm scales) stay fp32 across steps.
    """
    count = opt_state["count"] + 1
    g32 = jax.tree.map(lambda g: g.astype(_F32), grads)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(g32)))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    g32 = jax.tree.map(lambda g: g * scale, g32)

    lr = _lr(count.astype(_F32), cfg)
    b1c = 1.0 - cfg.b1 ** count.astype(_F32)
    b2c = 1.0 - cfg.b2 ** count.astype(_F32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      opt_state["mu"], g32)
    nu = jax.tree.map(lambda n, g: cfg.b2 * n + (1 - cfg.b2) * g * g,
                      opt_state["nu"], g32)

    def upd(p, m, n):
        mh, nh = m / b1c, n / b2c
        return p - lr * (mh / (jnp.sqrt(nh) + cfg.eps)
                         + cfg.weight_decay * p)

    master = jax.tree.map(upd, opt_state["master"], mu, nu)
    if params is not None:
        new_params = jax.tree.map(lambda x, p: x.astype(p.dtype),
                                  master, params)
    else:
        new_params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), master)
    return new_params, {"master": master, "mu": mu, "nu": nu,
                        "count": count}, gnorm
