"""Loop-aware HLO cost analysis from ``compiled.as_text()``.

XLA's built-in ``cost_analysis()`` counts ``while``-loop bodies ONCE — with
``lax.scan`` over layers (mandatory for compile time at 512 devices) that
undercounts FLOPs/bytes/collectives by ~n_layers×.  This analyzer parses the
optimized HLO text, recovers every while loop's trip count from its
condition computation, and aggregates per-computation costs weighted by the
product of enclosing trip counts:

  * FLOPs       — 2·M·N·K per ``dot`` (+convolutions via output×kernel);
  * collective  — result bytes of all-reduce / all-gather / reduce-scatter /
                  all-to-all / collective-permute, by kind;
  * HBM bytes   — sum of top-level op result sizes (fusion internals
                  excluded): a write-once/read-once lower-bound proxy for
                  HBM traffic.

Known approximations (documented in EXPERIMENTS.md): elementwise FLOPs are
ignored (dots dominate LM steps); bytes is a proxy, not a buffer-assignment
simulation; dynamic trip counts (none in this codebase) would default to 1.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CONST_CMP = re.compile(r"constant\((\d+)\)")
_DIRECTION = re.compile(r"direction=(LT|LE|GT|GE)")
_DOT = re.compile(r"=\s*((?:\w+\[[\d,]*\]\S*)|\(.*?\))\s+dot\(")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COLLECTIVE = re.compile(
    r"=\s*((?:\w+\[[\d,]*\]\S*)|\(.*?\))\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|"
    r"collective-permute)\(")
_RESULT_SHAPE = re.compile(r"=\s*((?:\w+\[[\d,]*\]\S*)|\(.*?\))\s+[\w\-]+")
_CALLS = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict | None = None
    while_trip_counts: dict | None = None

    @property
    def coll_total(self) -> float:
        return float(sum((self.collective_bytes or {}).values()))


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_numel(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(text):
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Recover the loop bound from the condition computation."""
    const = None
    direction = None
    for ln in cond_lines:
        if "compare(" in ln:
            d = _DIRECTION.search(ln)
            if d:
                direction = d.group(1)
        c = _CONST_CMP.search(ln)
        if c:
            const = int(c.group(1))
    if const is None:
        return 1
    if direction == "LE":
        return const + 1
    return const              # LT (lax.scan default); GT/GE are countdown


_DEF = re.compile(r"%([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:\w+\[[\d,]*\]\S*))")
_OPERANDS = re.compile(r"%([\w.\-]+)")


def _dot_flops(line: str, symbols: dict[str, str]) -> float:
    m = _DOT.search(line)
    if not m:
        return 0.0
    result = m.group(1)
    shapes = _shape_numel(result)
    if not shapes:
        return 0.0
    out_numel = 1
    for d in shapes[0][1]:
        out_numel *= d
    # contraction size: resolve the lhs operand's shape via the symbol table
    args = line[line.index("dot(") + 4:]
    names = _OPERANDS.findall(args)
    cm = _CONTRACT.search(line)
    k = 1
    if cm and names:
        lhs_shape_txt = symbols.get(names[0], "")
        lhs = _shape_numel(lhs_shape_txt)
        if lhs:
            lhs_dims = lhs[0][1]
            for idx in (int(i) for i in cm.group(1).split(",") if i != ""):
                if idx < len(lhs_dims):
                    k *= lhs_dims[idx]
    return 2.0 * out_numel * k


def analyze_hlo(hlo: str) -> HloCost:
    comps = _split_computations(hlo)

    # map computation -> (cond, body) children with trip counts
    entry = None
    for name in comps:
        if name.lower().startswith("main") or name == "entry":
            entry = name
    if entry is None:                      # fall back: the last computation
        entry = list(comps)[-1]

    # build while edges
    while_edges: dict[str, list[tuple[str, int]]] = {n: [] for n in comps}
    for name, lines in comps.items():
        for ln in lines:
            if " while(" in ln:
                w = _WHILE.search(ln)
                if w:
                    cond, body = w.group(1), w.group(2)
                    tc = _trip_count(comps.get(cond, []))
                    while_edges[name].append((body, tc))

    # call/fusion edges (fusion bodies hold the dots on the CPU backend)
    call_edges: dict[str, list[str]] = {n: [] for n in comps}
    for name, lines in comps.items():
        for ln in lines:
            if " while(" in ln:
                continue                 # handled via while_edges
            for callee in _CALLS.findall(ln):
                if callee in comps:
                    call_edges[name].append(callee)

    # control multipliers (ENTRY + while bodies): bytes/collectives level
    mult_ctrl: dict[str, float] = {entry: 1.0}
    stack = [entry]
    while stack:
        cur = stack.pop()
        for body, tc in while_edges.get(cur, []):
            m = mult_ctrl[cur] * max(tc, 1)
            if mult_ctrl.get(body, 0) < m:
                mult_ctrl[body] = m
                stack.append(body)

    # flops multipliers: also descend through fusion/call bodies
    mult_all = dict(mult_ctrl)
    stack = list(mult_all)
    while stack:
        cur = stack.pop()
        for callee in call_edges.get(cur, []):
            m = mult_all[cur]
            if mult_all.get(callee, 0) < m:
                mult_all[callee] = m
                stack.append(callee)
        for body, tc in while_edges.get(cur, []):
            m = mult_all[cur] * max(tc, 1)
            if mult_all.get(body, 0) < m:
                mult_all[body] = m
                stack.append(body)

    cost = HloCost(collective_bytes={}, while_trip_counts={})
    for name, lines in comps.items():
        m_all = mult_all.get(name)
        m_ctrl = mult_ctrl.get(name)
        if m_all is None and m_ctrl is None:
            continue
        symbols: dict[str, str] = {}
        for ln in lines:
            d = _DEF.search(ln)
            if d:
                symbols[d.group(1)] = d.group(2)
        for ln in lines:
            if m_all is not None:
                f = _dot_flops(ln, symbols)
                if f:
                    cost.flops += f * m_all
            if m_ctrl is None:
                continue
            cm = _COLLECTIVE.search(ln)
            if cm:
                kind = cm.group(2).replace("-start", "")
                b = _shape_bytes(cm.group(1)) * m_ctrl
                # TPU-equivalent accounting: the CPU backend upcasts bf16
                # matmul operands to f32 *before* the FSDP all-gather
                # (no native bf16 dot), doubling wire bytes vs the TPU
                # lowering where gathers stay bf16.  Collectives whose
                # operand is a convert-fusion of a bf16 param are counted
                # at bf16 width (documented in EXPERIMENTS.md §Roofline).
                args = ln[ln.index("(") + 1:]
                first_op = _OPERANDS.search(args)
                if ("f32" in cm.group(1) and first_op
                        and "convert" in first_op.group(1)):
                    b *= 0.5
                cost.collective_bytes[kind] = \
                    cost.collective_bytes.get(kind, 0.0) + b
            rm = _RESULT_SHAPE.search(ln)
            if rm and " parameter(" not in ln:
                cost.bytes += _shape_bytes(rm.group(1)) * m_ctrl
    for name, edges in while_edges.items():
        for body, tc in edges:
            cost.while_trip_counts[body] = tc
    return cost
