"""Production training launcher: ``--arch <id>`` on the production mesh.

On real hardware this runs the pjit train step across the pod(s); on this
CPU container ``--dry-run`` lowers+compiles only (see ``dryrun.py`` for the
full sweep) and ``--local`` runs a reduced config end-to-end.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b --local
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.lm import SyntheticLM
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--local", action="store_true",
                    help="run the reduced smoke config on local devices")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.local else get_config(args.arch)
    print(f"arch={cfg.name} params~{cfg.n_params() / 1e6:.0f}M "
          f"active~{cfg.n_active_params() / 1e6:.0f}M")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(total_steps=args.steps)
    step = jax.jit(make_train_step(cfg, opt_cfg,
                                   microbatches=args.microbatches),
                   donate_argnums=(0,))
    state = init_train_state(params)
    seq = 64 if args.local else 4096
    batch = 8 if args.local else 256
    enc = cfg.d_model if cfg.family.value == "encdec" else None
    data = SyntheticLM(cfg.vocab_size, seq, batch, seed=0,
                       enc_dim=enc, enc_len=seq)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=25,
                         ckpt_dir=args.ckpt_dir)
    out = Trainer(step, state, data, tcfg).run()
    print(out)


if __name__ == "__main__":
    main()
