"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
``xla_force_host_platform_device_count`` before any jax initialization.

Topology mapping (TPU v5e target):
  * ``model`` (16) — intra-pod ICI ring: TP/EP/SP collectives.
  * ``data`` (16)  — intra-pod ICI: FSDP all-gathers + DP grad reduce.
  * ``pod`` (2+)   — inter-pod DCN: only DP gradient all-reduce
    (optionally int8-compressed, ``repro.optim.compression``) or pipeline
    stage boundaries cross it.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "dp_axes"]


import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run must set xla_force_host_platform_device_count first")
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires host-device override)."""
    shape = (pod, data, model) if pod else (data, model)
    axes = (("pod", "data", "model") if pod else ("data", "model"))
    n = int(np.prod(shape))
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:n]).reshape(shape), axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel axes: ('pod','data') when the pod axis exists."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
