"""Serving launcher: multi-tenant engine + ECI-managed pool.

On real hardware this drives the pjit-compiled paged decode across the pod;
here ``--local`` runs the reduced config end-to-end on CPU.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --local \
        --requests 12
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import BlockPool, TieredKVCache
from repro.configs import get_smoke_config
from repro.core import ECICacheManager
from repro.models import model as M
from repro.models.attention import build_heads
from repro.serve.engine import MultiTenantEngine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--local", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--pool-pages", type=int, default=512)
    ap.add_argument("--capacity", type=int, default=192)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    hq, hkv = build_heads(cfg, 1)
    pool = BlockPool(args.pool_pages, args.page_size, cfg.n_layers, hkv,
                     cfg.head_dim, dtype=jnp.float32)
    manager = ECICacheManager(
        args.capacity, [f"tenant{i}" for i in range(args.tenants)],
        c_min=8, initial_blocks=args.capacity // max(args.tenants, 1))
    tiered = TieredKVCache(pool, manager, window_events=128)
    engine = MultiTenantEngine(cfg, params, tiered,
                               page_size=args.page_size,
                               max_pages_per_seq=32)

    rng = np.random.default_rng(0)
    shared = {t: rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
              for t in range(args.tenants)}
    t0 = time.perf_counter()
    for i in range(args.requests):
        t = i % args.tenants
        prompt = np.concatenate(
            [shared[t], rng.integers(0, cfg.vocab_size, 8).astype(np.int32)])
        engine.submit(Request(tenant=t, prompt=prompt,
                              max_new_tokens=args.max_new_tokens))
    engine.run(max_steps=args.requests * args.max_new_tokens + 8)
    dt = time.perf_counter() - t0

    done = len(engine.completed)
    toks = sum(len(r.generated) for r in engine.completed)
    print(f"served {done}/{args.requests} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks / dt:.1f} tok/s)")
    print("pool:", tiered.summary())


if __name__ == "__main__":
    main()
