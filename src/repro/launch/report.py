"""Render §Dry-run / §Roofline markdown tables from results/dryrun.json.

    PYTHONPATH=src python -m repro.launch.report [--results path]
"""
from __future__ import annotations

import argparse
import json
import os

PEAK = 197e12


def render(path: str, mesh: str = "16x16") -> str:
    with open(path) as f:
        data = [d for d in json.load(f)
                if d.get("ok") and d["mesh"] == mesh and not d.get("tag")]
    data.sort(key=lambda d: (d["arch"], d["shape"]))
    out = ["| arch | shape | kind | compute s | memory s | collective s | "
           "bound | roofline frac | model/HLO | HBM fit |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for d in data:
        r = d["roofline_s"]
        pd = d["per_device"]
        dom = max(r, key=r.get)
        tot = max(max(r.values()), 1e-30)
        # roofline fraction: useful-compute time / dominant-term time
        frac = (pd["model_flops"] / PEAK) / tot
        temp = (pd["temp_bytes"] or 0) / 1e9
        fit = "yes" if temp < 16 else f"~{temp:.0f}G*"
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['kind']} | "
            f"{r['compute']:.2e} | {r['memory']:.2e} | "
            f"{r['collective']:.2e} | {dom} | {frac:.1%} | "
            f"{d['model_flops_ratio']:.2f} | {fit} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    default = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun.json")
    ap.add_argument("--results", default=default)
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    print(render(args.results, args.mesh))


if __name__ == "__main__":
    main()
