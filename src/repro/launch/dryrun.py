import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware:
  * ``.lower().compile()`` must succeed on the single-pod (16×16) and
    multi-pod (2×16×16) production meshes for every assigned cell;
  * ``memory_analysis()`` proves the per-device footprint fits a v5e chip;
  * ``cost_analysis()`` + HLO collective parsing feed §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_0_6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-spot]
Results append to ``results/dryrun.json`` (one record per cell × mesh).
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, skip_shapes          # noqa: E402
from repro.distributed.ctx import (activation_rules, default_decode_rules,  # noqa: E402
                                   default_train_rules)
from repro.distributed.sharding import (batch_specs, cache_specs,        # noqa: E402
                                        param_specs, state_specs, DP)
from repro.launch.hlo_analysis import analyze_hlo                # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.launch.roofline import model_bytes, model_flops       # noqa: E402
from repro.launch.specs import build_cell                        # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P       # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun.json")

# v5e hardware constants (brief §Roofline)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s/link (per-chip effective)

# per-arch microbatch counts for train_4k so activation peaks fit 16 GB HBM
TRAIN_MICROBATCHES = {
    "command_r_plus_104b": 4,     # §Perf T5: explicit-SP fits mb=4 at 9.3 GB
    "chameleon_34b": 4,
    "deepseek_moe_16b": 2,
    "zamba2_7b": 2,
    "minicpm3_4b": 2,
    "seamless_m4t_large_v2": 2,
}

def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             sp: bool = True, remat: str = "full", microbatches: int = 1,
             commit: bool = False, verbose: bool = True) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    grad_sh = None
    cell = build_cell(arch, shape_name, remat=remat,
                      microbatches=microbatches, commit=commit)
    if cell.kind == "train":
        # reduce-scatter grads to their FSDP shards (T7)
        grad_sh = param_specs(cell.args[0]["params"], mesh)
        cell = build_cell(arch, shape_name, remat=remat,
                          microbatches=microbatches, commit=commit,
                          grad_shardings=grad_sh)
    n_chips = int(np.prod(mesh.devices.shape))

    if cell.kind == "train":
        state_sds, batch_sds = cell.args
        in_sh = (state_specs(state_sds, mesh), batch_specs(batch_sds, mesh))
        out_sh = (in_sh[0], NamedSharding(mesh, P()))
    elif cell.kind == "prefill":
        params_sds, batch_sds = cell.args
        in_sh = (param_specs(params_sds, mesh), batch_specs(batch_sds, mesh))
        out_sh = NamedSharding(mesh, P())
    else:
        params_sds, tok_sds, cache_sds = cell.args
        csh = cache_specs(cache_sds, mesh)
        # tokens: DP if divisible else replicated
        from repro.distributed.sharding import _div_ok
        dp = DP(mesh)
        tsh = NamedSharding(mesh, P(dp) if _div_ok(tok_sds.shape[0], mesh, dp)
                            else P())
        in_sh = (param_specs(params_sds, mesh), tsh, csh)
        # frozen-cache decode returns KV deltas (shapes differ from the
        # cache): let GSPMD infer output shardings in that case
        out_sh = (NamedSharding(mesh, P()), csh) if commit else None

    with mesh:
        rules = default_train_rules(mesh, sp=sp)
        if cell.kind == "decode":
            rules.update(default_decode_rules(mesh))
        with activation_rules(rules):
            lowered = jax.jit(cell.step_fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo)                 # loop-aware (scan trip counts)

    flops = float(hc.flops)               # per-device, loops expanded
    bytes_proxy = float(hc.bytes)         # CPU-HLO spill proxy (diagnostic)
    bytes_acc = model_bytes(cell.cfg, cell.shape, n_chips, remat=remat,
                            sp=sp)        # TPU-path analytic HBM traffic
    coll_total = float(hc.coll_total)
    mf = model_flops(cell.cfg, cell.shape) / n_chips   # useful FLOPs/device

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": cell.kind, "sp": sp, "remat": remat,
        "microbatches": microbatches,
        "n_chips": n_chips,
        "per_device": {
            "flops": flops,
            "bytes_accessed": bytes_acc,
            "bytes_xla_cpu_proxy": bytes_proxy,
            "collective_bytes": coll_total,
            "collectives": hc.collective_bytes,
            "builtin_flops_loops_once": float(cost.get("flops", 0.0)),
            "model_flops": mf,
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "arg_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline_s": {
            "compute": flops / PEAK_FLOPS,
            "memory": bytes_acc / HBM_BW,
            "collective": coll_total / ICI_BW,
        },
        "model_flops_ratio": mf / flops if flops else 0.0,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "ok": True,
    }
    if verbose:
        r = rec["roofline_s"]
        dom = max(r, key=r.get)
        print(f"[OK] {arch} × {shape_name} × {rec['mesh']}: "
              f"compute {r['compute']:.3e}s, memory {r['memory']:.3e}s, "
              f"collective {r['collective']:.3e}s -> {dom}-bound "
              f"(compile {rec['compile_s']}s)")
        print(f"     memory_analysis: temp={rec['per_device']['temp_bytes']}"
              f" args={rec['per_device']['arg_bytes']}")
    return rec


def append_result(rec: dict, path: str = RESULTS) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    data = []
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data = [d for d in data
            if not (d["arch"] == rec["arch"] and d["shape"] == rec["shape"]
                    and d["mesh"] == rec["mesh"]
                    and d.get("tag") == rec.get("tag"))]
    data.append(rec)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-sp", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = per-arch auto (TRAIN_MICROBATCHES)")
    ap.add_argument("--commit-cache", action="store_true",
                    help="naive in-graph cache update (baseline decode)")
    ap.add_argument("--tag", default=None,
                    help="label for perf-iteration variants")
    ap.add_argument("--results", default=RESULTS)
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in ARCH_IDS:
            skips = skip_shapes(a)
            for s in SHAPES:
                if s not in skips:
                    cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mb = args.microbatches or TRAIN_MICROBATCHES.get(arch, 1)
            try:
                rec = run_cell(arch, shape, multi_pod=mp,
                               sp=not args.no_sp, remat=args.remat,
                               microbatches=mb,
                               commit=args.commit_cache)
                if args.tag:
                    rec["tag"] = args.tag
                append_result(rec, args.results)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))
                append_result({"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "ok": False, "error": repr(e),
                               "tag": args.tag}, args.results)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nall {len(cells) * len(meshes)} dry-run cells compiled OK")


if __name__ == "__main__":
    main()
