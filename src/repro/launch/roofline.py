"""Analytic MODEL_FLOPS per (arch × shape) + roofline report generation.

MODEL_FLOPS is the *useful* compute of the step:
  train   : 6·N_active·tokens  +  3 × attention-context FLOPs
  prefill : 2·N_active·tokens  +  attention-context FLOPs
  decode  : 2·N_active·batch   +  KV-read attention FLOPs

Attention-context FLOPs (per token pair visited): 4·head_dim (QKᵀ + PV),
halved for causal masks, window-clipped for SWA, latent-rank-sized for MLA;
SSD chunks contribute linear terms.  The ratio MODEL_FLOPS / HLO_FLOPs in
§Roofline exposes remat recompute + dispatch overhead.
"""
from __future__ import annotations

from repro.models.config import AttnKind, Family, ModelConfig, ShapeConfig

__all__ = ["model_flops", "attention_flops", "model_bytes"]


def model_bytes(cfg: ModelConfig, shape: ShapeConfig, n_chips: int,
                *, remat: str = "full", sp: bool = True,
                tp: int = 16) -> float:
    """Analytic per-device HBM traffic of the TPU production path.

    Counts the traffic XLA+Pallas would generate with tiles resident in
    VMEM (the CPU-lowered HLO spills tile buffers and wildly overstates
    HBM bytes — recorded separately as a diagnostic):

      params      read per fwd and per bwd (sharded 1/n_chips);
      opt state   3×fp32 read+write + master write (train);
      activations scan-carry h per layer written+read (seq/TP when SP);
      CE          per-chunk logits written+read once (remat: recomputed);
      KV cache    decode: full read per step + 1-token write;
      SSM state   decode: read+write per layer.
    """
    B, S = shape.global_batch, shape.seq_len
    P_total = cfg.n_params()
    p_bytes = 2.0 * P_total / n_chips            # bf16 shard
    d = cfg.d_model

    if shape.kind == "train":
        fwd_reads = p_bytes
        bwd_reads = p_bytes * (2.0 if remat == "full" else 1.0)
        grads = 4.0 * P_total / n_chips
        opt = (3 * 2 + 1) * 4.0 * P_total / n_chips   # m,v,master rw + p w
        tokens_dev = B * S / n_chips
        act_shard = tp if sp else 1
        acts = cfg.n_layers * (B / max(n_chips // tp, 1)) * (S / act_shard) \
            * d * 2.0 * 2.0                      # carry write+read, bf16
        return fwd_reads + bwd_reads + grads + opt + acts

    if shape.kind == "prefill":
        tokens_dev = B * S / n_chips
        acts = cfg.n_layers * tokens_dev * d * 2.0 * 2.0
        # flash KV re-reads: each q block streams K/V once
        nq = max(S // 512, 1)
        kv_bytes = 2.0 * B * S * cfg.n_kv_heads * cfg.head_dim * 2.0 \
            * cfg.n_layers / n_chips
        return p_bytes + acts + kv_bytes * min(nq, 8)

    # decode
    if cfg.family == Family.SSM:
        state = B * cfg.ssm_n_heads * cfg.ssm_state * cfg.ssm_head_dim \
            * 4.0 * cfg.n_layers / n_chips
        return p_bytes + 2.0 * state
    if cfg.family == Family.HYBRID:
        state = B * cfg.ssm_n_heads * cfg.ssm_state * cfg.ssm_head_dim \
            * 4.0 * cfg.n_layers / n_chips
        n_groups = cfg.n_layers // max(cfg.attn_every, 1)
        kv_len = min(S, cfg.window) if cfg.window else S
        kv = 2.0 * B * kv_len * cfg.n_kv_heads * cfg.head_dim * 2.0 \
            * n_groups / n_chips
        return p_bytes + 2.0 * state + kv
    kv_len = min(S, cfg.window) if cfg.window else S
    if cfg.attn == AttnKind.MLA:
        kv = B * kv_len * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2.0 \
            * cfg.n_layers / n_chips
    else:
        kv = 2.0 * B * kv_len * cfg.n_kv_heads * cfg.head_dim * 2.0 \
            * cfg.n_layers / n_chips
    if cfg.family == Family.ENCDEC:
        kv += 2.0 * B * 4096 * cfg.n_kv_heads * cfg.head_dim * 2.0 \
            * cfg.n_layers / n_chips
    return p_bytes + kv


def attention_flops(cfg: ModelConfig, B: int, S_q: int, S_kv: int,
                    causal: bool) -> float:
    """Total attention context FLOPs for one forward pass, all layers."""
    if cfg.family == Family.SSM:
        return _ssd_flops(cfg, B, S_q) * cfg.n_layers
    if cfg.attn == AttnKind.MLA:
        per_pair = 2.0 * (cfg.qk_nope_dim + cfg.qk_rope_dim) \
            + 2.0 * cfg.v_head_dim
    else:
        per_pair = 4.0 * cfg.head_dim
    pairs = _visible_pairs(S_q, S_kv, causal, cfg.window)
    layers_with_attn = cfg.n_layers
    total = B * cfg.n_heads * pairs * per_pair
    if cfg.family == Family.HYBRID:
        n_groups = cfg.n_layers // max(cfg.attn_every, 1)
        total = B * cfg.n_heads * pairs * per_pair * n_groups \
            + _ssd_flops(cfg, B, S_q) * cfg.n_layers
        return total
    if cfg.family == Family.ENCDEC:
        enc = B * cfg.n_heads * _visible_pairs(S_kv, S_kv, False, 0) \
            * per_pair * cfg.n_enc_layers
        cross = B * cfg.n_heads * S_q * S_kv * per_pair * cfg.n_layers
        return total * 0 + enc + cross + \
            B * cfg.n_heads * _visible_pairs(S_q, S_q, True, 0) \
            * per_pair * cfg.n_layers
    return total * layers_with_attn


def _visible_pairs(S_q: int, S_kv: int, causal: bool, window: int) -> float:
    if causal and S_q == S_kv:
        pairs = S_q * (S_q + 1) / 2.0
        if window and window < S_q:
            pairs = min(pairs, S_q * float(window))
        return pairs
    if window and window < S_kv:
        return S_q * float(window)
    return float(S_q) * S_kv


def _ssd_flops(cfg: ModelConfig, B: int, S: int) -> float:
    """Chunked SSD per layer: intra-chunk quadratic + state updates."""
    Q = min(cfg.ssm_chunk, S)
    nh, P, N = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
    nc = max(S // Q, 1)
    intra = nc * (2.0 * Q * Q * N + 2.0 * Q * Q * P) * nh   # CBᵀ then ·x
    inter = nc * (4.0 * Q * N * P) * nh                     # state in/out
    return B * (intra + inter)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = B * S
        return 6.0 * n_active * tokens + 3.0 * attention_flops(
            cfg, B, S, S, cfg.causal)
    if shape.kind == "prefill":
        tokens = B * S
        return 2.0 * n_active * tokens + attention_flops(
            cfg, B, S, S, cfg.causal)
    # decode: 1 token per sequence over an S-deep cache
    if cfg.family == Family.SSM:
        ctx = 0.0
        for _ in range(1):
            nh, P, N = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
            ctx = B * cfg.n_layers * nh * 4.0 * N * P
        return 2.0 * n_active * B + ctx
    if cfg.family == Family.HYBRID:
        nh, P, N = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
        ssm_ctx = B * cfg.n_layers * nh * 4.0 * N * P
        n_groups = cfg.n_layers // max(cfg.attn_every, 1)
        kv = min(S, cfg.window) if cfg.window else S
        attn_ctx = B * cfg.n_heads * kv * 4.0 * cfg.head_dim * n_groups
        return 2.0 * n_active * B + ssm_ctx + attn_ctx
    kv = min(S, cfg.window) if cfg.window else S
    if cfg.attn == AttnKind.MLA:
        per = 2.0 * (cfg.kv_lora_rank + cfg.qk_rope_dim) \
            + 2.0 * cfg.kv_lora_rank      # absorbed decode
    else:
        per = 4.0 * cfg.head_dim
    attn_ctx = B * cfg.n_heads * kv * per * cfg.n_layers
    if cfg.family == Family.ENCDEC:
        attn_ctx += B * cfg.n_heads * 4096 * 4.0 * cfg.head_dim \
            * cfg.n_layers                # cross-attention reads
    return 2.0 * n_active * B + attn_ctx
