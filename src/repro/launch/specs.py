"""ShapeDtypeStruct input specs + step functions for every dry-run cell.

``input_specs(arch, shape)`` builds weak-type-correct, shardable stand-ins
for every model input with **zero device allocation** (``jax.eval_shape``
over the real init/loss functions), so lowering a 104B model on a CPU host
is free.

``make_step(arch, shape)`` returns the jittable step for the cell's kind:
  train_*   -> train_step(state, batch)
  prefill_* -> prefill(params, batch)         (last-position logits)
  decode_* / long_* -> serve_step(params, tokens, cache)  (1 new token)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models.config import Family, ModelConfig, SHAPES, ShapeConfig
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state, make_train_step

__all__ = ["CellSpec", "build_cell", "ENC_DECODE_CROSS_LEN"]

SDS = jax.ShapeDtypeStruct
TP_DEGREE = 16                       # production model-axis size
ENC_DECODE_CROSS_LEN = 4096          # enc-dec decode: encoder output length
ENC_TRAIN_RATIO = 1                  # enc len == dec len for train/prefill


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    step_fn: object                 # jittable callable
    args: tuple                     # ShapeDtypeStruct pytrees
    kind: str                       # train | prefill | decode


def _eval_sds(fn, *a, **k):
    return jax.eval_shape(fn, *a, **k)


def _params_sds(cfg: ModelConfig, tp: int):
    key = SDS((2,), jnp.uint32)
    return _eval_sds(lambda k: M.init_params(cfg, k, tp=tp), key)


def build_cell(arch: str, shape_name: str, *, tp: int = TP_DEGREE,
               remat: str | None = "full", microbatches: int = 1,
               commit: bool = False, grad_shardings=None,
               dp_total: int | None = None) -> CellSpec:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if remat is not None and shape.kind == "train":
        cfg = dataclasses.replace(cfg, remat=remat)
    B, S = shape.global_batch, shape.seq_len
    params = _params_sds(cfg, tp)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        step = make_train_step(cfg, opt_cfg, tp=tp,
                               microbatches=microbatches,
                               grad_shardings=grad_shardings)
        state = _eval_sds(init_train_state, params)
        batch = {"tokens": SDS((B, S), jnp.int32),
                 "labels": SDS((B, S), jnp.int32)}
        if cfg.family == Family.ENCDEC:
            batch["enc_embeds"] = SDS((B, S * ENC_TRAIN_RATIO, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
        return CellSpec(arch, shape, cfg, step, (state, batch), "train")

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return M.prefill(params, cfg, batch["tokens"], tp,
                             enc_embeds=batch.get("enc_embeds"))
        batch = {"tokens": SDS((B, S if cfg.family != Family.ENCDEC
                                else S // 8), jnp.int32)}
        if cfg.family == Family.ENCDEC:
            batch["enc_embeds"] = SDS((B, S, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
        return CellSpec(arch, shape, cfg, prefill_step, (params, batch),
                        "prefill")

    # decode: one new token against a seq_len-deep cache.  The production
    # path keeps the sequence-sharded cache FROZEN (split-KV + lse merge;
    # KV deltas returned for the serving loop's separate batched commit) —
    # §Perf iteration D1.  commit=True is the naive baseline.
    def serve_step(params, tokens, cache):
        return M.decode_step(params, cfg, tokens, cache, tp,
                             commit=commit)

    enc_len = ENC_DECODE_CROSS_LEN if cfg.family == Family.ENCDEC else 0
    cache = _eval_sds(
        lambda: M.init_decode_cache(cfg, B, S, tp=tp, enc_len=enc_len))
    tokens = SDS((B,), jnp.int32)
    return CellSpec(arch, shape, cfg, serve_step, (params, tokens, cache),
                    "decode")
