"""Paged block pool + two-tier (HBM/host) KV cache under ECI management."""
from repro.cache.block_pool import BlockPool, PageMeta
from repro.cache.tiered import TieredKVCache, TierStats

__all__ = ["BlockPool", "PageMeta", "TieredKVCache", "TierStats"]
