"""Three-tier paged KV cache (HBM pool → managed host tier → recompute)
driven by the ECI/ETICA cache manager.

Level mapping (DESIGN.md §2, extended to the ETICA two-level hierarchy):

    serving tier            trace-replay level      paper device
    -------------------     -------------------     -------------------
    HBM page pool           L1  (``capacity``)      DRAM cache  (ETICA L1)
    managed host tier       L2  (``capacity2``)     SSD cache   (ETICA L2)
    cold recompute          backing store           disk subsystem

A *read* is a prefix-page reuse (decode/prefill hitting a cached page); a
*write* is the admission of a freshly computed page.  Per-tenant write
policy (L1):

  WB — every fresh page is admitted to HBM immediately (classic prefix
       caching: best reuse latency, maximal pool write traffic);
  RO — fresh pages go to the host tier only; a page is *promoted* to HBM
       the first time it is re-read (write-around: pages that are never
       re-read never cost HBM writes or capacity).

With ``manager.capacity2 > 0`` the host tier is *managed*: each tenant owns
a host-page quota (the Analyzer's ``sizes2``), pages evicted from the HBM
pool are **demoted** into the host tier's MRU (``BlockPool.on_evict``), a
host hit promotes the page back into HBM, and pages falling off the host
tier are genuinely gone — the next access is a cold recompute.  With
``capacity2 == 0`` the host tier is unmanaged (retains every page ever
computed), preserving the original two-tier behaviour.

Every event is recorded into preallocated numpy arrays (the batched
Monitor); at window boundaries ``rebalance()`` flushes them to the
``ECICacheManager``, re-runs Alg. 1/3 per level, and applies both quota
vectors and both policy vectors through the pool's quota enforcement and
the host tier's LRU trim — the Actuator.  ``rebalance_seconds``
accumulates the wall time spent in that path.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict

import numpy as np

from repro.cache.block_pool import BlockPool, PageMeta
from repro.core.manager import ECICacheManager
from repro.core.write_policy import WritePolicy

__all__ = ["TieredKVCache", "TierStats"]


@dataclasses.dataclass
class TierStats:
    hbm_hits: int = 0
    host_hits: int = 0
    misses: int = 0                 # page had to be (re)computed
    hbm_writes: int = 0             # endurance metric (paper Eq. 3)
    promotions: int = 0
    bypassed_writes: int = 0
    demotions: int = 0              # HBM victims pushed into the host tier
    host_evictions: int = 0         # pages that fell off the managed host
    rerouted_writes: int = 0        # WB admissions sent to host: L1 down

    @property
    def accesses(self) -> int:
        return self.hbm_hits + self.host_hits + self.misses

    @property
    def hit_ratio(self) -> float:
        return self.hbm_hits / self.accesses if self.accesses else 0.0


class TieredKVCache:
    """Prefix-page cache across tenants with ECI-managed partitioning."""

    def __init__(self, pool: BlockPool, manager: ECICacheManager,
                 window_events: int = 4096):
        self.pool = pool
        self.manager = manager
        self.host: dict[tuple, int] = {}       # key -> host "address"
        self._next_host = 0
        n_tenants = len(manager.tenants)
        self.quotas = {i: None for i in range(n_tenants)}
        self.policies = {i: t.policy for i, t in enumerate(manager.tenants)}
        self.stats = [TierStats() for _ in manager.tenants]
        # managed host tier (L2): per-tenant LRU of resident keys + quota
        self.managed_host = manager.capacity2 > 0
        self.host_lru: dict[int, OrderedDict[tuple, None]] = {
            i: OrderedDict() for i in range(n_tenants)}
        self.host_quotas: dict[int, int | None] = {
            i: None for i in range(n_tenants)}
        pool.on_evict = self._demote
        # batched Monitor: page touches land in preallocated arrays (grown
        # by doubling), flushed to the manager once per window
        self.window_events = window_events
        cap = max(256, min(int(window_events), 1 << 16))
        self._ev_tenant = np.empty(cap, np.int32)
        self._ev_addr = np.empty(cap, np.int64)
        self._ev_read = np.empty(cap, bool)
        self._n_ev = 0
        self.rebalance_seconds = 0.0           # Actuator-path wall time
        # tier-failure state (fail_tier/recover_tier): while a level is in
        # ``_down`` its residents are gone and traffic re-routes to the
        # next tier; the manager handles the policy/quota consequences
        self._down: set[int] = set()
        self.tier_failures = 0
        self.dropped_pages = 0                 # residents lost to crashes
        self.dirty_loss = 0                    # of those, dirty (WB) pages

    # ----------------------------------------------------------- app API
    def _addr(self, key: tuple) -> int:
        """Stable integer address per content key (for the Monitor)."""
        a = self.host.get(key)
        if a is None:
            a = self._next_host
            self._next_host += 1
            self.host[key] = a
        return a

    def _record_event(self, tenant: int, addr: int, read: bool) -> None:
        i = self._n_ev
        if i >= self._ev_addr.size:            # amortized doubling
            self._ev_tenant = np.concatenate(
                [self._ev_tenant, np.empty_like(self._ev_tenant)])
            self._ev_addr = np.concatenate(
                [self._ev_addr, np.empty_like(self._ev_addr)])
            self._ev_read = np.concatenate(
                [self._ev_read, np.empty_like(self._ev_read)])
        self._ev_tenant[i] = tenant
        self._ev_addr[i] = addr
        self._ev_read[i] = read
        self._n_ev = i + 1

    def access_page(self, tenant: int, key: tuple,
                    fresh: bool = False) -> str:
        """One page touch.  fresh=True → this is a newly computed page
        (a *write*); fresh=False → the engine wants to reuse it (a *read*).

        Returns where it was served from: "hbm" | "host" | "miss".
        """
        st = self.stats[tenant]
        self._record_event(tenant, self._addr(key), not fresh)
        served = "miss"
        down1 = 1 in self._down

        if fresh:
            if self.policies[tenant] is WritePolicy.WB and not down1:
                pid, _ = self.pool.allocate(tenant, key,
                                            quota=self.quotas[tenant],
                                            dirty=True)
                if pid is not None:
                    st.hbm_writes += 1
                    served = "hbm"
            elif self.policies[tenant] is WritePolicy.WB:
                # L1 down: WB admission re-routes to the next tier (no HBM
                # write, no dirty page that a second crash could lose)
                st.rerouted_writes += 1
                self._host_insert(tenant, key)
                served = "host"
            else:                               # RO: write-around
                st.bypassed_writes += 1
                self._host_insert(tenant, key)
                served = "host"
        else:
            pid = None if down1 else self.pool.lookup(key)
            if pid is not None:
                st.hbm_hits += 1
                served = "hbm"
            elif key in self.host and self._host_materialized(tenant, key):
                st.host_hits += 1
                served = "host"
                if not down1:
                    # promote on proven reuse (the hierarchy's L2-hit rule)
                    if self.managed_host:
                        self.host_lru[tenant].pop(key, None)
                    pid, _ = self.pool.allocate(tenant, key,
                                                quota=self.quotas[tenant],
                                                dirty=False)
                    if pid is not None:
                        st.hbm_writes += 1
                        st.promotions += 1
                    elif self.managed_host:
                        # promotion refused (quota 0): keep it in host tier
                        self._host_insert(tenant, key)
            else:
                st.misses += 1
        if self._n_ev >= self.window_events:
            self.rebalance()
        return served

    # ------------------------------------------------- managed host tier
    def _host_insert(self, tenant: int, key: tuple) -> None:
        """Admit/refresh a key at the host tier's MRU, enforcing its quota."""
        if not self.managed_host or tenant < 0 or 2 in self._down:
            return
        q = self.host_lru[tenant]
        q[key] = None
        q.move_to_end(key)
        quota = self.host_quotas[tenant]
        if quota is not None:
            while len(q) > max(quota, 0):
                q.popitem(last=False)          # page is gone: next touch
                self.stats[tenant].host_evictions += 1   # recomputes

    def _demote(self, pid: int, meta: PageMeta) -> None:
        """``BlockPool.on_evict``: HBM victim enters the host tier's MRU."""
        if meta.key is None or meta.tenant < 0 or not self.managed_host:
            return
        self.stats[meta.tenant].demotions += 1
        self._host_insert(meta.tenant, meta.key)

    def _host_materialized(self, tenant: int, key: tuple) -> bool:
        if 2 in self._down:
            return False
        if not self.managed_host:
            # legacy: host tier retains every page ever computed
            return True
        return key in self.host_lru.get(tenant, ())

    # ------------------------------------------------------ tier failures
    def tier_down(self, level: int) -> bool:
        return level in self._down

    def fail_tier(self, level: int = 1) -> dict:
        """Crash one tier: drop every resident page (pins do not survive a
        device loss), account dirty pages as ``dirty_loss``, and notify the
        manager (which demotes WB tenants of that level — paper §3's
        reliability rationale).  Traffic re-routes to the next tier until
        ``recover_tier``.  Returns ``{"dropped": n, "dirty": n}``."""
        if level in self._down:
            return {"dropped": 0, "dirty": 0}
        if level == 1:
            dropped = len(self.pool.meta)
            dirty = sum(1 for m in self.pool.meta.values() if m.dirty)
            # a crash is not an eviction: no demotion into the host tier,
            # the data is simply gone
            self.pool.meta.clear()
            self.pool.by_key.clear()
            self.pool.lru.clear()
            self.pool.free = list(range(self.pool.n_pages - 1, -1, -1))
        elif level == 2:
            if not self.managed_host:
                raise ValueError("tier 2 failure requires a managed host "
                                 "tier (manager.capacity2 > 0)")
            dropped = sum(len(q) for q in self.host_lru.values())
            dirty = 0           # demoted/bypassed pages are recomputable
            for i in self.host_lru:
                self.host_lru[i] = OrderedDict()
        else:
            raise ValueError(f"unknown tier level {level}")
        self._down.add(level)
        self.tier_failures += 1
        self.dropped_pages += dropped
        self.dirty_loss += dirty
        self.manager.note_tier_loss(level, dirty)
        # the manager demotes WB tenants of the lost level immediately
        for i, t in enumerate(self.manager.tenants):
            self.policies[i] = t.policy
        return {"dropped": dropped, "dirty": dirty}

    def recover_tier(self, level: int = 1) -> None:
        """Bring a failed tier back (empty): traffic returns, the manager
        stamps the WB demotion cooldown (see ``ECICacheManager``)."""
        if level not in self._down:
            return
        self._down.discard(level)
        self.manager.note_tier_recovery(level)
        for i, t in enumerate(self.manager.tenants):
            self.policies[i] = t.policy

    def add_tenant(self, name: str = "") -> int:
        """Tenant churn on the serving path: a workload joins mid-run.

        Extends every per-tenant structure and registers the tenant with
        the manager (whose next analyze records the ``"join"`` event and
        sizes the newcomer).  Existing tenants' quotas, host tiers and
        monitor state are untouched.
        """
        i = self.manager.add_tenant(name)
        self.quotas[i] = None
        self.policies[i] = self.manager.tenants[i].policy
        self.stats.append(TierStats())
        self.host_lru[i] = OrderedDict()
        self.host_quotas[i] = None
        return i

    def finish_tenant(self, tenant: int) -> None:
        hook = self.pool.on_evict
        self.pool.on_evict = None      # retiring pages are not demotions
        try:
            self.pool.release_tenant(tenant)
        finally:
            self.pool.on_evict = hook
        self.host_lru[tenant] = OrderedDict()
        self.host_quotas[tenant] = 0
        self.quotas[tenant] = 0
        self.manager.retire_tenant(tenant)

    # ------------------------------------------------- Analyzer/Actuator
    def rebalance(self) -> None:
        """Flush the event window into the Monitor, re-run Alg. 1 + Alg. 3
        (per level), apply quota + policy vectors (Actuator)."""
        n = self._n_ev
        if n == 0:
            return
        t0 = time.perf_counter()
        ten = self._ev_tenant[:n]
        ad = self._ev_addr[:n]
        rd = self._ev_read[:n]
        self._n_ev = 0
        for t in range(len(self.manager.tenants)):
            mask = ten == t
            if mask.any():
                self.manager.record(t, ad[mask].copy(), rd[mask].copy())
        joins = self.manager._drain_joined(self.manager.windows_run)
        if joins:
            self.manager._record_events(joins)
        decision = self.manager.analyze(trigger=tuple(joins))
        for i, tstate in enumerate(self.manager.tenants):
            if not tstate.active:
                continue
            self.quotas[i] = int(decision.sizes[i])
            self.policies[i] = tstate.policy
            self.pool.enforce_quota(i, self.quotas[i])
            if self.managed_host and decision.sizes2 is not None:
                self.host_quotas[i] = int(decision.sizes2[i])
                q = self.host_lru[i]
                while len(q) > self.host_quotas[i]:
                    q.popitem(last=False)
                    self.stats[i].host_evictions += 1
            tstate.clear_window()
        self.rebalance_seconds += time.perf_counter() - t0

    # ------------------------------------------------------------ report
    def summary(self) -> dict:
        tot = TierStats()
        for s in self.stats:
            tot.hbm_hits += s.hbm_hits; tot.host_hits += s.host_hits
            tot.misses += s.misses; tot.hbm_writes += s.hbm_writes
            tot.promotions += s.promotions
            tot.bypassed_writes += s.bypassed_writes
            tot.demotions += s.demotions
            tot.host_evictions += s.host_evictions
            tot.rerouted_writes += s.rerouted_writes
        return {
            "hbm_hit_ratio": tot.hit_ratio,
            "hbm_writes": tot.hbm_writes,
            "bypassed_writes": tot.bypassed_writes,
            "promotions": tot.promotions,
            "demotions": tot.demotions,
            "host_evictions": tot.host_evictions,
            "host_resident": sum(len(q) for q in self.host_lru.values()),
            "resident_pages": sum(self.pool.resident(i)
                                  for i in range(len(self.stats))),
            "quotas": dict(self.quotas),
            "host_quotas": dict(self.host_quotas),
            "policies": {i: p.value for i, p in self.policies.items()},
            "rebalance_seconds": self.rebalance_seconds,
            "tier_failures": self.tier_failures,
            "dropped_pages": self.dropped_pages,
            "dirty_loss": self.dirty_loss,
            "rerouted_writes": tot.rerouted_writes,
            "tiers_down": sorted(self._down),
        }
