"""Two-tier paged KV cache (HBM pool + host tier) managed by ECI-Cache.

Mapping (DESIGN.md §2): HBM pool == SSD cache, host tier == HDD subsystem.
A *read* is a prefix-page reuse (decode/prefill hitting a cached page); a
*write* is the admission of a freshly computed page.  Per-tenant write
policy:

  WB — every fresh page is admitted to HBM immediately (classic prefix
       caching: best reuse latency, maximal pool write traffic);
  RO — fresh pages go to the host tier only; a page is *promoted* to HBM
       the first time it is re-read (write-around: pages that are never
       re-read never cost HBM writes or capacity).

Every event is forwarded to the ``ECICacheManager`` Monitor; at window
boundaries ``rebalance()`` applies the Analyzer's sizes (page quotas) and
policies through the pool's quota enforcement — the Actuator.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cache.block_pool import BlockPool
from repro.core.manager import ECICacheManager
from repro.core.write_policy import WritePolicy

__all__ = ["TieredKVCache", "TierStats"]


@dataclasses.dataclass
class TierStats:
    hbm_hits: int = 0
    host_hits: int = 0
    misses: int = 0                 # page had to be (re)computed
    hbm_writes: int = 0             # endurance metric (paper Eq. 3)
    promotions: int = 0
    bypassed_writes: int = 0

    @property
    def accesses(self) -> int:
        return self.hbm_hits + self.host_hits + self.misses

    @property
    def hit_ratio(self) -> float:
        return self.hbm_hits / self.accesses if self.accesses else 0.0


class TieredKVCache:
    """Prefix-page cache across tenants with ECI-managed partitioning."""

    def __init__(self, pool: BlockPool, manager: ECICacheManager,
                 window_events: int = 4096):
        self.pool = pool
        self.manager = manager
        self.host: dict[tuple, int] = {}       # key -> host "address"
        self._next_host = 0
        self.quotas = {i: None for i in range(len(manager.tenants))}
        self.policies = {i: t.policy for i, t in enumerate(manager.tenants)}
        self.stats = [TierStats() for _ in manager.tenants]
        self._events = 0
        self.window_events = window_events
        self._pending: list[tuple[int, int, bool]] = []  # (tenant, addr, read)

    # ----------------------------------------------------------- app API
    def _addr(self, key: tuple) -> int:
        """Stable integer address per content key (for the Monitor)."""
        a = self.host.get(key)
        if a is None:
            a = self._next_host
            self._next_host += 1
            self.host[key] = a
        return a

    def access_page(self, tenant: int, key: tuple,
                    fresh: bool = False) -> str:
        """One page touch.  fresh=True → this is a newly computed page
        (a *write*); fresh=False → the engine wants to reuse it (a *read*).

        Returns where it was served from: "hbm" | "host" | "miss".
        """
        st = self.stats[tenant]
        addr = self._addr(key)
        self._pending.append((tenant, addr, not fresh))
        self._events += 1
        served = "miss"

        if fresh:
            if self.policies[tenant] is WritePolicy.WB:
                pid, _ = self.pool.allocate(tenant, key,
                                            quota=self.quotas[tenant],
                                            dirty=True)
                if pid is not None:
                    st.hbm_writes += 1
                    served = "hbm"
            else:                               # RO: write-around
                st.bypassed_writes += 1
                served = "host"
        else:
            pid = self.pool.lookup(key)
            if pid is not None:
                st.hbm_hits += 1
                served = "hbm"
            elif key in self.host and self._host_materialized(key):
                st.host_hits += 1
                served = "host"
                # promote on proven reuse (RO admission rule)
                pid, _ = self.pool.allocate(tenant, key,
                                            quota=self.quotas[tenant],
                                            dirty=False)
                if pid is not None:
                    st.hbm_writes += 1
                    st.promotions += 1
            else:
                st.misses += 1
        if self._events >= self.window_events:
            self.rebalance()
        return served

    def _host_materialized(self, key: tuple) -> bool:
        # host tier retains every page ever computed (capacity >> HBM)
        return True

    def finish_tenant(self, tenant: int) -> None:
        self.pool.release_tenant(tenant)
        self.manager.retire_tenant(tenant)

    # ------------------------------------------------- Analyzer/Actuator
    def rebalance(self) -> None:
        """Flush the event window into the Monitor, re-run Alg. 1 + Alg. 3,
        apply quotas/policies (Actuator)."""
        if not self._pending:
            return
        ev = np.array(self._pending, dtype=np.int64)
        self._pending.clear()
        self._events = 0
        for t in range(len(self.manager.tenants)):
            rows = ev[ev[:, 0] == t]
            if rows.size:
                self.manager.record(t, rows[:, 1], rows[:, 2].astype(bool))
        decision = self.manager.analyze()
        for i, tstate in enumerate(self.manager.tenants):
            if not tstate.active:
                continue
            self.quotas[i] = int(decision.sizes[i])
            self.policies[i] = tstate.policy
            self.pool.enforce_quota(i, self.quotas[i])
            tstate.clear_window()

    # ------------------------------------------------------------ report
    def summary(self) -> dict:
        tot = TierStats()
        for s in self.stats:
            tot.hbm_hits += s.hbm_hits; tot.host_hits += s.host_hits
            tot.misses += s.misses; tot.hbm_writes += s.hbm_writes
            tot.promotions += s.promotions
            tot.bypassed_writes += s.bypassed_writes
        return {
            "hbm_hit_ratio": tot.hit_ratio,
            "hbm_writes": tot.hbm_writes,
            "bypassed_writes": tot.bypassed_writes,
            "promotions": tot.promotions,
            "resident_pages": sum(self.pool.resident(i)
                                  for i in range(len(self.stats))),
            "quotas": dict(self.quotas),
            "policies": {i: p.value for i, p in self.policies.items()},
        }
