"""Paged KV block pool — the framework's "SSD cache" device substrate.

The pool is a set of fixed-size pages living in device arrays
(``[n_pages, page_size, kv_heads, head_dim]`` per layer per k/v); page
*contents* stay on device and are only touched by JAX ops (scatter of fresh
KV, gather via block tables inside the paged-attention kernel).  Page
*metadata* — free list, per-tenant LRU ordering, content keys for prefix
reuse — is host-side, exactly like vLLM's block manager.

Every metadata operation emits a block-access event (read = page re-use,
write = page admission) that the ECI-Cache ``Monitor`` consumes: the pool
IS the cache the paper's algorithms manage.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PageMeta", "BlockPool"]


@dataclasses.dataclass
class PageMeta:
    tenant: int = -1
    key: tuple | None = None      # content key (tenant, prefix-page hash)
    dirty: bool = False
    pinned: bool = False          # in-flight pages are never evicted


class BlockPool:
    """Host-side manager of a device-resident paged pool.

    Device arrays (one per layer): k_pages/v_pages.  The manager hands out
    page ids; per-tenant LRU + quota enforcement implement the Actuator's
    partition decisions.
    """

    def __init__(self, n_pages: int, page_size: int, n_layers: int,
                 kv_heads: int, head_dim: int, dtype=jnp.bfloat16,
                 allocate_device: bool = True):
        self.n_pages = n_pages
        self.page_size = page_size
        self.shape = (n_layers, n_pages, page_size, kv_heads, head_dim)
        if allocate_device:
            self.k_pages = jnp.zeros(self.shape, dtype)
            self.v_pages = jnp.zeros(self.shape, dtype)
        else:                       # metadata-only mode (tests/benchmarks)
            self.k_pages = self.v_pages = None
        self.free: list[int] = list(range(n_pages - 1, -1, -1))
        self.meta: dict[int, PageMeta] = {}
        # per-tenant LRU of resident pages: tenant -> OrderedDict[page_id]
        self.lru: dict[int, OrderedDict[int, None]] = {}
        self.by_key: dict[tuple, int] = {}
        self.stats = {"admitted": 0, "evicted": 0, "reused": 0,
                      "writes": 0}
        # demote-on-evict hook: called as on_evict(pid, meta) after a page
        # leaves the pool (TieredKVCache pushes the victim into the managed
        # host tier — the L2 of the serving hierarchy)
        self.on_evict = None

    # ------------------------------------------------------------ metadata
    def resident(self, tenant: int) -> int:
        return len(self.lru.get(tenant, ()))

    def lookup(self, key: tuple) -> int | None:
        """Prefix-cache hit test; bumps LRU on hit."""
        pid = self.by_key.get(key)
        if pid is not None:
            m = self.meta[pid]
            self.lru[m.tenant].move_to_end(pid)
            self.stats["reused"] += 1
        return pid

    def allocate(self, tenant: int, key: tuple | None = None,
                 quota: int | None = None,
                 dirty: bool = False) -> tuple[int | None, list[int]]:
        """Allocate one page for ``tenant``; evicts LRU pages of the same
        tenant while over quota.  Returns (page_id | None, evicted_ids)."""
        evicted: list[int] = []
        q = self.lru.setdefault(tenant, OrderedDict())
        if quota is not None and quota <= 0:
            return None, evicted
        while quota is not None and len(q) >= quota:
            v = self._evict_one(tenant)
            if v is None:
                return None, evicted        # all resident pages pinned
            evicted.append(v)
        if not self.free:
            victim = self._evict_any(tenant)
            if victim is None:
                return None, evicted
            evicted.append(victim)
        pid = self.free.pop()
        self.meta[pid] = PageMeta(tenant, key, dirty)
        q[pid] = None
        if key is not None:
            self.by_key[key] = pid
        self.stats["admitted"] += 1
        self.stats["writes"] += 1
        return pid, evicted

    def _evict_one(self, tenant: int) -> int | None:
        q = self.lru[tenant]
        for pid in q:                       # LRU-first, skipping pinned
            if not self.meta[pid].pinned:
                break
        else:
            return None                     # everything in flight
        q.pop(pid)
        m = self.meta.pop(pid)
        if m.key is not None:
            self.by_key.pop(m.key, None)
        self.free.append(pid)
        self.stats["evicted"] += 1
        if self.on_evict is not None:
            self.on_evict(pid, m)
        return pid

    def pin(self, pid: int) -> None:
        if pid in self.meta:
            self.meta[pid].pinned = True

    def unpin(self, pid: int) -> None:
        if pid in self.meta:
            self.meta[pid].pinned = False

    def _evict_any(self, prefer_tenant: int) -> int | None:
        if self.lru.get(prefer_tenant):
            return self._evict_one(prefer_tenant)
        for t, q in self.lru.items():
            if q:
                v = self._evict_one(t)
                if v is not None:
                    return v
        return None

    def release_tenant(self, tenant: int) -> int:
        """Free all pages of a finished tenant (paper §6.3 retire)."""
        n = 0
        for pid in list(self.lru.get(tenant, ())):
            self.meta[pid].pinned = False
        while self.lru.get(tenant):
            if self._evict_one(tenant) is None:
                break
            n += 1
        return n

    def enforce_quota(self, tenant: int, quota: int) -> list[int]:
        """Actuator resize: shrink a tenant's residency to ``quota``."""
        out = []
        q = self.lru.setdefault(tenant, OrderedDict())
        while len(q) > quota:
            v = self._evict_one(tenant)
            if v is None:
                break
            out.append(v)
        return out

    # -------------------------------------------------------- device data
    def write_page(self, layer_slice_k: jax.Array, layer_slice_v: jax.Array,
                   pid: int) -> None:
        """Scatter one page of fresh KV into the pool (all layers).

        layer_slice_*: [n_layers, page_size, kv_heads, head_dim].
        """
        if self.k_pages is None:
            return
        self.k_pages = self.k_pages.at[:, pid].set(layer_slice_k)
        self.v_pages = self.v_pages.at[:, pid].set(layer_slice_v)
