"""Multi-tenant continuous-batching serving engine over the paged pool.

Request lifecycle:
  * prefill — the prompt's KV is computed layer-stacked; the prompt is cut
    into pages; pages whose content key (tenant, prefix-hash) is already in
    the HBM pool are *reused* (read events to ECI-Cache — no recompute
    charge); fresh pages are *admitted* per the tenant's write policy
    (write events).
  * decode — batched single-token steps; attention runs over the pool
    through per-request block tables (the ``paged_attention`` kernel path
    on TPU, its jnp oracle here).  Completed pages become admission events.

The ECI manager observes the event stream; every ``window_events`` it
re-partitions page quotas + write policies across tenants (Actuator =
``BlockPool.enforce_quota``).  The decode path is the "performance" the
paper's hit ratio protects: pages served from the HBM pool avoid the
host-tier fetch penalty.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.block_pool import BlockPool
from repro.cache.tiered import TieredKVCache
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.models.attention import build_heads
from repro.models.config import Family, ModelConfig
from repro.models.layers import rms_norm, swiglu, moe_ffn, apply_rope
from repro.models.model import Param

__all__ = ["Request", "MultiTenantEngine", "prefill_with_kv"]

_F32 = jnp.float32


@dataclasses.dataclass
class Request:
    tenant: int
    prompt: np.ndarray                   # int32[S]
    max_new_tokens: int = 16
    rid: int = -1
    generated: list[int] = dataclasses.field(default_factory=list)
    pages: list[int] = dataclasses.field(default_factory=list)   # pool pids
    length: int = 0                      # tokens with KV in the pool
    done: bool = False


def _prefix_key(tenant: int, tokens: np.ndarray) -> tuple:
    return (tenant, hash(tokens.tobytes()))


@partial(jax.jit, static_argnames=("cfg", "tp"))
def prefill_with_kv(params: Param, cfg: ModelConfig, tokens: jax.Array,
                    tp: int = 1):
    """Forward returning (last_logits, k [L,B,S,Hkv,D], v [L,B,S,Hkv,D])."""
    from repro.models.model import _attn_mlp_block, _lm_head  # noqa
    hq, hkv = build_heads(cfg, tp)
    B, S = tokens.shape
    h = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]

    def body(carry, p_l):
        hh = carry
        x = rms_norm(hh, p_l["ln1"], cfg.rms_eps)
        k = jnp.einsum("bsd,de->bse", x, p_l["attn"]["wk"],
                       preferred_element_type=_F32).astype(x.dtype)
        v = jnp.einsum("bsd,de->bse", x, p_l["attn"]["wv"],
                       preferred_element_type=_F32).astype(x.dtype)
        k = k.reshape(B, S, hkv, cfg.head_dim)
        v = v.reshape(B, S, hkv, cfg.head_dim)
        if cfg.qk_norm:
            k = rms_norm(k, p_l["attn"]["k_norm"], cfg.rms_eps)
        k = apply_rope(k, positions, cfg.rope_theta)
        hh = _attn_mlp_block(p_l, hh, cfg, tp)
        return hh, (k, v)

    h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], _lm_head(params, cfg),
                        preferred_element_type=_F32)
    return logits, ks, vs


class MultiTenantEngine:
    """CPU-runnable reference engine (smoke-scale models)."""

    def __init__(self, cfg: ModelConfig, params: Param,
                 tiered: TieredKVCache, page_size: int = 16,
                 max_pages_per_seq: int = 64, tp: int = 1):
        assert cfg.family in (Family.DENSE, Family.MOE), \
            "reference engine covers attention-KV families"
        self.cfg, self.params, self.tp = cfg, params, tp
        self.tiered = tiered
        self.pool: BlockPool = tiered.pool
        self.page = page_size
        self.max_pages = max_pages_per_seq
        self.active: list[Request] = []
        self.waiting: list[Request] = []
        self._rid = 0
        self.completed: list[Request] = []
        self.aborted_restarts = 0    # requests restarted after a pool crash

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        req.rid = self._rid
        self._rid += 1
        self.waiting.append(req)

    # ----------------------------------------------------------- prefill
    def _prefill_one(self, req: Request) -> None:
        cfg, page = self.cfg, self.page
        prompt = np.asarray(req.prompt, np.int32)
        S = len(prompt)
        n_pages = -(-S // page)
        pad = n_pages * page - S
        tok = jnp.asarray(np.pad(prompt, (0, pad))[None, :])
        logits, ks, vs = prefill_with_kv(self.params, cfg, tok, self.tp)

        for pi in range(n_pages):
            key = _prefix_key(req.tenant, prompt[:(pi + 1) * page])
            pid = self.pool.lookup(key)
            if pid is not None:
                self.tiered.access_page(req.tenant, key, fresh=False)
            else:
                self.tiered.access_page(req.tenant, key, fresh=True)
                pid = self.pool.by_key.get(key)
                if pid is not None and self.pool.k_pages is not None:
                    sl = slice(pi * page, (pi + 1) * page)
                    self.pool.write_page(ks[:, 0, sl], vs[:, 0, sl], pid)
            if pid is None:
                # bypassed (RO) or over quota: the page logically lives in
                # the host tier; stage it unmanaged (tenant -2) so decode
                # can still attend — latency accounting treats it as a
                # host-tier fetch, and it never counts against quotas.
                pid, _ = self.pool.allocate(-2, None, quota=None)
                if pid is not None and self.pool.k_pages is not None:
                    sl = slice(pi * page, (pi + 1) * page)
                    self.pool.write_page(ks[:, 0, sl], vs[:, 0, sl], pid)
            if pid is not None:
                self.pool.pin(pid)
            req.pages.append(pid if pid is not None else 0)
        req.length = S
        req.generated.append(int(jnp.argmax(logits[0, :cfg.vocab_size])))
        self.active.append(req)

    # ------------------------------------------------------------ decode
    def _decode_batch(self) -> None:
        cfg, page = self.cfg, self.page
        reqs = [r for r in self.active if not r.done]
        if not reqs:
            return
        B = len(reqs)
        # ensure every request has a page with room for the next token
        for r in reqs:
            if r.length % page == 0:
                key = (r.tenant, "decode", r.rid, r.length // page)
                self.tiered.access_page(r.tenant, key, fresh=True)
                pid = self.pool.by_key.get(key)
                if pid is None:
                    pid, _ = self.pool.allocate(-2, None, quota=None)
                if pid is not None:
                    self.pool.pin(pid)
                r.pages.append(pid if pid is not None else 0)

        tables = np.zeros((B, self.max_pages), np.int32)
        lens = np.zeros((B,), np.int32)
        toks = np.zeros((B,), np.int32)
        for i, r in enumerate(reqs):
            tables[i, :len(r.pages)] = r.pages
            lens[i] = r.length
            toks[i] = r.generated[-1]
        logits, k_new, v_new = _decode_step_jit(
            self.params, self.pool.k_pages, self.pool.v_pages,
            jnp.asarray(toks), jnp.asarray(tables), jnp.asarray(lens),
            self.cfg, self.tp)
        # scatter the new token's KV into each request's current page
        if self.pool.k_pages is not None:
            pids = np.array([r.pages[r.length // page] for r in reqs])
            offs = np.array([r.length % page for r in reqs])
            L = self.pool.shape[0]
            li = np.repeat(np.arange(L), B)
            pi = np.tile(pids, L)
            oi = np.tile(offs, L)
            kn = k_new.transpose(0, 1, 2, 3)      # [L,B,Hkv,D]
            self.pool.k_pages = self.pool.k_pages.at[li, pi, oi].set(
                kn.reshape(L * B, *kn.shape[2:]))
            vn = v_new.transpose(0, 1, 2, 3)
            self.pool.v_pages = self.pool.v_pages.at[li, pi, oi].set(
                vn.reshape(L * B, *vn.shape[2:]))
        nxt = np.asarray(jnp.argmax(logits[:, :cfg.vocab_size], axis=-1))
        for i, r in enumerate(reqs):
            r.length += 1
            r.generated.append(int(nxt[i]))
            if len(r.generated) >= r.max_new_tokens:
                r.done = True
                self.completed.append(r)
                for pid in r.pages:           # pages stay cached (prefix
                    self.pool.unpin(pid)      # reuse), but become evictable
        self.active = [r for r in self.active if not r.done]

    # ----------------------------------------------------- tier failures
    def _requeue_active(self) -> None:
        """After an HBM-pool crash every in-flight request's KV is gone:
        abort them and restart from the prompt (prepended to ``waiting``
        so they re-admit first once the tier recovers)."""
        for r in self.active:
            for pid in r.pages:
                self.pool.unpin(pid)       # no-op post-crash; safe anytime
            r.pages = []
            r.generated = []
            r.length = 0
            r.done = False
            self.aborted_restarts += 1
        self.waiting[:0] = self.active
        self.active = []

    # -------------------------------------------------------------- loop
    def step(self) -> None:
        if self.tiered.tier_down(1):
            # admission control: no prefill/decode against a dead pool —
            # in-flight work restarts once, new work queues until recovery
            if self.active:
                self._requeue_active()
            return
        while self.waiting:
            self._prefill_one(self.waiting.pop(0))
        self._decode_batch()

    def run(self, max_steps: int = 256) -> None:
        for _ in range(max_steps):
            if not (self.waiting or self.active):
                break
            self.step()


@partial(jax.jit, static_argnames=("cfg", "tp"))
def _decode_step_jit(params, k_pool, v_pool, toks, tables, lens,
                     cfg: ModelConfig, tp: int):
    """Batched one-token decode over the paged pool (jnp oracle path)."""
    hq, hkv = build_heads(cfg, tp)
    B = toks.shape[0]
    h = params["embed"][toks][:, None, :]
    positions = lens[:, None]

    def body(carry, xs):
        hh = carry
        p_l, k_pg, v_pg = xs
        x = rms_norm(hh, p_l["ln1"], cfg.rms_eps)
        q = jnp.einsum("bsd,de->bse", x, p_l["attn"]["wq"],
                       preferred_element_type=_F32).astype(x.dtype)
        q = q.reshape(B, 1, hq, cfg.head_dim)
        k = jnp.einsum("bsd,de->bse", x, p_l["attn"]["wk"],
                       preferred_element_type=_F32).astype(x.dtype)
        k = k.reshape(B, 1, hkv, cfg.head_dim)
        v = jnp.einsum("bsd,de->bse", x, p_l["attn"]["wv"],
                       preferred_element_type=_F32).astype(x.dtype)
        v = v.reshape(B, 1, hkv, cfg.head_dim)
        if cfg.qk_norm:
            q = rms_norm(q, p_l["attn"]["q_norm"], cfg.rms_eps)
            k = rms_norm(k, p_l["attn"]["k_norm"], cfg.rms_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        att = paged_attention_ref(q[:, 0], k_pg, v_pg, tables, lens)
        # exact online merge of the in-flight token's KV (not yet pooled)
        att = _merge_self(q[:, 0], k[:, 0], v[:, 0], att, k_pg, tables,
                          lens, cfg.head_dim, hq, hkv)
        a = att.reshape(B, 1, hq * cfg.head_dim)
        a = jnp.einsum("bse,ed->bsd", a, p_l["attn"]["wo"],
                       preferred_element_type=_F32).astype(x.dtype)
        hh = hh + a
        hn = rms_norm(hh, p_l["ln2"], cfg.rms_eps)
        if cfg.family == Family.MOE:
            hh = hh + moe_ffn(hn, p_l["mlp"], cfg, ep=tp)
        else:
            hh = hh + swiglu(hn, p_l["mlp"]["w_gate"], p_l["mlp"]["w_up"],
                             p_l["mlp"]["w_down"])
        return hh, (k[:, 0], v[:, 0])

    h, (k_new, v_new) = jax.lax.scan(
        body, h, (params["layers"], k_pool, v_pool))
    from repro.models.model import _lm_head
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], _lm_head(params, cfg),
                        preferred_element_type=_F32)
    return logits, k_new, v_new


def _merge_self(q, k_self, v_self, att_pool, k_pg, tables, lens,
                head_dim, hq, hkv):
    """Exact online merge of the current token's KV with pooled attention."""
    rep = hq // hkv
    kr = jnp.repeat(k_self, rep, axis=1)
    vr = jnp.repeat(v_self, rep, axis=1)
    scale = 1.0 / np.sqrt(head_dim)
    # recompute pool logits' logsumexp for exact combination
    from repro.kernels.paged_attention.ref import gather_pages
    kp = gather_pages(k_pg, tables)
    kp = jnp.repeat(kp, rep, axis=2)
    s_pool = jnp.einsum("bhd,bkhd->bhk", q.astype(_F32),
                        kp.astype(_F32)) * scale
    mask = jnp.arange(kp.shape[1])[None, None, :] < lens[:, None, None]
    s_pool = jnp.where(mask, s_pool, -1e30)
    lse_pool = jax.nn.logsumexp(s_pool, axis=-1)
    s_self = jnp.einsum("bhd,bhd->bh", q.astype(_F32),
                        kr.astype(_F32)) * scale
    lse_all = jnp.logaddexp(lse_pool, s_self)
    w_pool = jnp.exp(lse_pool - lse_all)[..., None]
    w_self = jnp.exp(s_self - lse_all)[..., None]
    return (att_pool.astype(_F32) * w_pool
            + vr.astype(_F32) * w_self).astype(att_pool.dtype)
