"""Multi-tenant serving: continuous batching over the ECI-managed pool."""
