"""Vectorized multi-tenant window replay — the batch simulation engine.

Replaces the per-access Python loop in ``simulator.simulate`` with array
programs over occurrence links, for a whole Δt window of **all tenants at
once**.  The engine is *exact*: it reproduces ``simulate()``'s hits,
write_hits, cache_writes, flush charges, total latency and the final LRU
state (the interpreter remains the oracle, property-tested in
``tests/test_batch_sim.py``).

Hit-oracle math
===============

Let ``prev[i]``/``nxt[j]`` be the previous/next occurrence links of the
access stream (``trace.prev_next_occurrence``).  Define the *stack distance*

    SD(i) = #{ j : prev[i] < j < i,  nxt[j] >= i }

— the number of distinct addresses touched strictly between an access and
its previous occurrence (each contributes exactly one ``j``, its last
occurrence inside the window).  For an LRU partition of ``C`` blocks that
**allocates on every access** (the WB and WT policies: reads install on
miss, writes install or touch), Mattson stack inclusion gives the exact
oracle:

    access i is resident  ⟺  prev[i] >= 0  and  SD(i) < C.

``SD`` is computed without any per-access loop as ``SD(i) = F(i) − G(i)``:

  * ``F(i) = #{ j < i : nxt[j] >= i }`` is the number of occurrence
    intervals ``(j, nxt[j]]`` covering ``i`` — an O(n) difference-array
    cumsum (it equals the number of distinct addresses seen before ``i``).
  * ``G(i) = #{ j <= prev[i] : nxt[j] >= i }``.  Because ``nxt[prev[i]] ==
    i``, the queries are the points themselves and ``G`` is a dominance
    count over the point set ``(j, nxt[j])``; it is evaluated for *all*
    accesses at once with a bottom-up merge tree (log n rounds of
    block-sort + ``searchsorted``), O(n log² n) in vectorized numpy.

Write-policy effects
====================

WB/WT share the oracle above (identical stack content; they differ only in
latency/endurance accounting).  RO (write-around) breaks reuse chains at
writes — a write invalidates the cached copy, so a read whose previous
occurrence is a write is always a miss — and writes never install.  The
trace transform is: gate residency on ``is_read[prev[i]]`` and restrict
occupancy to reads.

**RO caveat (why there is a guard):** invalidation *frees the slot
immediately*, and LRU-with-deletion loses the stack property once a
capacity eviction has occurred.  Counterexample at C=2 for trace
``r(a) r(b) r(c) w(b) w(c) r(a)``: the real cache evicted ``a`` at
``r(c)``, so the final read misses, but after the two invalidations only
zero live blocks separate ``r(a)`` from its reuse, so any distance oracle
says hit.  The engine therefore computes the *live count*
``L(t) = #{ j <= t : is_read[j], nxt[j] > t }`` (an O(n) cumsum — numpy on
host, the ``cache_sim`` live-count op on-device on TPU); when
``max L <= C`` the cache never fills, no eviction can occur, and
``resident ⟺ live`` is exact — otherwise that tenant's window is replayed
by the O(n) *eviction-token* loop (below).  WB/WT never need the guard
(no deletions).

Endurance / latency / flush accounting are pure array reductions:
per-address *dirty chains* (segmented cumulative OR over residency
periods, grouped by address), suffix distinct-counts for end-of-trace
evictions, and ``bincount`` per tenant.  Warm cross-window state is
handled exactly by prepending the cache content as pseudo-read accesses
(LRU→MRU order) carrying their dirty flags; the prefix is excluded from
the reported stats.

Two-level hierarchy
===================

For the exclusive ETICA-style hierarchy (see ``simulator``): every touch
moves the block to the global MRU and every L1 victim is demoted to L2's
MRU, so the *union* of both levels is one LRU stack of ``C1 + C2`` blocks
whose top ``C1`` entries are L1 (after ``rebalance_levels`` restored the
"L1 full or L2 empty" invariant at window start).  The same ``SD`` array
therefore classifies each access against **two** thresholds in one pass:

    L1 hit  ⟺  SD < C1        L2 hit  ⟺  C1 <= SD < C1 + C2.

Warm state prepends L2 (LRU→MRU) then L1 (LRU→MRU) — the union stack.
Demotions (= L2 cache writes) are counted in closed form per tenant:
``installs_into_L1 − (final_L1 − initial_L1)`` where ``final_L1 =
min(distinct_addrs, C1)``.  Per-level write policies: ``policy2 != WB``
keeps L2 *clean* — dirty victims flush at demotion, so the dirty chains
segment at L1 exits (``SD >= C1``) instead of union exits, and the flush
eviction test uses the ``C1`` threshold.  Final per-level LRU state is the
union survivor stack split at depth ``C1``.  RO (write-around) keeps the
live-count guard, compared per level (``L1-live = live − untouched warm-L2
blocks``).

Two-level RO under eviction pressure (the token formulation, per level)
=======================================================================

When a two-level RO window fails the guard the stack property is gone
(invalidation leaves a *hole* in L1 that the next install fills without
demoting), but the eviction-token formulation generalizes: every read
position is a token, and each token additionally carries a **level**.
Three facts make the replay O(n) with two forward pointers:

  * *Recency is birth order, per level.*  A touch always creates a new
    token (hit = renewal, promotion = rebirth in L1), so within each
    level the LRU order is token-position order.
  * *Demotion order is position order.*  The demoted victim is always
    L1's minimum live position, which is non-decreasing over time, so L2's
    arrival order (warm L2 first, then demotions) is ascending position.
  * *Every live L1 position exceeds every live L2 position.*  After a
    demotion of position ``q`` all remaining L1 tokens sit above ``q``,
    and later births sit higher still — so the L2 victim scan never has
    to check levels (the lowest live token *is* the L2 victim), and the
    L1 victim scan (``_ro_token_replay_levels``'s ``b1``) just skips
    demoted tokens.

Invalidation frees a slot in whichever level holds the token (a hole:
the next install does *not* demote), an L2 read hit retires its token and
rebirths it in L1 (demoting L1's victim only when L1 is actually full),
and a demotion *transfers* the token to L2 — shortening its death time
only if L2 then overflows (the final eviction, flushed when dirty; with a
clean ``policy2`` the flush happens at the demotion boundary instead and
the token's dirty flag clears).  Afterwards every residency question is
vectorized exactly as in the single-level case, plus ``lvl[prev]`` splits
hits by level.  Both the single-level loop and this two-level
generalization have ``lax.fori_loop`` on-device ports
(``ro_token_replay_device`` / ``ro_token_replay_levels_device``), used
automatically on TPU hosts; the interpreter remains only for genuinely
degenerate windows (empty two-level windows, or warm L2 behind a dead
``C2 <= 0`` level), counted by ``SimResult.fallback``.

On TPU the ``SD`` counting runs on-accelerator via the
``repro.kernels.cache_sim`` Pallas kernel (the occupancy-masked
generalization of ``urd_scan``); on CPU the merge-tree host path is used.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.reuse_distance import RDResult
from repro.core.simulator import (LRUCache, SimResult, rebalance_levels,
                                  simulate)
from repro.core.trace import Trace, prev_next_occurrence
from repro.core.write_policy import WritePolicy

__all__ = [
    "count_prev_ge",
    "count_prev_ge_padded",
    "padded_segment_layout",
    "stack_distances",
    "reuse_distances_fast",
    "ro_token_replay_device",
    "ro_token_replay_levels_device",
    "simulate_batch",
    "simulate_many",
]

# every padded segment width is a power of two and a multiple of the dense
# base-level block, so the base pass never spans two segments (64 trades a
# little dense work for two fewer sort-merge levels)
_PAD_MIN = 64
# single-tape ``count_prev_ge`` switches to the width-bounded sort-merge
# levels once the tape is long enough that searchsorted's global binary
# searches (log n probes over the whole tape per element) dominate
_SORT_MERGE_MIN = 1 << 15


# --------------------------------------------------------------- primitives
def count_prev_ge(y: np.ndarray) -> np.ndarray:
    """cnt[q] = #{ j < q : y[j] >= y[q] }, vectorized merge-tree counting.

    Bottom-up merge levels: at half-size ``s`` every element in the right
    half of a 2s-block counts the elements >= it in the left half — by
    direct broadcast for narrow blocks, by block-local ``searchsorted``
    (composite keys while blocks are many, a python loop once they are
    few) for wide ones.  O(n log² n) array work, int32 throughout, no
    per-element Python loop.  Requires ``0 <= y < 2**31 - 2``.

    Long tapes take the sort-merge level engine instead (the degenerate
    one-segment case of ``count_prev_ge_padded``): same counts, but each
    merge level is one SIMD ``np.sort`` of packed (value, side, position)
    keys instead of a global-array ``searchsorted``.
    """
    m = int(y.shape[0])
    out = np.zeros(m, dtype=np.int64)
    if m <= 1:
        return out
    if m >= _SORT_MERGE_MIN:
        w = _next_pow2(m)
        yp = np.zeros(w, dtype=np.int64)
        yp[:m] = np.asarray(y, dtype=np.int64) + 1   # pads sort below all
        return count_prev_ge_padded(
            yp, np.array([w], dtype=np.int64))[:m].astype(np.int64)
    y = y.astype(np.int32)
    base = np.int64(int(y.max()) + 2)

    # base level: all within-16-block pairs in one dense masked pass
    B0 = 16
    ms0 = -(-m // B0) * B0
    yp0 = np.full(ms0, -1, dtype=np.int32)
    yp0[:m] = y
    blk = yp0.reshape(-1, B0)
    lower = np.arange(B0)[:, None] < np.arange(B0)[None, :]   # j < q
    cnt0 = ((blk[:, :, None] >= blk[:, None, :]) & lower[None]) \
        .sum(axis=1, dtype=np.int64).reshape(-1)
    out[:] = cnt0[:m]

    idx = np.arange(m, dtype=np.int64)
    s, ell = B0, 4
    while s < m:
        width = 2 * s
        ms = -(-m // width) * width              # pad only to this level
        yp = np.full(ms, -1, dtype=np.int32)     # pad < every real value
        yp[:m] = y
        blocks = yp.reshape(-1, width)
        lefts = blocks[:, :s]                                    # [nb, s]
        rights = blocks[:, s:]                                   # [nb, s]
        nb = lefts.shape[0]
        lefts_s = np.sort(lefts, axis=1)
        if nb <= 16:
            n_lt = np.concatenate([
                np.searchsorted(lefts_s[b], rights[b])
                for b in range(nb)])
        else:
            if nb * int(base) < 2**31 - 1:       # int32 composite keys
                row = (np.arange(nb, dtype=np.int32)
                       * np.int32(base))[:, None]
                keys = (lefts_s + np.int32(1) + row).ravel()
                qkeys = (rights + np.int32(1) + row).ravel()
            else:
                row = (np.arange(nb, dtype=np.int64) * base)[:, None]
                keys = (lefts_s.astype(np.int64) + 1 + row).ravel()
                qkeys = (rights.astype(np.int64) + 1 + row).ravel()
            n_lt = (np.searchsorted(keys, qkeys)
                    - (np.arange(nb, dtype=np.int64) * s).repeat(s))
        # queries of this level = positions with bit `ell` set (ascending;
        # pads sit only at the tail, so a head-slice aligns them)
        sel = idx[(idx >> ell) & 1 == 1]
        out[sel] += s - n_lt.reshape(-1)[:sel.size]
        s, ell = width, ell + 1
    return out


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def padded_segment_layout(bounds: np.ndarray):
    """Segment-aligned power-of-two padding for a multi-segment tape.

    Each non-empty segment of ``bounds`` is padded to the next power of two
    (min ``_PAD_MIN``) and the padded segments are laid out in descending
    width order — prefix sums of descending powers of two are multiples of
    every following width, so **every segment starts at a multiple of its
    own padded width**.  A merge tree that stops at each segment's width
    therefore never builds a block spanning two segments.

    Returns ``(src, tpos, base_src, base_pad, widths, total)``:

      src      int[k]    original tape positions of the real entries,
                         grouped by segment in padded-layout order — or
                         ``None`` when the layout keeps the original
                         segment order AND the tape has no empty segments,
                         i.e. ``src`` would be ``arange`` (callers skip
                         their gathers)
      tpos     int[k]    their positions on the padded tape
      base_src int[k]    per-entry original segment start
      base_pad int[k]    per-entry padded segment start
      widths   int64[g]  padded width per non-empty segment (descending)
      total    int       padded tape length (``widths.sum()``)
      starts   int64[g]  original tape start per non-empty segment, in
                         the same (descending-width) layout order

    Index arrays are int32 when everything fits (half the gather traffic).
    """
    bounds = np.asarray(bounds, dtype=np.int64)
    lens = np.diff(bounds)
    act = np.flatnonzero(lens > 0)
    if act.size == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z, z, z, 0, z
    L = lens[act]
    W = (1 << np.ceil(np.log2(L)).astype(np.int64))
    W = np.where(W < L, W * 2, W)                # guard float rounding
    W = np.maximum(W, _PAD_MIN)
    order = np.argsort(-W, kind="stable")        # descending, ties stable
    Ws, Ls, segs = W[order], L[order], act[order]
    total = int(Ws.sum())
    row_base = np.concatenate([[0], np.cumsum(Ws)[:-1]]).astype(np.int64)
    csl = np.concatenate([[0], np.cumsum(Ls)[:-1]]).astype(np.int64)
    k = int(Ls.sum())
    idt = np.int32 if max(total, int(bounds[-1])) < 2**31 else np.int64
    loc = np.arange(k, dtype=idt) - np.repeat(csl.astype(idt), Ls)
    base_src = np.repeat(bounds[segs].astype(idt), Ls)
    base_pad = np.repeat(row_base.astype(idt), Ls)
    identity = (act.size == lens.size and int(bounds[0]) == 0
                and bool(np.all(W[:-1] >= W[1:])))
    src = None if identity else base_src + loc
    return src, base_pad + loc, base_src, base_pad, Ws, total, bounds[segs]


def padded_tape_links(prev: np.ndarray, nxt: np.ndarray, layout
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Scatter severed/clamped occurrence links onto the padded tape.

    ``prev``/``nxt`` live on the original multi-segment tape
    (``monitor._segment_links`` semantics); ``layout`` is the tape's
    ``padded_segment_layout``.  Returns ``(gprev, gnxt, gocc)`` on the
    padded tape: real entries carry their links shifted into padded
    coordinates, padding rows the cold/non-occupying sentinels
    (``gprev = -1``, self-``gnxt``, ``gocc = 0``) whose contributions to
    any in-segment dominance count are identically zero.  This is the one
    ingest format shared by the per-width accelerator launches
    (``kernels.cache_sim.ops.stack_distances_segments_accel``) and the
    fused device window program (``core.device_pipeline``).
    """
    src, tpos, base_src, base_pad, widths, total, _ = layout
    n = prev.shape[0]
    if src is None:                              # layout kept tape order
        src = np.arange(n, dtype=tpos.dtype if tpos.size else np.int64)
    shift = (tpos - src).astype(np.int64)
    gprev = np.full(total, -1, dtype=np.int64)
    gprev[tpos] = np.where(prev[src] >= 0, shift + prev[src], -1)
    gnxt = np.arange(total, dtype=np.int64)
    gnxt[tpos] = base_pad.astype(np.int64) + (nxt[src] - base_src)
    gocc = np.zeros(total, dtype=np.int32)
    gocc[tpos] = 1
    return gprev, gnxt, gocc


def count_prev_ge_padded(y: np.ndarray, seg_widths: np.ndarray) -> np.ndarray:
    """Width-bounded merge-tree counting on a padded, segment-aligned tape.

    ``y.size == seg_widths.sum()``; widths are powers of two
    ``>= _PAD_MIN`` in descending order and every width-W segment starts
    at a multiple of W (``padded_segment_layout``).  Returns, per position q,
    ``#{ j < q, same segment : y[j] >= y[q] }``: the merge recursion for a
    segment stops at its own padded width, so no merge level ever spans two
    segments and the deep global levels of the unpadded tree (whose
    contributions to in-segment queries provably cancel — see
    ``repro.core.monitor``) are simply never built.  Padding entries must
    carry ``y = 0`` with every real entry ``>= 1``; a pad then sorts below
    every real query and contributes nothing to its >=-count.

    Each level is one SIMD ``np.sort`` over packed
    ``(value << pb+1) | (is_left << pb) | local_position`` keys: after the
    sort, the k-th right-half element of a block at merged position p has
    exactly ``p - k`` strictly-smaller left elements (equal-valued lefts
    pack *above* rights, so ties count toward >=), and its own local
    position rides along in the low bits for the scatter back — no
    ``argsort`` and no ``searchsorted`` anywhere.  Counts are returned as
    int32 (they never exceed the segment width); tapes must be shorter
    than 2**31.
    """
    m = int(y.shape[0])
    if m == 0:
        return np.zeros(0, dtype=np.int32)
    seg_widths = np.asarray(seg_widths, dtype=np.int64)
    wmax = int(seg_widths[0])
    y = np.asarray(y)
    ymax = int(y.max(initial=0))
    vb = max(ymax.bit_length(), 1)                    # value bits
    pb = (wmax - 1).bit_length()                      # local-position bits
    kdt = np.int32 if vb + pb + 2 <= 32 else np.int64
    yk = y.astype(kdt, copy=False)
    # counts never exceed the segment width, and every index fits int32:
    # the whole pass runs in int32 to halve the memory traffic
    out = np.zeros(m, dtype=np.int32)
    # base level: dense all-pairs inside _PAD_MIN-blocks (every width
    # divides into whole blocks, so the dense pass never spans segments),
    # column-transposed so each of the B0(B0-1)/2 compares is contiguous
    blk_t = np.ascontiguousarray(yk.reshape(-1, _PAD_MIN).T)
    cnt_t = np.zeros(blk_t.shape, dtype=np.int32)
    for q in range(1, _PAD_MIN):
        cq, bq = cnt_t[q], blk_t[q]
        for j in range(q):
            cq += blk_t[j] >= bq
    out[:] = cnt_t.T.ravel()
    if wmax <= _PAD_MIN:
        return out
    ysh = yk << (pb + 1)                              # value field, reused
    csw = np.cumsum(seg_widths)
    iota = np.arange(m // 2, dtype=np.int32)
    kbuf = np.empty(m, dtype=kdt)                     # per-level scratch:
    abuf = np.empty(m, dtype=kdt)                     # reused allocations
    mbuf = np.empty(m, dtype=bool)
    s = _PAD_MIN
    while s < wmax:
        w = 2 * s
        # segments narrower than 2s have finished merging; the live
        # prefix of the descending-width layout is exactly width >= 2s
        n_seg = int(np.searchsorted(-seg_widths, -w, side="right"))
        mlvl = int(csw[n_seg - 1])
        nb = mlvl // w
        lpos = np.arange(w, dtype=kdt)
        combo = ((lpos < s).astype(kdt) << pb) | lpos
        kv = kbuf[:mlvl].reshape(nb, w)
        np.bitwise_or(ysh[:mlvl].reshape(nb, w), combo[None, :], out=kv)
        kv.sort(axis=1)                               # in-place SIMD sort
        M = kbuf[:mlvl]
        np.bitwise_and(M, kdt(1 << pb), out=abuf[:mlvl])
        np.equal(abuf[:mlvl], 0, out=mbuf[:mlvl])
        pf = np.flatnonzero(mbuf[:mlvl]).astype(np.int32)
        n_ge = np.int32(s) - ((pf & np.int32(w - 1))
                              - (iota[: pf.size] & np.int32(s - 1)))
        tgt = (pf & np.int32(-w)) + (M[pf] & np.int32((1 << pb) - 1))
        out[tgt] += n_ge
        s = w
    return out


def _coverage_counts(nxt: np.ndarray) -> np.ndarray:
    """F[i] = #{ j < i : nxt[j] >= i } via a difference array, O(n)."""
    n = nxt.shape[0]
    d = -np.bincount(np.minimum(nxt, n) + 1,
                     minlength=n + 2)[:n + 2]    # interval ends after nxt[j]
    d[1:n + 1] += 1                              # starts at j+1
    return np.cumsum(d)[:n + 1]


def _stack_distances_padded(prev: np.ndarray, nxt: np.ndarray,
                            bounds: np.ndarray,
                            layout=None) -> np.ndarray:
    """Exact SD for a multi-segment tape via the padded pow2 layout.

    One width-bounded counting pass covers every segment at once: real
    entries carry their segment-local ``nxt`` (>= 1), padding entries the
    sentinel ``y = 0`` / empty coverage interval, so the padded tape is
    bit-identical to running each segment alone (property-tested in
    ``tests/test_monitor_padding.py``).
    """
    n = prev.shape[0]
    sd = np.full(n, -1, dtype=np.int64)
    src, tpos, base_src, base_pad, widths, total, _ = \
        layout if layout is not None else padded_segment_layout(bounds)
    if tpos.size == 0:
        return sd
    # F needs no padded tape: on the severed/clamped original tape a
    # cross-segment interval can only reach a segment's *first* position
    # (which is cold), so the global coverage count equals the
    # segment-local one at every hot access — the same cancellation
    # argument as the merge tree's (see repro.core.monitor)
    F = _coverage_counts(nxt)
    gy = np.zeros(total, dtype=np.int32 if total < 2**31 else np.int64)
    if src is None:                              # layout kept tape order
        gy[tpos] = nxt - base_src                # local nxt in [1, L]
        cnt = count_prev_ge_padded(gy, widths)
        sh = np.flatnonzero(prev >= 0)
        gprev = (tpos[sh] - sh).astype(np.int64) + prev[sh]
        sd[sh] = F[sh] - (cnt[gprev] + 1)
        return sd
    gy[tpos] = nxt[src] - base_src               # assignment casts in place
    cnt = count_prev_ge_padded(gy, widths)
    pl = prev[src]
    hot = pl >= 0
    gprev = (tpos[hot] - src[hot]).astype(np.int64) + pl[hot]
    sh = src[hot]                                # same in-segment offset
    sd[sh] = F[sh] - (cnt[gprev] + 1)
    return sd


def _stack_distances_host(prev: np.ndarray, nxt: np.ndarray,
                          bounds: np.ndarray | None = None,
                          layout=None) -> np.ndarray:
    """Exact SD per access (occupancy = every access); -1 for cold.

    ``bounds`` (optional) splits the tape into independent contiguous
    blocks (one per tenant: links never cross).  Multi-segment tapes go
    through the segment-aligned padded layout — one width-bounded counting
    pass for all tenants, no per-segment Python loop (see
    ``padded_segment_layout`` / ``count_prev_ge_padded``); callers that
    already hold the tape's ``padded_segment_layout`` pass it as
    ``layout`` to avoid recomputing it.
    """
    n = prev.shape[0]
    sd = np.full(n, -1, dtype=np.int64)
    if n == 0:
        return sd
    if bounds is not None and len(bounds) > 2:
        return _stack_distances_padded(prev, nxt, bounds, layout)
    s, e = (0, n) if bounds is None else (int(bounds[0]), int(bounds[-1]))
    if e <= s:
        return sd
    pl = prev[s:e]
    nl = nxt[s:e] - s
    F = _coverage_counts(nl)
    cnt = count_prev_ge(nl)
    idx = np.flatnonzero(pl >= 0)                # links never cross blocks
    sd[s + idx] = F[idx] - (cnt[pl[idx] - s] + 1)
    return sd


def _accel_default() -> bool:
    """True when SD counting should run in the Pallas kernel (TPU host)."""
    global _ACCEL
    if _ACCEL is None:
        try:
            import jax
            _ACCEL = jax.default_backend() == "tpu"
        except Exception:  # pragma: no cover - jax always present in-tree
            _ACCEL = False
    return _ACCEL


_ACCEL: bool | None = None


def stack_distances(trace: Trace, backend: str = "auto") -> np.ndarray:
    """Per-access LRU stack distances (TRD samples at every re-touch).

    backend: "host" (numpy merge tree), "accel" (cache_sim Pallas kernel /
    jnp oracle), or "auto" (kernel on TPU, host otherwise).
    """
    prev, nxt = prev_next_occurrence(trace.addrs)
    if backend == "auto":
        backend = "accel" if _accel_default() else "host"
    if backend == "accel":
        from repro.kernels.cache_sim.ops import stack_distances_accel
        return stack_distances_accel(prev, nxt)
    return _stack_distances_host(prev, nxt)


def reuse_distances_fast(trace: Trace, kind: str = "urd",
                         backend: str = "auto") -> RDResult:
    """Drop-in for ``reuse_distances`` built on the vectorized SD engine.

    Same output, no per-access Python loop: the production Analyzer path.
    """
    if kind not in ("trd", "urd"):
        raise ValueError(f"kind must be 'trd' or 'urd', got {kind!r}")
    sd = stack_distances(trace, backend)
    out = sd.copy()
    if kind == "urd":
        out[~trace.is_read] = -1
    return RDResult(out, kind)


# ------------------------------------------------------------ batch replay
def _ro_token_replay(is_read_blk: np.ndarray, prev_blk: np.ndarray,
                     nxt_blk: np.ndarray, force_blk: np.ndarray,
                     cap: int) -> tuple[np.ndarray, np.ndarray, int]:
    """Exact RO (write-around) replay under capacity pressure, O(n).

    Token formulation: every read position ``j`` is a cache slot "token"
    alive on ``(j, nxt[j])`` — independent of hit/miss, because a read hit
    retires the previous token and births a new one simultaneously (net
    resident count 0) while a miss is a pure birth and a write-hit a pure
    death.  Evictions only *shorten* a token's death time, and the LRU
    victim is always the minimum live token, which is non-decreasing over
    time — so a single forward bottom pointer suffices and the whole
    replay is one O(n) integer pass with no dictionary.  Afterwards every
    residency question is vectorized: access ``i`` hit ⟺ its previous
    occurrence ``p`` was a read whose token survived to its natural death
    (``death[p] == i``).

    Returns (death, dirty, flushes): ``death[j]`` = when token j left the
    cache (== ``nxt_blk[j]`` iff never evicted), ``dirty[j]`` = the dirty
    flag the token carried (inherited from warm-prefix blocks through hit
    chains; RO installs are clean), ``flushes`` = dirty evictions.
    """
    n = int(is_read_blk.shape[0])
    rd = is_read_blk.tolist()
    pv = prev_blk.tolist()
    death = nxt_blk.tolist()
    dirty = force_blk.tolist()
    flushes = 0
    resident = 0
    b = 0                                        # oldest-resident candidate
    for t in range(n):
        p = pv[t]
        if rd[t]:
            if p >= 0 and rd[p] and death[p] == t:
                dirty[t] = dirty[p]              # hit: token renewal
            else:
                resident += 1                    # miss: install clean
                if resident > cap:
                    while not rd[b] or death[b] <= t:
                        b += 1
                    death[b] = t                 # evict oldest resident
                    if dirty[b]:
                        flushes += 1
                    resident -= 1
        elif p >= 0 and rd[p] and death[p] == t:
            resident -= 1                        # write-hit: invalidate
    return (np.asarray(death, dtype=np.int64),
            np.asarray(dirty, dtype=bool), flushes)


def _ro_token_replay_levels(is_read_blk: np.ndarray, prev_blk: np.ndarray,
                            nxt_blk: np.ndarray, force_blk: np.ndarray,
                            cap1: int, cap2: int, l2_end: int,
                            clean2: bool
                            ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                       int, int]:
    """Exact two-level RO replay under eviction pressure, O(n).

    The eviction-token loop generalized to the exclusive demote/promote
    hierarchy (see the module docstring): each token carries a *level*,
    recency within each level is token-position order, demotions transfer
    the L1 victim (minimum live L1 position — non-decreasing, so a forward
    pointer ``b1`` that skips demoted tokens suffices) into L2, and an L2
    overflow evicts the globally-lowest live token (``b2``; always L2,
    because live L1 positions provably sit above all live L2 positions).
    Invalidation frees a slot in whichever level holds the token; the next
    install fills the hole without demoting.  ``clean2`` flushes dirty
    victims at the demotion boundary (entering L2 clean) instead of at the
    final L2 eviction.

    Positions below ``l2_end`` are the warm-L2 pseudo-reads: their tokens
    are born directly in L2.  Warm-L1 pseudo-reads need no special case —
    they are read misses installing into a never-overflowing L1.

    Returns ``(death, dirty, lvl, flushes, demotions)``: ``death[j]`` =
    when token j left the hierarchy entirely (== ``nxt_blk[j]`` iff never
    evicted from L2), ``dirty[j]`` = the flag the token carried, ``lvl[j]``
    = the level it occupied when it died (splits hits per level),
    ``flushes`` = dirty evictions/demotion-flushes, ``demotions`` = L2
    cache writes.
    """
    n = int(is_read_blk.shape[0])
    rd = is_read_blk.tolist()
    pv = prev_blk.tolist()
    death = nxt_blk.tolist()
    dirty = force_blk.tolist()
    lvl = [1] * n
    flushes = demotions = 0
    res1 = res2 = 0
    b1 = b2 = 0                                  # per-level victim candidates
    for t in range(n):
        if t < l2_end:
            lvl[t] = 2                           # warm-L2 token: born in L2
            res2 += 1
            continue
        p = pv[t]
        if rd[t]:
            if p >= 0 and rd[p] and death[p] == t:
                dirty[t] = dirty[p]              # hit: token renewal
                if lvl[p] == 1:
                    continue                     # L1 hit: occupancy unchanged
                res2 -= 1                        # L2 hit: promote out of L2
            res1 += 1                            # install / rebirth into L1
            if res1 > cap1:
                while not rd[b1] or death[b1] <= t or lvl[b1] == 2:
                    b1 += 1                      # min live L1 token
                lvl[b1] = 2                      # demote into L2's MRU
                if clean2 and dirty[b1]:
                    flushes += 1                 # flush at the demotion
                    dirty[b1] = False
                res1 -= 1
                res2 += 1
                demotions += 1
                if res2 > cap2:
                    while not rd[b2] or death[b2] <= t:
                        b2 += 1                  # min live token == L2 victim
                    death[b2] = t                # evicted for good
                    if dirty[b2]:
                        flushes += 1
                    res2 -= 1
        elif p >= 0 and rd[p] and death[p] == t:
            if lvl[p] == 1:                      # write-hit: invalidate the
                res1 -= 1                        # holding level (a hole)
            else:
                res2 -= 1
    return (np.asarray(death, dtype=np.int64),
            np.asarray(dirty, dtype=bool),
            np.asarray(lvl, dtype=np.int8), flushes, demotions)


_RO_DEVICE_JIT = None
_RO_LEVELS_DEVICE_JIT = None


def _ro_device_core():
    """Build (and cache) the jitted sequential token-replay loop."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def run(rd, pv, nxt, force, cap):
        n = rd.shape[0]

        def body(t, carry):
            death, dirty, fl, res, b = carry
            p = pv[t]
            ps = jnp.maximum(p, 0)
            hit = (p >= 0) & rd[ps] & (death[ps] == t)

            def read_case(c):
                death, dirty, fl, res, b = c

                def on_hit(c):
                    death, dirty, fl, res, b = c
                    return (death, dirty.at[t].set(dirty[ps]), fl, res, b)

                def on_miss(c):
                    death, dirty, fl, res, b = c
                    res = res + 1

                    def evict(c):
                        death, dirty, fl, res, b = c
                        b = jax.lax.while_loop(
                            lambda bb: (~rd[bb]) | (death[bb] <= t),
                            lambda bb: bb + 1, b)
                        fl = fl + dirty[b].astype(jnp.int32)
                        return (death.at[b].set(t), dirty, fl, res - 1, b)

                    return jax.lax.cond(res > cap, evict, lambda c: c,
                                        (death, dirty, fl, res, b))

                return jax.lax.cond(hit, on_hit, on_miss, c)

            def write_case(c):
                death, dirty, fl, res, b = c
                return (death, dirty, fl, res - hit.astype(jnp.int32), b)

            return jax.lax.cond(rd[t], read_case, write_case, carry)

        death0 = nxt.astype(jnp.int32)
        carry = (death0, force, jnp.int32(0), jnp.int32(0), jnp.int32(0))
        death, dirty, fl, _, _ = jax.lax.fori_loop(0, n, body, carry)
        return death, dirty, fl

    return run


def ro_token_replay_device(is_read_blk: np.ndarray, prev_blk: np.ndarray,
                           nxt_blk: np.ndarray, force_blk: np.ndarray,
                           cap: int) -> tuple[np.ndarray, np.ndarray, int]:
    """``_ro_token_replay`` as a ``lax.fori_loop`` sequential device pass.

    Same token formulation, same outputs (the host loop stays the oracle —
    equivalence-tested on randomized RO-pressure traces); the whole replay
    is one fori_loop with an inner while advancing the bottom pointer, so
    RO tenants under eviction pressure stay on-device on TPU hosts.  Inputs
    are padded to a multiple of 64 with no-op writes (``prev = -1``) to
    bound jit retraces across window lengths.
    """
    import jax.numpy as jnp
    global _RO_DEVICE_JIT
    if _RO_DEVICE_JIT is None:
        _RO_DEVICE_JIT = _ro_device_core()
    n = int(is_read_blk.shape[0])
    if n == 0:
        return (np.zeros(0, np.int64), np.zeros(0, bool), 0)
    pad = (-n) % 64
    rd = np.pad(is_read_blk.astype(bool), (0, pad), constant_values=False)
    pv = np.pad(prev_blk.astype(np.int32), (0, pad), constant_values=-1)
    nx = np.pad(nxt_blk.astype(np.int32), (0, pad), constant_values=n + pad)
    fc = np.pad(force_blk.astype(bool), (0, pad), constant_values=False)
    death, dirty, fl = _RO_DEVICE_JIT(jnp.asarray(rd), jnp.asarray(pv),
                                      jnp.asarray(nx), jnp.asarray(fc),
                                      jnp.int32(cap))
    death = np.asarray(death)[:n].astype(np.int64)
    # padded positions never evict, so real token deaths are unaffected,
    # but clamp natural deaths back to the unpadded horizon
    death = np.minimum(death, nxt_blk.astype(np.int64))
    return death, np.asarray(dirty)[:n].astype(bool), int(fl)


def _ro_levels_device_core():
    """Build (and cache) the jitted two-level token-replay loop."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def run(rd, pv, nxt, force, cap1, cap2, l2_end, clean2):
        n = rd.shape[0]

        def body(t, carry):
            death, dirty, lvl, fl, res1, res2, b1, b2, dem = carry
            p = pv[t]
            ps = jnp.maximum(p, 0)
            hit = (p >= 0) & rd[ps] & (death[ps] == t)
            hit1 = hit & (lvl[ps] == 1)
            hit2 = hit & (lvl[ps] == 2)

            def warm_l2(c):
                death, dirty, lvl, fl, res1, res2, b1, b2, dem = c
                return (death, dirty, lvl.at[t].set(2), fl,
                        res1, res2 + 1, b1, b2, dem)

            def demote(c):
                death, dirty, lvl, fl, res1, res2, b1, b2, dem = c
                b1 = jax.lax.while_loop(
                    lambda b: (~rd[b]) | (death[b] <= t) | (lvl[b] == 2),
                    lambda b: b + 1, b1)
                fl = fl + (clean2 & dirty[b1]).astype(jnp.int32)
                dirty = dirty.at[b1].set(dirty[b1] & ~clean2)
                lvl = lvl.at[b1].set(2)
                c2s = (death, dirty, fl, res2 + 1, b2)

                def evict2(c2):
                    death, dirty, fl, res2, b2 = c2
                    b2 = jax.lax.while_loop(
                        lambda b: (~rd[b]) | (death[b] <= t),
                        lambda b: b + 1, b2)
                    fl = fl + dirty[b2].astype(jnp.int32)
                    return (death.at[b2].set(t), dirty, fl, res2 - 1, b2)

                death, dirty, fl, res2, b2 = jax.lax.cond(
                    res2 + 1 > cap2, evict2, lambda c2: c2, c2s)
                return (death, dirty, lvl, fl, res1 - 1, res2, b1, b2,
                        dem + 1)

            def read_case(c):
                def on_hit1(c):
                    death, dirty, lvl, fl, res1, res2, b1, b2, dem = c
                    return (death, dirty.at[t].set(dirty[ps]), lvl, fl,
                            res1, res2, b1, b2, dem)

                def on_other(c):
                    # promotion (hit2) or miss: a new token born in L1
                    death, dirty, lvl, fl, res1, res2, b1, b2, dem = c
                    dirty = dirty.at[t].set(
                        jnp.where(hit2, dirty[ps], dirty[t]))
                    res1 = res1 + 1
                    res2 = res2 - hit2.astype(jnp.int32)
                    c = (death, dirty, lvl, fl, res1, res2, b1, b2, dem)
                    return jax.lax.cond(res1 > cap1, demote,
                                        lambda c: c, c)

                return jax.lax.cond(hit1, on_hit1, on_other, c)

            def write_case(c):
                death, dirty, lvl, fl, res1, res2, b1, b2, dem = c
                return (death, dirty, lvl, fl,
                        res1 - hit1.astype(jnp.int32),
                        res2 - hit2.astype(jnp.int32), b1, b2, dem)

            def window(c):
                return jax.lax.cond(rd[t], read_case, write_case, c)

            return jax.lax.cond(t < l2_end, warm_l2, window, carry)

        carry = (nxt.astype(jnp.int32), force, jnp.ones(n, jnp.int32),
                 jnp.int32(0), jnp.int32(0), jnp.int32(0),
                 jnp.int32(0), jnp.int32(0), jnp.int32(0))
        death, dirty, lvl, fl, _, _, _, _, dem = jax.lax.fori_loop(
            0, n, body, carry)
        return death, dirty, lvl, fl, dem

    return run


def ro_token_replay_levels_device(is_read_blk: np.ndarray,
                                  prev_blk: np.ndarray, nxt_blk: np.ndarray,
                                  force_blk: np.ndarray, cap1: int,
                                  cap2: int, l2_end: int, clean2: bool
                                  ) -> tuple[np.ndarray, np.ndarray,
                                             np.ndarray, int, int]:
    """``_ro_token_replay_levels`` as a ``lax.fori_loop`` device pass.

    Same token formulation, same outputs (the host loop stays the oracle —
    equivalence-tested on randomized two-level RO-pressure traces), so
    two-level RO tenants under eviction pressure stay on-device on TPU
    hosts.  Inputs are padded to a multiple of 64 with no-op writes
    (``prev = -1``) to bound jit retraces across window lengths.
    """
    import jax.numpy as jnp
    global _RO_LEVELS_DEVICE_JIT
    if _RO_LEVELS_DEVICE_JIT is None:
        _RO_LEVELS_DEVICE_JIT = _ro_levels_device_core()
    n = int(is_read_blk.shape[0])
    if n == 0:
        return (np.zeros(0, np.int64), np.zeros(0, bool),
                np.zeros(0, np.int8), 0, 0)
    pad = (-n) % 64
    rd = np.pad(is_read_blk.astype(bool), (0, pad), constant_values=False)
    pv = np.pad(prev_blk.astype(np.int32), (0, pad), constant_values=-1)
    nx = np.pad(nxt_blk.astype(np.int32), (0, pad), constant_values=n + pad)
    fc = np.pad(force_blk.astype(bool), (0, pad), constant_values=False)
    death, dirty, lvl, fl, dem = _RO_LEVELS_DEVICE_JIT(
        jnp.asarray(rd), jnp.asarray(pv), jnp.asarray(nx), jnp.asarray(fc),
        jnp.int32(cap1), jnp.int32(cap2), jnp.int32(l2_end),
        jnp.asarray(bool(clean2)))
    death = np.asarray(death)[:n].astype(np.int64)
    # padded positions never evict, so real token deaths are unaffected,
    # but clamp natural deaths back to the unpadded horizon
    death = np.minimum(death, nxt_blk.astype(np.int64))
    return (death, np.asarray(dirty)[:n].astype(bool),
            np.asarray(lvl)[:n].astype(np.int8), int(fl), int(dem))


def _segment_heads(sorted_vals: np.ndarray) -> np.ndarray:
    head = np.ones(sorted_vals.shape[0], dtype=bool)
    head[1:] = sorted_vals[1:] != sorted_vals[:-1]
    return head


def simulate_many(traces: list[Trace], capacities=None, policies=None, *,
                  t_fast: float = 1.0, t_slow: float = 20.0,
                  t_write_bypass: float | None = None,
                  flush_cost: float = 0.0,
                  caches: list[LRUCache | None] | None = None,
                  capacities2=None, policies2=None,
                  caches2: list[LRUCache | None] | None = None,
                  t_fast2: float | None = None,
                  return_window_rd: bool = False):
    """Replay one window for every tenant at once (exact, vectorized).

    Mirrors ``simulate()`` per tenant: when ``caches[k]`` is given its
    capacity wins over ``capacities[k]``, warm content seeds the replay,
    and the cache object is left in the exact final LRU state.  The same
    holds per level: ``capacities2``/``caches2``/``policies2`` describe the
    second hierarchy level (see the module docstring — both levels are
    classified against the same stack-distance array).  RO tenants whose
    window fails the no-eviction guard (see module docstring) are replayed
    with the O(n) eviction-token loop — single-level or the per-level
    two-level generalization — so write-around windows under pressure
    never leave the vectorized path.  The per-access interpreter remains
    only for genuinely degenerate windows (an empty window with two
    levels, or warm L2 content behind a dead ``C2 <= 0`` level); those are
    flagged with ``SimResult.fallback = 1`` so deployments can measure how
    often it happens (``ECICacheManager`` aggregates the counter).

    With ``return_window_rd=True`` also returns, per tenant, the TRD
    sample array of the *window* trace (``reuse_distances(trace, "trd")``,
    -1 at cold accesses) — the tape's stack distances restricted to
    window-internal reuses, so the Analyzer gets its reuse distances for
    free from the same counting pass; ``None`` where the tenant was not
    replayed on the tape (empty window or zero capacity).
    """
    if t_write_bypass is None:
        t_write_bypass = 1.2 * t_fast
    if t_fast2 is None:
        t_fast2 = 3.0 * t_fast
    T = len(traces)
    caches = caches if caches is not None else [None] * T
    caches2 = caches2 if caches2 is not None else [None] * T
    if policies is None:
        policies = [WritePolicy.WB] * T
    if policies2 is None:
        policies2 = [WritePolicy.WB] * T
    results: list[SimResult | None] = [None] * T

    def run_interp(k: int) -> SimResult:
        """Exact per-tenant fallback through the stateful interpreter."""
        return simulate(traces[k], caps1[k], policies[k], t_fast, t_slow,
                        t_write_bypass=t_write_bypass, flush_cost=flush_cost,
                        cache=caches[k], capacity2=caps2[k],
                        policy2=policies2[k], t_fast2=t_fast2,
                        cache2=caches2[k])

    vec: list[int] = []
    caps1 = [0] * T
    caps2 = [0] * T
    for k in range(T):
        tr, c, c2 = traces[k], caches[k], caches2[k]
        cap = int(c.capacity if c is not None else capacities[k])
        cap2 = int(c2.capacity if c2 is not None else
                   (capacities2[k] if capacities2 is not None else 0))
        caps1[k], caps2[k] = cap, cap2
        pol = policies[k]
        two = cap2 > 0 or (c2 is not None and len(c2) > 0)
        n = len(tr)
        if n == 0:
            if two:                  # rebalance/flush side effects still run
                results[k] = run_interp(k)
                results[k].fallback = 1          # degenerate: telemetry
            else:
                results[k] = SimResult(capacity=cap, policy=pol.value)
            continue
        if cap <= 0 and not two:
            r = SimResult(capacity=cap, policy=pol.value)
            r.reads = int(np.sum(tr.is_read))
            r.writes = n - r.reads
            r.total_latency = r.reads * t_slow + r.writes * t_write_bypass
            results[k] = r
            continue
        if two and cap2 <= 0:        # degenerate warm L2 behind a dead level
            results[k] = run_interp(k)
            results[k].fallback = 1              # degenerate: telemetry
            continue
        vec.append(k)

    rds: list[np.ndarray | None] = [None] * T
    if not vec:
        return (results, rds) if return_window_rd else results

    # restore the hierarchy invariant before reading warm state (both
    # engines normalize identically — see simulator.rebalance_levels)
    for k in vec:
        c, c2 = caches[k], caches2[k]
        if c is not None and c2 is not None and len(c2) > 0:
            rebalance_levels(c, c2)

    # ------------------------------------------------------ build the tape
    # one contiguous block per tenant: [warm L2 prefix][warm L1 prefix]
    # (pseudo-reads carrying dirty flags, each LRU -> MRU: the union stack)
    # + [window accesses]; address ids remapped per tenant so blocks never
    # interact.
    parts_addr, parts_read, parts_force = [], [], []
    starts, l2_ends, bodies, ends = [], [], [], []
    off = 0
    for k in vec:
        tr, c, c2 = traces[k], caches[k], caches2[k]
        if c2 is not None and len(c2) > 0:
            paddrs2, pdirty2 = c2.state_arrays()
        else:
            paddrs2 = np.zeros(0, np.int64)
            pdirty2 = np.zeros(0, bool)
        if c is not None and len(c) > 0:
            paddrs, pdirty = c.state_arrays()
        else:
            paddrs = np.zeros(0, np.int64)
            pdirty = np.zeros(0, bool)
        parts_addr.append(np.concatenate([paddrs2, paddrs, tr.addrs]))
        parts_read.append(np.concatenate(
            [np.ones(paddrs2.size + paddrs.size, bool), tr.is_read]))
        parts_force.append(np.concatenate(
            [pdirty2, pdirty, np.zeros(len(tr), bool)]))
        starts.append(off)
        l2_ends.append(off + paddrs2.size)
        bodies.append(off + paddrs2.size + paddrs.size)
        off += paddrs2.size + paddrs.size + len(tr)
        ends.append(off)

    orig_addr = np.concatenate(parts_addr)
    is_read = np.concatenate(parts_read)
    force_dirty = np.concatenate(parts_force)
    m = off
    pos = np.arange(m, dtype=np.int64)
    starts_a = np.array(starts, np.int64)
    l2_ends_a = np.array(l2_ends, np.int64)
    bodies_a = np.array(bodies, np.int64)
    ends_a = np.array(ends, np.int64)
    lens = ends_a - starts_a
    tid = np.repeat(np.arange(len(vec), dtype=np.int64), lens)
    cap1_arr = np.array([caps1[k] for k in vec], np.int64)
    cap2_arr = np.array([caps2[k] for k in vec], np.int64)
    captot_arr = cap1_arr + cap2_arr
    cap1_of = np.repeat(cap1_arr, lens)
    captot_of = np.repeat(captot_arr, lens)
    pol_codes = np.array([{"wb": 0, "wt": 1, "ro": 2}[policies[k].value]
                          for k in vec], np.int64)
    clean2_arr = np.array([policies2[k] is not WritePolicy.WB
                           and caps2[k] > 0 and caps1[k] > 0 for k in vec],
                          bool)
    clean2_of = np.repeat(clean2_arr, lens)
    # hit-level boundary: hits whose previous occurrence precedes it are L2
    # hits (RO path); when L1 has no capacity the only level *is* L2
    l2b_arr = np.where(cap1_arr > 0, l2_ends_a, m)
    l2b_of = np.repeat(l2b_arr, lens)
    l2end_of = np.repeat(l2_ends_a, lens)       # true warm-L2 boundary
    pol_of = np.repeat(pol_codes, lens)
    end_of = np.repeat(ends_a, lens)
    counted = pos >= np.repeat(bodies_a, lens)
    is_write = ~is_read

    # occurrence links from per-tenant stable argsorts (cache-resident;
    # blocks never interact, so cross-block address collisions are severed
    # by forcing segment heads at block starts); the same ordering is
    # reused below for the dirty-chain segmented reductions
    ordi = np.empty(m, dtype=np.int64)
    for t in range(len(vec)):
        s, e = starts[t], ends[t]
        ordi[s:e] = s + np.argsort(orig_addr[s:e], kind="stable")
    sorted_vals = orig_addr[ordi]
    same_prev = np.zeros(m, dtype=bool)
    same_prev[1:] = sorted_vals[1:] == sorted_vals[:-1]
    same_prev[starts_a] = False                  # sever cross-block ties
    prev = np.full(m, -1, dtype=np.int64)
    prev[ordi[1:]] = np.where(same_prev[1:], ordi[:-1], -1)
    nxt = np.full(m, m, dtype=np.int64)
    nxt[ordi[:-1]] = np.where(same_prev[1:], ordi[1:], m)
    nxt_c = np.minimum(nxt, end_of)

    # clean-L2 policies flush any warm dirty L2 content up-front (the
    # interpreter does the same); the tape forgets those flags so the
    # token replays, dirty chains and final state all see a clean L2
    flush_pre = np.zeros(len(vec), np.int64)
    if force_dirty.any():
        for t in range(len(vec)):
            if not clean2_arr[t]:
                continue
            sl = slice(starts[t], l2_ends[t])
            nd = int(np.sum(force_dirty[sl]))
            if nd:
                flush_pre[t] = nd
                force_dirty[sl] = False

    # --------------------------------------- RO residency: guard or tokens
    # L[t] = live blocks after access t assuming no eviction; for a real
    # L1 level subtract U2[t] = still-untouched warm-L2 blocks (they live
    # in L2, not L1).  While L1-live <= C1 the level can never have filled,
    # so no eviction/demotion has occurred and resident ⟺ live is exact.
    # Tenants exceeding the bound are replayed by the O(n) eviction-token
    # loop — single-level (``_ro_token_replay``) when C2 == 0 or C1 == 0
    # (where L2 *is* the level), the per-level two-level generalization
    # (``_ro_token_replay_levels``) otherwise — still exact, still
    # loop-free afterwards: the loops only shorten token deaths and
    # transfer levels, and hits are recovered as ``death[prev] == i``
    # (split per level by ``lvl[prev]``).  Both have fori_loop device
    # ports used on TPU hosts, where the guard's live counts also stay
    # on-device (cache_sim's O(n) delta-cumsum live-count op).
    tokens: dict[int, tuple[np.ndarray, np.ndarray, int]] = {}
    tokens2: dict[int, tuple[np.ndarray, np.ndarray, np.ndarray,
                             int, int]] = {}
    if np.any(pol_codes == 2):
        w2 = np.flatnonzero(pos < l2end_of)      # warm-L2 pseudo positions
        if _accel_default():
            from repro.kernels.cache_sim.ops import ro_live_counts_accel
            L = ro_live_counts_accel(nxt_c, is_read)
            U2 = (ro_live_counts_accel(nxt_c, pos < l2end_of)
                  if w2.size else None)
        else:
            d = (np.bincount(np.flatnonzero(is_read), minlength=m + 1)
                 - np.bincount(nxt_c[is_read], minlength=m + 1))
            L = np.cumsum(d[:m])
            if w2.size:
                du = (np.bincount(w2, minlength=m + 1)
                      - np.bincount(nxt_c[w2], minlength=m + 1))
                U2 = np.cumsum(du[:m])
            else:
                U2 = None
        token_replay = (ro_token_replay_device if _accel_default()
                        else _ro_token_replay)
        token_replay2 = (ro_token_replay_levels_device if _accel_default()
                         else _ro_token_replay_levels)
        for t, k in enumerate(vec):
            if pol_codes[t] != 2:
                continue
            s, e = starts[t], ends[t]
            cap1, cap2 = int(cap1_arr[t]), int(cap2_arr[t])
            ro_cap = cap1 if cap1 > 0 else cap1 + cap2
            lt = L[s:e]
            if U2 is not None and cap1 > 0 and cap2 > 0:
                lt = lt - U2[s:e]
            if int(lt.max()) > ro_cap:
                if cap1 > 0 and cap2 > 0:
                    tokens2[t] = token_replay2(
                        is_read[s:e], prev[s:e] - s, nxt_c[s:e] - s,
                        force_dirty[s:e], cap1, cap2,
                        int(l2_ends_a[t] - s), bool(clean2_arr[t]))
                else:
                    tokens[t] = token_replay(
                        is_read[s:e], prev[s:e] - s, nxt_c[s:e] - s,
                        force_dirty[s:e], ro_cap)

    # -------------------------------------------------- residency oracle
    # (the kernel's counting window (prev[i], i) never crosses a tenant
    # block for hot accesses and cold rows are masked, so the whole tape
    # goes through one kernel launch on TPU)
    sd = level_masks = None
    if _accel_default():
        from repro.kernels.cache_sim.ops import (residency_levels_accel,
                                                 stack_distances_accel)
        if return_window_rd:
            sd = stack_distances_accel(prev, nxt_c)
        else:
            # both-level residency straight off the kernel, one launch
            level_masks = residency_levels_accel(prev, nxt_c,
                                                 cap1_of, captot_of)
    else:
        sd = _stack_distances_host(prev, nxt_c,
                                   bounds=np.concatenate([starts_a, [m]]))
    if return_window_rd:
        # window-internal reuse distances: reuses whose previous occurrence
        # is a warm-prefix pseudo-access are cold from the Analyzer's view
        for t, k in enumerate(vec):
            sl = slice(int(bodies_a[t]), int(ends_a[t]))
            rds[k] = np.where(prev[sl] >= bodies_a[t], sd[sl], -1)
    hot = prev >= 0
    prev_safe = np.maximum(prev, 0)
    if level_masks is not None:
        res_l1_sd, res_un_sd = level_masks
    else:
        res_l1_sd = hot & (sd < cap1_of) & (sd >= 0)
        res_un_sd = hot & (sd < captot_of) & (sd >= 0)
    res_ro = hot & is_read[prev_safe]
    resident = np.where(pol_of == 2, res_ro, res_un_sd)
    for t, rec in itertools.chain(tokens.items(), tokens2.items()):
        death = rec[0]
        s, e = starts[t], ends[t]
        pl = prev[s:e] - s
        pls = np.maximum(pl, 0)
        blk_read = is_read[s:e]
        resident[s:e] = ((pl >= 0) & blk_read[pls]
                         & (death[pls] == np.arange(e - s)))
    # split hits by level: WB/WT against the two stack thresholds, RO by
    # whether the previous occurrence is a still-untouched warm-L2 block —
    # or, under eviction pressure, by the level the token died in
    res_l2 = np.where(pol_of == 2,
                      resident & (prev_safe < l2b_of),
                      resident & ~res_l1_sd)
    for t, (_, _, lv, _, _) in tokens2.items():
        s, e = starts[t], ends[t]
        pls = np.maximum(prev[s:e] - s, 0)
        res_l2[s:e] = resident[s:e] & (lv[pls] == 2)
    res_l1 = resident & ~res_l2

    # ------------------------------------------------------- dirty chains
    # group by address, segment at installs (non-resident accesses — for a
    # clean L2 the chain instead segments at L1 exits, since demotion
    # flushes the block and it re-promotes clean); the dirty flag after
    # each access is a segmented reduction:
    #   WB       : OR of (is_write | forced) over the period so far
    #   WT / RO  : forced flag at the period head, cleared by any write
    #              (WT write-through propagates -> cached copy is clean;
    #               RO writes invalidate, the flag only matters for warm
    #               prefix blocks)
    chain_res = np.where(clean2_of & (pol_of != 2), res_l1, resident)
    head = _segment_heads(sorted_vals) | ~chain_res[ordi]
    head[starts_a] = True                        # sever cross-block ties
    head_pos = np.maximum.accumulate(np.where(head, np.arange(m), -1))
    any_force = bool(force_dirty.any())
    all_wb = bool(np.all(pol_codes == 0))
    w_wb = (is_write | force_dirty)[ordi].astype(np.int64)
    cw_wb = np.cumsum(w_wb)
    dirty_wb_s = (cw_wb - cw_wb[head_pos] + w_wb[head_pos]) > 0
    if any_force and not all_wb:
        w_any = is_write[ordi].astype(np.int64)
        cw_any = np.cumsum(w_any)
        seg_writes = cw_any - cw_any[head_pos] + w_any[head_pos]
        dirty_chain_s = force_dirty[ordi][head_pos] & (seg_writes == 0)
    else:
        # WT/RO blocks can only be dirty via warm-prefix flags
        dirty_chain_s = np.zeros(m, dtype=bool)
    dirty_after = np.empty(m, dtype=bool)
    dirty_after[ordi] = np.where(pol_of[ordi] == 0, dirty_wb_s,
                                 dirty_chain_s)

    # ------------------------------------------------- flush accounting
    # an eviction displaces the block last touched at j iff its next
    # occurrence misses, or (no next occurrence) >= C distinct addresses
    # follow it; dirty evictions charge flush_cost (WB/WT only: RO fast
    # path proved no evictions happen).  With a dirty-accepting L2 the
    # flush happens at the *union* eviction; with a clean L2 it happens at
    # the L1 exit (demotion) instead — same machinery, C1 threshold.
    last = nxt_c == end_of
    cl = np.cumsum(last.astype(np.int64))
    D = cl[end_of - 1] - cl
    if flush_cost > 0.0:
        flushcap_of = np.where(clean2_of & (pol_of != 2),
                               cap1_of, captot_of)
        miss_next = np.zeros(m, dtype=bool)
        nz = ~last
        miss_next[nz] = ~chain_res[nxt_c[nz]]
        evicted = np.where(last, D >= flushcap_of, miss_next)
        flush_ev = dirty_after & evicted & (pol_of != 2)
        flush_per = np.bincount(tid[flush_ev], minlength=len(vec))
    else:
        flush_per = np.zeros(len(vec), np.int64)
    flush_per += flush_pre
    for t, (_, _, fl) in tokens.items():         # RO evictions under pressure
        flush_per[t] += fl
    for t, (_, _, _, fl, _) in tokens2.items():  # incl. demotion flushes
        flush_per[t] += fl

    # ------------------------------------------------------- per-tenant stats
    # one fused bincount: code = 8*tenant + 4*is_read + level
    # (level: 0 = miss, 1 = L2 hit, 2 = L1 hit)
    lvl = res_l1.astype(np.int64) * 2 + res_l2.astype(np.int64)
    code = tid * 8 + is_read.astype(np.int64) * 4 + lvl
    cnts = np.bincount(code[counted], minlength=8 * len(vec)) \
        .reshape(len(vec), 8)
    reads_per = cnts[:, 4] + cnts[:, 5] + cnts[:, 6]
    rhits_per = cnts[:, 6]
    rhits2_per = cnts[:, 5]
    writes_per = cnts[:, 0] + cnts[:, 1] + cnts[:, 2]
    whits_per = cnts[:, 2]
    whits2_per = cnts[:, 1]
    # distinct union addresses per tenant block -> closed-form demotions
    U_per = np.bincount(tid[last], minlength=len(vec))

    for t, k in enumerate(vec):
        pol = policies[k]
        cap1, cap2 = int(cap1_arr[t]), int(cap2_arr[t])
        captot = cap1 + cap2
        r = SimResult(capacity=cap1, policy=pol.value, capacity2=cap2,
                      policy2=(policies2[k].value if cap2 > 0 else "wb"))
        r.reads = int(reads_per[t])
        r.read_hits = int(rhits_per[t])
        r.read_hits_l2 = int(rhits2_per[t])
        r.writes = int(writes_per[t])
        r.write_hits = int(whits_per[t])
        r.write_hits_l2 = int(whits2_per[t])
        l2h = r.read_hits_l2
        rmiss = r.reads - r.read_hits - l2h
        fl = int(flush_per[t])
        if pol is WritePolicy.WB:
            if cap1 > 0:
                r.cache_writes = rmiss + l2h + r.writes
                r.total_latency = (r.read_hits * t_fast + rmiss * t_slow
                                   + r.writes * t_fast + fl * flush_cost)
            elif captot > 0:
                r.cache_writes_l2 = rmiss + r.writes
                r.total_latency = (rmiss * t_slow + r.writes * t_fast2
                                   + fl * flush_cost)
            else:
                r.total_latency = (rmiss * t_slow
                                   + r.writes * t_write_bypass)
        elif pol is WritePolicy.WT:
            if cap1 > 0:
                r.cache_writes = rmiss + l2h + r.writes
            elif captot > 0:
                r.cache_writes_l2 = rmiss + r.writes
            r.total_latency = (r.read_hits * t_fast + rmiss * t_slow
                               + r.writes * t_write_bypass
                               + fl * flush_cost)
        else:
            if cap1 > 0:
                r.cache_writes = rmiss + l2h     # installs + promotions
                if t in tokens2:                 # demotions under pressure
                    r.cache_writes_l2 = int(tokens2[t][4])
            elif captot > 0:
                r.cache_writes_l2 = rmiss
            r.total_latency = (r.read_hits * t_fast + rmiss * t_slow
                               + r.writes * t_write_bypass
                               + fl * flush_cost)
        if l2h:
            r.total_latency += l2h * t_fast2
        if cap1 > 0 and cap2 > 0 and pol is not WritePolicy.RO:
            # every install into a full L1 demotes its victim into L2
            installs = (r.reads - r.read_hits) + (r.writes - r.write_hits)
            final_l1 = min(int(U_per[t]), cap1)
            init_l1 = int(bodies_a[t] - l2_ends_a[t])
            r.cache_writes_l2 = installs - (final_l1 - init_l1)

        # ------------------------------------------- final LRU state
        c = caches[k]
        c2v = caches2[k]
        if c is not None or c2v is not None:
            sl = slice(starts[t], ends[t])
            surv_lvl = None
            if t in tokens:
                death, tdirty, _ = tokens[t]
                keep = is_read[sl] & (death == ends[t] - starts[t])
                dirty_keep = tdirty[keep]
            elif t in tokens2:
                death, tdirty, tlvl, _, _ = tokens2[t]
                keep = is_read[sl] & (death == ends[t] - starts[t])
                dirty_keep = tdirty[keep]
                surv_lvl = tlvl[keep]
            else:
                blk_last = last[sl]
                if pol is WritePolicy.RO:
                    keep = blk_last & is_read[sl]
                else:
                    keep = blk_last & (D[sl] < captot)
                dirty_keep = dirty_after[starts[t]:ends[t]][keep]
            js = np.flatnonzero(keep) + starts[t]       # ascending = LRU->MRU
            if cap2 <= 0 and (c2v is None or len(c2v) == 0):
                if c is not None:
                    c.set_state_arrays(orig_addr[js], dirty_keep)
            else:
                # split the union survivor stack at depth C1 (WB/WT), by
                # warm-L2 pseudo position (RO: untouched blocks stay in
                # L2), or by the surviving token's level (RO pressure)
                if surv_lvl is not None:
                    in_l2 = surv_lvl == 2
                elif pol is WritePolicy.RO:
                    in_l2 = js < int(l2b_arr[t])
                else:
                    n1 = min(cap1, js.size)
                    in_l2 = np.arange(js.size) < js.size - n1
                if c is not None:
                    c.set_state_arrays(orig_addr[js[~in_l2]],
                                       dirty_keep[~in_l2])
                if c2v is not None:
                    d2k = dirty_keep[in_l2]
                    if clean2_arr[t]:
                        d2k = np.zeros(d2k.size, bool)
                    c2v.set_state_arrays(orig_addr[js[in_l2]], d2k)
        results[k] = r
    return (results, rds) if return_window_rd else results


def simulate_batch(trace: Trace, capacity: int,
                   policy: WritePolicy = WritePolicy.WB,
                   t_fast: float = 1.0, t_slow: float = 20.0,
                   t_write_bypass: float | None = None,
                   flush_cost: float = 0.0,
                   cache: LRUCache | None = None, *,
                   capacity2: int = 0,
                   policy2: WritePolicy = WritePolicy.WB,
                   t_fast2: float | None = None,
                   cache2: LRUCache | None = None) -> SimResult:
    """Drop-in vectorized replacement for ``simulator.simulate``."""
    return simulate_many([trace], [capacity], [policy], t_fast=t_fast,
                         t_slow=t_slow, t_write_bypass=t_write_bypass,
                         flush_cost=flush_cost, caches=[cache],
                         capacities2=[capacity2], policies2=[policy2],
                         caches2=[cache2], t_fast2=t_fast2)[0]
