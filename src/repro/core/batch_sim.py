"""Vectorized multi-tenant window replay — the batch simulation engine.

Replaces the per-access Python loop in ``simulator.simulate`` with array
programs over occurrence links, for a whole Δt window of **all tenants at
once**.  The engine is *exact*: it reproduces ``simulate()``'s hits,
write_hits, cache_writes, flush charges, total latency and the final LRU
state (the interpreter remains the oracle, property-tested in
``tests/test_batch_sim.py``).

Hit-oracle math
===============

Let ``prev[i]``/``nxt[j]`` be the previous/next occurrence links of the
access stream (``trace.prev_next_occurrence``).  Define the *stack distance*

    SD(i) = #{ j : prev[i] < j < i,  nxt[j] >= i }

— the number of distinct addresses touched strictly between an access and
its previous occurrence (each contributes exactly one ``j``, its last
occurrence inside the window).  For an LRU partition of ``C`` blocks that
**allocates on every access** (the WB and WT policies: reads install on
miss, writes install or touch), Mattson stack inclusion gives the exact
oracle:

    access i is resident  ⟺  prev[i] >= 0  and  SD(i) < C.

``SD`` is computed without any per-access loop as ``SD(i) = F(i) − G(i)``:

  * ``F(i) = #{ j < i : nxt[j] >= i }`` is the number of occurrence
    intervals ``(j, nxt[j]]`` covering ``i`` — an O(n) difference-array
    cumsum (it equals the number of distinct addresses seen before ``i``).
  * ``G(i) = #{ j <= prev[i] : nxt[j] >= i }``.  Because ``nxt[prev[i]] ==
    i``, the queries are the points themselves and ``G`` is a dominance
    count over the point set ``(j, nxt[j])``; it is evaluated for *all*
    accesses at once with a bottom-up merge tree (log n rounds of
    block-sort + ``searchsorted``), O(n log² n) in vectorized numpy.

Write-policy effects
====================

WB/WT share the oracle above (identical stack content; they differ only in
latency/endurance accounting).  RO (write-around) breaks reuse chains at
writes — a write invalidates the cached copy, so a read whose previous
occurrence is a write is always a miss — and writes never install.  The
trace transform is: gate residency on ``is_read[prev[i]]`` and restrict
occupancy to reads.

**RO caveat (why there is a guard):** invalidation *frees the slot
immediately*, and LRU-with-deletion loses the stack property once a
capacity eviction has occurred.  Counterexample at C=2 for trace
``r(a) r(b) r(c) w(b) w(c) r(a)``: the real cache evicted ``a`` at
``r(c)``, so the final read misses, but after the two invalidations only
zero live blocks separate ``r(a)`` from its reuse, so any distance oracle
says hit.  The engine therefore computes the *live count*
``L(t) = #{ j <= t : is_read[j], nxt[j] > t }`` (O(n) cumsum); when
``max L <= C`` the cache never fills, no eviction can occur, and
``resident ⟺ live`` is exact — otherwise that tenant's window falls back
to the interpreter.  WB/WT never need the guard (no deletions).

Endurance / latency / flush accounting are pure array reductions:
per-address *dirty chains* (segmented cumulative OR over residency
periods, grouped by address), suffix distinct-counts for end-of-trace
evictions, and ``bincount`` per tenant.  Warm cross-window state is
handled exactly by prepending the cache content as pseudo-read accesses
(LRU→MRU order) carrying their dirty flags; the prefix is excluded from
the reported stats.

On TPU the ``SD`` counting runs on-accelerator via the
``repro.kernels.cache_sim`` Pallas kernel (the occupancy-masked
generalization of ``urd_scan``); on CPU the merge-tree host path is used.
"""
from __future__ import annotations

import numpy as np

from repro.core.reuse_distance import RDResult
from repro.core.simulator import LRUCache, SimResult
from repro.core.trace import Trace, prev_next_occurrence
from repro.core.write_policy import WritePolicy

__all__ = [
    "count_prev_ge",
    "stack_distances",
    "reuse_distances_fast",
    "simulate_batch",
    "simulate_many",
]


# --------------------------------------------------------------- primitives
def count_prev_ge(y: np.ndarray) -> np.ndarray:
    """cnt[q] = #{ j < q : y[j] >= y[q] }, vectorized merge-tree counting.

    Bottom-up merge levels: at half-size ``s`` every element in the right
    half of a 2s-block counts the elements >= it in the left half — by
    direct broadcast for narrow blocks, by block-local ``searchsorted``
    (composite keys while blocks are many, a python loop once they are
    few) for wide ones.  O(n log² n) array work, int32 throughout, no
    per-element Python loop.  Requires ``0 <= y < 2**31 - 2``.
    """
    m = int(y.shape[0])
    out = np.zeros(m, dtype=np.int64)
    if m <= 1:
        return out
    y = y.astype(np.int32)
    base = np.int64(int(y.max()) + 2)

    # base level: all within-16-block pairs in one dense masked pass
    B0 = 16
    ms0 = -(-m // B0) * B0
    yp0 = np.full(ms0, -1, dtype=np.int32)
    yp0[:m] = y
    blk = yp0.reshape(-1, B0)
    lower = np.arange(B0)[:, None] < np.arange(B0)[None, :]   # j < q
    cnt0 = ((blk[:, :, None] >= blk[:, None, :]) & lower[None]) \
        .sum(axis=1, dtype=np.int64).reshape(-1)
    out[:] = cnt0[:m]

    idx = np.arange(m, dtype=np.int64)
    s, ell = B0, 4
    while s < m:
        width = 2 * s
        ms = -(-m // width) * width              # pad only to this level
        yp = np.full(ms, -1, dtype=np.int32)     # pad < every real value
        yp[:m] = y
        blocks = yp.reshape(-1, width)
        lefts = blocks[:, :s]                                    # [nb, s]
        rights = blocks[:, s:]                                   # [nb, s]
        nb = lefts.shape[0]
        lefts_s = np.sort(lefts, axis=1)
        if nb <= 16:
            n_lt = np.concatenate([
                np.searchsorted(lefts_s[b], rights[b])
                for b in range(nb)])
        else:
            if nb * int(base) < 2**31 - 1:       # int32 composite keys
                row = (np.arange(nb, dtype=np.int32)
                       * np.int32(base))[:, None]
                keys = (lefts_s + np.int32(1) + row).ravel()
                qkeys = (rights + np.int32(1) + row).ravel()
            else:
                row = (np.arange(nb, dtype=np.int64) * base)[:, None]
                keys = (lefts_s.astype(np.int64) + 1 + row).ravel()
                qkeys = (rights.astype(np.int64) + 1 + row).ravel()
            n_lt = (np.searchsorted(keys, qkeys)
                    - (np.arange(nb, dtype=np.int64) * s).repeat(s))
        # queries of this level = positions with bit `ell` set (ascending;
        # pads sit only at the tail, so a head-slice aligns them)
        sel = idx[(idx >> ell) & 1 == 1]
        out[sel] += s - n_lt.reshape(-1)[:sel.size]
        s, ell = width, ell + 1
    return out


def _coverage_counts(nxt: np.ndarray) -> np.ndarray:
    """F[i] = #{ j < i : nxt[j] >= i } via a difference array, O(n)."""
    n = nxt.shape[0]
    d = -np.bincount(np.minimum(nxt, n) + 1,
                     minlength=n + 2)[:n + 2]    # interval ends after nxt[j]
    d[1:n + 1] += 1                              # starts at j+1
    return np.cumsum(d)[:n + 1]


def _stack_distances_host(prev: np.ndarray, nxt: np.ndarray,
                          bounds: np.ndarray | None = None) -> np.ndarray:
    """Exact SD per access (occupancy = every access); -1 for cold.

    ``bounds`` (optional) splits the tape into independent contiguous
    blocks (one per tenant: links never cross), processed one at a time so
    each tenant's working set stays cache-resident.
    """
    n = prev.shape[0]
    sd = np.full(n, -1, dtype=np.int64)
    if n == 0:
        return sd
    if bounds is None:
        bounds = np.array([0, n], dtype=np.int64)
    for s, e in zip(bounds[:-1], bounds[1:]):
        s, e = int(s), int(e)
        if e <= s:
            continue
        pl = prev[s:e]
        nl = nxt[s:e] - s
        F = _coverage_counts(nl)
        cnt = count_prev_ge(nl)
        idx = np.flatnonzero(pl >= 0)            # links never cross blocks
        sd[s + idx] = F[idx] - (cnt[pl[idx] - s] + 1)
    return sd


def _accel_default() -> bool:
    """True when SD counting should run in the Pallas kernel (TPU host)."""
    global _ACCEL
    if _ACCEL is None:
        try:
            import jax
            _ACCEL = jax.default_backend() == "tpu"
        except Exception:  # pragma: no cover - jax always present in-tree
            _ACCEL = False
    return _ACCEL


_ACCEL: bool | None = None


def stack_distances(trace: Trace, backend: str = "auto") -> np.ndarray:
    """Per-access LRU stack distances (TRD samples at every re-touch).

    backend: "host" (numpy merge tree), "accel" (cache_sim Pallas kernel /
    jnp oracle), or "auto" (kernel on TPU, host otherwise).
    """
    prev, nxt = prev_next_occurrence(trace.addrs)
    if backend == "auto":
        backend = "accel" if _accel_default() else "host"
    if backend == "accel":
        from repro.kernels.cache_sim.ops import stack_distances_accel
        return stack_distances_accel(prev, nxt)
    return _stack_distances_host(prev, nxt)


def reuse_distances_fast(trace: Trace, kind: str = "urd",
                         backend: str = "auto") -> RDResult:
    """Drop-in for ``reuse_distances`` built on the vectorized SD engine.

    Same output, no per-access Python loop: the production Analyzer path.
    """
    if kind not in ("trd", "urd"):
        raise ValueError(f"kind must be 'trd' or 'urd', got {kind!r}")
    sd = stack_distances(trace, backend)
    out = sd.copy()
    if kind == "urd":
        out[~trace.is_read] = -1
    return RDResult(out, kind)


# ------------------------------------------------------------ batch replay
def _ro_token_replay(is_read_blk: np.ndarray, prev_blk: np.ndarray,
                     nxt_blk: np.ndarray, force_blk: np.ndarray,
                     cap: int) -> tuple[np.ndarray, np.ndarray, int]:
    """Exact RO (write-around) replay under capacity pressure, O(n).

    Token formulation: every read position ``j`` is a cache slot "token"
    alive on ``(j, nxt[j])`` — independent of hit/miss, because a read hit
    retires the previous token and births a new one simultaneously (net
    resident count 0) while a miss is a pure birth and a write-hit a pure
    death.  Evictions only *shorten* a token's death time, and the LRU
    victim is always the minimum live token, which is non-decreasing over
    time — so a single forward bottom pointer suffices and the whole
    replay is one O(n) integer pass with no dictionary.  Afterwards every
    residency question is vectorized: access ``i`` hit ⟺ its previous
    occurrence ``p`` was a read whose token survived to its natural death
    (``death[p] == i``).

    Returns (death, dirty, flushes): ``death[j]`` = when token j left the
    cache (== ``nxt_blk[j]`` iff never evicted), ``dirty[j]`` = the dirty
    flag the token carried (inherited from warm-prefix blocks through hit
    chains; RO installs are clean), ``flushes`` = dirty evictions.
    """
    n = int(is_read_blk.shape[0])
    rd = is_read_blk.tolist()
    pv = prev_blk.tolist()
    death = nxt_blk.tolist()
    dirty = force_blk.tolist()
    flushes = 0
    resident = 0
    b = 0                                        # oldest-resident candidate
    for t in range(n):
        p = pv[t]
        if rd[t]:
            if p >= 0 and rd[p] and death[p] == t:
                dirty[t] = dirty[p]              # hit: token renewal
            else:
                resident += 1                    # miss: install clean
                if resident > cap:
                    while not rd[b] or death[b] <= t:
                        b += 1
                    death[b] = t                 # evict oldest resident
                    if dirty[b]:
                        flushes += 1
                    resident -= 1
        elif p >= 0 and rd[p] and death[p] == t:
            resident -= 1                        # write-hit: invalidate
    return (np.asarray(death, dtype=np.int64),
            np.asarray(dirty, dtype=bool), flushes)


def _segment_heads(sorted_vals: np.ndarray) -> np.ndarray:
    head = np.ones(sorted_vals.shape[0], dtype=bool)
    head[1:] = sorted_vals[1:] != sorted_vals[:-1]
    return head


def simulate_many(traces: list[Trace], capacities=None, policies=None, *,
                  t_fast: float = 1.0, t_slow: float = 20.0,
                  t_write_bypass: float | None = None,
                  flush_cost: float = 0.0,
                  caches: list[LRUCache | None] | None = None,
                  return_window_rd: bool = False):
    """Replay one window for every tenant at once (exact, vectorized).

    Mirrors ``simulate()`` per tenant: when ``caches[k]`` is given its
    capacity wins over ``capacities[k]``, warm content seeds the replay,
    and the cache object is left in the exact final LRU state.  RO tenants
    whose window fails the no-eviction guard (see module docstring) are
    replayed with the interpreter instead — same results, just slower.

    With ``return_window_rd=True`` also returns, per tenant, the TRD
    sample array of the *window* trace (``reuse_distances(trace, "trd")``,
    -1 at cold accesses) — the tape's stack distances restricted to
    window-internal reuses, so the Analyzer gets its reuse distances for
    free from the same counting pass; ``None`` where the tenant was not
    replayed on the tape (empty window or zero capacity).
    """
    if t_write_bypass is None:
        t_write_bypass = 1.2 * t_fast
    T = len(traces)
    caches = caches if caches is not None else [None] * T
    if policies is None:
        policies = [WritePolicy.WB] * T
    results: list[SimResult | None] = [None] * T

    vec: list[int] = []
    for k in range(T):
        tr, c = traces[k], caches[k]
        cap = int(c.capacity if c is not None else capacities[k])
        pol = policies[k]
        n = len(tr)
        if n == 0:
            results[k] = SimResult(capacity=cap, policy=pol.value)
            continue
        if cap <= 0:
            r = SimResult(capacity=cap, policy=pol.value)
            r.reads = int(np.sum(tr.is_read))
            r.writes = n - r.reads
            r.total_latency = r.reads * t_slow + r.writes * t_write_bypass
            results[k] = r
            continue
        vec.append(k)

    rds: list[np.ndarray | None] = [None] * T
    if not vec:
        return (results, rds) if return_window_rd else results

    # ------------------------------------------------------ build the tape
    # one contiguous block per tenant: [warm prefix (pseudo-reads carrying
    # dirty flags, LRU -> MRU)] + [window accesses]; address ids remapped
    # per tenant so blocks never interact.
    parts_addr, parts_read, parts_force = [], [], []
    starts, bodies, ends = [], [], []
    off = 0
    for k in vec:
        tr, c = traces[k], caches[k]
        if c is not None and len(c) > 0:
            paddrs, pdirty = c.state_arrays()
        else:
            paddrs = np.zeros(0, np.int64)
            pdirty = np.zeros(0, bool)
        parts_addr.append(np.concatenate([paddrs, tr.addrs]))
        parts_read.append(np.concatenate(
            [np.ones(paddrs.size, bool), tr.is_read]))
        parts_force.append(np.concatenate(
            [pdirty, np.zeros(len(tr), bool)]))
        starts.append(off)
        bodies.append(off + paddrs.size)
        off += paddrs.size + len(tr)
        ends.append(off)

    orig_addr = np.concatenate(parts_addr)
    is_read = np.concatenate(parts_read)
    force_dirty = np.concatenate(parts_force)
    m = off
    pos = np.arange(m, dtype=np.int64)
    starts_a = np.array(starts, np.int64)
    bodies_a = np.array(bodies, np.int64)
    ends_a = np.array(ends, np.int64)
    lens = ends_a - starts_a
    tid = np.repeat(np.arange(len(vec), dtype=np.int64), lens)
    cap_of = np.repeat(np.array(
        [caches[k].capacity if caches[k] is not None else int(capacities[k])
         for k in vec], np.int64), lens)
    pol_codes = np.array([{"wb": 0, "wt": 1, "ro": 2}[policies[k].value]
                          for k in vec], np.int64)
    pol_of = np.repeat(pol_codes, lens)
    end_of = np.repeat(ends_a, lens)
    counted = pos >= np.repeat(bodies_a, lens)
    is_write = ~is_read

    # occurrence links from per-tenant stable argsorts (cache-resident;
    # blocks never interact, so cross-block address collisions are severed
    # by forcing segment heads at block starts); the same ordering is
    # reused below for the dirty-chain segmented reductions
    ordi = np.empty(m, dtype=np.int64)
    for t in range(len(vec)):
        s, e = starts[t], ends[t]
        ordi[s:e] = s + np.argsort(orig_addr[s:e], kind="stable")
    sorted_vals = orig_addr[ordi]
    same_prev = np.zeros(m, dtype=bool)
    same_prev[1:] = sorted_vals[1:] == sorted_vals[:-1]
    same_prev[starts_a] = False                  # sever cross-block ties
    prev = np.full(m, -1, dtype=np.int64)
    prev[ordi[1:]] = np.where(same_prev[1:], ordi[:-1], -1)
    nxt = np.full(m, m, dtype=np.int64)
    nxt[ordi[:-1]] = np.where(same_prev[1:], ordi[1:], m)
    nxt_c = np.minimum(nxt, end_of)

    # --------------------------------------- RO residency: guard or tokens
    # L[t] = live blocks after access t assuming no eviction.  While
    # L <= C the cache can never have filled, so no eviction has occurred
    # and resident ⟺ live is exact.  Tenants whose window exceeds that
    # bound are replayed by the O(n) eviction-token loop instead
    # (``_ro_token_replay``) — still exact, still loop-free afterwards:
    # the loop only shortens token deaths, and hits are recovered as
    # ``death[prev] == i``.
    tokens: dict[int, tuple[np.ndarray, np.ndarray, int]] = {}
    if np.any(pol_codes == 2):
        occ_idx = np.flatnonzero(is_read)
        d = (np.bincount(occ_idx, minlength=m + 1)
             - np.bincount(nxt_c[occ_idx], minlength=m + 1))
        L = np.cumsum(d[:m])
        for t, k in enumerate(vec):
            if pol_codes[t] != 2:
                continue
            s, e = starts[t], ends[t]
            if int(L[s:e].max()) > int(cap_of[s]):
                tokens[t] = _ro_token_replay(
                    is_read[s:e], prev[s:e] - s, nxt_c[s:e] - s,
                    force_dirty[s:e], int(cap_of[s]))

    # -------------------------------------------------- residency oracle
    # (the kernel's counting window (prev[i], i) never crosses a tenant
    # block for hot accesses and cold rows are masked, so the whole tape
    # goes through one kernel launch on TPU)
    if _accel_default():
        from repro.kernels.cache_sim.ops import stack_distances_accel
        sd = stack_distances_accel(prev, nxt_c)
    else:
        sd = _stack_distances_host(prev, nxt_c,
                                   bounds=np.concatenate([starts_a, [m]]))
    if return_window_rd:
        # window-internal reuse distances: reuses whose previous occurrence
        # is a warm-prefix pseudo-access are cold from the Analyzer's view
        for t, k in enumerate(vec):
            sl = slice(int(bodies_a[t]), int(ends_a[t]))
            rds[k] = np.where(prev[sl] >= bodies_a[t], sd[sl], -1)
    hot = prev >= 0
    prev_safe = np.maximum(prev, 0)
    res_wbwt = hot & (sd < cap_of) & (sd >= 0)
    res_ro = hot & is_read[prev_safe]
    resident = np.where(pol_of == 2, res_ro, res_wbwt)
    for t, (death, _, _) in tokens.items():
        s, e = starts[t], ends[t]
        pl = prev[s:e] - s
        pls = np.maximum(pl, 0)
        blk_read = is_read[s:e]
        resident[s:e] = ((pl >= 0) & blk_read[pls]
                         & (death[pls] == np.arange(e - s)))

    # ------------------------------------------------------- dirty chains
    # group by address, segment at installs (non-resident accesses); the
    # dirty flag after each access is a segmented reduction:
    #   WB       : OR of (is_write | forced) over the period so far
    #   WT / RO  : forced flag at the period head, cleared by any write
    #              (WT write-through propagates -> cached copy is clean;
    #               RO writes invalidate, the flag only matters for warm
    #               prefix blocks)
    head = _segment_heads(sorted_vals) | ~resident[ordi]
    head[starts_a] = True                        # sever cross-block ties
    head_pos = np.maximum.accumulate(np.where(head, np.arange(m), -1))
    any_force = bool(force_dirty.any())
    all_wb = bool(np.all(pol_codes == 0))
    w_wb = (is_write | force_dirty)[ordi].astype(np.int64)
    cw_wb = np.cumsum(w_wb)
    dirty_wb_s = (cw_wb - cw_wb[head_pos] + w_wb[head_pos]) > 0
    if any_force and not all_wb:
        w_any = is_write[ordi].astype(np.int64)
        cw_any = np.cumsum(w_any)
        seg_writes = cw_any - cw_any[head_pos] + w_any[head_pos]
        dirty_chain_s = force_dirty[ordi][head_pos] & (seg_writes == 0)
    else:
        # WT/RO blocks can only be dirty via warm-prefix flags
        dirty_chain_s = np.zeros(m, dtype=bool)
    dirty_after = np.empty(m, dtype=bool)
    dirty_after[ordi] = np.where(pol_of[ordi] == 0, dirty_wb_s,
                                 dirty_chain_s)

    # ------------------------------------------------- flush accounting
    # an eviction displaces the block last touched at j iff its next
    # occurrence misses, or (no next occurrence) >= C distinct addresses
    # follow it; dirty evictions charge flush_cost (WB/WT only: RO fast
    # path proved no evictions happen).
    last = nxt_c == end_of
    cl = np.cumsum(last.astype(np.int64))
    D = cl[end_of - 1] - cl
    if flush_cost > 0.0:
        miss_next = np.zeros(m, dtype=bool)
        nz = ~last
        miss_next[nz] = ~resident[nxt_c[nz]]
        evicted = np.where(last, D >= cap_of, miss_next)
        flush_ev = dirty_after & evicted & (pol_of != 2)
        flush_per = np.bincount(tid[flush_ev], minlength=len(vec))
    else:
        flush_per = np.zeros(len(vec), np.int64)
    for t, (_, _, fl) in tokens.items():         # RO evictions under pressure
        flush_per[t] += fl

    # ------------------------------------------------------- per-tenant stats
    # one fused bincount: code = 4*tenant + 2*is_read + resident
    code = tid * 4 + (is_read.astype(np.int64) * 2
                      + resident.astype(np.int64))
    cnts = np.bincount(code[counted], minlength=4 * len(vec)) \
        .reshape(len(vec), 4)
    reads_per = cnts[:, 2] + cnts[:, 3]
    rhits_per = cnts[:, 3]
    writes_per = cnts[:, 0] + cnts[:, 1]
    whits_per = cnts[:, 1]

    for t, k in enumerate(vec):
        pol = policies[k]
        cap = int(cap_of[starts[t]])
        r = SimResult(capacity=cap, policy=pol.value)
        r.reads = int(reads_per[t])
        r.read_hits = int(rhits_per[t])
        r.writes = int(writes_per[t])
        r.write_hits = int(whits_per[t])
        rmiss = r.reads - r.read_hits
        fl = int(flush_per[t])
        if pol is WritePolicy.WB:
            r.cache_writes = rmiss + r.writes
            r.total_latency = (r.read_hits * t_fast + rmiss * t_slow
                               + r.writes * t_fast + fl * flush_cost)
        elif pol is WritePolicy.WT:
            r.cache_writes = rmiss + r.writes
            r.total_latency = (r.read_hits * t_fast + rmiss * t_slow
                               + r.writes * t_write_bypass
                               + fl * flush_cost)
        else:
            r.cache_writes = rmiss
            r.total_latency = (r.read_hits * t_fast + rmiss * t_slow
                               + r.writes * t_write_bypass
                               + fl * flush_cost)

        # ------------------------------------------- final LRU state
        c = caches[k]
        if c is not None:
            sl = slice(starts[t], ends[t])
            if t in tokens:
                death, tdirty, _ = tokens[t]
                keep = is_read[sl] & (death == ends[t] - starts[t])
                dirty_keep = tdirty[keep]
            else:
                blk_last = last[sl]
                if pol is WritePolicy.RO:
                    keep = blk_last & is_read[sl]
                else:
                    keep = blk_last & (D[sl] < cap)
                dirty_keep = dirty_after[starts[t]:ends[t]][keep]
            js = np.flatnonzero(keep) + starts[t]       # ascending = LRU->MRU
            c.set_state_arrays(orig_addr[js], dirty_keep)
        results[k] = r
    return (results, rds) if return_window_rd else results


def simulate_batch(trace: Trace, capacity: int,
                   policy: WritePolicy = WritePolicy.WB,
                   t_fast: float = 1.0, t_slow: float = 20.0,
                   t_write_bypass: float | None = None,
                   flush_cost: float = 0.0,
                   cache: LRUCache | None = None) -> SimResult:
    """Drop-in vectorized replacement for ``simulator.simulate``."""
    return simulate_many([trace], [capacity], [policy], t_fast=t_fast,
                         t_slow=t_slow, t_write_bypass=t_write_bypass,
                         flush_cost=flush_cost, caches=[cache])[0]
