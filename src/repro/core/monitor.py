"""Fused multi-tenant Monitor/Analyzer — one counting pass for all tenants,
optionally one *device program* for the whole window.

``ECICacheManager.analyze`` used to loop tenants in Python: a reuse-distance
pass, ``build_hit_ratio_function`` and the Alg.-3 write ratio per tenant, so
the control plane — not the simulated I/O — dominated at the ROADMAP's
thousand-tenant scale.  ``analyze_windows`` replaces that loop with batched
array code, in one of three pipelines:

  * ``pipeline="host"`` (default): the fused numpy path below — one padded
    tape, one counting pass, segment reductions.  Stage boundaries still
    cross the host: the counting pass syncs once per distinct padded width
    (``stack_distances_segments_accel``), and curves/write ratios/URD sizes
    are numpy reductions over the fetched distances.
  * ``pipeline="device"``: the same window, computed by **one jitted
    device program per window shape bucket** (``core.device_pipeline``).
    Ingest scatters the padded tape's links once, then counting
    (``ops.segment_counts_device`` — Pallas kernel on TPU, the
    ``cache_sim_segments_tree`` merge-sort-tree oracle elsewhere), the
    stacked-breakpoint curve build (a device twin of
    ``BatchedHitRatioFunctions``, reduced by per-row sort + run-length
    scatter), Alg.-3 write ratios (device bincount) and the URD sizes all
    run inside a single jit — **zero host syncs inside the window**, one
    sync to fetch the results.  Off TPU the program runs under
    ``jax.experimental.enable_x64`` and every output is bit-identical to
    the host pipeline (differential-tested in ``tests/test_monitor_scale``
    across both routes); on TPU it runs in f32/int32 with a documented
    tolerance.  ``precomputed_trd`` is ignored on this path — the program
    recounts on device (deterministically equal), which beats shipping
    per-tenant host arrays back in.  ``DeviceWindowPipeline`` extends the
    same program through the partition stage and double-buffers ingest
    across windows.
  * ``pipeline="sharded"``: the device program partitioned over a 1-D
    ``("shards",)`` mesh (``core.shard_pipeline``).  The padded tape is
    split **by whole tenant-segments** (greedy width-balanced assignment
    that keeps every shard's rows descending-pow2 self-aligned), and each
    shard runs the same counting/curve/write-ratio stage closures under
    ``shard_map`` on its resident chunk.  *Why sharding is exact*: the
    boundary-severing argument above is segment-local — occurrence links
    are clamped at segment ends and every pad/cross-segment dominance
    contribution cancels identically — so a shard holding whole segments
    computes exactly the counts the global tape would, with **no
    cross-device links at all**.  Only integer per-tenant summaries
    (breakpoint/URD/write counts) are ``psum``-reduced across shards
    (exact — each tenant lives wholly on one shard, foreign shards add
    zeros) and the device-resident curve store is ``all_gather``-ed once
    for the single replicated step, the envelope-walk budget cut — so
    curves, URD sizes, write ratios and allocations stay bit-identical
    to the fused host path at any shard count.  Still ≤ 1 host sync per
    window *per mesh*.  Default mesh:
    ``distributed.sharding.control_plane_mesh()`` over every local
    device (tests/CI force 8 host devices via ``XLA_FLAGS``).

All pipelines accept a ``StageProfile`` (``profile=``) recording per-stage
wall time and host-sync counts — ``benchmarks/bench_monitor_scale.py
--profile`` reports the breakdown, and the ≤1-sync-per-window(-per-mesh)
property of the device and sharded programs is asserted in tests.

The fused host path:

  * **One padded tape.**  All tenants' Δt window traces are concatenated
    into a single access tape with per-tenant segment offsets.  Occurrence
    links are severed at segment boundaries and ``nxt`` is clamped to the
    segment end, and the counting pass lays the segments out
    **power-of-two padded and self-aligned** (``batch_sim``'s
    ``padded_segment_layout``: each segment padded to the next power of
    two, segments ordered by descending padded width so every segment
    starts at a multiple of its own width).  The merge-tree stack-distance
    recursion then *stops at each segment's padded width*, so no merge
    level ever spans two tenants and the deep global-tape levels — which
    made the pre-padding fused pass *lose* to the per-tenant loop at 8M
    accesses — are never built at all.

    *Why padding is exact.*  Padding entries carry sentinel occurrence
    links (``prev = -1``, an empty coverage interval, counting value 0
    below every real segment-local ``nxt >= 1``), so a pad never enters a
    real access's dominance count — the same cancellation argument as the
    boundary severing: ``SD(i) = F(i) - G(i)`` only ever queries positions
    inside ``i``'s own segment, every cross-segment or pad contribution to
    ``F`` and ``G`` is identically zero there (a clamped link never
    reaches past its segment, a pad's interval is empty), and inside a
    self-aligned segment the width-bounded tree performs exactly the
    merges the segment-alone tree would.  The padded pass is therefore
    bit-identical to the per-tenant path — property-tested across
    adversarial shapes in ``tests/test_monitor_padding.py``.

    On TPU hosts the padded tape routes through the ``cache_sim`` ops
    layer instead (``stack_distances_segments_accel``): one Pallas kernel
    launch per distinct padded width, each with its grid restricted to the
    segment-aligned (i, j) blocks.
  * **Segment reductions.**  URD/TRD sample histograms, hit-ratio curves
    (``build_hit_ratio_functions``: one composite-key sort for all
    tenants, stacked breakpoint arrays), Alg.-3 write ratios (re-touch
    writes per tenant = one ``bincount``) and URD-based sizes all come
    from the same pass — no per-tenant Python loop anywhere.
  * **SHARDS end-to-end.**  With ``sample_rate`` set (a float, or
    ``"auto"`` for the target-sample-count tuner) the tape is spatially
    filtered *before* counting — hash salts are seed-stabilized per
    (tenant, window) via ``shards_salt`` — distances are scaled by 1/rate,
    curve heights use the Horvitz–Thompson estimator, and per-tenant
    expected-error bars (~1/sqrt(kept)) are reported.  Write ratios are
    estimated on the sampled sub-trace: spatial sampling keeps every access
    of a kept address, so the re-touch classification is exact per address
    and the ratio is unbiased.
  * **Precomputed distances.**  The batch replay engine already counts the
    window's stack distances; ``precomputed_trd`` forwards those raw TRD
    arrays so the exact path never re-counts what ``simulate_many`` just
    measured.

Exactness: on the exact path (``sample_rate=None``) every curve, URD size
and write ratio is bit-identical to the per-tenant seed code — property
tested in ``tests/test_monitor_scale.py``.
"""
from __future__ import annotations

import contextlib
import dataclasses

import numpy as np

from repro.core.batch_sim import (_accel_default, _stack_distances_host,
                                  padded_segment_layout)
from repro.core.mrc import BatchedHitRatioFunctions, build_hit_ratio_functions
from repro.core.reuse_distance import (auto_sample_rate, shards_keep_mask,
                                       shards_salt)
from repro.core.trace import Trace, validate_trace

__all__ = ["MonitorResult", "analyze_windows"]


@dataclasses.dataclass(frozen=True)
class MonitorResult:
    """Per-tenant Analyzer outputs for one Δt window, batched.

    curves: stacked hit-ratio step functions (sequence of
      ``HitRatioFunction`` views; feed directly to the partitioners).
    urd_sizes: int64[N] — ``calculateURDbasedSize`` per tenant (at the
      requested percentile; sampled path: from the scaled distances).
    write_ratios: float64[N] — Alg. 3 ``(WAW + WAR) / n`` per tenant
      (sampled path: unbiased estimate from the kept sub-trace).
    sample_rates: float64[N] — effective SHARDS rate per tenant (1.0 exact).
    expected_errors: float64[N] — expected absolute curve error
      (~1/sqrt(kept accesses)); 0.0 on the exact path.
    kind: "urd" | "trd".
    """

    curves: BatchedHitRatioFunctions
    urd_sizes: np.ndarray
    write_ratios: np.ndarray
    sample_rates: np.ndarray
    expected_errors: np.ndarray
    kind: str


def _segment_links(addrs: np.ndarray, tid: np.ndarray,
                   bounds: np.ndarray,
                   layout=None) -> tuple[np.ndarray, np.ndarray]:
    """prev/next occurrence links on a multi-tenant tape, severed at
    segment boundaries; ``nxt`` clamped to the owning segment's end.

    Runs on the same segment-aligned padded layout as the counting pass:
    ``(addr + 1) << pb | local_position`` keys are scattered onto the
    padded tape (pads carry key 0, sorting below every real entry and
    severing runs automatically) and each width group is one in-place SIMD
    row sort — adjacent equal-address entries of a row are then exactly
    the occurrence pairs.  No global ``argsort``: the value sort plus a
    handful of O(m) passes replaces it.  Falls back to the composite-key
    argsort for negative or enormous address spaces.
    """
    m = addrs.shape[0]
    prev = np.full(m, -1, dtype=np.int64)
    nxt = np.repeat(bounds[1:], np.diff(bounds))     # default: segment end
    if m == 0:
        return prev, nxt
    lo = int(addrs.min(initial=0))
    amax = int(addrs.max(initial=0))
    src, tpos, base_src, base_pad, widths, total, seg_starts = \
        layout if layout is not None else padded_segment_layout(bounds)
    pb = int(widths[0] - 1).bit_length()             # local-position bits
    vb = (amax - min(lo, 0) + 1).bit_length()        # address field bits
    if lo < 0 or vb + pb > 62:
        # composite key would overflow: legacy sort path
        big = amax + 1 - min(lo, 0)
        n_seg = int(tid[-1]) + 1
        same = np.zeros(m, dtype=bool)
        if lo < 0 or n_seg * big >= 2**62:
            order = np.lexsort((addrs, tid))
            sa, st = addrs[order], tid[order]
            same[1:] = (sa[1:] == sa[:-1]) & (st[1:] == st[:-1])
        else:
            key = tid * big + addrs
            order = np.argsort(key, kind="stable")
            sk = key[order]                  # one gather serves the compare
            same[1:] = sk[1:] == sk[:-1]
        prev[order[1:]] = np.where(same[1:], order[:-1], -1)
        nxt_full = np.full(m, m, dtype=np.int64)
        nxt_full[order[:-1]] = np.where(same[1:], order[1:], m)
        return prev, np.minimum(nxt_full, nxt)
    kdt = np.int32 if vb + pb <= 31 else np.int64
    gk = np.zeros(total, dtype=kdt)
    loc = (tpos - base_pad).astype(kdt)
    av = (addrs if src is None else addrs[src]).astype(kdt)
    gk[tpos] = ((av + kdt(1)) << pb) | loc
    # one in-place SIMD row sort per distinct width (contiguous, aligned)
    csw = np.concatenate([[0], np.cumsum(widths)]).astype(np.int64)
    heads = np.flatnonzero(
        np.concatenate([[True], widths[1:] != widths[:-1]]))
    for h0, h1 in zip(heads, np.append(heads[1:], widths.size)):
        glo, ghi = int(csw[h0]), int(csw[int(h1)])
        w = int(widths[h0])
        gk[glo:ghi].reshape(-1, w).sort(axis=1)
    H = gk >> pb                                     # 0 at pads
    # adjacent equal addresses inside a row = occurrence pairs; rows are
    # severed explicitly, pads sever themselves (H == 0 < every real)
    pair = np.empty(total, dtype=bool)
    pair[0] = False
    np.equal(H[1:], H[:-1], out=pair[1:])
    pair[1:] &= H[1:] > 0
    pair[csw[:-1]] = False                           # row starts
    # decode original tape positions of the sorted entries (pads decode to
    # their row's start; harmless — they never appear in a pair)
    P = (gk & kdt((1 << pb) - 1)).astype(np.int64)
    P += np.repeat(seg_starts, widths)
    iv = np.flatnonzero(pair)                        # pair = (iv - 1, iv)
    prev[P[iv]] = P[iv - 1]
    nxt[P[iv - 1]] = P[iv]
    return prev, nxt


def _pstage(profile, name: str):
    """Profile a host-pipeline stage (no-op without a ``StageProfile``)."""
    return (profile.stage(name) if profile is not None
            else contextlib.nullcontext())


def _sd_pass(prev: np.ndarray, nxt_c: np.ndarray, backend: str,
             bounds: np.ndarray | None = None,
             layout=None, profile=None) -> np.ndarray:
    """One width-bounded stack-distance counting pass over the whole tape.

    ``bounds`` carries the per-tenant segment offsets so both backends can
    use the segment-aligned padded layout (host: width-bounded merge tree;
    accel: width-restricted kernel grids) instead of paying the full
    global merge depth; ``layout`` is the tape's precomputed
    ``padded_segment_layout`` (shared with the link construction).
    ``profile`` records the accel route's per-width-launch host syncs.
    """
    if backend == "auto":
        backend = "accel" if _accel_default() else "host"
    if backend == "accel":
        from repro.kernels.cache_sim.ops import stack_distances_segments_accel
        return stack_distances_segments_accel(prev, nxt_c, bounds=bounds,
                                              layout=layout, profile=profile)
    return _stack_distances_host(prev, nxt_c, bounds=bounds, layout=layout)


def _urd_sizes(dist: np.ndarray, tid: np.ndarray, n_tenants: int,
               bounds: np.ndarray, percentile: float,
               curves: BatchedHitRatioFunctions) -> np.ndarray:
    """Batched ``urd_cache_blocks`` (max sample + 1, or percentile)."""
    if percentile >= 100.0:
        # max sample + 1 == the curve's largest breakpoint, already stacked
        return curves.max_useful_sizes.astype(np.int64).copy()
    out = np.zeros(n_tenants, dtype=np.int64)
    for i in range(n_tenants):                   # rare config; no recount
        seg = dist[bounds[i]:bounds[i + 1]]
        s = seg[seg >= 0]
        if s.size:
            out[i] = int(np.percentile(s, percentile)) + 1
    return out


def analyze_windows(traces: list[Trace], kind: str = "urd",
                    percentile: float = 100.0,
                    sample_rate: float | str | None = None,
                    window_seed: int = 0,
                    sample_target: int = 4096, sample_floor: int = 256,
                    precomputed_trd: list[np.ndarray | None] | None = None,
                    tenant_ids: list[int] | None = None,
                    backend: str = "auto", pipeline: str = "host",
                    profile=None, validate: bool = False,
                    fault_hook=None) -> MonitorResult:
    """Analyze every tenant's Δt window in one fused pass (see module doc).

    ``precomputed_trd[i]`` (host exact path only) carries tenant i's raw
    window-internal TRD sample array from the batch replay engine; missing
    entries are counted here.  ``tenant_ids`` stabilizes the per-tenant
    SHARDS salts under tenant retirement (defaults to positional ids).
    ``pipeline="device"`` routes the window through the fused device
    program (one jit, one host sync — requires ``percentile == 100``);
    ``pipeline="sharded"`` through its ``shard_map`` twin over the
    default control-plane mesh (same requirement, one sync per mesh);
    ``profile`` (a ``device_pipeline.StageProfile``) records per-stage
    times and host syncs on any pipeline.

    ``validate=True`` checks every tape against the ingest contract first
    and raises ``TraceError`` with (tenant, window) coordinates on a
    malformed one (``window_seed`` doubles as the window coordinate) —
    direct callers get one clear error instead of a cryptic numpy/lax
    failure deep in the counting pass.  ``fault_hook`` (internal, fault
    injection) is invoked once at the pipeline's dispatch boundary: on
    the host path right before the counting/curve stage, on the device
    path immediately before the fused program launch.
    """
    if kind not in ("trd", "urd"):
        raise ValueError(f"kind must be 'trd' or 'urd', got {kind!r}")
    if pipeline not in ("host", "device", "sharded"):
        raise ValueError(f"pipeline must be 'host', 'device' or 'sharded', "
                         f"got {pipeline!r}")
    if pipeline != "host" and percentile < 100.0:
        raise ValueError(f"pipeline={pipeline!r} computes URD sizes from "
                         "the curve store (percentile=100); use the host "
                         "pipeline for percentile < 100")
    n = len(traces)
    lens = np.array([len(t) for t in traces], dtype=np.int64)
    bounds = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    m = int(bounds[-1])
    ids = np.asarray(tenant_ids if tenant_ids is not None else range(n),
                     dtype=np.int64)
    if validate:
        for i, t in enumerate(traces):
            validate_trace(t, tenant=int(ids[i]) if i < ids.size else i,
                           window=window_seed)

    if sample_rate is None:
        # ------------------------------------------------------ exact path
        is_read = (np.concatenate([t.is_read for t in traces]) if m
                   else np.zeros(0, bool))
        tid = np.repeat(np.arange(n, dtype=np.int64), lens)
        if pipeline in ("device", "sharded"):
            # one fused program (per mesh when sharded), one sync;
            # recounts even precomputed windows (deterministically equal
            # — see module doc)
            addrs = (np.concatenate([t.addrs for t in traces]) if m
                     else np.zeros(0, np.int64))
            if pipeline == "sharded":
                from repro.core.shard_pipeline import monitor_window_sharded
                curves, urd, wr, _ = monitor_window_sharded(
                    addrs, is_read, bounds, lens, kind=kind,
                    profile=profile, launch_hook=fault_hook)
            else:
                from repro.core.device_pipeline import monitor_window_device
                curves, urd, wr, _ = monitor_window_device(
                    addrs, is_read, bounds, lens, kind=kind,
                    profile=profile, launch_hook=fault_hook)
            return MonitorResult(curves, urd, wr, np.ones(n),
                                 np.zeros(n), kind)
        if fault_hook is not None:
            fault_hook()
        pre = precomputed_trd or []
        dist = np.full(m, -1, dtype=np.int64)
        need = []
        for i in range(n):
            raw = pre[i] if i < len(pre) else None
            if raw is not None:
                dist[bounds[i]:bounds[i + 1]] = raw
            elif lens[i] > 0:
                need.append(i)
        if need:
            # only the tenants without precomputed distances hit the
            # counting pass (no tape is built at all when every window
            # came through the batch replay engine)
            if len(need) == n:
                sel = np.ones(m, dtype=bool)
            else:
                sel = np.zeros(m, dtype=bool)
                for i in need:
                    sel[bounds[i]:bounds[i + 1]] = True
            addrs = np.concatenate([t.addrs for t in traces])
            sub_addr = addrs[sel]
            sub_tid = tid[sel]
            sub_lens = np.bincount(sub_tid, minlength=n)[need]
            sub_bounds = np.concatenate([[0], np.cumsum(sub_lens)])
            # compact tenant ids so segment ends line up on the sub-tape
            remap = np.zeros(n, dtype=np.int64)
            remap[need] = np.arange(len(need))
            sub_bounds = sub_bounds.astype(np.int64)
            with _pstage(profile, "links"):
                layout = padded_segment_layout(sub_bounds)
                prev, nxt_c = _segment_links(sub_addr, remap[sub_tid],
                                             sub_bounds, layout)
            with _pstage(profile, "count"):
                dist[sel] = _sd_pass(prev, nxt_c, backend, sub_bounds,
                                     layout, profile=profile)
        with _pstage(profile, "curve"):
            hot = dist >= 0
            wr = (np.bincount(tid[hot & ~is_read], minlength=n)
                  / np.maximum(lens, 1))
            smask = (hot & is_read) if kind == "urd" else hot
            if kind == "urd" and percentile < 100.0:
                dist = np.where(smask, dist, -1)  # rare: per-segment slices
            curves = build_hit_ratio_functions(dist, tid, n, lens,
                                               mask=smask)
            urd = _urd_sizes(dist, tid, n, bounds, percentile, curves)
        if profile is not None:
            profile.windows += 1
        return MonitorResult(curves, urd, wr, np.ones(n),
                             np.zeros(n), kind)

    # -------------------------------------------------------- sampled path
    if sample_rate == "auto":
        rates = np.array([auto_sample_rate(int(nl), sample_target,
                                           sample_floor) for nl in lens])
    else:
        r = float(sample_rate)
        if not (0 < r <= 1):
            raise ValueError("sample_rate must be in (0, 1] or 'auto'")
        rates = np.full(n, r)
    # spatial filter per tenant (seed-stabilized salt per (tenant, window));
    # only the kept sub-tape is ever concatenated — the Monitor's ingest
    # never materializes a full-window tape on the sampled path
    keeps = [shards_keep_mask(t.addrs, float(rates[i]),
                              shards_salt(window_seed, int(ids[i])))
             for i, t in enumerate(traces)]
    kept = np.array([int(k.sum()) for k in keeps], dtype=np.int64)
    sub_bounds = np.concatenate([[0], np.cumsum(kept)]).astype(np.int64)
    if int(kept.sum()):
        addrs_s = np.concatenate(
            [t.addrs[k] for t, k in zip(traces, keeps)])
        read_s = np.concatenate(
            [t.is_read[k] for t, k in zip(traces, keeps)])
    else:
        addrs_s = np.zeros(0, np.int64)
        read_s = np.zeros(0, bool)
    tid_s = np.repeat(np.arange(n, dtype=np.int64), kept)
    if pipeline in ("device", "sharded"):
        # the fused program scales distances, builds the HT curves and the
        # write ratios on device; cold accesses of the kept sub-tape (its
        # distinct addresses) come back for the error bars
        if pipeline == "sharded":
            from repro.core.shard_pipeline import monitor_window_sharded
            curves, urd, wr, distinct = monitor_window_sharded(
                addrs_s, read_s, sub_bounds, lens, rates=rates, kind=kind,
                profile=profile, launch_hook=fault_hook)
        else:
            from repro.core.device_pipeline import monitor_window_device
            curves, urd, wr, distinct = monitor_window_device(
                addrs_s, read_s, sub_bounds, lens, rates=rates, kind=kind,
                profile=profile, launch_hook=fault_hook)
    else:
        if fault_hook is not None:
            fault_hook()
        with _pstage(profile, "links"):
            layout = padded_segment_layout(sub_bounds)
            prev, nxt_c = _segment_links(addrs_s, tid_s, sub_bounds, layout)
        with _pstage(profile, "count"):
            sd = _sd_pass(prev, nxt_c, backend, sub_bounds, layout,
                          profile=profile)
        with _pstage(profile, "curve"):
            rate_s = rates[tid_s]
            dist = np.where(sd >= 0, np.round(sd / np.maximum(rate_s, 1e-300)
                                              ).astype(np.int64), -1)
            hot_w = (dist >= 0) & ~read_s
            wr = np.bincount(tid_s[hot_w], minlength=n) / np.maximum(kept, 1)
            if kind == "urd":
                dist = np.where(read_s, dist, -1)
            curves = build_hit_ratio_functions(dist, tid_s, n, lens,
                                               rates=rates)
            urd = _urd_sizes(dist, tid_s, n, sub_bounds, percentile, curves)
            # error bars scale with the kept *distinct* addresses (= cold
            # accesses of the sub-tape): curve noise is binomial over
            # surviving addresses
            distinct = np.bincount(tid_s[prev < 0], minlength=n)
        if profile is not None:
            profile.windows += 1
    errors = np.where(rates < 1.0,
                      np.minimum(1.0,
                                 1.0 / np.sqrt(np.maximum(distinct, 1))),
                      0.0)
    return MonitorResult(curves, urd, wr, rates, errors, kind)
