"""Fused multi-tenant Monitor/Analyzer — one counting pass for all tenants.

``ECICacheManager.analyze`` used to loop tenants in Python: a reuse-distance
pass, ``build_hit_ratio_function`` and the Alg.-3 write ratio per tenant, so
the control plane — not the simulated I/O — dominated at the ROADMAP's
thousand-tenant scale.  ``analyze_windows`` replaces that loop with batched
array code end to end:

  * **One tape.**  All tenants' Δt window traces are concatenated into a
    single access tape with per-tenant segment offsets.  Occurrence links
    are severed at segment boundaries and ``nxt`` is clamped to the segment
    end, so one merge-tree stack-distance pass (``batch_sim``'s
    ``_stack_distances_host`` / the ``cache_sim`` kernel on TPU) yields
    every tenant's exact window reuse distances at once — the cross-segment
    dominance contributions provably cancel (a clamped link never reaches
    into the next segment).
  * **Segment reductions.**  URD/TRD sample histograms, hit-ratio curves
    (``build_hit_ratio_functions``: one lexsort for all tenants, stacked
    breakpoint arrays), Alg.-3 write ratios (re-touch writes per tenant =
    one ``bincount``) and URD-based sizes all come from the same pass — no
    per-tenant Python loop anywhere.
  * **SHARDS end-to-end.**  With ``sample_rate`` set (a float, or
    ``"auto"`` for the target-sample-count tuner) the tape is spatially
    filtered *before* counting — hash salts are seed-stabilized per
    (tenant, window) via ``shards_salt`` — distances are scaled by 1/rate,
    curve heights use the Horvitz–Thompson estimator, and per-tenant
    expected-error bars (~1/sqrt(kept)) are reported.  Write ratios are
    estimated on the sampled sub-trace: spatial sampling keeps every access
    of a kept address, so the re-touch classification is exact per address
    and the ratio is unbiased.
  * **Precomputed distances.**  The batch replay engine already counts the
    window's stack distances; ``precomputed_trd`` forwards those raw TRD
    arrays so the exact path never re-counts what ``simulate_many`` just
    measured.

Exactness: on the exact path (``sample_rate=None``) every curve, URD size
and write ratio is bit-identical to the per-tenant seed code — property
tested in ``tests/test_monitor_scale.py``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.batch_sim import _accel_default, _stack_distances_host
from repro.core.mrc import BatchedHitRatioFunctions, build_hit_ratio_functions
from repro.core.reuse_distance import (auto_sample_rate, shards_keep_mask,
                                       shards_salt)
from repro.core.trace import Trace

__all__ = ["MonitorResult", "analyze_windows"]


@dataclasses.dataclass(frozen=True)
class MonitorResult:
    """Per-tenant Analyzer outputs for one Δt window, batched.

    curves: stacked hit-ratio step functions (sequence of
      ``HitRatioFunction`` views; feed directly to the partitioners).
    urd_sizes: int64[N] — ``calculateURDbasedSize`` per tenant (at the
      requested percentile; sampled path: from the scaled distances).
    write_ratios: float64[N] — Alg. 3 ``(WAW + WAR) / n`` per tenant
      (sampled path: unbiased estimate from the kept sub-trace).
    sample_rates: float64[N] — effective SHARDS rate per tenant (1.0 exact).
    expected_errors: float64[N] — expected absolute curve error
      (~1/sqrt(kept accesses)); 0.0 on the exact path.
    kind: "urd" | "trd".
    """

    curves: BatchedHitRatioFunctions
    urd_sizes: np.ndarray
    write_ratios: np.ndarray
    sample_rates: np.ndarray
    expected_errors: np.ndarray
    kind: str


def _segment_links(addrs: np.ndarray, tid: np.ndarray,
                   bounds: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """prev/next occurrence links on a multi-tenant tape, severed at
    segment boundaries; ``nxt`` clamped to the owning segment's end."""
    m = addrs.shape[0]
    lo = int(addrs.min(initial=0))
    big = int(addrs.max(initial=0)) + 1 - min(lo, 0)
    n_seg = int(tid[-1]) + 1 if m else 1
    if lo < 0 or n_seg * big >= 2**62:       # composite key would overflow
        order = np.lexsort((addrs, tid))
    else:
        order = np.argsort(tid * big + addrs, kind="stable")
    sa, st = addrs[order], tid[order]
    same = np.zeros(m, dtype=bool)
    same[1:] = (sa[1:] == sa[:-1]) & (st[1:] == st[:-1])
    prev = np.full(m, -1, dtype=np.int64)
    prev[order[1:]] = np.where(same[1:], order[:-1], -1)
    nxt = np.full(m, m, dtype=np.int64)
    nxt[order[:-1]] = np.where(same[1:], order[1:], m)
    end_of = np.repeat(bounds[1:], np.diff(bounds))
    return prev, np.minimum(nxt, end_of)


def _sd_pass(prev: np.ndarray, nxt_c: np.ndarray, backend: str) -> np.ndarray:
    """One stack-distance counting pass over the whole tape."""
    if backend == "auto":
        backend = "accel" if _accel_default() else "host"
    if backend == "accel":
        from repro.kernels.cache_sim.ops import stack_distances_segments_accel
        return stack_distances_segments_accel(prev, nxt_c)
    return _stack_distances_host(prev, nxt_c)


def _urd_sizes(dist: np.ndarray, tid: np.ndarray, n_tenants: int,
               bounds: np.ndarray, percentile: float,
               curves: BatchedHitRatioFunctions) -> np.ndarray:
    """Batched ``urd_cache_blocks`` (max sample + 1, or percentile)."""
    if percentile >= 100.0:
        # max sample + 1 == the curve's largest breakpoint, already stacked
        return curves.max_useful_sizes.astype(np.int64).copy()
    out = np.zeros(n_tenants, dtype=np.int64)
    for i in range(n_tenants):                   # rare config; no recount
        seg = dist[bounds[i]:bounds[i + 1]]
        s = seg[seg >= 0]
        if s.size:
            out[i] = int(np.percentile(s, percentile)) + 1
    return out


def analyze_windows(traces: list[Trace], kind: str = "urd",
                    percentile: float = 100.0,
                    sample_rate: float | str | None = None,
                    window_seed: int = 0,
                    sample_target: int = 4096, sample_floor: int = 256,
                    precomputed_trd: list[np.ndarray | None] | None = None,
                    tenant_ids: list[int] | None = None,
                    backend: str = "auto") -> MonitorResult:
    """Analyze every tenant's Δt window in one fused pass (see module doc).

    ``precomputed_trd[i]`` (exact path only) carries tenant i's raw
    window-internal TRD sample array from the batch replay engine; missing
    entries are counted here.  ``tenant_ids`` stabilizes the per-tenant
    SHARDS salts under tenant retirement (defaults to positional ids).
    """
    if kind not in ("trd", "urd"):
        raise ValueError(f"kind must be 'trd' or 'urd', got {kind!r}")
    n = len(traces)
    lens = np.array([len(t) for t in traces], dtype=np.int64)
    bounds = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    m = int(bounds[-1])
    ids = np.asarray(tenant_ids if tenant_ids is not None else range(n),
                     dtype=np.int64)

    if sample_rate is None:
        # ------------------------------------------------------ exact path
        is_read = (np.concatenate([t.is_read for t in traces]) if m
                   else np.zeros(0, bool))
        tid = np.repeat(np.arange(n, dtype=np.int64), lens)
        pre = precomputed_trd or []
        dist = np.full(m, -1, dtype=np.int64)
        need = []
        for i in range(n):
            raw = pre[i] if i < len(pre) else None
            if raw is not None:
                dist[bounds[i]:bounds[i + 1]] = raw
            elif lens[i] > 0:
                need.append(i)
        if need:
            # only the tenants without precomputed distances hit the
            # counting pass (no tape is built at all when every window
            # came through the batch replay engine)
            if len(need) == n:
                sel = np.ones(m, dtype=bool)
            else:
                sel = np.zeros(m, dtype=bool)
                for i in need:
                    sel[bounds[i]:bounds[i + 1]] = True
            addrs = np.concatenate([t.addrs for t in traces])
            sub_addr = addrs[sel]
            sub_tid = tid[sel]
            sub_lens = np.bincount(sub_tid, minlength=n)[need]
            sub_bounds = np.concatenate([[0], np.cumsum(sub_lens)])
            # compact tenant ids so segment ends line up on the sub-tape
            remap = np.zeros(n, dtype=np.int64)
            remap[need] = np.arange(len(need))
            prev, nxt_c = _segment_links(sub_addr, remap[sub_tid],
                                         sub_bounds.astype(np.int64))
            dist[sel] = _sd_pass(prev, nxt_c, backend)
        hot_w = (dist >= 0) & ~is_read
        wr = (np.bincount(tid[hot_w], minlength=n)
              / np.maximum(lens, 1))
        if kind == "urd":
            dist = np.where(is_read, dist, -1)
        curves = build_hit_ratio_functions(dist, tid, n, lens)
        urd = _urd_sizes(dist, tid, n, bounds, percentile, curves)
        return MonitorResult(curves, urd, wr, np.ones(n),
                             np.zeros(n), kind)

    # -------------------------------------------------------- sampled path
    if sample_rate == "auto":
        rates = np.array([auto_sample_rate(int(nl), sample_target,
                                           sample_floor) for nl in lens])
    else:
        r = float(sample_rate)
        if not (0 < r <= 1):
            raise ValueError("sample_rate must be in (0, 1] or 'auto'")
        rates = np.full(n, r)
    # spatial filter per tenant (seed-stabilized salt per (tenant, window));
    # only the kept sub-tape is ever concatenated — the Monitor's ingest
    # never materializes a full-window tape on the sampled path
    keeps = [shards_keep_mask(t.addrs, float(rates[i]),
                              shards_salt(window_seed, int(ids[i])))
             for i, t in enumerate(traces)]
    kept = np.array([int(k.sum()) for k in keeps], dtype=np.int64)
    sub_bounds = np.concatenate([[0], np.cumsum(kept)]).astype(np.int64)
    if int(kept.sum()):
        addrs_s = np.concatenate(
            [t.addrs[k] for t, k in zip(traces, keeps)])
        read_s = np.concatenate(
            [t.is_read[k] for t, k in zip(traces, keeps)])
    else:
        addrs_s = np.zeros(0, np.int64)
        read_s = np.zeros(0, bool)
    tid_s = np.repeat(np.arange(n, dtype=np.int64), kept)
    prev, nxt_c = _segment_links(addrs_s, tid_s, sub_bounds)
    sd = _sd_pass(prev, nxt_c, backend)
    rate_s = rates[tid_s]
    dist = np.where(sd >= 0, np.round(sd / np.maximum(rate_s, 1e-300)
                                      ).astype(np.int64), -1)
    hot_w = (dist >= 0) & ~read_s
    wr = np.bincount(tid_s[hot_w], minlength=n) / np.maximum(kept, 1)
    if kind == "urd":
        dist = np.where(read_s, dist, -1)
    curves = build_hit_ratio_functions(dist, tid_s, n, lens, rates=rates)
    urd = _urd_sizes(dist, tid_s, n, sub_bounds, percentile, curves)
    # error bars scale with the kept *distinct* addresses (= cold accesses
    # of the sub-tape): curve noise is binomial over surviving addresses
    distinct = np.bincount(tid_s[prev < 0], minlength=n)
    errors = np.where(rates < 1.0,
                      np.minimum(1.0,
                                 1.0 / np.sqrt(np.maximum(distinct, 1))),
                      0.0)
    return MonitorResult(curves, urd, wr, rates, errors, kind)
