"""ReCA-style online workload characterization (PAPERS.md, arxiv 1805.06747).

ECI-Cache's premise is *online* adaptation: URD and the Alg.-3 write ratio
are recomputed per Δt so the partition tracks the workload.  This module
supplies the half the fixed-Δt loop never exercised — detecting *when* a
tenant's behavior changes so ``ECICacheManager`` can reconfigure on phase
boundaries instead of only on the clock.  It has two parts: a vectorized
per-(tenant, window) feature pass and a hysteresis phase detector.

Feature definitions (one ``WindowFeatures`` row per tenant per window)
-----------------------------------------------------------------------

  * ``stride_hist[:, 4]`` — normalized histogram of successive address
    deltas ``addrs[i] - addrs[i-1]`` *within* a tenant's window, binned as
    ``[+1, 0, local, other]`` where ``local`` means ``2 <= |s| <= 64``
    (a semi-sequential run or small seek) and ``other`` is everything
    else (random).  ``seq_fraction`` is the ``+1`` bin — the fraction of
    perfectly sequential successors, the ReCA sequential/random axis.
  * ``read_fraction`` — reads / accesses (the read/write-mix axis).
  * ``write_ratio`` — Alg. 3's ``(WAW + WAR) / n``: the fraction of
    accesses that are *write re-touches* (previous occurrence of the same
    address exists inside the window and the current access is a write).
    Identical to ``repro.core.write_policy.write_ratio`` per window, and
    to the fused monitor's per-tenant write ratio.
  * ``working_set`` — distinct addresses touched in the window (the
    number of cold accesses, i.e. positions with no previous occurrence).
  * ``jaccard_drift`` — ``1 - |A ∩ B| / |A ∪ B]`` between this window's
    distinct-address set and the previous window's (0 when no previous
    set is known): working-set *drift*, the axis that catches a tenant
    migrating to new data even when its mix/locality statistics are
    unchanged.
  * ``reuse_intensity`` — re-touch fraction ``1 - distinct / n``: how much
    of the window is re-reference at all (the quantity URD feeds on).

Fused computation — no second pass over the trace
-------------------------------------------------

The spatial features (working set, drift, reuse intensity, write ratio)
need per-position *previous-occurrence* information — exactly what the
fused monitor / batch replay engine already compute.  ``characterize_windows``
therefore accepts the per-tenant window reuse-distance arrays
(``dists[k]``, ``-1`` at cold positions) that ``simulate_many(...,
return_window_rd=True)`` returns: with those, the whole feature pass is a
handful of ``bincount``/``diff`` segment reductions over the window tape —
O(n) with **no sort and no counting pass**.  Only tenants *without* a
precomputed distance array fall back to one occurrence-link construction
(``monitor._segment_links`` on the same power-of-two padded, self-aligned
segment layout the counting pass uses).  The stream features (stride
histogram, read fraction) are plain O(n) reductions on the raw access
stream.

Sampled-path estimator (SHARDS + Horvitz–Thompson)
--------------------------------------------------

With ``sample_rate`` set, the spatial features are estimated from the
SHARDS-filtered sub-trace: spatial hashing keeps *every* access of a kept
address, so re-touch classification is exact per kept address and

  * ``working_set ≈ distinct_kept / rate``  (each distinct address is
    kept with probability ``rate`` — the Horvitz–Thompson estimator, the
    same correction the sampled monitor applies to curve heights),
  * ``write_ratio`` / ``reuse_intensity`` are ratio estimators over the
    kept sub-trace (numerator and denominator both restricted to kept
    accesses — unbiased, matching the monitor's sampled write ratio),
  * ``jaccard_drift`` compares *kept* distinct sets; because the keep
    decision is a pure function of the address, ``kept(A) ∩ kept(B) =
    kept(A ∩ B)`` and the kept-set Jaccard is a consistent estimator of
    the true one — **provided the filter is identical across windows**.
    The characterization filter therefore salts per *tenant only*
    (``characterize_salt``), deliberately unlike the monitor's
    per-(tenant, window) salts: a persistent spatial sample is what makes
    drift comparable window-over-window.

Stream features are always computed exactly on the raw stream: sampling
destroys successive-address deltas (kept accesses are not adjacent in the
original stream), and the exact computation is already sort-free O(n).

Hysteresis phase detection
--------------------------

``PhaseDetector`` keeps, per tenant, an EMA baseline over the normalized
feature vector ``[seq_fraction, read_fraction, write_ratio,
reuse_intensity, log2(working_set + 1) / ws_scale]`` plus a baseline drift
level.  The change score is the max of (a) the largest absolute deviation
of the feature vector from its baseline and (b) the *excess* Jaccard
drift over its baseline (weighted by ``drift_weight``; the steady-state
drift of a stationary workload is learned, only drift *beyond* it
scores).  The hysteresis rule: a tenant triggers when its score reaches
``hi`` while armed, and stays disarmed while its score sits in the
``[lo, hi)`` band.  On trigger the tenant *cold-restarts*: the next
window re-initializes the baseline and the warm-up repeats, so the new
phase becomes the reference from its first warmed window and a single
phase change yields a single event.  Additionally, when ``w_threshold``
is set, any
window whose write ratio crosses the threshold relative to the baseline
raises a ``"write_ratio"`` event even below ``hi`` — the Alg.-3 policy
flip must not wait for the next clock tick.  The first window a tenant is
ever seen only initializes its baseline (cold start, no event), the
first *drift* observation likewise only initializes the drift baseline,
and for ``warmup`` further windows the detector only updates its EMA
without triggering: a workload's very first window is systematically
atypical (caches and re-touch pools start empty), and the warm-up lets
the baseline absorb that transient instead of reporting it as a phase.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.batch_sim import padded_segment_layout
from repro.core.reuse_distance import shards_keep_mask, shards_salt
from repro.core.trace import Trace

__all__ = [
    "STRIDE_BINS",
    "WindowFeatures",
    "PhaseEvent",
    "PhaseDetector",
    "characterize_salt",
    "characterize_trace",
    "characterize_windows",
]

# stride histogram bins: [+1 (sequential), 0 (repeat), 2<=|s|<=64 (local
# seek / semi-sequential), other (random)]
STRIDE_BINS = 4
_LOCAL_REACH = 64

# fixed seed for the characterization SHARDS filter: per-tenant salts must
# be stable across windows so kept-set Jaccard drift is comparable (see
# module docstring) — deliberately not the monitor's per-window salts
_CHAR_SEED = 0x5EC4


def characterize_salt(tenant: int) -> int:
    """Window-stable SHARDS salt for the characterization filter."""
    return shards_salt(_CHAR_SEED, int(tenant))


@dataclasses.dataclass(frozen=True)
class WindowFeatures:
    """Per-tenant workload features for one Δt window (see module doc).

    ``address_sets[k]`` is tenant k's sorted distinct (kept) address
    array — feed it back as ``prev_sets`` when characterizing the next
    window so ``jaccard_drift`` is populated.  ``sample_rates`` records
    the effective SHARDS rate per tenant (1.0 exact).
    """

    stride_hist: np.ndarray      # float64[N, STRIDE_BINS], rows sum to 1
    seq_fraction: np.ndarray     # float64[N] == stride_hist[:, 0]
    read_fraction: np.ndarray    # float64[N]
    write_ratio: np.ndarray      # float64[N]  Alg. 3 (WAW+WAR)/n
    working_set: np.ndarray      # float64[N]  (HT-corrected when sampled)
    jaccard_drift: np.ndarray    # float64[N]  1 - Jaccard vs prev window
    reuse_intensity: np.ndarray  # float64[N]  re-touch fraction
    sample_rates: np.ndarray     # float64[N]
    address_sets: list           # [N] sorted int64 distinct kept addrs


def _stride_counts(addrs: np.ndarray) -> np.ndarray:
    """Histogram of successive deltas for one window (int64[STRIDE_BINS])."""
    out = np.zeros(STRIDE_BINS, dtype=np.int64)
    if addrs.shape[0] < 2:
        return out
    d = np.diff(addrs)
    a = np.abs(d)
    out[0] = int(np.sum(d == 1))
    out[1] = int(np.sum(d == 0))
    out[2] = int(np.sum((a >= 2) & (a <= _LOCAL_REACH)))
    out[3] = d.shape[0] - int(out[:3].sum())
    return out


def characterize_trace(trace: Trace, prev_set: np.ndarray | None = None,
                       rate: float = 1.0, salt: int | None = None
                       ) -> WindowFeatures:
    """Naive single-tenant reference (dict/set loops): the test oracle.

    Bit-identical to one row of ``characterize_windows`` — exact when
    ``rate == 1.0``, and on the identically-filtered sub-trace when a
    ``rate`` (and optionally an explicit ``salt``) is given.
    """
    n = len(trace)
    hist = _stride_counts(trace.addrs).astype(np.float64)
    hist /= max(int(hist.sum()), 1)
    read_fraction = float(np.sum(trace.is_read)) / max(n, 1)

    if rate < 1.0:
        keep = shards_keep_mask(
            trace.addrs, rate,
            characterize_salt(0) if salt is None else salt)
        addrs = trace.addrs[keep]
        is_read = trace.is_read[keep]
    else:
        addrs, is_read = trace.addrs, trace.is_read
    kept = addrs.shape[0]

    seen: set[int] = set()
    retouch_writes = 0
    retouches = 0
    for a, rd in zip(addrs.tolist(), is_read.tolist()):
        if a in seen:
            retouches += 1
            if not rd:
                retouch_writes += 1
        else:
            seen.add(a)
    distinct = len(seen)
    cur = np.sort(np.fromiter(seen, dtype=np.int64, count=distinct))
    if prev_set is not None and (distinct or prev_set.size):
        inter = np.intersect1d(cur, prev_set, assume_unique=True).size
        union = distinct + prev_set.size - inter
        drift = 1.0 - inter / union
    else:
        drift = 0.0
    return WindowFeatures(
        stride_hist=hist[None, :],
        seq_fraction=np.array([hist[0]]),
        read_fraction=np.array([read_fraction]),
        write_ratio=np.array([retouch_writes / max(kept, 1)]),
        working_set=np.array([distinct / max(rate, 1e-300)]),
        jaccard_drift=np.array([drift]),
        reuse_intensity=np.array([retouches / max(kept, 1)]),
        sample_rates=np.array([float(rate)]),
        address_sets=[cur])


def _cold_mask(addrs: np.ndarray, tid: np.ndarray,
               bounds: np.ndarray) -> np.ndarray:
    """True at each segment's first occurrence of an address (prev < 0),
    via one occurrence-link pass on the padded segment layout."""
    from repro.core.monitor import _segment_links
    layout = padded_segment_layout(bounds)
    prev, _ = _segment_links(addrs, tid, bounds, layout)
    return prev < 0


def characterize_windows(traces: list[Trace],
                         prev_sets: list[np.ndarray | None] | None = None,
                         dists: list[np.ndarray | None] | None = None,
                         sample_rate: float | None = None,
                         tenant_ids: list[int] | None = None
                         ) -> WindowFeatures:
    """Batched per-(tenant, window) feature pass (see module docstring).

    ``dists[k]`` optionally carries tenant k's window reuse-distance array
    (``-1`` at cold positions) from ``simulate_many(...,
    return_window_rd=True)`` or the fused monitor — those tenants need no
    occurrence-link pass at all.  ``prev_sets[k]`` is the previous
    window's ``address_sets[k]`` (enables ``jaccard_drift``).
    ``sample_rate`` routes tenants *without* a precomputed distance array
    through the SHARDS-filtered estimator; ``tenant_ids`` stabilizes their
    filter salts under churn (defaults to positional ids).
    """
    n = len(traces)
    lens = np.array([len(t) for t in traces], dtype=np.int64)
    prev_sets = prev_sets if prev_sets is not None else [None] * n
    dists = dists if dists is not None else [None] * n
    ids = np.asarray(tenant_ids if tenant_ids is not None else range(n),
                     dtype=np.int64)

    # ---------------------------------------------- stream features, exact
    # successive deltas on the raw stream; one concatenated diff with the
    # window boundaries masked out (no sort, no counting pass)
    hist = np.zeros((n, STRIDE_BINS), dtype=np.float64)
    m = int(lens.sum())
    if m:
        addrs_all = np.concatenate([t.addrs for t in traces])
        reads_all = np.concatenate([t.is_read for t in traces])
        tid = np.repeat(np.arange(n, dtype=np.int64), lens)
        d = addrs_all[1:] - addrs_all[:-1]
        internal = tid[1:] == tid[:-1]          # sever at window boundaries
        a = np.abs(d)
        bin_idx = np.where(d == 1, 0,
                           np.where(d == 0, 1,
                                    np.where((a >= 2) & (a <= _LOCAL_REACH),
                                             2, 3)))
        key = tid[1:] * STRIDE_BINS + bin_idx
        counts = np.bincount(key[internal],
                             minlength=n * STRIDE_BINS).reshape(n,
                                                                STRIDE_BINS)
        hist = counts / np.maximum(counts.sum(axis=1, keepdims=True), 1)
        read_fraction = (np.bincount(tid[reads_all], minlength=n)
                         / np.maximum(lens, 1))
    else:
        read_fraction = np.zeros(n)
    seq_fraction = hist[:, 0].copy()

    # --------------------------------------------------- spatial features
    # tenants with a precomputed distance array: hot = dist >= 0, free;
    # the rest share one occurrence-link pass on a (possibly SHARDS-
    # filtered) sub-tape
    rates = np.ones(n)
    kept = lens.astype(np.float64).copy()
    retouch = np.zeros(n, dtype=np.int64)
    retouch_w = np.zeros(n, dtype=np.int64)
    distinct = np.zeros(n, dtype=np.int64)
    sets: list[np.ndarray] = [None] * n
    need = []
    for k in range(n):
        dk = dists[k]
        if dk is None:
            if lens[k] > 0:
                need.append(k)
            else:
                sets[k] = np.zeros(0, dtype=np.int64)
                if sample_rate is not None:
                    rates[k] = float(sample_rate)
            continue
        hot = dk >= 0
        retouch[k] = int(hot.sum())
        retouch_w[k] = int(np.sum(hot & ~traces[k].is_read))
        distinct[k] = int(lens[k]) - retouch[k]
        sets[k] = np.sort(traces[k].addrs[~hot])

    if need:
        if sample_rate is not None:
            r = float(sample_rate)
            if not (0 < r <= 1):
                raise ValueError("sample_rate must be in (0, 1]")
            keeps = [shards_keep_mask(traces[k].addrs, r,
                                      characterize_salt(int(ids[k])))
                     for k in need]
            for k in need:
                rates[k] = r
        else:
            keeps = [np.ones(int(lens[k]), dtype=bool) for k in need]
        sub_lens = np.array([int(kp.sum()) for kp in keeps], dtype=np.int64)
        sub_bounds = np.concatenate([[0], np.cumsum(sub_lens)]).astype(
            np.int64)
        if int(sub_lens.sum()):
            sub_addr = np.concatenate(
                [traces[k].addrs[kp] for k, kp in zip(need, keeps)])
            sub_read = np.concatenate(
                [traces[k].is_read[kp] for k, kp in zip(need, keeps)])
        else:
            sub_addr = np.zeros(0, dtype=np.int64)
            sub_read = np.zeros(0, dtype=bool)
        sub_tid = np.repeat(np.arange(len(need), dtype=np.int64), sub_lens)
        cold = _cold_mask(sub_addr, sub_tid, sub_bounds)
        nn = len(need)
        r_c = np.bincount(sub_tid[~cold], minlength=nn)
        r_w = np.bincount(sub_tid[~cold & ~sub_read], minlength=nn)
        d_c = np.bincount(sub_tid[cold], minlength=nn)
        for j, k in enumerate(need):
            kept[k] = float(sub_lens[j])
            retouch[k] = int(r_c[j])
            retouch_w[k] = int(r_w[j])
            distinct[k] = int(d_c[j])
            seg = sub_addr[sub_bounds[j]:sub_bounds[j + 1]]
            sets[k] = np.sort(seg[cold[sub_bounds[j]:sub_bounds[j + 1]]])

    working_set = distinct / np.maximum(rates, 1e-300)
    write_ratio = retouch_w / np.maximum(kept, 1)
    reuse_intensity = retouch / np.maximum(kept, 1)

    drift = np.zeros(n)
    for k in range(n):
        ps = prev_sets[k]
        cur = sets[k]
        if ps is None or (cur.size == 0 and ps.size == 0):
            continue
        inter = np.intersect1d(cur, ps, assume_unique=True).size
        union = cur.size + ps.size - inter
        drift[k] = 1.0 - inter / union

    return WindowFeatures(
        stride_hist=hist, seq_fraction=seq_fraction,
        read_fraction=read_fraction, write_ratio=write_ratio,
        working_set=working_set, jaccard_drift=drift,
        reuse_intensity=reuse_intensity, sample_rates=rates,
        address_sets=sets)


# --------------------------------------------------------- phase detection
@dataclasses.dataclass(frozen=True)
class PhaseEvent:
    """One detected phase change: tenant, window, why, how large."""

    window: int
    tenant: int
    reason: str          # "phase" | "write_ratio"
    score: float


class PhaseDetector:
    """Hysteresis-thresholded per-tenant change detector (see module doc).

    ``hi``/``lo`` are the trigger/re-arm thresholds on the change score,
    ``ema`` the baseline update weight, ``ws_scale`` the log2 working-set
    normalization (a ``2**ws_scale``-fold working-set change scores 1.0),
    ``drift_weight`` the weight of excess Jaccard drift, ``w_threshold``
    (optional) the Alg.-3 boundary whose crossing always raises a
    ``"write_ratio"`` event, ``warmup`` the number of post-init windows
    scored into the baseline before triggers arm (cold-start transient,
    see module docstring).
    """

    def __init__(self, hi: float = 0.25, lo: float = 0.10,
                 ema: float = 0.5, ws_scale: float = 3.0,
                 drift_weight: float = 0.5,
                 w_threshold: float | None = None, warmup: int = 1):
        if not (0.0 <= lo <= hi):
            raise ValueError(f"need 0 <= lo <= hi, got lo={lo} hi={hi}")
        self.hi, self.lo = float(hi), float(lo)
        self.ema = float(ema)
        self.ws_scale = float(ws_scale)
        self.drift_weight = float(drift_weight)
        self.w_threshold = (None if w_threshold is None
                            else float(w_threshold))
        self.warmup = max(int(warmup), 0)
        self._base: dict[int, np.ndarray] = {}
        self._base_wr: dict[int, float] = {}
        self._base_drift: dict[int, float | None] = {}
        self._armed: dict[int, bool] = {}
        self._seen: dict[int, int] = {}

    def _fvec(self, feats: WindowFeatures, k: int) -> np.ndarray:
        return np.array([
            feats.seq_fraction[k],
            feats.read_fraction[k],
            feats.write_ratio[k],
            feats.reuse_intensity[k],
            np.log2(max(feats.working_set[k], 0.0) + 1.0) / self.ws_scale,
        ])

    def forget(self, tenant: int) -> None:
        """Drop a retired tenant's state (a later re-join is a cold start)."""
        self._base.pop(tenant, None)
        self._base_wr.pop(tenant, None)
        self._base_drift.pop(tenant, None)
        self._armed.pop(tenant, None)
        self._seen.pop(tenant, None)

    def update(self, feats: WindowFeatures, window: int,
               tenant_ids=None) -> list[PhaseEvent]:
        """Score one window's features; return triggered events."""
        n = feats.read_fraction.shape[0]
        ids = list(tenant_ids) if tenant_ids is not None else list(range(n))
        events: list[PhaseEvent] = []
        for k, t in enumerate(ids):
            t = int(t)
            fvec = self._fvec(feats, k)
            wr = float(feats.write_ratio[k])
            drift = float(feats.jaccard_drift[k])
            base = self._base.get(t)
            if base is None:                     # cold start: baseline only
                self._base[t] = fvec
                self._base_wr[t] = wr
                self._base_drift[t] = None
                self._armed[t] = True
                self._seen[t] = 1
                continue
            self._seen[t] += 1
            if self._seen[t] <= self.warmup + 1:
                # warm-up: the init window is systematically atypical
                # (empty caches/pools) — *replace* the baseline with this
                # warmed window rather than averaging the transient in
                self._base[t] = fvec
                self._base_wr[t] = wr
                self._base_drift[t] = drift
                continue
            score = float(np.max(np.abs(fvec - base)))
            bd = self._base_drift[t]
            if bd is not None:
                score = max(score, self.drift_weight * max(0.0, drift - bd))
            crossed = (self.w_threshold is not None
                       and (self._base_wr[t] >= self.w_threshold)
                       != (wr >= self.w_threshold))
            armed = self._armed[t]
            if armed and (score >= self.hi or crossed):
                events.append(PhaseEvent(
                    window, t, "write_ratio" if crossed else "phase",
                    score))
                # full cold restart: the *next* window (the first warmed
                # window of the new phase) becomes the reference — the
                # transition window itself carries the phase's cold-start
                # transient and would poison an EMA baseline
                self.forget(t)
                continue
            if not armed and score < self.lo:
                self._armed[t] = True
            a = self.ema
            self._base[t] = (1.0 - a) * base + a * fvec
            self._base_wr[t] = (1.0 - a) * self._base_wr[t] + a * wr
            self._base_drift[t] = (drift if bd is None
                                   else (1.0 - a) * bd + a * drift)
        return events
