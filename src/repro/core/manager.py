"""Monitor → Analyzer → Actuator loop (paper Fig. 8, Alg. 1/3/4).

``ECICacheManager`` is the hypervisor-level controller:

  * ``Monitor``  — accumulates per-tenant (addr, r/w) events for the current
    Δt window (the paper's modified-blktrace).
  * ``Analyzer`` — at window boundaries computes URD (or TRD for baselines),
    builds H_i(c), estimates URD-based sizes, checks feasibility, and — when
    infeasible — runs the Eq.-2 partitioner; also assigns write policies
    (Alg. 3).
  * ``Actuator`` — applies the decisions: resizes per-tenant LRU partitions
    (evicting LRU-first on shrink) and switches write policies; keeps the
    Map Table (block residency) implicitly through the per-tenant caches.

The same class drives both the trace-replay benchmarks and the live paged-KV
serving engine (see ``repro.cache.tiered`` which feeds events back here).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable

import numpy as np

from repro.core.batch_sim import simulate_many
from repro.core.characterize import PhaseDetector, characterize_windows
from repro.core.monitor import analyze_windows
from repro.core.mrc import HitRatioFunction
from repro.core.partitioner import (PartitionResult, pgd_solve,
                                    two_level_solve)
from repro.core.simulator import LRUCache, SimResult, simulate
from repro.core.trace import Trace
from repro.core.write_policy import WritePolicy

__all__ = ["TenantState", "AnalyzerDecision", "ReconfigEvent",
           "ECICacheManager"]


@dataclasses.dataclass
class TenantState:
    name: str
    cache: LRUCache
    policy: WritePolicy = WritePolicy.WB        # paper: WB initially
    h_fn: HitRatioFunction | None = None
    urd_size: int = 0
    window_addrs: list[np.ndarray] = dataclasses.field(default_factory=list)
    window_reads: list[np.ndarray] = dataclasses.field(default_factory=list)
    result: SimResult = dataclasses.field(default_factory=SimResult)
    active: bool = True                         # finished tenants are excluded
    # second hierarchy level (ETICA): host-DRAM partition + its policy
    cache2: LRUCache = dataclasses.field(
        default_factory=lambda: LRUCache(0))
    policy2: WritePolicy = WritePolicy.WB

    def window_trace(self) -> Trace:
        if not self.window_addrs:
            return Trace(np.zeros(0, np.int64), np.zeros(0, bool), self.name)
        return Trace(np.concatenate(self.window_addrs),
                     np.concatenate(self.window_reads), self.name)

    def clear_window(self) -> None:
        self.window_addrs.clear()
        self.window_reads.clear()


@dataclasses.dataclass(frozen=True)
class ReconfigEvent:
    """Why the Analyzer ran (event-driven mode telemetry).

    reason: "phase" (detector score crossed ``hi``), "write_ratio"
    (Alg.-3 threshold crossing), "interval" (the fixed-Δt fallback
    clock), "join" / "retire" (tenant churn).  ``tenant`` is the manager
    index, -1 for deployment-wide triggers.
    """

    window: int
    tenant: int
    reason: str
    score: float = 0.0


@dataclasses.dataclass(frozen=True)
class AnalyzerDecision:
    sizes: np.ndarray
    policies: list[WritePolicy]
    feasible: bool
    partition: PartitionResult
    # per-level extension (all None/zeros for a single-level manager)
    sizes2: np.ndarray | None = None
    policies2: list[WritePolicy] | None = None
    partition2: PartitionResult | None = None
    # event-driven mode: what triggered this analyze (empty on fixed-Δt)
    trigger: tuple[ReconfigEvent, ...] = ()


class ECICacheManager:
    """Dynamic per-tenant cache sizing (URD) + write-policy assignment.

    Parameters mirror the paper's setup: ``capacity`` in blocks, ``c_min``
    initial/minimum per-tenant blocks (paper: 1000), ``w_threshold`` for
    Alg. 3 (paper sweeps 0.2–0.9, default 0.5), ``t_fast``/``t_slow`` the
    SSD/HDD (here HBM/host-tier) service times.

    ``rd_kind='trd'`` + ``adaptive_policy=False`` turns this manager into the
    **Centaur** baseline (TRD sizing, WB everywhere) — see ``baselines.py``.

    ``engine`` selects the window-replay path: ``"batch"`` (default) replays
    every tenant's window at once through the vectorized stack-distance
    engine (``repro.core.batch_sim``, exact — the Analyzer additionally
    reuses its counting pass for the reuse distances), ``"lru"`` the
    stateful per-access interpreter.  Both produce identical results.

    ``capacity2 > 0`` turns the managed partitions into ETICA-style
    two-level hierarchies: each tenant owns an L1 (HBM) *and* an L2
    (host-DRAM) LRU partition, the Analyzer sizes both levels
    (``two_level_solve``: the level-2 Eq. 2 runs on the residual hit-ratio
    curves with service time ``t_fast2``) and assigns a per-level write
    policy (Alg. 3 at ``w_threshold`` for L1, the stricter ``w_threshold2``
    for L2 — a clean L2 flushes dirty victims at demotion).  With the
    default ``capacity2 == 0`` everything reduces bit-identically to the
    single-level scheme.

    ``sample_rate`` selects the Monitor's SHARDS spatial sampling: ``None``
    (exact), a float rate, or ``"auto"`` (per-tenant rate tuned to
    ``sample_target`` kept accesses, floored at ``sample_floor`` — see
    ``auto_sample_rate``).  Deployments with at least
    ``auto_sample_tenants`` tenants default to ``"auto"`` when
    ``sample_rate`` is left ``None`` — at thousand-tenant scale the control
    plane monitors on sampled traces by default, with per-tenant error bars
    reported by the monitor; smaller setups (every paper-figure
    reproduction) stay exact and bit-identical.  Either way the whole
    Analyzer runs through the fused batched monitor
    (``repro.core.monitor.analyze_windows``): one stack-distance pass and
    batched curve/write-ratio reductions for all tenants, no per-tenant
    Python loop.

    ``history_limit`` bounds the retained ``AnalyzerDecision`` list (a
    long-running serving deployment analyzes every Δt forever; unbounded
    history is a leak).  ``None`` keeps everything.  The same limit bounds
    the ``events`` reconfiguration log.

    ``phase_detect=True`` turns on ReCA-style event-driven
    reconfiguration (default **off**; with it off every code path is
    bit-identical to the fixed-Δt manager): each replayed window is
    characterized (``repro.core.characterize``, reusing the batch
    engine's window reuse distances so the feature pass adds no second
    pass over the trace) and a hysteresis ``PhaseDetector`` scores every
    tenant.  The Analyzer then runs only when (a) a tenant changes phase,
    (b) a tenant's Alg.-3 write ratio crosses ``w_threshold`` (the policy
    flip must not wait for the clock), (c) a tenant joins or retires, or
    (d) ``reconfig_interval`` windows have accumulated since the last
    analyze (the fixed-Δt fallback clock; 1 analyzes every window).
    Windows between analyzes accumulate in the Monitor, so a triggered
    analyze sees the full access history since the last decision.  Every
    trigger is recorded as a ``ReconfigEvent`` in ``events`` (bounded by
    ``history_limit``) and on the resulting decision's ``trigger`` field.
    ``phase_hi``/``phase_lo``/``phase_ema`` parameterize the detector's
    hysteresis thresholds and baseline EMA.
    """

    def __init__(self, capacity: int, tenant_names: list[str],
                 c_min: int = 1000, w_threshold: float = 0.5,
                 t_fast: float = 1.0, t_slow: float = 20.0,
                 t_write_bypass: float | None = None, flush_cost: float = 0.0,
                 rd_kind: str = "urd", adaptive_policy: bool = True,
                 sample_rate: float | str | None = None,
                 initial_blocks: int | None = None,
                 percentile: float = 100.0,
                 partition_fn: Callable = pgd_solve,
                 engine: str = "batch",
                 capacity2: int = 0, t_fast2: float | None = None,
                 w_threshold2: float = 0.3,
                 history_limit: int | None = 256,
                 sample_target: int = 4096, sample_floor: int = 256,
                 auto_sample_tenants: int = 256,
                 phase_detect: bool = False, reconfig_interval: int = 1,
                 phase_hi: float = 0.25, phase_lo: float = 0.10,
                 phase_ema: float = 0.5, pipeline: str = "host"):
        if engine not in ("batch", "lru"):
            raise ValueError(f"engine must be 'batch' or 'lru', got {engine!r}")
        if pipeline not in ("host", "device"):
            raise ValueError(
                f"pipeline must be 'host' or 'device', got {pipeline!r}")
        self.capacity = int(capacity)
        self.capacity2 = int(capacity2)
        self.c_min = int(c_min)
        self.w_threshold = float(w_threshold)
        self.w_threshold2 = float(w_threshold2)
        self.t_fast, self.t_slow = float(t_fast), float(t_slow)
        self.t_fast2 = (3.0 * t_fast if t_fast2 is None else float(t_fast2))
        self.t_write_bypass = (1.2 * t_fast if t_write_bypass is None
                               else float(t_write_bypass))
        self.flush_cost = float(flush_cost)
        self.rd_kind = rd_kind
        self.adaptive_policy = adaptive_policy
        self.sample_rate = sample_rate
        self.sample_target = int(sample_target)
        self.sample_floor = int(sample_floor)
        self.auto_sample_tenants = int(auto_sample_tenants)
        self.percentile = percentile
        self.partition_fn = partition_fn
        self.engine = engine
        # "device" routes each analyze through the fused device window
        # program (core.device_pipeline); falls back to the host pipeline
        # when percentile < 100 (the device program is percentile-free)
        self.pipeline = pipeline
        init = int(initial_blocks if initial_blocks is not None else c_min)
        self.tenants = [TenantState(n, LRUCache(init)) for n in tenant_names]
        self.history: collections.deque[AnalyzerDecision] = \
            collections.deque(maxlen=history_limit)
        self.windows_analyzed = 0       # also salts the SHARDS hash per window
        self.tenant_windows = 0         # replayed tenant-windows (denominator)
        # event-driven reconfiguration (ReCA-style; default off = exact
        # pre-existing fixed-Δt behavior, analyze every window)
        self.reconfig_interval = max(int(reconfig_interval), 1)
        self.detector = (PhaseDetector(
            hi=phase_hi, lo=phase_lo, ema=phase_ema,
            w_threshold=(w_threshold if adaptive_policy else None))
            if phase_detect else None)
        self.events: collections.deque[ReconfigEvent] = \
            collections.deque(maxlen=history_limit)
        self.reconfig_events = 0        # total events ever (deque is bounded)
        self.windows_run = 0            # run_window calls (≥ windows_analyzed)
        self._pending_windows = 0       # replayed but not yet analyzed
        self._prev_sets: dict[int, np.ndarray] = {}   # drift continuity
        self._joined: list[int] = []    # tenants added since last window
        # interpreter-fallback tenant-windows: since the two-level RO
        # eviction-token replay this counts only genuinely degenerate
        # windows (empty two-level windows / warm L2 behind a dead level);
        # CI asserts it stays 0 on the standard two-level bench mixes
        self.ro_fallback_windows = 0

    # ------------------------------------------------------------- Monitor
    def record(self, tenant: int, addrs: np.ndarray, is_read: np.ndarray) -> None:
        t = self.tenants[tenant]
        t.window_addrs.append(np.asarray(addrs, np.int64))
        t.window_reads.append(np.asarray(is_read, bool))

    def add_tenant(self, name: str,
                   initial_blocks: int | None = None) -> int:
        """Tenant churn: a workload joins mid-run.  Returns its index.

        The next ``run_window`` records a ``"join"`` reconfiguration
        event; in event-driven mode that forces an analyze so the
        newcomer is sized from its first window.  Existing tenants'
        SHARDS salts and detector baselines are untouched (ids are
        positional and a join only appends).
        """
        init = int(initial_blocks if initial_blocks is not None
                   else self.c_min)
        self.tenants.append(TenantState(name, LRUCache(init)))
        i = len(self.tenants) - 1
        self._joined.append(i)
        return i

    def retire_tenant(self, tenant: int) -> None:
        """Workload finished: release its partitions (paper §6.3)."""
        t = self.tenants[tenant]
        t.active = False
        t.cache.resize(0)
        t.cache2.resize(0)
        self._prev_sets.pop(tenant, None)
        if self.detector is not None:
            self.detector.forget(tenant)

    # ------------------------------------------------------------ Analyzer
    def effective_sample_rate(self) -> float | str | None:
        """Resolve the Monitor's sampling mode for the current deployment."""
        if self.sample_rate is None \
                and len(self.tenants) >= self.auto_sample_tenants:
            return "auto"
        return self.sample_rate

    def analyze(self, window_trd: dict[int, np.ndarray] | None = None,
                trigger: tuple[ReconfigEvent, ...] = ()
                ) -> AnalyzerDecision:
        """Alg. 1 / Alg. 4: run at every Δt window boundary.

        All active tenants are analyzed in one fused pass
        (``analyze_windows``): one stack-distance counting pass over the
        concatenated window tape, batched curve construction, batched
        Alg.-3 write ratios — optionally SHARDS-sampled (see the class
        docstring).  ``window_trd`` optionally carries per-tenant raw TRD
        sample arrays already computed by the batch engine's counting pass
        (identical to ``reuse_distances(trace, "trd").distances``); the
        exact path reuses them instead of re-counting.
        """
        window_trd = window_trd or {}
        act = [i for i, t in enumerate(self.tenants) if t.active]
        traces = [self.tenants[i].window_trace() for i in act]
        rate = self.effective_sample_rate()
        pipe = (self.pipeline if self.percentile >= 100.0 else "host")
        # the device program recounts on device, so precomputed TRD arrays
        # are only forwarded to the host pipeline
        pre = ([window_trd.get(i) for i in act]
               if rate is None and pipe == "host" else None)
        mon = analyze_windows(
            traces, kind=self.rd_kind, percentile=self.percentile,
            sample_rate=rate, window_seed=self.windows_analyzed,
            sample_target=self.sample_target, sample_floor=self.sample_floor,
            precomputed_trd=pre, tenant_ids=act, pipeline=pipe)
        self.windows_analyzed += 1
        for k, i in enumerate(act):
            t = self.tenants[i]
            t.h_fn = mon.curves[k]
            t.urd_size = int(mon.urd_sizes[k])
            if self.adaptive_policy:
                # Alg. 3 writeRatio = (WAW + WAR)/n: write re-touches are
                # exactly the writes with a TRD sample
                wr = float(mon.write_ratios[k])
                t.policy = (WritePolicy.RO if wr >= self.w_threshold
                            else WritePolicy.WB)
                if self.capacity2 > 0:
                    # per-level Alg. 3: the larger endurance-sensitive L2
                    # switches to the clean policy at a stricter threshold
                    t.policy2 = (WritePolicy.RO if wr >= self.w_threshold2
                                 else WritePolicy.WB)

        part, part2 = two_level_solve(
            mon.curves, self.capacity, self.capacity2, self.t_fast,
            self.t_fast2, self.t_slow, c_min=self.c_min,
            partition_fn=self.partition_fn)

        sizes_full = np.zeros(len(self.tenants), dtype=np.int64)
        sizes2_full = np.zeros(len(self.tenants), dtype=np.int64)
        k = 0
        for i, t in enumerate(self.tenants):
            if t.active:
                sizes_full[i] = part.sizes[k]
                if part2 is not None:
                    sizes2_full[i] = part2.sizes[k]
                k += 1
        decision = AnalyzerDecision(sizes_full,
                                    [t.policy for t in self.tenants],
                                    part.feasible, part,
                                    sizes2=sizes2_full,
                                    policies2=[t.policy2
                                               for t in self.tenants],
                                    partition2=part2,
                                    trigger=tuple(trigger))
        self.history.append(decision)
        return decision

    # ------------------------------------------------------------ Actuator
    def actuate(self, decision: AnalyzerDecision) -> None:
        sizes2 = (decision.sizes2 if decision.sizes2 is not None
                  else np.zeros(len(self.tenants), np.int64))
        for t, size, size2 in zip(self.tenants, decision.sizes, sizes2):
            if t.active:
                t.cache.resize(int(size))
                if self.capacity2 > 0 or t.cache2.capacity > 0:
                    t.cache2.resize(int(size2))
                t.clear_window()

    # --------------------------------------------------------- trace replay
    def _accumulate(self, t: TenantState, res: SimResult) -> None:
        agg = t.result
        agg.reads += res.reads; agg.read_hits += res.read_hits
        agg.writes += res.writes; agg.write_hits += res.write_hits
        agg.cache_writes += res.cache_writes
        agg.total_latency += res.total_latency
        agg.read_hits_l2 += res.read_hits_l2
        agg.write_hits_l2 += res.write_hits_l2
        agg.cache_writes_l2 += res.cache_writes_l2
        agg.fallback += res.fallback
        agg.capacity = t.cache.capacity
        agg.capacity2 = t.cache2.capacity
        agg.policy = t.policy.value
        agg.policy2 = t.policy2.value

    def _record_events(self, events: list[ReconfigEvent]) -> None:
        self.events.extend(events)
        self.reconfig_events += len(events)

    def _drain_joined(self, window: int) -> list[ReconfigEvent]:
        """Pending ``add_tenant`` joins -> churn events (not yet recorded)."""
        evs = [ReconfigEvent(window, i, "join") for i in self._joined]
        self._joined.clear()
        return evs

    def run_window(self, traces: list[Trace | None],
                   engine: str | None = None) -> None:
        """Replay one Δt window for every tenant, then analyze + actuate.

        ``traces[i] is None`` marks tenant i as finished.  With
        ``phase_detect`` on, the analyze/actuate half runs only when the
        phase detector, a churn event, or the ``reconfig_interval`` clock
        triggers it (see the class docstring); the replay half always
        runs.
        """
        engine = self.engine if engine is None else engine
        win = self.windows_run
        events = self._drain_joined(win)
        for i, tr in enumerate(traces):
            if tr is None and self.tenants[i].active:
                self.retire_tenant(i)
                events.append(ReconfigEvent(win, i, "retire"))

        idx = [i for i, tr in enumerate(traces) if tr is not None]
        for i in idx:
            self.record(i, traces[i].addrs, traces[i].is_read)

        window_trd: dict[int, np.ndarray] | None = None
        if engine == "batch":
            results, rds = simulate_many(
                [traces[i] for i in idx],
                policies=[self.tenants[i].policy for i in idx],
                t_fast=self.t_fast, t_slow=self.t_slow,
                t_write_bypass=self.t_write_bypass,
                flush_cost=self.flush_cost,
                caches=[self.tenants[i].cache for i in idx],
                policies2=[self.tenants[i].policy2 for i in idx],
                caches2=[self.tenants[i].cache2 for i in idx],
                t_fast2=self.t_fast2,
                return_window_rd=True)
            window_trd = {i: rd for i, rd in zip(idx, rds) if rd is not None}
            for i, res in zip(idx, results):
                self._accumulate(self.tenants[i], res)
            self.ro_fallback_windows += sum(r.fallback for r in results)
        else:
            for i in idx:
                t = self.tenants[i]
                res = simulate(traces[i], t.cache.capacity, t.policy,
                               self.t_fast, self.t_slow,
                               t_write_bypass=self.t_write_bypass,
                               flush_cost=self.flush_cost, cache=t.cache,
                               capacity2=t.cache2.capacity, policy2=t.policy2,
                               t_fast2=self.t_fast2, cache2=t.cache2)
                self._accumulate(t, res)
        self.tenant_windows += len(idx)
        self.windows_run += 1

        if self.detector is None:
            # fixed-Δt mode: analyze + actuate every window, exactly the
            # pre-event-driven behavior (churn events are telemetry only)
            self._record_events(events)
            decision = self.analyze(window_trd)
            self.actuate(decision)
            return

        # ---------------------------------------- event-driven mode (ReCA)
        # characterize this window's accesses on the replay engine's
        # window reuse distances (no second pass; see core.characterize)
        feats = characterize_windows(
            [traces[i] for i in idx],
            prev_sets=[self._prev_sets.get(i) for i in idx],
            dists=[None if window_trd is None else window_trd.get(i)
                   for i in idx],
            tenant_ids=idx)
        for k, i in enumerate(idx):
            self._prev_sets[i] = feats.address_sets[k]
        events.extend(ReconfigEvent(win, e.tenant, e.reason, e.score)
                      for e in self.detector.update(feats, win, idx))
        self._pending_windows += 1
        if self._pending_windows >= self.reconfig_interval:
            events.append(ReconfigEvent(win, -1, "interval"))
        if events:
            # a multi-window accumulation invalidates the single-window
            # precomputed distances; the Analyzer re-counts the full span
            wtrd = window_trd if self._pending_windows == 1 else None
            self._record_events(events)
            decision = self.analyze(wtrd, trigger=tuple(events))
            self.actuate(decision)
            self._pending_windows = 0

    # ------------------------------------------------------------- metrics
    def allocated_sizes(self) -> np.ndarray:
        return np.array([t.cache.capacity for t in self.tenants], np.int64)

    def allocated_sizes2(self) -> np.ndarray:
        return np.array([t.cache2.capacity for t in self.tenants], np.int64)

    def summary(self) -> dict[str, float]:
        res = [t.result for t in self.tenants]
        n = sum(r.n for r in res)
        lat = sum(r.total_latency for r in res)
        writes = sum(r.cache_writes for r in res)
        alloc = int(self.allocated_sizes().sum())
        mean_lat = lat / n if n else 0.0
        return {
            "accesses": n,
            "mean_latency": mean_lat,
            "performance": 1.0 / mean_lat if mean_lat else 0.0,
            "cache_writes": writes,
            "allocated_blocks": alloc,
            "perf_per_cost": (1.0 / mean_lat) / alloc if mean_lat and alloc else 0.0,
            "read_hit_ratio": (sum(r.read_hits for r in res)
                               / max(sum(r.reads for r in res), 1)),
            "cache_writes_l2": sum(r.cache_writes_l2 for r in res),
            "allocated_blocks_l2": int(self.allocated_sizes2().sum()),
            "read_hit_ratio_l2": (sum(r.read_hits_l2 for r in res)
                                  / max(sum(r.reads for r in res), 1)),
            # batch-engine telemetry: tenant-windows replayed through the
            # per-access interpreter (degenerate windows only — RO
            # eviction pressure stays vectorized), over all replayed windows
            "ro_fallback_windows": self.ro_fallback_windows,
            "tenant_windows": self.tenant_windows,
            # event-driven telemetry: replayed vs analyzed windows and the
            # cumulative reconfiguration-event count (the `events` deque
            # itself is bounded by history_limit)
            "windows_run": self.windows_run,
            "windows_analyzed": self.windows_analyzed,
            "reconfig_events": self.reconfig_events,
        }
