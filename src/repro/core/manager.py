"""Monitor → Analyzer → Actuator loop (paper Fig. 8, Alg. 1/3/4).

``ECICacheManager`` is the hypervisor-level controller:

  * ``Monitor``  — accumulates per-tenant (addr, r/w) events for the current
    Δt window (the paper's modified-blktrace).
  * ``Analyzer`` — at window boundaries computes URD (or TRD for baselines),
    builds H_i(c), estimates URD-based sizes, checks feasibility, and — when
    infeasible — runs the Eq.-2 partitioner; also assigns write policies
    (Alg. 3).
  * ``Actuator`` — applies the decisions: resizes per-tenant LRU partitions
    (evicting LRU-first on shrink) and switches write policies; keeps the
    Map Table (block residency) implicitly through the per-tenant caches.

The same class drives both the trace-replay benchmarks and the live paged-KV
serving engine (see ``repro.cache.tiered`` which feeds events back here).

Failure domains & the graceful-degradation ladder
-------------------------------------------------
With a ``FaultPlan`` attached (``faults=``) — or ``fault_tolerant=True`` —
the manager survives control-plane and tier failures instead of crashing or
actuating garbage.  Everything below is **default-off and bit-identical
when off** (the checked-in goldens enforce this):

  * **Monitor ladder.**  Each analyze walks the rungs
    ``device → fused host → per-tenant`` : the configured pipeline runs
    first with up to ``retry_limit`` retries per rung (exponential backoff
    ``backoff_base * 2**attempt`` seconds, 0 = no sleep), a failed device
    rung steps down to the fused host pass (``device_stepdowns``), a failed
    host rung to per-tenant solo passes (``host_stepdowns``) where a single
    poisoned tenant can no longer take the whole deployment's analyze down
    (failed solo tenants are individually quarantined at their
    last-known-good size/policy).  If every rung fails, the manager
    re-applies the **last-known-good decision** (``lkg_decisions``).
  * **Decision guard.**  Every decision — degraded or not — is validated
    against ``repro.core.guard`` hard invariants (Σsizes ≤ capacity, c_min
    floors, finite curves/latency, policies ∈ {WB, WT, RO}).  A tolerant
    manager retries a *sampled* analyze once exactly
    (``sampled_exact_retries``) and otherwise quarantines the decision
    (``guard_quarantines``) behind the last-known-good allocation; an
    intolerant manager counts it (``guard_violations_actuated``) so silent
    garbage still surfaces in ``summary()``.
  * **Ingest validation.**  Malformed tapes raise ``TraceError`` with
    (tenant, window) coordinates; a tolerant ``run_window`` quarantines the
    offending tenant-window (empty tape, held at last-known-good —
    ``poisoned_windows``) instead of raising.  Straggler tapes
    (``FaultPlan`` ``"straggler"``) hold the tenant out of this window's
    analyze and fold the deferred tape into the next one
    (``straggler_windows``).
  * **Tier loss + write-policy demotion (the paper-faithful part).**
    ``fail_tier(level)`` / ``note_tier_loss`` drop the level's residents —
    lost dirty blocks are counted in ``dirty_loss`` (the reliability cost
    the paper's Alg. 3 restricts WB to bound) — and every WB tenant on the
    failed level is demoted to ``demote_policy`` (default WT: hits without
    dirty-loss exposure) for the outage **plus ``demote_cooldown`` analyzes
    after recovery**, after which Alg. 3 reassigns policies normally.
    While a level is down its partition budget is 0 and the partitioner
    degrades to ``greedy_allocate`` (the box-projected PGD solver cannot
    express an empty budget).  Reconvergence: decisions depend only on the
    current window's tape and the restored capacities, so a recovered
    manager matches the no-fault run within
    ``K = demote_cooldown + 2`` windows of the last fault — gated in
    ``benchmarks/bench_faults.py`` and the chaos suite.

Every degradation is recorded as a ``DegradeEvent`` in the shared
``events`` deque (alongside ``ReconfigEvent``) and counted once in
``summary()``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.batch_sim import simulate_many
from repro.core.characterize import PhaseDetector, characterize_windows
from repro.core.faults import FaultPlan, InjectedFault
from repro.core.guard import validate_decision
from repro.core.monitor import MonitorResult, analyze_windows
from repro.core.mrc import HitRatioFunction
from repro.core.partitioner import (PartitionResult, greedy_allocate,
                                    pgd_solve, two_level_solve)
from repro.core.simulator import LRUCache, SimResult, simulate
from repro.core.trace import (Trace, TraceError, validate_trace,
                              validate_trace_arrays)
from repro.core.write_policy import WritePolicy

__all__ = ["TenantState", "AnalyzerDecision", "ReconfigEvent",
           "DegradeEvent", "ECICacheManager"]


@dataclasses.dataclass
class TenantState:
    name: str
    cache: LRUCache
    policy: WritePolicy = WritePolicy.WB        # paper: WB initially
    h_fn: HitRatioFunction | None = None
    urd_size: int = 0
    window_addrs: list[np.ndarray] = dataclasses.field(default_factory=list)
    window_reads: list[np.ndarray] = dataclasses.field(default_factory=list)
    result: SimResult = dataclasses.field(default_factory=SimResult)
    active: bool = True                         # finished tenants are excluded
    # second hierarchy level (ETICA): host-DRAM partition + its policy
    cache2: LRUCache = dataclasses.field(
        default_factory=lambda: LRUCache(0))
    policy2: WritePolicy = WritePolicy.WB

    def window_trace(self) -> Trace:
        if not self.window_addrs:
            return Trace(np.zeros(0, np.int64), np.zeros(0, bool), self.name)
        return Trace(np.concatenate(self.window_addrs),
                     np.concatenate(self.window_reads), self.name)

    def clear_window(self) -> None:
        self.window_addrs.clear()
        self.window_reads.clear()


@dataclasses.dataclass(frozen=True)
class ReconfigEvent:
    """Why the Analyzer ran (event-driven mode telemetry).

    reason: "phase" (detector score crossed ``hi``), "write_ratio"
    (Alg.-3 threshold crossing), "interval" (the fixed-Δt fallback
    clock), "join" / "retire" (tenant churn).  ``tenant`` is the manager
    index, -1 for deployment-wide triggers.
    """

    window: int
    tenant: int
    reason: str
    score: float = 0.0


@dataclasses.dataclass(frozen=True)
class DegradeEvent:
    """One graceful-degradation action (fault-tolerance telemetry).

    Lives in the same ``events`` deque as ``ReconfigEvent`` (same
    ``window``/``tenant``/``reason`` consumer contract).  reason:
    "tier_loss" / "tier_recover" (``level``, ``blocks`` = lost dirty
    blocks), "stepdown" (``rung`` = the rung that failed), "straggler",
    "poisoned" (quarantined tenant-window), "tenant_quarantine" (solo
    analyze failed), "guard_quarantine", "monitor_outage" (all rungs
    failed → last-known-good).  ``tenant`` is -1 for deployment-wide
    events.
    """

    window: int
    tenant: int
    reason: str
    level: int = 0
    blocks: int = 0
    rung: str = ""


@dataclasses.dataclass(frozen=True)
class AnalyzerDecision:
    sizes: np.ndarray
    policies: list[WritePolicy]
    feasible: bool
    partition: PartitionResult
    # per-level extension (all None/zeros for a single-level manager)
    sizes2: np.ndarray | None = None
    policies2: list[WritePolicy] | None = None
    partition2: PartitionResult | None = None
    # event-driven mode: what triggered this analyze (empty on fixed-Δt)
    trigger: tuple[ReconfigEvent, ...] = ()
    # fault tolerance (all defaults on the healthy path): ``quarantined``
    # marks a last-known-good fallback (sizes are the *current*
    # allocations, not a fresh solve), ``guard`` the guard violations that
    # were detected (non-empty + not quarantined = actuated violation,
    # intolerant managers only), ``degraded`` the degradation reason,
    # ``held`` tenants excluded from this analyze (kept at current
    # size/policy), ``deferred`` tenants whose window tape the Actuator
    # must NOT clear (stragglers: it joins the next analyze).
    quarantined: bool = False
    guard: tuple[str, ...] = ()
    degraded: str = ""
    held: tuple[int, ...] = ()
    deferred: tuple[int, ...] = ()


class ECICacheManager:
    """Dynamic per-tenant cache sizing (URD) + write-policy assignment.

    Parameters mirror the paper's setup: ``capacity`` in blocks, ``c_min``
    initial/minimum per-tenant blocks (paper: 1000), ``w_threshold`` for
    Alg. 3 (paper sweeps 0.2–0.9, default 0.5), ``t_fast``/``t_slow`` the
    SSD/HDD (here HBM/host-tier) service times.

    ``rd_kind='trd'`` + ``adaptive_policy=False`` turns this manager into the
    **Centaur** baseline (TRD sizing, WB everywhere) — see ``baselines.py``.

    ``engine`` selects the window-replay path: ``"batch"`` (default) replays
    every tenant's window at once through the vectorized stack-distance
    engine (``repro.core.batch_sim``, exact — the Analyzer additionally
    reuses its counting pass for the reuse distances), ``"lru"`` the
    stateful per-access interpreter.  Both produce identical results.

    ``capacity2 > 0`` turns the managed partitions into ETICA-style
    two-level hierarchies: each tenant owns an L1 (HBM) *and* an L2
    (host-DRAM) LRU partition, the Analyzer sizes both levels
    (``two_level_solve``: the level-2 Eq. 2 runs on the residual hit-ratio
    curves with service time ``t_fast2``) and assigns a per-level write
    policy (Alg. 3 at ``w_threshold`` for L1, the stricter ``w_threshold2``
    for L2 — a clean L2 flushes dirty victims at demotion).  With the
    default ``capacity2 == 0`` everything reduces bit-identically to the
    single-level scheme.

    ``sample_rate`` selects the Monitor's SHARDS spatial sampling: ``None``
    (exact), a float rate, or ``"auto"`` (per-tenant rate tuned to
    ``sample_target`` kept accesses, floored at ``sample_floor`` — see
    ``auto_sample_rate``).  Deployments with at least
    ``auto_sample_tenants`` tenants default to ``"auto"`` when
    ``sample_rate`` is left ``None`` — at thousand-tenant scale the control
    plane monitors on sampled traces by default, with per-tenant error bars
    reported by the monitor; smaller setups (every paper-figure
    reproduction) stay exact and bit-identical.  Either way the whole
    Analyzer runs through the fused batched monitor
    (``repro.core.monitor.analyze_windows``): one stack-distance pass and
    batched curve/write-ratio reductions for all tenants, no per-tenant
    Python loop.

    ``history_limit`` bounds the retained ``AnalyzerDecision`` list (a
    long-running serving deployment analyzes every Δt forever; unbounded
    history is a leak).  ``None`` keeps everything.  The same limit bounds
    the ``events`` reconfiguration log.

    ``phase_detect=True`` turns on ReCA-style event-driven
    reconfiguration (default **off**; with it off every code path is
    bit-identical to the fixed-Δt manager): each replayed window is
    characterized (``repro.core.characterize``, reusing the batch
    engine's window reuse distances so the feature pass adds no second
    pass over the trace) and a hysteresis ``PhaseDetector`` scores every
    tenant.  The Analyzer then runs only when (a) a tenant changes phase,
    (b) a tenant's Alg.-3 write ratio crosses ``w_threshold`` (the policy
    flip must not wait for the clock), (c) a tenant joins or retires, or
    (d) ``reconfig_interval`` windows have accumulated since the last
    analyze (the fixed-Δt fallback clock; 1 analyzes every window).
    Windows between analyzes accumulate in the Monitor, so a triggered
    analyze sees the full access history since the last decision.  Every
    trigger is recorded as a ``ReconfigEvent`` in ``events`` (bounded by
    ``history_limit``) and on the resulting decision's ``trigger`` field.
    ``phase_hi``/``phase_lo``/``phase_ema`` parameterize the detector's
    hysteresis thresholds and baseline EMA.

    ``faults``/``fault_tolerant`` arm the graceful-degradation machinery
    (default off, bit-identical when off — see the module docstring for
    the full failure-domain model).  Ladder order is
    ``device → fused host → per-tenant``; each non-terminal rung gets
    ``retry_limit`` retries with ``backoff_base * 2**attempt`` seconds of
    backoff (0 = no sleep, capped at 1 s).  On a tier loss every WB tenant
    of that level demotes to ``demote_policy`` (default WT) for the outage
    plus ``demote_cooldown`` further analyzes after recovery — the paper's
    reliability rationale: WB buffers dirty data that a cache-device crash
    loses (counted in ``dirty_loss``), so a tier with a fresh failure
    history must serve writes through a clean policy until trust is
    re-established.
    """

    def __init__(self, capacity: int, tenant_names: list[str],
                 c_min: int = 1000, w_threshold: float = 0.5,
                 t_fast: float = 1.0, t_slow: float = 20.0,
                 t_write_bypass: float | None = None, flush_cost: float = 0.0,
                 rd_kind: str = "urd",
                 # the adaptive write policy IS the paper's ECI scheme
                 # (Alg. 2) — shipping it on is the reproduction contract;
                 # the off-path is the Centaur/static baselines, pinned
                 # bit-identical in test_baselines.
                 adaptive_policy: bool = True,  # repro-lint: disable=RL003
                 sample_rate: float | str | None = None,
                 initial_blocks: int | None = None,
                 percentile: float = 100.0,
                 partition_fn: Callable = pgd_solve,
                 engine: str = "batch",
                 capacity2: int = 0, t_fast2: float | None = None,
                 w_threshold2: float = 0.3,
                 history_limit: int | None = 256,
                 sample_target: int = 4096, sample_floor: int = 256,
                 auto_sample_tenants: int = 256,
                 phase_detect: bool = False, reconfig_interval: int = 1,
                 phase_hi: float = 0.25, phase_lo: float = 0.10,
                 phase_ema: float = 0.5, pipeline: str = "host",
                 faults: FaultPlan | None = None,
                 fault_tolerant: bool | None = None,
                 retry_limit: int = 2, backoff_base: float = 0.0,
                 demote_cooldown: int = 2,
                 demote_policy: WritePolicy | str = WritePolicy.WT):
        if engine not in ("batch", "lru"):
            raise ValueError(f"engine must be 'batch' or 'lru', got {engine!r}")
        if pipeline not in ("host", "device", "sharded"):
            raise ValueError(f"pipeline must be 'host', 'device' or "
                             f"'sharded', got {pipeline!r}")
        self.capacity = int(capacity)
        self.capacity2 = int(capacity2)
        self.c_min = int(c_min)
        self.w_threshold = float(w_threshold)
        self.w_threshold2 = float(w_threshold2)
        self.t_fast, self.t_slow = float(t_fast), float(t_slow)
        self.t_fast2 = (3.0 * t_fast if t_fast2 is None else float(t_fast2))
        self.t_write_bypass = (1.2 * t_fast if t_write_bypass is None
                               else float(t_write_bypass))
        self.flush_cost = float(flush_cost)
        self.rd_kind = rd_kind
        self.adaptive_policy = adaptive_policy
        self.sample_rate = sample_rate
        self.sample_target = int(sample_target)
        self.sample_floor = int(sample_floor)
        self.auto_sample_tenants = int(auto_sample_tenants)
        self.percentile = percentile
        self.partition_fn = partition_fn
        self.engine = engine
        # "device" routes each analyze through the fused device window
        # program (core.device_pipeline), "sharded" through its mesh twin
        # (core.shard_pipeline); both fall back to the host pipeline when
        # percentile < 100 (the device programs are percentile-free)
        self.pipeline = pipeline
        init = int(initial_blocks if initial_blocks is not None else c_min)
        self.tenants = [TenantState(n, LRUCache(init)) for n in tenant_names]
        self.history: collections.deque[AnalyzerDecision] = \
            collections.deque(maxlen=history_limit)
        self.windows_analyzed = 0       # also salts the SHARDS hash per window
        self.tenant_windows = 0         # replayed tenant-windows (denominator)
        # event-driven reconfiguration (ReCA-style; default off = exact
        # pre-existing fixed-Δt behavior, analyze every window)
        self.reconfig_interval = max(int(reconfig_interval), 1)
        self.detector = (PhaseDetector(
            hi=phase_hi, lo=phase_lo, ema=phase_ema,
            w_threshold=(w_threshold if adaptive_policy else None))
            if phase_detect else None)
        self.events: collections.deque[ReconfigEvent] = \
            collections.deque(maxlen=history_limit)
        self.reconfig_events = 0        # total events ever (deque is bounded)
        self.windows_run = 0            # run_window calls (≥ windows_analyzed)
        self._pending_windows = 0       # replayed but not yet analyzed
        self._prev_sets: dict[int, np.ndarray] = {}   # drift continuity
        self._joined: list[int] = []    # tenants added since last window
        # interpreter-fallback tenant-windows: since the two-level RO
        # eviction-token replay this counts only genuinely degenerate
        # windows (empty two-level windows / warm L2 behind a dead level);
        # CI asserts it stays 0 on the standard two-level bench mixes
        self.ro_fallback_windows = 0
        # ---------------- fault tolerance (see the module docstring) ------
        # ``faults`` injects; ``fault_tolerant`` arms the ladder/guard/
        # quarantine machinery (defaults on exactly when a plan is
        # attached).  With both off every path above is bit-identical to
        # the pre-fault-tolerance manager.
        self.faults = faults
        self.fault_tolerant = (faults is not None if fault_tolerant is None
                               else bool(fault_tolerant))
        self.retry_limit = max(int(retry_limit), 0)
        self.backoff_base = float(backoff_base)
        self.demote_cooldown = max(int(demote_cooldown), 0)
        self.demote_policy = (WritePolicy(demote_policy)
                              if isinstance(demote_policy, str)
                              else demote_policy)
        self._down_levels: set[int] = set()       # levels currently failed
        self._tier_restore_at: dict[int, int] = {}  # level -> restore window
        # (tenant, level) -> analyze-count when the demotion expires
        # (None = still down: expiry is stamped at recovery)
        self._demoted_until: dict[tuple[int, int], int | None] = {}
        self._held: set[int] = set()              # held out of this analyze
        self._defer_clear: set[int] = set()       # straggler tapes to keep
        self._cur_window = 0                      # window under analysis
        self._accumulated: set[int] = set()       # multi-window tapes
        self._lkg: AnalyzerDecision | None = None
        # unified degrade counters (each increments exactly once per event;
        # surfaced in summary())
        self.dirty_loss = 0
        self.tier_failures = 0
        self.guard_quarantines = 0
        self.guard_violations_observed = 0
        self.guard_violations_actuated = 0
        self.sharded_stepdowns = 0
        self.device_stepdowns = 0
        self.host_stepdowns = 0
        self.tenant_quarantines = 0
        self.lkg_decisions = 0
        self.sampled_exact_retries = 0
        self.poisoned_windows = 0
        self.straggler_windows = 0
        self.degrade_events = 0

    # ------------------------------------------------------------- Monitor
    def record(self, tenant: int, addrs: np.ndarray, is_read: np.ndarray) -> None:
        """Ingest one tenant's window events (validated: raises
        ``TraceError`` with (tenant, window) coordinates on a malformed
        tape — a tolerant ``run_window`` quarantines instead)."""
        validate_trace_arrays(addrs, is_read, tenant=tenant,
                              window=self.windows_run)
        t = self.tenants[tenant]
        t.window_addrs.append(np.asarray(addrs, np.int64))
        t.window_reads.append(np.asarray(is_read, bool))

    def add_tenant(self, name: str,
                   initial_blocks: int | None = None) -> int:
        """Tenant churn: a workload joins mid-run.  Returns its index.

        The next ``run_window`` records a ``"join"`` reconfiguration
        event; in event-driven mode that forces an analyze so the
        newcomer is sized from its first window.  Existing tenants'
        SHARDS salts and detector baselines are untouched (ids are
        positional and a join only appends).
        """
        init = int(initial_blocks if initial_blocks is not None
                   else self.c_min)
        self.tenants.append(TenantState(name, LRUCache(init)))
        i = len(self.tenants) - 1
        self._joined.append(i)
        return i

    def retire_tenant(self, tenant: int) -> None:
        """Workload finished: release its partitions (paper §6.3)."""
        t = self.tenants[tenant]
        t.active = False
        t.cache.resize(0)
        t.cache2.resize(0)
        self._prev_sets.pop(tenant, None)
        if self.detector is not None:
            self.detector.forget(tenant)

    # ------------------------------------------------------------ Analyzer
    def effective_sample_rate(self) -> float | str | None:
        """Resolve the Monitor's sampling mode for the current deployment."""
        if self.sample_rate is None \
                and len(self.tenants) >= self.auto_sample_tenants:
            return "auto"
        return self.sample_rate

    def _record_degrade(self, ev: DegradeEvent) -> None:
        self.events.append(ev)
        self.degrade_events += 1

    def _launch_hook(self, win: int, rung: str, attempt: int):
        """Fault-injection hook for one monitor launch (None = no plan)."""
        if self.faults is None or not self.faults.enabled:
            return None

        def hook() -> None:
            if self.faults.launch_should_fail(win, rung, attempt):
                raise InjectedFault(
                    f"injected {rung} launch failure "
                    f"(window={win}, attempt={attempt})")
        return hook

    def _monitor_kwargs(self, act: list[int]) -> dict:
        return dict(kind=self.rd_kind, percentile=self.percentile,
                    sample_rate=self.effective_sample_rate(),
                    window_seed=self.windows_analyzed,
                    sample_target=self.sample_target,
                    sample_floor=self.sample_floor, tenant_ids=act)

    def _per_tenant_monitor(self, act: list[int], traces: list[Trace],
                            kw: dict, win: int
                            ) -> tuple[MonitorResult, list[int]]:
        """Bottom ladder rung: solo analyze per tenant, so one bad tenant
        can no longer take the whole deployment's analyze down.  Failed
        tenants are quarantined (held at last-known-good)."""
        curves, urds, wrs, rates, errs, ok = [], [], [], [], [], []
        for trace, i in zip(traces, act):
            try:
                m = analyze_windows([trace], **{**kw, "tenant_ids": [i]},
                                    pipeline="host",
                                    fault_hook=self._launch_hook(
                                        win, "tenant", 0))
            except Exception:
                self._held.add(i)
                self.tenant_quarantines += 1
                self._record_degrade(
                    DegradeEvent(win, i, "tenant_quarantine"))
                continue
            ok.append(i)
            curves.append(m.curves[0])
            urds.append(int(m.urd_sizes[0]))
            wrs.append(float(m.write_ratios[0]))
            rates.append(float(m.sample_rates[0]))
            errs.append(float(m.expected_errors[0]))
        mon = MonitorResult(curves, np.asarray(urds, np.int64),
                            np.asarray(wrs, np.float64),
                            np.asarray(rates, np.float64),
                            np.asarray(errs, np.float64), self.rd_kind)
        return mon, ok

    def _monitor_ladder(self, act: list[int],
                        window_trd: dict[int, np.ndarray]
                        ) -> tuple[MonitorResult | None, list[int], str]:
        """Run the monitor pass down the degradation ladder.

        Returns ``(result, analyzed_tenants, rung)``; ``result`` is None
        only when every rung failed (total outage → last-known-good).
        Without fault tolerance this is exactly the single fused call."""
        rate = self.effective_sample_rate()
        pipe = (self.pipeline if self.percentile >= 100.0 else "host")
        traces = [self.tenants[i].window_trace() for i in act]
        # the device program recounts on device, so precomputed TRD arrays
        # are only forwarded to the host pipeline; a deferred (straggler)
        # tape spans multiple windows, invalidating its single-window
        # precomputed distances
        pre = ([None if i in self._accumulated else window_trd.get(i)
                for i in act]
               if rate is None and pipe == "host" else None)
        kw = self._monitor_kwargs(act)
        if not self.fault_tolerant:
            mon = analyze_windows(traces, precomputed_trd=pre,
                                  pipeline=pipe, **kw)
            self.windows_analyzed += 1
            return mon, act, pipe
        win = self._cur_window
        # top of the ladder: sharded mesh → single device → fused host →
        # per-tenant solo; a per-shard launch failure inside the mesh
        # program surfaces at the window dispatch and steps the whole
        # window down one rung (counted per rung in summary())
        rungs = ({"sharded": ["sharded", "device"],
                  "device": ["device"]}.get(pipe, []) + ["host", "tenant"])
        for rung in rungs:
            attempts = (self.retry_limit + 1) if rung != "tenant" else 1
            for attempt in range(attempts):
                try:
                    if rung == "tenant":
                        mon, ok = self._per_tenant_monitor(
                            act, traces, kw, win)
                        if not ok and act:
                            # every solo analyze died too: total outage
                            return None, act, ""
                        self.windows_analyzed += 1
                        return mon, ok, rung
                    mon = analyze_windows(
                        traces,
                        precomputed_trd=(pre if rung == "host" else None),
                        pipeline=(rung if rung in ("sharded", "device")
                                  else "host"),
                        fault_hook=self._launch_hook(win, rung, attempt),
                        **kw)
                    self.windows_analyzed += 1
                    return mon, act, rung
                except TraceError:
                    raise          # ingest bugs are not launch failures
                except Exception:
                    if self.backoff_base > 0 and attempt + 1 < attempts:
                        time.sleep(min(self.backoff_base * (2 ** attempt),
                                       1.0))
            if rung == "sharded":
                self.sharded_stepdowns += 1
            elif rung == "device":
                self.device_stepdowns += 1
            elif rung == "host":
                self.host_stepdowns += 1
            self._record_degrade(DegradeEvent(win, -1, "stepdown",
                                              rung=rung))
        return None, act, ""

    def _fallback_decision(self, trigger: tuple[ReconfigEvent, ...],
                           reason: str,
                           violations: tuple[str, ...] = ()
                           ) -> AnalyzerDecision:
        """Last-known-good: keep every tenant at its current size/policy."""
        self.lkg_decisions += 1
        sizes = self.allocated_sizes()
        sizes2 = self.allocated_sizes2()
        n_act = sum(t.active for t in self.tenants)
        part = PartitionResult(
            sizes[[i for i, t in enumerate(self.tenants) if t.active]],
            False, 0.0, np.zeros(n_act))
        decision = AnalyzerDecision(
            sizes, [t.policy for t in self.tenants], False, part,
            sizes2=sizes2, policies2=[t.policy2 for t in self.tenants],
            partition2=None, trigger=tuple(trigger), quarantined=True,
            guard=tuple(violations), degraded=reason,
            deferred=tuple(sorted(self._defer_clear)))
        self._record_degrade(DegradeEvent(self._cur_window, -1, reason))
        self.history.append(decision)
        return decision

    def _apply_demotions(self) -> None:
        """Hold WB tenants of a failed(-and-recovering) tier on the demoted
        policy until ``demote_cooldown`` analyzes after recovery."""
        if not self._demoted_until:
            return
        for (i, lv), until in list(self._demoted_until.items()):
            if until is not None and self.windows_analyzed >= until:
                del self._demoted_until[(i, lv)]
                continue
            t = self.tenants[i]
            if lv == 1 and t.policy is WritePolicy.WB:
                t.policy = self.demote_policy
            elif lv == 2 and t.policy2 is WritePolicy.WB:
                t.policy2 = self.demote_policy

    def _build_decision(self, mon: MonitorResult, act: list[int],
                        held: set[int],
                        trigger: tuple[ReconfigEvent, ...]
                        ) -> tuple[AnalyzerDecision, np.ndarray, int]:
        """Alg. 3 + Eq. 2 over one monitor result.  Returns the decision,
        the guard floors and the partitioned L1 budget."""
        for k, i in enumerate(act):
            t = self.tenants[i]
            t.h_fn = mon.curves[k]
            t.urd_size = int(mon.urd_sizes[k])
            if self.adaptive_policy:
                # Alg. 3 writeRatio = (WAW + WAR)/n: write re-touches are
                # exactly the writes with a TRD sample
                wr = float(mon.write_ratios[k])
                t.policy = (WritePolicy.RO if wr >= self.w_threshold
                            else WritePolicy.WB)
                if self.capacity2 > 0:
                    # per-level Alg. 3: the larger endurance-sensitive L2
                    # switches to the clean policy at a stricter threshold
                    t.policy2 = (WritePolicy.RO if wr >= self.w_threshold2
                                 else WritePolicy.WB)
        self._apply_demotions()

        down1 = 1 in self._down_levels
        down2 = 2 in self._down_levels
        cap1, cap2 = self.capacity, self.capacity2
        pfn = self.partition_fn
        if self.fault_tolerant and (down1 or down2 or held):
            # held tenants keep their current partitions: solve the rest
            # against the residual budget; a down level's budget is 0
            held_sz = sum(int(self.tenants[i].cache.capacity) for i in held)
            held_sz2 = sum(int(self.tenants[i].cache2.capacity)
                           for i in held)
            cap1 = 0 if down1 else max(self.capacity - held_sz, 0)
            cap2 = 0 if down2 else max(self.capacity2 - held_sz2, 0)
            if down1 or down2 or cap1 <= 0:
                # degraded mode: the discrete greedy handles an empty
                # budget exactly (the PGD box projection cannot go below
                # its floors)
                pfn = greedy_allocate
        part, part2 = two_level_solve(
            mon.curves, cap1, cap2, self.t_fast,
            self.t_fast2, self.t_slow, c_min=self.c_min,
            partition_fn=pfn)

        n_ten = len(self.tenants)
        sizes_full = np.zeros(n_ten, dtype=np.int64)
        sizes2_full = np.zeros(n_ten, dtype=np.int64)
        floors = np.zeros(n_ten, dtype=np.int64)
        k = 0
        for i, t in enumerate(self.tenants):
            if not t.active:
                continue
            if i in held:
                sizes_full[i] = t.cache.capacity
                sizes2_full[i] = t.cache2.capacity
                continue
            sizes_full[i] = part.sizes[k]
            if part2 is not None:
                sizes2_full[i] = part2.sizes[k]
            if not down1:
                floors[i] = min(self.c_min, t.urd_size)
            k += 1
        decision = AnalyzerDecision(sizes_full,
                                    [t.policy for t in self.tenants],
                                    part.feasible, part,
                                    sizes2=sizes2_full,
                                    policies2=[t.policy2
                                               for t in self.tenants],
                                    partition2=part2,
                                    trigger=tuple(trigger),
                                    held=tuple(sorted(held)),
                                    deferred=tuple(sorted(
                                        self._defer_clear & held)))
        return decision, floors, cap1

    def analyze(self, window_trd: dict[int, np.ndarray] | None = None,
                trigger: tuple[ReconfigEvent, ...] = ()
                ) -> AnalyzerDecision:
        """Alg. 1 / Alg. 4: run at every Δt window boundary.

        All active tenants are analyzed in one fused pass
        (``analyze_windows``): one stack-distance counting pass over the
        concatenated window tape, batched curve construction, batched
        Alg.-3 write ratios — optionally SHARDS-sampled (see the class
        docstring).  ``window_trd`` optionally carries per-tenant raw TRD
        sample arrays already computed by the batch engine's counting pass
        (identical to ``reuse_distances(trace, "trd").distances``); the
        exact path reuses them instead of re-counting.

        Fault tolerance (see the module docstring): the monitor pass walks
        the degradation ladder, the resulting decision is guard-validated,
        and a violating or unobtainable decision degrades to the
        last-known-good allocation instead of crashing or actuating
        garbage.
        """
        window_trd = window_trd or {}
        held = {i for i in self._held if self.tenants[i].active}
        act = [i for i, t in enumerate(self.tenants)
               if t.active and i not in held]
        # guard rollback point: a quarantined decision must not leak the
        # corrupted pass's Alg.-3 policy flips
        pol_snap = [(t.policy, t.policy2) for t in self.tenants]
        mon, act, rung = self._monitor_ladder(act, window_trd)
        try:
            if mon is None:
                return self._fallback_decision(trigger, "monitor_outage")
            held = {i for i in self._held if self.tenants[i].active}
            if self.faults is not None:
                self.faults.corrupt_monitor(mon, act, self._cur_window)
            decision, floors, budget = self._build_decision(
                mon, act, held, trigger)
            report = validate_decision(decision, self.capacity,
                                       self.capacity2, floors=floors,
                                       floor_budget=budget)
            if not report.ok:
                self.guard_violations_observed += len(report.violations)
                if self.fault_tolerant:
                    retried = False
                    if any(float(r) < 1.0 for r in mon.sample_rates):
                        # a sampled pass can violate by estimation noise:
                        # retry once exactly before giving up on the window
                        self.sampled_exact_retries += 1
                        retried = True
                        try:
                            kw = {**self._monitor_kwargs(act),
                                  "sample_rate": None}
                            mon2 = analyze_windows(
                                [self.tenants[i].window_trace()
                                 for i in act],
                                pipeline="host", **kw)
                            self.windows_analyzed += 1
                            if self.faults is not None:
                                self.faults.corrupt_monitor(
                                    mon2, act, self._cur_window)
                            decision, floors, budget = self._build_decision(
                                mon2, act, held, trigger)
                            report = validate_decision(
                                decision, self.capacity, self.capacity2,
                                floors=floors, floor_budget=budget)
                        except Exception:
                            report = None
                    if report is None or not report.ok:
                        self.guard_quarantines += 1
                        vio = (() if report is None
                               else report.violations)
                        if retried and report is not None:
                            self.guard_violations_observed += \
                                len(report.violations)
                        for t, (p, p2) in zip(self.tenants, pol_snap):
                            t.policy, t.policy2 = p, p2
                        return self._fallback_decision(
                            trigger, "guard_quarantine", vio)
                else:
                    # intolerant: the violation WILL be actuated — count it
                    # so garbage never ships silently
                    decision = dataclasses.replace(
                        decision, guard=report.violations)
            if report is not None and report.ok:
                self._lkg = decision
            self.history.append(decision)
            return decision
        finally:
            self._held = set()
            self._defer_clear = set()

    # ------------------------------------------------------------ Actuator
    def actuate(self, decision: AnalyzerDecision) -> None:
        if decision.guard and not decision.quarantined:
            # an intolerant manager ships the violating decision; count it
            # exactly once so garbage never actuates silently
            self.guard_violations_actuated += 1
        sizes2 = (decision.sizes2 if decision.sizes2 is not None
                  else np.zeros(len(self.tenants), np.int64))
        defer = set(decision.deferred)
        for i, (t, size, size2) in enumerate(
                zip(self.tenants, decision.sizes, sizes2)):
            if t.active:
                t.cache.resize(int(size))
                if self.capacity2 > 0 or t.cache2.capacity > 0:
                    t.cache2.resize(int(size2))
                if i not in defer:
                    t.clear_window()
        # deferred (straggler) tapes now span >1 window: their precomputed
        # single-window distances are invalid at the next analyze
        self._accumulated = defer

    # ------------------------------------------------- tier failure domain
    def fail_tier(self, level: int, duration: int | None = None) -> int:
        """Cache device of hierarchy ``level`` (1 = L1/HBM, 2 = L2/host)
        crashes: drop every tenant's residents on that level, account the
        lost dirty blocks (``dirty_loss``), demote WB tenants (see
        ``note_tier_loss``).  ``duration`` (trace-replay mode) restores
        the tier automatically after that many windows; ``None`` waits for
        an explicit ``note_tier_recovery``.  Returns the dirty-block
        count."""
        dirty = 0
        for t in self.tenants:
            cache = t.cache if level == 1 else t.cache2
            if len(cache):
                _, d = cache.state_arrays()
                if d is not None:
                    dirty += int(np.asarray(d).sum())
            cache.resize(0)
        self.note_tier_loss(level, dirty)
        if duration is not None:
            self._tier_restore_at[level] = \
                self.windows_run + max(int(duration), 1)
        return dirty

    def note_tier_loss(self, level: int, dirty_blocks: int = 0) -> None:
        """Register a tier failure (serving path: ``TieredKVCache`` calls
        this after dropping its own residents).  Marks the level down —
        its partition budget is 0 until recovery — and demotes every WB
        tenant on it to ``demote_policy`` (paper §3: WB's dirty blocks are
        exactly what a cache-device crash loses; a tenant on a tier that
        just failed must not keep buffering dirty data)."""
        self.tier_failures += 1
        self.dirty_loss += int(dirty_blocks)
        self._down_levels.add(int(level))
        for i, t in enumerate(self.tenants):
            if not t.active:
                continue
            pol = t.policy if level == 1 else t.policy2
            if pol is WritePolicy.WB:
                # expiry is stamped at recovery (None = still down)
                self._demoted_until.setdefault((i, int(level)), None)
                if level == 1:
                    t.policy = self.demote_policy
                else:
                    t.policy2 = self.demote_policy
        self._record_degrade(DegradeEvent(
            self.windows_run, -1, "tier_loss", level=int(level),
            blocks=int(dirty_blocks)))

    def note_tier_recovery(self, level: int) -> None:
        """The failed tier is back: restore its budget and start the
        WB-demotion cooldown clock (``demote_cooldown`` analyzes)."""
        level = int(level)
        if level not in self._down_levels:
            return
        self._down_levels.discard(level)
        self._tier_restore_at.pop(level, None)
        until = self.windows_analyzed + 1 + self.demote_cooldown
        for key, u in list(self._demoted_until.items()):
            if key[1] == level and u is None:
                self._demoted_until[key] = until
        self._record_degrade(DegradeEvent(
            self.windows_run, -1, "tier_recover", level=level))

    def tier_is_down(self, level: int) -> bool:
        return int(level) in self._down_levels

    # --------------------------------------------------------- trace replay
    def _accumulate(self, t: TenantState, res: SimResult) -> None:
        agg = t.result
        agg.reads += res.reads; agg.read_hits += res.read_hits
        agg.writes += res.writes; agg.write_hits += res.write_hits
        agg.cache_writes += res.cache_writes
        agg.total_latency += res.total_latency
        agg.read_hits_l2 += res.read_hits_l2
        agg.write_hits_l2 += res.write_hits_l2
        agg.cache_writes_l2 += res.cache_writes_l2
        agg.fallback += res.fallback
        agg.capacity = t.cache.capacity
        agg.capacity2 = t.cache2.capacity
        agg.policy = t.policy.value
        agg.policy2 = t.policy2.value

    def _record_events(self, events: list[ReconfigEvent]) -> None:
        self.events.extend(events)
        self.reconfig_events += len(events)

    def _drain_joined(self, window: int) -> list[ReconfigEvent]:
        """Pending ``add_tenant`` joins -> churn events (not yet recorded)."""
        evs = [ReconfigEvent(window, i, "join") for i in self._joined]
        self._joined.clear()
        return evs

    def _fault_preamble(self, traces: list[Trace | None],
                        win: int) -> list[Trace | None]:
        """Apply the window's scheduled faults and quarantine bad tapes.

        Runs only on a tolerant (or fault-injected) manager: restores
        tiers whose outage expired, injects tape corruption / tier losses
        / stragglers from the plan, and validates every incoming tape —
        a malformed one is quarantined (replaced by an empty tape, tenant
        held at last-known-good) instead of raising."""
        for lv, at in list(self._tier_restore_at.items()):
            if win >= at:
                self.note_tier_recovery(lv)
        self._held = set()
        self._defer_clear = set()
        if self.faults is not None and self.faults.enabled:
            traces = self.faults.corrupt_traces(traces, win)
            for spec in self.faults.at(win, "tier_loss"):
                if spec.level not in self._down_levels:
                    self.fail_tier(spec.level, duration=spec.duration)
            for i in sorted(self.faults.stragglers(win)):
                if 0 <= i < len(traces) and traces[i] is not None \
                        and self.tenants[i].active:
                    self._held.add(i)
                    self._defer_clear.add(i)
                    self.straggler_windows += 1
                    self._record_degrade(DegradeEvent(win, i, "straggler"))
        if self.fault_tolerant:
            for i, tr in enumerate(traces):
                if tr is None:
                    continue
                try:
                    validate_trace(tr, tenant=i, window=win)
                except TraceError:
                    traces[i] = Trace(np.zeros(0, np.int64),
                                      np.zeros(0, bool), tr.name)
                    self._held.add(i)
                    self.poisoned_windows += 1
                    self._record_degrade(DegradeEvent(win, i, "poisoned"))
        return traces

    def run_window(self, traces: list[Trace | None],
                   engine: str | None = None) -> None:
        """Replay one Δt window for every tenant, then analyze + actuate.

        ``traces[i] is None`` marks tenant i as finished.  With
        ``phase_detect`` on, the analyze/actuate half runs only when the
        phase detector, a churn event, or the ``reconfig_interval`` clock
        triggers it (see the class docstring); the replay half always
        runs.
        """
        engine = self.engine if engine is None else engine
        win = self.windows_run
        self._cur_window = win
        if self.fault_tolerant or self.faults is not None:
            traces = self._fault_preamble(list(traces), win)
        events = self._drain_joined(win)
        for i, tr in enumerate(traces):
            if tr is None and self.tenants[i].active:
                self.retire_tenant(i)
                events.append(ReconfigEvent(win, i, "retire"))

        idx = [i for i, tr in enumerate(traces) if tr is not None]
        for i in idx:
            self.record(i, traces[i].addrs, traces[i].is_read)

        window_trd: dict[int, np.ndarray] | None = None
        if engine == "batch":
            results, rds = simulate_many(
                [traces[i] for i in idx],
                policies=[self.tenants[i].policy for i in idx],
                t_fast=self.t_fast, t_slow=self.t_slow,
                t_write_bypass=self.t_write_bypass,
                flush_cost=self.flush_cost,
                caches=[self.tenants[i].cache for i in idx],
                policies2=[self.tenants[i].policy2 for i in idx],
                caches2=[self.tenants[i].cache2 for i in idx],
                t_fast2=self.t_fast2,
                return_window_rd=True)
            window_trd = {i: rd for i, rd in zip(idx, rds) if rd is not None}
            for i, res in zip(idx, results):
                self._accumulate(self.tenants[i], res)
            self.ro_fallback_windows += sum(r.fallback for r in results)
        else:
            for i in idx:
                t = self.tenants[i]
                res = simulate(traces[i], t.cache.capacity, t.policy,
                               self.t_fast, self.t_slow,
                               t_write_bypass=self.t_write_bypass,
                               flush_cost=self.flush_cost, cache=t.cache,
                               capacity2=t.cache2.capacity, policy2=t.policy2,
                               t_fast2=self.t_fast2, cache2=t.cache2)
                self._accumulate(t, res)
        self.tenant_windows += len(idx)
        self.windows_run += 1

        if self.detector is None:
            # fixed-Δt mode: analyze + actuate every window, exactly the
            # pre-event-driven behavior (churn events are telemetry only)
            self._record_events(events)
            decision = self.analyze(window_trd)
            self.actuate(decision)
            return

        # ---------------------------------------- event-driven mode (ReCA)
        # characterize this window's accesses on the replay engine's
        # window reuse distances (no second pass; see core.characterize)
        feats = characterize_windows(
            [traces[i] for i in idx],
            prev_sets=[self._prev_sets.get(i) for i in idx],
            dists=[None if window_trd is None else window_trd.get(i)
                   for i in idx],
            tenant_ids=idx)
        for k, i in enumerate(idx):
            self._prev_sets[i] = feats.address_sets[k]
        events.extend(ReconfigEvent(win, e.tenant, e.reason, e.score)
                      for e in self.detector.update(feats, win, idx))
        self._pending_windows += 1
        if self._pending_windows >= self.reconfig_interval:
            events.append(ReconfigEvent(win, -1, "interval"))
        if events:
            # a multi-window accumulation invalidates the single-window
            # precomputed distances; the Analyzer re-counts the full span
            wtrd = window_trd if self._pending_windows == 1 else None
            self._record_events(events)
            decision = self.analyze(wtrd, trigger=tuple(events))
            self.actuate(decision)
            self._pending_windows = 0

    # ------------------------------------------------------------- metrics
    def allocated_sizes(self) -> np.ndarray:
        return np.array([t.cache.capacity for t in self.tenants], np.int64)

    def allocated_sizes2(self) -> np.ndarray:
        return np.array([t.cache2.capacity for t in self.tenants], np.int64)

    def summary(self) -> dict[str, float]:
        res = [t.result for t in self.tenants]
        n = sum(r.n for r in res)
        lat = sum(r.total_latency for r in res)
        writes = sum(r.cache_writes for r in res)
        alloc = int(self.allocated_sizes().sum())
        mean_lat = lat / n if n else 0.0
        return {
            "accesses": n,
            "mean_latency": mean_lat,
            "performance": 1.0 / mean_lat if mean_lat else 0.0,
            "cache_writes": writes,
            "allocated_blocks": alloc,
            "perf_per_cost": (1.0 / mean_lat) / alloc if mean_lat and alloc else 0.0,
            "read_hit_ratio": (sum(r.read_hits for r in res)
                               / max(sum(r.reads for r in res), 1)),
            "cache_writes_l2": sum(r.cache_writes_l2 for r in res),
            "allocated_blocks_l2": int(self.allocated_sizes2().sum()),
            "read_hit_ratio_l2": (sum(r.read_hits_l2 for r in res)
                                  / max(sum(r.reads for r in res), 1)),
            # batch-engine telemetry: tenant-windows replayed through the
            # per-access interpreter (degenerate windows only — RO
            # eviction pressure stays vectorized), over all replayed windows
            "ro_fallback_windows": self.ro_fallback_windows,
            "tenant_windows": self.tenant_windows,
            # event-driven telemetry: replayed vs analyzed windows and the
            # cumulative reconfiguration-event count (the `events` deque
            # itself is bounded by history_limit)
            "windows_run": self.windows_run,
            "windows_analyzed": self.windows_analyzed,
            "reconfig_events": self.reconfig_events,
            # unified fallback/degrade telemetry (each counter increments
            # exactly once per event; all 0 on a healthy fault-free run)
            "dirty_loss": self.dirty_loss,
            "tier_failures": self.tier_failures,
            "guard_quarantines": self.guard_quarantines,
            "guard_violations_observed": self.guard_violations_observed,
            "guard_violations_actuated": self.guard_violations_actuated,
            "sharded_stepdowns": self.sharded_stepdowns,
            "device_stepdowns": self.device_stepdowns,
            "host_stepdowns": self.host_stepdowns,
            "tenant_quarantines": self.tenant_quarantines,
            "lkg_decisions": self.lkg_decisions,
            "sampled_exact_retries": self.sampled_exact_retries,
            "poisoned_windows": self.poisoned_windows,
            "straggler_windows": self.straggler_windows,
            "degrade_events": self.degrade_events,
        }
