"""Exact and sampled reuse-distance engines (TRD and URD).

Definitions (paper §4):

  * A *reuse-distance sample* at access ``i`` to address ``a`` with a previous
    access at ``p`` is the number of **distinct** addresses touched strictly
    between ``p`` and ``i``.
  * **TRD** (traditional): every re-touch produces a sample, regardless of
    request type.
  * **URD** (useful): only *read* re-touches (RAR, RAW) produce samples —
    a block whose next touch overwrites it (WAR/WAW) gains nothing from
    caching, so those distances are excluded.  Writes still update the
    last-occurrence bookkeeping.

  Invariant (paper Eq. 1): the URD sample set is a subset of the TRD sample
  set, hence ``max URD <= max TRD`` and every URD percentile <= the matching
  TRD percentile.  Property-tested in ``tests/test_urd.py``.

Engines:
  * ``reuse_distances``       — exact, Fenwick-tree O(n log n) (the classic
                                Bennett–Kruskal / Olken formulation).
  * ``reuse_distances_vectorized`` — exact O(n²/tile) masked counting on the
                                ``prev``/``next`` occurrence arrays; this is
                                the formulation the ``urd_scan`` Pallas kernel
                                implements for the TPU.
  * ``sampled_reuse_distances``    — SHARDS-style spatial sampling
                                (hash(addr) < R): unbiased scaled histograms
                                at O(n · s) cost for monitor scalability.
                                The filtered sub-trace is measured by the
                                vectorized ``reuse_distances_fast`` engine
                                (``batch_sim``), the salt is a deterministic
                                function of ``seed`` so a (tenant, window)
                                pair always samples the same address subset,
                                ``rate="auto"`` tunes the rate to a target
                                sample count, and the returned ``RDResult``
                                carries the rate plus an expected-error bar
                                (Waldspurger et al., FAST'15: error shrinks
                                like 1/sqrt(kept samples)).

The fused thousand-tenant path (all tenants' windows analyzed in one
counting pass, exact or sampled) lives in ``repro.core.monitor``; its
counting core is the **width-bounded** merge tree of
``repro.core.batch_sim`` (``count_prev_ge`` / ``count_prev_ge_padded``):
segments are power-of-two padded and self-aligned so the merge recursion
stops at each segment's width, and long single tapes take the same
sort-merge level engine — ``reuse_distances_fast`` rides on it directly.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.trace import Trace, prev_next_occurrence

__all__ = [
    "RDResult",
    "auto_sample_rate",
    "reuse_distances",
    "reuse_distances_vectorized",
    "sampled_reuse_distances",
    "shards_keep_mask",
    "shards_salt",
    "max_rd",
    "urd_cache_blocks",
]


@dataclasses.dataclass(frozen=True)
class RDResult:
    """Per-access reuse-distance samples.

    distances: int64[n] — RD sample per access; -1 where the access produced
      no sample (cold access, or — for URD — a write access).
    kind: "trd" | "urd".
    rate: spatial sampling rate the samples were measured at (1.0 = exact;
      sampled distances are already scaled back by 1/rate).
    expected_error: expected absolute hit-ratio-curve error of a curve built
      from these samples — ~1/sqrt(kept distinct addresses) for
      SHARDS-sampled results (FAST'15 sizes its reservoir in sampled
      *locations*: curve noise is binomial over which addresses survive
      the spatial filter, so the distinct count is the sample size that
      matters), 0.0 for exact engines.
    """

    distances: np.ndarray
    kind: str
    rate: float = 1.0
    expected_error: float = 0.0

    @property
    def samples(self) -> np.ndarray:
        return self.distances[self.distances >= 0]

    def histogram(self, max_bins: int | None = None) -> np.ndarray:
        s = self.samples
        if s.size == 0:
            return np.zeros(1, dtype=np.int64)
        hi = int(s.max()) + 1 if max_bins is None else max_bins
        return np.bincount(np.minimum(s, hi - 1), minlength=hi)


class _Fenwick:
    """Binary indexed tree over trace positions (prefix sums of 0/1 flags)."""

    def __init__(self, n: int):
        self.n = n
        self.t = np.zeros(n + 1, dtype=np.int64)

    def add(self, i: int, v: int) -> None:
        i += 1
        while i <= self.n:
            self.t[i] += v
            i += i & (-i)

    def prefix(self, i: int) -> int:
        # sum over positions [0, i)
        s = 0
        while i > 0:
            s += self.t[i]
            i -= i & (-i)
        return int(s)

    def range(self, lo: int, hi: int) -> int:
        # sum over positions [lo, hi)
        return self.prefix(hi) - self.prefix(lo)


def reuse_distances(trace: Trace, kind: str = "urd") -> RDResult:
    """Exact reuse distances via a Fenwick tree, O(n log n).

    The tree holds a 1 at the position of the *last* occurrence of every
    address seen so far; the count of ones strictly between ``prev`` and the
    current position is exactly the number of distinct intervening addresses.
    """
    if kind not in ("trd", "urd"):
        raise ValueError(f"kind must be 'trd' or 'urd', got {kind!r}")
    n = len(trace)
    addrs, is_read = trace.addrs, trace.is_read
    fen = _Fenwick(n)
    last: dict[int, int] = {}
    out = np.full(n, -1, dtype=np.int64)
    for i in range(n):
        a = int(addrs[i])
        p = last.get(a)
        if p is not None:
            sample = kind == "trd" or bool(is_read[i])
            if sample:
                out[i] = fen.range(p + 1, i)
            fen.add(p, -1)
        fen.add(i, 1)
        last[a] = i
    return RDResult(out, kind)


def reuse_distances_vectorized(trace: Trace, kind: str = "urd",
                               tile: int = 512) -> RDResult:
    """Exact reuse distances via prev/next counting, O(n²/tile) masked ops.

    RD(i) = #{ j : prev[i] < j < i and nxt[j] >= i }.

    Each intervening distinct address contributes exactly one such ``j``
    (its last occurrence inside the window).  This is the pure-numpy oracle
    for the ``urd_scan`` Pallas kernel (same math, same tiling).
    """
    n = len(trace)
    prev, nxt = prev_next_occurrence(trace.addrs)
    out = np.full(n, -1, dtype=np.int64)
    idx = np.arange(n, dtype=np.int64)

    sample_mask = prev >= 0
    if kind == "urd":
        sample_mask &= trace.is_read
    sample_idx = idx[sample_mask]

    for j0 in range(0, n, tile):
        j1 = min(j0 + tile, n)
        js = idx[j0:j1]                                   # [t]
        nj = nxt[j0:j1]                                   # [t]
        # contribution of tile [j0, j1) to every sampled access i
        lo = prev[sample_idx][:, None]                    # [s, 1]
        i = sample_idx[:, None]                           # [s, 1]
        m = (js[None, :] > lo) & (js[None, :] < i) & (nj[None, :] >= i)
        counts = m.sum(axis=1)
        out[sample_idx] = np.where(out[sample_idx] < 0, 0, out[sample_idx])
        out[sample_idx] += counts
    return RDResult(out, kind)


_MASK64 = (1 << 64) - 1


def shards_salt(seed: int, tenant: int = 0) -> int:
    """Deterministic SHARDS hash salt in ``[1, 2**31 - 3]``.

    A splitmix64-style mix of ``(seed, tenant)``: the same (tenant, window)
    pair always tracks the same address subset — sampled curves stay
    comparable across the Δt sequence — while distinct tenants and windows
    decorrelate (important when tenants share an address space).
    """
    z = (int(seed) * 0x9E3779B97F4A7C15 + int(tenant) * 0xBF58476D1CE4E5B9
         + 0x94D049BB133111EB) & _MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
    z ^= z >> 31
    return int(z % (2**31 - 3)) + 1


def auto_sample_rate(n: int, target: int = 4096, floor: int = 256) -> float:
    """SHARDS rate tuner: aim for ``target`` kept accesses per window.

    ``floor`` is the minimum expected sample count a curve is allowed to be
    built from — windows shorter than ``max(target, floor)`` are measured
    exactly (rate 1.0), so tiny tenants never pay sampling noise.
    """
    n = int(n)
    if n <= 0:
        return 1.0
    want = max(int(target), int(floor), 1)
    return min(1.0, want / n)


def shards_keep_mask(addrs: np.ndarray, rate: float, salt: int) -> np.ndarray:
    """bool[n]: SHARDS spatial filter ``hash(addr) < rate`` (salted).

    Cheap multiplicative hash -> [0, 1); evaluated in uint32 (the natural
    wrap *is* the mod) against an integer threshold — exactly equivalent to
    ``((addrs * 2654435761 + salt) % 2**32) / 2**32 < rate`` (division by
    2**32 is exact in float64), at a quarter of the memory traffic.
    """
    thr = math.ceil(rate * float(2**32))
    if thr >= 2**32:        # rate == 1 (or within 2**-32 of it): keep all
        return np.ones(addrs.shape[0], dtype=bool)
    h = (addrs.astype(np.uint32) * np.uint32(2654435761)
         + np.uint32(salt))
    return h < np.uint32(thr)


def sampled_reuse_distances(trace: Trace, kind: str = "urd",
                            rate: float | str = 0.1, seed: int = 0,
                            salt: int | None = None,
                            target_samples: int = 4096,
                            min_samples: int = 256,
                            engine: str = "fast") -> RDResult:
    """SHARDS-style spatially-sampled reuse distances.

    Keeps addresses whose salted hash falls below ``rate``; distances measured
    on the filtered trace are scaled by ``1/rate`` (unbiased in expectation —
    Waldspurger et al., FAST'15).  Returned distances are the scaled values.

    ``rate="auto"`` picks ``auto_sample_rate(len(trace), target_samples,
    min_samples)``.  The filtered sub-trace goes through the vectorized
    ``reuse_distances_fast`` engine by default (``engine="fenwick"`` keeps
    the exact per-access loop as the equivalence oracle); both produce
    identical distances, the fast path just restores the O(n·s) sampling
    win the monitor relies on at scale.
    """
    if rate == "auto":
        rate = auto_sample_rate(len(trace), target_samples, min_samples)
    rate = float(rate)
    if not (0 < rate <= 1):
        raise ValueError("rate must be in (0, 1]")
    if salt is None:
        salt = shards_salt(seed)
    keep = shards_keep_mask(trace.addrs, rate, salt)
    if not keep.any():
        # A fixed low rate on a tiny window can keep zero accesses: return
        # a well-formed empty result (no samples -> ``urd_cache_blocks``
        # is 0 and curves built from it are flat at 0) with the error bar
        # saturated at 1, instead of running the engines on an empty
        # sub-trace.  An empty *input* trace is exact by definition.
        return RDResult(np.full(len(trace), -1, dtype=np.int64), kind,
                        rate=rate,
                        expected_error=0.0 if len(trace) == 0 else 1.0)
    sub = Trace(trace.addrs[keep], trace.is_read[keep], trace.name)
    if engine == "fast":
        from repro.core.batch_sim import reuse_distances_fast
        res = reuse_distances_fast(sub, kind)
    else:
        res = reuse_distances(sub, kind)
    scaled = np.full(len(trace), -1, dtype=np.int64)
    vals = res.distances.copy()
    pos = vals >= 0
    vals[pos] = np.round(vals[pos] / rate).astype(np.int64)
    scaled[np.flatnonzero(keep)] = vals
    distinct = int(np.unique(sub.addrs).size)
    err = (0.0 if rate >= 1.0
           else min(1.0, 1.0 / math.sqrt(max(distinct, 1))))
    return RDResult(scaled, kind, rate=rate, expected_error=err)


def max_rd(result: RDResult, percentile: float = 100.0) -> int:
    """Max (or percentile) reuse distance; -1 when no samples exist."""
    s = result.samples
    if s.size == 0:
        return -1
    if percentile >= 100.0:
        return int(s.max())
    return int(np.percentile(s, percentile))


def urd_cache_blocks(result: RDResult, percentile: float = 100.0) -> int:
    """Paper ``calculateURDbasedSize``: cache blocks needed to capture every
    sampled reuse.  A reuse at distance d needs d+1 resident blocks
    (Fig. 5: max URD 1 -> 2 blocks; max TRD 4 -> 5 blocks)."""
    m = max_rd(result, percentile)
    return m + 1 if m >= 0 else 0
