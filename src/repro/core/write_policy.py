"""Per-tenant write-policy assignment (paper Alg. 3).

Policies:
  WB — write-back: writes are buffered in the fast tier (admitted pages),
       flushed on eviction.  Best write performance, worst endurance.
  WT — write-through: buffered *and* propagated immediately (same endurance
       as WB, lower performance; the paper omits it from experiments and so
       does the live engine, but the simulator supports it).
  RO — read-only / write-around: writes bypass the fast tier; only read
       misses install pages.  Best endurance + reliability.

Assignment rule (Alg. 3):  RO  iff  (WAW + WAR) / total >= wThreshold.
"""
from __future__ import annotations

import enum

import numpy as np

from repro.core.trace import AccessClass, Trace, classify_accesses

__all__ = ["WritePolicy", "write_ratio", "assign_write_policy",
           "assign_write_policy_levels"]


class WritePolicy(enum.Enum):
    WB = "wb"
    WT = "wt"
    RO = "ro"


def write_ratio(trace: Trace) -> float:
    """writeRatio = (#WAW + #WAR) / #requests (paper Alg. 3 line 4)."""
    if len(trace) == 0:
        return 0.0
    codes = classify_accesses(trace)
    unref = np.sum((codes == AccessClass.WAW) | (codes == AccessClass.WAR))
    return float(unref) / len(trace)


def assign_write_policy(trace: Trace, w_threshold: float = 0.5) -> WritePolicy:
    """RO when unreferenced-write re-touches dominate, else WB (Alg. 3)."""
    return (WritePolicy.RO if write_ratio(trace) >= w_threshold
            else WritePolicy.WB)


def assign_write_policy_levels(trace: Trace, w_threshold: float = 0.5,
                               w_threshold2: float = 0.3
                               ) -> tuple[WritePolicy, WritePolicy]:
    """ETICA-style per-level Alg. 3 from one request-type classification.

    Each level applies the Alg.-3 rule at its own threshold to the same
    writeRatio.  Level 2 (the larger, endurance-sensitive device) uses a
    *stricter* (lower) threshold: at moderate WAW/WAR pressure it already
    switches to the clean policy (``RO``: dirty victims are flushed at
    demotion and never stored dirty — see ``simulator``), while L1 only
    gives up write buffering when unreferenced writes dominate outright.
    """
    wr = write_ratio(trace)
    return (WritePolicy.RO if wr >= w_threshold else WritePolicy.WB,
            WritePolicy.RO if wr >= w_threshold2 else WritePolicy.WB)
