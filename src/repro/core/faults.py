"""Deterministic, seeded fault injection for the control plane and tiers.

ECI-Cache's write-policy assignment is explicitly a *reliability* decision
(paper §3: WB maximizes hits but loses dirty data on a cache-device crash,
which is why Alg. 3 restricts it), yet a reproduction with no failure model
can never exercise that rationale.  ``FaultPlan`` is the failure model: a
frozen, seed-deterministic schedule of injected faults at chosen
``(tenant, window)`` coordinates that the ``ECICacheManager`` (and the
serving tiers) consult while running.  With no plan attached — or an empty
one — every consumer is bit-identical to the fault-free code path.

Fault taxonomy (``FaultSpec.kind``):

  ``tier_loss``   — cache device of hierarchy level ``level`` (1 = HBM/SSD,
                    2 = host/SSD-2) crashes at ``window`` for ``duration``
                    windows: residents drop, dirty blocks are lost
                    (``dirty_loss``), WB tenants demote (see manager).
  ``poison``      — tenant ``tenant``'s window tape is corrupted in a
                    *detectable* way (negative / non-integer addresses,
                    op codes outside {0, 1}) — exercises the ``TraceError``
                    ingest validation and quarantine path.
  ``truncate``    — tenant's tape is cut to a ``1 - param`` fraction
                    (a short-but-valid window: ingest under-delivery).
  ``curve_nan``   — the monitor's outputs for ``tenant`` are corrupted
                    after the pass (NaN/inf curve heights, negative URD —
                    ``param`` selects the mode): exercises the decision
                    guard, which must quarantine instead of actuating.
  ``pipeline``    — monitor launch failure: the ladder rung named by
                    ``rung`` ("sharded" | "device" | "host" | "tenant",
                    "" = all) raises ``InjectedFault`` at dispatch for
                    the first ``count`` attempts of each matching window
                    (a "sharded" spec models a per-shard launch failure
                    inside the mesh program: the whole window steps down
                    to the single-device rung).
  ``straggler``   — tenant's window tape arrives late: the manager holds
                    the tenant out of this window's analyze (last-known-good
                    size/policy) and folds the deferred tape into the next.

All randomness used to *materialize* a fault (which addresses to poison,
which corruption mode) derives from ``(seed, window)`` — replaying the same
plan over the same scenario is bit-reproducible, which the chaos suite
(``tests/test_faults.py``) relies on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.trace import Trace

__all__ = ["FAULT_KINDS", "FaultSpec", "FaultPlan", "InjectedFault"]

FAULT_KINDS = ("tier_loss", "poison", "truncate", "curve_nan", "pipeline",
               "straggler")


class InjectedFault(RuntimeError):
    """Raised by injected launch failures (never escapes a tolerant manager)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault (see module doc for the kind taxonomy).

    ``window`` is the first affected ``run_window`` index; the fault stays
    active for ``duration`` windows.  ``tenant`` is the manager tenant
    index (-1 = not tenant-scoped), ``level`` the hierarchy level for
    ``tier_loss``.  ``count`` bounds how many launch *attempts* a
    ``pipeline`` fault kills per window (1 = the retry succeeds; a value
    above the manager's ``retry_limit`` forces a rung step-down).
    ``param`` is a kind-specific knob (truncation fraction, corruption
    mode).  ``rung`` restricts ``pipeline`` faults to one ladder rung.
    """

    kind: str
    window: int
    tenant: int = -1
    level: int = 1
    duration: int = 1
    count: int = 1
    rung: str = ""
    param: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.duration < 1:
            raise ValueError("fault duration must be >= 1 window")

    def active(self, window: int) -> bool:
        return self.window <= window < self.window + self.duration


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, immutable schedule of ``FaultSpec``s.

    Query API (all pure; the manager calls these per window):
      ``at(window, kind)``       specs of ``kind`` *starting* at ``window``
      ``active(window, kind)``   specs of ``kind`` covering ``window``
      ``stragglers(window)``     tenant indices straggling this window
      ``launch_should_fail``     should this (window, rung, attempt) die
      ``corrupt_traces``         apply poison/truncate faults to a window
      ``corrupt_monitor``        apply curve_nan faults to a MonitorResult
    """

    specs: tuple[FaultSpec, ...] = ()
    seed: int = 0

    def __post_init__(self) -> None:
        object.__setattr__(self, "specs", tuple(self.specs))

    @property
    def enabled(self) -> bool:
        return bool(self.specs)

    # ------------------------------------------------------------- queries
    def at(self, window: int, kind: str) -> list[FaultSpec]:
        return [s for s in self.specs
                if s.kind == kind and s.window == window]

    def active(self, window: int, kind: str) -> list[FaultSpec]:
        return [s for s in self.specs
                if s.kind == kind and s.active(window)]

    def stragglers(self, window: int) -> set[int]:
        return {s.tenant for s in self.active(window, "straggler")
                if s.tenant >= 0}

    def launch_should_fail(self, window: int, rung: str,
                           attempt: int) -> bool:
        for s in self.active(window, "pipeline"):
            if s.rung in ("", rung) and attempt < s.count:
                return True
        return False

    def last_fault_window(self) -> int:
        """Last window any fault is still active (-1: empty plan)."""
        if not self.specs:
            return -1
        return max(s.window + s.duration - 1 for s in self.specs)

    def reconverge_bound(self, demote_cooldown: int) -> int:
        """K: windows after ``last_fault_window()`` within which a tolerant
        manager must match the no-fault decision again.

        One window flushes deferred straggler tapes out of the monitor,
        ``demote_cooldown`` analyzes hold recovered-tier WB tenants on the
        demoted policy, and one more window re-runs Alg. 1/3 on clean
        state.  Decisions depend only on the current window's tape and the
        (restored) capacities, so this bound is tight — gated in
        ``benchmarks/bench_faults.py`` and the chaos suite.
        """
        return int(demote_cooldown) + 2

    # ----------------------------------------------------- trace corruption
    def _rng(self, window: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 0x9E3779B1 + window * 1_000_003 + 7) & 0x7FFFFFFF)

    def corrupt_traces(self, traces: list[Trace | None],
                       window: int) -> list[Trace | None]:
        """Apply poison/truncate faults to one window's tapes (pure)."""
        out = list(traces)
        rng = self._rng(window)
        for s in self.active(window, "poison"):
            i = s.tenant
            if 0 <= i < len(out) and out[i] is not None:
                out[i] = _poison_trace(out[i], rng, int(s.param))
        for s in self.active(window, "truncate"):
            i = s.tenant
            if 0 <= i < len(out) and out[i] is not None:
                frac = s.param if 0.0 < s.param < 1.0 else 0.75
                keep = int(len(out[i]) * (1.0 - frac))
                out[i] = out[i].slice(0, max(keep, 0))
        return out

    def corrupt_monitor(self, mon, act: list[int], window: int) -> None:
        """Apply curve_nan faults in place to one analyze's outputs."""
        for s in self.active(window, "curve_nan"):
            if s.tenant not in act:
                continue
            k = act.index(s.tenant)
            mode = int(s.param)
            curves = mon.curves
            if mode in (0, 1):
                bad = np.nan if mode == 0 else np.inf
                try:
                    if hasattr(curves, "heights") \
                            and hasattr(curves, "offsets"):
                        lo = int(curves.offsets[k])
                        hi = int(curves.offsets[k + 1])
                        curves.heights[lo:hi] = bad
                        continue
                    c = curves[k]
                    if getattr(c, "heights", None) is not None \
                            and len(c.heights):
                        c.heights[:] = bad
                        continue
                except (TypeError, ValueError):
                    pass  # immutable (device) arrays: fall through to URD
            mon.urd_sizes[k] = -7

    # ------------------------------------------------------------ factories
    @classmethod
    def standard(cls, n_tenants: int, n_windows: int,
                 seed: int = 0) -> "FaultPlan":
        """The bench's canonical mixed plan: one of everything.

        Exercises in one run: trace quarantine (poison + truncate), an
        in-rung launch retry, a forced host→per-tenant step-down, a
        mid-run L1 loss (dirty loss + WB demotion + recovery), a
        straggler hold, and a guard quarantine (NaN curve).
        """
        nt, nw = int(n_tenants), int(n_windows)
        if nt < 1 or nw < 8:
            raise ValueError("standard plan needs >= 1 tenant, >= 8 windows")
        mid = nw // 2
        return cls(specs=(
            FaultSpec("poison", window=1, tenant=0),
            FaultSpec("pipeline", window=2, rung="host", count=1),
            FaultSpec("straggler", window=max(mid - 2, 1),
                      tenant=min(1, nt - 1)),
            FaultSpec("tier_loss", window=mid, level=1, duration=1),
            FaultSpec("pipeline", window=mid + 1, rung="host", count=99),
            FaultSpec("curve_nan", window=nw - 3, tenant=min(2, nt - 1)),
            FaultSpec("truncate", window=nw - 2, tenant=0, param=0.5),
        ), seed=seed)

    @classmethod
    def chaos(cls, n_tenants: int, n_windows: int, seed: int = 0,
              max_faults: int = 4) -> "FaultPlan":
        """A random-but-deterministic plan for the hypothesis chaos suite."""
        rng = np.random.default_rng(seed)
        n_faults = int(rng.integers(1, max(max_faults, 1) + 1))
        specs = []
        for _ in range(n_faults):
            kind = FAULT_KINDS[int(rng.integers(len(FAULT_KINDS)))]
            window = int(rng.integers(1, max(n_windows - 3, 2)))
            specs.append(FaultSpec(
                kind, window=window,
                tenant=int(rng.integers(n_tenants)),
                level=1, duration=int(rng.integers(1, 3)),
                count=int(rng.integers(1, 4)),
                rung=("", "host", "sharded")[int(rng.integers(3))]
                     if kind == "pipeline" else "",
                param=float(rng.integers(3)) if kind in ("curve_nan",
                                                         "poison")
                      else (0.5 if kind == "truncate" else 0.0)))
        return cls(specs=tuple(specs), seed=seed)


def _poison_trace(tr: Trace, rng: np.random.Generator, mode: int) -> Trace:
    """Corrupt a tape *detectably* (the ingest validator must catch it)."""
    n = len(tr)
    if n == 0:
        return Trace(np.array([-1], np.int64), np.array([True]), tr.name)
    if mode == 0:                      # negative block addresses
        addrs = tr.addrs.copy()
        k = max(1, n // 8)
        pos = rng.choice(n, size=min(k, n), replace=False)
        addrs[pos] = -1 - np.abs(addrs[pos])
        return Trace(addrs, tr.is_read.copy(), tr.name)
    if mode == 1:                      # op codes outside {read, write}
        ops = tr.is_read.astype(np.int8)
        pos = rng.choice(n, size=max(1, n // 8), replace=False)
        ops[pos] = 2
        return Trace(tr.addrs.copy(), ops, tr.name)
    # non-integer addresses (float tape)
    return Trace(tr.addrs.astype(np.float64) + 0.5, tr.is_read.copy(),
                 tr.name)
