"""Sharded Δt window pipeline: the fused device program over a 1-D mesh.

``core.device_pipeline`` keeps a whole window decision on one device;
this module partitions the padded, width-sorted segment tape across a
1-D ``("shards",)`` device mesh **by whole tenant-segments** and runs
exactly the same per-window jitted stages under ``shard_map``:

  * **Assignment** (``shard_assignment``): greedy width-balanced (LPT)
    placement of segments, walked in the tape's global descending-width
    order so each shard's sub-tape is again a descending sequence of
    power-of-two rows — prefix sums of descending pow2 widths are
    multiples of every following width, so every row stays self-aligned
    on its shard and the boundary-severing proof (links clamped at
    segment ends, pad/cross-segment dominance contributions cancel —
    see ``core.monitor``) applies *per shard*: counting needs no
    cross-device links.  The greedy max-shard load never exceeds 2× the
    optimal (load ≤ mean + w_max ≤ 2·max(mean, w_max)); pinned as a
    hypothesis invariant in the shard suite.
  * **Uniform stacked ingest** (``ingest_window_sharded``): shard_map
    needs one static per-shard structure, so each distinct width's row
    count is padded to its max across shards; surplus rows carry the
    ``padded_tape_links`` pad sentinels and a *trash tenant slot* ``n``
    (per-tenant arrays run length ``n+1``; the slot is dropped after the
    cross-shard reduction, so all-pad rows can never alias a real
    tenant's curve).  The whole ``[n_shards, S]`` tree ships in a single
    async ``jax.device_put`` with ``NamedSharding(mesh, P("shards"))``
    leaves — the per-shard async transfer that ``run_stream``'s double
    buffering overlaps with the previous window's analysis.
  * **One jitted program per shape bucket**: inside ``shard_map`` each
    shard runs the *identical* stage closures the single-device program
    jits (``device_pipeline._programs(...)["stages"]``) — SD counting,
    device curve build (SHARDS scaling included), write counts — on its
    own resident tape chunk; only the per-tenant summaries cross shards
    (integer ``lax.psum`` of breakpoint/URD/write counts — exact, since
    every tenant lives wholly on one shard and foreign shards contribute
    zeros) plus one ``lax.all_gather`` of the device-resident curve
    store (the envelope-walk input).  The budget cut — the existing
    envelope-scan walk + partition stage over the concatenated store —
    then runs once, replicated, at jit level, so the grant order and
    allocations are **bit-identical** to the fused host path (the walk
    is layout-order free: one total-order 3-key sort, row-local scans).
  * **Transfer contract**: ≤ 1 host sync per window *per mesh* — ingest
    is one explicit ``device_put``, the decision fetch one explicit
    ``device_get`` — enforced under ``transfer_sanitizer`` and asserted
    by the shard suite via ``StageProfile``.

``monitor_window_sharded`` backs ``analyze_windows(pipeline="sharded")``
and the manager's new top ladder rung (sharded → device → host → solo);
``DeviceWindowPipeline(mesh=...)`` routes its fused decisions (and
``run_stream``) through here.  Default-off everywhere: without a mesh /
with ``pipeline != "sharded"`` nothing in this module ever runs.  On CPU
hosts the harness forces ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` so tests and CI exercise real multi-device semantics.
"""
from __future__ import annotations

import dataclasses
import heapq

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.batch_sim import padded_segment_layout, padded_tape_links
from repro.core.device_pipeline import (StageProfile, _f64_default, _fetch,
                                        _np_dtypes, _programs, _pstage,
                                        _trivial_monitor, _x64,
                                        transfer_sanitizer)
from repro.core.mrc import BatchedHitRatioFunctions
from repro.kernels.cache_sim.ops import _on_tpu

__all__ = ["ShardIngest", "ShardLayout", "dispatch_decision_sharded",
           "ingest_window_sharded", "monitor_window_sharded",
           "shard_assignment", "uniform_shard_layout"]

_AXIS = "shards"
_TAPE_KEYS = ("gprev", "gnxt", "gocc", "gread", "gtid", "grank", "row_tids")
_REP_KEYS = ("rates", "n_acc", "wr_den")


# ----------------------------------------------------------- shard placement
def shard_assignment(widths: np.ndarray, n_shards: int) -> np.ndarray:
    """Greedy width-balanced (LPT) shard per padded row.

    ``widths`` are the layout's padded row widths in descending order;
    each row goes to the currently lightest shard (ties → lowest index),
    so every shard's row subsequence stays descending (self-alignment)
    and ``max_load <= mean + w_max <= 2 * max(mean, w_max)`` — within 2×
    of the optimal max-shard width.
    """
    n_shards = int(n_shards)
    assign = np.empty(widths.shape[0], dtype=np.int64)
    heap = [(0, s) for s in range(n_shards)]    # (load, shard); ties → low s
    heapq.heapify(heap)
    for r, w in enumerate(widths):
        load, s = heapq.heappop(heap)
        assign[r] = s
        heapq.heappush(heap, (load + int(w), s))
    return assign


@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """The uniform per-shard tape structure (identical on every shard).

    ``shard_wg`` is the per-shard ``width_groups_of``-style structure
    (every distinct width padded to its max row count over shards — the
    static shape shard_map requires); ``entry_base``/``row_index`` map
    each *global* layout row to its local slot on its assigned shard.
    """

    uwidths: np.ndarray        # distinct pow2 widths, descending
    rcap: np.ndarray           # rows per width in the uniform layout
    size: int                  # per-shard padded tape length S
    rows: int                  # per-shard row count R
    shard_wg: tuple            # ((w, lo, hi), ...) over [0, S)
    entry_base: np.ndarray     # int64[g] local entry offset per global row
    row_index: np.ndarray      # int64[g] local row index per global row


def uniform_shard_layout(widths: np.ndarray, assign: np.ndarray,
                         n_shards: int) -> ShardLayout:
    """Place every assigned row into the uniform per-shard structure."""
    widths = np.asarray(widths, np.int64)
    neg_u, inv = np.unique(-widths, return_inverse=True)
    uw = (-neg_u).astype(np.int64)               # descending distinct widths
    per = np.zeros((int(n_shards), uw.size), np.int64)
    np.add.at(per, (assign, inv), 1)
    rcap = per.max(axis=0)
    blk_entry = np.concatenate([[0], np.cumsum(rcap * uw)[:-1]]
                               ).astype(np.int64)
    blk_row = np.concatenate([[0], np.cumsum(rcap)[:-1]]).astype(np.int64)
    # arrival order per (shard, width) — rows walked in global descending
    # order, so the k-th arrival takes the block's k-th slot
    key = assign * uw.size + inv
    order = np.argsort(key, kind="stable")
    sk = key[order]
    starts = np.flatnonzero(np.concatenate([[True], sk[1:] != sk[:-1]]))
    runs = np.diff(np.append(starts, sk.size))
    seq = np.empty(sk.size, np.int64)
    seq[order] = np.arange(sk.size, dtype=np.int64) - np.repeat(starts, runs)
    shard_wg = tuple((int(w), int(lo), int(lo + int(c) * int(w)))
                     for w, lo, c in zip(uw, blk_entry, rcap))
    return ShardLayout(uw, rcap, int(np.sum(rcap * uw)), int(rcap.sum()),
                       shard_wg, blk_entry[inv] + seq * widths,
                       blk_row[inv] + seq)


# ------------------------------------------------------------------- ingest
@dataclasses.dataclass
class ShardIngest:
    """One window's mesh-resident stacked tape + host-side metadata.

    Mirrors ``device_pipeline.WindowIngest``; ``dev`` holds three trees —
    ``tape`` ([n_shards, S] leaves, sharded over the mesh), ``rep``
    (replicated length-``n+1`` per-tenant inputs with the trash slot) and
    ``geo`` (replicated concatenated-store coordinates for the budget
    cut).  ``row_start`` is already in concatenated-store coordinates so
    the host curve reassembly is the same ``from_padded`` gather.
    """

    key: tuple
    dev: dict
    n: int
    total: int                 # concatenated store length n_shards * S
    f64: bool
    row_start: np.ndarray      # int64[n] concatenated-store row base
    n_acc: np.ndarray
    cold: np.ndarray
    mesh: object
    n_shards: int
    shard_size: int


def ingest_window_sharded(addrs: np.ndarray, is_read: np.ndarray,
                          bounds: np.ndarray, n_accesses: np.ndarray, *,
                          mesh, rates: np.ndarray | None = None,
                          kind: str = "urd", use_kernel: bool | None = None,
                          f64: bool | None = None,
                          profile: StageProfile | None = None
                          ) -> ShardIngest | None:
    """Host half of the sharded pipeline: layout + links + shard placement
    + one async mesh-wide ``device_put`` of the stacked tape.

    Same contract as ``device_pipeline.ingest_window`` (returns ``None``
    for an all-empty window); the extra work is the greedy assignment and
    the scatter of every row into its shard-local slot (links shift by a
    per-row constant — they are clamped within the row, so relative
    comparisons, and therefore counts, are unchanged).
    """
    from repro.core.monitor import _segment_links
    bounds = np.asarray(bounds, np.int64)
    n = bounds.shape[0] - 1
    if use_kernel is None:
        use_kernel = _on_tpu()
    if f64 is None:
        f64 = _f64_default()
    idt, fdt = _np_dtypes(f64)
    n_shards = int(mesh.devices.size)
    with _pstage(profile, "ingest"):
        lens_sub = np.diff(bounds)
        tid = np.repeat(np.arange(n, dtype=np.int64), lens_sub)
        layout = padded_segment_layout(bounds)
        src, tpos, base_src, base_pad, widths, total, seg_starts = layout
        if n == 0 or total == 0:
            return None
        assign = shard_assignment(widths, n_shards)
        lay = uniform_shard_layout(widths, assign, n_shards)
        S = lay.size
        if not f64 and S * (S + 2) >= 2**31 and not use_kernel:
            raise ValueError(
                "sharded pipeline: f64=False limits the merge-sort-tree "
                f"counting oracle to shard tapes with S*(S+2) < 2^31 "
                f"(got S={S}); use f64=True or the TPU kernel")
        prev, nxt_c = _segment_links(addrs, tid, bounds, layout)
        gprev, gnxt, gocc = padded_tape_links(prev, nxt_c, layout)
        src_eff = (src if src is not None
                   else np.arange(addrs.shape[0], dtype=np.int64))
        gread = np.zeros(total, bool)
        gread[tpos] = is_read[src_eff]
        row_base = np.concatenate([[0], np.cumsum(widths)[:-1]]
                                  ).astype(np.int64)
        row_tids = (np.searchsorted(bounds, seg_starts, side="right")
                    - 1).astype(np.int64)
        n_acc = np.maximum(np.asarray(n_accesses, np.int64), 1)
        cold = np.bincount(tid[prev < 0], minlength=n).astype(np.int64)
        # templates: surplus (all-pad) rows carry the padded_tape_links
        # sentinels and the trash tenant slot n
        u_widths = np.repeat(lay.uwidths, lay.rcap)
        u_base = np.concatenate([[0], np.cumsum(u_widths)[:-1]]
                                ).astype(np.int64)
        tape = {
            "gprev": np.full((n_shards, S), -1, np.int32),
            "gnxt": np.tile(np.arange(S, dtype=np.int32), (n_shards, 1)),
            "gocc": np.zeros((n_shards, S), np.int32),
            "gread": np.zeros((n_shards, S), bool),
            "gtid": np.full((n_shards, S), n, np.int32),
            "grank": np.tile((np.arange(S, dtype=np.int64)
                              - np.repeat(u_base, u_widths)
                              ).astype(np.int32), (n_shards, 1)),
            "row_tids": np.full((n_shards, lay.rows), n, np.int32),
        }
        # scatter real rows: links are row-internal, so one constant shift
        # per row relocates them exactly; grank is shift-invariant and the
        # template already matches
        rows_e = np.repeat(np.arange(widths.size, dtype=np.int64), widths)
        shift_e = (lay.entry_base - row_base)[rows_e]
        sh_e = assign[rows_e]
        dst_e = np.arange(total, dtype=np.int64) + shift_e
        tape["gprev"][sh_e, dst_e] = np.where(gprev >= 0, gprev + shift_e,
                                              -1).astype(np.int32)
        tape["gnxt"][sh_e, dst_e] = (gnxt + shift_e).astype(np.int32)
        tape["gocc"][sh_e, dst_e] = gocc.astype(np.int32)
        tape["gread"][sh_e, dst_e] = gread
        tape["gtid"][sh_e, dst_e] = np.repeat(row_tids,
                                              widths).astype(np.int32)
        tape["row_tids"][assign, lay.row_index] = row_tids.astype(np.int32)
        rates_t = (np.ones(n, fdt) if rates is None
                   else np.asarray(rates, fdt))
        rep = {
            "rates": np.concatenate([rates_t, np.ones(1, fdt)]),
            "n_acc": np.concatenate([n_acc, [1]]).astype(idt),
            "wr_den": np.concatenate([np.maximum(lens_sub, 1),
                                      [1]]).astype(idt),
        }
        row_start_cat = np.zeros(n + 1, np.int64)
        row_start_cat[row_tids] = assign * S + lay.entry_base
        geo = {
            "gtid": np.ascontiguousarray(tape["gtid"].reshape(-1)),
            "grank": np.ascontiguousarray(tape["grank"].reshape(-1)),
            "row_start": row_start_cat.astype(idt),
        }
        key = (lay.shard_wg, n_shards, n, rates is not None, kind,
               bool(use_kernel), bool(f64), mesh)
        shardings = ({k: NamedSharding(mesh, P(_AXIS)) for k in tape},
                     {k: NamedSharding(mesh, P()) for k in rep},
                     {k: NamedSharding(mesh, P()) for k in geo})
        with _x64(f64):
            # one async mesh-wide transfer: window t+1's stacked put
            # overlaps window t's on-device analysis under run_stream
            dev_tape, dev_rep, dev_geo = jax.device_put((tape, rep, geo),
                                                        shardings)
    return ShardIngest(key, {"tape": dev_tape, "rep": dev_rep,
                             "geo": dev_geo}, n, n_shards * S, bool(f64),
                       row_start_cat[:n].copy(), n_acc, cold, mesh,
                       n_shards, S)


# ----------------------------------------------------------------- programs
_SHARD_PROGRAMS: dict[tuple, dict] = {}


def _shard_programs(key: tuple) -> dict:
    """Build (and cache) the sharded window programs for one shape bucket.

    Per-shard work re-traces the single-device stage closures
    (``device_pipeline._programs(...)["stages"]``) inside the shard_map
    body; only integer per-tenant summaries are ``psum``-reduced (exact —
    each tenant is whole on one shard, foreign shards add zeros) and the
    curve store ``all_gather``-ed for the single replicated budget cut.
    """
    if key in _SHARD_PROGRAMS:
        return _SHARD_PROGRAMS[key]
    shard_wg, n_shards, n, sampled, kind, use_kernel, f64, mesh = key
    S = shard_wg[-1][2]
    n1 = n + 1
    idt = jnp.int64 if f64 else jnp.int32
    per = _programs((shard_wg, n1, sampled, kind, use_kernel,
                     f64))["stages"]
    # the replicated partition walks the concatenated store: the shard
    # structure repeated per mesh position (all_gather order)
    wg_cat = tuple((w, s * S + lo, s * S + hi)
                   for s in range(n_shards) for (w, lo, hi) in shard_wg)
    part = _programs((wg_cat, n1, sampled, kind, use_kernel,
                      f64))["stages"]["partition"]
    tape_specs = {k: P(_AXIS) for k in _TAPE_KEYS}
    rep_specs = {k: P() for k in _REP_KEYS}

    def shard_body(tape, rep):
        d = {k: v[0] for k, v in tape.items()}      # drop the block axis
        d.update(rep)
        dist = per["count"](d)
        edges_p, hgt_p, kcnt, urd = per["curve"](d, dist)
        wflag = ((dist >= 0) & (~d["gread"])).astype(idt)
        wcnt = jnp.zeros(n1, idt).at[d["gtid"]].add(wflag)
        # integer summaries reduce exactly; the curve store stays device-
        # resident and only concatenates for the replicated walk
        return (lax.all_gather(edges_p, _AXIS).reshape(-1),
                lax.all_gather(hgt_p, _AXIS).reshape(-1),
                lax.psum(kcnt, _AXIS), lax.psum(urd, _AXIS),
                lax.psum(wcnt, _AXIS))

    smap = shard_map(shard_body, mesh=mesh,
                     in_specs=(tape_specs, rep_specs),
                     out_specs=(P(),) * 5, check_rep=False)

    def monitor_core(tape, rep):
        edges_c, hgt_c, kcnt, urd, wcnt = smap(tape, rep)
        wr = wcnt / rep["wr_den"]                   # one division, exact
        return edges_c, hgt_c, kcnt[:n], urd[:n], wr[:n]

    def decision_core(tape, rep, geo, p):
        edges_c, hgt_c, kcnt, urd, wcnt = smap(tape, rep)
        wr = wcnt / rep["wr_den"]
        # the single replicated step: budget cut + envelope walk over the
        # gathered store — bit-identical grant order to the host walk
        sizes, h_at, lat, feas = part(geo, edges_c, hgt_c, kcnt, urd, p)
        return (edges_c, hgt_c, kcnt[:n], urd[:n], wr[:n],
                sizes[:n], h_at[:n], lat, feas)

    progs = {"monitor": jax.jit(monitor_core),
             "decision": jax.jit(decision_core)}
    _SHARD_PROGRAMS[key] = progs
    return progs


# --------------------------------------------------------------- dispatch
def _dispatch_monitor_sharded(ing: ShardIngest,
                              profile: StageProfile | None,
                              sanitize: bool = False):
    progs = _shard_programs(ing.key)
    with transfer_sanitizer(sanitize), _x64(ing.f64):
        with _pstage(profile, "dispatch"):
            return progs["monitor"](ing.dev["tape"], ing.dev["rep"])


def dispatch_decision_sharded(ing: ShardIngest, params: dict,
                              profile: StageProfile | None = None,
                              sanitize: bool = False):
    """Launch the fused sharded decision (DeviceWindowPipeline backend).

    ``params`` are the single-device ``_params`` dict; the weights gain
    the trash slot (weight 0, so the pad tenant never contributes to the
    latency objective).  Always fused — the sharded program has no staged
    per-launch mode (``StageProfile.staged`` is ignored here).
    """
    progs = _shard_programs(ing.key)
    p = dict(params)
    w = np.asarray(params["weights"])
    p["weights"] = np.concatenate([w, np.zeros(1, w.dtype)])
    with transfer_sanitizer(sanitize), _x64(ing.f64):
        if sanitize:
            # under the guard the numpy params must cross explicitly —
            # replicated over the mesh, or the launch would need a
            # (guarded) device-to-device broadcast
            p = jax.device_put(p, NamedSharding(ing.mesh, P()))
        with _pstage(profile, "dispatch"):
            return progs["decision"](ing.dev["tape"], ing.dev["rep"],
                                     ing.dev["geo"], p)


def monitor_window_sharded(addrs: np.ndarray, is_read: np.ndarray,
                           bounds: np.ndarray, n_accesses: np.ndarray, *,
                           mesh=None, rates: np.ndarray | None = None,
                           kind: str = "urd",
                           use_kernel: bool | None = None,
                           f64: bool | None = None,
                           profile: StageProfile | None = None,
                           launch_hook=None,
                           transfer_sanitize: bool = False):
    """Monitor outputs for one window, computed across the mesh.

    ``analyze_windows(pipeline="sharded")``'s backend; same signature
    and return contract as ``monitor_window_device`` plus ``mesh``
    (default: ``distributed.sharding.control_plane_mesh()`` over every
    local device).  One host sync per window per mesh (the fetch);
    bit-identical to the host monitor in f64 mode at any shard count.
    """
    if mesh is None:
        from repro.distributed.sharding import control_plane_mesh
        mesh = control_plane_mesh()
    n = int(np.asarray(bounds).shape[0]) - 1
    n_acc = np.maximum(np.asarray(n_accesses, np.int64), 1)
    ing = ingest_window_sharded(addrs, is_read, bounds, n_accesses,
                                mesh=mesh, rates=rates, kind=kind,
                                use_kernel=use_kernel, f64=f64,
                                profile=profile)
    if profile is not None:
        profile.windows += 1
    if launch_hook is not None:
        launch_hook()
    if ing is None:
        return _trivial_monitor(n, n_acc)
    out = _dispatch_monitor_sharded(ing, profile, sanitize=transfer_sanitize)
    edges_c, hgt_c, kcnt, urd, wr = _fetch(ing, out, profile,
                                           sanitize=transfer_sanitize)
    curves = BatchedHitRatioFunctions.from_padded(
        edges_c, hgt_c, kcnt, ing.row_start, ing.n_acc)
    return (curves, np.asarray(urd, np.int64), np.asarray(wr, np.float64),
            ing.cold)
