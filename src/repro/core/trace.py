"""Block-access trace representation and request-type classification.

The paper's entire analysis operates on an abstract trace of
``(tenant, block_address, is_read)`` events (its Monitor extracts exactly
this from blktrace).  In this framework the same events are emitted by the
paged-KV serving runtime (a "read" = re-use of a cached KV page, a "write" =
admission of a freshly computed page); the math below is identical.

Request-type taxonomy (paper §4, Fig. 6):

  first touch of an address:   CR (cold read) / CW (cold write)
  re-touch, classified by (previous type, current type):
      RAR  read  after read
      RAW  read  after write
      WAR  write after read
      WAW  write after write
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

__all__ = [
    "AccessClass",
    "Trace",
    "TraceError",
    "classify_accesses",
    "request_type_mix",
    "total_cache_writes_wb",
    "validate_trace",
    "validate_trace_arrays",
]


class TraceError(ValueError):
    """A malformed trace at the Monitor/manager ingest boundary.

    Carries the (tenant, window) coordinates of the offending tape so a
    thousand-tenant deployment's logs point at the culprit instead of a
    cryptic numpy/lax failure deep inside the counting pass.
    """

    def __init__(self, msg: str, tenant: int = -1, window: int = -1):
        self.tenant = int(tenant)
        self.window = int(window)
        super().__init__(f"{msg} (tenant={self.tenant}, window={self.window})")


def validate_trace_arrays(addrs, is_read, tenant: int = -1,
                          window: int = -1) -> None:
    """Validate one window tape's raw arrays; raise ``TraceError`` if bad.

    Checks (the full ingest contract): 1-D arrays of equal length, integer
    block addresses, non-negative addresses, op codes either bool or
    integers restricted to {0 (write), 1 (read)}.  Empty tapes are valid
    (an idle tenant-window).
    """
    a = np.asarray(addrs)
    r = np.asarray(is_read)
    if a.ndim != 1 or r.ndim != 1:
        raise TraceError("trace arrays must be 1-D", tenant, window)
    if a.shape != r.shape:
        raise TraceError(
            f"addrs length {a.shape[0]} != is_read length {r.shape[0]}",
            tenant, window)
    if not np.issubdtype(a.dtype, np.integer):
        raise TraceError(
            f"non-integer block addresses (dtype {a.dtype})", tenant, window)
    if a.size and int(a.min()) < 0:
        raise TraceError(
            f"negative block address {int(a.min())}", tenant, window)
    if r.dtype != np.bool_:
        if not np.issubdtype(r.dtype, np.integer):
            raise TraceError(
                f"op codes must be bool or {{0,1}} ints (dtype {r.dtype})",
                tenant, window)
        if r.size:
            bad = (r != 0) & (r != 1)
            if bad.any():
                raise TraceError(
                    f"unknown op code {int(r[bad][0])} (expected 0=write, "
                    f"1=read)", tenant, window)


def validate_trace(trace: "Trace", tenant: int = -1,
                   window: int = -1) -> None:
    """``validate_trace_arrays`` over a ``Trace`` (same raises)."""
    validate_trace_arrays(trace.addrs, trace.is_read, tenant, window)


class AccessClass(enum.IntEnum):
    """Per-access classification codes (stable ints: used in arrays)."""

    CR = 0   # cold read
    CW = 1   # cold write
    RAR = 2  # read after read
    RAW = 3  # read after write
    WAR = 4  # write after read
    WAW = 5  # write after write


@dataclasses.dataclass(frozen=True)
class Trace:
    """A single tenant's block-access trace.

    Attributes:
      addrs:    int64[n]  block addresses (opaque ids).
      is_read:  bool[n]   True = read, False = write.
      name:     workload label (e.g. ``wdev_0``).
    """

    addrs: np.ndarray
    is_read: np.ndarray
    name: str = ""

    def __post_init__(self) -> None:
        if self.addrs.shape != self.is_read.shape:
            raise ValueError(
                f"addrs {self.addrs.shape} vs is_read {self.is_read.shape}")
        if self.addrs.ndim != 1:
            raise ValueError("trace arrays must be 1-D")

    def __len__(self) -> int:
        return int(self.addrs.shape[0])

    @property
    def n_unique(self) -> int:
        return int(np.unique(self.addrs).size)

    def slice(self, start: int, stop: int) -> "Trace":
        return Trace(self.addrs[start:stop], self.is_read[start:stop], self.name)

    def concat(self, other: "Trace") -> "Trace":
        return Trace(
            np.concatenate([self.addrs, other.addrs]),
            np.concatenate([self.is_read, other.is_read]),
            self.name,
        )


def _prev_occurrence(addrs: np.ndarray) -> np.ndarray:
    """prev[i] = index of the previous access to addrs[i], or -1."""
    n = addrs.shape[0]
    prev = np.full(n, -1, dtype=np.int64)
    last: dict[int, int] = {}
    for i in range(n):
        a = int(addrs[i])
        p = last.get(a, -1)
        prev[i] = p
        last[a] = i
    return prev


def prev_next_occurrence(addrs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized prev/next occurrence indices per position.

    prev[i] = largest j < i with addrs[j] == addrs[i], else -1.
    nxt[j]  = smallest i > j with addrs[i] == addrs[j], else n.

    O(n log n) via stable argsort on (addr, position).
    """
    n = addrs.shape[0]
    order = np.argsort(addrs, kind="stable")  # groups equal addrs, pos asc
    sorted_addrs = addrs[order]
    same_as_prev = np.zeros(n, dtype=bool)
    same_as_prev[1:] = sorted_addrs[1:] == sorted_addrs[:-1]

    prev = np.full(n, -1, dtype=np.int64)
    # within each addr-group, prev of order[k] is order[k-1]
    prev[order[1:]] = np.where(same_as_prev[1:], order[:-1], -1)

    nxt = np.full(n, n, dtype=np.int64)
    same_as_next = np.zeros(n, dtype=bool)
    same_as_next[:-1] = sorted_addrs[1:] == sorted_addrs[:-1]
    nxt[order[:-1]] = np.where(same_as_next[:-1], order[1:], n)
    return prev, nxt


def classify_accesses(trace: Trace) -> np.ndarray:
    """Return AccessClass code per access (paper Fig. 6 taxonomy)."""
    prev, _ = prev_next_occurrence(trace.addrs)
    is_read = trace.is_read
    cold = prev < 0
    prev_read = np.zeros(len(trace), dtype=bool)
    hot = ~cold
    prev_read[hot] = is_read[prev[hot]]

    out = np.empty(len(trace), dtype=np.int64)
    out[cold & is_read] = AccessClass.CR
    out[cold & ~is_read] = AccessClass.CW
    out[hot & is_read & prev_read] = AccessClass.RAR
    out[hot & is_read & ~prev_read] = AccessClass.RAW
    out[hot & ~is_read & prev_read] = AccessClass.WAR
    out[hot & ~is_read & ~prev_read] = AccessClass.WAW
    return out


def request_type_mix(trace: Trace) -> dict[str, float]:
    """Fraction of each AccessClass in the trace (paper Fig. 12)."""
    codes = classify_accesses(trace)
    n = max(len(trace), 1)
    return {c.name: float(np.sum(codes == c)) / n for c in AccessClass}


def total_cache_writes_wb(trace: Trace) -> int:
    """Paper Eq. 3: TotalWrites = CR + CW + WAR + WAW under the WB policy.

    Every cold access installs a block (1 SSD write); every write re-touch
    modifies a cached block (1 SSD write).  RAR/RAW re-touches are pure reads.
    """
    codes = classify_accesses(trace)
    mask = np.isin(codes, [AccessClass.CR, AccessClass.CW,
                           AccessClass.WAR, AccessClass.WAW])
    return int(np.sum(mask))
