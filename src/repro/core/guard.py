"""Decision guard: hard invariants every ``AnalyzerDecision`` must satisfy.

The Analyzer's outputs drive real resizes and policy flips; a corrupted
monitor pass (NaN curves, a poisoned tape that slipped through, a solver
returning garbage) must never be *actuated*.  ``validate_decision`` checks
the invariants below and returns a ``GuardReport``; a fault-tolerant
``ECICacheManager`` quarantines any violating decision (re-applying the
last-known-good allocation) instead of actuating it, and a fault-intolerant
one counts the violation (``guard_violations_actuated``) so silent garbage
still shows up in ``summary()``.

Invariants (tentpole spec):

  * every L1 size is finite and >= 0, and Σ sizes  <= capacity;
  * every L2 size is finite and >= 0, and Σ sizes2 <= capacity2
    (checked only when a second level exists);
  * per-tenant ``c_min`` floors hold — ``floors[i] = min(c_min, urd_i)``,
    checked only when the floors themselves fit the partitioned budget
    (``floor_budget``): under scale-down (minimums do not fit) or a tier
    outage the floors are definitionally unsatisfiable and are skipped;
  * the partition objective (Eq. 2 latency) and hit ratios are finite,
    hit ratios within [0, 1];
  * every policy is a ``WritePolicy`` member (WB/WT/RO).

The guard is pure and cheap (a handful of vector reductions); the manager
runs it on *every* analyze, fault-tolerant or not.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.write_policy import WritePolicy

__all__ = ["GuardReport", "validate_decision"]


@dataclasses.dataclass(frozen=True)
class GuardReport:
    """Outcome of one decision validation: empty ``violations`` = pass."""

    violations: tuple[str, ...] = ()

    @property
    def ok(self) -> bool:
        return not self.violations


def _check_level(v: list[str], sizes, capacity: int, tag: str) -> None:
    fs = np.asarray(sizes, dtype=np.float64)
    if fs.size == 0:
        return
    if not np.all(np.isfinite(fs)):
        v.append(f"non-finite {tag} size")
        return
    if float(fs.min()) < 0:
        v.append(f"negative {tag} size")
    if float(fs.sum()) > capacity + 0.5:
        v.append(f"{tag} sizes exceed capacity "
                 f"({int(fs.sum())} > {int(capacity)})")


def _check_policies(v: list[str], policies, tag: str) -> None:
    if policies is None:
        return
    for p in policies:
        if not isinstance(p, WritePolicy):
            v.append(f"invalid {tag} policy {p!r}")
            return


def validate_decision(decision, capacity: int, capacity2: int = 0,
                      floors: np.ndarray | None = None,
                      floor_budget: int | None = None) -> GuardReport:
    """Validate one ``AnalyzerDecision`` against the hard invariants.

    ``floors`` (optional, aligned with ``decision.sizes``) carries the
    per-tenant minimums ``min(c_min, urd_i)`` — zero for tenants the floor
    does not apply to (inactive, held, not analyzed).  ``floor_budget`` is
    the capacity the partitioner actually had (defaults to ``capacity``);
    floors are only enforced when they fit it.
    """
    v: list[str] = []
    _check_level(v, decision.sizes, int(capacity), "L1")
    if capacity2 > 0 and decision.sizes2 is not None:
        _check_level(v, decision.sizes2, int(capacity2), "L2")
    _check_policies(v, decision.policies, "L1")
    if capacity2 > 0:
        _check_policies(v, decision.policies2, "L2")

    part = decision.partition
    if part is not None:
        if not np.isfinite(float(part.latency)):
            v.append("non-finite partition latency")
        hr = np.asarray(part.hit_ratios, dtype=np.float64)
        if hr.size and not np.all(np.isfinite(hr)):
            v.append("non-finite hit ratios")
        elif hr.size and (float(hr.min()) < -1e-9
                          or float(hr.max()) > 1.0 + 1e-9):
            v.append("hit ratios outside [0, 1]")

    if floors is not None and not v:
        fl = np.asarray(floors, dtype=np.float64)
        budget = int(capacity if floor_budget is None else floor_budget)
        if fl.size and float(fl.min()) < 0:
            # floors derive from min(c_min, urd_i): a negative floor means
            # the monitor reported a negative URD size — corrupt output
            v.append("negative c_min floor (corrupt URD size)")
        elif float(fl.sum()) <= budget:
            fs = np.asarray(decision.sizes, dtype=np.float64)
            short = np.flatnonzero(fs < fl - 0.5)
            if short.size:
                v.append(f"c_min floor violated for tenants "
                         f"{short.tolist()}")
    return GuardReport(tuple(v))
