"""Trace-driven LRU cache simulator — the measurement substrate for every
paper table/figure reproduction.

Simulates one tenant's partition of the fast tier (LRU replacement, paper's
EnhanceIO-like allocate-on-miss behaviour, Fig. 7 flowchart) under a write
policy, and reports:

  * read hits / read accesses (cache hit ratio — paper defines hits for reads)
  * cache writes (endurance metric, Eq. 3 semantics)
  * mean service latency given (t_fast, t_slow)

Latency model (paper §5.1): read hit -> t_fast; read miss -> t_slow (+install
write to the fast tier, not on the critical path); writes under WB -> t_fast;
writes that bypass the fast tier (RO/WT) -> t_write_bypass.  On the paper's
testbed the HDD RAID sits behind a battery-backed controller write cache, so
bypassed writes are acknowledged far faster than a random HDD read —
t_write_bypass defaults to 1.2*t_fast, not t_slow.  Optionally, dirty evictions
charge ``flush_cost`` each (write-back flush competing with foreground I/O —
the effect behind the paper's Fig. 3 observation).

Dirty-state semantics: WB writes dirty the cached block; WT writes propagate
synchronously so the cached copy is always *clean* after a write; RO writes
invalidate (and drop the dirty flag of) any cached copy.  The ``c_dirty``
shadow map mirrors the LRU's own flags exactly — evictions from every insert
path and RO invalidations pop their entries, so no stale dirty flag survives
across long traces or policy switches on a persistent cache.

``simulate`` is the per-access oracle; ``repro.core.batch_sim`` replays the
same semantics vectorized for all tenants of a Δt window at once.

Two-level hierarchy (ETICA)
===========================

``simulate`` also interprets an exclusive **two-level** hierarchy — a vector
of ``(capacity, policy)`` levels — with ETICA semantics (Ahmadian et al.):

  * L1 hit: touch (global MRU).  L1-miss-L2-hit: the block is *promoted*
    (removed from L2, installed at L1's MRU; 1 L1 cache write) and served at
    ``t_fast2``.  Full miss: served at ``t_slow`` and installed into L1.
  * Every install into a full L1 *demotes* the L1 victim into L2's MRU
    (1 L2 cache write); eviction from L2 is final (dirty evictions charge
    ``flush_cost``).
  * Because every touch moves the block to the global MRU and every victim
    re-enters immediately below L1, the *union* of the two levels is a
    single LRU stack of ``C1 + C2`` blocks whose top ``C1`` entries are L1 —
    the Mattson property the batch engine exploits (one stack-distance
    array, two capacity thresholds).
  * Per-level write policy: ``policy`` (L1) governs write admission exactly
    as in the single-level scheme; ``policy2`` governs whether L2 accepts
    dirty blocks.  ``policy2 != WB`` keeps L2 *clean*: dirty victims are
    flushed at demotion time (charging ``flush_cost``) and enter L2 clean,
    so evictions from L2 never cost a write-back — the ETICA endurance
    argument for the flash level.  Any dirty blocks already in L2 when a
    clean policy takes effect are flushed up-front.
  * At replay start the hierarchy invariant "L1 full or L2 empty" is
    restored by ``rebalance_levels`` (the actuator growing L1 refills it
    from L2's MRU side; union recency order is unchanged).
  * Degenerate ``C1 == 0``: L2 is the single level (hits cost ``t_fast2``,
    installs/modifies count as L2 cache writes, ``policy2`` is moot).
    ``C2 == 0`` reduces bit-identically to the single-level scheme.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.core.trace import Trace
from repro.core.write_policy import WritePolicy

__all__ = ["SimResult", "LRUCache", "simulate", "rebalance_levels"]


@dataclasses.dataclass
class SimResult:
    reads: int = 0
    read_hits: int = 0             # reads served from L1 (the fast tier)
    writes: int = 0
    write_hits: int = 0            # writes that touched an L1-resident block
    cache_writes: int = 0          # L1 installs + in-place modifies (endurance)
    total_latency: float = 0.0
    capacity: int = 0
    policy: str = "wb"
    # ---- level-2 accounting (all zero for a single-level hierarchy) ----
    read_hits_l2: int = 0          # reads served from L2 (promotions)
    write_hits_l2: int = 0         # writes that touched an L2-resident block
    cache_writes_l2: int = 0       # demotions into L2 (+ direct L2 installs)
    capacity2: int = 0
    policy2: str = "wb"
    # 1 when the batch engine replayed this tenant-window through the
    # per-access interpreter.  Since the two-level eviction-token replay
    # (see batch_sim) this only happens for genuinely degenerate windows —
    # an empty window with two levels, or warm L2 content behind a dead
    # C2 <= 0 level; every RO window under pressure stays vectorized.
    # Telemetry only: CI asserts the counter stays 0 on the standard
    # two-level benchmark mixes.
    fallback: int = 0

    @property
    def n(self) -> int:
        return self.reads + self.writes

    @property
    def read_hit_ratio(self) -> float:
        return self.read_hits / self.reads if self.reads else 0.0

    @property
    def hit_ratio(self) -> float:
        """L1 read hits over all accesses (paper's h in Eq. 2)."""
        return self.read_hits / self.n if self.n else 0.0

    @property
    def hit_ratio_l2(self) -> float:
        """L2 read hits over all accesses (second-level h in ETICA Eq. 2)."""
        return self.read_hits_l2 / self.n if self.n else 0.0

    @property
    def union_hit_ratio(self) -> float:
        """Read hits anywhere in the hierarchy over all accesses."""
        return (self.read_hits + self.read_hits_l2) / self.n if self.n else 0.0

    @property
    def mean_latency(self) -> float:
        return self.total_latency / self.n if self.n else 0.0

    @property
    def perf(self) -> float:
        """Performance = 1 / mean latency (IOPS-like)."""
        return 1.0 / self.mean_latency if self.mean_latency > 0 else 0.0

    @property
    def perf_per_cost(self) -> float:
        """Performance per allocated cache block (paper's perf-per-cost)."""
        return self.perf / self.capacity if self.capacity else 0.0


class LRUCache:
    """Minimal LRU set of block addresses with a capacity in blocks.

    Two interchangeable representations of the same state:

      * an ``OrderedDict`` (LRU -> MRU, addr -> dirty) driving the
        per-access interpreter paths (``_od``, materialized lazily);
      * a compact array pair set by the batch engine
        (``set_state_arrays``/``state_arrays``) so whole-window vectorized
        replay never pays per-entry dict churn.  ``resize`` shrinks the
        array form by slicing.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._od: OrderedDict[int, bool] = OrderedDict()
        self._addrs = None                       # int64[k], LRU -> MRU
        self._dirty = None                       # bool[k]

    def __getattr__(self, name):
        # materialize the dict form on first access after set_state_arrays
        # (__getattr__ only fires while "_od" is absent, so interpreter
        # paths pay plain-attribute cost afterwards)
        if name == "_od":
            od = OrderedDict(zip(self._addrs.tolist(), self._dirty.tolist()))
            self._addrs = self._dirty = None
            self._od = od
            return od
        raise AttributeError(name)

    def set_state_arrays(self, addrs, dirty) -> None:
        """Replace the whole state (LRU->MRU order) without dict churn."""
        self.__dict__.pop("_od", None)
        self._addrs = addrs
        self._dirty = dirty

    def state_arrays(self):
        """(addrs, dirty) LRU->MRU, without forcing the dict form."""
        if "_od" not in self.__dict__:
            return self._addrs, self._dirty
        k = len(self._od)
        return (np.fromiter(self._od.keys(), dtype=np.int64, count=k),
                np.fromiter(self._od.values(), dtype=bool, count=k))

    def __contains__(self, addr: int) -> bool:
        return addr in self._od

    def __len__(self) -> int:
        if "_od" not in self.__dict__:
            return int(self._addrs.shape[0])
        return len(self._od)

    def touch(self, addr: int) -> None:
        self._od.move_to_end(addr)

    def insert(self, addr: int, dirty: bool) -> int | None:
        """Insert/refresh; returns an evicted addr if one was displaced."""
        evicted = None
        if addr in self._od:
            self._od.move_to_end(addr)
            self._od[addr] = self._od[addr] or dirty
            return None
        if self.capacity <= 0:
            return None
        if len(self._od) >= self.capacity:
            evicted, _ = self._od.popitem(last=False)
        self._od[addr] = dirty
        return evicted

    def mark_dirty(self, addr: int) -> None:
        if addr in self._od:
            self._od[addr] = True
            self._od.move_to_end(addr)

    def mark_clean(self, addr: int) -> None:
        """Touch + clear dirty (a write-through made the copy current)."""
        if addr in self._od:
            self._od[addr] = False
            self._od.move_to_end(addr)

    def invalidate(self, addr: int) -> None:
        """Drop a cached block (RO write-around invalidation)."""
        self._od.pop(addr, None)

    def resize(self, capacity: int) -> list[int]:
        """Shrink/grow; returns evicted addrs (LRU-first) on shrink."""
        self.capacity = int(capacity)
        if "_od" not in self.__dict__:           # array form: slice LRU off
            k = int(self._addrs.shape[0]) - self.capacity
            if k <= 0:
                return []
            out = self._addrs[:k].tolist()
            self._addrs = self._addrs[k:]
            self._dirty = self._dirty[k:]
            return out
        out = []
        while len(self._od) > self.capacity:
            a, _ = self._od.popitem(last=False)
            out.append(a)
        return out


def rebalance_levels(c1: LRUCache, c2: LRUCache) -> None:
    """Restore the hierarchy invariant "L1 full or L2 empty".

    Promotes L2's MRU blocks into L1's LRU end until L1 is full or L2 is
    empty.  The union recency order is unchanged (the moved blocks sit
    directly below the old L1 content), so this is a pure re-labelling of
    which device holds each block — the actuator refilling the fast tier
    after growing it.  Both replay engines call this at window start so
    "L1 == top C1 of the union LRU stack" holds throughout the window.
    """
    need = c1.capacity - len(c1)
    if need <= 0 or len(c2) == 0:
        return
    a1, f1 = c1.state_arrays()
    a2, f2 = c2.state_arrays()
    k = min(need, int(a2.shape[0]))
    c1.set_state_arrays(np.concatenate([a2[-k:], a1]),
                        np.concatenate([f2[-k:], f1]))
    c2.set_state_arrays(a2[:-k].copy(), f2[:-k].copy())


def _simulate_two_level(trace: Trace, c1: LRUCache, c2: LRUCache,
                        policy: WritePolicy, policy2: WritePolicy,
                        t_fast: float, t_fast2: float, t_slow: float,
                        t_write_bypass: float, flush_cost: float) -> SimResult:
    """Per-access interpreter for the exclusive two-level hierarchy.

    The stateful oracle: promotion on L2 hit, demote-on-evict from L1 into
    L2, per-level write policies (``policy2 != WB`` keeps L2 clean by
    flushing dirty victims at demotion).  ``repro.core.batch_sim`` must
    reproduce this exactly (property-tested in ``tests/test_two_level.py``).
    """
    cap1, cap2 = c1.capacity, c2.capacity
    r = SimResult(capacity=cap1, policy=policy.value,
                  capacity2=cap2, policy2=policy2.value)
    rebalance_levels(c1, c2)
    clean2 = policy2 is not WritePolicy.WB and cap2 > 0 and cap1 > 0
    # dirty shadows mirror each level's own flags (survive eviction return)
    d1: dict[int, bool] = dict(c1._od)
    d2: dict[int, bool] = dict(c2._od)
    if clean2:
        # a clean L2 policy taking effect flushes any dirty L2 content
        for a, fl in c2._od.items():
            if fl:
                c2._od[a] = False
                d2[a] = False
                if flush_cost > 0.0:
                    r.total_latency += flush_cost

    def final_evict(addr: int, dirty: bool) -> None:
        if dirty and flush_cost > 0.0:
            r.total_latency += flush_cost

    def demote(addr: int, dirty: bool) -> None:
        """L1 victim displaced: push into L2's MRU (or evict for good)."""
        if cap2 <= 0:
            final_evict(addr, dirty)
            return
        if clean2 and dirty:
            if flush_cost > 0.0:
                r.total_latency += flush_cost
            dirty = False
        ev = c2.insert(addr, dirty)
        d2[addr] = dirty
        r.cache_writes_l2 += 1
        if ev is not None:
            final_evict(ev, d2.pop(ev, False))

    def install_l1(addr: int, dirty: bool) -> None:
        """Insert at the hierarchy's global MRU (caller ensured cap1 > 0)."""
        ev = c1.insert(addr, dirty)
        d1[addr] = dirty
        r.cache_writes += 1
        if ev is not None:
            demote(ev, d1.pop(ev, False))

    def install_top(addr: int, dirty: bool) -> None:
        if cap1 > 0:
            install_l1(addr, dirty)
        else:                                    # degenerate: L2 is the level
            ev = c2.insert(addr, dirty)
            d2[addr] = dirty
            r.cache_writes_l2 += 1
            if ev is not None:
                final_evict(ev, d2.pop(ev, False))

    captot = cap1 + cap2
    addrs, is_read = trace.addrs, trace.is_read
    for i in range(len(trace)):
        a = int(addrs[i])
        if is_read[i]:
            r.reads += 1
            if a in c1:
                r.read_hits += 1
                c1.touch(a)
                r.total_latency += t_fast
            elif a in c2:
                r.read_hits_l2 += 1
                r.total_latency += t_fast2
                if cap1 > 0:                     # promote on L2 hit
                    fl = d2.pop(a, False)
                    c2.invalidate(a)
                    install_l1(a, fl)
                else:                            # L2 is the only level
                    c2.touch(a)
            else:
                r.total_latency += t_slow
                if captot > 0:
                    install_top(a, False)
        else:
            r.writes += 1
            if policy is WritePolicy.WB:
                if a in c1:
                    r.write_hits += 1
                    c1.mark_dirty(a)
                    d1[a] = True
                    r.cache_writes += 1          # in-place modify
                    r.total_latency += t_fast
                elif a in c2:
                    r.write_hits_l2 += 1
                    if cap1 > 0:
                        d2.pop(a, None)
                        c2.invalidate(a)
                        install_l1(a, True)      # promote, dirtied by the write
                        r.total_latency += t_fast
                    else:
                        c2.mark_dirty(a)
                        d2[a] = True
                        r.cache_writes_l2 += 1
                        r.total_latency += t_fast2
                elif captot > 0:
                    install_top(a, True)
                    r.total_latency += (t_fast if cap1 > 0 else t_fast2)
                else:
                    r.total_latency += t_write_bypass
            elif policy is WritePolicy.WT:
                if a in c1:
                    r.write_hits += 1
                    c1.mark_clean(a)             # propagated synchronously
                    d1[a] = False
                    r.cache_writes += 1
                elif a in c2:
                    r.write_hits_l2 += 1
                    if cap1 > 0:
                        d2.pop(a, None)
                        c2.invalidate(a)
                        install_l1(a, False)     # promote clean
                    else:
                        c2.mark_clean(a)
                        d2[a] = False
                        r.cache_writes_l2 += 1
                elif captot > 0:
                    install_top(a, False)
                r.total_latency += t_write_bypass
            else:  # RO: write-around invalidates every cached copy
                if a in c1:
                    r.write_hits += 1
                    c1.invalidate(a)
                    d1.pop(a, None)
                elif a in c2:
                    r.write_hits_l2 += 1
                    c2.invalidate(a)
                    d2.pop(a, None)
                r.total_latency += t_write_bypass
    return r


def simulate(trace: Trace, capacity: int,
             policy: WritePolicy = WritePolicy.WB,
             t_fast: float = 1.0, t_slow: float = 20.0,
             t_write_bypass: float | None = None,
             flush_cost: float = 0.0,
             cache: LRUCache | None = None, *,
             capacity2: int = 0,
             policy2: WritePolicy = WritePolicy.WB,
             t_fast2: float | None = None,
             cache2: LRUCache | None = None) -> SimResult:
    """Replay ``trace`` against an LRU partition of ``capacity`` blocks.

    With ``capacity2 > 0`` (or a non-empty ``cache2``) the partition is an
    exclusive two-level hierarchy — see the module docstring.  With the
    default ``capacity2 == 0`` the single-level path below runs unchanged.
    """
    if t_write_bypass is None:
        t_write_bypass = 1.2 * t_fast
    if cache2 is not None or capacity2 > 0:
        c2 = cache2 if cache2 is not None else LRUCache(capacity2)
        if c2.capacity > 0 or len(c2) > 0:
            if t_fast2 is None:
                t_fast2 = 3.0 * t_fast
            c1 = cache if cache is not None else LRUCache(capacity)
            return _simulate_two_level(trace, c1, c2, policy, policy2,
                                       t_fast, t_fast2, t_slow,
                                       t_write_bypass, flush_cost)
    c = cache if cache is not None else LRUCache(capacity)
    cap = c.capacity
    r = SimResult(capacity=cap, policy=policy.value)

    def charge_flush(evicted: int | None) -> None:
        # always pop: c_dirty must mirror residency or stale dirty flags
        # leak across long traces / policy switches on a persistent cache
        if evicted is not None and c_dirty.pop(evicted, False) \
                and flush_cost > 0.0:
            r.total_latency += flush_cost

    # dirty tracking mirrors the LRU's own flags but survives eviction return
    c_dirty: dict[int, bool] = dict(c._od)
    addrs, is_read = trace.addrs, trace.is_read
    for i in range(len(trace)):
        a = int(addrs[i])
        if is_read[i]:
            r.reads += 1
            if a in c:
                r.read_hits += 1
                c.touch(a)
                r.total_latency += t_fast
            else:
                r.total_latency += t_slow
                if cap > 0:                    # allocate-on-read-miss install
                    charge_flush(c.insert(a, dirty=False))
                    c_dirty[a] = False
                    r.cache_writes += 1
        else:
            r.writes += 1
            if policy is WritePolicy.WB:
                if a in c:
                    r.write_hits += 1
                    c.mark_dirty(a)
                    c_dirty[a] = True
                    r.cache_writes += 1        # in-place modify
                    r.total_latency += t_fast
                elif cap > 0:
                    charge_flush(c.insert(a, dirty=True))   # allocate-on-write
                    c_dirty[a] = True
                    r.cache_writes += 1
                    r.total_latency += t_fast
                else:
                    r.total_latency += t_write_bypass
            elif policy is WritePolicy.WT:
                if a in c:
                    r.write_hits += 1
                    # write-through propagates synchronously: the cached
                    # copy is up to date with the backing store -> clean
                    # (marking it dirty would double-charge a later flush)
                    c.mark_clean(a)
                    c_dirty[a] = False
                    r.cache_writes += 1
                elif cap > 0:
                    charge_flush(c.insert(a, dirty=False))
                    c_dirty[a] = False
                    r.cache_writes += 1
                r.total_latency += t_write_bypass  # propagate synchronously
            else:  # RO: write-around — invalidate any stale cached copy
                if a in c:
                    r.write_hits += 1
                    c.invalidate(a)            # no SSD write
                    c_dirty.pop(a, None)       # drop its dirty flag too
                r.total_latency += t_write_bypass
    return r
