"""Trace-driven LRU cache simulator — the measurement substrate for every
paper table/figure reproduction.

Simulates one tenant's partition of the fast tier (LRU replacement, paper's
EnhanceIO-like allocate-on-miss behaviour, Fig. 7 flowchart) under a write
policy, and reports:

  * read hits / read accesses (cache hit ratio — paper defines hits for reads)
  * cache writes (endurance metric, Eq. 3 semantics)
  * mean service latency given (t_fast, t_slow)

Latency model (paper §5.1): read hit -> t_fast; read miss -> t_slow (+install
write to the fast tier, not on the critical path); writes under WB -> t_fast;
writes that bypass the fast tier (RO/WT) -> t_write_bypass.  On the paper's
testbed the HDD RAID sits behind a battery-backed controller write cache, so
bypassed writes are acknowledged far faster than a random HDD read —
t_write_bypass defaults to 1.2*t_fast, not t_slow.  Optionally, dirty evictions
under WB charge ``flush_cost`` each (write-back flush competing with
foreground I/O — the effect behind the paper's Fig. 3 observation).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

from repro.core.trace import Trace
from repro.core.write_policy import WritePolicy

__all__ = ["SimResult", "LRUCache", "simulate"]


@dataclasses.dataclass
class SimResult:
    reads: int = 0
    read_hits: int = 0
    writes: int = 0
    write_hits: int = 0            # writes that touched a resident block
    cache_writes: int = 0          # installs + in-place modifies (endurance)
    total_latency: float = 0.0
    capacity: int = 0
    policy: str = "wb"

    @property
    def n(self) -> int:
        return self.reads + self.writes

    @property
    def read_hit_ratio(self) -> float:
        return self.read_hits / self.reads if self.reads else 0.0

    @property
    def hit_ratio(self) -> float:
        """Read hits over all accesses (paper's h in Eq. 2)."""
        return self.read_hits / self.n if self.n else 0.0

    @property
    def mean_latency(self) -> float:
        return self.total_latency / self.n if self.n else 0.0

    @property
    def perf(self) -> float:
        """Performance = 1 / mean latency (IOPS-like)."""
        return 1.0 / self.mean_latency if self.mean_latency > 0 else 0.0

    @property
    def perf_per_cost(self) -> float:
        """Performance per allocated cache block (paper's perf-per-cost)."""
        return self.perf / self.capacity if self.capacity else 0.0


class LRUCache:
    """Minimal LRU set of block addresses with a capacity in blocks."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._od: OrderedDict[int, bool] = OrderedDict()  # addr -> dirty

    def __contains__(self, addr: int) -> bool:
        return addr in self._od

    def __len__(self) -> int:
        return len(self._od)

    def touch(self, addr: int) -> None:
        self._od.move_to_end(addr)

    def insert(self, addr: int, dirty: bool) -> int | None:
        """Insert/refresh; returns an evicted addr if one was displaced."""
        evicted = None
        if addr in self._od:
            self._od.move_to_end(addr)
            self._od[addr] = self._od[addr] or dirty
            return None
        if self.capacity <= 0:
            return None
        if len(self._od) >= self.capacity:
            evicted, _ = self._od.popitem(last=False)
        self._od[addr] = dirty
        return evicted

    def mark_dirty(self, addr: int) -> None:
        if addr in self._od:
            self._od[addr] = True
            self._od.move_to_end(addr)

    def resize(self, capacity: int) -> list[int]:
        """Shrink/grow; returns evicted addrs (LRU-first) on shrink."""
        self.capacity = int(capacity)
        out = []
        while len(self._od) > self.capacity:
            a, _ = self._od.popitem(last=False)
            out.append(a)
        return out


def simulate(trace: Trace, capacity: int,
             policy: WritePolicy = WritePolicy.WB,
             t_fast: float = 1.0, t_slow: float = 20.0,
             t_write_bypass: float | None = None,
             flush_cost: float = 0.0,
             cache: LRUCache | None = None) -> SimResult:
    """Replay ``trace`` against an LRU partition of ``capacity`` blocks."""
    if t_write_bypass is None:
        t_write_bypass = 1.2 * t_fast
    c = cache if cache is not None else LRUCache(capacity)
    cap = c.capacity
    r = SimResult(capacity=cap, policy=policy.value)

    def charge_flush(evicted: int | None) -> None:
        if evicted is not None and flush_cost > 0.0 and c_dirty.pop(evicted, False):
            r.total_latency += flush_cost

    # dirty tracking mirrors the LRU's own flags but survives eviction return
    c_dirty: dict[int, bool] = dict(c._od)
    addrs, is_read = trace.addrs, trace.is_read
    for i in range(len(trace)):
        a = int(addrs[i])
        if is_read[i]:
            r.reads += 1
            if a in c:
                r.read_hits += 1
                c.touch(a)
                r.total_latency += t_fast
            else:
                r.total_latency += t_slow
                if cap > 0:                    # allocate-on-read-miss install
                    charge_flush(c.insert(a, dirty=False))
                    c_dirty[a] = False
                    r.cache_writes += 1
        else:
            r.writes += 1
            if policy is WritePolicy.WB:
                if a in c:
                    r.write_hits += 1
                    c.mark_dirty(a)
                    c_dirty[a] = True
                    r.cache_writes += 1        # in-place modify
                    r.total_latency += t_fast
                elif cap > 0:
                    charge_flush(c.insert(a, dirty=True))   # allocate-on-write
                    c_dirty[a] = True
                    r.cache_writes += 1
                    r.total_latency += t_fast
                else:
                    r.total_latency += t_write_bypass
            elif policy is WritePolicy.WT:
                if a in c:
                    r.write_hits += 1
                    c.mark_dirty(a)
                    r.cache_writes += 1
                elif cap > 0:
                    c.insert(a, dirty=False)
                    r.cache_writes += 1
                r.total_latency += t_write_bypass  # propagate synchronously
            else:  # RO: write-around — invalidate any stale cached copy
                if a in c:
                    r.write_hits += 1
                    c._od.pop(a, None)         # invalidate (no SSD write)
                r.total_latency += t_write_bypass
    return r
