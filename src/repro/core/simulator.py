"""Trace-driven LRU cache simulator — the measurement substrate for every
paper table/figure reproduction.

Simulates one tenant's partition of the fast tier (LRU replacement, paper's
EnhanceIO-like allocate-on-miss behaviour, Fig. 7 flowchart) under a write
policy, and reports:

  * read hits / read accesses (cache hit ratio — paper defines hits for reads)
  * cache writes (endurance metric, Eq. 3 semantics)
  * mean service latency given (t_fast, t_slow)

Latency model (paper §5.1): read hit -> t_fast; read miss -> t_slow (+install
write to the fast tier, not on the critical path); writes under WB -> t_fast;
writes that bypass the fast tier (RO/WT) -> t_write_bypass.  On the paper's
testbed the HDD RAID sits behind a battery-backed controller write cache, so
bypassed writes are acknowledged far faster than a random HDD read —
t_write_bypass defaults to 1.2*t_fast, not t_slow.  Optionally, dirty evictions
charge ``flush_cost`` each (write-back flush competing with foreground I/O —
the effect behind the paper's Fig. 3 observation).

Dirty-state semantics: WB writes dirty the cached block; WT writes propagate
synchronously so the cached copy is always *clean* after a write; RO writes
invalidate (and drop the dirty flag of) any cached copy.  The ``c_dirty``
shadow map mirrors the LRU's own flags exactly — evictions from every insert
path and RO invalidations pop their entries, so no stale dirty flag survives
across long traces or policy switches on a persistent cache.

``simulate`` is the per-access oracle; ``repro.core.batch_sim`` replays the
same semantics vectorized for all tenants of a Δt window at once.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.core.trace import Trace
from repro.core.write_policy import WritePolicy

__all__ = ["SimResult", "LRUCache", "simulate"]


@dataclasses.dataclass
class SimResult:
    reads: int = 0
    read_hits: int = 0
    writes: int = 0
    write_hits: int = 0            # writes that touched a resident block
    cache_writes: int = 0          # installs + in-place modifies (endurance)
    total_latency: float = 0.0
    capacity: int = 0
    policy: str = "wb"

    @property
    def n(self) -> int:
        return self.reads + self.writes

    @property
    def read_hit_ratio(self) -> float:
        return self.read_hits / self.reads if self.reads else 0.0

    @property
    def hit_ratio(self) -> float:
        """Read hits over all accesses (paper's h in Eq. 2)."""
        return self.read_hits / self.n if self.n else 0.0

    @property
    def mean_latency(self) -> float:
        return self.total_latency / self.n if self.n else 0.0

    @property
    def perf(self) -> float:
        """Performance = 1 / mean latency (IOPS-like)."""
        return 1.0 / self.mean_latency if self.mean_latency > 0 else 0.0

    @property
    def perf_per_cost(self) -> float:
        """Performance per allocated cache block (paper's perf-per-cost)."""
        return self.perf / self.capacity if self.capacity else 0.0


class LRUCache:
    """Minimal LRU set of block addresses with a capacity in blocks.

    Two interchangeable representations of the same state:

      * an ``OrderedDict`` (LRU -> MRU, addr -> dirty) driving the
        per-access interpreter paths (``_od``, materialized lazily);
      * a compact array pair set by the batch engine
        (``set_state_arrays``/``state_arrays``) so whole-window vectorized
        replay never pays per-entry dict churn.  ``resize`` shrinks the
        array form by slicing.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._od: OrderedDict[int, bool] = OrderedDict()
        self._addrs = None                       # int64[k], LRU -> MRU
        self._dirty = None                       # bool[k]

    def __getattr__(self, name):
        # materialize the dict form on first access after set_state_arrays
        # (__getattr__ only fires while "_od" is absent, so interpreter
        # paths pay plain-attribute cost afterwards)
        if name == "_od":
            od = OrderedDict(zip(self._addrs.tolist(), self._dirty.tolist()))
            self._addrs = self._dirty = None
            self._od = od
            return od
        raise AttributeError(name)

    def set_state_arrays(self, addrs, dirty) -> None:
        """Replace the whole state (LRU->MRU order) without dict churn."""
        self.__dict__.pop("_od", None)
        self._addrs = addrs
        self._dirty = dirty

    def state_arrays(self):
        """(addrs, dirty) LRU->MRU, without forcing the dict form."""
        if "_od" not in self.__dict__:
            return self._addrs, self._dirty
        k = len(self._od)
        return (np.fromiter(self._od.keys(), dtype=np.int64, count=k),
                np.fromiter(self._od.values(), dtype=bool, count=k))

    def __contains__(self, addr: int) -> bool:
        return addr in self._od

    def __len__(self) -> int:
        if "_od" not in self.__dict__:
            return int(self._addrs.shape[0])
        return len(self._od)

    def touch(self, addr: int) -> None:
        self._od.move_to_end(addr)

    def insert(self, addr: int, dirty: bool) -> int | None:
        """Insert/refresh; returns an evicted addr if one was displaced."""
        evicted = None
        if addr in self._od:
            self._od.move_to_end(addr)
            self._od[addr] = self._od[addr] or dirty
            return None
        if self.capacity <= 0:
            return None
        if len(self._od) >= self.capacity:
            evicted, _ = self._od.popitem(last=False)
        self._od[addr] = dirty
        return evicted

    def mark_dirty(self, addr: int) -> None:
        if addr in self._od:
            self._od[addr] = True
            self._od.move_to_end(addr)

    def mark_clean(self, addr: int) -> None:
        """Touch + clear dirty (a write-through made the copy current)."""
        if addr in self._od:
            self._od[addr] = False
            self._od.move_to_end(addr)

    def invalidate(self, addr: int) -> None:
        """Drop a cached block (RO write-around invalidation)."""
        self._od.pop(addr, None)

    def resize(self, capacity: int) -> list[int]:
        """Shrink/grow; returns evicted addrs (LRU-first) on shrink."""
        self.capacity = int(capacity)
        if "_od" not in self.__dict__:           # array form: slice LRU off
            k = int(self._addrs.shape[0]) - self.capacity
            if k <= 0:
                return []
            out = self._addrs[:k].tolist()
            self._addrs = self._addrs[k:]
            self._dirty = self._dirty[k:]
            return out
        out = []
        while len(self._od) > self.capacity:
            a, _ = self._od.popitem(last=False)
            out.append(a)
        return out


def simulate(trace: Trace, capacity: int,
             policy: WritePolicy = WritePolicy.WB,
             t_fast: float = 1.0, t_slow: float = 20.0,
             t_write_bypass: float | None = None,
             flush_cost: float = 0.0,
             cache: LRUCache | None = None) -> SimResult:
    """Replay ``trace`` against an LRU partition of ``capacity`` blocks."""
    if t_write_bypass is None:
        t_write_bypass = 1.2 * t_fast
    c = cache if cache is not None else LRUCache(capacity)
    cap = c.capacity
    r = SimResult(capacity=cap, policy=policy.value)

    def charge_flush(evicted: int | None) -> None:
        # always pop: c_dirty must mirror residency or stale dirty flags
        # leak across long traces / policy switches on a persistent cache
        if evicted is not None and c_dirty.pop(evicted, False) \
                and flush_cost > 0.0:
            r.total_latency += flush_cost

    # dirty tracking mirrors the LRU's own flags but survives eviction return
    c_dirty: dict[int, bool] = dict(c._od)
    addrs, is_read = trace.addrs, trace.is_read
    for i in range(len(trace)):
        a = int(addrs[i])
        if is_read[i]:
            r.reads += 1
            if a in c:
                r.read_hits += 1
                c.touch(a)
                r.total_latency += t_fast
            else:
                r.total_latency += t_slow
                if cap > 0:                    # allocate-on-read-miss install
                    charge_flush(c.insert(a, dirty=False))
                    c_dirty[a] = False
                    r.cache_writes += 1
        else:
            r.writes += 1
            if policy is WritePolicy.WB:
                if a in c:
                    r.write_hits += 1
                    c.mark_dirty(a)
                    c_dirty[a] = True
                    r.cache_writes += 1        # in-place modify
                    r.total_latency += t_fast
                elif cap > 0:
                    charge_flush(c.insert(a, dirty=True))   # allocate-on-write
                    c_dirty[a] = True
                    r.cache_writes += 1
                    r.total_latency += t_fast
                else:
                    r.total_latency += t_write_bypass
            elif policy is WritePolicy.WT:
                if a in c:
                    r.write_hits += 1
                    # write-through propagates synchronously: the cached
                    # copy is up to date with the backing store -> clean
                    # (marking it dirty would double-charge a later flush)
                    c.mark_clean(a)
                    c_dirty[a] = False
                    r.cache_writes += 1
                elif cap > 0:
                    charge_flush(c.insert(a, dirty=False))
                    c_dirty[a] = False
                    r.cache_writes += 1
                r.total_latency += t_write_bypass  # propagate synchronously
            else:  # RO: write-around — invalidate any stale cached copy
                if a in c:
                    r.write_hits += 1
                    c.invalidate(a)            # no SSD write
                    c_dirty.pop(a, None)       # drop its dirty flag too
                r.total_latency += t_write_bypass
    return r
