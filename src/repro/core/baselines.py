"""Baseline cache-management schemes the paper compares against (§2).

  * ``centaur``        — state-of-the-art dynamic partitioning [Koller+,
    ICAC'15]: TRD-based MRC sizing, Eq.-2-style optimization when infeasible,
    WB policy everywhere.  (The paper's head-to-head baseline.)
  * ``static``         — equal static partitioning, WB (EMC VFCache-style).
  * ``global_share``   — one global LRU shared by all tenants, WB
    (Fusion-io ioTurbine-style).
  * ``reuse_intensity``— vCacheShare-like: partitions proportionally to each
    tenant's re-reference *intensity* (hit burstiness proxy), Write-Around
    (= RO) everywhere, matching vCacheShare's fixed policy.
  * ``eci``            — the paper's scheme (URD sizing + Alg. 3 policies).

All are thin configurations of ``ECICacheManager`` so every scheme shares
the identical simulator, latency model and accounting.
"""
from __future__ import annotations

import numpy as np

from repro.core.manager import ECICacheManager
from repro.core.mrc import HitRatioFunction
from repro.core.partitioner import PartitionResult, greedy_allocate
from repro.core.simulator import LRUCache, SimResult, simulate
from repro.core.trace import Trace
from repro.core.write_policy import WritePolicy

__all__ = ["make_manager", "GlobalLRUManager", "SCHEMES"]


def _static_partition(hs: list[HitRatioFunction], capacity: int,
                      t_fast: float, t_slow: float,
                      c_min: int = 0, weights=None) -> PartitionResult:
    """Equal static split; the ``capacity % n`` remainder blocks are
    granted deterministically to the first tenants (one each) instead of
    being silently dropped, so the full budget is always allocated."""
    n = max(len(hs), 1)
    share, rem = divmod(capacity, n)
    sizes = np.full(len(hs), share, dtype=np.int64)
    sizes[:rem] += 1
    from repro.core.partitioner import aggregate_latency
    return PartitionResult(
        sizes, False, aggregate_latency(hs, sizes, t_fast, t_slow, weights),
        np.array([h(int(s)) for h, s in zip(hs, sizes)]))


def _reuse_intensity_partition(hs: list[HitRatioFunction], capacity: int,
                               t_fast: float, t_slow: float,
                               c_min: int = 0, weights=None) -> PartitionResult:
    """Proportional to max achievable hit mass (reuse intensity proxy).

    Every tenant is floored at ``min(c_min, capacity // n)`` *before* the
    proportional split, and only the residual budget is divided by
    intensity (largest-remainder rounding, ties broken by tenant index) —
    so ``sum(sizes) == capacity`` exactly.  Clamping after the
    proportional floor used to let intensity-skewed mixes overshoot the
    budget (e.g. two tenants, capacity 10, c_min 5, intensities 99:1 →
    floors [9, 0] → clamped [9, 5] = 14 blocks).
    """
    n = len(hs)
    intensity = np.array([h.max_hit_ratio * h.n_accesses for h in hs], float)
    total = intensity.sum()
    if total <= 0 or n == 0:
        return _static_partition(hs, capacity, t_fast, t_slow, c_min, weights)
    cm = min(c_min, capacity // n)
    residual = capacity - cm * n
    raw = intensity / total * residual
    sizes = cm + np.floor(raw).astype(np.int64)
    residue = capacity - int(sizes.sum())         # < n floor leftovers
    if residue > 0:
        frac = raw - np.floor(raw)
        order = np.lexsort((np.arange(n), -frac))
        sizes[order[:residue]] += 1
    from repro.core.partitioner import aggregate_latency
    return PartitionResult(
        sizes, False, aggregate_latency(hs, sizes, t_fast, t_slow, weights),
        np.array([h(int(s)) for h, s in zip(hs, sizes)]))


def make_manager(scheme: str, capacity: int, tenant_names: list[str],
                 **kw) -> ECICacheManager:
    """Factory for every comparison scheme (same knobs as ECICacheManager).

    ``etica`` is the two-level configuration of the ECI scheme: pass
    ``capacity2`` (host-DRAM blocks) and optionally ``t_fast2`` /
    ``w_threshold2``; each tenant then owns an (L1, L2) hierarchy with
    per-level URD sizing and per-level write policies.
    """
    if scheme in ("eci", "etica"):
        if scheme == "etica" and int(kw.get("capacity2", 0)) <= 0:
            raise ValueError("scheme 'etica' needs capacity2 > 0")
        return ECICacheManager(capacity, tenant_names, rd_kind="urd",
                               adaptive_policy=True, **kw)
    if scheme == "centaur":
        return ECICacheManager(capacity, tenant_names, rd_kind="trd",
                               adaptive_policy=False, **kw)
    if scheme == "static":
        m = ECICacheManager(capacity, tenant_names, rd_kind="trd",
                            adaptive_policy=False,
                            partition_fn=_static_partition, **kw)
        return m
    if scheme == "reuse_intensity":
        m = ECICacheManager(capacity, tenant_names, rd_kind="trd",
                            adaptive_policy=False,
                            partition_fn=_reuse_intensity_partition, **kw)
        for t in m.tenants:           # vCacheShare uses Write-Around always
            t.policy = WritePolicy.RO
        return m
    raise ValueError(f"unknown scheme {scheme!r} (see SCHEMES)")


class GlobalLRUManager:
    """One shared LRU over all tenants (no partitioning, WB)."""

    def __init__(self, capacity: int, tenant_names: list[str],
                 t_fast: float = 1.0, t_slow: float = 20.0, **_):
        self.cache = LRUCache(capacity)
        self.capacity = capacity
        self.t_fast, self.t_slow = t_fast, t_slow
        self.results = [SimResult(capacity=capacity) for _ in tenant_names]

    def run_window(self, traces: list[Trace | None]) -> None:
        for i, tr in enumerate(traces):
            if tr is None:
                continue
            res = simulate(tr, self.cache.capacity, WritePolicy.WB,
                           self.t_fast, self.t_slow, cache=self.cache)
            agg = self.results[i]
            agg.reads += res.reads; agg.read_hits += res.read_hits
            agg.writes += res.writes; agg.cache_writes += res.cache_writes
            agg.total_latency += res.total_latency

    def summary(self) -> dict[str, float]:
        n = sum(r.n for r in self.results)
        lat = sum(r.total_latency for r in self.results)
        writes = sum(r.cache_writes for r in self.results)
        mean_lat = lat / n if n else 0.0
        return {
            "accesses": n, "mean_latency": mean_lat,
            "performance": 1.0 / mean_lat if mean_lat else 0.0,
            "cache_writes": writes, "allocated_blocks": self.capacity,
            "perf_per_cost": ((1.0 / mean_lat) / self.capacity
                              if mean_lat and self.capacity else 0.0),
            "read_hit_ratio": (sum(r.read_hits for r in self.results)
                               / max(sum(r.reads for r in self.results), 1)),
        }


SCHEMES = ("eci", "etica", "centaur", "static", "reuse_intensity", "global")
