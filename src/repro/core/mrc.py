"""Miss-ratio curves and the paper's hit-ratio step function H_i(c).

Paper Alg. 2: ``H_i(c)`` is a non-decreasing step function of cache size —
for an LRU cache of ``c`` blocks, an access with reuse distance ``d`` hits
iff ``d < c`` (Mattson stack inclusion).  The breakpoints ``m_1 < ... < m_k``
are the distinct observed reuse distances (+1), the plateau values ``h_k``
the cumulative fraction of accesses whose distance falls below each
breakpoint.

For URD-based curves the numerator counts only read re-uses (the useful
hits); the denominator is all accesses, matching the paper's use of ``h`` in
Eq. 2 (a latency-weighted mean over the whole request stream).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.reuse_distance import RDResult

__all__ = ["HitRatioFunction", "build_hit_ratio_function"]


@dataclasses.dataclass(frozen=True)
class HitRatioFunction:
    """Piecewise-constant h(c): h(c) = heights[k] for c in [edges[k], edges[k+1]).

    edges:   int64[k+1], edges[0] == 0, strictly increasing.
    heights: float64[k], non-decreasing, heights[-1] == max achievable hit
             ratio (at c >= edges[-1] the curve stays flat at heights[-1]).
    n_accesses: denominator used (for latency weighting across tenants).
    """

    edges: np.ndarray
    heights: np.ndarray
    n_accesses: int

    def __call__(self, c: float | np.ndarray) -> np.ndarray | float:
        c_arr = np.asarray(c)
        idx = np.searchsorted(self.edges, c_arr, side="right") - 1
        idx = np.clip(idx, 0, len(self.heights) - 1)
        out = self.heights[idx]
        out = np.where(c_arr <= 0, 0.0, out)
        return float(out) if np.isscalar(c) or c_arr.ndim == 0 else out

    @property
    def max_useful_size(self) -> int:
        """Smallest c achieving the maximum hit ratio (== URD-based size)."""
        return int(self.edges[-1])

    @property
    def max_hit_ratio(self) -> float:
        return float(self.heights[-1]) if len(self.heights) else 0.0

    def breakpoints(self) -> list[tuple[int, float]]:
        """(cache size, hit ratio) pairs at each step, for greedy allocation."""
        return [(int(e), float(h)) for e, h in zip(self.edges, self.heights)]

    def interp(self, c: np.ndarray) -> np.ndarray:
        """Piecewise-linear relaxation (for the smooth PGD solver)."""
        return np.interp(c, self.edges.astype(np.float64),
                         self.heights.astype(np.float64))

    def marginal_gain(self, c: int) -> tuple[int, float]:
        """From size c: (next breakpoint size, hit-ratio gain going there).

        Returns (c, 0.0) when the curve is already saturated.
        """
        k = int(np.searchsorted(self.edges, c, side="right"))
        if k >= len(self.edges):
            return c, 0.0
        nxt = int(self.edges[k])
        cur = self(c)
        return nxt, float(self.heights[min(k, len(self.heights) - 1)] - cur)

    def shifted(self, base: int) -> "HitRatioFunction":
        """Residual curve ``h~(c) = h(base + c) − h(base)``: level-2 input.

        For the exclusive two-level hierarchy the union behaves as one LRU
        stack, so with ``base`` L1 blocks already granted, ``c`` additional
        L2 blocks convert exactly the accesses with reuse distance in
        ``[base, base + c)`` into L2 hits.  The baseline ``h(base)`` (mass
        already captured by L1) is subtracted so the curve starts at 0 and
        marginal gains/densities are the true level-2 gains; the dropped
        constant does not affect the Eq.-2 argmax.  ``shifted(0) == self``.
        """
        base = max(int(base), 0)
        k = int(np.searchsorted(self.edges, base, side="right"))
        h0 = float(self(base)) if base > 0 else float(self.heights[0])
        edges = np.concatenate([[0], self.edges[k:] - base]).astype(np.int64)
        heights = np.concatenate([[0.0], self.heights[k:] - h0])
        return HitRatioFunction(edges, heights, self.n_accesses)


def build_hit_ratio_function(rd: RDResult, n_accesses: int | None = None,
                             max_size: int | None = None) -> HitRatioFunction:
    """Construct H(c) from reuse-distance samples.

    An access with sampled distance d hits an LRU cache of size c iff
    d + 1 <= c.  Cold accesses and (for URD) write re-touches never hit.
    """
    samples = rd.samples
    n = int(n_accesses if n_accesses is not None else rd.distances.shape[0])
    n = max(n, 1)
    if samples.size == 0:
        return HitRatioFunction(np.array([0], dtype=np.int64),
                                np.array([0.0]), n)
    if max_size is not None:
        samples = samples[samples + 1 <= max_size]
        if samples.size == 0:
            return HitRatioFunction(np.array([0], dtype=np.int64),
                                    np.array([0.0]), n)
    sizes, counts = np.unique(samples + 1, return_counts=True)
    heights = np.cumsum(counts) / n
    edges = np.concatenate([[0], sizes]).astype(np.int64)
    heights_full = np.concatenate([[0.0], heights])
    return HitRatioFunction(edges, heights_full, n)
