"""Miss-ratio curves and the paper's hit-ratio step function H_i(c).

Paper Alg. 2: ``H_i(c)`` is a non-decreasing step function of cache size —
for an LRU cache of ``c`` blocks, an access with reuse distance ``d`` hits
iff ``d < c`` (Mattson stack inclusion).  The breakpoints ``m_1 < ... < m_k``
are the distinct observed reuse distances (+1), the plateau values ``h_k``
the cumulative fraction of accesses whose distance falls below each
breakpoint.

For URD-based curves the numerator counts only read re-uses (the useful
hits); the denominator is all accesses, matching the paper's use of ``h`` in
Eq. 2 (a latency-weighted mean over the whole request stream).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.reuse_distance import RDResult

__all__ = ["HitRatioFunction", "BatchedHitRatioFunctions",
           "build_hit_ratio_function", "build_hit_ratio_functions"]


@dataclasses.dataclass(frozen=True)
class HitRatioFunction:
    """Piecewise-constant h(c): h(c) = heights[k] for c in [edges[k], edges[k+1]).

    edges:   int64[k+1], edges[0] == 0, strictly increasing.
    heights: float64[k], non-decreasing, heights[-1] == max achievable hit
             ratio (at c >= edges[-1] the curve stays flat at heights[-1]).
    n_accesses: denominator used (for latency weighting across tenants).
    """

    edges: np.ndarray
    heights: np.ndarray
    n_accesses: int

    def __call__(self, c: float | np.ndarray) -> np.ndarray | float:
        c_arr = np.asarray(c)
        idx = np.searchsorted(self.edges, c_arr, side="right") - 1
        idx = np.clip(idx, 0, len(self.heights) - 1)
        out = self.heights[idx]
        out = np.where(c_arr <= 0, 0.0, out)
        return float(out) if np.isscalar(c) or c_arr.ndim == 0 else out

    @property
    def max_useful_size(self) -> int:
        """Smallest c achieving the maximum hit ratio (== URD-based size)."""
        return int(self.edges[-1])

    @property
    def max_hit_ratio(self) -> float:
        return float(self.heights[-1]) if len(self.heights) else 0.0

    def breakpoints(self) -> list[tuple[int, float]]:
        """(cache size, hit ratio) pairs at each step, for greedy allocation."""
        return [(int(e), float(h)) for e, h in zip(self.edges, self.heights)]

    def interp(self, c: np.ndarray) -> np.ndarray:
        """Piecewise-linear relaxation (for the smooth PGD solver)."""
        return np.interp(c, self.edges.astype(np.float64),
                         self.heights.astype(np.float64))

    def marginal_gain(self, c: int) -> tuple[int, float]:
        """From size c: (next breakpoint size, hit-ratio gain going there).

        Returns (c, 0.0) when the curve is already saturated.
        """
        k = int(np.searchsorted(self.edges, c, side="right"))
        if k >= len(self.edges):
            return c, 0.0
        nxt = int(self.edges[k])
        cur = self(c)
        return nxt, float(self.heights[min(k, len(self.heights) - 1)] - cur)

    def shifted(self, base: int) -> "HitRatioFunction":
        """Residual curve ``h~(c) = h(base + c) − h(base)``: level-2 input.

        For the exclusive two-level hierarchy the union behaves as one LRU
        stack, so with ``base`` L1 blocks already granted, ``c`` additional
        L2 blocks convert exactly the accesses with reuse distance in
        ``[base, base + c)`` into L2 hits.  The baseline ``h(base)`` (mass
        already captured by L1) is subtracted so the curve starts at 0 and
        marginal gains/densities are the true level-2 gains; the dropped
        constant does not affect the Eq.-2 argmax.  ``shifted(0) == self``.
        """
        base = max(int(base), 0)
        k = int(np.searchsorted(self.edges, base, side="right"))
        h0 = float(self(base)) if base > 0 else float(self.heights[0])
        edges = np.concatenate([[0], self.edges[k:] - base]).astype(np.int64)
        heights = np.concatenate([[0.0], self.heights[k:] - h0])
        return HitRatioFunction(edges, heights, self.n_accesses)


def build_hit_ratio_function(rd: RDResult, n_accesses: int | None = None,
                             max_size: int | None = None) -> HitRatioFunction:
    """Construct H(c) from reuse-distance samples.

    An access with sampled distance d hits an LRU cache of size c iff
    d + 1 <= c.  Cold accesses and (for URD) write re-touches never hit.

    For SHARDS-sampled results (``rd.rate < 1``) each kept sample stands
    for ``1/rate`` accesses, so plateau heights are scaled back up
    (Horvitz–Thompson) and clipped at 1; the exact path (``rate == 1``)
    is numerically untouched.
    """
    samples = rd.samples
    n = int(n_accesses if n_accesses is not None else rd.distances.shape[0])
    n = max(n, 1)
    if samples.size == 0:
        return HitRatioFunction(np.array([0], dtype=np.int64),
                                np.array([0.0]), n)
    if max_size is not None:
        samples = samples[samples + 1 <= max_size]
        if samples.size == 0:
            return HitRatioFunction(np.array([0], dtype=np.int64),
                                    np.array([0.0]), n)
    sizes, counts = np.unique(samples + 1, return_counts=True)
    if rd.rate < 1.0:
        heights = np.minimum(np.cumsum(counts) / (n * rd.rate), 1.0)
    else:
        heights = np.cumsum(counts) / n
    edges = np.concatenate([[0], sizes]).astype(np.int64)
    heights_full = np.concatenate([[0.0], heights])
    return HitRatioFunction(edges, heights_full, n)


@dataclasses.dataclass(frozen=True)
class BatchedHitRatioFunctions:
    """N hit-ratio step curves backed by stacked breakpoint arrays.

    The fused monitor and the vectorized partitioner operate on this store
    directly — evaluation, residual shifting and breakpoint walks are single
    array programs over all tenants.  It also behaves as a read-only
    sequence of :class:`HitRatioFunction` views, so every legacy
    ``partition_fn`` (pgd, static, reuse-intensity) keeps working unchanged.

    Layout: curve ``i`` owns ``edges[offsets[i]:offsets[i+1]]`` (int64,
    starts at 0, strictly increasing) and the matching ``heights`` slice
    (same length: ``heights[k]`` is the plateau on ``[edges[k],
    edges[k+1])``).
    """

    edges: np.ndarray       # int64[M] concatenated breakpoint sizes
    heights: np.ndarray     # float64[M] concatenated plateau values
    offsets: np.ndarray     # int64[N+1] curve boundaries into edges/heights
    n_accesses: np.ndarray  # int64[N] per-curve denominators

    def __len__(self) -> int:
        return int(self.n_accesses.shape[0])

    def __getitem__(self, i: int) -> HitRatioFunction:
        i = range(len(self))[int(i)]         # normalize negative indices
        o, o2 = int(self.offsets[i]), int(self.offsets[i + 1])
        return HitRatioFunction(self.edges[o:o2], self.heights[o:o2],
                                int(self.n_accesses[i]))

    def __iter__(self):
        for i in range(len(self)):
            yield self[i]

    @classmethod
    def from_curves(cls, hs) -> "BatchedHitRatioFunctions":
        """Stack a list of curves (no-op passthrough if already batched)."""
        if isinstance(hs, cls):
            return hs
        hs = list(hs)
        if not hs:
            return cls(np.zeros(0, np.int64), np.zeros(0, np.float64),
                       np.zeros(1, np.int64), np.zeros(0, np.int64))
        parts_e, parts_h = [], []
        for h in hs:
            e = np.asarray(h.edges, np.int64)
            v = np.asarray(h.heights, np.float64)
            if v.shape[0] < e.shape[0]:      # tolerate the k+1/k layout
                v = np.concatenate([v, np.repeat(v[-1:], e.shape[0] - v.shape[0])])
            parts_e.append(e)
            parts_h.append(v[:e.shape[0]])
        lens = np.array([p.shape[0] for p in parts_e], np.int64)
        offsets = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
        return cls(np.concatenate(parts_e), np.concatenate(parts_h), offsets,
                   np.array([h.n_accesses for h in hs], np.int64))

    @classmethod
    def from_padded(cls, edges_p: np.ndarray, heights_p: np.ndarray,
                    k: np.ndarray, row_start: np.ndarray,
                    n_accesses: np.ndarray) -> "BatchedHitRatioFunctions":
        """Stack curves out of a padded device curve store.

        The fused device window program (``core.device_pipeline``) leaves
        tenant ``i``'s ``k[i]`` breakpoints at
        ``edges_p[row_start[i] : row_start[i] + k[i]]`` (matching
        ``heights_p`` plateaus); this gathers them into the compact
        stacked layout, prepending each curve's 0-head exactly like
        ``build_hit_ratio_functions`` — bit-identical when the device
        program ran in f64.
        """
        k = np.asarray(k, np.int64)
        n = k.shape[0]
        off = np.concatenate([[0], np.cumsum(k + 1)]).astype(np.int64)
        edges = np.zeros(int(off[-1]), np.int64)
        heights = np.zeros(int(off[-1]), np.float64)
        total = int(k.sum())
        if total:
            rank = (np.arange(total, dtype=np.int64)
                    - np.repeat(np.cumsum(k) - k, k))
            src = np.repeat(np.asarray(row_start, np.int64), k) + rank
            dst = np.repeat(off[:-1] + 1, k) + rank
            edges[dst] = np.asarray(edges_p)[src]
            heights[dst] = np.asarray(heights_p)[src]
        return cls(edges, heights, off,
                   np.maximum(np.asarray(n_accesses, np.int64), 1))

    # ------------------------------------------------------------ queries
    @property
    def max_useful_sizes(self) -> np.ndarray:
        """int64[N]: each curve's smallest saturating size (URD sizes)."""
        return self.edges[self.offsets[1:] - 1]

    @property
    def max_hit_ratios(self) -> np.ndarray:
        return self.heights[self.offsets[1:] - 1]

    def _composite(self, queries: np.ndarray) -> np.ndarray:
        """Global insertion positions of per-curve queries (side='right')."""
        lens = np.diff(self.offsets)
        big = int(self.edges.max(initial=0)) + 2
        seg = np.repeat(np.arange(len(self), dtype=np.int64), lens)
        q = np.minimum(np.maximum(queries, 0), big - 1)
        return np.searchsorted(seg * big + self.edges,
                               np.arange(len(self), dtype=np.int64) * big + q,
                               side="right")

    def evaluate(self, sizes: np.ndarray) -> np.ndarray:
        """Vectorized ``h_i(sizes[i])`` for all curves — one searchsorted.

        Bit-identical to calling each :class:`HitRatioFunction` view (same
        index arithmetic, same stored plateau floats).
        """
        c = np.asarray(sizes)
        if len(self) == 0:
            return np.zeros(0, np.float64)
        lens = np.diff(self.offsets)
        idx = np.clip(self._composite(c) - 1 - self.offsets[:-1], 0, lens - 1)
        out = self.heights[self.offsets[:-1] + idx]
        return np.where(c <= 0, 0.0, out)

    def shifted(self, bases: np.ndarray) -> "BatchedHitRatioFunctions":
        """Vectorized residual curves ``h~_i(c) = h_i(base_i + c) − h_i(base_i)``.

        Matches ``HitRatioFunction.shifted`` per curve bit-for-bit (same
        searchsorted split, same float subtractions) — the level-2 stage of
        ``two_level_solve`` runs on this without any per-tenant loop.
        """
        b = np.maximum(np.asarray(bases, np.int64), 0)
        n = len(self)
        if n == 0:
            return self
        lens = np.diff(self.offsets)
        k = self._composite(b) - self.offsets[:-1]          # per-curve split
        h0 = np.where(b > 0, self.evaluate(b),
                      self.heights[self.offsets[:-1]])
        tail = lens - k                                      # kept breakpoints
        new_lens = tail + 1                                  # +1 for the 0 head
        new_off = np.concatenate([[0], np.cumsum(new_lens)]).astype(np.int64)
        edges = np.zeros(int(new_off[-1]), np.int64)
        heights = np.zeros(int(new_off[-1]), np.float64)
        total = int(tail.sum())
        if total:
            rank = (np.arange(total, dtype=np.int64)
                    - np.repeat(np.cumsum(tail) - tail, tail))
            src = np.repeat(self.offsets[:-1] + k, tail) + rank
            dst = np.repeat(new_off[:-1] + 1, tail) + rank
            edges[dst] = self.edges[src] - np.repeat(b, tail)
            heights[dst] = self.heights[src] - np.repeat(h0, tail)
        return BatchedHitRatioFunctions(edges, heights, new_off,
                                        self.n_accesses.copy())


def build_hit_ratio_functions(dist: np.ndarray, tid: np.ndarray,
                              n_tenants: int, n_accesses: np.ndarray,
                              rates: np.ndarray | None = None,
                              mask: np.ndarray | None = None
                              ) -> BatchedHitRatioFunctions:
    """Batched ``build_hit_ratio_function``: every tenant in one lexsort.

    ``dist`` holds all tenants' reuse-distance samples concatenated (-1 =
    no sample), ``tid`` the tenant id per position.  Per-(tenant, size)
    counts come from one lexsort + segmented reductions, so no per-tenant
    Python work happens; plateau heights are the same integer cumsums over
    the same denominators as the per-tenant constructor (bit-identical on
    the exact path).  ``rates`` (per-tenant SHARDS rates) switches the
    heights to the scaled-and-clipped sampled estimator.
    """
    n_acc = np.maximum(np.asarray(n_accesses, np.int64), 1)
    if mask is None:
        mask = dist >= 0            # callers may pass the sample mask
    s = dist[mask] + 1              # directly (e.g. URD = hot reads)
    t = tid[mask]
    if s.size:
        smax = int(s.max())
        if n_tenants * (smax + 1) < 2**62:
            # only the sorted (tenant, size) pairs matter, never the
            # permutation, so one SIMD value-sort of composite keys
            # replaces the lexsort (same (t, s) ordering, bit-identical
            # downstream; the guard keeps the key in int64 range)
            big = np.int64(smax + 1)
            ks = t * big + s
            if n_tenants * (smax + 1) < 2**31:
                ks = ks.astype(np.int32)     # halves the sort's traffic
            ks = np.sort(ks)
            ts = (ks // big).astype(np.int64)
            ss = ks.astype(np.int64) - ts * big
        else:
            order = np.lexsort((s, t))
            ss, ts = s[order], t[order]
        new = np.ones(ss.size, dtype=bool)
        new[1:] = (ss[1:] != ss[:-1]) | (ts[1:] != ts[:-1])
        uidx = np.flatnonzero(new)
        sizes_u, t_u = ss[uidx], ts[uidx]
        counts = np.diff(np.append(uidx, ss.size))
        csum = np.cumsum(counts)
        head = np.ones(t_u.size, dtype=bool)
        head[1:] = t_u[1:] != t_u[:-1]
        starts = np.flatnonzero(head)
        seg_lens = np.diff(np.append(starts, t_u.size))
        base = np.repeat(csum[starts] - counts[starts], seg_lens)
        cum_in = csum - base                  # within-tenant cumulative counts
    else:
        sizes_u = np.zeros(0, np.int64)
        t_u = np.zeros(0, np.int64)
        cum_in = np.zeros(0, np.int64)
        starts = np.zeros(0, np.int64)
        seg_lens = np.zeros(0, np.int64)
    k_per = np.bincount(t_u, minlength=n_tenants)
    off = np.concatenate([[0], np.cumsum(k_per + 1)]).astype(np.int64)
    edges = np.zeros(int(off[-1]), np.int64)
    heights = np.zeros(int(off[-1]), np.float64)
    if s.size:
        rank = (np.arange(t_u.size, dtype=np.int64)
                - np.repeat(starts, seg_lens))
        dst = off[t_u] + 1 + rank
        edges[dst] = sizes_u
        if rates is None:
            heights[dst] = cum_in / n_acc[t_u]
        else:
            r = np.asarray(rates, np.float64)
            heights[dst] = np.minimum(cum_in / (n_acc[t_u] * r[t_u]), 1.0)
    return BatchedHitRatioFunctions(edges, heights, off, n_acc)
