"""ECI-Cache core: URD/TRD analysis, MRC partitioning, write policies.

Public API re-exports for the paper's primary contribution.
"""
from repro.core.baselines import GlobalLRUManager, make_manager
from repro.core.batch_sim import (reuse_distances_fast,
                                  ro_token_replay_device,
                                  ro_token_replay_levels_device,
                                  simulate_batch, simulate_many,
                                  stack_distances)
from repro.core.characterize import (PhaseDetector, PhaseEvent,
                                     WindowFeatures, characterize_trace,
                                     characterize_windows)
from repro.core.device_pipeline import (DeviceWindowPipeline, StageProfile,
                                        WindowDecision, greedy_walk_device,
                                        monitor_window_device,
                                        transfer_sanitizer)
from repro.core.faults import (FAULT_KINDS, FaultPlan, FaultSpec,
                               InjectedFault)
from repro.core.guard import GuardReport, validate_decision
from repro.core.manager import (AnalyzerDecision, DegradeEvent,
                                ECICacheManager, ReconfigEvent, TenantState)
from repro.core.monitor import MonitorResult, analyze_windows
from repro.core.mrc import (BatchedHitRatioFunctions, HitRatioFunction,
                            build_hit_ratio_function,
                            build_hit_ratio_functions)
from repro.core.partitioner import (PartitionResult, aggregate_latency,
                                    greedy_allocate, pgd_solve,
                                    two_level_solve)
from repro.core.reuse_distance import (RDResult, auto_sample_rate, max_rd,
                                       reuse_distances,
                                       reuse_distances_vectorized,
                                       sampled_reuse_distances, shards_salt,
                                       urd_cache_blocks)
from repro.core.simulator import (LRUCache, SimResult, rebalance_levels,
                                  simulate)
from repro.core.trace import (AccessClass, Trace, TraceError,
                              classify_accesses, request_type_mix,
                              total_cache_writes_wb, validate_trace,
                              validate_trace_arrays)
from repro.core.write_policy import (WritePolicy, assign_write_policy,
                                     assign_write_policy_levels, write_ratio)

__all__ = [
    "AccessClass", "AnalyzerDecision", "BatchedHitRatioFunctions",
    "DegradeEvent", "DeviceWindowPipeline", "ECICacheManager",
    "FAULT_KINDS", "FaultPlan", "FaultSpec", "GlobalLRUManager",
    "GuardReport", "HitRatioFunction", "InjectedFault", "LRUCache",
    "MonitorResult", "PartitionResult",
    "PhaseDetector", "PhaseEvent", "RDResult", "ReconfigEvent", "SimResult",
    "StageProfile", "TenantState", "Trace", "TraceError", "WindowDecision",
    "WindowFeatures", "WritePolicy",
    "aggregate_latency",
    "analyze_windows", "assign_write_policy", "assign_write_policy_levels",
    "auto_sample_rate", "build_hit_ratio_function",
    "build_hit_ratio_functions", "characterize_trace",
    "characterize_windows", "classify_accesses",
    "greedy_allocate", "greedy_walk_device", "make_manager", "max_rd",
    "monitor_window_device", "pgd_solve",
    "rebalance_levels", "request_type_mix", "reuse_distances",
    "reuse_distances_fast", "reuse_distances_vectorized",
    "ro_token_replay_device", "ro_token_replay_levels_device",
    "sampled_reuse_distances", "shards_salt",
    "simulate", "simulate_batch", "simulate_many", "stack_distances",
    "total_cache_writes_wb", "transfer_sanitizer", "two_level_solve",
    "urd_cache_blocks",
    "validate_decision", "validate_trace", "validate_trace_arrays",
    "write_ratio",
]
