"""Cache-space partitioners solving the paper's Eq. 2.

    Latency_i(c_i) = h_i(c_i) * T_fast + (1 - h_i(c_i)) * T_slow
    minimize   sum_i w_i * Latency_i(c_i)
    subject to sum_i c_i <= C,    c_min <= c_i <= c_urd_i

The paper solves this with MATLAB ``fmincon`` on the (piecewise-constant)
hit-ratio functions.  We provide:

  * ``greedy_allocate``  — breakpoint greedy: H_i are step functions, so
    latency only improves at breakpoints; repeatedly granting the jump with
    the best latency-reduction *density* (Δlatency / Δblocks) is the classic
    MRC-partitioning procedure (Centaur's convex-hull walk).  Near-optimal:
    exact on the concave hull, with at most a one-breakpoint knapsack
    rounding gap at tight capacities.  Deterministic, no MATLAB.

    The default ``method="fast"`` is a *vectorized breakpoint walk*: every
    tenant's (Δh/Δc density, Δc) steps are materialized as arrays, each
    chain is reduced to its prefix-min density envelope (the order the heap
    consumes a chain: a cheap step blocks its better successors, so a
    chain's effective priority is the running minimum), one argsort merges
    all chains, and a prefix sum over Δc finds the budget cut — O(K log K)
    array work for K breakpoints total, no Python inner loop.  The grant
    order — hence the allocation — is **bit-identical** to the retained
    ``method="heap"`` oracle (property-tested), including the partial grant
    of the first step past the budget.
  * ``pgd_solve``        — projected-gradient descent in JAX on the
    piecewise-linear relaxation of H_i, with a Dykstra-style projection onto
    { sum c <= C } ∩ box.  This is the faithful "fmincon analog"; tests check
    it matches greedy within the relaxation gap.

Both return allocations in *blocks* (pages).  All entry points accept a
plain list of ``HitRatioFunction`` or the fused monitor's
``BatchedHitRatioFunctions`` store (stacked breakpoint arrays; zero-copy
for the vectorized paths).

``two_level_solve`` adds ETICA's second capacity constraint: level 1
(HBM blocks) is sized by the single-level problem, then level 2 (host-DRAM
blocks) solves the *same* Eq. 2 on the residual hit-ratio curves
``h~_i(c) = h_i(c1_i + c)`` with service time ``t_fast2`` — exact because
the exclusive hierarchy's union is one LRU stack (see ``batch_sim``), so
L2 hits are precisely the reuses in ``[c1_i, c1_i + c2_i)``.  With batched
curves the residual shift is vectorized too, so both levels stay on the
fast path.
"""
from __future__ import annotations

import dataclasses
import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mrc import BatchedHitRatioFunctions, HitRatioFunction

__all__ = ["PartitionResult", "greedy_allocate", "pgd_solve",
           "aggregate_latency", "two_level_solve"]


@dataclasses.dataclass(frozen=True)
class PartitionResult:
    sizes: np.ndarray          # int64[N] allocated blocks per tenant
    feasible: bool             # True iff sum(urd sizes) <= C (paper's term)
    latency: float             # aggregate objective value at `sizes`
    hit_ratios: np.ndarray     # float64[N] at `sizes`


def _hit_ratios_at(hs, sizes: np.ndarray) -> np.ndarray:
    """Vectorized ``[h_i(sizes_i)]`` via the stacked-curve store."""
    return BatchedHitRatioFunctions.from_curves(hs).evaluate(
        np.asarray(sizes))


def aggregate_latency(hs, sizes: np.ndarray,
                      t_fast: float, t_slow: float,
                      weights: np.ndarray | None = None) -> float:
    """Paper Eq. 2 objective at an allocation (vectorized over tenants)."""
    w = np.ones(len(hs)) if weights is None else np.asarray(weights, float)
    hr = _hit_ratios_at(hs, sizes)
    return float(np.sum(w * (hr * t_fast + (1.0 - hr) * t_slow)))


def greedy_allocate(hs, capacity: int,
                    t_fast: float, t_slow: float,
                    c_min: int = 0,
                    weights: np.ndarray | None = None,
                    method: str = "fast") -> PartitionResult:
    """Breakpoint-greedy partitioner (the discrete reference optimizer).

    Feasible case (paper Alg. 1 line 8): if the URD-based sizes all fit,
    allocate them outright.  Otherwise walk breakpoints by best
    Δlatency/Δblocks until capacity is exhausted.  ``method="fast"``
    (default) runs the vectorized breakpoint walk, ``"heap"`` the original
    one-pop-at-a-time loop — both produce bit-identical sizes (the heap is
    retained as the oracle in tests and for the partial-grant semantics
    reference).  ``"device"`` runs the fast walk's jitted ``lax`` port
    (``core.device_pipeline.greedy_walk_device``) — bit-identical to the
    host walk in its f64 mode, used standalone here and inlined by the
    fused device window program.
    """
    if method not in ("fast", "heap", "device"):
        raise ValueError(
            f"method must be 'fast', 'heap' or 'device', got {method!r}")
    n = len(hs)
    w = np.ones(n) if weights is None else np.asarray(weights, float)
    b = BatchedHitRatioFunctions.from_curves(hs)
    urd_sizes = b.max_useful_sizes.astype(np.int64)
    c_min_arr = np.minimum(np.full(n, c_min, dtype=np.int64), urd_sizes)

    if int(urd_sizes.sum()) <= capacity:
        sizes = urd_sizes
        return PartitionResult(
            sizes, True,
            aggregate_latency(b, sizes, t_fast, t_slow, w),
            b.evaluate(sizes))

    sizes = c_min_arr.copy()
    budget = capacity - int(sizes.sum())
    if budget < 0:  # even the minimums do not fit: scale the minimums down
        sizes = np.floor(c_min_arr * capacity / max(c_min_arr.sum(), 1)
                         ).astype(np.int64)
        budget = capacity - int(sizes.sum())

    gain = t_slow - t_fast  # latency saved per unit hit-ratio
    if method == "heap":
        sizes = _greedy_walk_heap(hs, sizes, budget, urd_sizes, w, gain)
    elif method == "device":
        from repro.core.device_pipeline import greedy_walk_device
        sizes = greedy_walk_device(b, sizes, budget, w, gain)
    else:
        sizes = _greedy_walk_fast(b, sizes, budget, w, gain)

    return PartitionResult(
        sizes, False,
        aggregate_latency(b, sizes, t_fast, t_slow, w),
        b.evaluate(sizes))


def _greedy_walk_heap(hs, sizes: np.ndarray, budget: int,
                      urd_sizes: np.ndarray, w: np.ndarray,
                      gain: float) -> np.ndarray:
    """The original heap inner loop: pop the densest next breakpoint,
    grant it, push the tenant's following step.  O(K log K) with Python
    constant factors — retained as the oracle for the fast walk."""
    n = len(hs)
    heap: list[tuple[float, int, int, int, float]] = []

    def push(i: int) -> None:
        nxt, dh = hs[i].marginal_gain(int(sizes[i]))
        dc = nxt - int(sizes[i])
        if dh > 0 and dc > 0 and nxt <= urd_sizes[i]:
            density = w[i] * dh * gain / dc
            heapq.heappush(heap, (-density, i, nxt, dc, dh))

    for i in range(n):
        push(i)
    while heap and budget > 0:
        _, i, nxt, dc, _ = heapq.heappop(heap)
        if nxt - int(sizes[i]) != dc:   # stale entry
            push(i)
            continue
        if dc > budget:                 # partial grant: no hit-ratio step is
            sizes[i] += budget          # crossed, but matches paper's diff
            budget = 0                  # term (maximize allocated space)
            break
        sizes[i] = nxt
        budget -= dc
        push(i)
    return sizes


def _greedy_walk_fast(b: BatchedHitRatioFunctions, sizes: np.ndarray,
                      budget: int, w: np.ndarray, gain: float) -> np.ndarray:
    """Vectorized replay of the heap walk (bit-identical grant order).

    Each tenant's chain of breakpoint steps must be consumed in curve
    order, so a step's effective priority under "always pop the densest
    head" is the prefix-min of densities along its chain; merging the
    chains by (envelope desc, tenant, step) reproduces the heap's pop
    sequence exactly (ties included: on equal density the heap compares
    the tenant index next, and a chain's better-than-envelope successors
    flush immediately after their blocking step either way).  A cumsum
    over Δc then finds the budget cut and the partial-grant step.
    """
    n = len(b)
    if budget <= 0 or n == 0:
        return sizes
    edges, heights, off = b.edges, b.heights, b.offsets
    lens = np.diff(off)
    # first step index per tenant (strictly above its current size)
    k0 = b._composite(sizes) - off[:-1]
    n_steps = np.maximum(lens - k0, 0)
    total = int(n_steps.sum())
    if total == 0:
        return sizes
    st_tid = np.repeat(np.arange(n, dtype=np.int64), n_steps)
    rank = (np.arange(total, dtype=np.int64)
            - np.repeat(np.cumsum(n_steps) - n_steps, n_steps))
    gk = off[st_tid] + k0[st_tid] + rank          # breakpoint per step
    h_cur0 = b.evaluate(sizes)                    # h at the starting sizes
    first = rank == 0
    dh = heights[gk] - np.where(first, h_cur0[st_tid], heights[gk - 1])
    dc = edges[gk] - np.where(first, sizes[st_tid], edges[gk - 1])
    # the heap stops a chain at its first non-improving step
    bad = (dh <= 0).astype(np.int64)
    cbad = np.cumsum(bad)
    seg0 = np.repeat(np.cumsum(n_steps) - n_steps, n_steps)
    valid = (cbad - cbad[seg0] + bad[seg0]) == 0
    if not valid.any():
        return sizes
    st_tid, rank = st_tid[valid], rank[valid]
    nxt_s, dc = edges[gk[valid]], dc[valid]
    d = w[st_tid] * dh[valid] * gain / dc         # heap's density, same ops
    # prefix-min envelope per chain (doubling scan: log K numpy passes)
    nv = d.shape[0]
    idx = np.arange(nv, dtype=np.int64)
    head = np.ones(nv, dtype=bool)
    head[1:] = st_tid[1:] != st_tid[:-1]
    first_idx = np.maximum.accumulate(np.where(head, idx, 0))
    e = d.copy()
    shift = 1
    while shift < nv:
        can = idx - shift >= first_idx
        prev_e = np.concatenate([np.full(shift, np.inf), e[:-shift]])
        e = np.where(can, np.minimum(e, prev_e), e)
        shift *= 2
    order = np.lexsort((rank, st_tid, -e))
    cum = np.cumsum(dc[order])
    n_full = int(np.searchsorted(cum, budget, side="right"))
    granted = order[:n_full]
    np.maximum.at(sizes, st_tid[granted], nxt_s[granted])
    rem = budget - (int(cum[n_full - 1]) if n_full else 0)
    if rem > 0 and n_full < nv:                   # partial-grant tail
        sizes[st_tid[order[n_full]]] += rem
    return sizes


def two_level_solve(hs: list[HitRatioFunction], capacity: int,
                    capacity2: int, t_fast: float, t_fast2: float,
                    t_slow: float, c_min: int = 0,
                    partition_fn=None,
                    weights: np.ndarray | None = None
                    ) -> tuple[PartitionResult, PartitionResult | None]:
    """Eq. 2 with per-level capacities and per-level service times.

    Stage 1 sizes L1 exactly as the single-level problem (``capacity2 == 0``
    therefore reproduces it bit-identically); stage 2 runs the same
    partitioner on the residual curves ``h_i.shifted(c1_i)`` against the
    level-2 budget with gain ``t_slow - t_fast2`` and no per-tenant
    minimum.  Returns ``(level1, level2)``; ``level2`` is ``None`` when
    ``capacity2 <= 0``.
    """
    fn = partition_fn if partition_fn is not None else pgd_solve
    # only forward weights when set: custom partition_fn callables predate
    # the weights kwarg and must keep working unchanged
    kw = {} if weights is None else {"weights": weights}
    p1 = fn(hs, capacity, t_fast, t_slow, c_min=c_min, **kw)
    if capacity2 <= 0:
        return p1, None
    if isinstance(hs, BatchedHitRatioFunctions):
        shifted = hs.shifted(p1.sizes)       # vectorized residual curves
    else:
        shifted = [h.shifted(int(s)) for h, s in zip(hs, p1.sizes)]
    p2 = fn(shifted, capacity2, t_fast2, t_slow, c_min=0, **kw)
    return p1, p2


def _project_capacity_box(c: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                          capacity: float, iters: int = 50) -> jnp.ndarray:
    """Project onto { lo <= c <= hi, sum(c) <= capacity } by bisection on the
    simplex Lagrange multiplier (exact for this polytope)."""
    c0 = jnp.clip(c, lo, hi)

    def over_budget(_c):
        return jnp.sum(_c) > capacity

    def bisect(_c):
        # find tau >= 0 with sum(clip(c - tau, lo, hi)) == capacity
        tau_lo = jnp.zeros(())
        tau_hi = jnp.max(c - lo) + 1.0

        def body(_, carry):
            tlo, thi = carry
            mid = 0.5 * (tlo + thi)
            s = jnp.sum(jnp.clip(c - mid, lo, hi))
            return jnp.where(s > capacity, mid, tlo), jnp.where(s > capacity, thi, mid)

        tlo, thi = jax.lax.fori_loop(0, iters, body, (tau_lo, tau_hi))
        return jnp.clip(c - 0.5 * (tlo + thi), lo, hi)

    return jax.lax.cond(over_budget(c0), bisect, lambda _c: _c, c0)


_TABLE_PTS = 128  # fixed interpolation-table width so jit caches per n


def _pgd_core(n: int, steps: int):
    """Build (and cache) the jitted PGD loop for n tenants."""

    @jax.jit
    def run(xs, ys, lo, hi, cap, w, t_fast, t_slow, lr):
        def interp_h(c):
            return jax.vmap(jnp.interp)(c, xs, ys)

        def objective(c):
            h = interp_h(c)
            return jnp.sum(w * (h * t_fast + (1.0 - h) * t_slow))

        grad_fn = jax.grad(objective)

        def body(_, c):
            g = grad_fn(c)
            c = c - lr * g / (jnp.linalg.norm(g) + 1e-9) * jnp.sqrt(float(n))
            return _project_capacity_box(c, lo, hi, cap)

        c0 = _project_capacity_box(hi * cap / (jnp.sum(hi) + 1e-9), lo, hi, cap)
        return jax.lax.fori_loop(0, steps, body, c0)

    return run


_PGD_CACHE: dict[tuple[int, int], object] = {}


def pgd_solve(hs: list[HitRatioFunction], capacity: int,
              t_fast: float, t_slow: float,
              c_min: int = 0, steps: int = 300, lr: float | None = None,
              weights: np.ndarray | None = None) -> PartitionResult:
    """Projected-gradient solver on the piecewise-linear relaxation (JAX).

    This is the faithful analog of the paper's MATLAB ``fmincon`` call: a
    first-order method on the *smoothed* MRC, with the exact projection onto
    { sum c <= C } ∩ box.  Like fmincon it works on the relaxation, so under
    capacity pressure it spreads the squeeze across tenants rather than
    walking exact breakpoints — reproducing the squeeze behaviour the paper
    reports for Centaur in infeasible states.  ``greedy_allocate`` is the
    exact (beyond-paper) discrete optimizer.
    """
    n = len(hs)
    w = np.ones(n) if weights is None else np.asarray(weights, float)
    urd_sizes = np.array([h.max_useful_size for h in hs], dtype=np.int64)
    if int(urd_sizes.sum()) <= capacity:
        sizes = urd_sizes
        return PartitionResult(
            sizes, True, aggregate_latency(hs, sizes, t_fast, t_slow, w),
            np.array([h(int(s)) for h, s in zip(hs, sizes)]))

    # Fixed-width piecewise-linear tables (resampled) so jit caches per n.
    xs = np.zeros((n, _TABLE_PTS), np.float32)
    ys = np.zeros((n, _TABLE_PTS), np.float32)
    for i, h in enumerate(hs):
        e = h.edges.astype(np.float64); v = h.heights.astype(np.float64)
        grid = np.linspace(0.0, max(float(e[-1]), 1.0), _TABLE_PTS)
        xs[i] = grid
        ys[i] = np.interp(grid, e, v)
    lo = np.minimum(np.full(n, float(c_min)), urd_sizes.astype(np.float32))
    hi = urd_sizes.astype(np.float32)
    if lr is None:
        lr = 0.05 * capacity / n

    key = (n, steps)
    if key not in _PGD_CACHE:
        _PGD_CACHE[key] = _pgd_core(n, steps)
    run = _PGD_CACHE[key]
    c_star = np.asarray(run(jnp.asarray(xs), jnp.asarray(ys),
                            jnp.asarray(lo), jnp.asarray(hi),
                            jnp.float32(capacity), jnp.asarray(w, jnp.float32),
                            jnp.float32(t_fast), jnp.float32(t_slow),
                            jnp.float32(lr)))

    # Snap each tenant down to its nearest breakpoint (never exceeds c*),
    # then spend any leftover with single marginal-density repair steps —
    # still a local method, faithful to the first-order character of fmincon.
    sizes = np.zeros(n, dtype=np.int64)
    for i, h in enumerate(hs):
        k = np.searchsorted(h.edges, c_star[i], side="right") - 1
        sizes[i] = int(h.edges[max(k, 0)])
    leftover = capacity - int(sizes.sum())
    gain = t_slow - t_fast
    while leftover > 0:
        best, best_i, best_nxt = 0.0, -1, 0
        for i, h in enumerate(hs):
            nxt, dh = h.marginal_gain(int(sizes[i]))
            dc = nxt - int(sizes[i])
            if dh > 0 and 0 < dc <= leftover:
                d = w[i] * dh * gain / dc
                if d > best:
                    best, best_i, best_nxt = d, i, nxt
        if best_i < 0:
            break
        leftover -= best_nxt - int(sizes[best_i])
        sizes[best_i] = best_nxt
    return PartitionResult(
        sizes, False, aggregate_latency(hs, sizes, t_fast, t_slow, w),
        np.array([h(int(s)) for h, s in zip(hs, sizes)]))
