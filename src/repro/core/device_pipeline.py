"""Device-resident Δt window pipeline: count → reduce → curve → partition
fused into one jitted program, with double-buffered window ingest.

The fused host monitor (``core.monitor``) still round-trips numpy between
its stages: the counting pass syncs per padded-width launch, the curve
build, write ratios and the breakpoint-walk partitioner all run on host
arrays.  This module keeps the whole window decision on device:

  * **Ingest** (the only host work): the window tape is laid out through
    ``batch_sim.padded_segment_layout`` (power-of-two padded,
    self-aligned segments), occurrence links are built and scattered onto
    the padded tape (``padded_tape_links``), and everything is shipped
    with ``jax.device_put`` — asynchronously, so window t+1's transfer
    overlaps window t's on-device analysis (``DeviceWindowPipeline
    .run_stream``).
  * **One jitted program per window shape bucket** (the static key is the
    tape's ``width_groups_of`` structure + tenant count + mode flags, so
    retraces are bounded by distinct padded-width *structures*):
      - SD counting via ``ops.segment_counts_device`` (Pallas kernel on
        TPU, the ``cache_sim_segments_tree`` merge-sort-tree oracle
        elsewhere),
      - device-side segment reduction of the URD/TRD distances into a
        **stacked-breakpoint curve store** (a device twin of
        ``mrc.BatchedHitRatioFunctions``: per-row sort + run-length
        reduction; tenant i's breakpoints live at
        ``[row_start[i], row_start[i] + k[i])`` of the padded tape),
      - Alg.-3 write ratios via a device bincount,
      - the ``method="fast"`` envelope-scan ``greedy_allocate`` ported to
        ``lax`` primitives (row-local ``lax.cummin`` prefix-min envelope,
        one stable ``lax.sort`` merge, prefix-sum budget cut — the same
        grant order as the host walk, partial grant included).
    Zero host syncs inside the window: the single sync is the final
    result fetch (asserted by ``StageProfile``).
  * **Bit parity.**  Off TPU the program runs in float64/int64
    (``jax.experimental.enable_x64`` scoped to this pipeline only), and
    every per-tenant output — curve edges *and* heights, URD sizes, write
    ratios, allocations — is bit-identical to the host path; tier-1
    therefore exercises the full pipeline everywhere.  On TPU the program
    runs in f32/int32: allocations may differ only where f32 density
    rounding flips a tie (documented tolerance: compare decisions by
    aggregate latency), and scaled SHARDS distances must stay below 2^31.
    The aggregate-latency scalar is reduction-order sensitive in either
    mode (jnp sums sequentially, numpy pairwise) — compare it
    approximately; sizes and curves exactly.

``monitor_window_device`` backs ``analyze_windows(pipeline="device")``
(monitor outputs only); ``DeviceWindowPipeline`` fuses the partition
stage in as well and exposes the double-buffered ``run_stream``;
``greedy_walk_device`` reuses the jitted walk for standalone
``greedy_allocate(method="device")`` calls on host curve stores.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.batch_sim import padded_segment_layout, padded_tape_links
from repro.core.mrc import BatchedHitRatioFunctions
from repro.kernels.cache_sim.ops import (_on_tpu, segment_counts_device,
                                         width_groups_of)

__all__ = ["StageProfile", "WindowIngest", "WindowDecision",
           "DeviceWindowPipeline", "greedy_walk_device", "ingest_window",
           "monitor_window_device", "transfer_sanitizer"]


# --------------------------------------------------------------- profiling
class StageProfile:
    """Per-stage wall time + host-sync counter for the window pipelines.

    ``sync()`` marks one host synchronization (a blocking fetch or an
    explicit ``jax.block_until_ready`` fence); ``stage(name)`` times a
    stage.  With ``staged=True`` the device pipeline runs its stages as
    separate launches with a fence after each — attributing wall time per
    stage at the cost of extra syncs; the default fused mode performs
    exactly **one** sync per window (the result fetch), which
    ``syncs_per_window`` exposes for the ≤1-sync assertion.
    """

    def __init__(self, staged: bool = False):
        self.staged = bool(staged)
        self.times: dict[str, float] = {}
        self.syncs = 0
        self.windows = 0

    def sync(self, k: int = 1) -> None:
        self.syncs += k

    @contextlib.contextmanager
    def stage(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.times[name] = (self.times.get(name, 0.0)
                                + time.perf_counter() - t0)

    @property
    def syncs_per_window(self) -> float:
        return self.syncs / max(self.windows, 1)

    def report(self) -> dict:
        return {"times_s": dict(self.times), "syncs": self.syncs,
                "windows": self.windows,
                "syncs_per_window": self.syncs_per_window}


def _pstage(profile: StageProfile | None, name: str):
    return profile.stage(name) if profile is not None \
        else contextlib.nullcontext()


# ------------------------------------------------------------ dtype plumbing
def _f64_default() -> bool:
    # off-TPU the pipeline runs in x64 for bit parity with the numpy host
    # path; on TPU it runs in the native f32/int32 (documented tolerance)
    return not _on_tpu()


def _x64(f64: bool):
    if f64:
        from jax.experimental import enable_x64
        return enable_x64()
    return contextlib.nullcontext()


def transfer_sanitizer(enabled: bool = True):
    """Runtime teeth for the zero-hidden-sync window contract.

    Entered around a window's dispatch + fetch, ``jax.transfer_guard
    ("disallow")`` makes every *implicit* transfer raise — a stray
    ``.item()``, ``float()`` or numpy coercion on a device value anywhere
    under the window program becomes an immediate ``XlaRuntimeError``
    instead of a silent sync the ``StageProfile`` counter can only count
    after the fact.  Explicit ``jax.device_put`` / ``jax.device_get``
    stay exempt, which is exactly the contract: ingest transfers in via
    ``device_put``, and the window's one permitted sync — the decision
    fetch in ``_fetch`` — goes out via ``device_get``.  On the sharded
    pipeline the same contract holds **per mesh**: the stacked per-shard
    ingest is one explicit (async) ``device_put`` across the whole mesh
    and the replicated decision comes back in one ``device_get``, so a
    window still costs ≤ 1 host sync no matter how many shards the mesh
    holds (asserted by the shard suite via ``StageProfile``).
    Complements the static RL001 pass (tools/repro_lint), which cannot
    see through dynamic dispatch.
    """
    if not enabled:
        return contextlib.nullcontext()
    return jax.transfer_guard("disallow")


def _np_dtypes(f64: bool):
    return (np.int64, np.float64) if f64 else (np.int32, np.float32)


# ------------------------------------------------------------------- ingest
@dataclasses.dataclass
class WindowIngest:
    """One window's device-resident tape + host-side metadata.

    ``dev`` holds the device arrays (transferred asynchronously);
    ``key`` is the static jit bucket: retraces happen per distinct
    ``(width structure, n_tenants, sampled, kind, use_kernel, f64)``.
    """

    key: tuple
    dev: dict
    n: int
    total: int
    f64: bool
    row_start: np.ndarray      # int64[n] curve-store row base per tenant
    n_acc: np.ndarray          # int64[n] curve denominators (full lens)
    cold: np.ndarray           # int64[n] cold accesses (= kept distinct)


def ingest_window(addrs: np.ndarray, is_read: np.ndarray,
                  bounds: np.ndarray, n_accesses: np.ndarray, *,
                  rates: np.ndarray | None = None, kind: str = "urd",
                  use_kernel: bool | None = None, f64: bool | None = None,
                  profile: StageProfile | None = None
                  ) -> WindowIngest | None:
    """Host half of the pipeline: layout + links + async device transfer.

    ``bounds`` are the per-tenant segment offsets of the (possibly
    SHARDS-filtered) tape; ``n_accesses`` the *full* window lengths (the
    curve denominators).  Returns ``None`` for an all-empty window (the
    callers short-circuit to the trivial host result).
    """
    from repro.core.monitor import _segment_links
    bounds = np.asarray(bounds, np.int64)
    n = bounds.shape[0] - 1
    if use_kernel is None:
        use_kernel = _on_tpu()
    if f64 is None:
        f64 = _f64_default()
    idt, fdt = _np_dtypes(f64)
    with _pstage(profile, "ingest"):
        lens_sub = np.diff(bounds)
        tid = np.repeat(np.arange(n, dtype=np.int64), lens_sub)
        layout = padded_segment_layout(bounds)
        src, tpos, base_src, base_pad, widths, total, seg_starts = layout
        if n == 0 or total == 0:
            return None
        if not f64 and int(total) * (int(total) + 2) >= 2**31 \
                and not use_kernel:
            raise ValueError(
                "device pipeline: f64=False limits the merge-sort-tree "
                f"counting oracle to tapes with total*(total+2) < 2^31 "
                f"(got total={int(total)}); use f64=True or the TPU kernel")
        prev, nxt_c = _segment_links(addrs, tid, bounds, layout)
        gprev, gnxt, gocc = padded_tape_links(prev, nxt_c, layout)
        src_eff = (src if src is not None
                   else np.arange(addrs.shape[0], dtype=np.int64))
        gread = np.zeros(total, bool)
        gread[tpos] = is_read[src_eff]
        wg = width_groups_of(widths)
        row_base = np.concatenate([[0], np.cumsum(widths)[:-1]]
                                  ).astype(np.int64)
        # non-empty segments only; 'right' lands on the owning tenant even
        # when empty tenants duplicate the bound value
        row_tids = (np.searchsorted(bounds, seg_starts, side="right")
                    - 1).astype(np.int64)
        row_start = np.zeros(n, np.int64)
        row_start[row_tids] = row_base
        n_acc = np.maximum(np.asarray(n_accesses, np.int64), 1)
        cold = np.bincount(tid[prev < 0], minlength=n).astype(np.int64)
        host = {
            "gprev": gprev.astype(np.int32),
            "gnxt": gnxt.astype(np.int32),
            "gocc": gocc.astype(np.int32),
            "gread": gread,
            "gtid": np.repeat(row_tids, widths).astype(np.int32),
            "grank": (np.arange(total, dtype=np.int64)
                      - np.repeat(row_base, widths)).astype(np.int32),
            "row_tids": row_tids.astype(np.int32),
            "row_start": row_start.astype(idt),
            "n_acc": n_acc.astype(idt),
            "wr_den": np.maximum(lens_sub, 1).astype(idt),
            "rates": (np.ones(n, fdt) if rates is None
                      else np.asarray(rates, fdt)),
        }
        key = (wg, n, rates is not None, kind, bool(use_kernel), bool(f64))
        with _x64(f64):
            dev = jax.device_put(host)      # async: overlaps prior analysis
    return WindowIngest(key, dev, n, int(total), bool(f64),
                        row_start, n_acc, cold)


# ------------------------------------------------- traceable stage bodies
def _make_eval(n: int, f64: bool):
    """h_i(sizes_i) from the padded device curve store (host ``evaluate``
    semantics: the 0-head plateau below the first breakpoint, 0 at
    sizes <= 0)."""
    idt = jnp.int64 if f64 else jnp.int32
    fdt = jnp.float64 if f64 else jnp.float32

    def eval_at(edges_p, hgt_p, kcnt, gtid, grank, row_start, sizes):
        has_u = grank < kcnt[gtid]
        le = has_u & (edges_p <= sizes[gtid])
        kq = jnp.zeros(n, idt).at[gtid].add(le.astype(idt))
        h = hgt_p[row_start + jnp.maximum(kq - 1, 0)]
        return jnp.where((kq > 0) & (sizes > 0), h, fdt(0.0))

    return eval_at


def _make_walk(wg: tuple, n: int, total: int, f64: bool):
    """The ``method="fast"`` envelope-scan breakpoint walk on the padded
    device curve store — the host walk's grant order, in ``lax``.

    Chains (a tenant's steps strictly above its current size) live inside
    self-aligned rows, so the chain-stop cumsum and the prefix-min density
    envelope are row-local scans; one stable 3-key ``lax.sort``
    (``-envelope, tenant, rank``) reproduces ``np.lexsort``'s merge of
    all chains, a prefix sum over Δc finds the budget cut, and the first
    un-granted step receives the host walk's partial grant.
    """
    idt = jnp.int64 if f64 else jnp.int32
    fdt = jnp.float64 if f64 else jnp.float32

    def row_scan(x, fn):
        parts = [fn(x[lo:hi].reshape((hi - lo) // w, w)).reshape(-1)
                 for w, lo, hi in wg]
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    def walk(edges_p, hgt_p, kcnt, gtid, grank, row_start,
             sizes0, budget, w_t, gain):
        has_u = grank < kcnt[gtid]
        le0 = has_u & (edges_p <= sizes0[gtid])
        k0 = jnp.zeros(n, idt).at[gtid].add(le0.astype(idt))
        step = has_u & (grank >= k0[gtid])
        first = step & (grank == k0[gtid])
        h0 = jnp.where((k0 > 0) & (sizes0 > 0),
                       hgt_p[row_start + jnp.maximum(k0 - 1, 0)], fdt(0.0))
        hprev = jnp.concatenate([jnp.zeros(1, fdt), hgt_p[:-1]])
        eprev = jnp.concatenate([jnp.zeros(1, idt), edges_p[:-1]])
        dh = hgt_p - jnp.where(first, h0[gtid], hprev)
        dc = edges_p - jnp.where(first, sizes0[gtid], eprev)
        # chain-stop at the first non-improving step (host `valid`)
        bad = (step & (dh <= 0)).astype(idt)
        cbad = row_scan(bad, lambda x: jnp.cumsum(x, axis=1))
        valid = step & (cbad == 0)
        dens = (w_t[gtid] * dh * gain) / dc.astype(fdt)
        env = row_scan(jnp.where(valid, dens, jnp.inf),
                       lambda x: lax.cummin(x, axis=1))
        neg_e = jnp.where(valid, -env, jnp.inf).astype(fdt)
        dc_z = jnp.where(valid, dc, 0)
        # host order: lexsort((rank, tenant, -envelope)); invalid slots
        # carry +inf and sort past every valid step
        _, tid_s, _, dc_s, nxt_s = lax.sort(
            (neg_e, gtid, grank, dc_z, edges_p), num_keys=3, is_stable=True)
        cum = jnp.cumsum(dc_s)
        granted = (dc_s > 0) & (cum <= budget)
        sizes1 = sizes0.at[tid_s].max(jnp.where(granted, nxt_s, 0))
        ngrant = jnp.sum(granted.astype(idt))
        nvalid = jnp.sum(valid.astype(idt))
        spent = jnp.where(ngrant > 0,
                          cum[jnp.clip(ngrant - 1, 0, total - 1)], 0)
        rem = budget - spent
        nxt_i = jnp.clip(ngrant, 0, total - 1)
        part = jnp.where((rem > 0) & (ngrant < nvalid), rem, 0)
        return sizes1.at[tid_s[nxt_i]].add(part.astype(idt))

    return walk


_PROGRAMS: dict[tuple, dict] = {}


def _programs(key: tuple) -> dict:
    """Build (and cache) the jitted window programs for one shape bucket."""
    if key in _PROGRAMS:
        return _PROGRAMS[key]
    wg, n, sampled, kind, use_kernel, f64 = key
    total = wg[-1][2]
    idt = jnp.int64 if f64 else jnp.int32
    fdt = jnp.float64 if f64 else jnp.float32
    sent = (1 << 62) if f64 else (1 << 30)   # above every real sample + 1
    rows_per = [(hi - lo) // w for w, lo, hi in wg]
    rb = np.concatenate([[0], np.cumsum(rows_per)]).astype(int)
    eval_at = _make_eval(n, f64)
    walk = _make_walk(wg, n, total, f64)

    def count_stage(d):
        counts = segment_counts_device(d["gprev"], d["gnxt"], d["gocc"], wg,
                                       use_kernel=use_kernel)
        hot = d["gprev"] >= 0
        if sampled:
            r = jnp.maximum(d["rates"][d["gtid"]], 1e-300)
            return jnp.where(hot, jnp.round(counts.astype(fdt) / r
                                            ).astype(idt), -1)
        return jnp.where(hot, counts.astype(idt), -1)

    def curve_stage(d, dist):
        smask = dist >= 0
        if kind == "urd":
            smask = smask & d["gread"]
        sv = jnp.where(smask, dist + 1, sent)
        edges_p = jnp.zeros(total, idt)
        cum_p = jnp.zeros(total, idt)
        kcnt = jnp.zeros(n, idt)
        urd = jnp.zeros(n, idt)
        for gi, (w, lo, hi) in enumerate(wg):
            rows = (hi - lo) // w
            s = jnp.sort(sv[lo:hi].reshape(rows, w), axis=1)
            val = s != sent
            sl = jnp.concatenate(
                [jnp.full((rows, 1), -1, s.dtype), s[:, :-1]], axis=1)
            sr = jnp.concatenate(
                [s[:, 1:], jnp.full((rows, 1), -1, s.dtype)], axis=1)
            new = val & (s != sl)               # first of a run = unique
            last = val & (s != sr)              # run end carries the cumsum
            rank = jnp.cumsum(new.astype(idt), axis=1) - 1
            iota = lax.broadcasted_iota(idt, (rows, w), 1)
            rowi = lax.broadcasted_iota(idt, (rows, w), 0)
            dst = jnp.where(last, lo + rowi * w + rank, total)
            edges_p = edges_p.at[dst.ravel()].set(s.ravel().astype(idt),
                                                  mode="drop")
            cum_p = cum_p.at[dst.ravel()].set((iota + 1).ravel(),
                                              mode="drop")
            rt = d["row_tids"][int(rb[gi]):int(rb[gi + 1])]
            kcnt = kcnt.at[rt].set(jnp.sum(new.astype(idt), axis=1))
            urd = urd.at[rt].set(jnp.max(jnp.where(val, s, 0),
                                         axis=1).astype(idt))
        # plateau heights: same int/int division (or HT estimator) as the
        # host build, computed where the run-ends landed
        if sampled:
            den = d["n_acc"] * d["rates"]
            hgt_p = jnp.minimum(cum_p / den[d["gtid"]], 1.0)
        else:
            hgt_p = cum_p / d["n_acc"][d["gtid"]]
        return edges_p, hgt_p.astype(fdt), kcnt, urd

    def wr_stage(d, dist):
        wflag = ((dist >= 0) & (~d["gread"])).astype(idt)
        wcnt = jnp.zeros(n, idt).at[d["gtid"]].add(wflag)
        return wcnt / d["wr_den"]

    def partition_stage(d, edges_p, hgt_p, kcnt, urd, p):
        capacity, c_min = p["capacity"], p["c_min"]
        w_t, t_fast, t_slow = p["weights"], p["t_fast"], p["t_slow"]
        c_min_arr = jnp.minimum(urd, c_min)
        feasible = jnp.sum(urd) <= capacity
        b0 = capacity - jnp.sum(c_min_arr)
        tot_min = jnp.maximum(jnp.sum(c_min_arr), 1)
        scaled = jnp.floor((c_min_arr * capacity).astype(fdt)
                           / tot_min.astype(fdt)).astype(idt)
        s0 = jnp.where(b0 < 0, scaled, c_min_arr)
        budget = capacity - jnp.sum(s0)
        walked = walk(edges_p, hgt_p, kcnt, d["gtid"], d["grank"],
                      d["row_start"], s0, budget, w_t, t_slow - t_fast)
        sizes = jnp.where(feasible, urd, walked)
        h_at = eval_at(edges_p, hgt_p, kcnt, d["gtid"], d["grank"],
                       d["row_start"], sizes)
        lat = jnp.sum(w_t * (h_at * t_fast + (1.0 - h_at) * t_slow))
        return sizes, h_at, lat, feasible

    def monitor_core(d):
        dist = count_stage(d)
        edges_p, hgt_p, kcnt, urd = curve_stage(d, dist)
        return edges_p, hgt_p, kcnt, urd, wr_stage(d, dist)

    def decision_core(d, p):
        dist = count_stage(d)
        edges_p, hgt_p, kcnt, urd = curve_stage(d, dist)
        wr = wr_stage(d, dist)
        sizes, h_at, lat, feasible = partition_stage(
            d, edges_p, hgt_p, kcnt, urd, p)
        return edges_p, hgt_p, kcnt, urd, wr, sizes, h_at, lat, feasible

    # donated scratch: each window's tape is consumed exactly once, so on
    # TPU the ingest buffers are recycled in place (CPU would only warn)
    dk = dict(donate_argnums=(0,)) if _on_tpu() else {}
    progs = {
        "monitor": jax.jit(monitor_core, **dk),
        "decision": jax.jit(decision_core, **dk),
        "count": jax.jit(count_stage),
        "curve": jax.jit(curve_stage),
        "wr": jax.jit(wr_stage),
        "partition": jax.jit(partition_stage),
        # unjitted stage bodies: the sharded pipeline re-traces exactly
        # these closures inside its shard_map body (core.shard_pipeline),
        # so per-shard counting/curve/partition stays one implementation
        "stages": {"count": count_stage, "curve": curve_stage,
                   "wr": wr_stage, "partition": partition_stage},
    }
    _PROGRAMS[key] = progs
    return progs


# --------------------------------------------------------------- dispatch
def _dispatch_monitor(ing: WindowIngest, profile: StageProfile | None,
                      sanitize: bool = False):
    progs = _programs(ing.key)
    with transfer_sanitizer(sanitize), _x64(ing.f64):
        if profile is not None and profile.staged:
            with profile.stage("count"):
                dist = progs["count"](ing.dev)
                jax.block_until_ready(dist)
                profile.sync()
            with profile.stage("curve"):
                cur = progs["curve"](ing.dev, dist)
                jax.block_until_ready(cur)
                profile.sync()
            with profile.stage("write_ratio"):
                wr = progs["wr"](ing.dev, dist)
                jax.block_until_ready(wr)
                profile.sync()
            return (*cur, wr)
        with _pstage(profile, "dispatch"):
            return progs["monitor"](ing.dev)


def _fetch(ing: WindowIngest, out, profile: StageProfile | None,
           sanitize: bool = False):
    """The window's single host sync: block on the program, copy out.

    The copy is an *explicit* ``jax.device_get`` — the one transfer the
    ``transfer_sanitizer`` guard permits, so under ``sanitize`` any other
    device->host escape in the window raises while this fetch stays legal.
    """
    with transfer_sanitizer(sanitize), _x64(ing.f64):
        with _pstage(profile, "fetch"):
            jax.block_until_ready(out)
            if profile is not None and not profile.staged:
                profile.sync()
        return jax.device_get(list(out))


def _trivial_monitor(n: int, n_accesses: np.ndarray):
    """Host-identical outputs for an all-empty window (no device work)."""
    k = np.zeros(n, np.int64)
    curves = BatchedHitRatioFunctions.from_padded(
        np.zeros(0, np.int64), np.zeros(0, np.float64), k,
        np.zeros(n, np.int64), n_accesses)
    return (curves, np.zeros(n, np.int64), np.zeros(n, np.float64),
            np.zeros(n, np.int64))


def monitor_window_device(addrs: np.ndarray, is_read: np.ndarray,
                          bounds: np.ndarray, n_accesses: np.ndarray, *,
                          rates: np.ndarray | None = None,
                          kind: str = "urd",
                          use_kernel: bool | None = None,
                          f64: bool | None = None,
                          profile: StageProfile | None = None,
                          launch_hook=None,
                          transfer_sanitize: bool = False):
    """Monitor outputs for one window, computed on device.

    Returns ``(curves, urd_sizes, write_ratios, cold_counts)`` —
    ``analyze_windows(pipeline="device")``'s backend.  One host sync (the
    fetch); bit-identical to the host monitor in f64 mode.  ``launch_hook``
    (fault injection) is invoked right before the fused program dispatch —
    after ingest, at the real launch boundary.  ``transfer_sanitize``
    (default off, bit-identical when on) runs dispatch + fetch under the
    ``transfer_sanitizer`` guard: any hidden host sync raises.
    """
    n = int(np.asarray(bounds).shape[0]) - 1
    n_acc = np.maximum(np.asarray(n_accesses, np.int64), 1)
    ing = ingest_window(addrs, is_read, bounds, n_accesses, rates=rates,
                        kind=kind, use_kernel=use_kernel, f64=f64,
                        profile=profile)
    if profile is not None:
        profile.windows += 1
    if launch_hook is not None:
        launch_hook()
    if ing is None:
        return _trivial_monitor(n, n_acc)
    out = _dispatch_monitor(ing, profile, sanitize=transfer_sanitize)
    edges_p, hgt_p, kcnt, urd, wr = _fetch(ing, out, profile,
                                           sanitize=transfer_sanitize)
    curves = BatchedHitRatioFunctions.from_padded(
        edges_p, hgt_p, kcnt, ing.row_start, ing.n_acc)
    return (curves, np.asarray(urd, np.int64), np.asarray(wr, np.float64),
            ing.cold)


# --------------------------------------------------- fused decision pipeline
@dataclasses.dataclass(frozen=True)
class WindowDecision:
    """One Δt window's full control-plane decision (device-computed).

    ``latency`` is the Eq.-2 objective at ``sizes`` — reduction-order
    approximate vs ``aggregate_latency`` (see module doc); everything
    else is bit-identical to the host path in f64 mode.
    """

    sizes: np.ndarray
    write_ratios: np.ndarray
    urd_sizes: np.ndarray
    hit_ratios: np.ndarray
    latency: float
    feasible: bool
    curves: BatchedHitRatioFunctions


class DeviceWindowPipeline:
    """End-to-end fused window decisions with double-buffered ingest.

    ``run(traces)`` analyzes + partitions one window in a single device
    program; ``run_stream(windows)`` overlaps window t+1's host-side
    ingest and async transfer with window t's on-device analysis, paying
    one host sync per window (the decision fetch).
    """

    def __init__(self, capacity: int, t_fast: float = 1.0,
                 t_slow: float = 20.0, c_min: int = 0, kind: str = "urd",
                 weights: np.ndarray | None = None,
                 use_kernel: bool | None = None, f64: bool | None = None,
                 transfer_sanitize: bool = False, mesh=None):
        self.capacity = int(capacity)
        self.t_fast, self.t_slow = float(t_fast), float(t_slow)
        self.c_min = int(c_min)
        self.kind = kind
        self.weights = None if weights is None else np.asarray(weights,
                                                               np.float64)
        self.use_kernel = use_kernel
        self.f64 = _f64_default() if f64 is None else bool(f64)
        # default-off, bit-identical when on: window dispatch + fetch run
        # under jax.transfer_guard("disallow") so any hidden host sync
        # raises; the decision fetch stays legal (explicit device_get)
        self.transfer_sanitize = bool(transfer_sanitize)
        # default-off (None = this single-device pipeline, byte-identical
        # to pre-mesh behavior); a 1-D ("shards",) mesh routes every
        # window through the shard_map twin (core.shard_pipeline) with
        # per-shard async ingest and the budget cut replicated
        self.mesh = mesh

    # ------------------------------------------------------------ plumbing
    def _params(self, n: int) -> dict:
        idt, fdt = _np_dtypes(self.f64)
        w = np.ones(n) if self.weights is None else self.weights
        return {"capacity": idt(self.capacity), "c_min": idt(self.c_min),
                "weights": np.asarray(w, fdt), "t_fast": fdt(self.t_fast),
                "t_slow": fdt(self.t_slow)}

    def ingest(self, traces, profile: StageProfile | None = None):
        """Host prep + async transfer for one window of tenant traces."""
        n = len(traces)
        lens = np.array([len(t) for t in traces], dtype=np.int64)
        bounds = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
        if int(bounds[-1]):
            addrs = np.concatenate([t.addrs for t in traces])
            is_read = np.concatenate([t.is_read for t in traces])
        else:
            addrs = np.zeros(0, np.int64)
            is_read = np.zeros(0, bool)
        if self.mesh is not None:
            from repro.core.shard_pipeline import ingest_window_sharded
            ing = ingest_window_sharded(
                addrs, is_read, bounds, lens, mesh=self.mesh,
                kind=self.kind, use_kernel=self.use_kernel, f64=self.f64,
                profile=profile)
        else:
            ing = ingest_window(addrs, is_read, bounds, lens,
                                kind=self.kind, use_kernel=self.use_kernel,
                                f64=self.f64, profile=profile)
        return ing, n, np.maximum(lens, 1)

    def _dispatch(self, ing: WindowIngest,
                  profile: StageProfile | None = None):
        if self.mesh is not None:
            from repro.core.shard_pipeline import dispatch_decision_sharded
            return dispatch_decision_sharded(
                ing, self._params(ing.n), profile,
                sanitize=self.transfer_sanitize)
        progs = _programs(ing.key)
        p = self._params(ing.n)
        with transfer_sanitizer(self.transfer_sanitize), _x64(ing.f64):
            if self.transfer_sanitize:
                # under the guard the numpy params must cross explicitly
                # (inside the x64 scope so dtypes match the implicit path)
                p = jax.device_put(p)
            if profile is not None and profile.staged:
                with profile.stage("count"):
                    dist = progs["count"](ing.dev)
                    jax.block_until_ready(dist)
                    profile.sync()
                with profile.stage("curve"):
                    cur = progs["curve"](ing.dev, dist)
                    jax.block_until_ready(cur)
                    profile.sync()
                with profile.stage("write_ratio"):
                    wr = progs["wr"](ing.dev, dist)
                    jax.block_until_ready(wr)
                    profile.sync()
                with profile.stage("partition"):
                    part = progs["partition"](ing.dev, *cur[:4], p)
                    jax.block_until_ready(part)
                    profile.sync()
                return (*cur, wr, *part)
            with _pstage(profile, "dispatch"):
                return progs["decision"](ing.dev, p)

    def _trivial(self, n: int, n_acc: np.ndarray) -> WindowDecision:
        curves, urd, wr, _ = _trivial_monitor(n, n_acc)
        w = np.ones(n) if self.weights is None else self.weights
        lat = float(np.sum(w * self.t_slow))
        return WindowDecision(np.zeros(n, np.int64), wr, urd,
                              np.zeros(n, np.float64), lat, True, curves)

    def _finish(self, ing: WindowIngest, out,
                profile: StageProfile | None = None) -> WindowDecision:
        (edges_p, hgt_p, kcnt, urd, wr, sizes, h_at, lat, feas) = \
            _fetch(ing, out, profile, sanitize=self.transfer_sanitize)
        curves = BatchedHitRatioFunctions.from_padded(
            edges_p, hgt_p, kcnt, ing.row_start, ing.n_acc)
        if profile is not None:
            profile.windows += 1
        return WindowDecision(np.asarray(sizes, np.int64),
                              np.asarray(wr, np.float64),
                              np.asarray(urd, np.int64),
                              np.asarray(h_at, np.float64),
                              float(lat), bool(feas), curves)

    # -------------------------------------------------------------- driving
    def run(self, traces, profile: StageProfile | None = None
            ) -> WindowDecision:
        ing, n, n_acc = self.ingest(traces, profile)
        if ing is None:
            if profile is not None:
                profile.windows += 1
            return self._trivial(n, n_acc)
        out = self._dispatch(ing, profile)
        return self._finish(ing, out, profile)

    def run_stream(self, windows, profile: StageProfile | None = None
                   ) -> list[WindowDecision]:
        """Double-buffered window stream: ingest t+1 overlaps analysis t.

        Per iteration the *previous* window's program is already running
        on device; the next window's host-side layout/link work and its
        async ``device_put`` proceed under it, and only then is the
        previous decision fetched (the one sync).
        """
        results: list[WindowDecision] = []
        pending = None                  # (ingest, in-flight outputs)
        for traces in windows:
            ing, n, n_acc = self.ingest(traces, profile)
            nxt = None
            if ing is not None:
                nxt = (ing, self._dispatch(ing, profile))
            if pending is not None:
                results.append(self._finish(*pending, profile))
            if ing is None:
                if profile is not None:
                    profile.windows += 1
                results.append(self._trivial(n, n_acc))
            pending = nxt
        if pending is not None:
            results.append(self._finish(*pending, profile))
        return results


# ------------------------------------------------- standalone device walk
_WALK_PROGRAMS: dict[tuple, object] = {}


def _walk_program(n: int, k_pad: int, f64: bool):
    key = (n, k_pad, f64)
    if key not in _WALK_PROGRAMS:
        wg = ((k_pad, 0, n * k_pad),)
        _WALK_PROGRAMS[key] = jax.jit(_make_walk(wg, n, n * k_pad, f64))
    return _WALK_PROGRAMS[key]


def greedy_walk_device(b: BatchedHitRatioFunctions, sizes: np.ndarray,
                       budget: int, w: np.ndarray, gain: float,
                       f64: bool | None = None) -> np.ndarray:
    """``partitioner._greedy_walk_fast`` on device (one jitted program).

    Pads the host curve store (0-heads stripped) to a uniform
    power-of-two breakpoint count per tenant and runs the jitted
    envelope-scan walk — ``greedy_allocate(method="device")``'s backend.
    Bit-identical grant order to the host walk in f64 mode.
    """
    if f64 is None:
        f64 = _f64_default()
    idt, fdt = _np_dtypes(f64)
    n = len(b)
    sizes = np.asarray(sizes, np.int64)
    if budget <= 0 or n == 0:
        return sizes
    k = np.maximum(np.diff(b.offsets) - 1, 0)        # drop the 0-heads
    kmax = int(k.max(initial=0))
    if kmax == 0:
        return sizes
    k_pad = 1 << (kmax - 1).bit_length()
    total = n * k_pad
    edges_p = np.zeros(total, np.int64)
    hgt_p = np.zeros(total, np.float64)
    tot_k = int(k.sum())
    if tot_k:
        rank = (np.arange(tot_k, dtype=np.int64)
                - np.repeat(np.cumsum(k) - k, k))
        src = np.repeat(b.offsets[:-1] + 1, k) + rank
        dst = np.repeat(np.arange(n, dtype=np.int64) * k_pad, k) + rank
        edges_p[dst] = b.edges[src]
        hgt_p[dst] = b.heights[src]
    walk = _walk_program(n, k_pad, bool(f64))
    with _x64(bool(f64)):
        out = walk(jnp.asarray(edges_p.astype(idt)),
                   jnp.asarray(hgt_p.astype(fdt)),
                   jnp.asarray(k.astype(idt)),
                   jnp.asarray(np.repeat(np.arange(n, dtype=np.int32),
                                         k_pad)),
                   jnp.asarray(np.tile(np.arange(k_pad, dtype=np.int32),
                                       n)),
                   jnp.asarray((np.arange(n, dtype=np.int64)
                                * k_pad).astype(idt)),
                   jnp.asarray(sizes.astype(idt)), idt(budget),
                   jnp.asarray(np.asarray(w, fdt)), fdt(gain))
        out = np.asarray(out)
    return out.astype(np.int64)
