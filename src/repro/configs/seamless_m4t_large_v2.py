"""seamless-m4t-large-v2 [arXiv:2308.11596]: enc-dec, 24 encoder + 24
decoder layers, d1024 16H(kv16, head 64), d_ff 8192, vocab 256206.
The speech frontend is a stub per the brief: the encoder consumes
precomputed frame embeddings [B, S_enc, d]."""
from repro.models.config import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family=Family.ENCDEC,
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=8192, vocab_size=256206, attn=AttnKind.GQA,
    frontend_stub=True,
)

SMOKE_CONFIG = ModelConfig(
    name="seamless-smoke", family=Family.ENCDEC,
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=512, attn=AttnKind.GQA,
    frontend_stub=True,
)

SKIP_SHAPES = {"long_500k"}
