"""deepseek-moe-16b [arXiv:2401.06066]: 28L d2048 16H(kv16), fine-grained MoE
2 shared + 64 routed top-6, expert d_ff 1408, vocab 102400."""
from repro.models.config import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family=Family.MOE,
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400, attn=AttnKind.GQA,
    n_experts=64, n_shared_experts=2, top_k=6,
    expert_d_ff=1408, shared_d_ff=2816,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-moe-smoke", family=Family.MOE,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, attn=AttnKind.GQA,
    n_experts=8, n_shared_experts=1, top_k=3, expert_d_ff=64, shared_d_ff=64,
)

SKIP_SHAPES = {"long_500k"}
