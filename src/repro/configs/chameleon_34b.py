"""chameleon-34b [arXiv:2405.09818]: early-fusion VLM backbone, 48L d8192
64H(kv8) d_ff 22016, vocab 65536 (VQ image tokens live in-vocab).
The patch/VQ frontend is a stub per the brief: image tokens arrive as
ordinary vocab ids."""
from repro.models.config import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family=Family.DENSE,
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=65536, attn=AttnKind.GQA, qk_norm=True,
)

SMOKE_CONFIG = ModelConfig(
    name="chameleon-smoke", family=Family.DENSE,
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, attn=AttnKind.GQA, qk_norm=True,
)

SKIP_SHAPES = {"long_500k"}
