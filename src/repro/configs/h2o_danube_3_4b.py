"""h2o-danube-3-4b [arXiv:2401.16818]: dense 24L d3840 32H(kv8, head 120),
d_ff 10240, vocab 32000, llama+mistral mix with sliding-window attention
(window 4096) -> KV bounded by the window, so long_500k decode runs."""
from repro.models.config import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family=Family.DENSE,
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab_size=32000, attn=AttnKind.SWA, window=4096,
    sub_quadratic=True,
)

SMOKE_CONFIG = ModelConfig(
    name="h2o-danube-smoke", family=Family.DENSE,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, attn=AttnKind.SWA, window=32,
    sub_quadratic=True,
)

SKIP_SHAPES: set[str] = set()
