"""qwen3-0.6b [hf:Qwen/Qwen3 family]: dense 28L d1024 16H(kv8) head 128,
d_ff 3072, vocab 151936, qk-norm."""
from repro.models.config import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family=Family.DENSE,
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab_size=151936, attn=AttnKind.GQA, qk_norm=True,
    rope_theta=1e6, tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-smoke", family=Family.DENSE,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, attn=AttnKind.GQA, qk_norm=True,
    tie_embeddings=True,
)

SKIP_SHAPES = {"long_500k"}
