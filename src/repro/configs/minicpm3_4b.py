"""minicpm3-4b [hf:openbmb/MiniCPM3-4B]: dense 62L d2560 40H with MLA
(q_lora 768, kv_lora 256, nope 64 + rope 32, v 64), d_ff 6400, vocab 73448.
40 q-heads are padded to 48 for 16-way TP (padding masked)."""
from repro.models.config import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family=Family.DENSE,
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=96,
    d_ff=6400, vocab_size=73448, attn=AttnKind.MLA,
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
)

SMOKE_CONFIG = ModelConfig(
    name="minicpm3-smoke", family=Family.DENSE,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=24,
    d_ff=128, vocab_size=512, attn=AttnKind.MLA,
    q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16,
)

SKIP_SHAPES = {"long_500k"}
