"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d2048 16H(kv16) MoE
4 shared + 60 routed top-4, expert d_ff 1408, vocab 151936."""
from repro.models.config import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family=Family.MOE,
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=151936, attn=AttnKind.GQA,
    n_experts=60, n_shared_experts=4, top_k=4,
    expert_d_ff=1408, shared_d_ff=5632,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-moe-smoke", family=Family.MOE,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, attn=AttnKind.GQA,
    n_experts=8, n_shared_experts=2, top_k=2, expert_d_ff=64, shared_d_ff=128,
)

SKIP_SHAPES = {"long_500k"}  # pure full attention: no sub-quadratic path
