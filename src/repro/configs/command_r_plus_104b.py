"""command-r-plus-104b [hf:CohereForAI]: dense 64L d12288 96H(kv8),
d_ff 33792, vocab 256000, no-bias GQA."""
from repro.models.config import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family=Family.DENSE,
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000, attn=AttnKind.GQA,
)

SMOKE_CONFIG = ModelConfig(
    name="command-r-smoke", family=Family.DENSE,
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, attn=AttnKind.GQA,
)

SKIP_SHAPES = {"long_500k"}
