"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG`` (exact pool spec) and ``SMOKE_CONFIG``
(a reduced same-family config for CPU smoke tests).  ``SKIP_SHAPES`` lists
shape cells inapplicable to the family (DESIGN.md §4).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, ShapeConfig, SHAPES

ARCH_IDS = [
    "qwen2_moe_a2_7b",
    "deepseek_moe_16b",
    "chameleon_34b",
    "zamba2_7b",
    "mamba2_780m",
    "command_r_plus_104b",
    "minicpm3_4b",
    "qwen3_0_6b",
    "h2o_danube_3_4b",
    "seamless_m4t_large_v2",
]

# accept dashed aliases from the pool listing
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE_CONFIG


def skip_shapes(arch: str) -> set[str]:
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return getattr(mod, "SKIP_SHAPES", set())


def all_cells() -> list[tuple[str, ShapeConfig]]:
    """Every (arch, shape) cell that runs (40 minus documented skips)."""
    cells = []
    for a in ARCH_IDS:
        skips = skip_shapes(a)
        for s in SHAPES.values():
            if s.name not in skips:
                cells.append((a, s))
    return cells
