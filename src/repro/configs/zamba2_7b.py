"""zamba2-7b [arXiv:2411.15242]: hybrid — 81 Mamba2 layers + one SHARED
attention+FFN block applied every 6 layers (weights shared across all
applications). d3584, attn 32H(kv32, head 112), d_ff 14336, ssm_state 64."""
from repro.models.config import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family=Family.HYBRID,
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000, attn=AttnKind.GQA,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
    sub_quadratic=True,
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-smoke", family=Family.HYBRID,
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, attn=AttnKind.GQA,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=32, attn_every=2,
    sub_quadratic=True,
)

SKIP_SHAPES: set[str] = set()
