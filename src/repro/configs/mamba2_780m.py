"""mamba2-780m [arXiv:2405.21060]: pure SSD (state-space duality), 48L
d1536, attention-free, ssm_state 128, vocab 50280."""
from repro.models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family=Family.SSM,
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    sub_quadratic=True, tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-smoke", family=Family.SSM,
    n_layers=3, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=32,
    sub_quadratic=True, tie_embeddings=True,
)

SKIP_SHAPES: set[str] = set()
