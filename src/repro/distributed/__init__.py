"""Mesh/axis sharding rules, collective compression, pipeline parallelism."""
