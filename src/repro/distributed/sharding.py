"""Logical→physical sharding rules for every param/cache/batch tree.

Strategy (DESIGN.md §6): FSDP over ``data`` (every weight also sharded on a
non-TP dim) × TP over ``model`` (heads/ffn/experts/vocab) × DP over
``pod``+``data``; decode KV caches shard their *sequence* axis over
``model`` (flash-decoding split-KV).

Rules are (regex over tree path, dims) — ``dims`` names a mesh axis per
tensor dim or None.  ``spec_for`` drops any axis whose size does not divide
the dim (safety: replication instead of a compile error), so one rule table
serves all 10 architectures.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "batch_specs", "cache_specs", "state_specs",
           "spec_for", "DP", "control_plane_mesh"]


def DP(mesh) -> tuple[str, ...] | str:
    names = mesh.axis_names
    axes = tuple(a for a in ("pod", "data") if a in names)
    return axes if len(axes) > 1 else axes[0]


# (path regex, per-dim mesh axes).  First match wins.  Paths look like
# "layers/attn/wq", "layers/mlp/w_gate", "embed", "shared_attn/attn/wo" …
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$",                   ("model", "data")),
    (r"lm_head$",                 (None, "model")),   # vocab-only: no per-step FSDP gather of the 6 GB head
    # attention (stacked layers get a leading None automatically)
    (r"attn/wq$",                 ("data", "model")),
    (r"attn/wk$",                 ("data", "model")),
    (r"attn/wv$",                 ("data", "model")),
    (r"attn/wo$",                 ("model", "data")),
    (r"cross/wq$",                ("data", "model")),
    (r"cross/wk$",                ("data", "model")),
    (r"cross/wv$",                ("data", "model")),
    (r"cross/wo$",                ("model", "data")),
    (r"attn/wq_a$",               ("data", "model")),
    (r"attn/wq_b$",               ("data", "model")),
    (r"attn/wkv_a$",              ("data", None)),
    (r"attn/wkv_b$",              ("data", "model")),
    (r"attn/(q_norm|k_norm|q_a_norm|kv_a_norm)$", (None,)),
    # dense / shared-expert FFN
    (r"mlp/w_gate$",              ("data", "model")),
    (r"mlp/w_up$",                ("data", "model")),
    (r"mlp/w_down$",              ("model", "data")),
    (r"mlp/shared_gate$",         ("data", "model")),
    (r"mlp/shared_up$",           ("data", "model")),
    (r"mlp/shared_down$",         ("model", "data")),
    # MoE experts: EP over model, FSDP over data
    (r"mlp/router$",              ("data", None)),
    (r"mlp/w_gate_e|experts",     ("model", "data", None)),
    # mamba2
    (r"mixer/in_proj$",           ("data", "model")),
    (r"mixer/conv_w$",            (None, "model")),
    (r"mixer/conv_b$",            ("model",)),
    (r"mixer/(A_log|D|dt_bias)$", ("model",)),
    (r"mixer/out_proj$",          ("model", "data")),
    (r"mixer/norm$",              ("model",)),
    # norms and everything small: replicate
    (r"(ln1|ln2|ln_x|final_norm|enc_norm|norm)$", None),
]

# MoE expert tensors are 3-D [E, d, ff] under stacked layers -> 4-D.
_MOE_EXPERT = re.compile(r"mlp/w_(gate|up|down)$")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def spec_for(path_str: str, shape: tuple[int, ...], mesh) -> P:
    """Resolve the rule table for one leaf, with divisibility fallback."""
    stacked = path_str.startswith(("layers/", "enc_layers/"))
    axes_by_name = dict(zip(mesh.axis_names, mesh.devices.shape))

    dims: tuple | None = None
    # distinguish expert tensors (rank 3 + stacking) from dense mlp (rank 2)
    rank = len(shape) - (1 if stacked else 0)
    if _MOE_EXPERT.search(path_str) and rank == 3:
        name = path_str.rsplit("/", 1)[-1]
        if name == "w_down":
            dims = ("model", None, "data")
        else:
            dims = ("model", "data", None)
    else:
        for pat, d in _PARAM_RULES:
            if re.search(pat, path_str):
                dims = d
                break
    if dims is None:
        return P()
    if stacked:
        dims = (None, *dims)
    dims = tuple(dims[:len(shape)]) + (None,) * (len(shape) - len(dims))
    # divisibility fallback: replicate dims the mesh axis cannot divide
    fixed = []
    for size, ax in zip(shape, dims):
        if ax is None:
            fixed.append(None)
        elif size % axes_by_name.get(ax, 1) == 0:
            fixed.append(ax)
        else:
            fixed.append(None)
    return P(*fixed)


def param_specs(params, mesh):
    """Tree of NamedShardings matching a param tree (or its eval_shape)."""
    def one(path, leaf):
        return NamedSharding(mesh, spec_for(_path_str(path), leaf.shape,
                                            mesh))
    return jax.tree_util.tree_map_with_path(one, params)


def _div_ok(n: int, mesh, axes) -> bool:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = int(np.prod([sizes[a] for a in (axes if isinstance(axes, tuple)
                                            else (axes,))]))
    return n % total == 0


def batch_specs(batch, mesh):
    """tokens/labels [B,S] + optional enc_embeds [B,S,d]: DP over batch."""
    dp = DP(mesh)

    def one(path, leaf):
        b = leaf.shape[0]
        axes = dp if _div_ok(b, mesh, dp) else (
            "data" if _div_ok(b, mesh, "data") else None)
        if axes is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(axes, *([None] * (len(leaf.shape) - 1))))
    return jax.tree_util.tree_map_with_path(one, batch)


def cache_specs(cache, mesh):
    """Decode caches: batch→DP when divisible; sequence axis→model.

    Layouts: kv k/v [L,B,S,H,D]; MLA c_kv/k_rope [L,B,S,r]; ssm conv
    [L,B,K,C] / ssm [L,B,H,N,P]; cross_k/v [L,B,Se,H,D]; len [B].
    """
    dp = DP(mesh)

    def one(path, leaf):
        ps = _path_str(path)
        shp = leaf.shape
        if ps in ("len", "enc_len"):
            return NamedSharding(
                mesh, P(dp if _div_ok(shp[0], mesh, dp) else None))
        dims: list = [None] * len(shp)
        if len(shp) >= 2:
            # dim 1 is batch
            if _div_ok(shp[1], mesh, dp):
                dims[1] = dp
            elif _div_ok(shp[1], mesh, "data"):
                dims[1] = "data"
        if ps.startswith(("kv/", "cross_")):
            # [L, B, S, ...]: shard sequence over model (split-KV decode);
            # with batch unshardable (long-context B=1), also spread seq
            # over the data axis.
            seq_axes = ("model",) if dims[1] is not None else ("data", "model")
            cand = tuple(a for a in seq_axes if a in mesh.axis_names)
            if _div_ok(shp[2], mesh, cand):
                dims[2] = cand if len(cand) > 1 else cand[0]
        elif ps.startswith("ssm/"):
            # conv [L,B,K,C]: C→model; ssm [L,B,H,N,P]: H→model
            if ps.endswith("conv") and _div_ok(shp[3], mesh, "model"):
                dims[3] = "model"
            elif ps.endswith("ssm") and _div_ok(shp[2], mesh, "model"):
                dims[2] = "model"
        return NamedSharding(mesh, P(*dims))
    return jax.tree_util.tree_map_with_path(one, cache)


def state_specs(state, mesh):
    """Train state {params, opt{master,mu,nu,count}, step}."""
    pspecs = param_specs(state["params"], mesh)
    return {
        "params": pspecs,
        "opt": {
            "master": pspecs, "mu": pspecs, "nu": pspecs,
            "count": NamedSharding(mesh, P()),
        },
        "step": NamedSharding(mesh, P()),
    }


# ---------------------------------------------------------------- control plane
def control_plane_mesh(n_shards: int | None = None):
    """1-D ``("shards",)`` mesh for the sharded control plane.

    The ECI control-plane shard pipeline (``core.shard_pipeline``)
    partitions the window tape by whole tenant-segments over this axis.
    Uses every local device by default; ``n_shards`` caps the mesh (and
    degrades gracefully to however many devices exist, so single-device
    hosts run the sharded path as a 1-shard mesh bit-identically).  On
    CPU hosts the test/CI harness forces
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the mesh
    exercises real multi-device semantics everywhere.
    """
    devices = jax.devices()
    k = len(devices) if n_shards is None else max(1, min(int(n_shards),
                                                         len(devices)))
    return jax.sharding.Mesh(np.array(devices[:k]), ("shards",))
