"""GPipe-style pipeline parallelism over the ``pod`` (DCN) axis.

For cross-pod scale-out an alternative to pure DP is to place layer ranges
(stages) on different pods and stream microbatches through a
``collective_permute`` ring: only stage-boundary activations cross the DCN
link (B_mb × S × d bytes per tick) instead of full gradient reductions.

Implementation: ``shard_map`` over the pipeline axis; each device group
holds its stage's layer slice (params pre-sharded with leading stage dim);
the classic GPipe schedule runs n_micro + n_stages - 1 ticks with bubble
fraction (S-1)/(M+S-1).

Provided as an opt-in feature (DP over ``pod`` is the default):
``pipeline_forward`` is the composable primitive (works under jit, grads
flow through ``ppermute``), exercised by ``tests/test_pipeline.py`` and the
``--tag pp_demo`` dry-run variant.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_forward", "stack_stages"]


def stack_stages(params, n_stages: int):
    """Split a stacked-layer param tree [L, ...] into [n_stages, L/S, ...]."""
    def one(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])
    return jax.tree.map(one, params)


def pipeline_forward(stage_fn, stage_params, x, *, mesh, axis: str = "pod",
                     n_microbatches: int = 2):
    """Run ``x`` [B, ...] through n_stages sequential stages on the ``axis``
    ring of ``mesh``.

    stage_fn(stage_params_slice, h) -> h : applies one stage's layers.
    stage_params: tree with leading [n_stages, ...] (sharded over ``axis``).
    Returns the final-stage output, valid on every device (broadcast back).
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    B = x.shape[0]
    assert B % n_microbatches == 0
    mb = B // n_microbatches

    other_axes = [a for a in mesh.axis_names if a != axis]

    def per_pod(p_stage, x_local):
        # p_stage: this pod's layer slice (leading stage dim stripped to 1)
        p_my = jax.tree.map(lambda t: t[0], p_stage)
        stage = jax.lax.axis_index(axis)
        ticks = n_microbatches + n_stages - 1
        x_mb = x_local.reshape(n_microbatches, mb, *x_local.shape[1:])

        carry_in = jnp.zeros((mb, *x_local.shape[1:]), x_local.dtype)
        outs = jnp.zeros((n_microbatches, mb, *x_local.shape[1:]),
                         x_local.dtype)

        def tick(t, state):
            carry, outs = state
            # stage 0 injects microbatch t (when available)
            inject = x_mb[jnp.clip(t, 0, n_microbatches - 1)]
            h_in = jnp.where(stage == 0, inject, carry)
            h_out = stage_fn(p_my, h_in)
            # last stage collects microbatch (t - (n_stages - 1))
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_microbatches - 1)
            take = jnp.logical_and(stage == n_stages - 1,
                                   t >= n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs,
                jnp.where(take, h_out, outs[out_idx]),
                out_idx, axis=0)
            # forward the activation ring: stage i -> i+1
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            carry = jax.lax.ppermute(h_out, axis, perm)
            return carry, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (carry_in, outs))
        # broadcast final-stage results to every pod (psum of masked outs)
        mask = (stage == n_stages - 1).astype(outs.dtype)
        outs = jax.lax.psum(outs * mask, axis)
        return outs.reshape(B, *x_local.shape[1:])

    in_specs = (P(axis), P(*[None] * x.ndim))
    out_specs = P(*[None] * x.ndim)
    fn = shard_map(per_pod, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn(stage_params, x)
