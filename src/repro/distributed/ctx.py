"""Activation-sharding context: sequence parallelism without threading
mesh objects through every model function.

``activation_rules`` installs named NamedShardings (e.g. "act" → scan-carry
hidden states sharded [DP, model, None]); ``constrain`` is a no-op unless a
rule is installed, so single-device tests/smoke runs never touch GSPMD.

SP rationale: with ``lax.scan`` + remat, the dominant residual is the per-
layer carry h [B, S, d].  Sharding its sequence axis over ``model`` cuts the
stored bytes by the TP degree; GSPMD inserts the all-gather at attention
entry and the reduce-scatter after wo — the standard Megatron-SP schedule.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["activation_rules", "constrain", "current_rules"]

_RULES: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "activation_rules", default=None)


@contextlib.contextmanager
def activation_rules(rules: dict):
    """rules: name -> NamedSharding (or PartitionSpec under a mesh ctx)."""
    tok = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(tok)


def current_rules() -> dict | None:
    return _RULES.get()


def constrain(x: jax.Array, name: str) -> jax.Array:
    rules = _RULES.get()
    if not rules or name not in rules:
        return x
    sh = rules[name]
    spec = getattr(sh, "spec", None)
    mesh = getattr(sh, "mesh", None)
    if spec is not None and mesh is not None:
        # drop axes that do not divide the dim (safety across arch shapes)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        fixed = []
        for i, entry in enumerate(tuple(spec) + (None,) * (x.ndim - len(spec))):
            if entry is None or i >= x.ndim:
                fixed.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= sizes.get(a, 1)
            fixed.append(entry if x.shape[i] % total == 0 else None)
        sh = NamedSharding(mesh, P(*fixed[:x.ndim]))
    return jax.lax.with_sharding_constraint(x, sh)


def default_decode_rules(mesh) -> dict:
    """Decode-only rules: weight-stationary MLP (§Perf iteration D2).

    One decode token per sequence makes activations ~1000× smaller than the
    weights; re-sharding the MLP input's d_model over ``data`` lets every
    FSDP shard contract its resident weight slice (partial-sum all-reduce of
    a few MB of activations) instead of all-gathering hundreds of MB of
    weights per layer."""
    return {"dec_mlp": NamedSharding(mesh, P(None, None, "data"))}


def default_train_rules(mesh, *, sp: bool = True,
                        attn_heads: bool = True) -> dict:
    """Baseline rules for train/prefill: DP batch, optional SP sequence.

    ``attn_heads`` adds head-sharded q/k/v constraints inside attention so
    the sequence all-gather happens once per layer (Megatron-SP schedule)
    instead of inside every flash tile iteration (§Perf iteration 1).
    """
    from repro.distributed.sharding import DP
    dp = DP(mesh)
    seq = "model" if sp else None
    rules = {"act": NamedSharding(mesh, P(dp, seq, None))}
    if attn_heads:
        rules["attn_qkv"] = NamedSharding(mesh, P(dp, None, "model", None))
    if sp:
        # explicit Megatron-SP schedule: one seq all-gather at block entry,
        # ff/head-sharded intermediates, seq-sharded residual carry.  Without
        # these, GSPMD hits a model-axis double-use conflict in the MLP
        # backward (seq-sharded activations × ff-sharded weights) and
        # resolves it by all-gathering FULL weights per layer (§Perf T5).
        rules["gathered"] = NamedSharding(mesh, P(dp, None, None))
        rules["mlp_mid"] = NamedSharding(mesh, P(dp, None, "model"))
    # grouped MoE buffers [B(groups), E, c, d]: groups over DP, experts EP
    rules["moe_xe"] = NamedSharding(mesh, P(dp, "model", None, None))
    return rules
