"""Synthetic LM data pipeline: deterministic, restartable, prefetched.

Batches are generated from a counter-keyed PRNG (step index → batch), so a
restarted trainer resumes the *exact* stream from its checkpoint step — the
data pipeline is stateless and elastically re-shardable (the global batch is
generated identically on any mesh and sharded by pjit).

A background thread keeps ``prefetch`` batches ahead (double buffering the
host→device edge).
"""
from __future__ import annotations

import queue
import threading

import numpy as np

__all__ = ["SyntheticLM", "PrefetchIterator"]


class SyntheticLM:
    """Zipf-distributed token stream with next-token labels."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, zipf_a: float = 1.3,
                 enc_dim: int | None = None, enc_len: int | None = None):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.zipf_a = zipf_a
        self.enc_dim = enc_dim
        self.enc_len = enc_len

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        # bounded zipf via inverse-CDF on a truncated harmonic series
        u = rng.random((self.batch, self.seq + 1))
        ranks = np.floor((u ** (-1.0 / (self.zipf_a - 1.0))) - 1.0)
        toks = np.clip(ranks, 0, self.vocab - 1).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.enc_dim:
            batch["enc_embeds"] = rng.standard_normal(
                (self.batch, self.enc_len or self.seq, self.enc_dim)
            ).astype(np.float32)
        return batch


class PrefetchIterator:
    """Runs ``source.batch_at(step)`` in a worker thread, ``prefetch`` deep."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 prefetch: int = 2):
        self.source = source
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        s = self.step
        while not self._stop.is_set():
            try:
                self._q.put(self.source.batch_at(s), timeout=0.1)
                s += 1
            except queue.Full:
                continue

    def __next__(self) -> dict[str, np.ndarray]:
        b = self._q.get()
        self.step += 1
        return b

    def __iter__(self):
        return self

    def close(self) -> None:
        self._stop.set()
