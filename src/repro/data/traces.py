"""Synthetic block-trace generator calibrated to the paper's workloads.

The MSR-Cambridge traces (SNIA IOTTA) are not redistributable offline, so we
synthesize traces whose *published statistics* match the paper:

  * per-workload request-type mix — Fig. 12 (CR/CW/RAR/RAW/WAR/WAW ratios);
  * locality — Zipfian re-reference over a working set (random workloads) or
    streaming address ramps (sequential workloads);
  * run lengths — Table 2 relative runtimes.

The generator is constructive: it draws, per re-touch, the *target class*
(RAR/RAW/WAR/WAW) and picks a previously-read or previously-written address
accordingly, so the realized mix converges to the requested one.  Cold
accesses extend the working set.  This gives exact control over the very
quantities URD/Alg. 3 depend on.

Also included: Filebench-like profiles for the Fig. 4 motivation experiment
(fileserver, varmail, webserver, ... ) expressed as mix+locality parameters.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.trace import Trace

__all__ = ["WorkloadProfile", "MSR_PROFILES", "FILEBENCH_PROFILES",
           "generate_trace", "msr_trace", "filebench_trace",
           "sequential_then_random", "random_then_sequential",
           "semi_sequential"]


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Target statistics for one synthetic workload.

    cold_read/cold_write/rar/raw/war/waw: target fractions (sum ~ 1).
    zipf_a: Zipf exponent for re-reference locality (higher = tighter).
    working_set: approximate number of distinct blocks.
    sequential: if True, cold accesses stream (defeats caching, paper Fig. 9a).
    """

    cold_read: float
    cold_write: float
    rar: float
    raw: float
    war: float
    waw: float
    zipf_a: float = 1.2
    working_set: int = 4096
    sequential: bool = False
    # Re-touch depth exponents: rank ~ u**a over most-recent-first pools.
    # Large a -> shallow (recent) re-touches; small a -> deep re-touches.
    # The paper's Eq. 1 case-2 workloads (TRD >> URD) arise when write
    # re-touches are much deeper than read re-touches: a slowly-cycled large
    # write set inflates TRD while the hot read set keeps URD small.
    read_depth_a: float | None = None    # default: zipf_a
    write_depth_a: float = 0.35
    # Hard bound on how deep read re-touches reach into the access pool:
    # bounds URD (and the useful cache size) structurally, while write
    # re-touches range over the whole pool (inflating TRD).  None = unbounded
    # (Eq. 1 case 1: TRD == URD).
    read_reach: int | None = 256

    def normalized(self) -> "WorkloadProfile":
        s = (self.cold_read + self.cold_write + self.rar + self.raw
             + self.war + self.waw)
        return dataclasses.replace(
            self, cold_read=self.cold_read / s, cold_write=self.cold_write / s,
            rar=self.rar / s, raw=self.raw / s, war=self.war / s,
            waw=self.waw / s)


# Request-type mixes approximating paper Fig. 12 (per-workload descriptions in
# §6.4/§6.6: e.g. wdev_0 ~77% WAW + mostly-RAR rest; hm_1 >92% RAR;
# prxy_0/web_0 WAW/WAR-heavy; stg_1/mds_1/prn_1 RAR/RAW-dominant, etc.).
# ``read_reach`` / cold rates are tuned so the TRD/URD size ratios land where
# the paper reports them (stg_1 Centaur ~1000x ECI, rsrch_2 extreme,
# mds_0/proj_0 sizes occasionally equal — App. A).
MSR_PROFILES: dict[str, WorkloadProfile] = {
    "wdev_0":  WorkloadProfile(0.04, 0.12, 0.12, 0.02, 0.00, 0.70,
                               read_reach=128),
    "web_1":   WorkloadProfile(0.10, 0.12, 0.20, 0.05, 0.04, 0.49,
                               read_reach=192),
    "stg_1":   WorkloadProfile(0.06, 0.34, 0.08, 0.06, 0.06, 0.40,
                               working_set=1 << 17, read_reach=96),
    "ts_0":    WorkloadProfile(0.05, 0.15, 0.10, 0.02, 0.05, 0.63,
                               read_reach=160),
    "hm_1":    WorkloadProfile(0.05, 0.03, 0.88, 0.02, 0.00, 0.02,
                               read_reach=384),
    "mds_0":   WorkloadProfile(0.04, 0.12, 0.08, 0.03, 0.05, 0.68,
                               read_reach=256, write_depth_a=0.9),
    "proj_0":  WorkloadProfile(0.03, 0.26, 0.08, 0.03, 0.06, 0.54,
                               read_reach=256, write_depth_a=0.9),
    "prxy_0":  WorkloadProfile(0.02, 0.10, 0.06, 0.04, 0.08, 0.70,
                               read_reach=96),
    "rsrch_0": WorkloadProfile(0.02, 0.12, 0.05, 0.03, 0.09, 0.69,
                               read_reach=96),
    "src1_2":  WorkloadProfile(0.02, 0.12, 0.05, 0.02, 0.10, 0.69,
                               read_reach=96),
    "prn_1":   WorkloadProfile(0.08, 0.12, 0.38, 0.22, 0.05, 0.15,
                               working_set=1 << 16, read_reach=512),
    "src2_0":  WorkloadProfile(0.03, 0.12, 0.06, 0.03, 0.07, 0.69,
                               read_reach=96),
    "web_0":   WorkloadProfile(0.03, 0.10, 0.08, 0.04, 0.10, 0.65,
                               read_reach=128),
    "usr_0":   WorkloadProfile(0.10, 0.15, 0.33, 0.17, 0.08, 0.17,
                               working_set=1 << 16, read_reach=384),
    "rsrch_2": WorkloadProfile(0.02, 0.38, 0.005, 0.005, 0.15, 0.44,
                               sequential=True, read_reach=32),
    "mds_1":   WorkloadProfile(0.06, 0.10, 0.43, 0.25, 0.06, 0.10,
                               working_set=1 << 15, read_reach=320),
}

# Paper Table 2 run-times (minutes) -> relative trace lengths.
MSR_RUNTIME_MIN: dict[str, int] = {
    "wdev_0": 1140, "web_1": 160, "stg_1": 2190, "ts_0": 1800, "hm_1": 600,
    "mds_0": 1210, "proj_0": 4220, "prxy_0": 12510, "rsrch_0": 1430,
    "src1_2": 1900, "prn_1": 11230, "src2_0": 1550, "web_0": 2020,
    "usr_0": 2230, "rsrch_2": 200, "mds_1": 1630,
}

# Fig. 4 Filebench personalities (read/write mixes per Filebench docs; the
# observations in §3 drive the expected WB-vs-RO outcomes).
FILEBENCH_PROFILES: dict[str, WorkloadProfile] = {
    "fileserver":       WorkloadProfile(0.10, 0.15, 0.25, 0.20, 0.10, 0.20),
    "randomrw":         WorkloadProfile(0.05, 0.05, 0.25, 0.25, 0.20, 0.20),
    "varmail":          WorkloadProfile(0.08, 0.12, 0.25, 0.30, 0.10, 0.15),
    "webserver":        WorkloadProfile(0.10, 0.02, 0.76, 0.02, 0.02, 0.08),
    "copyfiles":        WorkloadProfile(0.45, 0.45, 0.02, 0.02, 0.03, 0.03,
                                        sequential=True),
    "webproxy":         WorkloadProfile(0.12, 0.03, 0.72, 0.03, 0.02, 0.08),
    "mongo":            WorkloadProfile(0.25, 0.15, 0.30, 0.10, 0.05, 0.15,
                                        sequential=True),
    "singlestreamread": WorkloadProfile(0.30, 0.02, 0.60, 0.04, 0.02, 0.02,
                                        working_set=1024),
}


def generate_trace(profile: WorkloadProfile, n: int, seed: int = 0,
                   name: str = "") -> Trace:
    """Draw an n-access trace matching ``profile``'s target class mix."""
    p = profile.normalized()
    rng = np.random.default_rng(seed)
    addrs = np.empty(n, dtype=np.int64)
    is_read = np.empty(n, dtype=bool)

    read_pool: list[int] = []     # addresses whose last touch was a read
    write_pool: list[int] = []    # addresses whose last touch was a write
    next_cold = 0                 # streaming frontier for cold addresses

    classes = rng.choice(6, size=n, p=[p.cold_read, p.cold_write, p.rar,
                                       p.raw, p.war, p.waw])
    # Zipf ranks for picking *which* previously-touched address to re-use.
    zipf_u = rng.random(n)

    read_a = p.read_depth_a if p.read_depth_a is not None else p.zipf_a

    def pick(pool: list[int], u: float, a: float, reach: int | None) -> int:
        # Zipf-like: rank ~ u**a over most-recent-first ordering, optionally
        # truncated to the most recent ``reach`` entries.
        k = len(pool)
        if reach is not None:
            k = min(k, reach)
        r = int((u ** a) * k)
        return pool[len(pool) - 1 - min(r, k - 1)]

    for i in range(n):
        c = int(classes[i])
        if c >= 2:
            src_read = c in (2, 4)       # RAR/WAR re-touch a last-read addr
            pool = read_pool if src_read else write_pool
            if not pool:                 # nothing to re-touch yet -> cold
                c = 0 if c in (2, 3) else 1
        if c == 0 or c == 1:
            a = next_cold if p.sequential else int(rng.integers(0, 2**31))
            next_cold += 1
            rd = c == 0
        else:
            src_read = c in (2, 4)
            pool = read_pool if src_read else write_pool
            # current access type decides the depth: reads (RAR/RAW) re-touch
            # recent data, writes (WAR/WAW) cycle deep through their set.
            if c in (2, 3):
                a = pick(pool, float(zipf_u[i]), read_a, p.read_reach)
            else:
                a = pick(pool, float(zipf_u[i]), p.write_depth_a, None)
            rd = c in (2, 3)
        addrs[i] = a
        is_read[i] = rd
        # update pools: address moves to the pool of its current access type
        if rd:
            read_pool.append(a)
            if len(read_pool) > p.working_set:
                read_pool.pop(0)
        else:
            write_pool.append(a)
            if len(write_pool) > p.working_set:
                write_pool.pop(0)
    return Trace(addrs, is_read, name)


def msr_trace(name: str, n: int = 20000, seed: int = 0) -> Trace:
    return generate_trace(MSR_PROFILES[name], n, seed, name)


def filebench_trace(name: str, n: int = 20000, seed: int = 0) -> Trace:
    return generate_trace(FILEBENCH_PROFILES[name], n, seed, name)


# ---------------------------------------------------------------- Appendix C
def sequential_then_random(n_seq: int, n_rand: int, seed: int = 0) -> Trace:
    """Paper App. C case 1: streaming interval then random repeats."""
    rng = np.random.default_rng(seed)
    seq = np.arange(n_seq, dtype=np.int64)
    rand = rng.choice(seq, size=n_rand, replace=True)
    addrs = np.concatenate([seq, rand])
    return Trace(addrs, np.ones(len(addrs), bool), "seq-rand")


def random_then_sequential(n_rand: int, n_seq: int, ws: int = 64,
                           seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    rand = rng.integers(0, ws, size=n_rand).astype(np.int64)
    seq = np.arange(10**6, 10**6 + n_seq, dtype=np.int64)
    addrs = np.concatenate([rand, seq, rand])
    reads = np.concatenate([np.ones(n_rand, bool), np.zeros(n_seq, bool),
                            np.ones(n_rand, bool)])
    return Trace(addrs, reads, "rand-seq")


def semi_sequential(stride: int, repeats: int, seed: int = 0) -> Trace:
    base = np.arange(stride, dtype=np.int64)
    addrs = np.tile(base, repeats)
    return Trace(addrs, np.ones(len(addrs), bool), "semi-seq")
