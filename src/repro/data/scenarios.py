"""Deterministic labeled multi-tenant scenario generator (test artifact).

The trace suite that exercises the *online* half of ECI-Cache: every
scenario is a sequence of Δt windows per tenant where each tenant moves
through explicitly labeled workload *phases* (ReCA's regimes — PAPERS.md,
arxiv 1805.06747), so phase-detection quality is measurable against ground
truth instead of eyeballed.  Every access carries its phase label (all
accesses of a (window, tenant) cell share the cell's label —
``access_labels``), and ``changes[w, t]`` marks exactly the windows where
tenant t entered a new phase (the detection targets; a tenant's very first
active window is a cold start, not a change).

Scenarios (all deterministic in ``seed``; see ``SCENARIOS``):

  * ``diurnal``     — every tenant alternates day (read-heavy hot-set,
    high load) and night (write-heavy batch, low load) regimes.
  * ``bursty``      — stationary background with deterministic burst
    windows per tenant: 5× load on a tight hot set, then back.
  * ``churn``       — tenants join and retire mid-run (plus one joiner
    that changes phase after joining): the scenario for the manager's
    churn invariants.
  * ``scan_flood``  — adversarial noisy neighbor: victims run stationary
    cache-friendly workloads while the aggressor flips mid-run from a
    benign mix to a high-rate sequential scan flood (the classic
    partition-stealing attack; feeds the isolation metric in
    ``benchmarks.bench_scenarios``).
  * ``correlated``  — every tenant changes phase in the *same* window
    (the hardest re-partitioning spike).

Phase-address disjointness: each (tenant, phase) run draws from its own
address-space slot (``_addr_offset``), so a phase change also moves the
working set — Jaccard drift is a real signal, and cross-tenant addresses
never collide.  Within a phase the accesses are one continuous
``generate_trace`` stream chopped into windows, so consecutive same-phase
windows overlap addresses the way a stationary workload does.

``replay_scenario`` drives an ``ECICacheManager`` (or anything with its
``run_window``/``add_tenant`` interface) through a scenario — handling
join/retire churn — and supports *differential replay*: ``exclude`` a
tenant (e.g. the aggressor) and every other tenant sees the identical
per-window traces, which is exactly the counterfactual the isolation
metric needs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.faults import FaultPlan, FaultSpec
from repro.core.trace import Trace
from repro.data.traces import WorkloadProfile, generate_trace

__all__ = [
    "Phase", "ScenarioRun", "SCENARIOS",
    "FaultScenario", "FAULT_SCENARIOS",
    "PH_MIXED", "PH_READ_HOT", "PH_WRITE_BATCH", "PH_BURST", "PH_SCAN",
    "diurnal", "bursty", "churn", "scan_flood", "correlated",
    "faulted_tier_loss", "faulted_straggler_burst", "faulted_poisoned_join",
    "build_scenario", "replay_scenario", "per_tenant_latency",
]


# ------------------------------------------------------- phase vocabulary
# Profiles are chosen so adjacent phases are far apart along the
# characterization axes (read mix, sequentiality, working set, reuse):
# a detector with hi=0.25 sees scores well above threshold at every
# labeled change and well below it within a phase.

#: benign balanced mix (the background phase almost everywhere)
PH_MIXED = WorkloadProfile(0.08, 0.06, 0.40, 0.16, 0.10, 0.20,
                           working_set=2048, read_reach=256)
#: read-heavy hot-set serving (day regime)
PH_READ_HOT = WorkloadProfile(0.08, 0.02, 0.78, 0.05, 0.02, 0.05,
                              working_set=2048, read_reach=256)
#: write-heavy batch (night regime; write_ratio crosses w_threshold=0.5)
PH_WRITE_BATCH = WorkloadProfile(0.03, 0.12, 0.05, 0.05, 0.25, 0.50,
                                 working_set=4096, read_reach=128)
#: burst: very tight hot set, reuse-dominated
PH_BURST = WorkloadProfile(0.03, 0.02, 0.80, 0.10, 0.02, 0.03,
                           working_set=256, read_reach=64)
#: sequential scan flood (cold-dominated streaming; defeats caching)
PH_SCAN = WorkloadProfile(0.75, 0.20, 0.02, 0.01, 0.01, 0.01,
                          sequential=True)


@dataclasses.dataclass(frozen=True)
class Phase:
    """One labeled phase of one tenant: a profile and per-window loads.

    ``cycle`` switches the phase from the profile generator to a pure
    cyclic read scan over ``cycle`` distinct blocks — the LRU-cliff
    workload (hit ratio is a step at exactly ``cycle`` blocks, URD =
    ``cycle``), the canonical capacity-sensitive victim for isolation
    experiments.  ``profile`` is ignored when ``cycle`` is set.
    """

    profile: WorkloadProfile
    label: int
    lengths: tuple[int, ...]          # accesses per window, len = #windows
    cycle: int | None = None


@dataclasses.dataclass(frozen=True)
class ScenarioRun:
    """A materialized scenario: labeled per-(window, tenant) traces.

    ``traces[w][t]`` is ``None`` while tenant t is inactive (not yet
    joined, or retired).  ``labels[w, t]`` is the ground-truth phase id
    (-1 inactive); ``changes[w, t]`` marks phase-transition windows.
    """

    name: str
    n_windows: int
    tenant_names: list[str]
    traces: list[list[Trace | None]]
    labels: np.ndarray                # int64[windows, tenants]
    changes: np.ndarray               # bool[windows, tenants]
    join_windows: np.ndarray          # int64[tenants]
    retire_windows: np.ndarray        # int64[tenants]; n_windows = never
    aggressor: int | None = None
    seed: int = 0

    @property
    def n_tenants(self) -> int:
        return len(self.tenant_names)

    def access_labels(self, window: int, tenant: int) -> np.ndarray:
        """Ground-truth phase label per access of one (window, tenant)."""
        tr = self.traces[window][tenant]
        n = 0 if tr is None else len(tr)
        return np.full(n, self.labels[window, tenant], dtype=np.int64)

    def true_changes(self) -> list[tuple[int, int]]:
        """(window, tenant) pairs of every labeled phase change."""
        w, t = np.nonzero(self.changes)
        return list(zip(w.tolist(), t.tolist()))


def _addr_offset(tenant: int, phase: int) -> int:
    """Disjoint address-space slot per (tenant, phase) run.

    Slots stay below 2**43 so the monitor's composite-key sort path
    (address bits + position bits <= 62) keeps working at every scale the
    suite uses.
    """
    if not (0 <= tenant < 64 and 0 <= phase < 32):
        raise ValueError(f"scenario slot out of range: ({tenant}, {phase})")
    return (tenant * 32 + phase + 1) << 32


def _mix_seed(seed: int, tenant: int, phase: int) -> int:
    return (seed * 1_000_003 + tenant * 8_191 + phase * 131) & 0x7FFFFFFF


def build_scenario(name: str, tenant_names: list[str],
                   phase_plans: list[list[Phase]],
                   join_windows: list[int] | None = None,
                   n_windows: int | None = None,
                   aggressor: int | None = None,
                   seed: int = 0) -> ScenarioRun:
    """Materialize per-tenant phase plans into a labeled ``ScenarioRun``.

    Tenant t is active from ``join_windows[t]`` for
    ``sum(len(p.lengths) for p in phase_plans[t])`` windows, then retires
    (``n_windows`` extends the run past the last retirement; tenants whose
    plan reaches the end never retire).
    """
    nt = len(tenant_names)
    joins = list(join_windows) if join_windows is not None else [0] * nt
    spans = [sum(len(p.lengths) for p in plans) for plans in phase_plans]
    total = n_windows if n_windows is not None else max(
        j + s for j, s in zip(joins, spans))
    traces: list[list[Trace | None]] = [[None] * nt for _ in range(total)]
    labels = np.full((total, nt), -1, dtype=np.int64)
    changes = np.zeros((total, nt), dtype=bool)
    retire = np.full(nt, total, dtype=np.int64)

    for t, plans in enumerate(phase_plans):
        w = joins[t]
        for p_idx, ph in enumerate(plans):
            n_total = int(sum(ph.lengths))
            if ph.cycle is not None:
                addrs = np.arange(n_total, dtype=np.int64) % int(ph.cycle)
                tr = Trace(addrs, np.ones(n_total, dtype=bool),
                           tenant_names[t])
            else:
                tr = generate_trace(ph.profile, n_total,
                                    seed=_mix_seed(seed, t, p_idx),
                                    name=tenant_names[t])
            addrs = tr.addrs + _addr_offset(t, p_idx)
            cuts = np.concatenate(
                [[0], np.cumsum(np.asarray(ph.lengths, dtype=np.int64))])
            for j in range(len(ph.lengths)):
                if w >= total:
                    break
                traces[w][t] = Trace(addrs[cuts[j]:cuts[j + 1]],
                                     tr.is_read[cuts[j]:cuts[j + 1]],
                                     tenant_names[t])
                labels[w, t] = ph.label
                # the first window of a *later* phase is a change target
                changes[w, t] = (j == 0 and p_idx > 0)
                w += 1
        if w < total:
            retire[t] = w
    return ScenarioRun(name, total, list(tenant_names), traces, labels,
                       changes, np.asarray(joins, dtype=np.int64), retire,
                       aggressor=aggressor, seed=seed)


# ------------------------------------------------------------- scenarios
def diurnal(n_tenants: int = 4, cycles: int = 2, day: int = 3,
            night: int = 3, n_day: int = 900, n_night: int = 400,
            seed: int = 0) -> ScenarioRun:
    """Day/night regime alternation: load and mix swing together."""
    plans = []
    for _t in range(n_tenants):
        phases = []
        for _c in range(cycles):
            phases.append(Phase(PH_READ_HOT, 0, (n_day,) * day))
            phases.append(Phase(PH_WRITE_BATCH, 1, (n_night,) * night))
        plans.append(phases)
    return build_scenario("diurnal", [f"d{t}" for t in range(n_tenants)],
                          plans, seed=seed)


def bursty(n_tenants: int = 4, n_windows: int = 10, n_base: int = 400,
           burst_mult: int = 5, seed: int = 0) -> ScenarioRun:
    """Stationary background with deterministic per-tenant burst windows."""
    rng = np.random.default_rng(seed)
    plans = []
    for t in range(n_tenants):
        # bursts last 3 windows: the detector cold-restarts after a
        # trigger, so phases shorter than warmup+2 windows are beneath its
        # resolution (the burst's *exit* would land inside the warm-up)
        burst_at = int(rng.integers(2, n_windows - 3))
        phases = [Phase(PH_MIXED, 0, (n_base,) * burst_at),
                  Phase(PH_BURST, 1, (n_base * burst_mult,) * 3),
                  Phase(PH_MIXED, 2, (n_base,) * (n_windows - burst_at - 3))]
        plans.append(phases)
    return build_scenario("bursty", [f"b{t}" for t in range(n_tenants)],
                          plans, n_windows=n_windows, seed=seed)


def churn(n_stable: int = 3, n_windows: int = 10, n_base: int = 500,
          seed: int = 0) -> ScenarioRun:
    """Join/retire churn: stable core, an early-retiring tenant, a late
    joiner, and a joiner that changes phase after joining."""
    names, plans, joins = [], [], []
    for t in range(n_stable):
        names.append(f"stable{t}")
        plans.append([Phase(PH_MIXED, 0, (n_base,) * n_windows)])
        joins.append(0)
    names.append("retiree")
    plans.append([Phase(PH_READ_HOT, 0, (n_base,) * (n_windows // 2))])
    joins.append(0)
    names.append("joiner")
    plans.append([Phase(PH_READ_HOT, 0, (n_base,) * (n_windows - 3))])
    joins.append(3)
    names.append("shifter")
    plans.append([Phase(PH_READ_HOT, 0, (n_base,) * 3),
                  Phase(PH_WRITE_BATCH, 1, (n_base,) * (n_windows - 5))])
    joins.append(2)
    return build_scenario("churn", names, plans, join_windows=joins,
                          n_windows=n_windows, seed=seed)


def scan_flood(n_victims: int = 4, n_windows: int = 10, flood_at: int = 4,
               n_victim: int = 2500, n_benign: int = 1200,
               flood_mult: int = 4, cycle_base: int = 1500,
               cycle_step: int = 200, seed: int = 0) -> ScenarioRun:
    """Noisy neighbor: the last tenant turns into a sequential scan flood.

    Victims are cyclic LRU-cliff workloads with staggered cycle sizes
    (``cycle_base + t * cycle_step`` blocks): each victim's hit ratio is a
    step function at its cycle, so losing even a slice of capacity to the
    aggressor collapses it from all-hits to all-misses — the
    capacity-sensitive tenant the isolation metric needs.  (A Zipf victim
    saturates long before realistic shares and would mask the theft.)
    """
    names = [f"victim{t}" for t in range(n_victims)] + ["aggressor"]
    plans = [[Phase(PH_READ_HOT, 0, (n_victim,) * n_windows,
                    cycle=cycle_base + t * cycle_step)]
             for t in range(n_victims)]
    plans.append([Phase(PH_MIXED, 0, (n_benign,) * flood_at),
                  Phase(PH_SCAN, 1,
                        (n_benign * flood_mult,) * (n_windows - flood_at))])
    return build_scenario("scan_flood", names, plans, n_windows=n_windows,
                          aggressor=n_victims, seed=seed)


def correlated(n_tenants: int = 5, n_windows: int = 8, switch_at: int = 4,
               n_base: int = 600, seed: int = 0) -> ScenarioRun:
    """Every tenant changes phase in the same window (correlated spike)."""
    before = (PH_READ_HOT, PH_MIXED)
    after = (PH_WRITE_BATCH, PH_SCAN)
    plans = []
    for t in range(n_tenants):
        plans.append([Phase(before[t % 2], 0, (n_base,) * switch_at),
                      Phase(after[t % 2], 1,
                            (n_base,) * (n_windows - switch_at))])
    return build_scenario("correlated", [f"c{t}" for t in range(n_tenants)],
                          plans, n_windows=n_windows, seed=seed)


#: name -> builder (all deterministic in their ``seed`` kwarg)
SCENARIOS = {
    "diurnal": diurnal,
    "bursty": bursty,
    "churn": churn,
    "scan_flood": scan_flood,
    "correlated": correlated,
}


# ------------------------------------------------------- fault scenarios
@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """A labeled chaos case: a workload scenario plus the fault schedule
    to run it under.  ``plan`` tenant indices are *manager* tenant indices
    (scenario order for window-0 tenants, ``add_tenant`` order for later
    joiners — see ``replay_scenario``)."""

    name: str
    run: ScenarioRun
    plan: FaultPlan
    description: str = ""


def faulted_tier_loss(seed: int = 0) -> FaultScenario:
    """L1 device loss mid write-batch phase (diurnal night): dirty WB
    windows are in flight, so the crash exercises dirty-loss accounting,
    immediate WB demotion, and the post-recovery cooldown."""
    run = diurnal(n_tenants=4, cycles=2, seed=seed)     # 12 windows
    plan = FaultPlan((
        FaultSpec("tier_loss", window=4, level=1, duration=2),
    ), seed=seed)
    return FaultScenario("faulted_tier_loss", run, plan,
                         "L1 loss at window 4 for 2 windows, inside the "
                         "first night phase")


def faulted_straggler_burst(seed: int = 0) -> FaultScenario:
    """Straggler tapes exactly at the correlated phase-change window —
    the manager must hold the late tenants at last-known-good *while*
    re-partitioning everyone else through the spike, then fold the
    deferred tapes in."""
    run = correlated(seed=seed)                         # 8 windows, switch@4
    plan = FaultPlan((
        FaultSpec("straggler", window=4, tenant=0, duration=2),
        FaultSpec("straggler", window=4, tenant=2),
        FaultSpec("pipeline", window=4, rung="host", count=1),
    ), seed=seed)
    return FaultScenario("faulted_straggler_burst", run, plan,
                         "two stragglers plus one launch retry at the "
                         "correlated switch window")


def faulted_poisoned_join(seed: int = 0) -> FaultScenario:
    """A tenant joins mid-run already emitting corrupt tapes: the ingest
    validator must quarantine the newcomer (empty window, held sizing)
    without disturbing the stable tenants or the same-window joiner."""
    run = churn(seed=seed)                              # 10 windows
    # churn manager layout: stable0-2 -> 0..2, retiree -> 3,
    # shifter (joins w2) -> 4, joiner (joins w3) -> 5
    plan = FaultPlan((
        FaultSpec("poison", window=3, tenant=5, duration=2),
    ), seed=seed)
    return FaultScenario("faulted_poisoned_join", run, plan,
                         "the window-3 joiner's first two tapes are "
                         "poisoned")


#: name -> builder (all deterministic in their ``seed`` kwarg)
FAULT_SCENARIOS = {
    "faulted_tier_loss": faulted_tier_loss,
    "faulted_straggler_burst": faulted_straggler_burst,
    "faulted_poisoned_join": faulted_poisoned_join,
}


# ------------------------------------------------------------ replay glue
def replay_scenario(run: ScenarioRun, manager_factory,
                    exclude: frozenset[int] | set[int] = frozenset(),
                    engine: str | None = None):
    """Drive a manager through a scenario, handling join/retire churn.

    ``manager_factory(names)`` builds the manager over the tenants active
    in window 0 (scenario order); later joiners enter via
    ``manager.add_tenant``.  ``exclude`` drops scenario tenants entirely
    (differential replay: every remaining tenant sees identical traces).
    Returns ``(manager, index_map)`` with ``index_map[scenario_tenant] =
    manager_tenant`` for every replayed tenant.
    """
    excl = set(exclude)
    order = [t for t in range(run.n_tenants) if t not in excl]
    initial = [t for t in order if run.join_windows[t] == 0]
    mgr = manager_factory([run.tenant_names[t] for t in initial])
    imap = {t: k for k, t in enumerate(initial)}
    for w in range(run.n_windows):
        for t in order:
            if t not in imap and run.join_windows[t] == w:
                imap[t] = mgr.add_tenant(run.tenant_names[t])
        traces: list[Trace | None] = [None] * len(mgr.tenants)
        for t, k in imap.items():
            traces[k] = run.traces[w][t]
        mgr.run_window(traces, engine=engine) if engine is not None \
            else mgr.run_window(traces)
    return mgr, imap


def per_tenant_latency(mgr, imap: dict[int, int]) -> dict[int, float]:
    """Mean replay latency per *scenario* tenant index."""
    out = {}
    for t, k in imap.items():
        res = mgr.tenants[k].result
        out[t] = res.total_latency / max(res.n, 1)
    return out
