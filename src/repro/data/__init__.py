"""Data substrate: synthetic LM pipeline + block-trace generators."""
