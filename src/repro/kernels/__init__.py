"""Pallas TPU kernels for the performance-critical hot spots.

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper with interpret fallback on CPU) and ref.py (pure-jnp
oracle used by the allclose test sweeps).
"""
from jax.experimental.pallas import tpu as _pltpu

__all__ = ["tpu_compiler_params"]


def tpu_compiler_params(**kwargs):
    """Version-portable ``compiler_params`` for ``pl.pallas_call``.

    jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
    support both so the kernels run on every toolchain in the fleet.
    """
    cls = getattr(_pltpu, "CompilerParams", None) \
        or getattr(_pltpu, "TPUCompilerParams")
    return cls(**kwargs)
