"""Pallas TPU kernels for the performance-critical hot spots.

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper with interpret fallback on CPU) and ref.py (pure-jnp
oracle used by the allclose test sweeps).
"""
