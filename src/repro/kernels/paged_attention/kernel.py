"""Paged decode attention — Pallas TPU kernel with block-table indirection.

This is the serving-side hot path of the ECI-Cache integration: the KV pages
of a request live scattered in the HBM block pool (the paper's "SSD cache"),
located through a per-request block table.  The kernel walks the table with
*scalar prefetch* (``pltpu.PrefetchScalarGridSpec``) so the page index feeds
the BlockSpec ``index_map`` — Pallas issues the HBM→VMEM DMA for page ``i+1``
while page ``i`` is being processed, hiding the gather latency the same way
vLLM's paged attention hides it with warp-level prefetch on GPU (TPU
adaptation: DMA double-buffering replaces warp scheduling).

Grid: (batch, kv_heads, pages_per_seq); the page axis is innermost /
sequential with fp32 running (m, l, acc) scratch for the online softmax over
the q-head group that shares each KV head (GQA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

__all__ = ["paged_attention"]

_NEG_INF = -1e30


def _kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, page_size: int):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    seq_len = lens_ref[b]
    page_start = pi * page_size

    @pl.when(page_start < seq_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # [g, d]
        k = k_ref[0, :, 0].astype(jnp.float32)          # [page, d]
        v = v_ref[0, :, 0].astype(jnp.float32)          # [page, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [g, page]
        pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < seq_len, s, _NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, seq_lens: jax.Array, *,
                    scale: float | None = None,
                    interpret: bool = False) -> jax.Array:
    """Decode attention over a paged KV pool.

    q:            [B, Hq, D]      (one new token per sequence)
    k/v_pages:    [n_pool_pages, page_size, Hkv, D]
    block_tables: [B, pages_per_seq] int32 (pool page ids, 0-padded)
    seq_lens:     [B] int32 valid KV length per sequence
    returns       [B, Hq, D]
    """
    B, Hq, D = q.shape
    n_pool, page_size, Hkv, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / np.sqrt(D)

    q_g = q.reshape(B, Hkv, g, D)
    kernel = functools.partial(_kernel, scale=scale, page_size=page_size)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, g, D),
                         lambda b, h, pi, tables, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, D),
                         lambda b, h, pi, tables, lens: (tables[b, pi], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, D),
                         lambda b, h, pi, tables, lens: (tables[b, pi], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D),
                               lambda b, h, pi, tables, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, seq_lens, q_g, k_pages, v_pages)
    return out.reshape(B, Hq, D)
