"""Jit'd wrapper for paged decode attention (TPU kernel / CPU fallback)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

__all__ = ["paged_attention_op"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel",))
def paged_attention_op(q, k_pages, v_pages, block_tables, seq_lens, *,
                       use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        return paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                               interpret=not _on_tpu())
    return paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens)
