"""Pure-jnp oracle: gather pages into a dense cache, run masked attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["paged_attention_ref", "gather_pages"]


def gather_pages(pages: jax.Array, block_tables: jax.Array) -> jax.Array:
    """[n_pool, page, H, D] + [B, n_per_seq] -> dense [B, S_max, H, D]."""
    gathered = pages[block_tables]          # [B, n_per_seq, page, H, D]
    B, n, p, H, D = gathered.shape
    return gathered.reshape(B, n * p, H, D)


def paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens, *,
                        scale: float | None = None):
    if scale is None:
        scale = 1.0 / np.sqrt(q.shape[-1])
    k = gather_pages(k_pages, block_tables)       # [B, S, Hkv, D]
    v = gather_pages(v_pages, block_tables)
    B, S, Hkv, D = k.shape
    Hq = q.shape[1]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, None, :] < seq_lens[:, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
