"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

The SSD algorithm splits the sequence into chunks of Q tokens: within a
chunk the SSM output is a masked quadratic form (two MXU matmuls), across
chunks a [headdim, state] recurrence is carried.  Grid:
(batch*heads, num_chunks) with the chunk axis innermost/sequential — the
carried state lives in fp32 VMEM scratch, exactly mirroring
``repro.models.mamba2.mamba2_forward``'s ``lax.scan`` (the jnp oracle).

Per chunk, with decay ``seg = cumsum(dt*A)``:
  y_intra = ((C Bᵀ) ⊙ L) (x·dt)      L[i,j] = exp(seg_i - seg_j), i>=j
  y_inter = (C · h_prev) ⊙ exp(seg)
  h_new   = h_prev · exp(seg_Q) + Σ_j exp(seg_Q - seg_j) B_j (x_j·dt_j)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

__all__ = ["mamba2_ssd"]


def _kernel(x_ref, b_ref, c_ref, seg_ref, y_ref, h_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)        # [Q, P] (already x * dt)
    B = b_ref[0].astype(jnp.float32)        # [Q, N]
    C = c_ref[0].astype(jnp.float32)        # [Q, N]
    seg = seg_ref[0].astype(jnp.float32)    # [Q, 1]

    # intra-chunk quadratic part
    L = jnp.exp(jnp.clip(seg - seg.T, -60.0, 0.0))          # [Q, Q]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, L, 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    y = jax.lax.dot_general(scores * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [Q,P]

    # inter-chunk contribution from the carried state h [N, P]
    decay_in = jnp.exp(jnp.clip(seg, -60.0, 0.0))                      # [Q,1]
    y += decay_in * jax.lax.dot_general(
        C, h_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update
    seg_last = seg[chunk - 1:chunk, :]                                 # [1,1]
    decay_out = jnp.exp(jnp.clip(seg_last - seg, -60.0, 0.0))          # [Q,1]
    s_new = jax.lax.dot_general(B, decay_out * x,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)    # [N,P]
    h_scr[...] = h_scr[...] * jnp.exp(jnp.clip(seg_last, -60.0, 0.0)) \
        + s_new
    y_ref[0] = y.astype(y_ref.dtype)


def mamba2_ssd(x_dt: jax.Array, B: jax.Array, C: jax.Array,
               seg: jax.Array, *, chunk: int,
               interpret: bool = False) -> jax.Array:
    """Chunked SSD scan.

    x_dt: [BH, S, P]   (x * dt, flattened batch*heads)
    B:    [BH, S, N]   (input matrix, already broadcast per head group)
    C:    [BH, S, N]
    seg:  [BH, S, 1]   per-chunk cumsum of dt*A (reset at chunk starts)
    returns y [BH, S, P]
    """
    BH, S, P = x_dt.shape
    N = B.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, ci: (b, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, ci: (b, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x_dt.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x_dt, B, C, seg)
