"""Pure-jnp oracle for the SSD chunked scan: sequential recurrence.

y_t = C_t · h_t + (skip handled by caller);  h_t = h_{t-1}·exp(dA_t) + B_t x_t
with x already premultiplied by dt.  ``seg`` is the within-chunk cumsum of
dA; the sequential reference reconstructs per-step dA from seg diffs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["mamba2_ssd_ref", "seg_from_dA"]


def seg_from_dA(dA: jax.Array, chunk: int) -> jax.Array:
    """[BH, S] per-step dA -> within-chunk cumsum [BH, S, 1]."""
    BH, S = dA.shape
    nc = S // chunk
    seg = jnp.cumsum(dA.reshape(BH, nc, chunk), axis=-1)
    return seg.reshape(BH, S, 1)


def mamba2_ssd_ref(x_dt: jax.Array, B: jax.Array, C: jax.Array,
                   dA: jax.Array) -> jax.Array:
    """Sequential scan oracle.  x_dt [BH,S,P], B/C [BH,S,N], dA [BH,S]."""
    BH, S, P = x_dt.shape
    N = B.shape[-1]

    def step(h, inp):
        x_t, b_t, c_t, da_t = inp
        h = h * jnp.exp(da_t)[:, None, None] \
            + jnp.einsum("bn,bp->bnp", b_t, x_t)
        y = jnp.einsum("bn,bnp->bp", c_t, h)
        return h, y

    h0 = jnp.zeros((BH, N, P), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (x_dt.astype(jnp.float32).transpose(1, 0, 2),
         B.astype(jnp.float32).transpose(1, 0, 2),
         C.astype(jnp.float32).transpose(1, 0, 2),
         dA.astype(jnp.float32).T))
    return ys.transpose(1, 0, 2).astype(x_dt.dtype)
