"""Jit'd wrapper for the SSD chunked scan."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.mamba2_ssd.kernel import mamba2_ssd
from repro.kernels.mamba2_ssd.ref import mamba2_ssd_ref, seg_from_dA

__all__ = ["mamba2_ssd_op"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def mamba2_ssd_op(x_dt, B, C, dA, *, chunk: int = 256,
                  use_kernel: bool | None = None):
    """x_dt [BH,S,P], B/C [BH,S,N], dA [BH,S] -> y [BH,S,P]."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        seg = seg_from_dA(dA, chunk)
        return mamba2_ssd(x_dt, B, C, seg, chunk=chunk,
                          interpret=not _on_tpu())
    return mamba2_ssd_ref(x_dt, B, C, dA)
