"""Flash attention (prefill) — Pallas TPU kernel.

Tiling: grid = (batch, q_heads, num_q_blocks, num_kv_blocks) with the KV
block axis innermost (sequential on TPU), so the fp32 running max / sum /
accumulator live in VMEM scratch and persist across KV iterations — the
canonical online-softmax schedule (FlashAttention-2 adapted to the MXU:
[block_q, head_dim] × [head_dim, block_kv] contractions hit the 128×128
systolic array when block sizes are multiples of 128).

Causal + sliding-window masking is applied per tile; fully-masked tiles are
skipped via ``pl.when`` on the block indices (the triangular schedule), so
the causal kernel does ~half the tile work of the dense one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int,
            block_q: int, block_kv: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv

    # tile-level reachability: skip tiles fully outside the causal/window band
    reachable = True
    if causal:
        reachable = k_start <= q_start + block_q - 1
    if window > 0:
        reachable = jnp.logical_and(
            reachable, k_start + block_kv - 1 > q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)          # [bk, dv]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
        mask = k_pos < seq_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]                           # [bq, 1]
        m_blk = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.named_call, name="flash_attention_pallas")
def _noop(x):
    return x


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B, Hq, Sq, D]; k/v: [B, Hq, Skv, D] (caller repeats GQA heads).

    Returns [B, Hq, Sq, Dv].  Sequences are padded to block multiples
    internally; padded KV positions are masked.
    """
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    Dv = v.shape[-1]
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    block_q = min(block_q, max(Sq, 8))
    block_kv = min(block_kv, max(Skv, 8))
    nq = -(-Sq // block_q)
    nk = -(-Skv // block_kv)
    pq, pk = nq * block_q - Sq, nk * block_kv - Skv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, seq_len=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, Dv), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]
