"""Jit'd public wrapper for flash attention.

On TPU this lowers the Pallas kernel; elsewhere (this CPU container) it runs
the kernel body in interpret mode, or falls back to the jnp reference for
speed when ``interpret=False`` is requested off-TPU.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention_op"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv",
                                   "use_kernel"))
def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       block_q: int = 128, block_kv: int = 128,
                       use_kernel: bool | None = None):
    """q/k/v: [B, H, S, D] (GQA pre-repeated) -> [B, H, Sq, Dv]."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=not _on_tpu())
    return attention_ref(q, k, v, causal=causal, window=window)
