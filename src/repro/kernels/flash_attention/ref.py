"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["attention_ref"]


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  scale: float | None = None) -> jax.Array:
    """q/k/v: [B, H, S, D] -> [B, H, Sq, Dv]; materializes full scores."""
    if scale is None:
        scale = 1.0 / np.sqrt(q.shape[-1])
    Sq, Skv = q.shape[2], k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
