"""Occupancy-masked LRU stack-distance counting — Pallas TPU kernel.

The batch simulation engine (``repro.core.batch_sim``) turns window replay
into the counting problem

    SD(i) = #{ j : prev[i] < j < i,  occ[j],  nxt[j] >= i }

(an access is resident iff SD < capacity; see the batch_sim docstring for
the derivation).  This is the ``urd_scan`` counting formulation with one
extra per-``j`` occupancy mask: ``occ = 1`` everywhere for WB/WT (every
access installs or touches), ``occ = is_read`` for RO write-around.

Same layout as ``urd_scan``: O(n²/tile) masked counts over the (i, j)
plane, grid (num_i_tiles, num_j_tiles) with j innermost and an fp32 VMEM
accumulator revisited across j-tiles — pure VPU work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

__all__ = ["cache_sim_scan", "cache_sim_segments_scan",
           "cache_sim_levels_scan", "live_count_scan"]


def _kernel(prev_ref, nxt_ref, occ_ref, out_ref, acc_scr, *, tile: int):
    ii = pl.program_id(0)
    jj = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(jj == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    prev_i = prev_ref[0]                                 # [1, tile] int32
    i_idx = ii * tile + jax.lax.broadcasted_iota(
        jnp.int32, (tile, tile), 0)                      # rows: i
    j_idx = jj * tile + jax.lax.broadcasted_iota(
        jnp.int32, (tile, tile), 1)                      # cols: j
    nxt_j = nxt_ref[0]                                   # [1, tile] int32
    occ_j = occ_ref[0]                                   # [1, tile] int32

    contrib = (
        (j_idx > prev_i.reshape(tile, 1))
        & (j_idx < i_idx)
        & (nxt_j.reshape(1, tile) >= i_idx)
        & (occ_j.reshape(1, tile) > 0)
    )
    acc_scr[...] += jnp.sum(contrib.astype(jnp.float32), axis=1,
                            keepdims=True)

    @pl.when(jj == nj - 1)
    def _finalize():
        out_ref[0] = acc_scr[...].reshape(tile).astype(jnp.int32)


def cache_sim_scan(prev: jax.Array, nxt: jax.Array, occ: jax.Array, *,
                   tile: int = 256, interpret: bool = False) -> jax.Array:
    """prev/nxt int32[n] occurrence links, occ int32[n] -> counts int32[n].

    counts[i] = occupying distinct addresses strictly between prev[i] and i.
    Cold accesses (prev[i] < 0) return prefix counts — callers mask them.
    """
    n = prev.shape[0]
    nt = -(-n // tile)
    pad = nt * tile - n
    if pad:
        # padded i rows: prev = n (j > prev never holds -> count 0)
        prev = jnp.pad(prev, (0, pad), constant_values=n)
        # padded j cols: never occupy, and nxt = -1 as belt-and-braces
        nxt = jnp.pad(nxt, (0, pad), constant_values=-1)
        occ = jnp.pad(occ, (0, pad), constant_values=0)
    prev2 = prev.reshape(nt, tile).astype(jnp.int32)
    nxt2 = nxt.reshape(nt, tile).astype(jnp.int32)
    occ2 = occ.reshape(nt, tile).astype(jnp.int32)

    kernel = functools.partial(_kernel, tile=tile)
    out = pl.pallas_call(
        kernel,
        grid=(nt, nt),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j: (i, 0)),
            pl.BlockSpec((1, tile), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tile), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, tile), jnp.int32),
        scratch_shapes=[pltpu.VMEM((tile, 1), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(prev2, nxt2, occ2)
    return out.reshape(nt * tile)[:n]


def _segments_kernel(prev_ref, nxt_ref, occ_ref, out_ref, acc_scr, *,
                     tile: int, seg_width: int):
    ii = pl.program_id(0)
    jj = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(jj == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    prev_i = prev_ref[0]                                 # [1, tile] int32
    # the j plane is restricted to the i-tile's seg_width-aligned block
    j_base = (ii * tile) // seg_width * seg_width + jj * tile
    i_idx = ii * tile + jax.lax.broadcasted_iota(
        jnp.int32, (tile, tile), 0)                      # rows: i
    j_idx = j_base + jax.lax.broadcasted_iota(
        jnp.int32, (tile, tile), 1)                      # cols: j
    nxt_j = nxt_ref[0]                                   # [1, tile] int32
    occ_j = occ_ref[0]                                   # [1, tile] int32

    contrib = (
        (j_idx > prev_i.reshape(tile, 1))
        & (j_idx < i_idx)
        & (nxt_j.reshape(1, tile) >= i_idx)
        & (occ_j.reshape(1, tile) > 0)
    )
    acc_scr[...] += jnp.sum(contrib.astype(jnp.float32), axis=1,
                            keepdims=True)

    @pl.when(jj == nj - 1)
    def _finalize():
        out_ref[0] = acc_scr[...].reshape(tile).astype(jnp.int32)


def cache_sim_segments_scan(prev: jax.Array, nxt: jax.Array, occ: jax.Array,
                            *, seg_width: int, tile: int = 256,
                            interpret: bool = False) -> jax.Array:
    """``cache_sim_scan`` on a segment-aligned padded tape, restricted grid.

    The tape (length a multiple of ``seg_width``) holds one padded segment
    per ``seg_width``-aligned block (``batch_sim.padded_segment_layout``
    guarantees alignment), links are severed at segment boundaries and
    padding rows carry ``occ = 0``, so counting windows never cross blocks
    and every (i, j) tile outside the i-tile's own block contributes
    exactly zero (the dense proof lives in ``cache_sim_segments_ref``).
    The grid therefore shrinks from ``nt x nt`` to
    ``nt x (seg_width / tile)`` — the j loop visits only the aligned
    block, the kernel body is ``_kernel`` with the absolute j base offset.
    Cold and padding rows return prefix counts — callers mask them.

    This is the TPU counting route of both the per-width host launches
    (``ops.stack_distances_segments_accel``) and the fused device window
    program (``ops.segment_counts_device``, inlined into
    ``core.device_pipeline``'s single-jit window decision — there the
    call traces into the surrounding program, so no host sync separates
    it from the curve/write-ratio/partition stages); off TPU the fused
    program substitutes the O(m log² w) ``cache_sim_segments_tree``
    oracle instead of this kernel's interpret mode.
    """
    n = prev.shape[0]
    if seg_width < tile:
        tile = int(seg_width)                # pow2 >= 16: still a valid tile
    nt = n // tile
    jt = seg_width // tile
    prev2 = prev.reshape(nt, tile).astype(jnp.int32)
    nxt2 = nxt.reshape(nt, tile).astype(jnp.int32)
    occ2 = occ.reshape(nt, tile).astype(jnp.int32)

    kernel = functools.partial(_segments_kernel, tile=tile,
                               seg_width=seg_width)
    j_map = lambda i, j: ((i * tile) // seg_width * jt + j, 0)  # noqa: E731
    out = pl.pallas_call(
        kernel,
        grid=(nt, jt),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j: (i, 0)),
            pl.BlockSpec((1, tile), j_map),
            pl.BlockSpec((1, tile), j_map),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, tile), jnp.int32),
        scratch_shapes=[pltpu.VMEM((tile, 1), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(prev2, nxt2, occ2)
    return out.reshape(nt * tile)[:n]


def _live_kernel(nxt_ref, occ_ref, out_ref, acc_scr, *, tile: int):
    ii = pl.program_id(0)
    jj = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(jj == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    i_idx = ii * tile + jax.lax.broadcasted_iota(
        jnp.int32, (tile, tile), 0)                      # rows: i
    j_idx = jj * tile + jax.lax.broadcasted_iota(
        jnp.int32, (tile, tile), 1)                      # cols: j
    nxt_j = nxt_ref[0]                                   # [1, tile] int32
    occ_j = occ_ref[0]                                   # [1, tile] int32

    contrib = (
        (j_idx <= i_idx)
        & (nxt_j.reshape(1, tile) > i_idx)
        & (occ_j.reshape(1, tile) > 0)
    )
    acc_scr[...] += jnp.sum(contrib.astype(jnp.float32), axis=1,
                            keepdims=True)

    @pl.when(jj == nj - 1)
    def _finalize():
        out_ref[0] = acc_scr[...].reshape(tile).astype(jnp.int32)


def live_count_scan(nxt: jax.Array, occ: jax.Array, *, tile: int = 256,
                    interpret: bool = False) -> jax.Array:
    """nxt int32[n] occurrence links, occ int32[n] -> live counts int32[n].

    counts[i] = #{ j <= i : occ[j], nxt[j] > i } — the RO write-around
    live count (occupying tokens resident after access i assuming no
    eviction).  Same (i, j)-plane layout as ``cache_sim_scan`` with the
    interval test flipped to "covers i from the left"; this is the
    accelerator path of the batch engine's RO no-eviction guard, feeding
    the eviction-token replay dispatch (see ``batch_sim``).
    """
    n = nxt.shape[0]
    nt = -(-n // tile)
    pad = nt * tile - n
    if pad:
        # padded j cols: never occupy, and nxt = -1 never covers a row
        nxt = jnp.pad(nxt, (0, pad), constant_values=-1)
        occ = jnp.pad(occ, (0, pad), constant_values=0)
    nxt2 = nxt.reshape(nt, tile).astype(jnp.int32)
    occ2 = occ.reshape(nt, tile).astype(jnp.int32)

    kernel = functools.partial(_live_kernel, tile=tile)
    out = pl.pallas_call(
        kernel,
        grid=(nt, nt),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tile), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, tile), jnp.int32),
        scratch_shapes=[pltpu.VMEM((tile, 1), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(nxt2, occ2)
    return out.reshape(nt * tile)[:n]


def _levels_kernel(prev_ref, nxt_ref, occ_ref, cap1_ref, captot_ref,
                   l1_ref, un_ref, acc_scr, *, tile: int):
    ii = pl.program_id(0)
    jj = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(jj == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    prev_i = prev_ref[0]                                 # [1, tile] int32
    i_idx = ii * tile + jax.lax.broadcasted_iota(
        jnp.int32, (tile, tile), 0)
    j_idx = jj * tile + jax.lax.broadcasted_iota(
        jnp.int32, (tile, tile), 1)
    nxt_j = nxt_ref[0]
    occ_j = occ_ref[0]

    contrib = (
        (j_idx > prev_i.reshape(tile, 1))
        & (j_idx < i_idx)
        & (nxt_j.reshape(1, tile) >= i_idx)
        & (occ_j.reshape(1, tile) > 0)
    )
    acc_scr[...] += jnp.sum(contrib.astype(jnp.float32), axis=1,
                            keepdims=True)

    @pl.when(jj == nj - 1)
    def _finalize():
        cnt = acc_scr[...].reshape(tile).astype(jnp.int32)
        hot = prev_i >= 0                                # cold rows -> 0
        l1_ref[0] = (hot & (cnt < cap1_ref[0])).astype(jnp.int32)
        un_ref[0] = (hot & (cnt < captot_ref[0])).astype(jnp.int32)


def cache_sim_levels_scan(prev: jax.Array, nxt: jax.Array, occ: jax.Array,
                          cap1: jax.Array, captot: jax.Array, *,
                          tile: int = 256, interpret: bool = False
                          ) -> tuple[jax.Array, jax.Array]:
    """Both-level residency masks in one launch (same counting layout).

    The accumulated count is compared in-kernel against the two per-access
    capacity thresholds (``cap1[i]`` = L1 blocks, ``captot[i]`` = L1 + L2
    blocks of the access's tenant): an access is an L1 hit iff
    ``SD < cap1`` and a hierarchy hit iff ``SD < captot`` — the exclusive
    two-level hierarchy's union is a single LRU stack (see batch_sim).
    Returns int32 0/1 masks ``(l1, union)``; cold rows are 0.
    """
    n = prev.shape[0]
    nt = -(-n // tile)
    pad = nt * tile - n
    if pad:
        prev = jnp.pad(prev, (0, pad), constant_values=n)
        nxt = jnp.pad(nxt, (0, pad), constant_values=-1)
        occ = jnp.pad(occ, (0, pad), constant_values=0)
        cap1 = jnp.pad(cap1, (0, pad), constant_values=0)
        captot = jnp.pad(captot, (0, pad), constant_values=0)
    shape2 = (nt, tile)
    args = [a.reshape(shape2).astype(jnp.int32)
            for a in (prev, nxt, occ, cap1, captot)]

    kernel = functools.partial(_levels_kernel, tile=tile)
    i_spec = pl.BlockSpec((1, tile), lambda i, j: (i, 0))
    j_spec = pl.BlockSpec((1, tile), lambda i, j: (j, 0))
    l1, un = pl.pallas_call(
        kernel,
        grid=(nt, nt),
        in_specs=[i_spec, j_spec, j_spec, i_spec, i_spec],
        out_specs=(i_spec, i_spec),
        out_shape=(jax.ShapeDtypeStruct(shape2, jnp.int32),
                   jax.ShapeDtypeStruct(shape2, jnp.int32)),
        scratch_shapes=[pltpu.VMEM((tile, 1), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
    return (l1.reshape(nt * tile)[:n], un.reshape(nt * tile)[:n])
