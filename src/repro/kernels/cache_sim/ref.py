"""Pure-jnp oracle for the occupancy-masked stack-distance kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cache_sim_ref"]


def cache_sim_ref(prev: jax.Array, nxt: jax.Array,
                  occ: jax.Array) -> jax.Array:
    """counts[i] = #{ j : prev[i] < j < i, occ[j], nxt[j] >= i } (dense O(n²)).

    With ``occ = 1`` everywhere this is the per-access LRU stack distance
    (the batch-sim hit oracle: resident ⟺ SD < capacity); restricting
    ``occ`` to reads gives the RO write-around live-distance.
    """
    n = prev.shape[0]
    i_idx = jnp.arange(n)[:, None]
    j_idx = jnp.arange(n)[None, :]
    contrib = ((j_idx > prev[:, None]) & (j_idx < i_idx)
               & (nxt[None, :] >= i_idx) & (occ[None, :] > 0))
    return jnp.sum(contrib, axis=1).astype(jnp.int32)
