"""Pure-jnp oracles for the occupancy-masked stack-distance kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cache_sim_ref", "cache_sim_levels_ref", "cache_sim_segments_ref",
           "cache_sim_segments_tree", "live_counts_delta", "live_counts_ref"]


def cache_sim_ref(prev: jax.Array, nxt: jax.Array,
                  occ: jax.Array) -> jax.Array:
    """counts[i] = #{ j : prev[i] < j < i, occ[j], nxt[j] >= i } (dense O(n²)).

    With ``occ = 1`` everywhere this is the per-access LRU stack distance
    (the batch-sim hit oracle: resident ⟺ SD < capacity); restricting
    ``occ`` to reads gives the RO write-around live-distance.
    """
    n = prev.shape[0]
    i_idx = jnp.arange(n)[:, None]
    j_idx = jnp.arange(n)[None, :]
    contrib = ((j_idx > prev[:, None]) & (j_idx < i_idx)
               & (nxt[None, :] >= i_idx) & (occ[None, :] > 0))
    return jnp.sum(contrib, axis=1).astype(jnp.int32)


def cache_sim_segments_ref(prev: jax.Array, nxt: jax.Array, occ: jax.Array,
                           seg_width: int) -> jax.Array:
    """``cache_sim_ref`` on a segment-aligned padded tape (dense oracle).

    The tape is laid out in ``seg_width``-aligned blocks, one padded
    segment per block (``batch_sim.padded_segment_layout``), so no
    counting window ``(prev[i], i)`` of a hot access ever crosses a block
    — the ``j`` plane is masked to the query's own block and everything
    outside it is provably zero (severed links never reach past a segment,
    padding rows carry ``occ = 0``).  This is the jnp oracle for the
    width-restricted Pallas grid of ``cache_sim_segments_scan``, which
    simply never visits the masked-off (i, j) tiles.
    """
    n = prev.shape[0]
    i_idx = jnp.arange(n)[:, None]
    j_idx = jnp.arange(n)[None, :]
    same = (j_idx // seg_width) == (i_idx // seg_width)
    contrib = ((j_idx > prev[:, None]) & (j_idx < i_idx)
               & (nxt[None, :] >= i_idx) & (occ[None, :] > 0) & same)
    return jnp.sum(contrib, axis=1).astype(jnp.int32)


def cache_sim_segments_tree(prev: jax.Array, nxt: jax.Array, occ: jax.Array,
                            seg_width: int) -> jax.Array:
    """``cache_sim_segments_ref`` without the dense (i, j) plane.

    A merge-sort tree over the segment-aligned tape: for every level
    ``s = 1, 2, 4, ..., seg_width/2`` the occupying ``nxt`` values are
    sorted inside each aligned ``s``-block, and each query interval
    ``(prev[i], i)`` is peeled into its canonical aligned blocks (at most
    two per level), each contributing a single ``searchsorted`` count of
    ``nxt >= i``.  Exactly the counts of the dense oracle, but
    O(m log² w) work and O(m) memory — this is the off-TPU production
    route of the fused device window program
    (``core.device_pipeline`` via ``ops.segment_counts_device``), where
    the dense plane would be quadratic in the whole window tape.
    Non-occupying rows (pads) carry value 0, below every real query key.
    """
    m = prev.shape[0]
    if m == 0:
        return jnp.zeros(0, jnp.int32)
    levels = max(int(seg_width).bit_length() - 1, 0)    # seg_width = 2**L
    kdt = jnp.int32 if m * (m + 2) < 2**31 else jnp.int64
    big = m + 2                                         # value field size
    pos = jnp.arange(m, dtype=kdt)
    v = jnp.where(occ > 0, nxt.astype(kdt) + 1, 0)      # +1: query is i+1
    a = jnp.where(prev >= 0, prev.astype(kdt) + 1, pos)  # cold: empty [i, i)
    b = pos
    q = pos + 1
    cnt = jnp.zeros(m, kdt)
    for lev in range(levels):
        s = 1 << lev
        srt = v if s == 1 else jnp.sort(v.reshape(-1, s), axis=1).reshape(-1)
        keys = (pos // s) * big + srt                   # sorted composite
        # left peel: a sits on an odd s-block of its 2s-parent
        do = (a < b) & ((a // s) % 2 == 1)
        blk = a // s
        p = jnp.searchsorted(keys, blk * big + q, side="left")
        cnt = cnt + jnp.where(do, (blk + 1) * s - p.astype(kdt), 0)
        a = a + jnp.where(do, s, 0)
        # right peel
        do = (a < b) & ((b // s) % 2 == 1)
        b = b - jnp.where(do, s, 0)
        blk = b // s
        p = jnp.searchsorted(keys, blk * big + q, side="left")
        cnt = cnt + jnp.where(do, (blk + 1) * s - p.astype(kdt), 0)
    return cnt.astype(jnp.int32)


def live_counts_ref(nxt: jax.Array, occ: jax.Array) -> jax.Array:
    """counts[i] = #{ j <= i : occ[j], nxt[j] > i } (dense O(n²) oracle).

    The RO write-around *live count*: how many occupying tokens (reads, or
    warm pseudo-reads) are resident after access ``i`` assuming no
    eviction — the no-eviction guard of the batch engine's RO paths, and
    the dispatcher feeding the eviction-token replays (host loops or their
    fori_loop device ports) when the bound is exceeded.  With ``occ``
    restricted to warm-L2 pseudo positions it counts the still-untouched
    warm-L2 blocks (the ``U2`` term of the per-level guard).
    """
    n = nxt.shape[0]
    i_idx = jnp.arange(n)[:, None]
    j_idx = jnp.arange(n)[None, :]
    contrib = ((j_idx <= i_idx) & (nxt[None, :] > i_idx)
               & (occ[None, :] > 0))
    return jnp.sum(contrib, axis=1).astype(jnp.int32)


def live_counts_delta(nxt: jax.Array, occ: jax.Array) -> jax.Array:
    """``live_counts_ref`` in O(n): scatter-add interval deltas + cumsum.

    Each occupying token is an interval ``[j, nxt[j])``: +1 at its birth,
    −1 at its death position, prefix-summed.  (``nxt[j] > j`` always, so a
    token dead by ``t`` was also born by ``t``.)  This is the production
    device path of the RO guard — the ``live_count_scan`` Pallas kernel
    computes the same counts on the tiled (i, j) plane and is kept as the
    in-kernel variant, validated against both forms.
    """
    n = nxt.shape[0]
    occi = (occ > 0).astype(jnp.int32)
    ends = jnp.zeros(n + 1, jnp.int32).at[jnp.clip(nxt, 0, n)].add(occi)
    return jnp.cumsum(occi) - jnp.cumsum(ends[:n])


def cache_sim_levels_ref(prev: jax.Array, nxt: jax.Array, occ: jax.Array,
                         cap1: jax.Array, captot: jax.Array
                         ) -> tuple[jax.Array, jax.Array]:
    """Both-level residency masks from one counting pass (jnp oracle).

    For the exclusive two-level hierarchy the union of the levels is a
    single LRU stack whose top ``cap1[i]`` entries are L1, so

        l1[i]    = prev[i] >= 0  and  SD(i) < cap1[i]
        union[i] = prev[i] >= 0  and  SD(i) < captot[i]

    (an access is an L2 hit iff ``union & ~l1``).  ``cap1``/``captot`` are
    per-access so one tape launch covers tenants with different quotas.
    """
    counts = cache_sim_ref(prev, nxt, occ)
    hot = prev >= 0
    return ((hot & (counts < cap1)).astype(jnp.int32),
            (hot & (counts < captot)).astype(jnp.int32))
