"""Pure-jnp oracles for the occupancy-masked stack-distance kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cache_sim_ref", "cache_sim_levels_ref"]


def cache_sim_ref(prev: jax.Array, nxt: jax.Array,
                  occ: jax.Array) -> jax.Array:
    """counts[i] = #{ j : prev[i] < j < i, occ[j], nxt[j] >= i } (dense O(n²)).

    With ``occ = 1`` everywhere this is the per-access LRU stack distance
    (the batch-sim hit oracle: resident ⟺ SD < capacity); restricting
    ``occ`` to reads gives the RO write-around live-distance.
    """
    n = prev.shape[0]
    i_idx = jnp.arange(n)[:, None]
    j_idx = jnp.arange(n)[None, :]
    contrib = ((j_idx > prev[:, None]) & (j_idx < i_idx)
               & (nxt[None, :] >= i_idx) & (occ[None, :] > 0))
    return jnp.sum(contrib, axis=1).astype(jnp.int32)


def cache_sim_levels_ref(prev: jax.Array, nxt: jax.Array, occ: jax.Array,
                         cap1: jax.Array, captot: jax.Array
                         ) -> tuple[jax.Array, jax.Array]:
    """Both-level residency masks from one counting pass (jnp oracle).

    For the exclusive two-level hierarchy the union of the levels is a
    single LRU stack whose top ``cap1[i]`` entries are L1, so

        l1[i]    = prev[i] >= 0  and  SD(i) < cap1[i]
        union[i] = prev[i] >= 0  and  SD(i) < captot[i]

    (an access is an L2 hit iff ``union & ~l1``).  ``cap1``/``captot`` are
    per-access so one tape launch covers tenants with different quotas.
    """
    counts = cache_sim_ref(prev, nxt, occ)
    hot = prev >= 0
    return ((hot & (counts < cap1)).astype(jnp.int32),
            (hot & (counts < captot)).astype(jnp.int32))
