"""Jit'd wrapper: occurrence links -> LRU stack distances on accelerator.

``stack_distances_accel`` is the TPU path of the batch simulation engine
(``repro.core.batch_sim.stack_distances``): counting runs in the Pallas
kernel on TPU, or via the jnp oracle elsewhere.  Matches the numpy
merge-tree host path exactly (tested in ``tests/test_batch_sim.py``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cache_sim.kernel import (cache_sim_levels_scan,
                                            cache_sim_scan,
                                            cache_sim_segments_scan,
                                            live_count_scan)
from repro.kernels.cache_sim.ref import (cache_sim_levels_ref, cache_sim_ref,
                                         cache_sim_segments_ref,
                                         cache_sim_segments_tree,
                                         live_counts_delta)

__all__ = ["cache_sim_op", "cache_sim_levels_op", "cache_sim_segments_op",
           "live_count_op", "segment_counts_device", "stack_distances_accel",
           "residency_levels_accel", "ro_live_counts_accel",
           "stack_distances_segments_accel", "width_groups_of"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel",))
def cache_sim_op(prev, nxt, occ, *, use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        return cache_sim_scan(prev, nxt, occ, interpret=not _on_tpu())
    return cache_sim_ref(prev, nxt, occ)


@partial(jax.jit, static_argnames=("use_kernel",))
def cache_sim_levels_op(prev, nxt, occ, cap1, captot, *,
                        use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        return cache_sim_levels_scan(prev, nxt, occ, cap1, captot,
                                     interpret=not _on_tpu())
    return cache_sim_levels_ref(prev, nxt, occ, cap1, captot)


@partial(jax.jit, static_argnames=("seg_width", "use_kernel"))
def cache_sim_segments_op(prev, nxt, occ, *, seg_width: int,
                          use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        return cache_sim_segments_scan(prev, nxt, occ, seg_width=seg_width,
                                       interpret=not _on_tpu())
    return cache_sim_segments_ref(prev, nxt, occ, seg_width)


@partial(jax.jit, static_argnames=("use_kernel",))
def live_count_op(nxt, occ, *, use_kernel: bool = False):
    if use_kernel:
        return live_count_scan(nxt, occ, interpret=not _on_tpu())
    return live_counts_delta(nxt, occ)


def width_groups_of(widths: np.ndarray) -> tuple[tuple[int, int, int], ...]:
    """Static (seg_width, lo, hi) spans of a padded tape's width runs.

    ``widths`` is ``padded_segment_layout``'s descending power-of-two
    width vector; each distinct width is one contiguous, self-aligned
    chunk ``[lo, hi)`` of the padded tape.  The tuple is hashable, so it
    serves as (part of) the jit static shape-bucket key of the fused
    device window program — retraces are bounded by the distinct width
    *structures*, not by raw window lengths.
    """
    widths = np.asarray(widths, dtype=np.int64)
    if widths.size == 0:
        return ()
    csw = np.concatenate([[0], np.cumsum(widths)]).astype(np.int64)
    heads = np.flatnonzero(
        np.concatenate([[True], widths[1:] != widths[:-1]]))
    return tuple((int(widths[h0]), int(csw[h0]), int(csw[int(h1)]))
                 for h0, h1 in zip(heads, np.append(heads[1:], widths.size)))


def segment_counts_device(gprev, gnxt, gocc,
                          width_groups: tuple[tuple[int, int, int], ...],
                          use_kernel: bool | None = None):
    """Traceable multi-width SD counting over a whole padded tape.

    The in-jit core of both ``stack_distances_segments_accel`` (which
    wraps it in one jitted launch per width and syncs per launch) and the
    fused device window program (``core.device_pipeline``, which inlines
    it so *no* host sync separates counting from the downstream segment
    reduction).  ``gprev``/``gnxt`` hold padded-tape-global links
    (``batch_sim.padded_tape_links``); each static ``(w, lo, hi)`` group
    is counted with the width-``w`` restricted grid (Pallas kernel on
    TPU, the O(m log² w) merge-sort-tree oracle
    ``cache_sim_segments_tree`` elsewhere — the dense (i, j) plane would
    be quadratic in the window tape) after localizing links to the
    group's own chunk.  Returns int32 counts for the full padded tape.
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    parts = []
    for w, lo, hi in width_groups:
        gp = gprev[lo:hi]
        loc_prev = jnp.where(gp >= 0, gp - lo, -1).astype(jnp.int32)
        loc_nxt = (gnxt[lo:hi] - lo).astype(jnp.int32)
        occ = gocc[lo:hi].astype(jnp.int32)
        if use_kernel:
            parts.append(cache_sim_segments_scan(loc_prev, loc_nxt, occ,
                                                 seg_width=w,
                                                 interpret=not _on_tpu()))
        else:
            parts.append(cache_sim_segments_tree(loc_prev, loc_nxt, occ, w))
    if not parts:
        return jnp.zeros(0, jnp.int32)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def ro_live_counts_accel(nxt: np.ndarray, occ: np.ndarray,
                         use_kernel: bool = False) -> np.ndarray:
    """int64 RO live counts ``L[i] = #{ j <= i : occ[j], nxt[j] > i }``.

    The accelerator path of the batch engine's write-around no-eviction
    guard: with ``occ = is_read`` it yields the live-block count per tape
    position, with ``occ`` restricted to warm-L2 pseudo positions the
    still-untouched warm-L2 count (``U2``).  Feeds the eviction-token
    replay dispatch (``_ro_token_replay`` / ``_ro_token_replay_levels``
    and their fori_loop device ports) so RO tenants under pressure are
    detected without leaving the device on TPU hosts.

    Default path is the O(n) delta-cumsum form (``live_counts_delta`` —
    interval counting is a prefix sum, no (i, j)-plane needed even
    in-kernel); ``use_kernel=True`` selects the tiled Pallas scan
    (``live_count_scan``), retained for launches that fuse the guard with
    the residency counting and as the interpret-mode validation target.
    """
    counts = np.asarray(live_count_op(jnp.asarray(nxt, jnp.int32),
                                      jnp.asarray(occ, jnp.int32),
                                      use_kernel=use_kernel))
    return counts.astype(np.int64)


def stack_distances_accel(prev: np.ndarray, nxt: np.ndarray,
                          occ: np.ndarray | None = None,
                          use_kernel: bool | None = None) -> np.ndarray:
    """int64 stack distances per access, -1 where cold (prev < 0)."""
    n = prev.shape[0]
    if occ is None:
        occ = np.ones(n, dtype=np.int32)
    counts = np.asarray(cache_sim_op(jnp.asarray(prev, jnp.int32),
                                     jnp.asarray(nxt, jnp.int32),
                                     jnp.asarray(occ, jnp.int32),
                                     use_kernel=use_kernel))
    out = np.full(n, -1, dtype=np.int64)
    hot = prev >= 0
    out[hot] = counts[hot].astype(np.int64)
    return out


def stack_distances_segments_accel(prev: np.ndarray, nxt: np.ndarray,
                                   bounds: np.ndarray | None = None,
                                   use_kernel: bool | None = None,
                                   layout=None, profile=None) -> np.ndarray:
    """SD counting for a multi-tenant *tape* (segment-severed links).

    The accelerator path of the fused monitor (``repro.core.monitor``):
    links are severed at tenant block boundaries and ``nxt`` is clamped to
    the owning block's end, so a hot access's counting window
    ``(prev[i], i)`` never crosses a segment and the cross-segment
    dominance contributions cancel.

    With ``bounds`` (the per-tenant segment offsets) the tape is re-laid
    out through ``batch_sim.padded_segment_layout`` — each segment padded
    to the next power of two and self-aligned, padding rows cold
    (``prev = -1``) and non-occupying (``occ = 0``) — and counted with
    **one launch per distinct padded width**, each launch restricted to
    the segment-aligned (i, j) grid blocks (``cache_sim_segments_scan`` /
    the ``cache_sim_segments_ref`` dense oracle).  Widths are powers of
    two, so jit retraces stay bounded.  Without ``bounds`` one
    unrestricted launch covers the whole tape, exactly like the batch
    replay engine's tape.

    ``profile`` (a ``device_pipeline.StageProfile``) records one host
    sync per width launch — the per-window sync count this path pays and
    the fused device program eliminates.
    """
    if bounds is None or len(bounds) <= 2:
        if profile is not None:
            profile.sync()
        return stack_distances_accel(prev, nxt, use_kernel=use_kernel)
    from repro.core.batch_sim import (padded_segment_layout,
                                      padded_tape_links)
    n = prev.shape[0]
    out = np.full(n, -1, dtype=np.int64)
    lay = layout if layout is not None else padded_segment_layout(bounds)
    src, tpos, base_src, base_pad, widths, total, _ = lay
    if tpos.size == 0:
        return out
    if src is None:                              # layout kept tape order
        src = np.arange(n, dtype=tpos.dtype)
    # padded tape with sentinel links: pads never occupy and stay cold
    gprev, gnxt, gocc = padded_tape_links(prev, nxt, lay)
    # widths descend, so each distinct width is one contiguous, aligned
    # chunk of the padded tape -> one restricted-grid launch per width
    counts = np.empty(total, dtype=np.int64)
    for w, lo, hi in width_groups_of(widths):
        gp = gprev[lo:hi]
        c = cache_sim_segments_op(
            jnp.asarray(np.where(gp >= 0, gp - lo, -1), jnp.int32),
            jnp.asarray(gnxt[lo:hi] - lo, jnp.int32),
            jnp.asarray(gocc[lo:hi]),
            seg_width=w, use_kernel=use_kernel)
        if profile is not None:
            profile.sync()                       # np.asarray blocks below
        counts[lo:hi] = np.asarray(c).astype(np.int64)
    hot = prev[src] >= 0
    out[src[hot]] = counts[tpos[hot]]
    return out


def residency_levels_accel(prev: np.ndarray, nxt: np.ndarray,
                           cap1: np.ndarray, captot: np.ndarray,
                           occ: np.ndarray | None = None,
                           use_kernel: bool | None = None
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Both-level residency as bool masks ``(l1, union)`` per access.

    The accelerator path of the two-level batch engine: one counting
    launch classifies every access of the tape against its tenant's L1 and
    L1+L2 capacity thresholds (see ``cache_sim_levels_scan``).
    """
    n = prev.shape[0]
    if occ is None:
        occ = np.ones(n, dtype=np.int32)
    l1, un = cache_sim_levels_op(jnp.asarray(prev, jnp.int32),
                                 jnp.asarray(nxt, jnp.int32),
                                 jnp.asarray(occ, jnp.int32),
                                 jnp.asarray(cap1, jnp.int32),
                                 jnp.asarray(captot, jnp.int32),
                                 use_kernel=use_kernel)
    return np.asarray(l1).astype(bool), np.asarray(un).astype(bool)
