"""Pure-jnp oracle for the reuse-distance counting kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["urd_scan_ref"]


def urd_scan_ref(prev: jax.Array, nxt: jax.Array) -> jax.Array:
    """counts[i] = #{ j : prev[i] < j < i, nxt[j] >= i } (dense O(n²))."""
    n = prev.shape[0]
    i_idx = jnp.arange(n)[:, None]
    j_idx = jnp.arange(n)[None, :]
    contrib = ((j_idx > prev[:, None]) & (j_idx < i_idx)
               & (nxt[None, :] >= i_idx))
    return jnp.sum(contrib, axis=1).astype(jnp.int32)
