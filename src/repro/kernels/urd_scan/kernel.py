"""URD/TRD reuse-distance counting — Pallas TPU kernel.

The paper's Analyzer spends its budget computing reuse distances (Appendix B
reports up to 22.7 s per window with modified PARDA on the host CPU).  On
TPU we use the counting formulation (DESIGN.md §5):

    RD(i) = #{ j : prev[i] < j < i  and  nxt[j] >= i }

(each distinct address between two touches contributes exactly one j — its
last occurrence inside the window).  This is an O(n²/tile) masked-count
over the (i, j) plane: embarrassingly parallel over i-tiles, sequential
accumulation over j-tiles — ideal VPU work, and ~3 orders of magnitude
faster than the pointer-chasing treap on host.  URD masking (only read
re-touches sample) is applied by the caller via ``sample_mask``.

Grid: (num_i_tiles, num_j_tiles), j innermost with an fp32 VMEM accumulator
revisited across j-tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["urd_scan"]


def _kernel(prev_ref, nxt_ref, out_ref, acc_scr, *, tile: int):
    ii = pl.program_id(0)
    jj = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(jj == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    prev_i = prev_ref[0]                                 # [1, tile] int32
    i_idx = ii * tile + jax.lax.broadcasted_iota(
        jnp.int32, (tile, tile), 0)                      # rows: i
    j_idx = jj * tile + jax.lax.broadcasted_iota(
        jnp.int32, (tile, tile), 1)                      # cols: j
    nxt_j = nxt_ref[0]                                   # [1, tile] int32

    contrib = (
        (j_idx > prev_i.reshape(tile, 1))
        & (j_idx < i_idx)
        & (nxt_j.reshape(1, tile) >= i_idx)
    )
    acc_scr[...] += jnp.sum(contrib.astype(jnp.float32), axis=1,
                            keepdims=True)

    @pl.when(jj == nj - 1)
    def _finalize():
        out_ref[0] = acc_scr[...].reshape(tile).astype(jnp.int32)


def urd_scan(prev: jax.Array, nxt: jax.Array, *, tile: int = 256,
             interpret: bool = False) -> jax.Array:
    """prev/nxt: int32[n] occurrence links -> counts int32[n].

    counts[i] = distinct addresses strictly between prev[i] and i.
    Cold accesses (prev[i] < 0) return counts over j<i with nxt>=i of the
    full prefix — callers mask them out with the sample mask.
    """
    n = prev.shape[0]
    nt = -(-n // tile)
    pad = nt * tile - n
    if pad:
        # padded i rows: prev = n (so j > prev never holds -> count 0)
        prev = jnp.pad(prev, (0, pad), constant_values=n)
        # padded j cols: nxt = -1 (so nxt >= i never holds -> no contribution)
        nxt = jnp.pad(nxt, (0, pad), constant_values=-1)
    prev2 = prev.reshape(nt, tile).astype(jnp.int32)
    nxt2 = nxt.reshape(nt, tile).astype(jnp.int32)

    kernel = functools.partial(_kernel, tile=tile)
    out = pl.pallas_call(
        kernel,
        grid=(nt, nt),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j: (i, 0)),
            pl.BlockSpec((1, tile), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, tile), jnp.int32),
        scratch_shapes=[pltpu.VMEM((tile, 1), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(prev2, nxt2)
    return out.reshape(nt * tile)[:n]
