"""Jit'd wrapper: trace -> reuse-distance samples on accelerator.

``reuse_distances_accel`` is the production Analyzer path: prev/next links
are computed with an O(n log n) host sort, the O(n²/tile) counting runs on
the TPU (kernel) or via the jnp oracle on CPU.  Matches
``repro.core.reuse_distance.reuse_distances`` exactly (tested).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.reuse_distance import RDResult
from repro.core.trace import Trace, prev_next_occurrence
from repro.kernels.urd_scan.kernel import urd_scan
from repro.kernels.urd_scan.ref import urd_scan_ref

__all__ = ["urd_scan_op", "reuse_distances_accel"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel",))
def urd_scan_op(prev, nxt, *, use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        return urd_scan(prev, nxt, interpret=not _on_tpu())
    return urd_scan_ref(prev, nxt)


def reuse_distances_accel(trace: Trace, kind: str = "urd",
                          use_kernel: bool | None = None) -> RDResult:
    """Accelerated drop-in for ``core.reuse_distance.reuse_distances``."""
    prev, nxt = prev_next_occurrence(trace.addrs)
    counts = np.asarray(urd_scan_op(jnp.asarray(prev, jnp.int32),
                                    jnp.asarray(nxt, jnp.int32),
                                    use_kernel=use_kernel))
    out = np.full(len(trace), -1, dtype=np.int64)
    mask = prev >= 0
    if kind == "urd":
        mask &= trace.is_read
    out[mask] = counts[mask]
    return RDResult(out, kind)
