"""Mamba-2 SSD (state-space duality) block — chunked-scan formulation.

Implements the paper's (arXiv:2405.21060) chunkwise algorithm: within a
chunk of Q tokens the SSM is evaluated as a masked quadratic attention-like
product (MXU-friendly), across chunks a linear recurrence on the
[H, P, N] state is carried by ``lax.scan``.  This is the TPU-native
adaptation: the quadratic intra-chunk part maps to the MXU, the recurrence
is O(S/Q) sequential — the same split the ``mamba2_ssd`` Pallas kernel uses.

Decode keeps an O(1) recurrent state (conv window + SSM state): the
"KV cache" of an SSM layer is a single page, which is why the paper's
paged-cache technique applies only partially to this family (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import Param, dense_init, rms_norm

Array = jax.Array
_F32 = jnp.float32

__all__ = ["init_mamba2_layer", "mamba2_forward", "mamba2_decode_step",
           "init_ssm_state"]


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_d_inner
    n_heads = cfg.ssm_n_heads
    conv_dim = d_inner + 2 * cfg.ssm_state      # x + B + C (n_groups = 1)
    return d_inner, n_heads, conv_dim


def init_mamba2_layer(key: Array, cfg: ModelConfig, dtype) -> Param:
    d = cfg.d_model
    d_inner, nh, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * cfg.ssm_state + nh
    return {
        "in_proj": dense_init(ks[0], (d, d_in_proj), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), dtype,
                             scale=1.0 / np.sqrt(cfg.ssm_conv)),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=_F32)),
        "D": jnp.ones((nh,), _F32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (nh,), _F32)
                    * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3)))),
        "out_proj": dense_init(ks[3], (d_inner, d), dtype),
        "norm": jnp.zeros((d_inner,), _F32),
    }


def _conv1d_causal(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv: x [B,S,C], w [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return out + b[None, None, :]


def _split_proj(zxbcdt: Array, cfg: ModelConfig):
    d_inner, nh, _ = _dims(cfg)
    N = cfg.ssm_state
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, xBC, dt


def mamba2_forward(p: Param, x: Array, cfg: ModelConfig,
                   use_kernel: bool | None = None) -> Array:
    """x: [B, S, d] -> [B, S, d] (chunked SSD).

    ``use_kernel=True`` routes the chunked scan through the
    ``mamba2_ssd`` Pallas kernel (the TPU production path; interpret mode
    off-TPU) — default: kernel on TPU, inline-jnp scan elsewhere.  Both
    paths implement identical math (pinned by tests).
    """
    B_, S, d = x.shape
    d_inner, nh, conv_dim = _dims(cfg)
    N, P, Q = cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_chunk
    Q = min(Q, S)
    assert S % Q == 0, f"seq {S} must be divisible by chunk {Q}"
    nc = S // Q
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"],
                        preferred_element_type=_F32).astype(x.dtype)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    xBC = jax.nn.silu(_conv1d_causal(xBC, p["conv_w"], p["conv_b"]))
    xs = xBC[..., :d_inner].reshape(B_, S, nh, P)
    Bm = xBC[..., d_inner:d_inner + N]                     # [B,S,N]
    Cm = xBC[..., d_inner + N:]                            # [B,S,N]

    dt = jax.nn.softplus(dt.astype(_F32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])                               # [H]
    dA = dt * A[None, None, :]                             # [B,S,H]

    if use_kernel:
        from repro.kernels.mamba2_ssd.kernel import mamba2_ssd
        from repro.kernels.mamba2_ssd.ref import seg_from_dA
        # flatten (batch, head) and broadcast the shared B/C per head
        x_dt = (xs.astype(_F32) * dt[..., None]).transpose(0, 2, 1, 3) \
            .reshape(B_ * nh, S, P)
        Bh = jnp.broadcast_to(Bm.astype(_F32)[:, None], (B_, nh, S, N)) \
            .reshape(B_ * nh, S, N)
        Ch = jnp.broadcast_to(Cm.astype(_F32)[:, None], (B_, nh, S, N)) \
            .reshape(B_ * nh, S, N)
        dAh = dA.transpose(0, 2, 1).reshape(B_ * nh, S)
        seg = seg_from_dA(dAh, Q)
        y = mamba2_ssd(x_dt, Bh, Ch, seg, chunk=Q,
                       interpret=jax.default_backend() != "tpu")
        y = y.reshape(B_, nh, S, P).transpose(0, 2, 1, 3)
        y = y + xs.astype(_F32) * p["D"][None, None, :, None]
        y = y.reshape(B_, S, d_inner)
        y = rms_norm((y * jax.nn.silu(z.astype(_F32))).astype(x.dtype),
                     p["norm"], cfg.rms_eps)
        return jnp.einsum("bse,ed->bsd", y, p["out_proj"],
                          preferred_element_type=_F32).astype(x.dtype)

    # chunk views
    xs_c = xs.reshape(B_, nc, Q, nh, P)
    B_c = Bm.reshape(B_, nc, Q, N).astype(_F32)
    C_c = Cm.reshape(B_, nc, Q, N).astype(_F32)
    dA_c = dA.reshape(B_, nc, Q, nh)
    dt_c = dt.reshape(B_, nc, Q, nh)
    seg = jnp.cumsum(dA_c, axis=2)                         # [B,nc,Q,H]

    xdt = (xs_c.astype(_F32) * dt_c[..., None])            # [B,nc,Q,H,P]
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    # rematerialized: the [B,Q,Q,H] decay/score tiles are recomputed in the
    # backward pass instead of being stored for every chunk.
    @jax.checkpoint
    def chunk_body(h_prev, inputs):
        x_q, B_q, C_q, seg_q, xdt_q = inputs
        # decay matrix L[i,j] = exp(seg_i - seg_j), i >= j
        L = jnp.exp(jnp.clip(seg_q[:, :, None, :] - seg_q[:, None, :, :],
                             -60.0, 0.0))                  # [B,Q,Q,H]
        L = jnp.where(tri[None, :, :, None], L, 0.0)
        scores = jnp.einsum("bin,bjn->bij", C_q, B_q,
                            preferred_element_type=_F32)   # [B,Q,Q]
        att = scores[..., None] * L                        # [B,Q,Q,H]
        y_intra = jnp.einsum("bijh,bjhp->bihp", att, xdt_q)
        # contribution of carried state
        decay_in = jnp.exp(jnp.clip(seg_q, -60.0, 0.0))    # [B,Q,H]
        y_inter = jnp.einsum("bin,bih,bhnp->bihp",
                             C_q, decay_in, h_prev)
        # new carried state
        seg_last = seg_q[:, -1:, :]                        # [B,1,H]
        decay_out = jnp.exp(jnp.clip(seg_last - seg_q, -60.0, 0.0))
        s_new = jnp.einsum("bjn,bjh,bjhp->bhnp", B_q, decay_out, xdt_q)
        h_new = h_prev * jnp.exp(jnp.clip(seg_last[:, 0, :], -60.0, 0.0)
                                 )[:, :, None, None] + s_new
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B_, nh, N, P), _F32)
    inputs = (xs_c.transpose(1, 0, 2, 3, 4), B_c.transpose(1, 0, 2, 3),
              C_c.transpose(1, 0, 2, 3), seg.transpose(1, 0, 2, 3),
              xdt.transpose(1, 0, 2, 3, 4))
    _, y_c = jax.lax.scan(chunk_body, h0, inputs)          # [nc,B,Q,H,P]
    y = y_c.transpose(1, 0, 2, 3, 4).reshape(B_, S, nh, P)
    y = y + xs.astype(_F32) * p["D"][None, None, :, None]
    y = y.reshape(B_, S, d_inner)
    y = rms_norm((y * jax.nn.silu(z.astype(_F32))).astype(x.dtype),
                 p["norm"], cfg.rms_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"],
                      preferred_element_type=_F32).astype(x.dtype)


# ------------------------------------------------------------------ decode
def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, nh, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, cfg.ssm_state, cfg.ssm_head_dim), _F32),
    }


def mamba2_decode_step(p: Param, x: Array, state: dict,
                       cfg: ModelConfig) -> tuple[Array, dict]:
    """x: [B, 1, d] one token; O(1) recurrent update."""
    B_, _, d = x.shape
    d_inner, nh, conv_dim = _dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"],
                        preferred_element_type=_F32).astype(x.dtype)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    window = jnp.concatenate([state["conv"], xBC], axis=1)  # [B,K,conv]
    conv_out = (jnp.einsum("bkc,kc->bc", window.astype(_F32),
                           p["conv_w"].astype(_F32)) + p["conv_b"].astype(_F32))
    xBC_t = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    new_conv = window[:, 1:, :]

    xs = xBC_t[..., :d_inner].reshape(B_, nh, P).astype(_F32)
    Bm = xBC_t[:, 0, d_inner:d_inner + N].astype(_F32)
    Cm = xBC_t[:, 0, d_inner + N:].astype(_F32)
    dt1 = jax.nn.softplus(dt[:, 0, :].astype(_F32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt1 * A[None, :])                      # [B,H]

    ssm = state["ssm"] * decay[:, :, None, None] \
        + jnp.einsum("bn,bh,bhp->bhnp", Bm, dt1, xs)
    y = jnp.einsum("bn,bhnp->bhp", Cm, ssm) \
        + xs * p["D"][None, :, None]
    y = y.reshape(B_, 1, d_inner)
    y = rms_norm((y * jax.nn.silu(z.astype(_F32))).astype(x.dtype),
                 p["norm"], cfg.rms_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"],
                     preferred_element_type=_F32).astype(x.dtype)
    return out, {"conv": new_conv, "ssm": ssm}
