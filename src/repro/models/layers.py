"""Shared neural layers: norms, RoPE, blockwise attention, SwiGLU, MoE.

Pure-jnp implementations built for TPU lowering:
  * attention is *blockwise* (online-softmax over KV chunks inside
    ``lax.scan``) so 32k-sequence prefill never materializes an S×S score
    tensor — the XLA path mirrors the Pallas flash kernel's tiling;
  * sliding-window attention only visits the KV blocks inside the window;
  * MoE uses capacity-based one-hot dispatch einsums (GShard-style) so
    expert parallelism shards cleanly over the ``model`` mesh axis.

All matmuls accumulate in fp32 (``preferred_element_type``) with bf16
operands, matching MXU semantics.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

__all__ = [
    "rms_norm", "apply_rope", "rope_freqs", "blockwise_attention",
    "decode_attention", "swiglu", "moe_ffn", "dense_init", "Param",
]

Array = jax.Array
_F32 = jnp.float32


# --------------------------------------------------------------------- init
def dense_init(key: Array, shape: tuple[int, ...], dtype,
               scale: float | None = None) -> Array:
    """Truncated-normal fan-in init (LM standard)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, _F32)
            * std).astype(dtype)


Param = dict  # nested-dict parameter trees


# -------------------------------------------------------------------- norms
def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(_F32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(_F32))).astype(dtype)


# --------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=_F32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., S, H, D] (D even), positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    angles = positions[..., None].astype(_F32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(_F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
def _attn_block(q, k, v, *, mask, scale):
    """One (q-block, kv-block) tile: returns (scores_max, exp-sums, pv)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=_F32) * scale
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1)                            # [b,h,q]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                            # [b,h,q]
    pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                    preferred_element_type=_F32)
    return m, l, pv


def blockwise_attention(q: Array, k: Array, v: Array, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 512, block_kv: int = 512,
                        scale: float | None = None) -> Array:
    """Memory-bounded attention: online softmax over KV blocks.

    q: [B, Sq, Hq, D]; k/v: [B, Skv, Hkv, Dk/Dv] with Hq % Hkv == 0.
    Never materializes more than [B, H, block_q, block_kv] scores.
    Causal blocks beyond the diagonal (and outside the SWA window) are
    *visited but fully masked*; the Pallas kernel and the triangular
    schedule (§Perf) skip them.
    """
    from repro.distributed.ctx import constrain
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    # head-sharded, sequence-gathered inside attention (one all-gather per
    # layer instead of per flash tile — Megatron-SP schedule)
    q = constrain(q, "attn_qkv")
    k = constrain(k, "attn_qkv")
    v = constrain(v, "attn_qkv")

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    nq = -(-Sq // block_q)
    nk = -(-Skv // block_kv)
    pad_q = nq * block_q - Sq
    pad_k = nk * block_kv - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    qb = q.reshape(B, nq, block_q, Hq, D).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(B, nk, block_kv, Hq, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, block_kv, Hq, Dv).transpose(1, 0, 2, 3, 4)

    q_pos = jnp.arange(nq * block_q).reshape(nq, block_q)
    k_pos = jnp.arange(nk * block_kv).reshape(nk, block_kv)
    valid_k = (k_pos < Skv)

    def per_q_block(qi, q_tile):
        # scan over kv blocks with running (m, l, acc)
        m0 = jnp.full((B, Hq, block_q), -1e30, _F32)
        l0 = jnp.zeros((B, Hq, block_q), _F32)
        a0 = jnp.zeros((B, block_q, Hq, Dv), _F32)

        # rematerialized tile body: the [B,H,bq,bk] fp32 score/prob tiles
        # are recomputed in the backward pass (flash-attention semantics)
        # instead of being stored per (q,kv) tile pair.
        @jax.checkpoint
        def body(carry, inputs):
            m_prev, l_prev, acc = carry
            k_tile, v_tile, kp, kv_valid = inputs
            mask = kv_valid[None, None, None, :]
            if causal:
                mask = mask & (kp[None, None, None, :]
                               <= q_pos[qi][None, None, :, None])
            if window > 0:
                mask = mask & (kp[None, None, None, :]
                               > q_pos[qi][None, None, :, None] - window)
            m_blk, l_blk, pv = _attn_block(q_tile, k_tile, v_tile,
                                           mask=mask, scale=scale)
            m_new = jnp.maximum(m_prev, m_blk)
            c_prev = jnp.exp(m_prev - m_new)
            c_blk = jnp.exp(m_blk - m_new)
            l_new = l_prev * c_prev + l_blk * c_blk
            acc = acc * c_prev.transpose(0, 2, 1)[..., None] \
                + pv * c_blk.transpose(0, 2, 1)[..., None]
            return (m_new, l_new, acc), None

        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      (kb, vb, k_pos, valid_k))
        out = acc / jnp.maximum(l.transpose(0, 2, 1)[..., None], 1e-30)
        return out.astype(q.dtype)

    out_blocks = jax.lax.map(
        lambda args: jax.checkpoint(per_q_block)(*args),
        (jnp.arange(nq), qb))
    out = out_blocks.transpose(1, 0, 2, 3, 4).reshape(B, nq * block_q, Hq, Dv)
    return out[:, :Sq]


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cache_len: Array, *, window: int = 0,
                     scale: float | None = None) -> Array:
    """Single-step decode: q [B, 1, Hq, D] over caches [B, Smax, Hkv, D].

    ``cache_len`` [B] masks the valid prefix.  The fp32 softmax runs over
    the (possibly sharded) Smax axis — GSPMD turns the reductions into the
    split-KV (flash-decoding) schedule when Smax is sharded over ``model``.
    """
    B, Smax, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    if scale is None:
        scale = 1.0 / np.sqrt(q.shape[-1])
    rep = Hq // Hkv
    if rep > 1:
        k_cache = jnp.repeat(k_cache, rep, axis=2)
        v_cache = jnp.repeat(v_cache, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache,
                   preferred_element_type=_F32) * scale
    pos = jnp.arange(Smax)[None, None, None, :]
    mask = pos < cache_len[:, None, None, None]
    if window > 0:
        mask = mask & (pos >= cache_len[:, None, None, None] - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=_F32)
    return out.astype(q.dtype)


# -------------------------------------------------------------------- FFNs
def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    from repro.distributed.ctx import constrain
    g = jnp.einsum("bsd,df->bsf", x, w_gate, preferred_element_type=_F32)
    u = jnp.einsum("bsd,df->bsf", x, w_up, preferred_element_type=_F32)
    h = constrain((jax.nn.silu(g) * u).astype(x.dtype), "mlp_mid")
    return jnp.einsum("bsf,fd->bsd", h, w_down,
                      preferred_element_type=_F32).astype(x.dtype)


def moe_ffn(x: Array, p: Param, cfg: ModelConfig, *, ep: int = 1) -> Array:
    """Capacity-based top-k MoE — *grouped local* dispatch (GShard groups).

    x: [B, S, d].  Each batch row is a routing group: router, position
    cumsum, capacity and the scatter/gather all happen *within* a group, so
    dispatch needs no cross-device coordination (a global-token position
    cumsum serializes across shards — GSPMD resolved it by all-reducing
    multi-GB fp32 expert buffers per layer; §Perf iterations M1/M2).
    Expert buffers [B(groups), E, c, d] shard (data, model, …): every
    device computes its (group-shard × expert-shard) GEMM block locally.

    Experts are padded to a multiple of the EP degree; padded experts get
    -inf router logits so they never receive tokens.  Capacity is
    per-group: c = ceil(cf · S · k / E) (standard GShard semantics).
    """
    from repro.distributed.ctx import constrain
    B, S, d = x.shape
    E = p["w_gate"].shape[0]                    # padded expert count
    k = cfg.top_k

    logits = jnp.einsum("bsd,de->bse", x, p["router"],
                        preferred_element_type=_F32)
    if E > cfg.n_experts:                       # mask padded experts
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    gates, idx = jax.lax.top_k(logits, k)       # [B,S,k]
    gates = jax.nn.softmax(gates, axis=-1)

    capacity = max(int(np.ceil(cfg.capacity_factor * S * k / E)), 4)
    onehot = jax.nn.one_hot(idx, E, dtype=_F32)               # [B,S,k,E]
    per_tok = onehot.sum(2)                                   # [B,S,E]
    pos = jnp.cumsum(per_tok, axis=1) - per_tok               # [B,S,E]
    pos_k = jnp.einsum("bske,bse->bsk", onehot, pos).astype(jnp.int32)
    keep = pos_k < capacity                                   # [B,S,k]
    gates = jnp.where(keep, gates, 0.0)

    # group-local scatter into [B, E*c, d] — dropped tokens use an
    # out-of-bounds index with mode="drop"/fill (an explicit drop-slot
    # concat on the expert-sharded axis forced full-tensor gathers: M4)
    dest = jnp.where(keep, idx * capacity + pos_k, E * capacity)
    src = jnp.broadcast_to(x[:, :, None, :],
                           (B, S, k, d)).reshape(B, S * k, d)
    dflat = dest.reshape(B, S * k)

    def row_scatter(dst_row, src_row):
        buf = jnp.zeros((E * capacity, d), x.dtype)
        return buf.at[dst_row].add(src_row, mode="drop")

    xe = jax.vmap(row_scatter)(dflat, src)                    # [B,E*c,d]
    xe = constrain(xe.reshape(B, E, capacity, d), "moe_xe")

    # the CPU executor lacks a bf16×bf16→f32 thunk for batched dots: upcast
    # operands off-TPU (tests); TPU lowering keeps bf16 MXU operands.
    if jax.default_backend() == "tpu":
        xe_op, wg, wu, wd = xe, p["w_gate"], p["w_up"], p["w_down"]
    else:
        xe_op = xe.astype(_F32)
        wg, wu, wd = (p["w_gate"].astype(_F32), p["w_up"].astype(_F32),
                      p["w_down"].astype(_F32))
    g = jnp.einsum("becd,edf->becf", xe_op, wg,
                   preferred_element_type=_F32)
    u = jnp.einsum("becd,edf->becf", xe_op, wu,
                   preferred_element_type=_F32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    ye = jnp.einsum("becf,efd->becd", h.astype(xe_op.dtype), wd,
                    preferred_element_type=_F32).astype(x.dtype)
    ye = constrain(ye, "moe_xe")

    # group-local gather + gate combine (OOB -> 0, matching dropped gates)
    ye_flat = ye.reshape(B, E * capacity, d)
    y_tk = jnp.take_along_axis(ye_flat, dflat[:, :, None], axis=1,
                               mode="fill", fill_value=0)
    y = jnp.einsum("bskd,bsk->bsd",
                   y_tk.reshape(B, S, k, d).astype(_F32),
                   gates).astype(x.dtype)

    if cfg.shared_d_ff:
        y = y + swiglu(x, p["shared_gate"], p["shared_up"], p["shared_down"])
    return y
