"""Model configuration for every assigned architecture family.

One ``ModelConfig`` describes dense / MoE / SSM / hybrid / enc-dec LMs with
the attention flavours the pool requires (GQA, MLA, SWA, qk-norm).  Derived
fields handle TPU divisibility adaptation (vocab padding to x256, MoE expert
padding to the expert-parallel degree, KV-head repetition up to the TP
degree) — all padding is masked out of losses and routing.
"""
from __future__ import annotations

import dataclasses
import enum

__all__ = ["AttnKind", "Family", "ModelConfig", "ShapeConfig", "SHAPES"]


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    ENCDEC = "encdec"


class AttnKind(str, enum.Enum):
    GQA = "gqa"          # grouped-query (covers MHA when n_kv == n_heads)
    MLA = "mla"          # multi-head latent attention (DeepSeek/MiniCPM3)
    SWA = "swa"          # sliding-window GQA (Mistral-style)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention flavour
    attn: AttnKind = AttnKind.GQA
    window: int = 0                  # SWA window (0 = full)
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True

    # MoE
    n_experts: int = 0               # routed experts (0 = dense FFN)
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0             # per-expert hidden (fine-grained MoE)
    shared_d_ff: int = 0
    capacity_factor: float = 1.25

    # MLA (only when attn == MLA)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2) / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0              # hybrid: shared attn block period (layers)

    # enc-dec
    n_enc_layers: int = 0            # 0 = decoder-only
    frontend_stub: bool = False      # audio/vision frontend provides embeddings

    # numerics / runtime
    dtype: str = "bfloat16"
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    remat: str = "none"              # none | full | dots
    # technique applicability note (DESIGN.md §4)
    sub_quadratic: bool = False      # can run long_500k decode

    # ------------------------------------------------------------- derived
    def padded_vocab(self, multiple: int = 256) -> int:
        return _round_up(self.vocab_size, multiple)

    def padded_experts(self, ep: int = 16) -> int:
        return _round_up(self.n_experts, ep) if self.n_experts else 0

    def padded_heads(self, tp: int = 16) -> int:
        """q heads padded up so tp | n_heads (minicpm3: 40 -> 48)."""
        if self.n_heads % tp == 0:
            return self.n_heads
        return _round_up(self.n_heads, tp)

    def kv_repeat(self, tp: int = 16) -> int:
        """Repeat factor so each TP shard owns whole KV heads (GQA -> TP)."""
        if self.n_kv_heads >= tp:
            return 1
        rep = tp // self.n_kv_heads
        if self.n_kv_heads * rep != tp:
            rep = _round_up(tp, self.n_kv_heads) // self.n_kv_heads
        return rep

    @property
    def qk_head_dim(self) -> int:
        if self.attn == AttnKind.MLA:
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim

    @property
    def v_dim(self) -> int:
        if self.attn == AttnKind.MLA:
            return self.v_head_dim
        return self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Approximate parameter count (for 6ND model-FLOPs accounting)."""
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        v = self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in (Family.DENSE, Family.MOE, Family.ENCDEC):
            if self.attn == AttnKind.MLA:
                qk = self.qk_nope_dim + self.qk_rope_dim
                per_layer += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qk
                per_layer += d * (self.kv_lora_rank + self.qk_rope_dim)
                per_layer += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                per_layer += self.n_heads * self.v_head_dim * d
            else:
                per_layer += d * self.n_heads * self.head_dim * 2  # wq, wo
                per_layer += d * self.n_kv_heads * self.head_dim * 2
        if self.family == Family.MOE:
            per_layer += d * self.n_experts  # router
            per_layer += 3 * d * self.expert_d_ff * self.n_experts
            per_layer += 3 * d * self.shared_d_ff
        elif self.family in (Family.DENSE, Family.ENCDEC):
            per_layer += 3 * d * ff
        if self.family in (Family.SSM, Family.HYBRID):
            di, ns = self.ssm_d_inner, self.ssm_state
            ssm = d * (2 * di + 2 * ns + self.ssm_n_heads) + di * d
            per_layer = ssm
        total = emb + L * per_layer
        if self.family == Family.HYBRID and self.attn_every:
            # one shared attention+FFN block (single copy)
            total += d * self.n_heads * self.head_dim * 2 \
                + d * self.n_kv_heads * self.head_dim * 2 + 3 * d * ff
        if self.family == Family.ENCDEC:
            # decoder mirror of encoder + cross-attention
            total += self.n_enc_layers * (per_layer + d * self.n_heads * self.head_dim * 2
                                          + d * self.n_kv_heads * self.head_dim * 2)
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        if self.family != Family.MOE:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        ffn = 3 * d * self.expert_d_ff * self.top_k + 3 * d * self.shared_d_ff
        return int(emb + L * (attn + ffn + d * self.n_experts))
